"""L1 kernel tests: Pallas kernels vs the scalar-loop spec oracles.

Bitwise assertions where the spec promises bitwise behaviour; hypothesis
sweeps shapes and values including adversarial magnitudes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.ref import (
    matmul_seq_fma_ref,
    matmul_seq_ref,
    softmax_rows_ref,
    sum_pairwise_ref,
    sum_seq_ref,
)
from compile.kernels.repmatmul import matmul_seq_scan, repmatmul
from compile.kernels.repsoftmax import repsoftmax_rows
from compile.kernels.repsum import repsum_sequential, sum_pairwise_spec
from compile.kernels.repexp import exp_fixed_f64


def rng_array(shape, seed, scale=2.0):
    r = np.random.default_rng(seed)
    return (r.random(shape, dtype=np.float32) - 0.5) * scale


def assert_bitwise(a, b, msg=""):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    ab, bb = a.view(np.uint32), b.view(np.uint32)
    if not np.array_equal(ab, bb):
        idx = np.argwhere(ab != bb)[0]
        raise AssertionError(
            f"{msg} first bit mismatch at {idx}: {a[tuple(idx)]} vs {b[tuple(idx)]}"
        )


class TestRepMatmul:
    def test_matches_fma_reference_bitwise(self):
        # XLA CPU contracts to FMA (paper §3.2.4 enables contraction) —
        # the kernel implements the sequential-k *FMA* spec.
        a = rng_array((7, 33), 1)
        b = rng_array((33, 5), 2)
        got = np.asarray(repmatmul(jnp.array(a), jnp.array(b)))
        want = matmul_seq_fma_ref(a, b)
        assert_bitwise(got, want, "repmatmul vs fma ref")

    def test_close_to_unfused_reference(self):
        # the unfused spec is the *other* named variant; ≤ a few ulp apart
        a = rng_array((5, 40), 21)
        b = rng_array((40, 4), 22)
        got = np.asarray(repmatmul(jnp.array(a), jnp.array(b)))
        want = matmul_seq_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_scan_variant_matches_pallas_bitwise(self):
        a = rng_array((6, 50), 3)
        b = rng_array((50, 9), 4)
        p = np.asarray(repmatmul(jnp.array(a), jnp.array(b)))
        s = np.asarray(matmul_seq_scan(jnp.array(a), jnp.array(b)))
        assert_bitwise(p, s, "pallas vs scan")

    def test_repeated_eval_is_bit_identical(self):
        a = rng_array((5, 64), 5)
        b = rng_array((64, 5), 6)
        x = np.asarray(repmatmul(jnp.array(a), jnp.array(b)))
        y = np.asarray(repmatmul(jnp.array(a), jnp.array(b)))
        assert_bitwise(x, y, "run-to-run")

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 6),
        k=st.integers(1, 24),
        n=st.integers(1, 6),
        seed=st.integers(0, 2**16),
        scale=st.sampled_from([1e-3, 1.0, 1e3]),
    )
    def test_hypothesis_shapes_and_scales(self, m, k, n, seed, scale):
        a = rng_array((m, k), seed, scale)
        b = rng_array((k, n), seed + 1, scale)
        got = np.asarray(repmatmul(jnp.array(a), jnp.array(b)))
        want = matmul_seq_fma_ref(a, b)
        assert_bitwise(got, want, f"m={m} k={k} n={n}")

    def test_identity(self):
        a = rng_array((4, 4), 9)
        eye = np.eye(4, dtype=np.float32)
        got = np.asarray(repmatmul(jnp.array(a), jnp.array(eye)))
        assert_bitwise(got, a, "A @ I")


class TestRepSum:
    def test_sequential_matches_ref_bitwise(self):
        x = rng_array((1000,), 10, 100.0)
        got = np.asarray(repsum_sequential(jnp.array(x)))[0]
        want = sum_seq_ref(x)
        assert np.float32(got).view(np.uint32) == want.view(np.uint32)

    def test_pairwise_matches_ref_bitwise(self):
        for n in [1, 7, 8, 9, 16, 100, 1000, 4096]:
            x = rng_array((n,), 11 + n, 10.0)
            got = np.float32(np.asarray(sum_pairwise_spec(jnp.array(x))))
            want = sum_pairwise_ref(x)
            assert got.view(np.uint32) == want.view(np.uint32), f"n={n}"

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 300), seed=st.integers(0, 2**16))
    def test_hypothesis_sequential(self, n, seed):
        x = rng_array((n,), seed, 1e4)
        got = np.float32(np.asarray(repsum_sequential(jnp.array(x)))[0])
        want = sum_seq_ref(x)
        assert got.view(np.uint32) == want.view(np.uint32)

    def test_orders_differ_but_each_is_stable(self):
        x = rng_array((4096,), 12, 1e6)
        s = np.float32(np.asarray(repsum_sequential(jnp.array(x)))[0])
        p = np.float32(np.asarray(sum_pairwise_spec(jnp.array(x))))
        # distinct APIs may differ in bits (usually do on wild data) …
        assert abs(float(s) - float(p)) < 1e3
        # … but each is deterministic
        s2 = np.float32(np.asarray(repsum_sequential(jnp.array(x)))[0])
        assert s.view(np.uint32) == s2.view(np.uint32)


class TestRepSoftmax:
    def test_rows_sum_to_one_and_match_ref(self):
        x = rng_array((8, 32), 13, 8.0)
        got = np.asarray(repsoftmax_rows(jnp.array(x)))
        want = softmax_rows_ref(x)
        np.testing.assert_allclose(got, want, rtol=2e-6, atol=2e-7)
        np.testing.assert_allclose(got.sum(axis=1), 1.0, rtol=1e-5)

    def test_bit_stable_within_backend(self):
        x = rng_array((4, 16), 14, 5.0)
        a = np.asarray(repsoftmax_rows(jnp.array(x)))
        b = np.asarray(repsoftmax_rows(jnp.array(x)))
        assert_bitwise(a, b, "softmax run-to-run")

    def test_shift_invariance_bitwise(self):
        # shifting logits by a constant leaves x - max identical, provided
        # the shifted values are exactly representable: use multiples of
        # 1/256 so that +16 is exact in f32
        r = np.random.default_rng(15)
        x = (r.integers(-1024, 1024, (3, 10)) / 256.0).astype(np.float32)
        a = np.asarray(repsoftmax_rows(jnp.array(x)))
        b = np.asarray(repsoftmax_rows(jnp.array(x + np.float32(16.0))))
        assert_bitwise(a, b, "shift invariance")


class TestExpFixed:
    def test_matches_numpy_exp_closely(self):
        x = rng_array((512,), 16, 30.0)
        got = np.asarray(exp_fixed_f64(jnp.array(x)))
        want = np.exp(x.astype(np.float64)).astype(np.float32)
        # both accurate; CR-vs-libm may differ by 1 ulp
        np.testing.assert_allclose(got, want, rtol=3e-7)

    def test_deterministic(self):
        x = rng_array((512,), 17, 50.0)
        a = np.asarray(exp_fixed_f64(jnp.array(x)))
        b = np.asarray(exp_fixed_f64(jnp.array(x)))
        assert_bitwise(a, b, "exp run-to-run")

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**16), scale=st.sampled_from([0.1, 10.0, 80.0]))
    def test_hypothesis_accuracy(self, seed, scale):
        x = rng_array((64,), seed, scale)
        got = np.asarray(exp_fixed_f64(jnp.array(x))).astype(np.float64)
        want = np.exp(x.astype(np.float64))
        ok = np.isfinite(want)
        np.testing.assert_allclose(got[ok], want[ok], rtol=4e-7)
