"""The committed golden fixture must stay reproducible from the
specification emulator — this is the Python side of the bit-exactness
conformance suite (the Rust side is rust/tests/golden_vectors.rs)."""

import importlib.util
import os
import sys

import pytest

_TOOLS = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "tools"
)


def _load_generator():
    spec = importlib.util.spec_from_file_location(
        "gen_golden_vectors", os.path.join(_TOOLS, "gen_golden_vectors.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def gen():
    return _load_generator()


def test_rounding_helpers_selftest(gen):
    gen.selftest()


def test_committed_fixture_matches_recomputation(gen):
    if not gen.FIXTURE.exists():
        pytest.skip("fixture not generated yet")
    entries = gen.compute_entries()
    on_disk = {}
    for line in gen.FIXTURE.read_text().splitlines():
        if line.startswith("#") or not line.strip():
            continue
        k, v = line.split()
        on_disk[k] = v
    assert on_disk == entries


def test_fixture_inputs_entry_guards_lockstep(gen):
    # the "inputs" entry must hash the LCG streams themselves, so a
    # generator/Rust drift is distinguishable from a kernel regression
    entries = gen.compute_entries()
    assert "inputs" in entries
    assert len(entries["inputs"]) == 64
    assert all(len(v) == 64 for v in entries.values())
