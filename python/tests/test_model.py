"""L2 model tests: shapes, determinism, and that the AOT train step
actually learns."""

import numpy as np
import jax.numpy as jnp

from compile import model


def params(seed=0):
    r = np.random.default_rng(seed)
    w1 = (r.random((64, 32), dtype=np.float32) - 0.5) * 0.3
    b1 = np.zeros(32, np.float32)
    w2 = (r.random((32, 10), dtype=np.float32) - 0.5) * 0.3
    b2 = np.zeros(10, np.float32)
    return w1, b1, w2, b2


def batch(seed=1):
    r = np.random.default_rng(seed)
    x = r.random((16, 64), dtype=np.float32)
    y = np.zeros((16, 10), np.float32)
    labels = r.integers(0, 10, 16)
    y[np.arange(16), labels] = 1.0
    return x, y


def test_forward_shapes():
    w1, b1, w2, b2 = params()
    x, _ = batch()
    (logits,) = model.mlp_forward(*(jnp.array(v) for v in (x, w1, b1, w2, b2)))
    assert logits.shape == (16, 10)
    (probs,) = model.mlp_forward_softmax(*(jnp.array(v) for v in (x, w1, b1, w2, b2)))
    np.testing.assert_allclose(np.asarray(probs).sum(axis=1), 1.0, rtol=1e-5)


def test_forward_deterministic():
    w1, b1, w2, b2 = params(2)
    x, _ = batch(3)
    args = tuple(jnp.array(v) for v in (x, w1, b1, w2, b2))
    (a,) = model.mlp_forward(*args)
    (b,) = model.mlp_forward(*args)
    assert np.array_equal(
        np.asarray(a).view(np.uint32), np.asarray(b).view(np.uint32)
    )


def test_train_step_learns():
    w1, b1, w2, b2 = params(4)
    x, y = batch(5)
    lr = jnp.float32(0.5)
    losses = []
    p = tuple(jnp.array(v) for v in (w1, b1, w2, b2))
    for _ in range(30):
        loss, *p = model.mlp_train_step(jnp.array(x), jnp.array(y), *p, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_train_step_deterministic():
    w1, b1, w2, b2 = params(6)
    x, y = batch(7)
    lr = jnp.float32(0.1)
    out1 = model.mlp_train_step(
        *(jnp.array(v) for v in (x, y, w1, b1, w2, b2)), lr
    )
    out2 = model.mlp_train_step(
        *(jnp.array(v) for v in (x, y, w1, b1, w2, b2)), lr
    )
    for a, b in zip(out1, out2):
        assert np.array_equal(
            np.asarray(a).view(np.uint32).ravel(),
            np.asarray(b).view(np.uint32).ravel(),
        )
