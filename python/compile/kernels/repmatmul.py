"""Reproducible GEMM as a Pallas kernel (paper §3.2.2).

Specification (shared bit-for-bit with `rust/src/tensor/matmul.rs`):
``C[i,j] = Σ_k A[i,k]·B[k,j]`` with the k-reduction **strictly
sequential**. The k-loop is a ``fori_loop`` carried dependency, which no
compiler may reassociate — the Pallas/TPU translation of the paper's
"one CUDA thread per output element, no atomics" design.

Empirical note (pinned by the tests): XLA CPU contracts the multiply+add
into a single **FMA** — precisely the contraction the paper *enables*
(§3.2.4: FMA has higher precision and performance and is itself an
IEEE-correctly-rounded op). The artifact therefore implements the
``matmul_fma`` spec; its bit-exact Rust partner is
``tensor::matmul_fma`` / ``rnum::dot::dot_strided_fma`` (experiment E6
asserts that equality).

Hardware adaptation (DESIGN.md §1): the grid iterates output *rows*
(VMEM-tiled via BlockSpec); within a row all N output columns accumulate
in parallel lanes while each column's reduction order stays sequential —
order-invariant parallelism. The MXU is deliberately not used: systolic
accumulation order is unspecified, exactly the hazard the paper's §4
names for low-precision units.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO (see /opt/xla-example).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def repmatmul(a, b):
    """Sequential-k reproducible matmul: (m,k) x (k,n) -> (m,n), f32."""
    m, kdim = a.shape
    k2, n = b.shape
    assert kdim == k2, f"shape mismatch {a.shape} x {b.shape}"

    def kernel(a_ref, b_ref, o_ref):
        arow = a_ref[0, :]  # (k,)
        bmat = b_ref[...]  # (k,n)

        def body(kk, acc):
            # loop-carried multiply-add; XLA contracts this to FMA (see
            # module docs) — the RepDL sequential-k FMA spec
            return acc + arow[kk] * bmat[kk, :]

        o_ref[0, :] = jax.lax.fori_loop(0, kdim, body, jnp.zeros((n,), jnp.float32))

    return pl.pallas_call(
        kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, kdim), lambda i: (i, 0)),
            pl.BlockSpec((kdim, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def matmul_seq_scan(a, b):
    """The same sequential-k spec in plain JAX (scan-based) — used by the
    differentiable L2 model (pallas_call has no automatic VJP)."""
    kdim = a.shape[1]
    n = b.shape[1]

    def body(acc, k):
        return acc + a[:, k][:, None] * b[k, :][None, :], None

    acc0 = jnp.zeros((a.shape[0], n), jnp.float32)
    out, _ = jax.lax.scan(body, acc0, jnp.arange(kdim))
    return out
