"""L1: Pallas kernels implementing the RepDL reproducible-op spec."""

from .repmatmul import repmatmul
from .repsum import repsum_sequential, sum_pairwise_spec
from .repsoftmax import repsoftmax_rows
from .repexp import exp_fixed_f64

__all__ = [
    "repmatmul",
    "repsum_sequential",
    "sum_pairwise_spec",
    "repsoftmax_rows",
    "exp_fixed_f64",
]
