"""Pure-numpy oracles for the kernel specs.

These implement the *specification text* as directly as possible (scalar
loops in float32), so a kernel matching them bitwise demonstrably
implements the spec rather than merely agreeing with another vectorised
implementation.
"""

import math

import numpy as np


def matmul_seq_ref(a, b):
    """Sequential-k, unfused multiply-add — scalar-loop reference."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    m, k = a.shape
    n = b.shape[1]
    out = np.zeros((m, n), np.float32)
    for i in range(m):
        for j in range(n):
            acc = np.float32(0.0)
            for kk in range(k):
                acc = np.float32(acc + np.float32(a[i, kk] * b[kk, j]))
            out[i, j] = acc
    return out


def matmul_seq_fma_ref(a, b):
    """Sequential-k with FMA contraction — the spec the XLA backend
    actually implements (it contracts mul+add; paper §3.2.4 allows it).

    Computed via ``math.fma`` in f64 then rounded to f32. For f32 inputs
    the product is exact in f64, so this equals true f32 FMA except in
    astronomically rare double-rounding ties — the test harness treats a
    ≤1-ulp discrepancy on <0.1% of elements as conforming.
    """
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    m, k = a.shape
    n = b.shape[1]
    out = np.zeros((m, n), np.float32)
    for i in range(m):
        for j in range(n):
            acc = np.float32(0.0)
            for kk in range(k):
                acc = np.float32(math.fma(float(a[i, kk]), float(b[kk, j]), float(acc)))
            out[i, j] = acc
    return out


def sum_seq_ref(x):
    """Strict left-to-right float32 sum."""
    acc = np.float32(0.0)
    for v in np.asarray(x, np.float32):
        acc = np.float32(acc + v)
    return acc


def sum_pairwise_ref(x):
    """Pairwise tree per the shared spec (base 8, split at 2^⌈lg n⌉⁻¹)."""
    x = np.asarray(x, np.float32)
    n = len(x)
    if n <= 8:
        return sum_seq_ref(x)
    p = 1
    while p * 2 < n:
        p *= 2
    return np.float32(sum_pairwise_ref(x[:p]) + sum_pairwise_ref(x[p:]))


def softmax_rows_ref(x):
    """Fixed-graph softmax with numpy exp (value reference only — the
    exp differs across libms, which is the paper's point; use allclose).
    Row max follows the canonical ``max_wins`` rule (NaN wins, first
    occurrence kept — rust/src/tensor/reduce.rs; identical to ``v > m``
    on the finite data this reference is used with)."""
    x = np.asarray(x, np.float32)
    out = np.zeros_like(x)
    for r in range(x.shape[0]):
        row = x[r]
        m = row[0]
        for v in row[1:]:
            if (np.isnan(v) and not np.isnan(m)) or v > m:
                m = v
        e = np.exp((row - m).astype(np.float32)).astype(np.float32)
        denom = sum_seq_ref(e)
        out[r] = e / denom
    return out
