"""Reproducible summation kernels (paper §3.2.2).

Two association orders, two APIs — the paper's rule:

* ``repsum_sequential``  — Pallas kernel, loop-carried scalar accumulator.
* ``sum_pairwise_spec``  — the pairwise tree with the *same shape spec* as
  ``rust/src/rnum/sum.rs``: base case = sequential over ≤8, split at the
  largest power of two below n. Host-recursion builds a fixed unrolled
  add-tree in the graph.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def repsum_sequential(x):
    """Strict left-to-right sum of a 1-D f32 vector -> shape (1,)."""
    (n,) = x.shape

    def kernel(x_ref, o_ref):
        v = x_ref[...]

        def body(i, acc):
            return acc + v[i]

        o_ref[0] = jax.lax.fori_loop(0, n, body, jnp.float32(0.0))

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(x)


def _split(n: int) -> int:
    """Largest power of two strictly below n (the shared tree spec)."""
    p = 1
    while p * 2 < n:
        p *= 2
    return p


def sum_pairwise_spec(x):
    """Pairwise-tree sum matching the Rust `sum_pairwise` spec bitwise."""
    n = x.shape[0]
    if n <= 8:
        # identical to Rust sum_sequential: start from +0.0 (this also
        # canonicalises a leading -0.0, matching the Rust bits exactly)
        acc = jnp.float32(0.0)
        for i in range(n):
            acc = acc + x[i]
        return acc
    m = _split(n)
    return sum_pairwise_spec(x[:m]) + sum_pairwise_spec(x[m:])
