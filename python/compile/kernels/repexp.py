"""exp as a *fixed f64 computation graph* (the cross-implementation
experiment's probe op).

This is bit-for-bit the same algorithm as the Rust fast path
(`rnum::exp::exp_f64`): Cody–Waite reduction against the split ln2
constants and a degree-14 nested Taylor polynomial, all in f64, rounded
once to f32. Every f64 op is IEEE-exact, so *if* XLA neither reassociates
nor FMA-contracts the graph (fast-math is off by default), the lowered
artifact reproduces the Rust bits exactly — experiment E6 verifies
which of these holds on this build.
"""

import jax.numpy as jnp

LOG2E = 1.4426950408889634
LN2_HI = 6.93147180369123816490e-01
LN2_LO = 1.90821492927058770002e-10

_INV = [
    1.0,
    0.5,
    0.333333333333333333,
    0.25,
    0.2,
    0.166666666666666667,
    0.142857142857142857,
    0.125,
    0.111111111111111111,
    0.1,
    0.0909090909090909091,
    0.0833333333333333333,
    0.0769230769230769231,
    0.0714285714285714286,
]


def exp_fixed_f64(x):
    """Elementwise e^x for f32 input via the fixed f64 graph."""
    xd = x.astype(jnp.float64)
    k = jnp.round(xd * LOG2E)
    r = (xd - k * LN2_HI) - k * LN2_LO
    p = 1.0 + r * _INV[13]
    for i in range(12, 0, -1):
        p = 1.0 + r * _INV[i] * p
    p = 1.0 + r * p
    y = p * jnp.exp2(k)  # 2^k with k integral is exact
    return y.astype(jnp.float32)
