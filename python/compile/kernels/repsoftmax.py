"""Reproducible row softmax as a Pallas kernel (paper §3.2.3).

The fixed graph matches `rust/src/nn/softmax.rs`: running first-max,
subtract, exp, **sequential** sum, divide. The exp is XLA's `exp` — a
platform-defined approximation — so cross-*implementation* bitwise
equality against the Rust softmax (which uses the correctly-rounded
`rexp`) is NOT expected for this op; the E6 harness measures and reports
the ULP gap instead. Within the XLA backend the kernel is bit-stable.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def repsoftmax_rows(x):
    """Row-wise softmax over a 2-D f32 array, fixed reduction orders."""
    rows, c = x.shape

    def kernel(x_ref, o_ref):
        v = x_ref[0, :]

        def maxbody(j, m):
            return jnp.maximum(m, v[j])

        m = jax.lax.fori_loop(1, c, maxbody, v[0])
        e = jnp.exp(v - m)

        def sumbody(j, acc):
            return acc + e[j]

        denom = jax.lax.fori_loop(0, c, sumbody, jnp.float32(0.0))
        o_ref[0, :] = e / denom

    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, c), jnp.float32),
        interpret=True,
    )(x)
