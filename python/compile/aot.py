"""AOT driver: lower every artifact to HLO **text** + write the manifest.

HLO text, NOT ``lowered.compile()`` / serialized protos: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.repexp import exp_fixed_f64
from .kernels.repmatmul import repmatmul
from .kernels.repsoftmax import repsoftmax_rows
from .kernels.repsum import repsum_sequential, sum_pairwise_spec


def to_hlo_text(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # (name, fn, input shapes, output shapes)
    B, NIN, H, C = 16, 64, 32, 10
    artifacts = [
        ("matmul_repro", lambda a, b: (repmatmul(a, b),),
         [(64, 128), (128, 32)], [(64, 32)]),
        ("matmul_repro_small", lambda a, b: (repmatmul(a, b),),
         [(4, 6), (6, 5)], [(4, 5)]),
        ("sum_seq", lambda x: (repsum_sequential(x),), [(4096,)], [(1,)]),
        ("sum_pairwise", lambda x: (sum_pairwise_spec(x).reshape(1),),
         [(4096,)], [(1,)]),
        ("softmax_repro", lambda x: (repsoftmax_rows(x),),
         [(32, 64)], [(32, 64)]),
        ("exp_fixed", lambda x: (exp_fixed_f64(x),), [(1024,)], [(1024,)]),
        ("mlp_fwd", model.mlp_forward,
         [(B, NIN), (NIN, H), (H,), (H, C), (C,)], [(B, C)]),
        ("mlp_fwd_softmax", model.mlp_forward_softmax,
         [(B, NIN), (NIN, H), (H,), (H, C), (C,)], [(B, C)]),
        ("mlp_train_step", model.mlp_train_step,
         [(B, NIN), (B, C), (NIN, H), (H,), (H, C), (C,), ()],
         [(), (NIN, H), (H,), (H, C), (C,)]),
    ]

    manifest = {"artifacts": []}
    for name, fn, ins, outs in artifacts:
        example = [spec(*s) for s in ins]
        text = to_hlo_text(fn, example)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [list(s) for s in ins],
                "outputs": [list(s) for s in outs],
            }
        )
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
