"""Build-time compile package: Pallas kernels (L1), JAX models (L2) and
the AOT lowering driver. Nothing in here runs at inference/training time —
the Rust coordinator executes the lowered HLO via PJRT."""

import jax

# The fixed-graph f64 ops (kernels/repexp.py) require real float64 —
# without this JAX silently truncates to f32 and the cross-implementation
# bitwise contract with the Rust f64 path breaks.
jax.config.update("jax_enable_x64", True)
