"""L2: JAX models built from the L1 kernel specs.

The inference paths call the Pallas kernels; the training path uses the
scan-based sequential-k matmul (same reduction-order spec) because
`pallas_call` has no automatic VJP. Everything here is lowered once by
`aot.py`; Python never runs at serving time.
"""

import jax
import jax.numpy as jnp

from .kernels.repmatmul import matmul_seq_scan, repmatmul
from .kernels.repsoftmax import repsoftmax_rows


def mlp_forward(x, w1, b1, w2, b2):
    """2-layer MLP forward with Pallas GEMMs: returns (logits,)."""
    h = repmatmul(x, w1) + b1
    h = jnp.maximum(h, 0.0)
    logits = repmatmul(h, w2) + b2
    return (logits,)


def mlp_forward_softmax(x, w1, b1, w2, b2):
    """MLP forward + reproducible softmax head: returns (probs,)."""
    (logits,) = mlp_forward(x, w1, b1, w2, b2)
    return (repsoftmax_rows(logits),)


def _mlp_loss(params, x, y_onehot):
    w1, b1, w2, b2 = params
    h = matmul_seq_scan(x, w1) + b1
    h = jnp.maximum(h, 0.0)
    logits = matmul_seq_scan(h, w2) + b2
    # fixed stable-CE graph: max-shift, exp, sequential-order sums are
    # XLA reductions here (deterministic within this backend)
    m = jnp.max(logits, axis=1, keepdims=True)
    z = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    logp = z - lse
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=1))


def mlp_train_step(x, y_onehot, w1, b1, w2, b2, lr):
    """One SGD step; returns (loss, w1', b1', w2', b2')."""
    loss, grads = jax.value_and_grad(_mlp_loss)((w1, b1, w2, b2), x, y_onehot)
    g1, gb1, g2, gb2 = grads
    return (
        loss,
        w1 - lr * g1,
        b1 - lr * gb1,
        w2 - lr * g2,
        b2 - lr * gb2,
    )
