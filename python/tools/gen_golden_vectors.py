#!/usr/bin/env python3
"""Generate the committed golden bit-exactness fixtures for the Rust
conformance suite (``rust/tests/golden_vectors.rs``).

Every RepDL op is a *specification*: sequential-k unfused GEMM, f32 FMA
GEMM, the pairwise summation tree, and the fixed softmax graph with
correctly-rounded ``rexp``. This script evaluates those specifications
independently of the Rust implementation:

* plain f32 ops        -> numpy float32 scalar arithmetic (IEEE-754 RNE),
* f32 FMA              -> libm ``fmaf`` via ctypes (correctly rounded),
* correctly-rounded exp -> 300-bit mpmath, rounded to f32 by exact
  integer round-to-nearest-even (ties cannot occur: exp of a nonzero
  dyadic rational is transcendental).

It then fingerprints the results with the same SHA-256 framing as
``rust/src/coordinator/hashing.rs`` (``hash_params`` /``hash_curve``)
and writes ``rust/tests/fixtures/golden_vectors.txt``. A cross-platform
CI run can therefore diff exact bits against a committed reference that
was *not* produced by the code under test.

Usage:
    python3 python/tools/gen_golden_vectors.py           # (re)write fixture
    python3 python/tools/gen_golden_vectors.py --check   # verify fixture
"""

from __future__ import annotations

import ctypes
import ctypes.util
import hashlib
import struct
import sys
from fractions import Fraction
from math import ldexp
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[2]
FIXTURE = REPO / "rust" / "tests" / "fixtures" / "golden_vectors.txt"

F32 = np.float32
_U64 = (1 << 64) - 1

# ---------------------------------------------------------------------------
# deterministic input generation — mirrors the LCG used by the Rust tests
# ---------------------------------------------------------------------------


def lcg_tensor(dims, seed, scale=1.0):
    """Bit-exact replica of the Rust test generator:
    s = s*6364136223846793005 + 1442695040888963407 (wrapping u64);
    value = (((s >> 40) as f32) / 2^24 - 0.5) * 2.0, then * scale.
    `scale` must be a power of two so the extra multiply is exact."""
    n = int(np.prod(dims)) if dims else 1
    s = seed
    out = np.empty(n, dtype=F32)
    half = F32(0.5)
    two = F32(2.0)
    inv = F32(1.0 / (1 << 24))  # exact: power of two
    sc = F32(scale)
    for i in range(n):
        s = (s * 6364136223846793005 + 1442695040888963407) & _U64
        v = F32(F32(s >> 40) * inv)  # division by 2^24 == exact multiply
        out[i] = F32(F32(F32(v - half) * two) * sc)
    return out.reshape(dims)


# ---------------------------------------------------------------------------
# f32 building blocks
# ---------------------------------------------------------------------------

_libm = ctypes.CDLL(ctypes.util.find_library("m") or "libm.so.6")
_libm.fmaf.restype = ctypes.c_float
_libm.fmaf.argtypes = [ctypes.c_float] * 3


def fmaf(a, b, c):
    """Correctly-rounded f32 fused multiply-add (libm)."""
    return F32(_libm.fmaf(float(a), float(b), float(c)))


def frac_to_f32(fr: Fraction) -> np.float32:
    """Round an exact rational to f32 with round-to-nearest-even."""
    if fr == 0:
        return F32(0.0)
    sign = F32(-1.0) if fr < 0 else F32(1.0)
    fr = abs(fr)
    num, den = fr.numerator, fr.denominator

    def scaled(e):  # fr * 2^-e, exact
        return Fraction(num, den << e) if e >= 0 else Fraction(num << -e, den)

    e = num.bit_length() - den.bit_length() - 24
    while scaled(e) >= (1 << 24):
        e += 1
    while scaled(e) < (1 << 23):
        e -= 1
    if e < -149:  # subnormal range
        e = -149
    s = scaled(e)
    q, rem = divmod(s.numerator, s.denominator)
    frac2 = Fraction(rem * 2, s.denominator)  # 2*remainder/den vs 1
    if frac2 > 1 or (frac2 == 1 and (q & 1)):
        q += 1
    if q == 1 << 24:
        q, e = 1 << 23, e + 1
    if e > 104:  # overflow to inf (not reachable for these fixtures)
        return F32(np.inf) * sign
    return F32(ldexp(q, e)) * sign


def rexp_f32(x: np.float32):
    """Correctly-rounded e^x for f32 — the `rnum::rexp` contract,
    evaluated via 300-bit mpmath + exact RNE rounding."""
    import mpmath

    x = F32(x)
    if np.isnan(x):
        return F32(np.nan)
    if x > F32(89.0):
        return F32(np.inf)
    if x < F32(-104.0):
        return F32(0.0)
    if x == 0:
        return F32(1.0)
    with mpmath.workprec(300):
        e = mpmath.exp(mpmath.mpf(float(x)))
        sign, man, exp, _ = e._mpf_
        fr = Fraction(man, 1) * Fraction(2) ** exp
        if sign:
            fr = -fr
    return frac_to_f32(fr)


def _mpf_to_frac(v) -> Fraction:
    sign, man, exp, _ = v._mpf_
    fr = Fraction(man, 1) * Fraction(2) ** exp
    return -fr if sign else fr


def rtanh_f32(x: np.float32):
    """Correctly-rounded tanh for f32 — the `rnum::rtanh` contract
    (rust/src/rnum/special.rs): NaN → NaN, ±0 preserved, |x| ≥ 10
    saturates to ±1 (1 − tanh 10 < ulp(1)/2, so the correctly-rounded
    value IS ±1), else 300-bit mpmath + exact RNE rounding."""
    import mpmath

    x = F32(x)
    if np.isnan(x):
        return F32(np.nan)
    if x == 0:
        return x  # ±0 preserved
    if abs(x) >= F32(10.0):
        return F32(np.copysign(1.0, x))
    with mpmath.workprec(300):
        fr = _mpf_to_frac(mpmath.tanh(mpmath.mpf(float(x))))
    return frac_to_f32(fr)


def rrsqrt_f32(x: np.float32):
    """Correctly-rounded 1/√x for f32 — the `rnum::rrsqrt` contract
    (rust/src/rnum/sqrt.rs): NaN/negative → NaN, ±0 → +inf, inf → 0,
    else 300-bit mpmath + exact RNE rounding (the exact 2^(2k) family
    falls out of correct rounding automatically)."""
    import mpmath

    x = F32(x)
    if np.isnan(x) or x < 0:
        return F32(np.nan)
    if x == 0:
        return F32(np.inf)
    if np.isinf(x):
        return F32(0.0)
    with mpmath.workprec(300):
        fr = _mpf_to_frac(1 / mpmath.sqrt(mpmath.mpf(float(x))))
    return frac_to_f32(fr)


# fixed f32 constants of the GELU tanh graph (rust/src/rnum/special.rs)
SQRT_2_OVER_PI = F32(0.7978846)
GELU_C = F32(0.044715)


def gelu_tanh_f32(x: np.float32):
    """GELU tanh graph (`rnum::rgelu_tanh`):
    `0.5·x·(1 + tanh(√(2/π)·(x + 0.044715·x³)))`, every op f32 RNE in
    the fixed order, tanh correctly rounded."""
    x = F32(x)
    x3 = F32(F32(x * x) * x)
    u = F32(SQRT_2_OVER_PI * F32(x + F32(GELU_C * x3)))
    th = rtanh_f32(u)
    return F32(F32(F32(0.5) * x) * F32(F32(1.0) + th))


# ---------------------------------------------------------------------------
# op specifications (scalar loops, fixed order — the paper's graphs)
# ---------------------------------------------------------------------------


def matmul_seq(a, b):
    """Sequential-k, unfused multiply-then-add."""
    m, k = a.shape
    n = b.shape[1]
    out = np.zeros((m, n), dtype=F32)
    for i in range(m):
        for j in range(n):
            acc = F32(0.0)
            for kk in range(k):
                acc = F32(acc + F32(a[i, kk] * b[kk, j]))
            out[i, j] = acc
    return out


def matmul_fma(a, b):
    """Sequential-k with true f32 FMA contraction."""
    m, k = a.shape
    n = b.shape[1]
    out = np.zeros((m, n), dtype=F32)
    for i in range(m):
        for j in range(n):
            acc = F32(0.0)
            for kk in range(k):
                acc = fmaf(a[i, kk], b[kk, j], acc)
            out[i, j] = acc
    return out


def sum_sequential(xs):
    acc = F32(0.0)
    for x in xs:
        acc = F32(acc + x)
    return acc


def _pairwise_split(n):
    """Largest power of two < n (shared tree spec: rust/src/rnum/sum.rs)."""
    return 1 << ((n - 1).bit_length() - 1)


def sum_pairwise(xs):
    if len(xs) <= 8:
        return sum_sequential(xs)
    m = _pairwise_split(len(xs))
    return F32(sum_pairwise(xs[:m]) + sum_pairwise(xs[m:]))


def max_wins(v, m):
    """The canonical comparison-reduction update rule
    (rust/src/tensor/reduce.rs `max_wins`): NaN beats every number,
    otherwise strictly-greater — so the first of equal maxima, and the
    first NaN, is kept. On finite inputs this is identical to the old
    plain ``v > m`` scan, which is why the committed fixtures did not
    change when the NaN-rule unification migration landed (DESIGN.md §8)."""
    return (np.isnan(v) and not np.isnan(m)) or v > m


def softmax_rows(x):
    """Fixed graph: row max (max_wins rule) -> subtract -> rexp ->
    sequential sum -> divide (rust/src/nn/softmax.rs)."""
    rows, c = x.shape
    out = np.zeros((rows, c), dtype=F32)
    for r in range(rows):
        m = x[r, 0]
        for v in x[r, 1:]:
            if max_wins(v, m):
                m = v
        denom = F32(0.0)
        for j in range(c):
            e = rexp_f32(F32(x[r, j] - m))
            out[r, j] = e
            denom = F32(denom + e)
        for j in range(c):
            out[r, j] = F32(out[r, j] / denom)
    return out


# ---------------------------------------------------------------------------
# model inference specifications (ISSUE 5) — mirror the Rust off-tape
# serving forwards op for op: nn::Linear::forward_infer_in,
# nn::layer_norm_forward, nn::attention_forward,
# Mlp::forward_infer_in, CharTransformer::forward_logits_infer_in
# ---------------------------------------------------------------------------


def add_rows(a, b):
    """Elementwise f32 add (Tensor::add_t, same-shape case)."""
    out = np.zeros(a.shape, F32)
    for idx in np.ndindex(a.shape):
        out[idx] = F32(a[idx] + b[idx])
    return out


def linear_forward(x, w, b):
    """nn::Linear off-tape forward: x·Wᵀ (sequential-k unfused GEMM —
    the transpose is layout-only) + broadcast bias add."""
    y = matmul_seq(x, np.ascontiguousarray(w.T))
    out = np.zeros(y.shape, F32)
    for i in range(y.shape[0]):
        for j in range(y.shape[1]):
            out[i, j] = F32(y[i, j] + b[j])
    return out


def layer_norm_rows(x, g, b, eps=F32(1e-5)):
    """nn::layer_norm_forward: per row, sequential mean sum, sequential
    squared-deviation sum (unfused), rrsqrt(var + eps), then x̂·γ + β."""
    rows, n = x.shape
    nn_ = F32(n)
    out = np.zeros((rows, n), F32)
    for r in range(rows):
        s = F32(0.0)
        for v in x[r]:
            s = F32(s + v)
        mu = F32(s / nn_)
        v2 = F32(0.0)
        for v in x[r]:
            dd = F32(v - mu)
            v2 = F32(v2 + F32(dd * dd))
        var = F32(v2 / nn_)
        rs = rrsqrt_f32(F32(var + eps))
        for j in range(n):
            xh = F32(F32(x[r, j] - mu) * rs)
            out[r, j] = F32(F32(xh * g[j]) + b[j])
    return out


def attention_forward(q, k, v, causal):
    """nn::attention_forward on (BH, T, Dh): per (head, query) row —
    unfused sequential QK dot · rrsqrt(dh), running max under max_wins
    seeded with −inf, rexp shift, sequential denominator, divide, then
    sequential P·V dots. Masked slots never enter any reduction."""
    bh, tt, dh = q.shape
    scale = rrsqrt_f32(F32(dh))
    out = np.zeros((bh, tt, dh), F32)
    for b in range(bh):
        for i in range(tt):
            jmax = i + 1 if causal else tt
            row = np.zeros(jmax, F32)
            m = F32(-np.inf)
            for j in range(jmax):
                acc = F32(0.0)
                for d in range(dh):
                    acc = F32(acc + F32(q[b, i, d] * k[b, j, d]))
                s = F32(acc * scale)
                row[j] = s
                if max_wins(s, m):
                    m = s
            denom = F32(0.0)
            for j in range(jmax):
                e = rexp_f32(F32(row[j] - m))
                row[j] = e
                denom = F32(denom + e)
            for j in range(jmax):
                row[j] = F32(row[j] / denom)
            for d in range(dh):
                acc = F32(0.0)
                for j in range(jmax):
                    acc = F32(acc + F32(row[j] * v[b, j, d]))
                out[b, i, d] = acc
    return out


# how many logical partial sums a row-split tensor-parallel layer always
# decomposes into — keep in lockstep with rust/src/nn/linear.rs
TP_LOGICAL_PARTS = 4


def tree_reduce_tensors(parts):
    """`rnum::fixed_tree_reduce` over element-wise f32 tensor partials:
    split at the largest power of two below n (`_pairwise_split`), left
    subtree first, one f32 RNE add per element at each internal node."""
    if len(parts) == 1:
        return parts[0]
    m = _pairwise_split(len(parts))
    return add_rows(tree_reduce_tensors(parts[:m]), tree_reduce_tensors(parts[m:]))


def sharded_linear_row(x, w, b):
    """Row-split tensor-parallel Linear (`Linear::pack_row_shard_in` +
    `reduce_row_partials`): k divides into TP_LOGICAL_PARTS equal
    contiguous logical segments, one bias-free sequential-k partial per
    segment, the partials combined in the fixed pairwise tree, bias
    added exactly once (one `+` per element) after the tree. A pure
    function of the layer shape — the identical graph at every
    tensor-parallel width, which is what the Rust side's TP {1, 2, 4}
    grids pin against this emulation."""
    k = x.shape[1]
    assert k % TP_LOGICAL_PARTS == 0, f"k {k} has no {TP_LOGICAL_PARTS}-segment split"
    sk = k // TP_LOGICAL_PARTS
    parts = []
    for g in range(TP_LOGICAL_PARTS):
        xs = np.ascontiguousarray(x[:, g * sk : (g + 1) * sk])
        ws = np.ascontiguousarray(w[:, g * sk : (g + 1) * sk].T)  # (sk, n)
        parts.append(matmul_seq(xs, ws))
    y = tree_reduce_tensors(parts)
    out = np.zeros(y.shape, F32)
    for i in range(y.shape[0]):
        for j in range(y.shape[1]):
            out[i, j] = F32(y[i, j] + b[j])
    return out


def mha_forward(x, in_w, in_b, out_w, out_b, heads, causal, out_proj=None):
    """nn::MultiheadAttention::forward_seq_infer_in: QKV projection,
    layout-only head split q/k/v[h,t,d] = qkv[t, c·D + h·Dh + d],
    attention core, layout-only merge, output projection. The sharded
    forward (`forward_seq_sharded_in`) differs only in `out_proj`: the
    per-head shard split is layout-only (each head keeps its graph, the
    merge is in fixed head order), so passing `sharded_linear_row`
    reproduces its bits."""
    tt, dim = x.shape
    dh = dim // heads
    qkv = linear_forward(x, in_w, in_b)  # (T, 3D)
    q = np.zeros((heads, tt, dh), F32)
    k = np.zeros((heads, tt, dh), F32)
    v = np.zeros((heads, tt, dh), F32)
    for c, dst in enumerate((q, k, v)):
        for h in range(heads):
            for t in range(tt):
                for d in range(dh):
                    dst[h, t, d] = qkv[t, c * dim + h * dh + d]
    o = attention_forward(q, k, v, causal)  # (H, T, Dh)
    y = np.zeros((tt, dim), F32)
    for h in range(heads):
        for t in range(tt):
            for d in range(dh):
                y[t, h * dh + d] = o[h, t, d]
    return (out_proj or linear_forward)(y, out_w, out_b)


def mlp_forward_gelu(x, layers):
    """Mlp::forward_infer_in with Act::Gelu: Linear → GELU between
    layers → Linear. `layers` is [(w, b), …]."""
    h = x
    for i, (w, b) in enumerate(layers):
        h = linear_forward(h, w, b)
        if i + 1 < len(layers):
            out = np.zeros(h.shape, F32)
            for idx in np.ndindex(h.shape):
                out[idx] = gelu_tanh_f32(h[idx])
            h = out
    return h


def mlp_forward_gelu_sharded(x, layers):
    """Mlp::forward_infer_sharded_in under the Megatron plan: even layer
    indices column-split (layout-only — bias and activation applied
    locally, element-wise, so identical bits to the unsharded layer),
    odd indices row-split through the fixed tree. Note the result is a
    *different* deterministic spec from `mlp_forward_gelu` (the odd
    layers' k-reduction associates as a 4-segment tree, not one
    sequential scan) — TP-invariant, but not unsharded-equal."""
    h = x
    for i, (w, b) in enumerate(layers):
        h = linear_forward(h, w, b) if i % 2 == 0 else sharded_linear_row(h, w, b)
        if i + 1 < len(layers):
            out = np.zeros(h.shape, F32)
            for idx in np.ndindex(h.shape):
                out[idx] = gelu_tanh_f32(h[idx])
            h = out
    return h


def transformer_param_shapes(cfg):
    """Parameter shapes in CharTransformer::params() order — the
    fixed traversal the Rust fixture test overwrites."""
    v, d, c, r = cfg["vocab"], cfg["dim"], cfg["context"], cfg["mlp_ratio"]
    shapes = [(v, d), (c, d)]  # tok_emb, pos_emb
    for _ in range(cfg["layers"]):
        shapes += [
            (d,), (d,),            # ln1 γ, β
            (3 * d, d), (3 * d,),  # attn in_proj w, b
            (d, d), (d,),          # attn out_proj w, b
            (d,), (d,),            # ln2 γ, β
            (r * d, d), (r * d,),  # fc1 w, b
            (d, r * d), (d,),      # fc2 w, b
        ]
    shapes += [(d,), (d,), (v, d), (v,)]  # ln_f γ, β; head w, b
    return shapes


def transformer_logits(params, ids, cfg, sharded=False):
    """CharTransformer::forward_logits_infer_in: embedding row lookup +
    positional rows (layout-only), pre-norm blocks (LN → causal MHA →
    residual, LN → GELU MLP → residual), final LN, head projection.

    With ``sharded=True`` this is `forward_logits_sharded_in` instead:
    the embedding / LayerNorm / residual graph is untouched (replicated,
    element-wise per row), attention shards per head (layout-only) with
    a row-split output projection, fc1 is column-split (layout-only),
    and fc2 and the LM head are row-split — each row-split k-reduction
    goes through the fixed 4-segment tree instead of one sequential
    scan, so the sharded logits are a different deterministic spec from
    the unsharded ones (TP-invariant, not unsharded-equal)."""
    row_proj = sharded_linear_row if sharded else linear_forward
    it = iter(params)
    tok, pos = next(it), next(it)
    tt, dim = len(ids), cfg["dim"]
    e = np.zeros((tt, dim), F32)
    for r, i in enumerate(ids):
        e[r] = tok[i]
    h = add_rows(e, pos[:tt])
    for _ in range(cfg["layers"]):
        ln1_w, ln1_b = next(it), next(it)
        in_w, in_b, out_w, out_b = next(it), next(it), next(it), next(it)
        ln2_w, ln2_b = next(it), next(it)
        fc1_w, fc1_b, fc2_w, fc2_b = next(it), next(it), next(it), next(it)
        a = layer_norm_rows(h, ln1_w, ln1_b)
        a = mha_forward(a, in_w, in_b, out_w, out_b, cfg["heads"], True, out_proj=row_proj)
        x = add_rows(h, a)
        g = layer_norm_rows(x, ln2_w, ln2_b)
        g = linear_forward(g, fc1_w, fc1_b)
        gg = np.zeros(g.shape, F32)
        for idx in np.ndindex(g.shape):
            gg[idx] = gelu_tanh_f32(g[idx])
        g = row_proj(gg, fc2_w, fc2_b)
        h = add_rows(x, g)
    ln_f_w, ln_f_b = next(it), next(it)
    head_w, head_b = next(it), next(it)
    h = layer_norm_rows(h, ln_f_w, ln_f_b)
    return row_proj(h, head_w, head_b)


# ---------------------------------------------------------------------------
# fingerprint framing — mirrors rust/src/coordinator/hashing.rs
# ---------------------------------------------------------------------------


def hash_params(tensors):
    """SHA-256 over (ndims u64-le, dims u64-le…, f32 bits le…) per tensor."""
    h = hashlib.sha256()
    for t in tensors:
        h.update(struct.pack("<Q", t.ndim))
        for d in t.shape:
            h.update(struct.pack("<Q", d))
        for v in t.reshape(-1):
            h.update(struct.pack("<I", np.frombuffer(F32(v).tobytes(), np.uint32)[0]))
    return h.hexdigest()


def hash_curve(values):
    """SHA-256 over f32 bit patterns (le)."""
    h = hashlib.sha256()
    for v in values:
        h.update(struct.pack("<I", np.frombuffer(F32(v).tobytes(), np.uint32)[0]))
    return h.hexdigest()


# ---------------------------------------------------------------------------
# fixture definition — keep in lockstep with rust/tests/golden_vectors.rs
# ---------------------------------------------------------------------------


# the transformer fixture's hyper-parameters — keep in lockstep with
# rust/tests/golden_vectors.rs (TransformerConfig literal there)
TRANSFORMER_CFG = {"vocab": 10, "dim": 8, "heads": 2, "layers": 2, "context": 6, "mlp_ratio": 2}
TRANSFORMER_IDS = [1, 4, 2, 9, 3, 7]
# LCG seed bases for the model fixtures (param i uses base + i); scale
# 0.5 is a power of two, so the extra multiply stays exact
MLP_PARAM_SEED = 2900
MLP_INPUT_SEED = 2950
TRANSFORMER_PARAM_SEED = 3000


def mlp_fixture_params():
    """[12, 16, 10] GELU MLP — Module::params order: (w, b) per layer."""
    shapes = [(16, 12), (16,), (10, 16), (10,)]
    flat = [lcg_tensor(s, MLP_PARAM_SEED + i, scale=0.5) for i, s in enumerate(shapes)]
    return flat, [(flat[0], flat[1]), (flat[2], flat[3])]


def transformer_fixture_params():
    shapes = transformer_param_shapes(TRANSFORMER_CFG)
    return [lcg_tensor(s, TRANSFORMER_PARAM_SEED + i, scale=0.5) for i, s in enumerate(shapes)]


def compute_entries():
    a = lcg_tensor((16, 32), 1001)
    b = lcg_tensor((32, 8), 1002)
    xs = lcg_tensor((1000,), 1003)
    sx = lcg_tensor((8, 32), 1004, scale=4.0)

    entries = {}
    entries["inputs"] = hash_params([a, b, xs, sx])
    entries["matmul_seq_16x32x8"] = hash_params([matmul_seq(a, b)])
    entries["matmul_fma_16x32x8"] = hash_params([matmul_fma(a, b)])
    entries["sum_sequential_1000"] = hash_curve([sum_sequential(xs)])
    entries["sum_pairwise_1000"] = hash_curve([sum_pairwise(xs)])
    entries["softmax_rows_8x32"] = hash_params([softmax_rows(sx)])

    # off-tape serving forwards (ISSUE 5): an input-lockstep hash over
    # the generated parameters, then the forward outputs themselves
    mlp_flat, mlp_layers = mlp_fixture_params()
    mx = lcg_tensor((4, 12), MLP_INPUT_SEED)
    entries["mlp_infer_params"] = hash_params(mlp_flat)
    entries["mlp_infer_gelu_4x10"] = hash_params([mlp_forward_gelu(mx, mlp_layers)])

    tp = transformer_fixture_params()
    entries["transformer_infer_params"] = hash_params(tp)
    entries["transformer_infer_logits_6x10"] = hash_params(
        [transformer_logits(tp, TRANSFORMER_IDS, TRANSFORMER_CFG)]
    )

    # tensor-parallel sharded forwards (ISSUE 9): the same models through
    # the sharded reduction graph — row-split layers reduce their
    # 4-segment logical partials in the fixed pairwise tree. One entry
    # per model because the graph is TP-invariant by construction; the
    # Rust test pins its TP grid against these single hashes.
    entries["mlp_infer_gelu_sharded_4x10"] = hash_params(
        [mlp_forward_gelu_sharded(mx, mlp_layers)]
    )
    entries["transformer_infer_logits_sharded_6x10"] = hash_params(
        [transformer_logits(tp, TRANSFORMER_IDS, TRANSFORMER_CFG, sharded=True)]
    )
    return entries


def selftest():
    """Sanity-check the rounding helpers before trusting the fixture."""
    # frac_to_f32 must invert exact f32 values…
    rng = np.random.default_rng(7)
    for v in rng.standard_normal(2000).astype(F32):
        assert frac_to_f32(Fraction(float(v))) == v, v
    # …agree with float64->float32 RNE casts…
    for v in rng.standard_normal(2000) * 1e3:
        assert frac_to_f32(Fraction(float(v))) == F32(v), v
    # …handle subnormals and halfway ties (2^-25 between 0 and 2^-24*…)
    assert frac_to_f32(Fraction(1, 1 << 149)) == np.ldexp(F32(1.0), -149)
    assert frac_to_f32(Fraction(1, 1 << 150)) == F32(0.0)  # tie -> even (0)
    # fmaf really fuses: 1 + 2^-24 - 1 style cancellation
    x = F32(1.0) + F32(2.0) ** F32(-12)
    fused = fmaf(x, x, F32(-1.0))
    unfused = F32(F32(x * x) - F32(1.0))
    assert fused != unfused, "libm fmaf did not fuse"
    # the fixed tree must associate ((0+1)+(2+3)) for four partials —
    # the association spec shared with rnum::fixed_tree_reduce, checked
    # on data where a sequential association gives different bits
    p = [np.array([[v]], F32) for v in (0.5, 1e9, -1e9, 0.25)]
    want = F32(F32(F32(0.5) + F32(1e9)) + F32(F32(-1e9) + F32(0.25)))
    assert tree_reduce_tensors(p)[0, 0] == want, "tree association drifted"
    seq = F32(F32(F32(F32(0.5) + F32(1e9)) + F32(-1e9)) + F32(0.25))
    assert want != seq, "association test data lost its discriminating power"
    # rexp at 0 / extremes
    assert rexp_f32(F32(0.0)) == F32(1.0)
    assert rexp_f32(F32(-200.0)) == F32(0.0)
    assert np.isinf(rexp_f32(F32(100.0)))
    # the GELU constants must round decimal→f32 the same way Rust's
    # literal parser does (decimal→double→f32 double-rounding hazard)
    assert SQRT_2_OVER_PI == frac_to_f32(Fraction("0.7978846")), "0.7978846 double-rounds"
    assert GELU_C == frac_to_f32(Fraction("0.044715")), "0.044715 double-rounds"
    assert F32(1e-5) == frac_to_f32(Fraction("0.00001")), "LN eps double-rounds"
    # rtanh: specials, saturation, and 1-ulp agreement with libm tanh
    assert np.isnan(rtanh_f32(F32(np.nan)))
    assert rtanh_f32(F32(0.0)) == F32(0.0)
    assert np.signbit(rtanh_f32(F32(-0.0)))
    assert rtanh_f32(F32(12.0)) == F32(1.0) and rtanh_f32(F32(-12.0)) == F32(-1.0)
    for v in [0.1, 0.5, -0.7, 2.3, -5.1]:
        got, ref = rtanh_f32(F32(v)), F32(np.tanh(np.float64(v)))
        ulp = abs(int(np.frombuffer(F32(got).tobytes(), np.int32)[0])
                  - int(np.frombuffer(ref.tobytes(), np.int32)[0]))
        assert ulp <= 1, f"tanh({v}): {got} vs {ref}"
    # rrsqrt: exact 2^(2k) family, specials, 1-ulp agreement
    assert rrsqrt_f32(F32(4.0)) == F32(0.5)
    assert rrsqrt_f32(F32(1.0)) == F32(1.0)
    assert rrsqrt_f32(F32(0.25)) == F32(2.0)
    assert np.isinf(rrsqrt_f32(F32(0.0)))
    assert np.isnan(rrsqrt_f32(F32(-1.0)))
    assert rrsqrt_f32(F32(np.inf)) == F32(0.0)
    for v in [2.0, 3.7, 0.013, 900.0]:
        got = rrsqrt_f32(F32(v))
        ref = F32(1.0 / np.sqrt(np.float64(F32(v))))
        ulp = abs(int(np.frombuffer(got.tobytes(), np.int32)[0])
                  - int(np.frombuffer(ref.tobytes(), np.int32)[0]))
        assert ulp <= 1, f"rrsqrt({v}): {got} vs {ref}"


def main():
    selftest()
    entries = compute_entries()
    lines = ["# golden bit-exactness fixtures — generated by python/tools/gen_golden_vectors.py"]
    lines += [f"{k} {v}" for k, v in entries.items()]
    text = "\n".join(lines) + "\n"
    if "--check" in sys.argv:
        if not FIXTURE.exists():
            print(f"fixture missing: {FIXTURE} (run without --check to generate)")
            sys.exit(1)
        on_disk = FIXTURE.read_text()
        if on_disk != text:
            print("MISMATCH between recomputed golden vectors and", FIXTURE)
            for line in text.splitlines():
                print("  want:", line)
            sys.exit(1)
        print("golden vectors verified:", len(entries), "entries")
    else:
        FIXTURE.parent.mkdir(parents=True, exist_ok=True)
        FIXTURE.write_text(text)
        print("wrote", FIXTURE)
        for k, v in entries.items():
            print(f"  {k} {v}")


if __name__ == "__main__":
    main()
