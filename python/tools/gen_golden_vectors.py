#!/usr/bin/env python3
"""Generate the committed golden bit-exactness fixtures for the Rust
conformance suite (``rust/tests/golden_vectors.rs``).

Every RepDL op is a *specification*: sequential-k unfused GEMM, f32 FMA
GEMM, the pairwise summation tree, and the fixed softmax graph with
correctly-rounded ``rexp``. This script evaluates those specifications
independently of the Rust implementation:

* plain f32 ops        -> numpy float32 scalar arithmetic (IEEE-754 RNE),
* f32 FMA              -> libm ``fmaf`` via ctypes (correctly rounded),
* correctly-rounded exp -> 300-bit mpmath, rounded to f32 by exact
  integer round-to-nearest-even (ties cannot occur: exp of a nonzero
  dyadic rational is transcendental).

It then fingerprints the results with the same SHA-256 framing as
``rust/src/coordinator/hashing.rs`` (``hash_params`` /``hash_curve``)
and writes ``rust/tests/fixtures/golden_vectors.txt``. A cross-platform
CI run can therefore diff exact bits against a committed reference that
was *not* produced by the code under test.

Usage:
    python3 python/tools/gen_golden_vectors.py           # (re)write fixture
    python3 python/tools/gen_golden_vectors.py --check   # verify fixture
"""

from __future__ import annotations

import ctypes
import ctypes.util
import hashlib
import struct
import sys
from fractions import Fraction
from math import ldexp
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[2]
FIXTURE = REPO / "rust" / "tests" / "fixtures" / "golden_vectors.txt"

F32 = np.float32
_U64 = (1 << 64) - 1

# ---------------------------------------------------------------------------
# deterministic input generation — mirrors the LCG used by the Rust tests
# ---------------------------------------------------------------------------


def lcg_tensor(dims, seed, scale=1.0):
    """Bit-exact replica of the Rust test generator:
    s = s*6364136223846793005 + 1442695040888963407 (wrapping u64);
    value = (((s >> 40) as f32) / 2^24 - 0.5) * 2.0, then * scale.
    `scale` must be a power of two so the extra multiply is exact."""
    n = int(np.prod(dims)) if dims else 1
    s = seed
    out = np.empty(n, dtype=F32)
    half = F32(0.5)
    two = F32(2.0)
    inv = F32(1.0 / (1 << 24))  # exact: power of two
    sc = F32(scale)
    for i in range(n):
        s = (s * 6364136223846793005 + 1442695040888963407) & _U64
        v = F32(F32(s >> 40) * inv)  # division by 2^24 == exact multiply
        out[i] = F32(F32(F32(v - half) * two) * sc)
    return out.reshape(dims)


# ---------------------------------------------------------------------------
# f32 building blocks
# ---------------------------------------------------------------------------

_libm = ctypes.CDLL(ctypes.util.find_library("m") or "libm.so.6")
_libm.fmaf.restype = ctypes.c_float
_libm.fmaf.argtypes = [ctypes.c_float] * 3


def fmaf(a, b, c):
    """Correctly-rounded f32 fused multiply-add (libm)."""
    return F32(_libm.fmaf(float(a), float(b), float(c)))


def frac_to_f32(fr: Fraction) -> np.float32:
    """Round an exact rational to f32 with round-to-nearest-even."""
    if fr == 0:
        return F32(0.0)
    sign = F32(-1.0) if fr < 0 else F32(1.0)
    fr = abs(fr)
    num, den = fr.numerator, fr.denominator

    def scaled(e):  # fr * 2^-e, exact
        return Fraction(num, den << e) if e >= 0 else Fraction(num << -e, den)

    e = num.bit_length() - den.bit_length() - 24
    while scaled(e) >= (1 << 24):
        e += 1
    while scaled(e) < (1 << 23):
        e -= 1
    if e < -149:  # subnormal range
        e = -149
    s = scaled(e)
    q, rem = divmod(s.numerator, s.denominator)
    frac2 = Fraction(rem * 2, s.denominator)  # 2*remainder/den vs 1
    if frac2 > 1 or (frac2 == 1 and (q & 1)):
        q += 1
    if q == 1 << 24:
        q, e = 1 << 23, e + 1
    if e > 104:  # overflow to inf (not reachable for these fixtures)
        return F32(np.inf) * sign
    return F32(ldexp(q, e)) * sign


def rexp_f32(x: np.float32):
    """Correctly-rounded e^x for f32 — the `rnum::rexp` contract,
    evaluated via 300-bit mpmath + exact RNE rounding."""
    import mpmath

    x = F32(x)
    if np.isnan(x):
        return F32(np.nan)
    if x > F32(89.0):
        return F32(np.inf)
    if x < F32(-104.0):
        return F32(0.0)
    if x == 0:
        return F32(1.0)
    with mpmath.workprec(300):
        e = mpmath.exp(mpmath.mpf(float(x)))
        sign, man, exp, _ = e._mpf_
        fr = Fraction(man, 1) * Fraction(2) ** exp
        if sign:
            fr = -fr
    return frac_to_f32(fr)


# ---------------------------------------------------------------------------
# op specifications (scalar loops, fixed order — the paper's graphs)
# ---------------------------------------------------------------------------


def matmul_seq(a, b):
    """Sequential-k, unfused multiply-then-add."""
    m, k = a.shape
    n = b.shape[1]
    out = np.zeros((m, n), dtype=F32)
    for i in range(m):
        for j in range(n):
            acc = F32(0.0)
            for kk in range(k):
                acc = F32(acc + F32(a[i, kk] * b[kk, j]))
            out[i, j] = acc
    return out


def matmul_fma(a, b):
    """Sequential-k with true f32 FMA contraction."""
    m, k = a.shape
    n = b.shape[1]
    out = np.zeros((m, n), dtype=F32)
    for i in range(m):
        for j in range(n):
            acc = F32(0.0)
            for kk in range(k):
                acc = fmaf(a[i, kk], b[kk, j], acc)
            out[i, j] = acc
    return out


def sum_sequential(xs):
    acc = F32(0.0)
    for x in xs:
        acc = F32(acc + x)
    return acc


def _pairwise_split(n):
    """Largest power of two < n (shared tree spec: rust/src/rnum/sum.rs)."""
    return 1 << ((n - 1).bit_length() - 1)


def sum_pairwise(xs):
    if len(xs) <= 8:
        return sum_sequential(xs)
    m = _pairwise_split(len(xs))
    return F32(sum_pairwise(xs[:m]) + sum_pairwise(xs[m:]))


def max_wins(v, m):
    """The canonical comparison-reduction update rule
    (rust/src/tensor/reduce.rs `max_wins`): NaN beats every number,
    otherwise strictly-greater — so the first of equal maxima, and the
    first NaN, is kept. On finite inputs this is identical to the old
    plain ``v > m`` scan, which is why the committed fixtures did not
    change when the NaN-rule unification migration landed (DESIGN.md §8)."""
    return (np.isnan(v) and not np.isnan(m)) or v > m


def softmax_rows(x):
    """Fixed graph: row max (max_wins rule) -> subtract -> rexp ->
    sequential sum -> divide (rust/src/nn/softmax.rs)."""
    rows, c = x.shape
    out = np.zeros((rows, c), dtype=F32)
    for r in range(rows):
        m = x[r, 0]
        for v in x[r, 1:]:
            if max_wins(v, m):
                m = v
        denom = F32(0.0)
        for j in range(c):
            e = rexp_f32(F32(x[r, j] - m))
            out[r, j] = e
            denom = F32(denom + e)
        for j in range(c):
            out[r, j] = F32(out[r, j] / denom)
    return out


# ---------------------------------------------------------------------------
# fingerprint framing — mirrors rust/src/coordinator/hashing.rs
# ---------------------------------------------------------------------------


def hash_params(tensors):
    """SHA-256 over (ndims u64-le, dims u64-le…, f32 bits le…) per tensor."""
    h = hashlib.sha256()
    for t in tensors:
        h.update(struct.pack("<Q", t.ndim))
        for d in t.shape:
            h.update(struct.pack("<Q", d))
        for v in t.reshape(-1):
            h.update(struct.pack("<I", np.frombuffer(F32(v).tobytes(), np.uint32)[0]))
    return h.hexdigest()


def hash_curve(values):
    """SHA-256 over f32 bit patterns (le)."""
    h = hashlib.sha256()
    for v in values:
        h.update(struct.pack("<I", np.frombuffer(F32(v).tobytes(), np.uint32)[0]))
    return h.hexdigest()


# ---------------------------------------------------------------------------
# fixture definition — keep in lockstep with rust/tests/golden_vectors.rs
# ---------------------------------------------------------------------------


def compute_entries():
    a = lcg_tensor((16, 32), 1001)
    b = lcg_tensor((32, 8), 1002)
    xs = lcg_tensor((1000,), 1003)
    sx = lcg_tensor((8, 32), 1004, scale=4.0)

    entries = {}
    entries["inputs"] = hash_params([a, b, xs, sx])
    entries["matmul_seq_16x32x8"] = hash_params([matmul_seq(a, b)])
    entries["matmul_fma_16x32x8"] = hash_params([matmul_fma(a, b)])
    entries["sum_sequential_1000"] = hash_curve([sum_sequential(xs)])
    entries["sum_pairwise_1000"] = hash_curve([sum_pairwise(xs)])
    entries["softmax_rows_8x32"] = hash_params([softmax_rows(sx)])
    return entries


def selftest():
    """Sanity-check the rounding helpers before trusting the fixture."""
    # frac_to_f32 must invert exact f32 values…
    rng = np.random.default_rng(7)
    for v in rng.standard_normal(2000).astype(F32):
        assert frac_to_f32(Fraction(float(v))) == v, v
    # …agree with float64->float32 RNE casts…
    for v in rng.standard_normal(2000) * 1e3:
        assert frac_to_f32(Fraction(float(v))) == F32(v), v
    # …handle subnormals and halfway ties (2^-25 between 0 and 2^-24*…)
    assert frac_to_f32(Fraction(1, 1 << 149)) == np.ldexp(F32(1.0), -149)
    assert frac_to_f32(Fraction(1, 1 << 150)) == F32(0.0)  # tie -> even (0)
    # fmaf really fuses: 1 + 2^-24 - 1 style cancellation
    x = F32(1.0) + F32(2.0) ** F32(-12)
    fused = fmaf(x, x, F32(-1.0))
    unfused = F32(F32(x * x) - F32(1.0))
    assert fused != unfused, "libm fmaf did not fuse"
    # rexp at 0 / extremes
    assert rexp_f32(F32(0.0)) == F32(1.0)
    assert rexp_f32(F32(-200.0)) == F32(0.0)
    assert np.isinf(rexp_f32(F32(100.0)))


def main():
    selftest()
    entries = compute_entries()
    lines = ["# golden bit-exactness fixtures — generated by python/tools/gen_golden_vectors.py"]
    lines += [f"{k} {v}" for k, v in entries.items()]
    text = "\n".join(lines) + "\n"
    if "--check" in sys.argv:
        if not FIXTURE.exists():
            print(f"fixture missing: {FIXTURE} (run without --check to generate)")
            sys.exit(1)
        on_disk = FIXTURE.read_text()
        if on_disk != text:
            print("MISMATCH between recomputed golden vectors and", FIXTURE)
            for line in text.splitlines():
                print("  want:", line)
            sys.exit(1)
        print("golden vectors verified:", len(entries), "entries")
    else:
        FIXTURE.parent.mkdir(parents=True, exist_ok=True)
        FIXTURE.write_text(text)
        print("wrote", FIXTURE)
        for k, v in entries.items():
            print(f"  {k} {v}")


if __name__ == "__main__":
    main()
