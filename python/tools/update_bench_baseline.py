#!/usr/bin/env python3
"""Refresh the committed ``BENCH_serve.json`` regression baseline from
one or more fresh bench runs (e.g. the ``bench-json`` CI artifacts).

The CI gate (.github/workflows/ci.yml, "Serve trajectory gate") reads
the committed ``BENCH_serve.json`` and

* **hard-fails** when a fresh row's ``allocs_per_call`` rises above the
  committed value (allocation counts are exact and deterministic), and
* **warns** when a fresh row's ``req_per_s`` drops below 85% of the
  committed value (wall-clock on shared runners is noisy — ROADMAP
  "de-flake the CI gate").

This script builds a *conservative* baseline so the armed gate cannot
flake: for every (kernel, model, shape…) key seen across the input
runs it keeps the **minimum** ``req_per_s`` (slowest observed run) and
the **maximum** ``allocs_per_call`` (both directions favour the gate
staying green on an honest re-run, while still catching real
regressions). Download 2–3 ``bench-json`` artifacts from CI runs on the
target machine class, then:

    python3 python/tools/update_bench_baseline.py run1/BENCH_serve.json \
        run2/BENCH_serve.json

and commit the rewritten ``BENCH_serve.json``.

Arming procedure for newly added kernels (e.g. the ``journal`` off/on
rows): the gate only compares rows whose key exists in the committed
baseline, so a new kernel ships *inert* — CI asserts the rows exist but
does not regression-gate them until a baseline containing them is
committed. To arm: merge the new kernel's rows from 2–3 CI artifacts
with this script (the ``journal`` rows' ``allocs_per_call`` is
event-sequence-pure, so it is hard-gated the moment it lands), commit,
and the next CI run gates them.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
BASELINE = REPO / "BENCH_serve.json"

KEY = (
    "kernel",
    "model",
    "mode",
    "tp",
    "context",
    "requests",
    "shards",
    "clients",
    "batch_window",
    "cache_capacity",
    "max_queue_depth",
    "pool_lanes",
)

NOTE = (
    "regression baseline for the CI serve trajectory gate: allocs_per_call is "
    "hard-gated (exact, deterministic), req_per_s is warn-only and recorded "
    "conservatively (min across the source runs; see "
    "python/tools/update_bench_baseline.py). Refresh from bench-json CI "
    "artifacts after intentional perf/alloc changes."
)


def row_key(entry: dict) -> tuple:
    return tuple(entry.get(k) for k in KEY)


def merge(runs: list[list[dict]]) -> list[dict]:
    merged: dict[tuple, dict] = {}
    for entries in runs:
        for e in entries:
            # "net" rows ride along for the trajectory record; the CI
            # hard gate deliberately skips them (their alloc counts
            # include the server's concurrent threads)
            if e.get("kernel") not in (
                "scheduler", "cache", "kv", "journal", "train", "tp", "net",
            ):
                continue
            k = row_key(e)
            cur = merged.get(k)
            if cur is None:
                merged[k] = dict(e)
                continue
            if "req_per_s" in e and "req_per_s" in cur:
                cur["req_per_s"] = min(cur["req_per_s"], e["req_per_s"])
            if "median_ns" in e and "median_ns" in cur:
                cur["median_ns"] = max(cur["median_ns"], e["median_ns"])
            if "allocs_per_call" in e and "allocs_per_call" in cur:
                cur["allocs_per_call"] = max(cur["allocs_per_call"], e["allocs_per_call"])
    return [merged[k] for k in sorted(merged, key=repr)]


def main() -> int:
    paths = [Path(p) for p in sys.argv[1:]]
    if not paths:
        print(__doc__)
        return 2
    runs = []
    for p in paths:
        data = json.loads(p.read_text())
        entries = data.get("entries", [])
        if not entries:
            print(f"warning: {p} has no entries; skipping")
            continue
        runs.append(entries)
    if not runs:
        print("error: no usable entries in any input")
        return 1
    entries = merge(runs)
    if not entries:
        print("error: inputs held no scheduler/cache/kv/journal/train/tp rows")
        return 1
    BASELINE.write_text(
        json.dumps({"bench": "serve", "note": NOTE, "entries": entries}, indent=2) + "\n"
    )
    print(f"wrote {BASELINE}: {len(entries)} baseline rows from {len(runs)} run(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
