//! E6 — cross-implementation reproducibility: native Rust kernels vs the
//! AOT-compiled JAX/Pallas artifacts executed via PJRT. Reports bitwise
//! agreement per op and the PJRT execution cost. Skips gracefully when
//! artifacts are missing.

use repdl::bench_harness::{bench, row, section};
use repdl::rng::uniform_tensor;
use repdl::rnum::fbits::ulp_diff;
use repdl::runtime::Runtime;
use repdl::tensor::matmul_fma;

fn main() {
    section("E6: cross-implementation (rust-native vs XLA/PJRT artifact)");
    let mut rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIPPED: {e}");
            return;
        }
    };
    row("PJRT platform", rt.platform());

    // matmul: bitwise across stacks
    let a = uniform_tensor(&[64, 128], -1.0, 1.0, 11);
    let b = uniform_tensor(&[128, 32], -1.0, 1.0, 12);
    let xla = rt.run("matmul_repro", &[a.clone(), b.clone()]).unwrap();
    let native = matmul_fma(&a, &b).unwrap();
    row("matmul 64x128x32 bitwise equal", xla[0].bit_eq(&native));

    // sums
    let x = uniform_tensor(&[4096], -100.0, 100.0, 13);
    let seq = rt.run("sum_seq", &[x.clone()]).unwrap();
    row(
        "sum_seq bitwise equal",
        seq[0].data()[0].to_bits() == repdl::rnum::sum_sequential(x.data()).to_bits(),
    );
    let pw = rt.run("sum_pairwise", &[x.clone()]).unwrap();
    row(
        "sum_pairwise bitwise equal",
        pw[0].data()[0].to_bits() == repdl::rnum::sum_pairwise(x.data()).to_bits(),
    );

    // exp fixed graph
    let e = uniform_tensor(&[1024], -60.0, 60.0, 14);
    let xe = rt.run("exp_fixed", &[e.clone()]).unwrap();
    let mut exact = 0;
    for (i, &v) in e.data().iter().enumerate() {
        let n = repdl::rnum::exp::exp_fixed_graph_f64(v as f64) as f32;
        exact += (xe[0].data()[i].to_bits() == n.to_bits()) as usize;
    }
    row("exp_fixed bit-equal fraction", format!("{exact}/1024"));

    // softmax ULP gap (different exp impls — expected nonzero)
    let s = uniform_tensor(&[32, 64], -8.0, 8.0, 15);
    let xs = rt.run("softmax_repro", &[s.clone()]).unwrap();
    let ns = repdl::nn::softmax_rows(&s).unwrap();
    let max_ulp = xs[0]
        .data()
        .iter()
        .zip(ns.data())
        .map(|(a, b)| ulp_diff(*a, *b))
        .max()
        .unwrap();
    row("softmax max ulp gap (exp differs)", max_ulp);

    section("E6: PJRT execution cost vs native");
    bench("xla matmul 64x128x32", 7, || {
        rt.run("matmul_repro", &[a.clone(), b.clone()]).unwrap()
    });
    bench("native matmul_fma 64x128x32", 7, || matmul_fma(&a, &b).unwrap());
}
