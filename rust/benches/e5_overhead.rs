//! E5 — "switching to RepDL can degrade performance mildly" (paper §4).
//!
//! Head-to-head: RepDL reproducible kernels vs the conventional baseline
//! kernels (which are free to pick any order), plus end-to-end training
//! step time. The interesting number is the ratio.
//!
//! Also measures this repo's engine work as reproducible ablations:
//!
//! * **GEMM three-way**: per-element dot form (seed) → cache-blocked
//!   (PR 1) → packed register-tiled microkernel (PR 2), same bits
//!   asserted before every timing.
//! * **Conv three-way**: direct loops → unfused im2col+GEMM round trip
//!   (PR 1's pipeline, reconstructed here as the baseline) → fused
//!   packed-im2col pipeline.
//! * **Serving throughput** in req/s through the prepacked batch path,
//!   with allocations per call (scratch-arena effect).
//!
//! Every ablation is emitted to machine-readable `BENCH_gemm.json` /
//! `BENCH_conv.json` / `BENCH_serve.json` at the repository root — the
//! perf trajectory consumed by CI. Pass `--smoke` for the quick CI
//! variant (smaller shapes, fewer samples, same schema).

use repdl::baseline::{baseline_matmul, baseline_softmax_rows, PlatformProfile};
use repdl::bench_harness::{
    allocs_during, bench, bench_json_path, bench_once, bench_threads, row, row_rate, section,
    write_bench_json, CountingAllocator, JsonObj,
};
use repdl::coordinator::{
    DeterministicServer, MlpTower, ModelTower, NumericsMode, ServeConfig, ServeScheduler,
    ShardedTower, Trainer, TrainerConfig, TransformerTower,
};
use repdl::nn::{Act, CharTransformer, Mlp, TransformerConfig};
use std::sync::Arc;
use repdl::nn::softmax_rows;
use repdl::rng::uniform_tensor;
use repdl::tensor::par::par_chunks_spawn;
use repdl::tensor::{
    conv2d_direct, conv2d_im2col, default_threads, im2col, matmul_blocked, matmul_dotform,
    matmul_fma, matmul_in, matmul_packed, matmul_pairwise, Conv2dParams, Tensor, WorkerPool,
};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// The seed's engine: per-element dot GEMM with fresh scoped threads
/// spawned on every call (kept verbatim as the before/after baseline).
fn matmul_spawn_percall(a: &Tensor, b: &Tensor, nthreads: usize) -> Tensor {
    let (m, k, n) = (a.dims()[0], a.dims()[1], b.dims()[1]);
    let bt = b.transpose2d().unwrap();
    let mut out = Tensor::zeros(&[m, n]);
    let (ad, btd) = (a.data(), bt.data());
    par_chunks_spawn(out.data_mut(), n, nthreads, |start, c| {
        let i = start / n;
        for (j, v) in c.iter_mut().enumerate() {
            *v = repdl::rnum::dot::dot_strided(&ad[i * k..(i + 1) * k], 1, &btd[j * k..(j + 1) * k], 1, k);
        }
    });
    out
}

/// PR 1's conv pipeline, reconstructed as an ablation baseline:
/// per-image im2col materialisation, explicit transpose, blocked GEMM,
/// then a per-element scatter into the NCHW planes. Bit-identical to
/// the fused path (asserted) — only the wall-clock differs.
fn conv2d_im2col_unfused(x: &Tensor, w: &Tensor, p: Conv2dParams) -> Tensor {
    let (b, h, wd) = (x.dims()[0], x.dims()[2], x.dims()[3]);
    let (o, kh, kw) = (w.dims()[0], w.dims()[2], w.dims()[3]);
    let k = w.dims()[1] * kh * kw;
    let oh = (h + 2 * p.padding - kh) / p.stride + 1;
    let ow = (wd + 2 * p.padding - kw) / p.stride + 1;
    let wmat = w.reshape(&[o, k]).unwrap();
    let mut out = Tensor::zeros(&[b, o, oh, ow]);
    for bi in 0..b {
        let cols = im2col(x, bi, kh, kw, &p).unwrap();
        let prod = matmul_blocked(&wmat, &cols.transpose2d().unwrap()).unwrap();
        for oi in 0..o {
            for s in 0..oh * ow {
                out.data_mut()[((bi * o + oi) * oh + s / ow) * ow + s % ow] =
                    prod.data()[oi * oh * ow + s];
            }
        }
    }
    out
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let p = PlatformProfile::zoo()[2]; // avx2-like: 8 lanes + FMA
    let lanes = default_threads();
    let samples = if smoke { 3 } else { 5 };

    // ---------------- GEMM three-way ablation ----------------
    section("E5: GEMM ablation — dotform (seed) vs blocked (PR 1) vs packed (PR 2)");
    let gemm_shapes: &[(usize, usize, usize)] = if smoke {
        &[(128, 128, 128), (256, 256, 256)]
    } else {
        &[(128, 256, 128), (256, 256, 256), (512, 512, 512)]
    };
    let mut gemm_entries = Vec::new();
    for &(m, k, n) in gemm_shapes {
        let a = uniform_tensor(&[m, k], -1.0, 1.0, 1);
        let b = uniform_tensor(&[k, n], -1.0, 1.0, 2);
        // bit-equality gate before any timing: the perf forms must agree
        let dref = matmul_dotform(&a, &b).unwrap();
        assert!(matmul_blocked(&a, &b).unwrap().bit_eq(&dref), "blocked diverged");
        assert!(matmul_packed(&a, &b).unwrap().bit_eq(&dref), "packed diverged");
        let flops = 2.0 * (m as f64) * (k as f64) * (n as f64);
        let kernels: [(&str, Box<dyn Fn() -> Tensor + '_>); 3] = [
            ("dotform", Box::new(|| matmul_dotform(&a, &b).unwrap())),
            ("blocked", Box::new(|| matmul_blocked(&a, &b).unwrap())),
            ("packed", Box::new(|| matmul_packed(&a, &b).unwrap())),
        ];
        let mut medians = Vec::new();
        for (kname, f) in &kernels {
            let st = bench(&format!("gemm {m}x{k}x{n} {kname}"), samples, || f());
            let (allocs, _) = allocs_during(|| f());
            gemm_entries.push(
                JsonObj::new()
                    .s("kernel", *kname)
                    .int("m", m as u64)
                    .int("k", k as u64)
                    .int("n", n as u64)
                    .int("pool_lanes", lanes as u64)
                    .num("median_ns", st.median_ns)
                    .num("gflops", flops / st.median_ns)
                    .int("allocs_per_call", allocs),
            );
            medians.push(st.median_ns);
        }
        row(
            &format!("  {m}x{k}x{n} speedups: packed/blocked, packed/dotform"),
            format!("{:.2}x, {:.2}x", medians[1] / medians[2], medians[0] / medians[2]),
        );
    }
    write_bench_json(&bench_json_path("gemm"), "gemm", &gemm_entries)
        .expect("write BENCH_gemm.json");

    // ---------------- engine dispatch ablation (PR 1) ----------------
    section("E5: engine — spawn-per-call vs persistent pool (same bits)");
    let a = uniform_tensor(&[128, 256], -1.0, 1.0, 1);
    let b = uniform_tensor(&[256, 128], -1.0, 1.0, 2);
    let pool = WorkerPool::new(lanes);
    assert!(
        matmul_spawn_percall(&a, &b, lanes).bit_eq(&matmul_dotform(&a, &b).unwrap()),
        "spawn baseline diverged from dotform"
    );
    let s_spawn =
        bench("GEMM dotform, spawn-per-call (seed)", samples, || matmul_spawn_percall(&a, &b, lanes));
    let s_dot = bench("GEMM dotform, persistent pool", samples, || {
        repdl::tensor::matmul_dotform_in(&pool, &a, &b).unwrap()
    });
    let s_pool = bench("GEMM routed, persistent pool", samples, || {
        matmul_in(&pool, &a, &b).unwrap()
    });
    row(
        "pool-dispatch speedup (same kernel)",
        format!("{:.2}x", s_spawn.median_ns / s_dot.median_ns),
    );
    row(
        "pool + kernel speedup (combined)",
        format!("{:.2}x", s_spawn.median_ns / s_pool.median_ns),
    );
    let rb = bench("baseline matmul (8-lane fma)", samples, || {
        baseline_matmul(&a, &b, &p).unwrap()
    });
    row("repdl/baseline ratio (seq)", format!("{:.2}x", s_pool.median_ns / rb.median_ns));
    let r2 = bench("repdl matmul_fma", samples, || matmul_fma(&a, &b).unwrap());
    let r3 = bench("repdl matmul_pairwise", samples, || matmul_pairwise(&a, &b).unwrap());
    row("repdl/baseline ratio (fma)", format!("{:.2}x", r2.median_ns / rb.median_ns));
    row("repdl/baseline ratio (pairwise)", format!("{:.2}x", r3.median_ns / rb.median_ns));

    // ---------------- conv three-way ablation ----------------
    section("E5: conv ablation — direct vs unfused im2col (PR 1) vs fused (PR 2)");
    // (B, C, H=W, O): ResNet-style 3x3/pad-1 body shapes
    let conv_shapes: &[(usize, usize, usize, usize)] = if smoke {
        &[(2, 16, 28, 32)]
    } else {
        &[(8, 16, 28, 32), (4, 64, 56, 64)]
    };
    let mut conv_entries = Vec::new();
    for &(bn, c, hw, o) in conv_shapes {
        let x = uniform_tensor(&[bn, c, hw, hw], -1.0, 1.0, 3);
        let wc = uniform_tensor(&[o, c, 3, 3], -0.2, 0.2, 4);
        let pc = Conv2dParams { stride: 1, padding: 1 };
        let dref = conv2d_direct(&x, &wc, None, pc).unwrap();
        assert!(conv2d_im2col(&x, &wc, None, pc).unwrap().bit_eq(&dref), "fused diverged");
        assert!(conv2d_im2col_unfused(&x, &wc, pc).bit_eq(&dref), "unfused ablation diverged");
        let flops = 2.0 * (bn * o * hw * hw * c * 9) as f64;
        let kernels: [(&str, Box<dyn Fn() -> Tensor + '_>); 3] = [
            ("direct", Box::new(|| conv2d_direct(&x, &wc, None, pc).unwrap())),
            ("im2col_unfused", Box::new(|| conv2d_im2col_unfused(&x, &wc, pc))),
            ("im2col_fused", Box::new(|| conv2d_im2col(&x, &wc, None, pc).unwrap())),
        ];
        let mut medians = Vec::new();
        for (kname, f) in &kernels {
            let st = bench(&format!("conv {bn}x{c}x{hw}² o={o} {kname}"), samples, || f());
            let (allocs, _) = allocs_during(|| f());
            conv_entries.push(
                JsonObj::new()
                    .s("kernel", *kname)
                    .int("batch", bn as u64)
                    .int("cin", c as u64)
                    .int("hw", hw as u64)
                    .int("cout", o as u64)
                    .int("pool_lanes", lanes as u64)
                    .num("median_ns", st.median_ns)
                    .num("gflops", flops / st.median_ns)
                    .int("allocs_per_call", allocs),
            );
            medians.push(st.median_ns);
        }
        row(
            "  conv speedups: fused/unfused, fused/direct",
            format!("{:.2}x, {:.2}x", medians[1] / medians[2], medians[0] / medians[2]),
        );
    }
    write_bench_json(&bench_json_path("conv"), "conv", &conv_entries)
        .expect("write BENCH_conv.json");

    // ---------------- serving throughput ----------------
    section("E5: serving throughput (prepacked pooled batch dispatch)");
    let w = uniform_tensor(&[256, 16], -0.3, 0.3, 5);
    let server = Arc::new(DeterministicServer::new(w, 64).unwrap());
    let queue: Vec<Tensor> = (0..64)
        .map(|i| uniform_tensor(&[256], -1.0, 1.0, 300 + i as u64))
        .collect();
    let mut serve_entries = Vec::new();
    for l in [1usize, lanes.max(2)] {
        let pl = WorkerPool::new(l);
        let t = server.throughput_report(&pl, &queue, samples).unwrap();
        let (allocs, _) = allocs_during(|| server.process_repro_in(&pl, &queue).unwrap());
        row(format!("serve req/s, pool={l}").as_str(), format!("{:.0} req/s", t.req_per_s));
        serve_entries.push(
            JsonObj::new()
                .s("kernel", "batch_loop")
                .int("requests", t.requests as u64)
                .int("pool_lanes", l as u64)
                .int("d_in", 256)
                .int("d_out", 16)
                .num("median_ns", t.median_ns)
                .num("req_per_s", t.req_per_s)
                .int("allocs_per_call", allocs),
        );
    }
    let stats = bench("serve 64 reqs (global pool)", samples, || {
        server.process_repro(&queue).unwrap()
    });
    row_rate("serve throughput (global pool)", &stats, queue.len(), "req");

    // scheduler grid: multi-client dynamic batching over sharded
    // replicas (one shared server + one shared pool handle). Each sample
    // is one full replay: every client submits its ticket-interleaved
    // slice and waits for all of its responses.
    section("E5: serve scheduler — shards × concurrent clients");
    let sched_grid: &[(usize, usize)] =
        if smoke { &[(1, 2), (2, 4)] } else { &[(1, 1), (1, 4), (2, 4), (4, 8)] };
    let batch_window = 16usize;
    for &(shards, clients) in sched_grid {
        let sched = ServeScheduler::sharded(
            Arc::clone(&server),
            shards,
            batch_window,
            WorkerPool::shared(lanes),
        )
        .unwrap();
        let replay = |c: usize| {
            sched.replay_slice(&queue, c, clients).unwrap();
        };
        let st = bench_threads(
            &format!("serve sched shards={shards} clients={clients}"),
            samples,
            clients,
            replay,
        );
        // allocation count for one full single-caller replay (the
        // multi-threaded grid timing above measures wall-clock only)
        let (allocs, _) = allocs_during(|| sched.process_all(&queue).unwrap());
        serve_entries.push(
            JsonObj::new()
                .s("kernel", "scheduler")
                .s("model", "linear")
                .int("requests", queue.len() as u64)
                .int("shards", shards as u64)
                .int("clients", clients as u64)
                .int("batch_window", batch_window as u64)
                .int("pool_lanes", lanes as u64)
                .int("d_in", 256)
                .int("d_out", 16)
                .num("median_ns", st.median_ns)
                .num("req_per_s", st.per_sec(queue.len()))
                .int("allocs_per_call", allocs),
        );
    }

    // per-model scheduler rows (ISSUE 5): the same dynamic-batching
    // front end over each ModelTower — linear (packed GEMM fast path),
    // off-tape MLP, off-tape transformer. Single shard + single
    // submitter so every counter and the composition are
    // event-sequence-pure; the bit gate (scheduler output == direct
    // forward_batch) runs before any timing, so these rows double as a
    // release-mode conformance check for the tower paths.
    section("E5: serve scheduler — per-model towers");
    let model_grid: Vec<(Arc<dyn ModelTower>, Vec<Tensor>)> = {
        let mlp_tower: Arc<dyn ModelTower> = Arc::new(
            MlpTower::new(Mlp::new(&[64, 64, 16], Act::Gelu, 11)).unwrap(),
        );
        let tcfg = TransformerConfig {
            vocab: 28,
            dim: if smoke { 16 } else { 32 },
            heads: 4,
            layers: 2,
            context: if smoke { 8 } else { 16 },
            mlp_ratio: 2,
        };
        let tr_tower: Arc<dyn ModelTower> =
            Arc::new(TransformerTower::new(CharTransformer::new(tcfg, 12).unwrap()).unwrap());
        let nreq = if smoke { 16 } else { 32 };
        let mlp_queue: Vec<Tensor> = (0..nreq)
            .map(|i| uniform_tensor(&[64], -1.0, 1.0, 500 + i as u64))
            .collect();
        let tr_queue: Vec<Tensor> = (0..nreq)
            .map(|i| {
                Tensor::from_vec(
                    &[tcfg.context],
                    (0..tcfg.context)
                        .map(|j| ((i * 31 + j * 7 + 3) % tcfg.vocab) as f32)
                        .collect(),
                )
                .unwrap()
            })
            .collect();
        let lin_queue: Vec<Tensor> = (0..nreq)
            .map(|i| uniform_tensor(&[256], -1.0, 1.0, 300 + i as u64))
            .collect();
        vec![
            (Arc::clone(&server) as Arc<dyn ModelTower>, lin_queue),
            (mlp_tower, mlp_queue),
            (tr_tower, tr_queue),
        ]
    };
    for (tower, mqueue) in &model_grid {
        let pl = WorkerPool::shared(lanes);
        // bit gate: the scheduler must reproduce the direct forward
        let reference = tower.forward_batch(&pl, mqueue).unwrap();
        let sched = ServeScheduler::sharded(
            Arc::clone(tower),
            1,
            batch_window,
            Arc::clone(&pl),
        )
        .unwrap();
        let outs = sched.process_all(mqueue).unwrap();
        for (a, b) in reference.iter().zip(outs.iter()) {
            assert!(a.bit_eq(b), "{} scheduler diverged", tower.model_id());
        }
        let st = bench_once(
            &format!("serve sched model={}", tower.model_id()),
            samples,
            || {
                sched.process_all(mqueue).unwrap();
            },
        );
        let (allocs, _) = allocs_during(|| sched.process_all(mqueue).unwrap());
        serve_entries.push(
            JsonObj::new()
                .s("kernel", "scheduler")
                .s("model", tower.model_id())
                .int("requests", mqueue.len() as u64)
                .int("shards", 1)
                .int("clients", 1)
                .int("batch_window", batch_window as u64)
                .int("pool_lanes", lanes as u64)
                .int("d_in", tower.d_in() as u64)
                .int("d_out", tower.d_out() as u64)
                .num("median_ns", st.median_ns)
                .num("req_per_s", st.per_sec(mqueue.len()))
                .int("allocs_per_call", allocs),
        );
    }
    // cache × admission-depth grid: every request appears twice in the
    // replayed queue, so a warm memo answers half the traffic; the
    // depth cap exercises the deterministic backpressure protocol
    // (rejection → flush → resubmit) on the same run. Cache-off and
    // depth-off cells anchor the comparison. Single shard + single
    // submitter on purpose: that makes the emitted hits/misses/
    // evictions/rejected counters event-sequence-pure (multi-shard
    // dispatchers interleave cache inserts in thread-timing order under
    // eviction pressure — bits never change, but counters would, and
    // these rows feed the CI regression gate).
    section("E5: serve cache × admission-depth grid");
    let repeated: Vec<Tensor> =
        queue.iter().chain(queue.iter()).cloned().collect();
    let cache_grid: &[(usize, usize)] =
        if smoke { &[(0, 0), (64, 32)] } else { &[(0, 0), (64, 0), (64, 32), (16, 32)] };
    for &(cap, depth) in cache_grid {
        let cfg = ServeConfig {
            batch_window,
            max_queue_depth: (depth > 0).then_some(depth),
            cache_capacity: cap,
            ..Default::default()
        };
        let sched =
            ServeScheduler::sharded_with(Arc::clone(&server), 1, WorkerPool::shared(lanes), cfg)
                .unwrap();
        // cold replay fills the memo; the measured replays are warm
        sched.process_all_with_backpressure(&repeated).unwrap();
        let st = bench_once(&format!("serve cache cap={cap} depth={depth}"), samples, || {
            sched.process_all_with_backpressure(&repeated).unwrap();
        });
        // counters are cumulative across the whole run — snapshot around
        // ONE warm replay so the emitted hits/misses/evictions/rejected
        // describe a single replay regardless of the sample count
        let cs0 = sched.cache_stats().unwrap_or_default();
        let rej0 = sched.rejected();
        let (allocs, _) =
            allocs_during(|| sched.process_all_with_backpressure(&repeated).unwrap());
        let cs = sched.cache_stats().unwrap_or_default();
        serve_entries.push(
            JsonObj::new()
                .s("kernel", "cache")
                .s("model", "linear")
                .int("requests", repeated.len() as u64)
                .int("shards", 1)
                .int("clients", 1)
                .int("batch_window", batch_window as u64)
                .int("cache_capacity", cap as u64)
                .int("max_queue_depth", depth as u64)
                .int("pool_lanes", lanes as u64)
                .int("d_in", 256)
                .int("d_out", 16)
                .num("median_ns", st.median_ns)
                .num("req_per_s", st.per_sec(repeated.len()))
                .int("hits", cs.hits - cs0.hits)
                .int("misses", cs.misses - cs0.misses)
                .int("evictions", cs.evictions - cs0.evictions)
                .int("rejected", sched.rejected() - rej0)
                .int("allocs_per_call", allocs),
        );
    }
    // KV-session decode: incremental (one step per prefix extension,
    // O(T)) vs full recompute (O(T²)) over one growing decode stream,
    // at several context lengths. The bit gate runs first — sessions
    // may only change cost. Warm replays are measured: every extension
    // hits the store (duplicate re-inserts are dropped), so the
    // incremental rows time the steady-state step path.
    section("E5: serve KV sessions — incremental vs recompute");
    let kv_contexts: &[usize] = if smoke { &[8, 16] } else { &[16, 32, 48] };
    for &ctx in kv_contexts {
        let kcfg = TransformerConfig {
            vocab: 28,
            dim: if smoke { 16 } else { 32 },
            heads: 4,
            layers: 2,
            context: ctx,
            mlp_ratio: 2,
        };
        // same cfg + seed ⇒ identical weights in both towers
        let plain = TransformerTower::new(CharTransformer::new(kcfg, 12).unwrap()).unwrap();
        let inc = TransformerTower::new(CharTransformer::new(kcfg, 12).unwrap())
            .unwrap()
            .with_sessions(2 * ctx);
        let kv_queue: Vec<Tensor> = (1..=ctx)
            .map(|tt| {
                Tensor::from_vec(
                    &[tt],
                    (0..tt).map(|t| ((t * 7 + 3) % kcfg.vocab) as f32).collect(),
                )
                .unwrap()
            })
            .collect();
        let tickets: Vec<u64> = (0..ctx as u64).collect();
        let pl = WorkerPool::shared(lanes);
        // bit gate: every prefix, incremental bits == recompute bits
        let want = plain.forward_batch(&pl, &kv_queue).unwrap();
        let got = inc.forward_batch_ticketed(&pl, &kv_queue, &tickets).unwrap();
        for (tt, (a, b)) in want.iter().zip(got.iter()).enumerate() {
            assert!(a.bit_eq(b), "kv ctx={ctx} prefix={}: sessions changed bits", tt + 1);
        }
        // warm replay check: extensions all hit and still match
        let warm = inc.forward_batch_ticketed(&pl, &kv_queue, &tickets).unwrap();
        for (a, b) in want.iter().zip(warm.iter()) {
            assert!(a.bit_eq(b), "kv ctx={ctx}: warm session replay changed bits");
        }
        let runs: [(&str, Box<dyn Fn() + '_>); 2] = [
            ("recompute", Box::new(|| {
                plain.forward_batch(&pl, &kv_queue).unwrap();
            })),
            ("incremental", Box::new(|| {
                inc.forward_batch_ticketed(&pl, &kv_queue, &tickets).unwrap();
            })),
        ];
        for (mode, run) in runs {
            let st = bench_once(&format!("serve kv ctx={ctx} {mode}"), samples, &run);
            let (allocs, _) = allocs_during(&run);
            serve_entries.push(
                JsonObj::new()
                    .s("kernel", "kv")
                    .s("model", "transformer")
                    .s("mode", mode)
                    .int("context", ctx as u64)
                    .int("requests", kv_queue.len() as u64)
                    .int("pool_lanes", lanes as u64)
                    .int("d_in", ctx as u64)
                    .int("d_out", kcfg.vocab as u64)
                    .num("median_ns", st.median_ns)
                    .num("req_per_s", st.per_sec(kv_queue.len()))
                    .int("allocs_per_call", allocs),
            );
        }
    }
    // durable journal: the same single-shard replay with journalling
    // off vs on (ISSUE 7). The on cell writes every submit/flush record
    // synchronously and drains the buffered response records at an
    // explicit sync barrier each call — the measured delta IS the
    // durability tax. An in-memory writer keeps the rows free of
    // filesystem noise; the encode/frame/hash work is identical to the
    // file path. Bits are gated first: journalling may never change
    // responses. Single shard + single submitter so `allocs_per_call`
    // is event-sequence-pure and can be hard-gated by CI.
    section("E5: serve journal — off vs on");
    {
        use repdl::coordinator::{Journal, JournalPolicy, VecWriter};
        use std::sync::Mutex;
        let want = {
            let plain =
                ServeScheduler::sharded(Arc::clone(&server), 1, batch_window, WorkerPool::shared(lanes))
                    .unwrap();
            plain.process_all(&queue).unwrap()
        };
        for mode in ["off", "on"] {
            let journal = (mode == "on").then(|| {
                let buf = Arc::new(Mutex::new(Vec::new()));
                Arc::new(Journal::with_writer(
                    Box::new(VecWriter::new(buf)),
                    JournalPolicy::FailStop,
                ))
            });
            let cfg = ServeConfig {
                batch_window,
                journal: journal.clone(),
                ..Default::default()
            };
            let sched =
                ServeScheduler::sharded_with(Arc::clone(&server), 1, WorkerPool::shared(lanes), cfg)
                    .unwrap();
            let outs = sched.process_all(&queue).unwrap();
            sched.sync_journal().unwrap();
            for (a, b) in want.iter().zip(outs.iter()) {
                assert!(a.bit_eq(b), "journal mode={mode} changed bits");
            }
            let run = || {
                sched.process_all(&queue).unwrap();
                sched.sync_journal().unwrap();
            };
            let st = bench_once(&format!("serve journal {mode}"), samples, &run);
            let (allocs, _) = allocs_during(&run);
            let appends =
                sched.journal_stats().map(|s| s.appends).unwrap_or(0);
            serve_entries.push(
                JsonObj::new()
                    .s("kernel", "journal")
                    .s("model", "linear")
                    .s("mode", mode)
                    .int("requests", queue.len() as u64)
                    .int("shards", 1)
                    .int("clients", 1)
                    .int("batch_window", batch_window as u64)
                    .int("pool_lanes", lanes as u64)
                    .int("d_in", 256)
                    .int("d_out", 16)
                    .int("journal_appends", appends)
                    .num("median_ns", st.median_ns)
                    .num("req_per_s", st.per_sec(queue.len()))
                    .int("allocs_per_call", allocs),
            );
        }
    }
    // train lanes ablation (ISSUE 8): the step-driven data-parallel
    // engine at lanes ∈ {1,2,4,8}. The bit gate runs before any timing:
    // every lane count must finish with the identical param_hash, so
    // these rows double as a release-mode check of the fixed-order
    // gradient-tree reduction. Timings then show what the lanes knob
    // buys (it may only change wall-clock, never bits); the CI perf
    // gate is hard on allocs_per_call only.
    section("E5: train — data-parallel lanes ablation (same bits)");
    {
        use repdl::coordinator::{DataParallelTrainer, OptimizerCfg};
        let tcfg = TrainerConfig {
            steps: if smoke { 4 } else { 10 },
            dropout: 0.1,
            ..Default::default()
        };
        let microbatch = 4usize;
        let opt_grid: [(&str, OptimizerCfg); 2] = [
            ("sgd", OptimizerCfg::Sgd { momentum: 0.9, weight_decay: 0.0 }),
            ("adam", OptimizerCfg::Adam),
        ];
        let lane_grid: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
        for (oname, opt) in opt_grid {
            let run_hash = |l: usize| {
                let engine = DataParallelTrainer::new(tcfg, l, microbatch).unwrap().optimizer(opt);
                let mut st = engine.init_state();
                for _ in 0..tcfg.steps {
                    engine.step(&mut st).unwrap();
                }
                st.param_hash()
            };
            let want = run_hash(1);
            for &l in lane_grid {
                // bit gate first: lanes are a pure performance knob
                assert_eq!(run_hash(l), want, "train opt={oname} lanes={l} changed bits");
                let engine =
                    DataParallelTrainer::new(tcfg, l, microbatch).unwrap().optimizer(opt);
                let run = || {
                    engine.run().unwrap();
                };
                let st = bench_once(
                    &format!("train {}-step opt={oname} lanes={l}", tcfg.steps),
                    samples,
                    &run,
                );
                let (allocs, _) = allocs_during(&run);
                let nsamples = tcfg.steps * tcfg.batch;
                serve_entries.push(
                    JsonObj::new()
                        .s("kernel", "train")
                        .s("model", "mlp")
                        .s("mode", oname)
                        .int("requests", nsamples as u64)
                        .int("pool_lanes", l as u64)
                        .int("d_in", (tcfg.side * tcfg.side) as u64)
                        .int("d_out", tcfg.classes as u64)
                        .num("median_ns", st.median_ns)
                        .num("req_per_s", st.per_sec(nsamples))
                        .int("allocs_per_call", allocs),
                );
            }
        }
    }
    // tensor-parallel width ablation (DESIGN.md §13): the transformer
    // tower served through TP ∈ {1,2,4} shard sets. The bit gate runs
    // before any timing — every width must produce the identical
    // response bits on every request, so these rows double as a
    // release-mode check of the fixed logical-segment reduction tree.
    // Timings then show what the width knob costs on one host (shards
    // run sequentially here; the win arrives with real multi-host
    // dispatch). Single submitter, so allocs_per_call is
    // event-sequence-pure and can be hard-gated by CI.
    section("E5: serve tensor-parallel — TP width ablation (same bits)");
    {
        let tctx = if smoke { 8 } else { 16 };
        let tcfg = TransformerConfig {
            vocab: 28,
            dim: if smoke { 16 } else { 32 },
            heads: 4,
            layers: 2,
            context: tctx,
            mlp_ratio: 2,
        };
        let tp_queue: Vec<Tensor> = (1..=tctx)
            .map(|tt| {
                Tensor::from_vec(
                    &[tt],
                    (0..tt).map(|t| ((t * 7 + 3) % tcfg.vocab) as f32).collect(),
                )
                .unwrap()
            })
            .collect();
        let pl = WorkerPool::shared(lanes);
        // same cfg + seed ⇒ identical weights in every tower
        let towers: Vec<(usize, ShardedTower)> = [1usize, 2, 4]
            .into_iter()
            .map(|tp| {
                (tp, ShardedTower::transformer(CharTransformer::new(tcfg, 12).unwrap(), tp).unwrap())
            })
            .collect();
        // bit gate: every width, every request — identical bits and an
        // identical (TP-invariant) weights hash
        let want = towers[0].1.forward_batch(&pl, &tp_queue).unwrap();
        for (tp, t) in &towers[1..] {
            assert_eq!(t.weights_hash(), towers[0].1.weights_hash(), "tp={tp} changed the hash");
            let got = t.forward_batch(&pl, &tp_queue).unwrap();
            for (i, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                assert!(a.bit_eq(b), "tp={tp} request={i}: sharding changed bits");
            }
        }
        for (tp, t) in &towers {
            let run = || {
                t.forward_batch(&pl, &tp_queue).unwrap();
            };
            let st = bench_once(&format!("serve tp={tp} ctx={tctx}"), samples, &run);
            let (allocs, _) = allocs_during(&run);
            serve_entries.push(
                JsonObj::new()
                    .s("kernel", "tp")
                    .s("model", "transformer")
                    .int("tp", *tp as u64)
                    .int("context", tctx as u64)
                    .int("requests", tp_queue.len() as u64)
                    .int("pool_lanes", lanes as u64)
                    .int("d_in", tctx as u64)
                    .int("d_out", tcfg.vocab as u64)
                    .num("median_ns", st.median_ns)
                    .num("req_per_s", st.per_sec(tp_queue.len()))
                    .int("allocs_per_call", allocs),
            );
        }
    }
    // TCP loopback front end (ISSUE 10 / DESIGN.md §14): the identical
    // request stream submitted directly to a ModelRegistry vs pipelined
    // over a real localhost socket — the measured delta IS the wire tax
    // (framing + SHA-256 digest both ways, frame decode, two thread
    // hops, kernel loopback). Bits are gated first: transport may never
    // change responses. allocs_per_call counts the whole process —
    // the server's reader/writer threads included — so the loopback row
    // is only event-sequence-pure because one pipelined client keeps
    // the arrival order deterministic.
    section("E5: serve net — direct vs TCP loopback");
    {
        use repdl::coordinator::{ModelRegistry, NetClient, NetServer};
        let mk_reg = || -> Arc<ModelRegistry> {
            let sched = ServeScheduler::sharded(
                Arc::clone(&server),
                1,
                batch_window,
                WorkerPool::shared(lanes),
            )
            .unwrap();
            let mut reg = ModelRegistry::new();
            reg.register(sched).unwrap();
            Arc::new(reg)
        };
        // reference bits: direct in-process registry submission
        let want: Vec<Tensor> = {
            let reg = mk_reg();
            let pending: Vec<_> = queue
                .iter()
                .map(|r| reg.submit_with_backpressure("linear", r).unwrap())
                .collect();
            reg.flush_all();
            pending.into_iter().map(|p| p.wait().unwrap()).collect()
        };
        // mode=direct: the registry without a socket in front
        {
            let reg = mk_reg();
            let run = || {
                let pending: Vec<_> = queue
                    .iter()
                    .map(|r| reg.submit_with_backpressure("linear", r).unwrap())
                    .collect();
                reg.flush_all();
                for p in pending {
                    p.wait().unwrap();
                }
            };
            let st = bench_once("serve net direct", samples, &run);
            let (allocs, _) = allocs_during(&run);
            serve_entries.push(
                JsonObj::new()
                    .s("kernel", "net")
                    .s("model", "linear")
                    .s("mode", "direct")
                    .int("requests", queue.len() as u64)
                    .int("shards", 1)
                    .int("clients", 1)
                    .int("batch_window", batch_window as u64)
                    .int("pool_lanes", lanes as u64)
                    .int("d_in", 256)
                    .int("d_out", 16)
                    .num("median_ns", st.median_ns)
                    .num("req_per_s", st.per_sec(queue.len()))
                    .int("allocs_per_call", allocs),
            );
        }
        // mode=loopback: the same stream through NetServer/NetClient
        {
            let reg = mk_reg();
            let mut net = NetServer::bind(Arc::clone(&reg), "127.0.0.1:0").unwrap();
            let addr = net.local_addr().to_string();
            let cl = std::cell::RefCell::new(NetClient::connect(&addr).unwrap());
            // bit gate: loopback responses == direct submission bits
            {
                let mut c = cl.borrow_mut();
                for r in &queue {
                    c.send_request("linear", r).unwrap();
                }
                c.send_flush("linear").unwrap();
                for (i, w) in want.iter().enumerate() {
                    let (_, _, resp) = c.recv_response().unwrap();
                    assert!(resp.bit_eq(w), "net loopback changed bits at request {i}");
                }
                c.recv_flushed().unwrap();
            }
            let run = || {
                let mut c = cl.borrow_mut();
                for r in &queue {
                    c.send_request("linear", r).unwrap();
                }
                c.send_flush("linear").unwrap();
                for _ in 0..queue.len() {
                    c.recv_response().unwrap();
                }
                c.recv_flushed().unwrap();
            };
            let st = bench_once("serve net loopback", samples, &run);
            let (allocs, _) = allocs_during(&run);
            serve_entries.push(
                JsonObj::new()
                    .s("kernel", "net")
                    .s("model", "linear")
                    .s("mode", "loopback")
                    .int("requests", queue.len() as u64)
                    .int("shards", 1)
                    .int("clients", 1)
                    .int("batch_window", batch_window as u64)
                    .int("pool_lanes", lanes as u64)
                    .int("d_in", 256)
                    .int("d_out", 16)
                    .num("median_ns", st.median_ns)
                    .num("req_per_s", st.per_sec(queue.len()))
                    .int("allocs_per_call", allocs),
            );
            net.shutdown();
        }
    }
    write_bench_json(&bench_json_path("serve"), "serve", &serve_entries)
        .expect("write BENCH_serve.json");

    // ---------------- softmax + end-to-end ----------------
    section("E5: softmax 256x1024");
    let s = uniform_tensor(&[256, 1024], -5.0, 5.0, 5);
    let s1 = bench("repdl softmax (CR rexp)", samples, || softmax_rows(&s).unwrap());
    let s2 = bench("baseline softmax (fast libm)", samples, || {
        baseline_softmax_rows(&s, &p).unwrap()
    });
    row("repdl/baseline ratio", format!("{:.2}x", s1.median_ns / s2.median_ns));

    section("E5: end-to-end training step (MLP workload)");
    let cfg = TrainerConfig { steps: 5, ..Default::default() };
    let t1 = bench("repdl 5-step train", samples, || {
        Trainer::new(cfg, NumericsMode::Repro).run().unwrap()
    });
    let t2 = bench("baseline 5-step train", samples, || {
        Trainer::new(cfg, NumericsMode::Baseline(p)).run().unwrap()
    });
    row(
        "end-to-end repdl/baseline",
        format!("{:.2}x  (paper: 'mild degradation')", t1.median_ns / t2.median_ns),
    );
}
