//! E5 — "switching to RepDL can degrade performance mildly" (paper §4).
//!
//! Head-to-head: RepDL reproducible kernels vs the conventional baseline
//! kernels (which are free to pick any order), plus end-to-end training
//! step time. The interesting number is the ratio.

use repdl::baseline::{baseline_matmul, baseline_softmax_rows, PlatformProfile};
use repdl::bench_harness::{bench, row, section};
use repdl::coordinator::{NumericsMode, Trainer, TrainerConfig};
use repdl::nn::softmax_rows;
use repdl::rng::uniform_tensor;
use repdl::tensor::{conv2d, matmul, matmul_fma, matmul_pairwise, Conv2dParams};

fn main() {
    let p = PlatformProfile::zoo()[2]; // avx2-like: 8 lanes + FMA

    section("E5: GEMM 128x256 · 256x128");
    let a = uniform_tensor(&[128, 256], -1.0, 1.0, 1);
    let b = uniform_tensor(&[256, 128], -1.0, 1.0, 2);
    let r1 = bench("repdl matmul (seq-k)", 7, || matmul(&a, &b).unwrap());
    let r2 = bench("repdl matmul_fma", 7, || matmul_fma(&a, &b).unwrap());
    let r3 = bench("repdl matmul_pairwise", 7, || matmul_pairwise(&a, &b).unwrap());
    let rb = bench("baseline matmul (8-lane fma)", 7, || {
        baseline_matmul(&a, &b, &p).unwrap()
    });
    row("repdl/baseline ratio (seq)", format!("{:.2}x", r1.median_ns / rb.median_ns));
    row("repdl/baseline ratio (fma)", format!("{:.2}x", r2.median_ns / rb.median_ns));
    row("repdl/baseline ratio (pairwise)", format!("{:.2}x", r3.median_ns / rb.median_ns));

    section("E5: conv2d 8x16x28x28, 32 filters 3x3 pad 1");
    let x = uniform_tensor(&[8, 16, 28, 28], -1.0, 1.0, 3);
    let w = uniform_tensor(&[32, 16, 3, 3], -0.2, 0.2, 4);
    let pc = Conv2dParams { stride: 1, padding: 1 };
    let c1 = bench("repdl conv2d_direct (ablation)", 5, || repdl::tensor::conv2d_direct(&x, &w, None, pc).unwrap());
    let c2 = bench("repdl conv2d (routed: im2col+GEMM)", 5, || {
        conv2d(&x, &w, None, pc).unwrap()
    });
    row("routed/direct ratio", format!("{:.2}x", c2.median_ns / c1.median_ns));

    section("E5: softmax 256x1024");
    let s = uniform_tensor(&[256, 1024], -5.0, 5.0, 5);
    let s1 = bench("repdl softmax (CR rexp)", 7, || softmax_rows(&s).unwrap());
    let s2 = bench("baseline softmax (fast libm)", 7, || {
        baseline_softmax_rows(&s, &p).unwrap()
    });
    row("repdl/baseline ratio", format!("{:.2}x", s1.median_ns / s2.median_ns));

    section("E5: end-to-end training step (MLP workload)");
    let cfg = TrainerConfig { steps: 5, ..Default::default() };
    let t1 = bench("repdl 5-step train", 5, || {
        Trainer::new(cfg, NumericsMode::Repro).run().unwrap()
    });
    let t2 = bench("baseline 5-step train", 5, || {
        Trainer::new(cfg, NumericsMode::Baseline(p)).run().unwrap()
    });
    row(
        "end-to-end repdl/baseline",
        format!("{:.2}x  (paper: 'mild degradation')", t1.median_ns / t2.median_ns),
    );
}
