//! E5 — "switching to RepDL can degrade performance mildly" (paper §4).
//!
//! Head-to-head: RepDL reproducible kernels vs the conventional baseline
//! kernels (which are free to pick any order), plus end-to-end training
//! step time. The interesting number is the ratio.
//!
//! Also measures the *engine* change of this repo: persistent worker
//! pool vs the seed's spawn-scoped-threads-per-call dispatch (same
//! bits — asserted below — different wall-clock), and serving
//! throughput in req/s through the pooled batch path.

use repdl::baseline::{baseline_matmul, baseline_softmax_rows, PlatformProfile};
use repdl::bench_harness::{bench, row, row_rate, section};
use repdl::coordinator::{DeterministicServer, NumericsMode, Trainer, TrainerConfig};
use repdl::nn::softmax_rows;
use repdl::rng::uniform_tensor;
use repdl::tensor::par::par_chunks_spawn;
use repdl::tensor::{
    conv2d, default_threads, matmul, matmul_fma, matmul_in, matmul_pairwise, Conv2dParams,
    Tensor, WorkerPool,
};

/// The seed's engine: per-element dot GEMM with fresh scoped threads
/// spawned on every call (kept verbatim as the before/after baseline).
fn matmul_spawn_percall(a: &Tensor, b: &Tensor, nthreads: usize) -> Tensor {
    let (m, k, n) = (a.dims()[0], a.dims()[1], b.dims()[1]);
    let bt = b.transpose2d().unwrap();
    let mut out = Tensor::zeros(&[m, n]);
    let (ad, btd) = (a.data(), bt.data());
    par_chunks_spawn(out.data_mut(), n, nthreads, |start, c| {
        let i = start / n;
        for (j, v) in c.iter_mut().enumerate() {
            *v = repdl::rnum::dot::dot_strided(&ad[i * k..(i + 1) * k], 1, &btd[j * k..(j + 1) * k], 1, k);
        }
    });
    out
}

fn main() {
    let p = PlatformProfile::zoo()[2]; // avx2-like: 8 lanes + FMA
    let lanes = default_threads();

    section("E5: GEMM 128x256 · 256x128");
    let a = uniform_tensor(&[128, 256], -1.0, 1.0, 1);
    let b = uniform_tensor(&[256, 128], -1.0, 1.0, 2);
    let r1 = bench("repdl matmul (blocked, pooled)", 7, || matmul(&a, &b).unwrap());
    let r2 = bench("repdl matmul_fma", 7, || matmul_fma(&a, &b).unwrap());
    let r3 = bench("repdl matmul_pairwise", 7, || matmul_pairwise(&a, &b).unwrap());
    let rb = bench("baseline matmul (8-lane fma)", 7, || {
        baseline_matmul(&a, &b, &p).unwrap()
    });
    row("repdl/baseline ratio (seq)", format!("{:.2}x", r1.median_ns / rb.median_ns));
    row("repdl/baseline ratio (fma)", format!("{:.2}x", r2.median_ns / rb.median_ns));
    row("repdl/baseline ratio (pairwise)", format!("{:.2}x", r3.median_ns / rb.median_ns));

    section("E5: engine — spawn-per-call vs persistent pool (same bits)");
    // bit-equality gate: the engine change must be invisible in the output
    let pool = WorkerPool::new(lanes);
    assert!(
        matmul_spawn_percall(&a, &b, lanes).bit_eq(&repdl::tensor::matmul_dotform(&a, &b).unwrap()),
        "spawn baseline diverged from dotform"
    );
    assert!(
        matmul(&a, &b).unwrap().bit_eq(&repdl::tensor::matmul_dotform(&a, &b).unwrap()),
        "blocked pooled GEMM diverged from dotform"
    );
    // isolate the two changes: same dotform kernel on both engines
    // measures dispatch only; the blocked row adds the kernel change
    let s_spawn =
        bench("GEMM dotform, spawn-per-call (seed)", 7, || matmul_spawn_percall(&a, &b, lanes));
    let s_dot = bench("GEMM dotform, persistent pool", 7, || {
        repdl::tensor::matmul_dotform_in(&pool, &a, &b).unwrap()
    });
    let s_pool = bench("GEMM blocked, persistent pool", 7, || {
        matmul_in(&pool, &a, &b).unwrap()
    });
    row(
        "pool-dispatch speedup (same kernel)",
        format!("{:.2}x", s_spawn.median_ns / s_dot.median_ns),
    );
    row(
        "pool + blocked-kernel speedup (combined)",
        format!("{:.2}x", s_spawn.median_ns / s_pool.median_ns),
    );
    // small GEMM: thread-creation overhead dominates the seed engine
    let sa = uniform_tensor(&[16, 64], -1.0, 1.0, 21);
    let sb = uniform_tensor(&[64, 16], -1.0, 1.0, 22);
    let t_spawn =
        bench("small GEMM 16x64x16 spawn-per-call", 7, || matmul_spawn_percall(&sa, &sb, lanes));
    let t_dot = bench("small GEMM 16x64x16 pooled dotform", 7, || {
        repdl::tensor::matmul_dotform_in(&pool, &sa, &sb).unwrap()
    });
    row(
        "small-GEMM pool-dispatch speedup",
        format!("{:.2}x", t_spawn.median_ns / t_dot.median_ns),
    );

    section("E5: serving throughput (pooled whole-batch dispatch)");
    let w = uniform_tensor(&[256, 16], -0.3, 0.3, 5);
    let srv = DeterministicServer::new(w, 64);
    let queue: Vec<Tensor> = (0..64)
        .map(|i| uniform_tensor(&[256], -1.0, 1.0, 300 + i as u64))
        .collect();
    for l in [1usize, lanes.max(2)] {
        let pl = WorkerPool::new(l);
        let t = srv.throughput_report(&pl, &queue, 5).unwrap();
        row(format!("serve req/s, pool={l}").as_str(), format!("{:.0} req/s", t.req_per_s));
    }
    let stats = bench("serve 64 reqs (global pool)", 7, || srv.process_repro(&queue).unwrap());
    row_rate("serve throughput (global pool)", &stats, queue.len(), "req");

    section("E5: conv2d 8x16x28x28, 32 filters 3x3 pad 1");
    let x = uniform_tensor(&[8, 16, 28, 28], -1.0, 1.0, 3);
    let wc = uniform_tensor(&[32, 16, 3, 3], -0.2, 0.2, 4);
    let pc = Conv2dParams { stride: 1, padding: 1 };
    let c1 = bench("repdl conv2d_direct (ablation)", 5, || {
        repdl::tensor::conv2d_direct(&x, &wc, None, pc).unwrap()
    });
    let c2 = bench("repdl conv2d (routed: im2col+GEMM)", 5, || {
        conv2d(&x, &wc, None, pc).unwrap()
    });
    row("routed/direct ratio", format!("{:.2}x", c2.median_ns / c1.median_ns));

    section("E5: softmax 256x1024");
    let s = uniform_tensor(&[256, 1024], -5.0, 5.0, 5);
    let s1 = bench("repdl softmax (CR rexp)", 7, || softmax_rows(&s).unwrap());
    let s2 = bench("baseline softmax (fast libm)", 7, || {
        baseline_softmax_rows(&s, &p).unwrap()
    });
    row("repdl/baseline ratio", format!("{:.2}x", s1.median_ns / s2.median_ns));

    section("E5: end-to-end training step (MLP workload)");
    let cfg = TrainerConfig { steps: 5, ..Default::default() };
    let t1 = bench("repdl 5-step train", 5, || {
        Trainer::new(cfg, NumericsMode::Repro).run().unwrap()
    });
    let t2 = bench("baseline 5-step train", 5, || {
        Trainer::new(cfg, NumericsMode::Baseline(p)).run().unwrap()
    });
    row(
        "end-to-end repdl/baseline",
        format!("{:.2}x  (paper: 'mild degradation')", t1.median_ns / t2.median_ns),
    );
}
