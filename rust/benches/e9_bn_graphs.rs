//! E9 — the batch-norm computation-graph example (paper §3.2.3).
//!
//! The three real-number-equal orders the paper lists produce different
//! bits from one another while each is individually reproducible; the
//! table counts differing elements pairwise and times each graph.

use repdl::bench_harness::{bench, row, section};
use repdl::nn::{batch_norm, batch_norm_affine_folded, batch_norm_folded};
use repdl::rng::uniform_tensor;

fn main() {
    let x = uniform_tensor(&[8, 64, 28, 28], -3.0, 3.0, 1);
    let c = 64;
    let mean: Vec<f32> = (0..c).map(|i| (i as f32 * 0.13).sin() * 0.5).collect();
    let var: Vec<f32> = (0..c).map(|i| 0.5 + (i as f32 * 0.7).cos().abs()).collect();
    let w: Vec<f32> = (0..c).map(|i| 0.8 + (i % 5) as f32 * 0.1).collect();
    let b: Vec<f32> = (0..c).map(|i| (i as f32 * 0.31).sin() * 0.2).collect();
    let eps = 1e-5;

    let v1 = batch_norm(&x, &mean, &var, &w, &b, eps).unwrap();
    let v2 = batch_norm_folded(&x, &mean, &var, &w, &b, eps).unwrap();
    let v3 = batch_norm_affine_folded(&x, &mean, &var, &w, &b, eps).unwrap();

    let diff = |a: &repdl::tensor::Tensor, b: &repdl::tensor::Tensor| {
        a.data()
            .iter()
            .zip(b.data())
            .filter(|(x, y)| x.to_bits() != y.to_bits())
            .count()
    };

    section("E9: three batch-norm graphs (8x64x28x28, 401k elements)");
    row("graph1 vs graph2: differing elements", diff(&v1, &v2));
    row("graph1 vs graph3: differing elements", diff(&v1, &v3));
    row("graph2 vs graph3: differing elements", diff(&v2, &v3));
    row(
        "graph1 deterministic",
        v1.bit_eq(&batch_norm(&x, &mean, &var, &w, &b, eps).unwrap()),
    );
    row(
        "graph2 deterministic",
        v2.bit_eq(&batch_norm_folded(&x, &mean, &var, &w, &b, eps).unwrap()),
    );
    row(
        "graph3 deterministic",
        v3.bit_eq(&batch_norm_affine_folded(&x, &mean, &var, &w, &b, eps).unwrap()),
    );

    section("E9: cost per graph");
    bench("batch_norm (documented order)", 7, || {
        batch_norm(&x, &mean, &var, &w, &b, eps).unwrap()
    });
    bench("batch_norm_folded", 7, || {
        batch_norm_folded(&x, &mean, &var, &w, &b, eps).unwrap()
    });
    bench("batch_norm_affine_folded", 7, || {
        batch_norm_affine_folded(&x, &mean, &var, &w, &b, eps).unwrap()
    });
}
