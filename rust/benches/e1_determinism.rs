//! E1 — run-to-run determinism (paper §1, §2.2.2 "atomic operations").
//!
//! Regenerates the claim as a table: N repeated training runs per
//! numerics mode → number of distinct final-state hashes (RepDL must give
//! 1; the simulated-atomics baseline gives ≈N) + step time.

use repdl::baseline::PlatformProfile;
use repdl::bench_harness::{bench, row, section};
use repdl::coordinator::{NumericsMode, Trainer, TrainerConfig};
use std::collections::HashSet;

fn distinct_hashes(mode: NumericsMode, runs: usize, cfg: TrainerConfig) -> usize {
    let mut set = HashSet::new();
    for _ in 0..runs {
        set.insert(Trainer::new(cfg, mode).run().unwrap().param_hash);
    }
    set.len()
}

fn main() {
    let cfg = TrainerConfig { steps: 25, ..Default::default() };
    let p = PlatformProfile::reference();
    section("E1: run-to-run determinism (5 runs each, 25 training steps)");
    row(
        "repdl            distinct final states",
        distinct_hashes(NumericsMode::Repro, 5, cfg),
    );
    row(
        "baseline         distinct final states",
        distinct_hashes(NumericsMode::Baseline(p), 5, cfg),
    );
    row(
        "baseline+atomics distinct final states",
        distinct_hashes(NumericsMode::BaselineAtomic(p), 5, cfg),
    );

    section("E1: training cost by mode (5 steps)");
    let small = TrainerConfig { steps: 5, ..Default::default() };
    bench("repdl 5-step train", 5, || {
        Trainer::new(small, NumericsMode::Repro).run().unwrap()
    });
    bench("baseline 5-step train", 5, || {
        Trainer::new(small, NumericsMode::Baseline(p)).run().unwrap()
    });
    bench("baseline+atomics 5-step train", 5, || {
        Trainer::new(small, NumericsMode::BaselineAtomic(p)).run().unwrap()
    });
}
