//! E4 — summation-order analysis (paper §3.2.2).
//!
//! Regenerates the section's content as tables:
//! 1. throughput of sequential / pairwise / Kahan / exact-superaccumulator
//!    summation (the paper rejects the superaccumulator on these grounds);
//! 2. the t_fc / t_conv parallel-task analysis including the ResNet-50
//!    worked example (t_conv = B·802816 ≫ 6912 CUDA cores).

use repdl::bench_harness::{bench, row, section};
use repdl::rnum::{sum_exact, sum_kahan, sum_pairwise, sum_sequential};

fn main() {
    let n = 1 << 20;
    let xs: Vec<f32> = (0..n).map(|i| ((i * 37 % 1013) as f32 - 506.0) * 0.01).collect();

    section("E4: summation algorithms, 2^20 elements");
    let s1 = bench("sum_sequential", 7, || sum_sequential(&xs));
    let s2 = bench("sum_pairwise", 7, || sum_pairwise(&xs));
    let s3 = bench("sum_kahan", 7, || sum_kahan(&xs));
    let s4 = bench("sum_exact (superaccumulator)", 7, || sum_exact(&xs));
    row(
        "superacc slowdown vs sequential",
        format!("{:.1}x  (the paper's 'too inefficient')", s4.median_ns / s1.median_ns),
    );
    row(
        "pairwise overhead vs sequential",
        format!("{:.2}x", s2.median_ns / s1.median_ns),
    );
    row(
        "kahan overhead vs sequential",
        format!("{:.2}x", s3.median_ns / s1.median_ns),
    );

    section("E4: accuracy on ill-conditioned data (n=2^20, mixed magnitudes)");
    let wild: Vec<f32> = (0..n)
        .map(|i| {
            let m = [1.0f32, 1e6, -1e6, 1e-6][i % 4];
            ((i * 131 % 997) as f32 - 498.0) * m * 1e-3
        })
        .collect();
    let exact = sum_exact(&wild) as f64;
    for (name, v) in [
        ("sequential", sum_sequential(&wild) as f64),
        ("pairwise", sum_pairwise(&wild) as f64),
        ("kahan", sum_kahan(&wild) as f64),
        ("superacc (exact)", exact),
    ] {
        row(
            &format!("{name}: |err| vs exact"),
            format!("{:.3e}", (v - exact).abs()),
        );
    }

    section("E4: the paper's parallel-task analysis (reproduced table)");
    println!(
        "{:<34} {:>14} {:>10} {:>18}",
        "layer", "tasks t", "n per task", "t >= 6912 cores?"
    );
    // fully connected: t_fc = B*M, n_fc = N
    for (b, m, nf) in [(1usize, 1000usize, 2048usize), (32, 1000, 2048), (256, 4096, 1024)] {
        println!(
            "{:<34} {:>14} {:>10} {:>18}",
            format!("fc B={b} M={m} N={nf}"),
            b * m,
            nf,
            if b * m >= 6912 { "yes" } else { "NO -> pairwise" }
        );
    }
    // conv: t_conv = B*O*W*H, n_conv = I*Kw*Kh — the ResNet-50 example
    for (b, o, w, h, i, k) in [
        (1usize, 256usize, 56usize, 56usize, 64usize, 1usize),
        (1, 256, 56, 56, 128, 3),
        (8, 512, 7, 7, 512, 3),
    ] {
        println!(
            "{:<34} {:>14} {:>10} {:>18}",
            format!("conv B={b} O={o} {w}x{h} I={i} K={k}"),
            b * o * w * h,
            i * k * k,
            if b * o * w * h >= 6912 { "yes" } else { "NO -> pairwise" }
        );
    }
    row(
        "ResNet-50 t_conv at B=1 (paper's example)",
        format!("{} = 802816  >> 6912 A100 cores", 256 * 56 * 56),
    );
}
