//! E2 — cross-platform reproducibility (paper §1, §2.2).
//!
//! Table: per simulated platform, the first training step at which the
//! conventional baseline diverges from the reference platform, plus the
//! RepDL control (identical everywhere — verified, not assumed).

use repdl::baseline::PlatformProfile;
use repdl::bench_harness::{row, section};
use repdl::coordinator::{compare_runs, NumericsMode, Trainer, TrainerConfig};

fn main() {
    let cfg = TrainerConfig { steps: 40, ..Default::default() };
    section("E2: cross-platform divergence (baseline numerics, 40 steps)");
    let reference = Trainer::new(cfg, NumericsMode::Baseline(PlatformProfile::reference()))
        .run()
        .unwrap();
    println!(
        "{:<24} {:>10} {:>14} {:>10}",
        "platform", "div-step", "max curve ulp", "state eq"
    );
    for p in PlatformProfile::zoo() {
        let r = Trainer::new(cfg, NumericsMode::Baseline(p)).run().unwrap();
        let c = compare_runs(
            &reference.loss_curve,
            &r.loss_curve,
            &reference.param_hash,
            &r.param_hash,
        );
        println!(
            "{:<24} {:>10} {:>14} {:>10}",
            p.name,
            c.first_divergence.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            c.max_ulp,
            c.hashes_equal
        );
    }

    section("E2: RepDL under the same sweep");
    let a = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
    let mut all_equal = true;
    for _ in 0..PlatformProfile::zoo().len() {
        let r = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        all_equal &= r.param_hash == a.param_hash;
    }
    row("repdl: all runs bit-identical", all_equal);
    assert!(all_equal);
}
