//! E7 — dynamic-batching hazard (paper §2.2.2): per-request bitwise
//! stability under varying batch composition, per platform, plus serving
//! throughput.

use repdl::baseline::PlatformProfile;
use repdl::bench_harness::{bench, row, section};
use repdl::coordinator::DeterministicServer;
use repdl::rng::uniform_tensor;
use repdl::tensor::Tensor;

fn main() {
    let d = 256;
    let n = 64;
    let w = uniform_tensor(&[d, 16], -0.3, 0.3, 5);
    let srv = DeterministicServer::new(w, 64).unwrap();
    let queue: Vec<Tensor> = (0..n)
        .map(|i| uniform_tensor(&[d], -1.0, 1.0, 100 + i as u64))
        .collect();

    section("E7: per-request bit changes across batch sizes {1,4,16,64}");
    println!("{:<24} {:>14} {:>18}", "platform", "repdl", "baseline");
    for p in PlatformProfile::zoo() {
        let rep = srv
            .batch_invariance_report(&queue, &[1, 4, 16, 64], &p)
            .unwrap();
        println!(
            "{:<24} {:>10}/{:<3} {:>14}/{:<3}",
            p.name, rep.repro_mismatches, rep.requests, rep.baseline_mismatches, rep.requests
        );
        assert_eq!(rep.repro_mismatches, 0);
    }

    section("E7: serving throughput (64 requests, max_batch 16)");
    let srv16 = DeterministicServer::new(uniform_tensor(&[d, 16], -0.3, 0.3, 5), 16).unwrap();
    let s = bench("repdl path", 7, || srv16.process_repro(&queue).unwrap());
    let p = PlatformProfile::zoo()[2];
    let b = bench("baseline path", 7, || srv16.process_baseline(&queue, &p).unwrap());
    row(
        "requests/sec (repdl)",
        format!("{:.0}", n as f64 / (s.median_ns / 1e9)),
    );
    row("repdl/baseline latency ratio", format!("{:.2}x", s.median_ns / b.median_ns));
}
