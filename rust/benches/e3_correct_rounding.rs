//! E3 — correct rounding of basic operations (paper §3.2.1).
//!
//! For each basic op: 0-ulp rate vs the 320-bit BigFloat oracle over a
//! large pseudo-random sweep, the competing "fast libm" variants' ULP
//! histograms (the paper's §2.2.1 hazard), and the runtime cost of
//! correct rounding.

use repdl::baseline::{exp_variant, log_variant, MathImpl};
use repdl::bench_harness::{bench, row, section};
use repdl::proptest::Gen;
use repdl::rnum::bigfloat::{BigFloat, PREC_ORACLE};
use repdl::rnum::fbits::ulp_diff;
use repdl::rnum::{rcos, rexp, rlog, rrsqrt, rsin, rsqrt_f32, rtanh};

const N: usize = 200_000;

fn sweep(
    name: &str,
    mut gen: impl FnMut(&mut Gen) -> f32,
    got: impl Fn(f32) -> f32,
    oracle: impl Fn(f32) -> f32,
) {
    let mut g = Gen::new(0xE3);
    let mut worst = 0u32;
    let mut exact = 0usize;
    for _ in 0..N {
        let x = gen(&mut g);
        let d = ulp_diff(got(x), oracle(x));
        worst = worst.max(d);
        exact += (d == 0) as usize;
    }
    row(
        &format!("{name}: 0-ulp rate"),
        format!("{exact}/{N}  (max {worst} ulp)"),
    );
    assert_eq!(exact, N, "{name} violated correct rounding");
}

fn main() {
    section("E3: correct-rounding verification vs 320-bit oracle");
    sweep(
        "rexp ",
        |g| g.f32_range(-104.0, 89.0),
        rexp,
        |x| BigFloat::from_f32(x, PREC_ORACLE).exp_bf().to_f32(),
    );
    sweep(
        "rlog ",
        |g| {
            let v = g.f32_any().abs();
            if v == 0.0 || !v.is_finite() {
                1.5
            } else {
                v
            }
        },
        rlog,
        |x| BigFloat::from_f32(x, PREC_ORACLE).ln_bf().to_f32(),
    );
    sweep(
        "rsin ",
        |g| g.f32_range(-1e6, 1e6),
        rsin,
        |x| BigFloat::from_f32(x, PREC_ORACLE).sin_bf().to_f32(),
    );
    sweep(
        "rcos ",
        |g| g.f32_range(-1e6, 1e6),
        rcos,
        |x| BigFloat::from_f32(x, PREC_ORACLE).cos_bf().to_f32(),
    );
    sweep(
        "rtanh",
        |g| g.f32_range(-9.9, 9.9),
        rtanh,
        |x| BigFloat::from_f32(x, PREC_ORACLE).tanh_bf().to_f32(),
    );
    sweep(
        "rsqrt",
        |g| {
            let v = g.f32_any().abs();
            if v.is_finite() {
                v
            } else {
                2.0
            }
        },
        rsqrt_f32,
        |x| BigFloat::from_f32(x, PREC_ORACLE).sqrt().to_f32(),
    );
    sweep(
        "rrsqrt",
        |g| g.f32_range(1e-30, 1e30),
        rrsqrt,
        |x| {
            let b = BigFloat::from_f32(x, PREC_ORACLE);
            BigFloat::one(PREC_ORACLE).div(&b.sqrt()).to_f32()
        },
    );

    section("E3: fast-libm variants' ULP distribution (exp, 100k points)");
    let mut g = Gen::new(7);
    let mut hist = [[0u32; 4]; 2];
    for _ in 0..100_000 {
        let x = g.f32_range(-85.0, 85.0);
        let want = BigFloat::from_f32(x, PREC_ORACLE).exp_bf().to_f32();
        hist[0][ulp_diff(exp_variant(x, MathImpl::GlibcLike), want).min(3) as usize] += 1;
        hist[1][ulp_diff(exp_variant(x, MathImpl::IntelLike), want).min(3) as usize] += 1;
    }
    println!("{:<16} {:>8} {:>8} {:>8} {:>8}", "impl", "0", "1", "2", ">2 ulp");
    for (name, h) in [("glibc-like", hist[0]), ("intel-like", hist[1])] {
        println!("{name:<16} {:>8} {:>8} {:>8} {:>8}", h[0], h[1], h[2], h[3]);
    }

    section("E3: cost of correct rounding (1000 calls per sample)");
    let xs: Vec<f32> = (0..1000).map(|i| -80.0 + i as f32 * 0.16).collect();
    bench("rexp (CR)", 7, || xs.iter().map(|&x| rexp(x)).sum::<f32>());
    bench("libm expf (platform)", 7, || xs.iter().map(|&x| x.exp()).sum::<f32>());
    bench("glibc-like variant", 7, || {
        xs.iter().map(|&x| exp_variant(x, MathImpl::GlibcLike)).sum::<f32>()
    });
    let ys: Vec<f32> = (0..1000).map(|i| 0.001 + i as f32 * 7.3).collect();
    bench("rlog (CR)", 7, || ys.iter().map(|&x| rlog(x)).sum::<f32>());
    bench("libm logf (platform)", 7, || ys.iter().map(|&x| x.ln()).sum::<f32>());
    bench("intel-like variant", 7, || {
        ys.iter().map(|&x| log_variant(x, MathImpl::IntelLike)).sum::<f32>()
    });
}
