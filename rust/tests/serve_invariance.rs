//! E7 integration — dynamic-batching hazard vs RepDL batch invariance.

use repdl::baseline::PlatformProfile;
use repdl::coordinator::DeterministicServer;
use repdl::rng::uniform_tensor;
use repdl::tensor::Tensor;

fn queue(n: usize, d: usize, seed: u64) -> Vec<Tensor> {
    (0..n)
        .map(|i| uniform_tensor(&[d], -1.0, 1.0, seed + i as u64))
        .collect()
}

#[test]
fn repdl_outputs_do_not_depend_on_batch_composition() {
    let w = uniform_tensor(&[256, 8], -0.3, 0.3, 1);
    let srv = DeterministicServer::new(w, 64).unwrap();
    let q = queue(64, 256, 100);
    let p = PlatformProfile::zoo()[4];
    let rep = srv
        .batch_invariance_report(&q, &[1, 2, 8, 17, 64], &p)
        .unwrap();
    assert_eq!(rep.repro_mismatches, 0);
    assert!(rep.baseline_mismatches > 0);
    // mismatch fraction is substantial on a size-dispatching platform
    assert!(rep.baseline_mismatches * 2 >= rep.requests);
}

#[test]
fn arrival_order_processing_is_stable() {
    let w = uniform_tensor(&[32, 4], -0.5, 0.5, 2);
    let srv = DeterministicServer::new(w, 5).unwrap();
    let q = queue(13, 32, 200);
    let a = srv.process_repro(&q).unwrap();
    let b = srv.process_repro(&q).unwrap();
    for (x, y) in a.iter().zip(b.iter()) {
        assert!(x.bit_eq(y));
    }
    assert_eq!(a.len(), 13);
}
