//! Crash-consistent recovery conformance suite (DESIGN.md §11).
//!
//! The tentpole claim: a serve process recovered from its journal is
//! **bit-identical to one that never crashed**. The crash matrix cuts
//! the reference run's journal after *every* record boundary (and mid-
//! record, for torn tails), across shards × pool lanes × cache on/off ×
//! KV-sessions on/off, and asserts in every cell that
//!
//! * recovery restores/re-derives exactly the journaled tickets with
//!   the uninterrupted run's response hashes AND batch ids,
//! * the resumed process serves the remaining requests with the
//!   uninterrupted run's bits, and
//! * `replay()` re-verifies the stitched log end to end.
//!
//! Around the matrix: journal byte-determinism (two identical runs →
//! identical files), deterministic fault injection (fail-stop vs
//! degrade-to-memory, short writes → torn tails), watermark survival,
//! failed-batch tickets, and the identity checks that make recovery
//! refuse a journal it cannot faithfully continue.

use repdl::coordinator::{
    read_journal, DeterministicServer, FaultPlan, FaultyWriter, Journal, JournalPolicy,
    ModelTower, PanicAtTicket, ServeConfig, ServeScheduler, TransformerTower, VecWriter,
};
use repdl::nn::{CharTransformer, TransformerConfig};
use repdl::rng::uniform_tensor;
use repdl::tensor::{Tensor, WorkerPool};
use repdl::Error;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("repdl-serve-recovery");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// The 12-byte file header (`REPDLJNL` + LE version 1), re-derived here
/// so the tests pin the on-disk format independently of the encoder.
fn journal_header() -> Vec<u8> {
    let mut h = b"REPDLJNL".to_vec();
    h.extend(1u32.to_le_bytes());
    h
}

/// Byte offsets of every record boundary in a cleanly closed journal
/// file, starting at the header boundary — recomputed from the
/// length-prefixed framing (u32 LE len ‖ payload ‖ 32-byte digest).
fn record_boundaries(bytes: &[u8]) -> Vec<usize> {
    let mut out = vec![12usize];
    let mut off = 12usize;
    while off < bytes.len() {
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        off += 4 + len + 32;
        out.push(off);
    }
    assert_eq!(off, bytes.len(), "reference journal must be cleanly closed");
    out
}

fn server(d_in: usize, d_out: usize, seed: u64) -> Arc<DeterministicServer> {
    let w = uniform_tensor(&[d_in, d_out], -0.3, 0.3, seed);
    Arc::new(DeterministicServer::new(w, 8).unwrap())
}

fn queue(n: usize, d: usize, seed: u64) -> Vec<Tensor> {
    (0..n)
        .map(|i| uniform_tensor(&[d], -1.0, 1.0, seed + i as u64))
        .collect()
}

fn cfg(journal: Option<Arc<Journal>>) -> ServeConfig {
    ServeConfig { batch_window: 4, log: true, journal, ..Default::default() }
}

fn tiny_model() -> CharTransformer {
    let c = TransformerConfig {
        vocab: 10,
        dim: 8,
        heads: 2,
        layers: 1,
        context: 4,
        mlp_ratio: 2,
    };
    CharTransformer::new(c, 17).unwrap()
}

fn prefix(stream: &[usize; 4], tt: usize) -> Tensor {
    Tensor::from_vec(&[tt], stream[..tt].iter().map(|&i| i as f32).collect()).unwrap()
}

/// THE crash matrix. Every cell builds an uninterrupted journaled
/// reference run, then for every crash point — after each record, plus
/// torn mid-record tails on the widest cells — rebuilds a fresh
/// scheduler from the cut journal and demands bit-identity with the
/// reference, both for the recovered prefix and for the resumed
/// remainder.
#[test]
fn crash_at_every_record_boundary_recovers_bit_identically_everywhere() {
    let streams: [[usize; 4]; 2] = [[1, 4, 2, 9], [5, 0, 3, 7]];
    // interleaved decode prefixes + a repeated tail, so cache-on cells
    // serve real hits and session-on cells take the incremental path
    let mut q: Vec<Tensor> = Vec::new();
    for tt in 1..=4 {
        for s in &streams {
            q.push(prefix(s, tt));
        }
    }
    for tt in 1..=2 {
        for s in &streams {
            q.push(prefix(s, tt));
        }
    }
    let n = q.len() as u64; // 12
    for shards in [1usize, 2] {
        for lanes in [1usize, 2] {
            for cache in [0usize, 8] {
                for sessions in [false, true] {
                    let cell = format!("shards={shards} lanes={lanes} cache={cache} kv={sessions}");
                    let mk_tower = || -> Arc<dyn ModelTower> {
                        let t = TransformerTower::new(tiny_model()).unwrap();
                        Arc::new(if sessions { t.with_sessions(8) } else { t })
                    };
                    let mk_cfg = |j: Option<Arc<Journal>>| ServeConfig {
                        batch_window: 4,
                        cache_capacity: cache,
                        log: true,
                        journal: j,
                        ..Default::default()
                    };
                    // uninterrupted reference run, journaled
                    let ref_path =
                        tmp(&format!("matrix-s{shards}l{lanes}c{cache}k{sessions}-ref.journal"));
                    let want: Vec<Tensor>;
                    let want_entries: Vec<(String, u64)>;
                    {
                        let j = Journal::create(&ref_path, JournalPolicy::FailStop).unwrap();
                        let sched = ServeScheduler::sharded_with(
                            mk_tower(),
                            shards,
                            WorkerPool::shared(lanes),
                            mk_cfg(Some(Arc::new(j))),
                        )
                        .unwrap();
                        want = sched.process_all(&q).unwrap();
                        let log = sched.log().unwrap();
                        want_entries = (0..n)
                            .map(|t| {
                                let e = log.get(t).unwrap();
                                (e.response_hash.clone(), e.batch_id)
                            })
                            .collect();
                    } // drop: dispatchers join, buffered responses drain
                    let bytes = std::fs::read(&ref_path).unwrap();
                    let mut crash_points = record_boundaries(&bytes);
                    if shards == 2 && lanes == 2 {
                        // torn tails too: cut 8 bytes into every record
                        // (mid length-field or mid payload — read_journal
                        // must repair either to the previous boundary)
                        let ends = crash_points.clone();
                        for w in ends.windows(2) {
                            crash_points.push(w[0] + 8);
                        }
                    }
                    let crash_path =
                        tmp(&format!("matrix-s{shards}l{lanes}c{cache}k{sessions}-crash.journal"));
                    for &cp in &crash_points {
                        std::fs::write(&crash_path, &bytes[..cp]).unwrap();
                        let readout = read_journal(&crash_path).unwrap();
                        let j = Journal::open_append(&crash_path, JournalPolicy::FailStop).unwrap();
                        let sched = ServeScheduler::sharded_with(
                            mk_tower(),
                            shards,
                            WorkerPool::shared(lanes),
                            mk_cfg(Some(Arc::new(j))),
                        )
                        .unwrap();
                        let k = if readout.events.is_empty() {
                            0 // crashed before the ident record: cold start
                        } else {
                            let rep = sched.recover(&readout).unwrap();
                            assert!(rep.consistent(), "{cell} cp={cp}: {rep:?}");
                            assert_eq!(
                                rep.responses_restored + rep.re_executed,
                                rep.next_ticket,
                                "{cell} cp={cp}: every journaled ticket accounted for"
                            );
                            rep.next_ticket as usize
                        };
                        let log = sched.log().unwrap();
                        for t in 0..k as u64 {
                            let e = log.get(t).unwrap();
                            let (want_hash, want_batch) = &want_entries[t as usize];
                            assert_eq!(
                                &e.response_hash, want_hash,
                                "{cell} cp={cp} ticket {t}: recovered bits differ"
                            );
                            assert_eq!(
                                e.batch_id, *want_batch,
                                "{cell} cp={cp} ticket {t}: recovered batch id differs"
                            );
                        }
                        // resume the interrupted run: the remaining
                        // requests must get the uninterrupted run's bits
                        let pending: Vec<_> =
                            q[k..].iter().map(|r| sched.submit(r.clone()).unwrap()).collect();
                        sched.flush();
                        for (i, p) in pending.into_iter().enumerate() {
                            let got = p.wait().unwrap();
                            assert!(
                                got.bit_eq(&want[k + i]),
                                "{cell} cp={cp}: resumed request {} changed bits",
                                k + i
                            );
                        }
                        // full audit of the stitched (restored +
                        // re-derived + freshly served) log
                        let rep2 = sched.replay(0..n).unwrap();
                        assert_eq!(rep2.replayed, q.len(), "{cell} cp={cp}");
                        assert!(rep2.verified(), "{cell} cp={cp}: {rep2:?}");
                    }
                    std::fs::remove_file(&ref_path).ok();
                    std::fs::remove_file(&crash_path).ok();
                }
            }
        }
    }
}

/// Two identical logical runs must produce **byte-identical** journal
/// files — no wall clock, pids or thread timing in the stream — for one
/// and for two racing dispatchers. Different layouts must differ (the
/// ident record pins them apart).
#[test]
fn identical_runs_write_byte_identical_journal_files() {
    let srv = server(16, 4, 3);
    let q = queue(10, 16, 600);
    let mut per_shards: Vec<Vec<u8>> = Vec::new();
    for shards in [1usize, 2] {
        let mut files: Vec<Vec<u8>> = Vec::new();
        for run in 0..2 {
            let path = tmp(&format!("bytes-s{shards}-r{run}.journal"));
            let j = Journal::create(&path, JournalPolicy::FailStop).unwrap();
            let sched = ServeScheduler::sharded_with(
                Arc::clone(&srv),
                shards,
                WorkerPool::shared(2),
                cfg(Some(Arc::new(j))),
            )
            .unwrap();
            sched.process_all(&q).unwrap();
            drop(sched); // joins dispatchers, drains responses, fsyncs
            files.push(std::fs::read(&path).unwrap());
            std::fs::remove_file(&path).ok();
        }
        assert_eq!(
            files[0], files[1],
            "shards={shards}: identical runs diverged on journal bytes"
        );
        per_shards.push(files.remove(0));
    }
    assert_ne!(per_shards[0], per_shards[1], "the ident record must pin the shard layout");
}

/// A short write mid-run (the on-disk signature of a crash inside
/// `write(2)`) leaves a torn tail; `read_journal` repairs it in place
/// and recovery re-derives the durable prefix bit-identically.
#[test]
fn a_short_write_crash_recovers_the_durable_prefix_bit_identically() {
    let srv = server(16, 4, 5);
    let q = queue(8, 16, 700);
    // the reference bits, from a journal-less run of the same scheduler
    let want = ServeScheduler::sharded_with(
        Arc::clone(&srv),
        1,
        WorkerPool::shared(1),
        cfg(None),
    )
    .unwrap()
    .process_all(&q)
    .unwrap();
    // appends: ident=0, submit t=1..; short-write append 4 (= submit of
    // ticket 3) to its first 7 bytes, then degrade so serving continues
    let buf = Arc::new(Mutex::new(Vec::new()));
    let writer = FaultyWriter::new(
        Box::new(VecWriter::new(Arc::clone(&buf))),
        FaultPlan::new().short_append(4, 7),
    );
    let j = Journal::with_writer(Box::new(writer), JournalPolicy::DegradeToMemory);
    let sched = ServeScheduler::sharded_with(
        Arc::clone(&srv),
        1,
        WorkerPool::shared(1),
        cfg(Some(Arc::new(j))),
    )
    .unwrap();
    let outs = sched.process_all(&q).unwrap();
    for (a, b) in outs.iter().zip(want.iter()) {
        assert!(a.bit_eq(b), "degraded journalling must never change bits");
    }
    let stats = sched.journal_stats().unwrap();
    assert!(stats.drops > 0, "the short write and everything after it count as drops");
    drop(sched);
    // materialise the torn stream as a journal file and recover from it
    let path = tmp("short-write.journal");
    let mut file = journal_header();
    file.extend(lock_bytes(&buf));
    std::fs::write(&path, &file).unwrap();
    let readout = read_journal(&path).unwrap();
    assert_eq!(readout.torn_bytes, 7, "exactly the short-written bytes are repaired away");
    assert_eq!(
        std::fs::metadata(&path).unwrap().len() as usize,
        file.len() - 7,
        "the repair is physical"
    );
    let sched = ServeScheduler::sharded_with(
        Arc::clone(&srv),
        1,
        WorkerPool::shared(1),
        cfg(Some(Arc::new(Journal::open_append(&path, JournalPolicy::FailStop).unwrap()))),
    )
    .unwrap();
    let rep = sched.recover(&readout).unwrap();
    assert!(rep.consistent(), "{rep:?}");
    assert_eq!(rep.submits, 3, "tickets 0..3 were durable before the torn submit");
    assert_eq!(rep.re_executed, 3, "no response record survived: all re-derived");
    let log = sched.log().unwrap();
    for t in 0..3u64 {
        assert_eq!(
            log.get(t).unwrap().response_hash,
            repdl::coordinator::hash_tensor(&want[t as usize]),
            "ticket {t}: recovered bits differ from the uninterrupted run"
        );
    }
    // resume: the rest of the queue serves the uninterrupted bits
    let pending: Vec<_> = q[3..].iter().map(|r| sched.submit(r.clone()).unwrap()).collect();
    sched.flush();
    for (i, p) in pending.into_iter().enumerate() {
        assert!(p.wait().unwrap().bit_eq(&want[3 + i]));
    }
    assert!(sched.replay(0..8).unwrap().verified());
    drop(sched);
    std::fs::remove_file(&path).ok();
}

fn lock_bytes(buf: &Arc<Mutex<Vec<u8>>>) -> Vec<u8> {
    buf.lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Fail-stop: the submit whose journal append fails gets the typed
/// `Error::Journal`, consumes **no ticket**, and every later submit
/// fails with the latched cause — while already-accepted requests are
/// still answered with exact bits.
#[test]
fn fail_stop_fails_the_submit_without_consuming_a_ticket() {
    let srv = server(16, 4, 9);
    let q = queue(3, 16, 800);
    let buf = Arc::new(Mutex::new(Vec::new()));
    let writer = FaultyWriter::new(
        Box::new(VecWriter::new(Arc::clone(&buf))),
        FaultPlan::new().fail_append(2), // ident=0, submit 0=1, submit 1=2
    );
    let j = Journal::with_writer(Box::new(writer), JournalPolicy::FailStop);
    let sched = ServeScheduler::sharded_with(
        Arc::clone(&srv),
        1,
        WorkerPool::shared(1),
        cfg(Some(Arc::new(j))),
    )
    .unwrap();
    let p0 = sched.submit(q[0].clone()).unwrap();
    let e = sched.submit(q[1].clone()).unwrap_err();
    assert!(matches!(e, Error::Journal(_)), "want Error::Journal, got {e:?}");
    assert!(format!("{e}").contains("injected fault"), "{e}");
    // latched: the journal can no longer prove the event stream, so
    // every later submit is refused with the original cause
    let e2 = sched.submit(q[2].clone()).unwrap_err();
    assert!(matches!(e2, Error::Journal(_)), "{e2:?}");
    sched.flush();
    assert!(p0.wait().unwrap().bit_eq(
        &ServeScheduler::sharded(Arc::clone(&srv), 1, 4, WorkerPool::shared(1))
            .unwrap()
            .process_all(&q[..1])
            .unwrap()[0]
    ));
    let stats = sched.journal_stats().unwrap();
    assert!(stats.failed);
    assert_eq!(stats.appends, 2, "ident + the one durable submit");
    // no ticket was consumed by the failed submits: exactly one logged
    assert_eq!(sched.log().unwrap().len(), 1);
}

/// Degrade-to-memory: serving continues bit-identically past the fault,
/// every unpersisted record is counted, and the journal's durable
/// prefix still recovers bit-exactly (with recovery running without any
/// journal attached — the readout alone carries the evidence).
#[test]
fn degrade_to_memory_keeps_serving_and_recovers_its_durable_prefix() {
    let srv = server(16, 4, 11);
    let q = queue(6, 16, 900);
    let want = ServeScheduler::sharded_with(
        Arc::clone(&srv),
        1,
        WorkerPool::shared(1),
        cfg(None),
    )
    .unwrap()
    .process_all(&q)
    .unwrap();
    let buf = Arc::new(Mutex::new(Vec::new()));
    let writer = FaultyWriter::new(
        Box::new(VecWriter::new(Arc::clone(&buf))),
        FaultPlan::new().fail_append(3), // ident, submits 0 and 1 land
    );
    let j = Journal::with_writer(Box::new(writer), JournalPolicy::DegradeToMemory);
    let sched = ServeScheduler::sharded_with(
        Arc::clone(&srv),
        1,
        WorkerPool::shared(1),
        cfg(Some(Arc::new(j))),
    )
    .unwrap();
    let outs = sched.process_all(&q).unwrap();
    for (a, b) in outs.iter().zip(want.iter()) {
        assert!(a.bit_eq(b), "degradation must never change bits");
    }
    drop(sched);
    // drops: submit 2 (the fault) + submits 3..5 + one flush cut + six
    // buffered responses drained at drop = 11, all counted
    // deterministically — reconstruct the journal and check the prefix
    let path = tmp("degrade.journal");
    let mut file = journal_header();
    file.extend(lock_bytes(&buf));
    std::fs::write(&path, &file).unwrap();
    let readout = read_journal(&path).unwrap();
    assert_eq!(readout.torn_bytes, 0, "degraded drops never tear the stream");
    let sched = ServeScheduler::sharded_with(
        Arc::clone(&srv),
        1,
        WorkerPool::shared(1),
        cfg(None),
    )
    .unwrap();
    let rep = sched.recover(&readout).unwrap();
    assert!(rep.consistent(), "{rep:?}");
    assert_eq!((rep.submits, rep.re_executed, rep.next_ticket), (2, 2, 2));
    let log = sched.log().unwrap();
    for t in 0..2u64 {
        assert_eq!(
            log.get(t).unwrap().response_hash,
            repdl::coordinator::hash_tensor(&want[t as usize]),
            "ticket {t}"
        );
    }
    std::fs::remove_file(&path).ok();
}

/// The exact drop accounting of a degraded run is deterministic: every
/// record the tripped writer could not persist is counted, none twice.
#[test]
fn degraded_drop_counters_are_event_sequence_pure() {
    let srv = server(16, 4, 11);
    let q = queue(6, 16, 900);
    for _ in 0..2 {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let writer = FaultyWriter::new(
            Box::new(VecWriter::new(Arc::clone(&buf))),
            FaultPlan::new().fail_append(3),
        );
        let j = Journal::with_writer(Box::new(writer), JournalPolicy::DegradeToMemory);
        let sched = ServeScheduler::sharded_with(
            Arc::clone(&srv),
            1,
            WorkerPool::shared(1),
            cfg(Some(Arc::new(j))),
        )
        .unwrap();
        sched.process_all(&q).unwrap();
        sched.sync_journal().unwrap();
        let stats = sched.journal_stats().unwrap();
        assert!(!stats.failed, "degrade mode never latches a failure");
        assert_eq!(stats.appends, 3, "ident + submits 0,1");
        assert_eq!(
            stats.drops, 11,
            "submit 2 + submits 3..5 + one flush cut + six responses"
        );
    }
}

/// A journaled log rotation survives the crash: recovery applies the
/// max truncation watermark, refuses to resurrect rotated responses,
/// and the recovered log replays only above the watermark.
#[test]
fn the_truncation_watermark_survives_recovery() {
    let srv = server(16, 4, 13);
    let q = queue(8, 16, 1000);
    let path = tmp("watermark.journal");
    let want: Vec<Tensor>;
    {
        let j = Journal::create(&path, JournalPolicy::FailStop).unwrap();
        let sched = ServeScheduler::sharded_with(
            Arc::clone(&srv),
            1,
            WorkerPool::shared(1),
            cfg(Some(Arc::new(j))),
        )
        .unwrap();
        want = sched.process_all(&q).unwrap();
        assert_eq!(sched.truncate_log_below(5).unwrap(), 5);
    }
    let readout = read_journal(&path).unwrap();
    let sched = ServeScheduler::sharded_with(
        Arc::clone(&srv),
        1,
        WorkerPool::shared(1),
        cfg(Some(Arc::new(Journal::open_append(&path, JournalPolicy::FailStop).unwrap()))),
    )
    .unwrap();
    let rep = sched.recover(&readout).unwrap();
    assert!(rep.consistent(), "{rep:?}");
    assert_eq!(rep.watermark, 5);
    assert_eq!(rep.submits, 8);
    assert_eq!(rep.responses_restored, 3, "only tickets 5..8 may come back");
    assert_eq!(rep.re_executed, 0, "rotated tickets are not re-derived either");
    let log = sched.log().unwrap();
    assert_eq!(log.len(), 3);
    assert_eq!(log.watermark(), 5);
    for t in 5..8u64 {
        assert_eq!(
            log.get(t).unwrap().response_hash,
            repdl::coordinator::hash_tensor(&want[t as usize])
        );
    }
    assert!(sched.replay(5..8).unwrap().verified());
    // reaching below the recovered watermark is the typed audit error,
    // exactly as in the uninterrupted process
    assert!(sched.replay(0..8).is_err());
    drop(sched);
    std::fs::remove_file(&path).ok();
}

/// Tickets journaled as failed (their batch hit a tower bug and every
/// client saw the typed error) are skipped by recovery: it must never
/// invent a response the original run never sent.
#[test]
fn failed_tickets_are_skipped_never_resurrected() {
    let q = queue(3, 16, 1100);
    let path = tmp("failed.journal");
    let mk_tower = || {
        let w = uniform_tensor(&[16, 4], -0.3, 0.3, 15);
        Arc::new(PanicAtTicket::new(DeterministicServer::new(w, 8).unwrap(), 1))
            as Arc<dyn ModelTower>
    };
    {
        let j = Journal::create(&path, JournalPolicy::FailStop).unwrap();
        let sched = ServeScheduler::sharded_with(
            mk_tower(),
            1,
            WorkerPool::shared(1),
            ServeConfig { batch_window: 2, log: true, journal: Some(Arc::new(j)), ..Default::default() },
        )
        .unwrap();
        // tickets 0 and 1 share the window-2 batch the injected panic
        // kills; both clients get the typed shield error
        let p0 = sched.submit(q[0].clone()).unwrap();
        let p1 = sched.submit(q[1].clone()).unwrap();
        sched.flush();
        for p in [p0, p1] {
            let e = p.wait().unwrap_err();
            assert!(format!("{e}").contains("panicked"), "{e}");
        }
        let p2 = sched.submit(q[2].clone()).unwrap();
        sched.flush();
        p2.wait().unwrap();
    }
    let readout = read_journal(&path).unwrap();
    let sched = ServeScheduler::sharded_with(
        mk_tower(),
        1,
        WorkerPool::shared(1),
        ServeConfig {
            batch_window: 2,
            log: true,
            journal: Some(Arc::new(Journal::open_append(&path, JournalPolicy::FailStop).unwrap())),
            ..Default::default()
        },
    )
    .unwrap();
    let rep = sched.recover(&readout).unwrap();
    assert!(rep.consistent(), "{rep:?}");
    assert_eq!(rep.failed_skipped, 2, "both panicked tickets stay failed");
    assert_eq!(rep.responses_restored, 1, "the survivor's journaled response is restored");
    assert_eq!(rep.re_executed, 0);
    let log = sched.log().unwrap();
    assert!(log.get(0).is_none() && log.get(1).is_none(), "no invented responses");
    assert!(log.get(2).is_some());
    assert!(sched.replay(2..3).unwrap().verified());
    // the recovered process keeps serving: new tickets are past the
    // panic ticket, so the same tower now answers normally
    let p = sched.submit(q[0].clone()).unwrap();
    sched.flush();
    p.wait().unwrap();
    drop(sched);
    std::fs::remove_file(&path).ok();
}

/// Recovery refuses journals it cannot faithfully continue: wrong
/// weights, wrong shard/window layout, a scheduler that already issued
/// tickets, a disabled response log, or a stream with no ident record.
#[test]
fn recovery_refuses_identity_and_state_mismatches() {
    let q = queue(4, 16, 1200);
    let path = tmp("identity.journal");
    {
        let j = Journal::create(&path, JournalPolicy::FailStop).unwrap();
        let sched = ServeScheduler::sharded_with(
            server(16, 4, 21),
            1,
            WorkerPool::shared(1),
            cfg(Some(Arc::new(j))),
        )
        .unwrap();
        sched.process_all(&q).unwrap();
    }
    let readout = read_journal(&path).unwrap();
    let fresh = |srv: Arc<DeterministicServer>, shards: usize, log: bool| {
        ServeScheduler::sharded_with(
            srv,
            shards,
            WorkerPool::shared(1),
            ServeConfig { batch_window: 4, log, ..Default::default() },
        )
        .unwrap()
    };
    // different weights (same model id, different hash)
    let e = fresh(server(16, 4, 22), 1, true).recover(&readout).unwrap_err();
    assert!(matches!(e, Error::Journal(_)), "{e:?}");
    assert!(format!("{e}").contains("journal is for model"), "{e}");
    // different shard layout: batch composition would differ
    let e = fresh(server(16, 4, 21), 2, true).recover(&readout).unwrap_err();
    assert!(format!("{e}").contains("batch composition would differ"), "{e}");
    // a scheduler that already issued a ticket
    let used = fresh(server(16, 4, 21), 1, true);
    let p = used.submit(q[0].clone()).unwrap();
    used.flush();
    p.wait().unwrap();
    let e = used.recover(&readout).unwrap_err();
    assert!(format!("{e}").contains("freshly built"), "{e}");
    // recovery rebuilds the log, so it must be enabled
    let e = fresh(server(16, 4, 21), 1, false).recover(&readout).unwrap_err();
    assert!(format!("{e}").contains("response log is disabled"), "{e}");
    // a header-only stream has no ident record to verify against
    let hdr_path = tmp("header-only.journal");
    std::fs::write(&hdr_path, journal_header()).unwrap();
    let empty = read_journal(&hdr_path).unwrap();
    assert!(empty.events.is_empty());
    let e = fresh(server(16, 4, 21), 1, true).recover(&empty).unwrap_err();
    assert!(format!("{e}").contains("no ident record"), "{e}");
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&hdr_path).ok();
}
