//! Pool-size invariance conformance suite.
//!
//! The worker pool (tensor/pool.rs) claims that pool size is a pure
//! performance knob: every fast path must be bit-identical to the
//! single-lane (sequential) run for *every* pool size, including
//! adversarial shapes where chunks outnumber lanes, lanes outnumber
//! chunks, or a single chunk covers the whole output. This suite pins
//! that claim for GEMM (all variants), conv2d (direct, im2col, routed),
//! axis reductions and the serving path.

use repdl::coordinator::DeterministicServer;
use repdl::tensor::par::par_chunks_in;
use repdl::tensor::{
    conv2d_direct_in, conv2d_im2col_in, conv2d_in, matmul_blocked_in, matmul_dotform_in,
    matmul_fma_dotform_in, matmul_fma_in, matmul_in, matmul_packed_in, matmul_pairwise_in,
    max_axis_in, sum_axis_in, sum_axis_pairwise_in, var_axis_in, Conv2dParams, Tensor, WorkerPool,
};

const POOL_SIZES: [usize; 6] = [1, 2, 3, 5, 8, 16];

fn lcg(dims: &[usize], seed: u64) -> Tensor {
    let n: usize = dims.iter().product();
    let mut s = seed;
    Tensor::from_vec(
        dims,
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(12345);
                (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 2.0
            })
            .collect(),
    )
    .unwrap()
}

#[test]
fn gemm_bit_identical_for_every_pool_size() {
    // tall/skinny, k=1, n=1, single-element, and tiles straddling the
    // blocked kernel's ROW_BLOCK/COL_BLOCK boundaries
    let shapes: [(usize, usize, usize); 6] =
        [(1, 1, 1), (257, 3, 2), (5, 1, 7), (64, 32, 1), (3, 77, 300), (9, 64, 257)];
    for (m, k, n) in shapes {
        let a = lcg(&[m, k], (m * 31 + k) as u64);
        let b = lcg(&[k, n], (n * 17 + k) as u64);
        let base = WorkerPool::new(1);
        let r_seq = matmul_in(&base, &a, &b).unwrap();
        let r_fma = matmul_fma_in(&base, &a, &b).unwrap();
        let r_pw = matmul_pairwise_in(&base, &a, &b).unwrap();
        let r_dot = matmul_dotform_in(&base, &a, &b).unwrap();
        let r_fma_dot = matmul_fma_dotform_in(&base, &a, &b).unwrap();
        // routed, blocked and packed kernels == dot form even sequentially
        assert!(r_seq.bit_eq(&r_dot), "routed != dotform at ({m},{k},{n})");
        assert!(
            matmul_blocked_in(&base, &a, &b).unwrap().bit_eq(&r_dot),
            "blocked != dotform at ({m},{k},{n})"
        );
        assert!(
            matmul_packed_in(&base, &a, &b).unwrap().bit_eq(&r_dot),
            "packed != dotform at ({m},{k},{n})"
        );
        assert!(r_fma.bit_eq(&r_fma_dot), "routed fma != fma dotform at ({m},{k},{n})");
        for lanes in POOL_SIZES {
            let pool = WorkerPool::new(lanes);
            assert!(
                r_seq.bit_eq(&matmul_in(&pool, &a, &b).unwrap()),
                "matmul ({m},{k},{n}) lanes={lanes}"
            );
            assert!(
                r_seq.bit_eq(&matmul_packed_in(&pool, &a, &b).unwrap()),
                "matmul_packed ({m},{k},{n}) lanes={lanes}"
            );
            assert!(
                r_seq.bit_eq(&matmul_blocked_in(&pool, &a, &b).unwrap()),
                "matmul_blocked ({m},{k},{n}) lanes={lanes}"
            );
            assert!(
                r_fma.bit_eq(&matmul_fma_in(&pool, &a, &b).unwrap()),
                "matmul_fma ({m},{k},{n}) lanes={lanes}"
            );
            assert!(
                r_fma_dot.bit_eq(&matmul_fma_dotform_in(&pool, &a, &b).unwrap()),
                "matmul_fma_dotform ({m},{k},{n}) lanes={lanes}"
            );
            assert!(
                r_pw.bit_eq(&matmul_pairwise_in(&pool, &a, &b).unwrap()),
                "matmul_pairwise ({m},{k},{n}) lanes={lanes}"
            );
            assert!(
                r_dot.bit_eq(&matmul_dotform_in(&pool, &a, &b).unwrap()),
                "matmul_dotform ({m},{k},{n}) lanes={lanes}"
            );
        }
    }
}

#[test]
fn conv2d_bit_identical_for_every_pool_size() {
    let x = lcg(&[2, 3, 9, 9], 51);
    let w = lcg(&[4, 3, 3, 3], 52);
    let bias = lcg(&[4], 53);
    for p in [
        Conv2dParams { stride: 1, padding: 0 },
        Conv2dParams { stride: 2, padding: 1 },
    ] {
        let base = WorkerPool::new(1);
        let r_direct = conv2d_direct_in(&base, &x, &w, Some(&bias), p).unwrap();
        let r_im2col = conv2d_im2col_in(&base, &x, &w, Some(&bias), p).unwrap();
        assert!(r_direct.bit_eq(&r_im2col), "direct != im2col sequentially");
        for lanes in POOL_SIZES {
            let pool = WorkerPool::new(lanes);
            assert!(
                r_direct.bit_eq(&conv2d_direct_in(&pool, &x, &w, Some(&bias), p).unwrap()),
                "conv2d_direct stride={} pad={} lanes={lanes}",
                p.stride,
                p.padding
            );
            assert!(
                r_im2col.bit_eq(&conv2d_im2col_in(&pool, &x, &w, Some(&bias), p).unwrap()),
                "conv2d_im2col stride={} pad={} lanes={lanes}",
                p.stride,
                p.padding
            );
            assert!(
                r_direct.bit_eq(&conv2d_in(&pool, &x, &w, Some(&bias), p).unwrap()),
                "conv2d routed stride={} pad={} lanes={lanes}",
                p.stride,
                p.padding
            );
        }
    }
}

#[test]
fn reductions_bit_identical_for_every_pool_size() {
    // 2-D both axes, 1-D (single output element), and a wide row where
    // the pool batches many tiny reductions per chunk
    let t2 = lcg(&[7, 129], 61);
    let t1 = lcg(&[1000], 62);
    let wide = lcg(&[513, 2], 63);
    let base = WorkerPool::new(1);
    for (t, axes) in [(&t2, vec![0usize, 1]), (&t1, vec![0]), (&wide, vec![0, 1])] {
        for &axis in &axes {
            let r_seq = sum_axis_in(&base, t, axis).unwrap();
            let r_pw = sum_axis_pairwise_in(&base, t, axis).unwrap();
            let r_var = var_axis_in(&base, t, axis).unwrap();
            let r_max = max_axis_in(&base, t, axis).unwrap();
            for lanes in POOL_SIZES {
                let pool = WorkerPool::new(lanes);
                assert!(
                    r_seq.bit_eq(&sum_axis_in(&pool, t, axis).unwrap()),
                    "sum_axis dims={:?} axis={axis} lanes={lanes}",
                    t.dims()
                );
                assert!(
                    r_pw.bit_eq(&sum_axis_pairwise_in(&pool, t, axis).unwrap()),
                    "sum_axis_pairwise dims={:?} axis={axis} lanes={lanes}",
                    t.dims()
                );
                assert!(
                    r_var.bit_eq(&var_axis_in(&pool, t, axis).unwrap()),
                    "var_axis dims={:?} axis={axis} lanes={lanes}",
                    t.dims()
                );
                assert!(
                    r_max.bit_eq(&max_axis_in(&pool, t, axis).unwrap()),
                    "max_axis dims={:?} axis={axis} lanes={lanes}",
                    t.dims()
                );
            }
        }
    }
}

#[test]
fn par_chunks_adversarial_geometry() {
    // chunk > len, chunk == len, len == 1: every pool size must produce
    // the full, identical output
    for (len, chunk) in [(5usize, 64usize), (64, 64), (1, 3), (97, 13)] {
        let mut base = vec![0.0f32; len];
        par_chunks_in(&WorkerPool::new(1), &mut base, chunk, fill);
        for lanes in POOL_SIZES {
            let mut out = vec![0.0f32; len];
            par_chunks_in(&WorkerPool::new(lanes), &mut out, chunk, fill);
            assert!(
                base.iter().zip(out.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "len={len} chunk={chunk} lanes={lanes}"
            );
        }
    }

    fn fill(start: usize, c: &mut [f32]) {
        for (i, v) in c.iter_mut().enumerate() {
            let idx = start + i;
            let mut acc = 0.0f32;
            for k in 0..32 {
                acc += ((idx * 13 + k * 3) % 71) as f32 * 1e-2;
            }
            *v = acc;
        }
    }
}

#[test]
fn serving_bit_identical_for_every_pool_size() {
    let w = lcg(&[96, 8], 71);
    let srv = DeterministicServer::new(w, 16).unwrap();
    let queue: Vec<Tensor> = (0..33).map(|i| lcg(&[96], 100 + i as u64)).collect();
    let base: Vec<Tensor> = srv.process_repro_in(&WorkerPool::new(1), &queue).unwrap();
    for lanes in POOL_SIZES {
        let got = srv.process_repro_in(&WorkerPool::new(lanes), &queue).unwrap();
        for (r, (a, b)) in base.iter().zip(got.iter()).enumerate() {
            assert!(a.bit_eq(b), "request {r} lanes={lanes}");
        }
    }
}
