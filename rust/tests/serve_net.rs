//! Conformance suite for the serve TCP front end (DESIGN.md §14 —
//! coordinator/serve/{wire,net}.rs).
//!
//! The tentpole claim: putting a socket in front of the registry
//! changes *transport*, never *bits*. The loopback grid pins every
//! response served over TCP against direct in-process
//! `ModelRegistry` submission across clients × shards × models ×
//! journal on/off; around it, the adversarial cases — malformed
//! frames, a peer vanishing mid-request, protocol-order violations —
//! must come back as typed error frames and closed connections, never
//! a panic, a hang, or a poisoned scheduler. The flush tests pin the
//! logical clock: batch cuts come from admitted-ticket counts
//! (`flush_every`) and explicit flush frames only, and the recovery
//! test replays a journal written by a TCP-fed server in a fresh
//! registry, bit-exactly.

use repdl::coordinator::{
    hash_tensor, Journal, JournalPolicy, MlpTower, ModelRegistry, ModelTower, NetClient,
    NetServer, ServeConfig, ServeScheduler, TransformerTower, WireFrame, WIRE_VERSION,
};
use repdl::nn::{Act, CharTransformer, Mlp, TransformerConfig};
use repdl::rng::uniform_tensor;
use repdl::tensor::{Tensor, WorkerPool};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("repdl-serve-net");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// The two grid towers, rebuilt from seeds — two calls with the same
/// arguments produce bit-identical weights, which is what lets the
/// "in-process reference" and the "behind a socket" registries stand
/// in for the same deployment.
fn tower(model: &str) -> Arc<dyn ModelTower> {
    match model {
        "mlp" => Arc::new(MlpTower::new(Mlp::new(&[12, 10, 4], Act::Gelu, 7)).unwrap()),
        "transformer" => {
            let cfg = TransformerConfig {
                vocab: 10,
                dim: 8,
                heads: 2,
                layers: 1,
                context: 4,
                mlp_ratio: 2,
            };
            Arc::new(TransformerTower::new(CharTransformer::new(cfg, 17).unwrap()).unwrap())
        }
        other => panic!("unknown grid model {other}"),
    }
}

fn queue(model: &str, n: usize) -> Vec<Tensor> {
    match model {
        "mlp" => (0..n).map(|i| uniform_tensor(&[12], -1.0, 1.0, 100 + i as u64)).collect(),
        "transformer" => (0..n)
            .map(|i| {
                let ids: Vec<f32> = (0..4).map(|j| ((i * 3 + j * 2 + 1) % 10) as f32).collect();
                Tensor::from_vec(&[4], ids).unwrap()
            })
            .collect(),
        other => panic!("unknown grid model {other}"),
    }
}

fn registry(model: &str, shards: usize, cfg: ServeConfig) -> ModelRegistry {
    let sched =
        ServeScheduler::sharded_with(tower(model), shards, WorkerPool::shared(1), cfg).unwrap();
    let mut reg = ModelRegistry::new();
    reg.register(sched).unwrap();
    reg
}

/// The reference bits: the same requests through a same-seed registry,
/// submitted directly in process.
fn reference(model: &str, q: &[Tensor]) -> Vec<Tensor> {
    let reg = registry(model, 1, ServeConfig::default());
    let pending: Vec<_> =
        q.iter().map(|r| reg.submit_with_backpressure(model, r).unwrap()).collect();
    reg.flush_all();
    pending.into_iter().map(|p| p.wait().unwrap()).collect()
}

/// THE loopback grid: clients {1,4} × shards {1,2} × models
/// {mlp, transformer} × journal on/off. Every cell binds a real TCP
/// server on a loopback port, drives it with pipelined concurrent
/// clients, and demands each response's bits equal direct in-process
/// submission of the same request — per-request bits are batch- and
/// transport-invariant, so the one thing the network may perturb
/// (cross-connection arrival order) cannot show up in any payload.
#[test]
fn loopback_grid_matches_in_process_registry_bits() {
    let n = 16usize;
    for model in ["mlp", "transformer"] {
        let q = queue(model, n);
        let want = reference(model, &q);
        for shards in [1usize, 2] {
            for clients in [1usize, 4] {
                for journaled in [false, true] {
                    let cell = format!(
                        "model={model} shards={shards} clients={clients} journal={journaled}"
                    );
                    let journal = if journaled {
                        let path = tmp(&format!(
                            "grid-{model}-s{shards}-c{clients}.journal"
                        ));
                        Some(Arc::new(
                            Journal::create(&path, JournalPolicy::FailStop).unwrap(),
                        ))
                    } else {
                        None
                    };
                    let cfg = ServeConfig { batch_window: 4, journal, ..Default::default() };
                    let reg = Arc::new(registry(model, shards, cfg));
                    let mut server = NetServer::bind(Arc::clone(&reg), "127.0.0.1:0").unwrap();
                    let addr = server.local_addr().to_string();
                    let got: Vec<(usize, Tensor)> = std::thread::scope(|s| {
                        let handles: Vec<_> = (0..clients)
                            .map(|c| {
                                let (addr, q) = (&addr, &q);
                                s.spawn(move || {
                                    let mut cl = NetClient::connect(addr).unwrap();
                                    let idx: Vec<usize> =
                                        (c..q.len()).step_by(clients).collect();
                                    let mut sent = Vec::new();
                                    for &i in &idx {
                                        sent.push(cl.send_request(model, &q[i]).unwrap());
                                    }
                                    cl.send_flush(model).unwrap();
                                    let mut out = Vec::new();
                                    for (&i, &req_id) in idx.iter().zip(sent.iter()) {
                                        let (got_id, _ticket, resp) =
                                            cl.recv_response().unwrap();
                                        assert_eq!(
                                            got_id, req_id,
                                            "per-connection FIFO broken at request {i}"
                                        );
                                        out.push((i, resp));
                                    }
                                    cl.recv_flushed().unwrap();
                                    cl.bye().unwrap();
                                    out
                                })
                            })
                            .collect();
                        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
                    });
                    assert_eq!(got.len(), n, "{cell}");
                    for (i, resp) in &got {
                        assert!(
                            resp.bit_eq(&want[*i]),
                            "{cell}: request {i} bits changed over the wire"
                        );
                    }
                    server.shutdown();
                }
            }
        }
    }
}

/// Malformed and hostile bytes: a garbage frame answers with a typed
/// `protocol` error frame and a closed connection; per-request defects
/// (bad shape, unknown model) answer with typed error frames and keep
/// the connection serving — and the server survives all of it.
#[test]
fn malformed_frames_get_typed_error_frames_never_a_hang() {
    use repdl::coordinator::serve::wire::{read_frame, write_frame};
    let reg = Arc::new(registry("mlp", 1, ServeConfig::default()));
    let mut server = NetServer::bind(Arc::clone(&reg), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    // raw socket: valid hello, then a hostile length prefix
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        write_frame(&mut s, &WireFrame::HelloClient { version: WIRE_VERSION }).unwrap();
        match read_frame(&mut s).unwrap() {
            Some(WireFrame::HelloServer { version, models }) => {
                assert_eq!(version, WIRE_VERSION);
                assert_eq!(models.len(), 1);
                assert_eq!(models[0].model_id, "mlp");
                assert_eq!((models[0].d_in, models[0].d_out), (12, 4));
            }
            f => panic!("expected server hello, got {f:?}"),
        }
        use std::io::Write;
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        s.write_all(&[0xAB; 64]).unwrap();
        match read_frame(&mut s).unwrap() {
            Some(WireFrame::Error { code, .. }) => assert_eq!(code, "protocol"),
            f => panic!("expected a protocol error frame, got {f:?}"),
        }
        // the server closes after a protocol violation
        assert!(matches!(read_frame(&mut s), Ok(None) | Err(_)));
    }

    // a first frame that is not a hello is refused the same way
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        write_frame(&mut s, &WireFrame::Flushed { req_id: 1 }).unwrap();
        match read_frame(&mut s).unwrap() {
            Some(WireFrame::Error { code, .. }) => assert_eq!(code, "protocol"),
            f => panic!("expected a protocol error frame, got {f:?}"),
        }
    }

    // per-request defects are typed and non-fatal to the connection
    {
        let mut cl = NetClient::connect(&addr).unwrap();
        let bad_shape = uniform_tensor(&[5], -1.0, 1.0, 1);
        cl.send_request("mlp", &bad_shape).unwrap();
        let e = cl.recv_response().unwrap_err();
        assert!(e.to_string().contains("[bad-request]"), "{e}");
        cl.send_request("nope", &uniform_tensor(&[12], -1.0, 1.0, 2)).unwrap();
        let e = cl.recv_response().unwrap_err();
        assert!(e.to_string().contains("[unknown-model]"), "{e}");
        // …and the connection still serves real requests afterwards
        let good = uniform_tensor(&[12], -1.0, 1.0, 100);
        let (_ticket, resp) = cl.request_flushed("mlp", &good).unwrap();
        assert!(resp.bit_eq(&reference("mlp", std::slice::from_ref(&good))[0]));
        cl.bye().unwrap();
    }
    server.shutdown();
}

/// A peer that vanishes mid-request must not wedge the server: its
/// admitted ticket executes (released by the next cut), nobody reads
/// the bits, and fresh connections keep getting reference-exact
/// responses.
#[test]
fn mid_request_disconnect_leaves_server_healthy() {
    let cfg = ServeConfig { batch_window: 8, ..Default::default() };
    let reg = Arc::new(registry("mlp", 2, cfg));
    let mut server = NetServer::bind(Arc::clone(&reg), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let q = queue("mlp", 3);
    let want = reference("mlp", &q);
    // connection A: submit without flushing, then vanish (drop without
    // a goodbye — the OS resets the socket under the server's writer)
    {
        let mut cl = NetClient::connect(&addr).unwrap();
        cl.send_request("mlp", &q[0]).unwrap();
        drop(cl);
    }
    // connection B: full request/response cycles, bit-exact. B's flush
    // cut also covers A's orphaned ticket, so its batch executes and
    // the server's writer discards the unreadable response.
    let mut cl = NetClient::connect(&addr).unwrap();
    for i in 1..3 {
        let (_ticket, resp) = cl.request_flushed("mlp", &q[i]).unwrap();
        assert!(resp.bit_eq(&want[i]), "request {i} after a peer vanished");
    }
    // A's reader thread races this one: wait (bounded) until its
    // orphaned submit has been admitted, then cut it loose
    let mut next_ticket = 0;
    for _ in 0..1000 {
        next_ticket = cl.stats("mlp").unwrap().0;
        if next_ticket == 3 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(next_ticket, 3, "A's orphaned submit still consumed its ticket");
    cl.send_flush("mlp").unwrap();
    cl.recv_flushed().unwrap();
    let (_, in_flight, rejected, _) = cl.stats("mlp").unwrap();
    assert_eq!(in_flight, 0, "the flush cut covered the orphan");
    assert_eq!(rejected, 0);
    cl.bye().unwrap();
    server.shutdown();
}

/// The logical clock, both sources: with `flush_every: K` configured,
/// cuts appear every K admitted tickets with no flush call anywhere —
/// and an explicit flush frame cuts the remainder. Batch composition
/// stays a pure function of the event sequence (the trace proves it),
/// and replies keep FIFO order throughout.
#[test]
fn logical_flush_every_k_and_explicit_flush_frames() {
    let cfg = ServeConfig {
        batch_window: 100, // never fills: every cut below is a flush cut
        flush_every: Some(3),
        ..Default::default()
    };
    let reg = Arc::new(registry("mlp", 1, cfg));
    let mut server = NetServer::bind(Arc::clone(&reg), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let q = queue("mlp", 7);
    let want = reference("mlp", &q);
    let mut cl = NetClient::connect(&addr).unwrap();
    for r in &q {
        cl.send_request("mlp", r).unwrap();
    }
    // six responses arrive with NO explicit flush anywhere: tickets
    // {0..2} and {3..5} were cut by the every-3 logical clock
    for i in 0..6 {
        let (_req, ticket, resp) = cl.recv_response().unwrap();
        assert_eq!(ticket, i as u64, "FIFO + ticket order");
        assert!(resp.bit_eq(&want[i]), "request {i}");
    }
    // the seventh needs the explicit flush frame
    cl.send_flush("mlp").unwrap();
    let (_req, ticket, resp) = cl.recv_response().unwrap();
    assert_eq!(ticket, 6);
    assert!(resp.bit_eq(&want[6]));
    cl.recv_flushed().unwrap();
    cl.bye().unwrap();
    // the trace pins the batch composition to the event sequence
    let trace: Vec<Vec<u64>> =
        reg.get("mlp").unwrap().trace().into_iter().map(|b| b.tickets).collect();
    assert_eq!(trace, vec![vec![0, 1, 2], vec![3, 4, 5], vec![6]]);
    server.shutdown();
}

/// Cross-process recovery: a journal written by a TCP-fed server
/// rebuilds, in a fresh registry (fresh "process"), the exact response
/// bits the remote clients saw — `recover_all` + `replay` close the
/// loop from socket to disk to a new process.
#[test]
fn journal_from_a_tcp_fed_server_recovers_bit_exactly_in_a_fresh_registry() {
    let dir = tmp("xproc");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mlp.journal");
    let q = queue("mlp", 9);
    // "process A": journaled server fed over TCP; record what the
    // remote client actually received, by ticket
    let served: Vec<(u64, String)> = {
        let journal = Arc::new(Journal::create(&path, JournalPolicy::FailStop).unwrap());
        let cfg = ServeConfig { batch_window: 4, journal: Some(journal), ..Default::default() };
        let reg = Arc::new(registry("mlp", 2, cfg));
        let mut server = NetServer::bind(Arc::clone(&reg), "127.0.0.1:0").unwrap();
        let mut cl = NetClient::connect(&server.local_addr().to_string()).unwrap();
        let mut got = Vec::new();
        for r in &q {
            cl.send_request("mlp", r).unwrap();
        }
        cl.send_flush("mlp").unwrap();
        for _ in 0..q.len() {
            let (_req, ticket, resp) = cl.recv_response().unwrap();
            got.push((ticket, hash_tensor(&resp)));
        }
        cl.recv_flushed().unwrap();
        cl.bye().unwrap();
        server.shutdown();
        reg.get("mlp").unwrap().sync_journal().unwrap();
        got
    };
    // "process B": same-seed model, state rebuilt purely from the file
    let reg = registry("mlp", 2, ServeConfig { log: true, ..Default::default() });
    let reports = reg.recover_all(&dir).unwrap();
    let rep = &reports["mlp"];
    assert!(rep.consistent(), "{rep:?}");
    assert_eq!(rep.next_ticket, q.len() as u64);
    let log = reg.get("mlp").unwrap().log().unwrap();
    for (ticket, want_hash) in &served {
        assert_eq!(
            &log.get(*ticket).unwrap().response_hash,
            want_hash,
            "ticket {ticket}: recovered bits must equal what the remote client received"
        );
    }
    // and the rebuilt log re-verifies by re-execution
    assert!(reg.replay("mlp", 0..q.len() as u64).unwrap().verified());
    std::fs::remove_file(&path).unwrap();
}
