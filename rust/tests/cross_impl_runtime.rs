//! E6 — cross-**implementation** reproducibility.
//!
//! Two entirely independent software stacks implement the RepDL op spec:
//! the native Rust kernels and the JAX/Pallas kernels AOT-compiled to HLO
//! and executed via PJRT. If both follow the spec, their bits must agree.
//! That is the strongest form of the paper's cross-platform claim we can
//! test on one machine — the "platforms" here are two real, unrelated
//! compiler+runtime stacks, not simulations.
//!
//! Pinned spec notes:
//! * GEMM: XLA CPU contracts mul+add → FMA (the paper §3.2.4 *enables*
//!   contraction), so the artifact implements the sequential-k **FMA**
//!   variant — partner op `tensor::matmul_fma`.
//! * Sums: pure additions (nothing to contract) — partner ops are the
//!   plain `sum_sequential` / `sum_pairwise`.
//!
//! Tests self-skip when `make artifacts` has not been run.

use repdl::rng::uniform_tensor;
use repdl::rnum::fbits::ulp_diff;
use repdl::runtime::Runtime;
use repdl::tensor::{matmul_fma, Tensor};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn matmul_artifact_matches_rust_fma_bitwise() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = uniform_tensor(&[64, 128], -1.0, 1.0, 101);
    let b = uniform_tensor(&[128, 32], -1.0, 1.0, 102);
    let xla = rt.run("matmul_repro", &[a.clone(), b.clone()]).unwrap();
    let native = matmul_fma(&a, &b).unwrap();
    assert!(
        xla[0].bit_eq(&native),
        "XLA artifact and native matmul_fma disagree bitwise"
    );
}

#[test]
fn matmul_small_artifact_matches_rust_fma_bitwise() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = uniform_tensor(&[4, 6], -2.0, 2.0, 103);
    let b = uniform_tensor(&[6, 5], -2.0, 2.0, 104);
    let xla = rt.run("matmul_repro_small", &[a.clone(), b.clone()]).unwrap();
    let native = matmul_fma(&a, &b).unwrap();
    assert!(xla[0].bit_eq(&native));
}

#[test]
fn sum_artifacts_match_rust_bitwise() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let x = uniform_tensor(&[4096], -100.0, 100.0, 105);
    let seq = rt.run("sum_seq", &[x.clone()]).unwrap();
    let want_seq = repdl::rnum::sum_sequential(x.data());
    assert_eq!(
        seq[0].data()[0].to_bits(),
        want_seq.to_bits(),
        "sequential sum disagrees"
    );
    let pw = rt.run("sum_pairwise", &[x.clone()]).unwrap();
    let want_pw = repdl::rnum::sum_pairwise(x.data());
    assert_eq!(
        pw[0].data()[0].to_bits(),
        want_pw.to_bits(),
        "pairwise sum disagrees"
    );
}

#[test]
fn exp_fixed_artifact_vs_rust_f64_graph() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let x = uniform_tensor(&[1024], -60.0, 60.0, 106);
    let xla = rt.run("exp_fixed", &[x.clone()]).unwrap();
    let mut exact = 0usize;
    let mut max_ulp = 0u32;
    for (i, &v) in x.data().iter().enumerate() {
        let native = repdl::rnum::exp::exp_fixed_graph_f64(v as f64) as f32;
        let got = xla[0].data()[i];
        let d = ulp_diff(got, native);
        max_ulp = max_ulp.max(d);
        if d == 0 {
            exact += 1;
        }
    }
    eprintln!(
        "exp_fixed cross-impl: {}/{} bit-identical, max {} ulp",
        exact,
        x.numel(),
        max_ulp
    );
    // The f64 graph is pinned; XLA may FMA-contract the polynomial, which
    // perturbs ≤1 ulp of f64 — invisible after rounding to f32 except in
    // borderline cases. Require near-total agreement and ≤1 ulp always.
    assert!(max_ulp <= 1, "exp artifact drifted: {max_ulp} ulp");
    assert!(exact * 100 >= x.numel() * 99, "only {exact}/1024 bit-equal");
}

#[test]
fn softmax_artifact_vs_rust_ulp_report() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let x = uniform_tensor(&[32, 64], -8.0, 8.0, 107);
    let xla = rt.run("softmax_repro", &[x.clone()]).unwrap();
    let native = repdl::nn::softmax_rows(&x).unwrap();
    // different exp implementations (XLA libm vs CR rexp): not bitwise,
    // but must be uniformly close — report the gap.
    let mut max_ulp = 0u32;
    for (a, b) in xla[0].data().iter().zip(native.data()) {
        max_ulp = max_ulp.max(ulp_diff(*a, *b));
    }
    eprintln!("softmax cross-impl max ulp = {max_ulp}");
    assert!(max_ulp <= 16, "softmax drifted by {max_ulp} ulp");
}

#[test]
fn mlp_forward_artifact_matches_rust_fma_graph() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let x = uniform_tensor(&[16, 64], -1.0, 1.0, 108);
    let w1 = uniform_tensor(&[64, 32], -0.3, 0.3, 109);
    let b1 = uniform_tensor(&[32], -0.1, 0.1, 110);
    let w2 = uniform_tensor(&[32, 10], -0.3, 0.3, 111);
    let b2 = uniform_tensor(&[10], -0.1, 0.1, 112);
    let xla = rt
        .run("mlp_fwd", &[x.clone(), w1.clone(), b1.clone(), w2.clone(), b2.clone()])
        .unwrap();
    // native replica of the same fixed graph (FMA GEMM, exact add/relu)
    let h = matmul_fma(&x, &w1).unwrap().add_t(&b1).unwrap();
    let h = h.map(|v| if v > 0.0 { v } else { 0.0 });
    let logits = matmul_fma(&h, &w2).unwrap().add_t(&b2).unwrap();
    assert!(
        xla[0].bit_eq(&logits),
        "full MLP forward disagrees across implementations"
    );
}

#[test]
fn train_step_artifact_is_deterministic_and_learns() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let x = uniform_tensor(&[16, 64], 0.0, 1.0, 113);
    let mut y = Tensor::zeros(&[16, 10]);
    for i in 0..16 {
        y.data_mut()[i * 10 + (i % 10)] = 1.0;
    }
    let mut w1 = uniform_tensor(&[64, 32], -0.2, 0.2, 114);
    let mut b1 = Tensor::zeros(&[32]);
    let mut w2 = uniform_tensor(&[32, 10], -0.2, 0.2, 115);
    let mut b2 = Tensor::zeros(&[10]);
    let lr = Tensor::scalar(0.5);
    // determinism: one step twice from identical state
    let o1 = rt
        .run("mlp_train_step", &[x.clone(), y.clone(), w1.clone(), b1.clone(), w2.clone(), b2.clone(), lr.clone()])
        .unwrap();
    let o2 = rt
        .run("mlp_train_step", &[x.clone(), y.clone(), w1.clone(), b1.clone(), w2.clone(), b2.clone(), lr.clone()])
        .unwrap();
    for (a, b) in o1.iter().zip(o2.iter()) {
        assert!(a.bit_eq(b), "train step nondeterministic");
    }
    // learning: 25 steps reduce the loss
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 0..25 {
        let out = rt
            .run("mlp_train_step", &[x.clone(), y.clone(), w1, b1, w2, b2, lr.clone()])
            .unwrap();
        let loss = out[0].data()[0];
        if step == 0 {
            first = loss;
        }
        last = loss;
        let mut it = out.into_iter();
        it.next(); // drop loss
        w1 = it.next().unwrap();
        b1 = it.next().unwrap();
        w2 = it.next().unwrap();
        b2 = it.next().unwrap();
    }
    eprintln!("train_step artifact loss: {first} -> {last}");
    assert!(last < first, "AOT training did not learn");
}
