//! Tensor-parallel invariance conformance suite (DESIGN.md §13).
//!
//! The tentpole claim: the tensor-parallel width is a pure layout knob.
//! For each model the served bits are pinned identical across
//! TP {1, 2, 4} × pool lanes {1, 2, 8} × scheduler shards {1, 2} ×
//! KV-sessions on/off, with `replay()` re-verifying the response log in
//! every cell. Because `model_id` and `weights_hash` are TP-invariant
//! too, a journal recorded at TP=1 recovers bit-exactly on a TP=4
//! process (and vice versa). Around the grid: indivisible shard shapes
//! are typed errors at every layer — shard plan, linear, attention,
//! mlp, transformer, tower, CLI — never panics.

use repdl::coordinator::{
    hash_tensor, read_journal, Journal, JournalPolicy, ModelTower, ServeConfig, ServeScheduler,
    ShardedTower,
};
use repdl::nn::{
    Act, CharTransformer, Linear, Mlp, MultiheadAttention, ShardPlan, TransformerConfig,
};
use repdl::tensor::{Tensor, WorkerPool};
use std::sync::Arc;

fn mlp_model() -> Mlp {
    Mlp::new(&[12, 16, 10], Act::Gelu, 7)
}

fn tf_model() -> CharTransformer {
    // heads = 4 so every width in {1, 2, 4} divides the head count
    let cfg = TransformerConfig { vocab: 12, dim: 8, heads: 4, layers: 2, context: 6, mlp_ratio: 2 };
    CharTransformer::new(cfg, 7).unwrap()
}

fn mlp_queue(n: usize) -> Vec<Tensor> {
    (0..n).map(|i| repdl::rng::uniform_tensor(&[12], -1.0, 1.0, 300 + i as u64)).collect()
}

/// Two growing-prefix decode streams: with sessions on, the store sees
/// fresh streams, extension hits and rebuilds — the cost paths whose
/// bits must all agree with a sessionless full recompute.
fn prefix_queue() -> Vec<Tensor> {
    let mut q = Vec::new();
    for k in 0..2usize {
        for tt in 1..=5usize {
            let ids: Vec<f32> = (0..tt).map(|t| ((k * 31 + t * 7 + 3) % 12) as f32).collect();
            q.push(Tensor::from_vec(&[tt], ids).unwrap());
        }
    }
    q
}

fn grid_cfg() -> ServeConfig {
    ServeConfig { batch_window: 4, log: true, ..Default::default() }
}

#[test]
fn mlp_bits_are_pinned_across_the_tp_grid() {
    let queue = mlp_queue(10);
    let mut want: Option<Vec<String>> = None;
    for tp in [1usize, 2, 4] {
        for lanes in [1usize, 2, 8] {
            for shards in [1usize, 2] {
                let tower = ShardedTower::mlp(mlp_model(), tp).unwrap();
                let sched = ServeScheduler::sharded_with(
                    Arc::new(tower),
                    shards,
                    WorkerPool::shared(lanes),
                    grid_cfg(),
                )
                .unwrap();
                let hashes: Vec<String> =
                    sched.process_all(&queue).unwrap().iter().map(hash_tensor).collect();
                match &want {
                    None => want = Some(hashes),
                    Some(w) => {
                        assert_eq!(w, &hashes, "tp={tp} lanes={lanes} shards={shards}")
                    }
                }
                assert!(
                    sched.replay(0..queue.len() as u64).unwrap().verified(),
                    "tp={tp} lanes={lanes} shards={shards}: replay failed"
                );
            }
        }
    }
}

#[test]
fn transformer_bits_are_pinned_across_the_tp_session_grid() {
    let queue = prefix_queue();
    let mut want: Option<Vec<String>> = None;
    for tp in [1usize, 2, 4] {
        for lanes in [1usize, 2, 8] {
            for shards in [1usize, 2] {
                for sessions in [0usize, 8] {
                    let tower =
                        ShardedTower::transformer(tf_model(), tp).unwrap().with_sessions(sessions);
                    let sched = ServeScheduler::sharded_with(
                        Arc::new(tower),
                        shards,
                        WorkerPool::shared(lanes),
                        grid_cfg(),
                    )
                    .unwrap();
                    let hashes: Vec<String> =
                        sched.process_all(&queue).unwrap().iter().map(hash_tensor).collect();
                    match &want {
                        None => want = Some(hashes),
                        Some(w) => assert_eq!(
                            w, &hashes,
                            "tp={tp} lanes={lanes} shards={shards} sessions={sessions}"
                        ),
                    }
                    assert!(
                        sched.replay(0..queue.len() as u64).unwrap().verified(),
                        "tp={tp} lanes={lanes} shards={shards} sessions={sessions}: replay failed"
                    );
                }
            }
        }
    }
}

#[test]
fn journal_recorded_at_tp1_recovers_bit_exactly_at_tp4() {
    let dir = std::env::temp_dir().join("repdl-tp-invariance");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cross-tp.journal");
    let _ = std::fs::remove_file(&path);
    let queue = prefix_queue();
    // record: TP=1, sessions ON, journaled — then drop (the drop syncs)
    let uninterrupted: Vec<String> = {
        let j = Journal::create(&path, JournalPolicy::FailStop).unwrap();
        let cfg = ServeConfig {
            batch_window: 4,
            log: true,
            journal: Some(Arc::new(j)),
            ..Default::default()
        };
        let tower = ShardedTower::transformer(tf_model(), 1).unwrap().with_sessions(4);
        let sched =
            ServeScheduler::sharded_with(Arc::new(tower), 2, WorkerPool::shared(2), cfg).unwrap();
        sched.process_all(&queue).unwrap().iter().map(hash_tensor).collect()
    };
    // recover: a fresh process at TP=4, sessions OFF — the journal's
    // Ident (model_id, weights_hash, dims) must match because identity
    // is a function of the unsharded weights, never the width
    let t1 = ShardedTower::transformer(tf_model(), 1).unwrap();
    let t4 = ShardedTower::transformer(tf_model(), 4).unwrap();
    assert_eq!(t1.weights_hash(), t4.weights_hash(), "weights_hash must be TP-invariant");
    assert_eq!(t1.model_id(), t4.model_id());
    let readout = read_journal(&path).unwrap();
    let sched = ServeScheduler::sharded_with(
        Arc::new(t4),
        2,
        WorkerPool::shared(1),
        ServeConfig { batch_window: 4, log: true, ..Default::default() },
    )
    .unwrap();
    let rep = sched.recover(&readout).unwrap();
    assert!(rep.consistent(), "{rep:?}");
    assert_eq!(rep.next_ticket, queue.len() as u64);
    let log = sched.log().unwrap();
    for (t, want) in uninterrupted.iter().enumerate() {
        assert_eq!(
            &log.get(t as u64).unwrap().response_hash,
            want,
            "ticket {t}: TP=4 recovery must carry the TP=1 run's bits"
        );
    }
    // and the rebuilt log replays bit-exactly through the TP=4 shards
    assert!(sched.replay(0..queue.len() as u64).unwrap().verified());
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn indivisible_shards_error_at_every_layer() {
    let pool = WorkerPool::new(1);
    // shard-plan layer: tp must be a divisor of the logical segment
    // count and the shard index in range
    assert!(ShardPlan::new(0, 0).is_err());
    assert!(ShardPlan::new(3, 0).is_err());
    assert!(ShardPlan::new(8, 0).is_err());
    assert!(ShardPlan::new(2, 2).is_err());
    // linear layer: column width 5 cannot split two ways; input width 6
    // has no 4-segment row decomposition (at ANY tp — the reduction
    // graph is width-independent)
    let l = Linear::new(8, 5, 1);
    assert!(l.pack_col_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).is_err());
    let l = Linear::new(6, 4, 1);
    assert!(l.pack_row_shard_in(&pool, ShardPlan::new(1, 0).unwrap()).is_err());
    // attention layer: 2 heads cannot split four ways
    let a = MultiheadAttention::new(8, 2, true, 3).unwrap();
    assert!(a.pack_shard_in(&pool, ShardPlan::new(4, 0).unwrap()).is_err());
    // mlp layer: hidden width 10 has no 4-segment row split
    let m = Mlp::new(&[8, 10, 4], Act::Relu, 1);
    assert!(m.pack_shard_in(&pool, ShardPlan::new(1, 0).unwrap()).is_err());
    // transformer layer: a heads=2 model packs at tp=2 but not tp=4
    let cfg = TransformerConfig { vocab: 10, dim: 8, heads: 2, layers: 1, context: 4, mlp_ratio: 2 };
    let m = CharTransformer::new(cfg, 1).unwrap();
    assert!(m.pack_shard_in(&pool, ShardPlan::new(2, 0).unwrap()).is_ok());
    assert!(m.pack_shard_in(&pool, ShardPlan::new(4, 0).unwrap()).is_err());
    // tower layer: the same shapes fail tower construction, not serving
    assert!(ShardedTower::transformer(CharTransformer::new(cfg, 1).unwrap(), 4).is_err());
    assert!(ShardedTower::mlp(Mlp::new(&[8, 10, 4], Act::Relu, 1), 2).is_err());
    assert!(ShardedTower::mlp(mlp_model(), 0).is_err());
    assert!(ShardedTower::mlp(mlp_model(), 3).is_err());
}

#[test]
fn cli_tp_flag_is_validated_and_composes() {
    use std::process::Command;
    let bin = env!("CARGO_BIN_EXE_repdl");
    let run = |args: &[&str]| Command::new(bin).args(args).output().unwrap();
    let code = |args: &[&str]| run(args).status.code();
    // usage errors (exit 2): zero/garbage widths, the linear reference
    // server has no shard plan, and train refuses the serve-time flag
    // (promotion is TP-agnostic)
    assert_eq!(code(&["serve", "--model", "mlp", "--tp", "0"]), Some(2));
    assert_eq!(code(&["serve", "--model", "mlp", "--tp", "lots"]), Some(2));
    assert_eq!(code(&["serve", "--model", "linear", "--tp", "2", "--requests", "1"]), Some(2));
    assert_eq!(code(&["train", "--tp", "2", "--steps", "1"]), Some(2));
    // an indivisible head count under a valid --tp is a construction
    // error (exit 1) — an error message, never a panic backtrace
    let out = run(&[
        "serve", "--model", "transformer", "--tp", "4", "--heads", "2", "--requests", "1",
    ]);
    assert_eq!(out.status.code(), Some(1));
    assert!(!String::from_utf8_lossy(&out.stderr).contains("panicked"));
    // happy paths: --tp composes with --sessions and --journal, and the
    // serve run's own bit checks (scheduler vs single-caller reference,
    // replay) all pass → exit 0
    assert_eq!(
        code(&[
            "serve", "--model", "mlp", "--tp", "2", "--dim", "16", "--hidden", "16",
            "--requests", "8", "--threads", "2", "--replay",
        ]),
        Some(0)
    );
    let dir = std::env::temp_dir().join("repdl-tp-invariance");
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("cli-tp.journal");
    let _ = std::fs::remove_file(&journal);
    assert_eq!(
        code(&[
            "serve", "--model", "transformer", "--tp", "2", "--width", "8", "--heads", "4",
            "--layers", "1", "--context", "4", "--requests", "8", "--threads", "2",
            "--sessions", "--replay", "--journal", journal.to_str().unwrap(),
        ]),
        Some(0)
    );
    let _ = std::fs::remove_file(&journal);
}
