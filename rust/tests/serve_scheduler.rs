//! Conformance suite for the deterministic dynamic-batching scheduler
//! (coordinator/serve/scheduler.rs).
//!
//! The claim under test is the serving-stack extension of RepDL §2.2.2:
//! because every kernel is batch-size invariant and pool-size invariant,
//! a request's output bits depend on nothing but (request, weights) — so
//! they must be *identical* across shard counts, batch windows, worker
//! pool sizes, concurrent client counts, and arrival interleavings. On
//! top of that, the scheduler's own bookkeeping (tickets → shards →
//! batches) must be a pure function of arrival order, proven via the
//! executed-batch trace.

use repdl::coordinator::{DeterministicServer, ServeReplica, ServeScheduler};
use repdl::rng::uniform_tensor;
use repdl::tensor::{matmul, Tensor, WorkerPool};
use std::sync::Arc;

fn server(d_in: usize, d_out: usize, max_batch: usize, seed: u64) -> Arc<DeterministicServer> {
    let w = uniform_tensor(&[d_in, d_out], -0.3, 0.3, seed);
    Arc::new(DeterministicServer::new(w, max_batch).unwrap())
}

fn queue(n: usize, d: usize, seed: u64) -> Vec<Tensor> {
    (0..n)
        .map(|i| uniform_tensor(&[d], -1.0, 1.0, seed + i as u64))
        .collect()
}

/// The reference bits: one request at a time, straight through `matmul`.
fn reference(srv: &DeterministicServer, q: &[Tensor]) -> Vec<Tensor> {
    q.iter()
        .map(|r| {
            matmul(&r.reshape(&[1, srv.d_in()]).unwrap(), &srv.weights).unwrap()
        })
        .collect()
}

/// Strict bit equality on the raw f32 payloads (outputs are rank-1
/// rows, the reference keeps its [1, d] shape — compare payloads, not
/// dims; `==` on f32 would conflate -0.0/0.0 and reject equal NaNs).
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn bits_invariant_across_shards_windows_and_pool_sizes() {
    let srv = server(96, 8, 8, 3);
    let q = queue(30, 96, 500);
    let want = reference(&srv, &q);
    for shards in [1usize, 2, 4] {
        for window in [1usize, 3, 16] {
            for lanes in [1usize, 3] {
                let sched = ServeScheduler::sharded(
                    Arc::clone(&srv),
                    shards,
                    window,
                    WorkerPool::shared(lanes),
                )
                .unwrap();
                let outs = sched.process_all(&q).unwrap();
                for (r, (o, w)) in outs.iter().zip(want.iter()).enumerate() {
                    assert!(
                        bits_eq(o.data(), w.data()),
                        "request {r} bits changed at shards={shards} window={window} lanes={lanes}"
                    );
                }
            }
        }
    }
}

#[test]
fn bits_invariant_across_concurrent_client_counts() {
    let srv = server(64, 8, 8, 9);
    let q = queue(40, 64, 700);
    let want = reference(&srv, &q);
    for shards in [1usize, 2, 4] {
        for clients in [1usize, 2, 5] {
            let sched = ServeScheduler::sharded(
                Arc::clone(&srv),
                shards,
                4,
                WorkerPool::shared(2),
            )
            .unwrap();
            // each client owns an interleaved slice; submission order
            // across clients is whatever the OS scheduler makes it —
            // per-request bits must not care
            let ok = std::thread::scope(|s| {
                let handles: Vec<_> = (0..clients)
                    .map(|c| {
                        let (sched, q, want) = (&sched, &q, &want);
                        s.spawn(move || {
                            sched
                                .replay_slice(q, c, clients)
                                .unwrap()
                                .into_iter()
                                .all(|(i, o)| bits_eq(o.data(), want[i].data()))
                        })
                    })
                    .collect();
                handles.into_iter().all(|h| h.join().unwrap())
            });
            assert!(ok, "bits changed at shards={shards} clients={clients}");
        }
    }
}

#[test]
fn batch_composition_is_a_pure_function_of_tickets() {
    // the executed-batch trace must equal the closed form: shard s gets
    // tickets ≡ s (mod shards) in order, chunked into `window`-sized
    // batches with one trailing partial from the flush — independent of
    // dispatcher wake-up timing (run several times to let timing vary)
    for round in 0..3u64 {
        let srv = server(32, 4, 16, 20 + round);
        let (n, shards, window) = (23usize, 3usize, 4usize);
        let q = queue(n, 32, 900 + round);
        let sched = ServeScheduler::sharded(
            Arc::clone(&srv),
            shards,
            window,
            WorkerPool::shared(2),
        )
        .unwrap();
        sched.process_all(&q).unwrap();
        let mut want: Vec<(usize, Vec<u64>)> = Vec::new();
        for s in 0..shards {
            let tickets: Vec<u64> =
                (0..n as u64).filter(|t| (*t as usize) % shards == s).collect();
            for chunk in tickets.chunks(window) {
                want.push((s, chunk.to_vec()));
            }
        }
        want.sort_by_key(|(_, t)| t[0]);
        let got = sched.trace();
        assert_eq!(got.len(), want.len(), "round {round}: {got:?}");
        for (g, (shard, tickets)) in got.iter().zip(want.iter()) {
            assert_eq!(g.shard, *shard, "round {round}");
            assert_eq!(&g.tickets, tickets, "round {round}");
        }
    }
}

#[test]
fn replicas_with_private_pools_match_shared_pool_bits() {
    let srv = server(48, 8, 8, 31);
    let q = queue(17, 48, 40);
    let want = reference(&srv, &q);
    // private per-replica pools of *different* sizes — still the same bits
    let replicas: Vec<ServeReplica> = [1usize, 2, 4]
        .iter()
        .map(|&lanes| ServeReplica::new(Arc::clone(&srv), WorkerPool::shared(lanes)))
        .collect();
    let sched = ServeScheduler::new(replicas, 5).unwrap();
    let outs = sched.process_all(&q).unwrap();
    for (o, w) in outs.iter().zip(want.iter()) {
        assert!(bits_eq(o.data(), w.data()), "private-pool replica changed bits");
    }
}

#[test]
fn malformed_requests_fail_alone_and_cleanly() {
    let srv = server(16, 4, 8, 5);
    let sched =
        ServeScheduler::sharded(Arc::clone(&srv), 2, 4, WorkerPool::shared(1)).unwrap();
    let good = queue(6, 16, 80);
    // wrong length is rejected at submit — same Error::shape style as
    // check_request, and it never consumes a ticket or poisons a batch
    assert!(sched.submit(uniform_tensor(&[17], -1.0, 1.0, 1)).is_err());
    assert!(sched.submit(Tensor::zeros(&[0])).is_err());
    let outs = sched.process_all(&good).unwrap();
    let want = reference(&srv, &good);
    for (o, w) in outs.iter().zip(want.iter()) {
        assert!(bits_eq(o.data(), w.data()));
    }
}

#[test]
fn drop_drains_in_flight_requests() {
    let srv = server(24, 4, 8, 6);
    let q = queue(5, 24, 60);
    let want = reference(&srv, &q);
    let pending: Vec<_> = {
        let sched =
            ServeScheduler::sharded(Arc::clone(&srv), 2, 64, WorkerPool::shared(2)).unwrap();
        // window 64 never fills and nobody flushes — drop must still
        // answer every submitted request (close drains partial batches)
        q.iter().map(|r| sched.submit(r.clone()).unwrap()).collect()
    };
    for (p, w) in pending.into_iter().zip(want.iter()) {
        let o = p.wait().unwrap();
        assert!(bits_eq(o.data(), w.data()), "drop lost or corrupted a request");
    }
}
