//! Cross-model serve conformance suite (ISSUE 5).
//!
//! PR 3–4 proved the scheduler invariants — bits invariant across
//! shards, pool sizes, batch composition, cache on/off; replay
//! verifying bit-exactly — for the linear GEMM server. Deep forward
//! passes compound non-associativity (arXiv:2408.05148), so this suite
//! re-proves every invariant over all three [`ModelTower`]s: linear,
//! off-tape MLP, off-tape transformer.
//!
//! Thread-count note: `REPDL_THREADS` is read once per process (DESIGN
//! §3), so the env-var axis of the grid cannot vary inside one test
//! run. The suite varies pool sizes {1, 2, 8} through explicit
//! `WorkerPool`s — the same mechanism the env var feeds — and CI runs
//! the whole suite a second time under `REPDL_THREADS=1`, which
//! completes the {1, 4}-style env grid.

use repdl::coordinator::{
    DeterministicServer, MlpTower, ModelRegistry, ModelTower, ServeConfig, ServeScheduler,
    TransformerTower,
};
use repdl::nn::{Act, CharTransformer, Mlp, TransformerConfig};
use repdl::tensor::{Tensor, WorkerPool};
use repdl::Error;
use std::sync::Arc;

const D_IN: usize = 24; // shared by linear + mlp so requests can cross
const VOCAB: usize = 12;
const CONTEXT: usize = 6;

fn linear_tower() -> Arc<dyn ModelTower> {
    let w = repdl::rng::uniform_tensor(&[D_IN, 6], -0.3, 0.3, 7);
    Arc::new(DeterministicServer::new(w, 8).unwrap())
}

fn mlp_tower() -> Arc<dyn ModelTower> {
    Arc::new(MlpTower::new(Mlp::new(&[D_IN, 16, 6], Act::Gelu, 3)).unwrap())
}

fn transformer_tower() -> Arc<dyn ModelTower> {
    let cfg = TransformerConfig {
        vocab: VOCAB,
        dim: 8,
        heads: 2,
        layers: 2,
        context: CONTEXT,
        mlp_ratio: 2,
    };
    Arc::new(TransformerTower::new(CharTransformer::new(cfg, 5).unwrap()).unwrap())
}

fn feature_queue(n: usize, seed: u64) -> Vec<Tensor> {
    (0..n)
        .map(|i| repdl::rng::uniform_tensor(&[D_IN], -1.0, 1.0, seed + i as u64))
        .collect()
}

fn token_queue(n: usize) -> Vec<Tensor> {
    (0..n)
        .map(|i| {
            Tensor::from_vec(
                &[CONTEXT],
                (0..CONTEXT)
                    .map(|j| ((i * 31 + j * 7 + 3) % VOCAB) as f32)
                    .collect(),
            )
            .unwrap()
        })
        .collect()
}

/// Every tower with a queue in its input domain.
fn towers() -> Vec<(Arc<dyn ModelTower>, Vec<Tensor>)> {
    vec![
        (linear_tower(), feature_queue(10, 100)),
        (mlp_tower(), feature_queue(10, 100)),
        (transformer_tower(), token_queue(10)),
    ]
}

#[test]
fn every_tower_is_bit_invariant_across_shards_pools_and_cache() {
    for (tower, queue) in towers() {
        // the reference: a direct single-threaded forward, no scheduler
        let reference = tower.forward_batch(&WorkerPool::new(1), &queue).unwrap();
        for shards in [1usize, 2, 4] {
            for lanes in [1usize, 2, 8] {
                for cache_capacity in [0usize, 16] {
                    let cfg = ServeConfig {
                        batch_window: 4,
                        cache_capacity,
                        log: true,
                        ..Default::default()
                    };
                    let sched = ServeScheduler::sharded_with(
                        Arc::clone(&tower),
                        shards,
                        WorkerPool::shared(lanes),
                        cfg,
                    )
                    .unwrap();
                    let cell = format!(
                        "model={} shards={shards} lanes={lanes} cache={cache_capacity}",
                        tower.model_id()
                    );
                    // two replays: the second is answered from a warm
                    // memo when the cache is on — bits must not move
                    for replay in 0..2 {
                        let outs = sched.process_all(&queue).unwrap();
                        for (i, (a, b)) in reference.iter().zip(outs.iter()).enumerate() {
                            assert!(
                                a.bit_eq(b),
                                "{cell} replay={replay} request={i}: bits changed"
                            );
                        }
                    }
                    if cache_capacity > 0 {
                        let s = sched.cache_stats().unwrap();
                        assert_eq!(
                            (s.misses, s.hits),
                            (queue.len() as u64, queue.len() as u64),
                            "{cell}: second replay must be served from the memo"
                        );
                    }
                    // audit: every logged ticket re-executes bit-exactly
                    // (singleton batches on the original shard)
                    let rep = sched.replay(0..(2 * queue.len()) as u64).unwrap();
                    assert_eq!(rep.replayed, 2 * queue.len(), "{cell}");
                    assert!(rep.verified(), "{cell}: replay mismatch {rep:?}");
                }
            }
        }
    }
}

#[test]
fn interleaved_multi_model_submits_preserve_per_model_ticket_traces() {
    let mut reg = ModelRegistry::new();
    let specs = towers();
    let mut references = Vec::new();
    for (tower, queue) in &specs {
        references
            .push(tower.forward_batch(&WorkerPool::new(1), queue).unwrap());
        reg.register(
            ServeScheduler::sharded_with(
                Arc::clone(tower),
                2,
                WorkerPool::shared(2),
                ServeConfig { batch_window: 4, log: true, ..Default::default() },
            )
            .unwrap(),
        )
        .unwrap();
    }
    let ids: Vec<&str> = specs.iter().map(|(t, _)| t.model_id()).collect();
    assert_eq!(reg.model_ids(), vec!["linear", "mlp", "transformer"]);
    // interleave submits round-robin across the three models: the
    // per-model ticket sequence must be the dense submit order within
    // each model, independent of the other models' traffic
    let n = specs[0].1.len();
    let mut pending = Vec::new();
    for i in 0..n {
        for (m, (_, queue)) in specs.iter().enumerate() {
            let p = reg.submit(ids[m], queue[i].clone()).unwrap();
            assert_eq!(p.ticket(), i as u64, "model {} submit {i}", ids[m]);
            pending.push((m, i, p));
        }
    }
    reg.flush_all();
    for (m, i, p) in pending {
        let out = p.wait().unwrap();
        assert!(
            out.bit_eq(&references[m][i]),
            "model {} request {i}: multi-model routing changed bits",
            ids[m]
        );
    }
    // per-model traces are the closed form: tickets 0..n, shard =
    // ticket % 2, window-4 chunks cut at the flush — identical to what
    // a single-model scheduler with the same event sequence produces
    for id in &ids {
        let sched = reg.get(id).unwrap();
        let seen: Vec<u64> = sched
            .trace()
            .into_iter()
            .flat_map(|b| {
                for (&a, &b2) in b.tickets.iter().zip(b.tickets.iter().skip(1)) {
                    assert!(a < b2, "model {id}: batch not ticket-ordered");
                }
                b.tickets
            })
            .collect();
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n as u64).collect::<Vec<u64>>(), "model {id}");
        // replay() verifies for every tower through the registry, too
        let rep = reg.replay(id, 0..n as u64).unwrap();
        assert_eq!(rep.replayed, n, "model {id}");
        assert!(rep.verified(), "model {id}: {rep:?}");
    }
}

#[test]
fn identical_requests_to_different_models_never_share_responses() {
    // linear and mlp share d_in, so the *same request bits* are valid
    // for both — with caches on, each model must keep answering from
    // its own (weights_hash-keyed) memo, never the other model's
    let mut reg = ModelRegistry::new();
    let lin = linear_tower();
    let mlp = mlp_tower();
    let queue = feature_queue(6, 900);
    let lin_ref = lin.forward_batch(&WorkerPool::new(1), &queue).unwrap();
    let mlp_ref = mlp.forward_batch(&WorkerPool::new(1), &queue).unwrap();
    for tower in [Arc::clone(&lin), Arc::clone(&mlp)] {
        reg.register(
            ServeScheduler::sharded_with(
                tower,
                1,
                WorkerPool::shared(1),
                ServeConfig { batch_window: 4, cache_capacity: 32, ..Default::default() },
            )
            .unwrap(),
        )
        .unwrap();
    }
    // the two models must actually disagree on these inputs (else the
    // isolation assertion below would be vacuous)
    assert!(
        lin_ref.iter().zip(mlp_ref.iter()).any(|(a, b)| !a.bit_eq(b)),
        "test needs models that disagree"
    );
    for round in 0..2 {
        for (id, reference) in [("linear", &lin_ref), ("mlp", &mlp_ref)] {
            let pending: Vec<_> = queue
                .iter()
                .map(|r| reg.submit(id, r.clone()).unwrap())
                .collect();
            reg.flush(id).unwrap();
            for (i, p) in pending.into_iter().enumerate() {
                let out = p.wait().unwrap();
                assert!(
                    out.bit_eq(&reference[i]),
                    "round {round} model {id} request {i}: cross-model contamination"
                );
            }
        }
    }
    // round 2 was answered from each model's own memo
    for id in ["linear", "mlp"] {
        let s = reg.get(id).unwrap().cache_stats().unwrap();
        assert_eq!((s.misses, s.hits), (6, 6), "model {id}: {s:?}");
    }
}

#[test]
fn log_rotation_holds_for_every_tower() {
    for (tower, queue) in towers() {
        let id = tower.model_id().to_string();
        let sched = ServeScheduler::sharded_with(
            Arc::clone(&tower),
            2,
            WorkerPool::shared(1),
            ServeConfig { batch_window: 4, log: true, ..Default::default() },
        )
        .unwrap();
        sched.process_all(&queue).unwrap();
        let n = queue.len() as u64;
        assert_eq!(sched.truncate_log_below(n / 2).unwrap(), (n / 2) as usize, "{id}");
        // above the watermark: still verifies bit-exactly
        let rep = sched.replay(n / 2..n).unwrap();
        assert_eq!(rep.replayed, (n - n / 2) as usize, "{id}");
        assert!(rep.verified(), "{id}: {rep:?}");
        // below: the typed error, never a silent pass
        match sched.replay(0..n) {
            Err(Error::Truncated { ticket, watermark }) => {
                assert_eq!((ticket, watermark), (0, n / 2), "{id}");
            }
            other => panic!("{id}: want Truncated, got {other:?}"),
        }
    }
}

#[test]
fn malformed_requests_are_rejected_at_submit_for_every_tower() {
    for (tower, queue) in towers() {
        let id = tower.model_id().to_string();
        let sched = ServeScheduler::sharded(
            Arc::clone(&tower),
            2,
            4,
            WorkerPool::shared(1),
        )
        .unwrap();
        // wrong length never consumes a ticket
        assert!(sched.submit(Tensor::zeros(&[tower.d_in() + 1])).is_err(), "{id}");
        if id == "transformer" {
            // right length, invalid tokens: rejected at submit too, so
            // a garbage request can never poison a composed batch
            for bad in [VOCAB as f32, 1.5, -1.0, f32::NAN] {
                let mut v = vec![0.0f32; CONTEXT];
                v[2] = bad;
                let r = Tensor::from_vec(&[CONTEXT], v).unwrap();
                assert!(sched.submit(r).is_err(), "token {bad} must be rejected");
            }
        }
        // the rejected submits consumed no tickets: a good queue still
        // gets the dense 0..n sequence
        let outs = sched.process_all(&queue).unwrap();
        assert_eq!(outs.len(), queue.len(), "{id}");
        let mut seen: Vec<u64> =
            sched.trace().into_iter().flat_map(|b| b.tickets).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..queue.len() as u64).collect::<Vec<u64>>(), "{id}");
    }
}

#[test]
fn mixed_tower_replicas_are_a_config_error() {
    let pool = WorkerPool::shared(1);
    let replicas = vec![
        repdl::coordinator::ServeReplica::new(linear_tower(), Arc::clone(&pool)),
        repdl::coordinator::ServeReplica::new(mlp_tower(), pool),
    ];
    assert!(
        ServeScheduler::new(replicas, 4).is_err(),
        "replicas of different models must be rejected"
    );
    // same architecture, different weights: also rejected (hash check)
    let a = linear_tower();
    let w2 = repdl::rng::uniform_tensor(&[D_IN, 6], -0.3, 0.3, 8);
    let b: Arc<dyn ModelTower> = Arc::new(DeterministicServer::new(w2, 8).unwrap());
    let pool = WorkerPool::shared(1);
    let replicas = vec![
        repdl::coordinator::ServeReplica::new(a, Arc::clone(&pool)),
        repdl::coordinator::ServeReplica::new(b, pool),
    ];
    assert!(
        ServeScheduler::new(replicas, 4).is_err(),
        "same shape but different weight bits must be rejected"
    );
}
