//! Conformance suite for the PR-2 perf layer: packed register-tiled
//! GEMM, the fused im2col convolution pipeline, pooled pooling ops and
//! the thread-local scratch arena.
//!
//! Everything here pins one claim: the fast paths are **bit-identical
//! by construction** to the reference forms (`matmul_dotform`,
//! `conv2d_direct`) — packing/im2col emission are layout-only, register
//! tiling reorders only independent output elements, and scratch reuse
//! can never leak stale state into an output bit because every consumed
//! slot is overwritten first.

use repdl::proptest::{forall, Gen};
use repdl::tensor::{
    avg_pool2d_in, conv2d_direct_in, conv2d_im2col_in, matmul_blocked_in, matmul_dotform_in,
    matmul_in, matmul_packed_in, max_pool2d_in, Conv2dParams, Tensor, WorkerPool,
};

const POOL_SIZES: [usize; 6] = [1, 2, 3, 5, 8, 16];

fn lcg(dims: &[usize], seed: u64) -> Tensor {
    let n: usize = dims.iter().product();
    let mut s = seed;
    Tensor::from_vec(
        dims,
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(777);
                (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 2.0
            })
            .collect(),
    )
    .unwrap()
}

#[test]
fn prop_packed_gemm_equals_dotform_bitwise() {
    // randomized shapes biased to straddle the MR=8 / NR=16 tile
    // boundaries (the ±1 neighbourhoods of multiples)
    let pool = WorkerPool::new(5);
    forall(
        23,
        40,
        |g: &mut Gen| {
            let near = |g: &mut Gen, step: usize| {
                let base = (1 + g.below(4)) * step; // a multiple of the tile step
                (base + g.below(3)).saturating_sub(1).max(1) // ±1 around it
            };
            let m = near(g, 8);
            let n = near(g, 16);
            let k = 1 + g.below(60);
            let a = g.f32_vec(m * k, 2.0);
            let b = g.f32_vec(k * n, 2.0);
            (m, k, n, a, b)
        },
        |(m, k, n, a, b)| {
            let at = Tensor::from_vec(&[*m, *k], a.clone()).unwrap();
            let bt = Tensor::from_vec(&[*k, *n], b.clone()).unwrap();
            let packed = matmul_packed_in(&pool, &at, &bt).unwrap();
            let dotform = matmul_dotform_in(&pool, &at, &bt).unwrap();
            packed.bit_eq(&dotform)
        },
    );
}

#[test]
fn prop_fused_conv_equals_direct_bitwise() {
    // random conv geometries (stride/padding included) — output spatial
    // sizes land on both sides of the NR panel width, O straddles MR
    let pool = WorkerPool::new(4);
    forall(
        29,
        25,
        |g: &mut Gen| {
            let b = 1 + g.below(3);
            let c = 1 + g.below(4);
            let hw = 3 + g.below(8);
            let o = 1 + g.below(12);
            let kk = 1 + g.below(3); // hw ≥ 3, so the kernel always fits
            let stride = 1 + g.below(2);
            let padding = g.below(2);
            let x = g.f32_vec(b * c * hw * hw, 2.0);
            let w = g.f32_vec(o * c * kk * kk, 1.0);
            let bias = g.f32_vec(o, 1.0);
            (b, c, hw, o, kk, stride, padding, x, w, bias)
        },
        |(b, c, hw, o, kk, stride, padding, x, w, bias)| {
            let xt = Tensor::from_vec(&[*b, *c, *hw, *hw], x.clone()).unwrap();
            let wt = Tensor::from_vec(&[*o, *c, *kk, *kk], w.clone()).unwrap();
            let bt = Tensor::from_vec(&[*o], bias.clone()).unwrap();
            let p = Conv2dParams { stride: *stride, padding: *padding };
            let direct = conv2d_direct_in(&pool, &xt, &wt, Some(&bt), p);
            let fused = conv2d_im2col_in(&pool, &xt, &wt, Some(&bt), p);
            match (direct, fused) {
                (Ok(d), Ok(f)) => d.bit_eq(&f),
                // kernel larger than padded input: both must refuse
                (Err(_), Err(_)) => true,
                _ => false,
            }
        },
    );
}

#[test]
fn packed_gemm_pool_size_invariance() {
    let a = lcg(&[33, 48], 1);
    let b = lcg(&[48, 49], 2);
    let base = matmul_packed_in(&WorkerPool::new(1), &a, &b).unwrap();
    for lanes in POOL_SIZES {
        let pool = WorkerPool::new(lanes);
        assert!(
            base.bit_eq(&matmul_packed_in(&pool, &a, &b).unwrap()),
            "packed GEMM lanes={lanes}"
        );
    }
}

#[test]
fn pooling_ops_pool_size_invariance() {
    let x = lcg(&[3, 4, 12, 12], 3);
    for k in [1usize, 2, 3, 4, 6] {
        let base_max = max_pool2d_in(&WorkerPool::new(1), &x, k).unwrap();
        let base_avg = avg_pool2d_in(&WorkerPool::new(1), &x, k).unwrap();
        for lanes in POOL_SIZES {
            let pool = WorkerPool::new(lanes);
            assert!(
                base_max.bit_eq(&max_pool2d_in(&pool, &x, k).unwrap()),
                "max_pool2d k={k} lanes={lanes}"
            );
            assert!(
                base_avg.bit_eq(&avg_pool2d_in(&pool, &x, k).unwrap()),
                "avg_pool2d k={k} lanes={lanes}"
            );
        }
    }
}

#[test]
fn degenerate_gemm_shapes_are_empty_or_zero_through_every_kernel() {
    // m=0 / n=0 → empty outputs of the right shape; k=0 → the empty sum
    // (exactly +0.0 everywhere). All three routed kernels and the router
    // itself must agree bit for bit and must not panic.
    let pool = WorkerPool::new(3);
    for (m, k, n) in [
        (0usize, 5usize, 7usize),
        (4, 5, 0),
        (4, 0, 7),
        (0, 0, 7),
        (0, 3, 0),
        (0, 0, 0),
        (64, 0, 64), // big enough that routing would pick packed
    ] {
        let a = lcg(&[m, k], (m * 10 + k) as u64 + 1);
        let b = lcg(&[k, n], (k * 10 + n) as u64 + 2);
        let dot = matmul_dotform_in(&pool, &a, &b).unwrap();
        let blocked = matmul_blocked_in(&pool, &a, &b).unwrap();
        let packed = matmul_packed_in(&pool, &a, &b).unwrap();
        let routed = matmul_in(&pool, &a, &b).unwrap();
        assert_eq!(dot.dims(), &[m, n], "m={m} k={k} n={n}");
        for (name, got) in [("blocked", &blocked), ("packed", &packed), ("routed", &routed)] {
            assert!(got.bit_eq(&dot), "{name} diverged at m={m} k={k} n={n}");
        }
        // k=0 with a non-empty output is the empty sum: exact +0.0 bits
        assert!(
            dot.data().iter().all(|v| v.to_bits() == 0.0f32.to_bits()),
            "m={m} k={k} n={n}: degenerate GEMM must be exact +0.0"
        );
    }
}

#[test]
fn degenerate_conv_shapes_are_empty_or_bias_through_fused_path() {
    let pool = WorkerPool::new(3);
    let p = Conv2dParams { stride: 1, padding: 0 };
    // b=0 (no images) and o=0 (no filters): empty outputs, right shape
    for (b, c, o) in [(0usize, 2usize, 3usize), (2, 2, 0), (0, 2, 0)] {
        let x = lcg(&[b, c, 5, 5], 11);
        let w = lcg(&[o, c, 2, 2], 12);
        let direct = conv2d_direct_in(&pool, &x, &w, None, p).unwrap();
        let fused = conv2d_im2col_in(&pool, &x, &w, None, p).unwrap();
        assert_eq!(direct.dims(), &[b, o, 4, 4], "b={b} o={o}");
        assert!(direct.bit_eq(&fused), "b={b} o={o}");
        assert_eq!(direct.numel(), 0);
    }
    // c=0 (zero-channel input): every output element is the empty sum
    // (+0.0), or exactly the bias once one is given
    let x = lcg(&[2, 0, 5, 5], 13);
    let w = lcg(&[3, 0, 2, 2], 14);
    let direct = conv2d_direct_in(&pool, &x, &w, None, p).unwrap();
    let fused = conv2d_im2col_in(&pool, &x, &w, None, p).unwrap();
    assert_eq!(direct.dims(), &[2, 3, 4, 4]);
    assert!(direct.bit_eq(&fused), "c=0 fused diverged");
    assert!(direct.data().iter().all(|v| v.to_bits() == 0.0f32.to_bits()));
    let bias = Tensor::from_vec(&[3], vec![1.5, -2.25, 0.125]).unwrap();
    let db = conv2d_direct_in(&pool, &x, &w, Some(&bias), p).unwrap();
    let fb = conv2d_im2col_in(&pool, &x, &w, Some(&bias), p).unwrap();
    assert!(db.bit_eq(&fb), "c=0 with bias: fused diverged");
    for oi in 0..3 {
        for s in 0..16 {
            for bi in 0..2 {
                let got = db.data()[(bi * 3 + oi) * 16 + s];
                assert_eq!(got.to_bits(), bias.data()[oi].to_bits());
            }
        }
    }
}

#[test]
fn scratch_arena_reuse_is_bit_clean_across_shapes() {
    // Alternate kernels and shapes on one thread so every call reuses
    // the arena buffers the previous (different-shape) call dirtied;
    // each result must still equal a reference computed by the
    // scratch-free dot form. A single stale slot reaching the output
    // would break bit-equality.
    let pool = WorkerPool::new(3);
    let shapes = [(9usize, 40usize, 33usize), (17, 7, 65), (3, 90, 5), (24, 24, 24)];
    for round in 0..3u64 {
        for (i, &(m, k, n)) in shapes.iter().enumerate() {
            let a = lcg(&[m, k], round * 100 + i as u64);
            let b = lcg(&[k, n], round * 100 + 50 + i as u64);
            let fast = matmul_packed_in(&pool, &a, &b).unwrap();
            let want = matmul_dotform_in(&pool, &a, &b).unwrap();
            assert!(fast.bit_eq(&want), "round={round} shape=({m},{k},{n})");
        }
        // interleave a conv so GEMM pack buffers and im2col buffers
        // trade places in the arena
        let x = lcg(&[2, 3, 9, 9], round + 900);
        let w = lcg(&[5, 3, 3, 3], round + 950);
        let p = Conv2dParams { stride: 1, padding: 1 };
        let fused = conv2d_im2col_in(&pool, &x, &w, None, p).unwrap();
        let direct = conv2d_direct_in(&pool, &x, &w, None, p).unwrap();
        assert!(fused.bit_eq(&direct), "conv round={round}");
    }
}

#[test]
fn scratch_guard_len_and_reuse_semantics() {
    use repdl::tensor::scratch_f32;
    {
        let mut g = scratch_f32(257);
        assert_eq!(g.len(), 257);
        g.fill(42.0);
    }
    // a later, smaller lease may see stale contents — the contract is
    // only that the *length* is exact and the buffer is exclusively ours
    let g2 = scratch_f32(100);
    assert_eq!(g2.len(), 100);
    let g3 = scratch_f32(1000);
    assert_eq!(g3.len(), 1000);
}
