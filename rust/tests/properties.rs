//! Property-based invariants over the correctly-rounded ops and the
//! coordinator substrates (mini-harness; see `repdl::proptest`).

use repdl::proptest::{forall, Gen};
use repdl::rnum::bigfloat::{BigFloat, PREC_ORACLE};
use repdl::rnum::{
    rcos, rexp, rlog, rpow, rrsqrt, rsin, rsqrt_f32, rtanh, sum::sum_exact, KulischAcc,
};

#[test]
fn exp_matches_oracle_on_random_inputs() {
    forall(1, 400, |g: &mut Gen| g.f32_range(-104.0, 89.0), |&x| {
        let want = BigFloat::from_f32(x, PREC_ORACLE).exp_bf().to_f32();
        rexp(x).to_bits() == want.to_bits()
    });
}

#[test]
fn log_exp_identity_within_analytic_bound() {
    // exp∘log is not the identity: log's half-ulp rounding error δ is
    // amplified to a relative error of e^δ − 1 ≈ δ, i.e. about
    // |log x| / 2 output ulps. CR ops must stay inside that bound.
    forall(2, 300, |g: &mut Gen| g.f32_range(0.01, 1e6), |&x| {
        let l = rlog(x);
        let y = rexp(l);
        let bound = 2 + (l.abs() * 0.75) as u32;
        repdl::rnum::fbits::ulp_diff(x, y) <= bound
    });
}

#[test]
fn sqrt_square_roundtrip() {
    forall(3, 400, |g: &mut Gen| g.f32_range(0.0, 1e18), |&x| {
        let s = rsqrt_f32(x);
        // s² ≤ x(1+2^-22) and (s is CR) — weak but universal property
        (s * s - x).abs() <= x * 3e-7 + f32::MIN_POSITIVE
    });
}

#[test]
fn rsqrt_equals_one_over_sqrt_within_ulp() {
    forall(4, 300, |g: &mut Gen| g.f32_range(1e-30, 1e30), |&x| {
        repdl::rnum::fbits::ulp_diff(rrsqrt(x), 1.0 / rsqrt_f32(x)) <= 1
    });
}

#[test]
fn sin_cos_pythagoras() {
    forall(5, 300, |g: &mut Gen| g.f32_range(-1000.0, 1000.0), |&x| {
        let (s, c) = (rsin(x) as f64, rcos(x) as f64);
        (s * s + c * c - 1.0).abs() < 1e-6
    });
}

#[test]
fn tanh_bounded_and_odd() {
    forall(6, 300, |g: &mut Gen| g.f32_any(), |&x| {
        if !x.is_finite() {
            return true;
        }
        let t = rtanh(x);
        t.abs() <= 1.0 && rtanh(-x).to_bits() == (-t).to_bits()
    });
}

#[test]
fn pow_integer_consistency() {
    forall(7, 200, |g: &mut Gen| (g.f32_range(0.1, 20.0), 1 + g.below(6)), |&(x, n)| {
        // x^n == x·x·…·x evaluated exactly in f64 then rounded? Too strict;
        // instead: rpow is within 1 ulp of the bigfloat oracle
        let want = {
            let xb = BigFloat::from_f32(x, 12);
            let nb = BigFloat::from_u64(n as u64, 12);
            nb.mul(&xb.ln_bf()).exp_bf().to_f32()
        };
        // integer powers are computed exactly — compare to oracle
        repdl::rnum::fbits::ulp_diff(rpow(x, n as f32), want) <= 1
    });
}

#[test]
fn kulisch_permutation_invariance() {
    forall(8, 50, |g: &mut Gen| {
        let n = 10 + g.below(500);
        (g.f32_vec(n, 1e5), g.u64())
    }, |(xs, seed)| {
        let direct = sum_exact(xs);
        // random permutation
        let mut perm = xs.clone();
        let mut s = *seed;
        for i in (1..perm.len()).rev() {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
            let j = ((s >> 33) as usize) % (i + 1);
            perm.swap(i, j);
        }
        let mut acc = KulischAcc::new();
        for &v in &perm {
            acc.add(v);
        }
        acc.round_f32().to_bits() == direct.to_bits()
    });
}

#[test]
fn sequential_sum_prefix_associativity_spec() {
    // sum(xs) == sum(sum(xs[..k]) followed by xs[k..]) — the defining
    // recurrence of the sequential order
    forall(9, 100, |g: &mut Gen| {
        let n = 2 + g.below(200);
        let k = 1 + g.below(n - 1);
        (g.f32_vec(n, 100.0), k)
    }, |(xs, k)| {
        let full = repdl::rnum::sum_sequential(xs);
        let mut acc = repdl::rnum::sum_sequential(&xs[..*k]);
        for &v in &xs[*k..] {
            acc += v;
        }
        acc.to_bits() == full.to_bits()
    });
}

#[test]
fn batchnorm_variants_are_each_deterministic() {
    use repdl::nn::{batch_norm, batch_norm_affine_folded, batch_norm_folded};
    use repdl::rng::uniform_tensor;
    forall(10, 30, |g: &mut Gen| g.u64(), |&seed| {
        let x = uniform_tensor(&[2, 3, 4, 4], -3.0, 3.0, seed);
        let mean = [0.1f32, -0.5, 0.2];
        let var = [1.0f32, 0.8, 1.3];
        let w = [1.1f32, 0.9, 1.0];
        let b = [0.0f32, 0.1, -0.1];
        let v1a = batch_norm(&x, &mean, &var, &w, &b, 1e-5).unwrap();
        let v1b = batch_norm(&x, &mean, &var, &w, &b, 1e-5).unwrap();
        let v2a = batch_norm_folded(&x, &mean, &var, &w, &b, 1e-5).unwrap();
        let v2b = batch_norm_folded(&x, &mean, &var, &w, &b, 1e-5).unwrap();
        let v3a = batch_norm_affine_folded(&x, &mean, &var, &w, &b, 1e-5).unwrap();
        let v3b = batch_norm_affine_folded(&x, &mean, &var, &w, &b, 1e-5).unwrap();
        v1a.bit_eq(&v1b) && v2a.bit_eq(&v2b) && v3a.bit_eq(&v3b)
    });
}

#[test]
fn serve_batching_routes_every_request_once() {
    use repdl::coordinator::DeterministicServer;
    use repdl::rng::uniform_tensor;
    forall(11, 20, |g: &mut Gen| (1 + g.below(40), 1 + g.below(12), g.u64()), |&(n, bs, seed)| {
        let w = uniform_tensor(&[16, 4], -0.3, 0.3, seed);
        let Ok(srv) = DeterministicServer::new(w, bs) else {
            return false;
        };
        let q: Vec<_> = (0..n)
            .map(|i| uniform_tensor(&[16], -1.0, 1.0, seed + 1 + i as u64))
            .collect();
        srv.process_repro(&q).map(|o| o.len() == n).unwrap_or(false)
    });
}
