//! PR 8 acceptance grid: bit-exact checkpoint/resume and train→serve
//! promotion.
//!
//! * resume-at-every-step ≡ uninterrupted, across lanes {1,2,8} ×
//!   {SGD+momentum, Adam}, with dropout on so the RNG stream restore is
//!   load-bearing (a mis-resumed Philox position would change the masks
//!   and therefore the bits);
//! * a checkpoint taken under one lane count resumes identically under
//!   another (lanes are a pure performance knob end to end);
//! * torn checkpoint tails are refused — never repaired — and
//!   `latest_checkpoint` falls back to the newest intact file;
//! * a tampered record whose own frame digest still verifies is caught
//!   by the manifest record;
//! * a promoted checkpoint serves responses bit-identical to direct
//!   inference on the final weights.

use repdl::coordinator::serve::journal::{frame, scan_payloads};
use repdl::coordinator::{
    checkpoint_path, hash_curve, latest_checkpoint, load_checkpoint, save_checkpoint, Checkpoint,
    CheckpointMeta, DataParallelTrainer, ModelRegistry, OptimizerCfg, ServeConfig, TrainerConfig,
};
use repdl::tensor::{Tensor, WorkerPool};

const STEPS: usize = 20;
const MICROBATCH: usize = 4;

fn cfg() -> TrainerConfig {
    TrainerConfig { steps: STEPS, dropout: 0.2, ..Default::default() }
}

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("repdl-train-ckpt-{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn resume_at_every_step_matches_uninterrupted_across_lanes_and_optimizers() {
    let opts = [OptimizerCfg::Sgd { momentum: 0.9, weight_decay: 0.0 }, OptimizerCfg::Adam];
    for (oi, opt) in opts.iter().enumerate() {
        for lanes in [1usize, 2, 8] {
            let dir = tmpdir(&format!("grid-o{oi}-l{lanes}"));
            let engine =
                DataParallelTrainer::new(cfg(), lanes, MICROBATCH).unwrap().optimizer(*opt);
            let meta = CheckpointMeta { cfg: cfg(), opt: *opt, microbatch: MICROBATCH };
            // the uninterrupted reference run, checkpointing every step
            let mut st = engine.init_state();
            let mut curve = Vec::new();
            for _ in 0..STEPS {
                curve.push(engine.step(&mut st).unwrap());
                save_checkpoint(&checkpoint_path(&dir, st.step), &meta, &st, &curve).unwrap();
            }
            let final_hash = st.param_hash();
            let final_curve = hash_curve(&curve);
            // resume from every step k and finish: identical bits
            for k in 1..=STEPS as u64 {
                let ckpt = load_checkpoint(&checkpoint_path(&dir, k)).unwrap();
                assert_eq!(ckpt.meta, meta);
                assert_eq!(ckpt.step, k);
                let (mut st2, mut curve2) = ckpt.into_state().unwrap();
                for _ in k..STEPS as u64 {
                    curve2.push(engine.step(&mut st2).unwrap());
                }
                assert_eq!(
                    st2.param_hash(),
                    final_hash,
                    "opt #{oi} lanes {lanes}: resume at step {k} drifted"
                );
                assert_eq!(
                    hash_curve(&curve2),
                    final_curve,
                    "opt #{oi} lanes {lanes}: loss curve after resume at step {k} drifted"
                );
            }
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }
}

#[test]
fn a_checkpoint_resumes_identically_under_a_different_lane_count() {
    let dir = tmpdir("cross-lane");
    let c = cfg();
    let meta = CheckpointMeta { cfg: c, opt: OptimizerCfg::Adam, microbatch: MICROBATCH };
    let e1 = DataParallelTrainer::new(c, 1, MICROBATCH).unwrap().optimizer(OptimizerCfg::Adam);
    let e8 = DataParallelTrainer::new(c, 8, MICROBATCH).unwrap().optimizer(OptimizerCfg::Adam);
    let mut st = e1.init_state();
    let mut curve = Vec::new();
    for _ in 0..10 {
        curve.push(e1.step(&mut st).unwrap());
    }
    save_checkpoint(&checkpoint_path(&dir, 10), &meta, &st, &curve).unwrap();
    for _ in 10..STEPS {
        curve.push(e1.step(&mut st).unwrap());
    }
    // the 1-lane run's checkpoint, finished on 8 lanes: identical bits
    let ckpt = load_checkpoint(&checkpoint_path(&dir, 10)).unwrap();
    let (mut st8, mut curve8) = ckpt.into_state().unwrap();
    for _ in 10..STEPS {
        curve8.push(e8.step(&mut st8).unwrap());
    }
    assert_eq!(st.param_hash(), st8.param_hash());
    assert_eq!(hash_curve(&curve), hash_curve(&curve8));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn torn_checkpoint_tails_are_refused_not_repaired() {
    let dir = tmpdir("torn");
    let engine = DataParallelTrainer::new(cfg(), 2, MICROBATCH).unwrap();
    let meta = CheckpointMeta { cfg: cfg(), opt: OptimizerCfg::default(), microbatch: MICROBATCH };
    let mut st = engine.init_state();
    let mut curve = Vec::new();
    for _ in 0..3 {
        curve.push(engine.step(&mut st).unwrap());
        save_checkpoint(&checkpoint_path(&dir, st.step), &meta, &st, &curve).unwrap();
    }
    let path = checkpoint_path(&dir, 3);
    let bytes = std::fs::read(&path).unwrap();
    // every truncation point — mid-digest, mid-record, header-only —
    // must refuse the file with a typed error, never "repair" it
    for cut in [bytes.len() - 1, bytes.len() - 40, 13, 8] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let err = load_checkpoint(&path).unwrap_err();
        assert!(
            matches!(err, repdl::Error::Journal(_)),
            "cut at {cut}: want a journal error, got {err}"
        );
    }
    // the file itself is untouched by the failed loads (refuse ≠ repair)
    std::fs::write(&path, &bytes[..bytes.len() - 1]).unwrap();
    let before = std::fs::read(&path).unwrap();
    let _ = load_checkpoint(&path);
    assert_eq!(std::fs::read(&path).unwrap(), before);
    // latest_checkpoint skips the torn step-3 file to the intact step-2
    let scan = latest_checkpoint(&dir).unwrap();
    let (loaded_path, ckpt) = scan.loaded.expect("step-2 must load");
    assert_eq!(loaded_path, checkpoint_path(&dir, 2));
    assert_eq!(ckpt.step, 2);
    assert_eq!(scan.rejected.len(), 1);
    assert_eq!(scan.rejected[0].0, path);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn a_tampered_record_with_a_valid_frame_is_caught_by_the_manifest() {
    let dir = tmpdir("manifest");
    let engine = DataParallelTrainer::new(cfg(), 1, MICROBATCH).unwrap();
    let meta = CheckpointMeta { cfg: cfg(), opt: OptimizerCfg::default(), microbatch: MICROBATCH };
    let mut st = engine.init_state();
    let mut curve = Vec::new();
    for _ in 0..2 {
        curve.push(engine.step(&mut st).unwrap());
    }
    let path = checkpoint_path(&dir, 2);
    save_checkpoint(&path, &meta, &st, &curve).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let (payloads, valid) = scan_payloads(&bytes[12..]);
    assert_eq!(valid, bytes.len() - 12, "fixture checkpoint must be intact");
    assert_eq!(payloads.len(), 6, "checkpoint is six records");
    // flip one loss bit in the CURVE record, then RE-FRAME it so its own
    // SHA-256 verifies — only the manifest's digest list can catch this
    let mut tampered: Vec<Vec<u8>> = payloads.iter().map(|p| p.to_vec()).collect();
    let last = tampered[1].len() - 1;
    tampered[1][last] ^= 1;
    let mut out = bytes[..12].to_vec();
    for p in &tampered {
        out.extend_from_slice(&frame(p).unwrap());
    }
    std::fs::write(&path, &out).unwrap();
    let err = load_checkpoint(&path).unwrap_err();
    assert!(
        err.to_string().contains("manifest"),
        "want a manifest refusal, got: {err}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn promoted_checkpoint_serves_the_trained_bits() {
    let c = cfg();
    let engine = DataParallelTrainer::new(c, 2, MICROBATCH).unwrap();
    let meta = CheckpointMeta { cfg: c, opt: OptimizerCfg::default(), microbatch: MICROBATCH };
    let mut st = engine.init_state();
    let mut curve = Vec::new();
    for _ in 0..STEPS {
        curve.push(engine.step(&mut st).unwrap());
    }
    let ckpt = Checkpoint::capture(meta, &st, &curve);
    assert_eq!(ckpt.param_hash(), st.param_hash());

    // direct inference on the final weights: the reference bits
    let pool = WorkerPool::shared(2);
    let mlp = ckpt.to_mlp().unwrap();
    let d_in = c.side * c.side;
    let reqs: Vec<Tensor> = (0..9)
        .map(|i| repdl::rng::uniform_tensor(&[d_in], -1.0, 1.0, 300 + i as u64))
        .collect();
    let mut x = Tensor::zeros(&[reqs.len(), d_in]);
    for (i, r) in reqs.iter().enumerate() {
        x.data_mut()[i * d_in..(i + 1) * d_in].copy_from_slice(r.data());
    }
    let direct = mlp.forward_infer_in(&pool, &x).unwrap();

    // promote into a registry and serve through the scheduler
    let mut reg = ModelRegistry::new();
    let promo = reg
        .promote("mlp", &ckpt, 2, pool.clone(), ServeConfig::default())
        .unwrap();
    assert!(promo.model_id.starts_with("mlp@"));
    assert_eq!(promo.watermark, 0);
    assert_eq!(reg.get("mlp").unwrap().weights_hash(), promo.weights_hash);
    let pending: Vec<_> =
        reqs.iter().map(|r| reg.submit("mlp", r.clone()).unwrap()).collect();
    reg.flush_all();
    for (i, p) in pending.into_iter().enumerate() {
        let out = p.wait().unwrap();
        assert_eq!(
            out.data(),
            &direct.data()[i * c.classes..(i + 1) * c.classes],
            "request {i}: promoted model served different bits than direct inference"
        );
    }
}
