//! E1 integration — run-to-run determinism of full training.

use repdl::baseline::PlatformProfile;
use repdl::coordinator::{compare_runs, NumericsMode, Trainer, TrainerConfig};
use repdl::data::SyntheticCorpus;
use repdl::nn::{CharTransformer, TransformerConfig};
use repdl::optim::Adam;
use repdl::tensor::Tensor;

#[test]
fn mlp_training_is_bitwise_deterministic() {
    let cfg = TrainerConfig { steps: 30, ..Default::default() };
    let a = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
    let b = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
    let c = compare_runs(&a.loss_curve, &b.loss_curve, &a.param_hash, &b.param_hash);
    assert!(c.curves_identical);
    assert!(c.hashes_equal);
    assert_eq!(c.max_ulp, 0);
}

#[test]
fn atomic_baseline_is_not_deterministic() {
    let cfg = TrainerConfig { steps: 15, ..Default::default() };
    let p = PlatformProfile::reference();
    let a = Trainer::new(cfg, NumericsMode::BaselineAtomic(p)).run().unwrap();
    let b = Trainer::new(cfg, NumericsMode::BaselineAtomic(p)).run().unwrap();
    let c = compare_runs(&a.loss_curve, &b.loss_curve, &a.param_hash, &b.param_hash);
    assert!(!c.hashes_equal, "simulated atomics should diverge");
    assert!(c.first_divergence.is_some());
}

#[test]
fn transformer_training_is_bitwise_deterministic() {
    let cfg = TransformerConfig {
        vocab: 28,
        dim: 16,
        heads: 2,
        layers: 1,
        context: 8,
        mlp_ratio: 2,
    };
    let corpus = SyntheticCorpus::generate(2000, 3);
    let run = || {
        let mut model = CharTransformer::new(cfg, 5).unwrap();
        let mut opt = Adam::new(3e-3);
        let mut losses = Vec::new();
        for step in 0..12 {
            let ids: Vec<usize> = corpus.window(step * 13, cfg.context).to_vec();
            let mut tape = repdl::autograd::Tape::new();
            let mut binds = Vec::new();
            let loss = model.loss_on_sequence(&mut tape, &ids, &mut binds).unwrap();
            tape.backward(loss).unwrap();
            let grads: Vec<Tensor> = binds.iter().map(|v| tape.grad(*v).unwrap()).collect();
            opt.step(model.params_mut(), &grads).unwrap();
            losses.push(tape.value(loss).data()[0]);
        }
        let params = model.params_mut();
        let refs: Vec<&Tensor> = params.iter().map(|p| &**p).collect();
        (losses, repdl::coordinator::hash_params(&refs))
    };
    let (la, ha) = run();
    let (lb, hb) = run();
    assert_eq!(ha, hb, "transformer params diverged run-to-run");
    assert!(la.iter().zip(lb.iter()).all(|(x, y)| x.to_bits() == y.to_bits()));
}
