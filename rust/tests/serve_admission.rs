//! Conformance suite for the serve scheduler's admission + audit layer
//! (coordinator/serve/{scheduler,cache,log}.rs — DESIGN.md §8).
//!
//! The claim under test extends PR 3's "batch composition is a pure
//! function of the event sequence" to *every* observable serving
//! behaviour: which submits are **accepted vs rejected** (the queue-depth
//! cap counts tickets against the flush logical clock, never drain
//! progress), which bits come back (cache on or off, any shard/pool/
//! client configuration), and what the audit log records (`replay` must
//! verify every logged response bit-exactly by re-execution).

use repdl::coordinator::{
    hash_tensor, DeterministicServer, ServeConfig, ServeScheduler,
};
use repdl::rng::uniform_tensor;
use repdl::tensor::{matmul, Tensor, WorkerPool};
use repdl::Error;
use std::sync::Arc;

fn server(d_in: usize, d_out: usize, max_batch: usize, seed: u64) -> Arc<DeterministicServer> {
    let w = uniform_tensor(&[d_in, d_out], -0.3, 0.3, seed);
    Arc::new(DeterministicServer::new(w, max_batch).unwrap())
}

fn queue(n: usize, d: usize, seed: u64) -> Vec<Tensor> {
    (0..n)
        .map(|i| uniform_tensor(&[d], -1.0, 1.0, seed + i as u64))
        .collect()
}

/// The reference bits: one request at a time, straight through `matmul`.
fn reference(srv: &DeterministicServer, q: &[Tensor]) -> Vec<Tensor> {
    q.iter()
        .map(|r| matmul(&r.reshape(&[1, srv.d_in()]).unwrap(), &srv.weights).unwrap())
        .collect()
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn cfg(window: usize, depth: Option<usize>, cache: usize, log: bool) -> ServeConfig {
    ServeConfig {
        batch_window: window,
        max_queue_depth: depth,
        cache_capacity: cache,
        log,
        ..Default::default()
    }
}

/// THE acceptance grid: the single-threaded backpressure protocol's
/// accept/reject ticket sequence, rejection count, batch trace and every
/// response bit must be invariant across shards {1,2,4} × pool sizes ×
/// cache on/off — and `replay()` must verify the log bit-exactly in
/// every cell.
#[test]
fn accept_reject_set_and_bits_invariant_across_shards_pools_and_cache() {
    let srv = server(64, 8, 8, 3);
    let base = queue(18, 64, 500);
    // every request appears twice → the cache-on cells serve real hits
    let q: Vec<Tensor> = base.iter().chain(base.iter()).cloned().collect();
    let want = reference(&srv, &q);
    let depth = Some(7usize);
    let mut reference_rejections: Option<u64> = None;
    for shards in [1usize, 2, 4] {
        for lanes in [1usize, 3] {
            for cache in [0usize, 64] {
                let sched = ServeScheduler::sharded_with(
                    Arc::clone(&srv),
                    shards,
                    WorkerPool::shared(lanes),
                    cfg(4, depth, cache, true),
                )
                .unwrap();
                let (outs, rejections) =
                    sched.process_all_with_backpressure(&q).unwrap();
                for (i, (o, w)) in outs.iter().zip(want.iter()).enumerate() {
                    assert!(
                        bits_eq(o.data(), w.data()),
                        "request {i} bits changed at shards={shards} lanes={lanes} cache={cache}"
                    );
                }
                // the accepted ticket sequence is dense (rejection never
                // consumes a ticket): exactly one ticket per request
                let mut seen: Vec<u64> =
                    sched.trace().into_iter().flat_map(|b| b.tickets).collect();
                seen.sort_unstable();
                assert_eq!(
                    seen,
                    (0..q.len() as u64).collect::<Vec<u64>>(),
                    "shards={shards} lanes={lanes} cache={cache}"
                );
                // the rejection count — and with it the whole
                // accept/reject event sequence of the single-threaded
                // protocol — is a pure function of (len, depth):
                // identical in every cell of the grid
                match reference_rejections {
                    None => reference_rejections = Some(rejections),
                    Some(r0) => assert_eq!(
                        rejections, r0,
                        "accept/reject set changed at shards={shards} lanes={lanes} cache={cache}"
                    ),
                }
                assert!(rejections > 0, "depth 7 under 36 submits must reject");
                // the audit log replays bit-exactly in every cell
                let rep = sched.replay(0..q.len() as u64).unwrap();
                assert_eq!(rep.replayed, q.len());
                assert!(
                    rep.verified(),
                    "replay mismatch at shards={shards} lanes={lanes} cache={cache}: {rep:?}"
                );
            }
        }
    }
}

/// Same trace across cache on/off for a fixed shard count: the memo
/// must not move a single ticket or batch boundary.
#[test]
fn cache_on_off_share_tickets_batches_and_rejections() {
    let srv = server(32, 4, 8, 9);
    let base = queue(10, 32, 700);
    let q: Vec<Tensor> = base.iter().chain(base.iter()).cloned().collect();
    let run = |cache: usize| {
        let sched = ServeScheduler::sharded_with(
            Arc::clone(&srv),
            2,
            WorkerPool::shared(2),
            cfg(3, Some(6), cache, false),
        )
        .unwrap();
        let (outs, rej) = sched.process_all_with_backpressure(&q).unwrap();
        let trace: Vec<(usize, Vec<u64>)> =
            sched.trace().into_iter().map(|b| (b.shard, b.tickets)).collect();
        (outs, rej, trace)
    };
    let (o_off, rej_off, t_off) = run(0);
    let (o_on, rej_on, t_on) = run(64);
    assert_eq!(rej_off, rej_on);
    assert_eq!(t_off, t_on, "cache changed batch composition");
    for (a, b) in o_off.iter().zip(o_on.iter()) {
        assert!(a.bit_eq(b), "cache changed bits");
    }
}

/// Concurrent clients under a depth cap: every client flushes through
/// rejections, every request is answered with reference bits, the
/// accepted ticket sequence stays dense, and the log covers every
/// ticket. (The *assignment* of requests to tickets is whatever the OS
/// interleaving made it — the invariants are about the ticket set and
/// per-request bits, which may not care.)
#[test]
fn concurrent_clients_under_backpressure_keep_reference_bits() {
    let srv = server(48, 8, 8, 21);
    let q = queue(36, 48, 900);
    let want = reference(&srv, &q);
    for shards in [1usize, 2, 4] {
        for clients in [1usize, 2, 5] {
            let sched = ServeScheduler::sharded_with(
                Arc::clone(&srv),
                shards,
                WorkerPool::shared(2),
                cfg(4, Some(5), 32, true),
            )
            .unwrap();
            let ok = std::thread::scope(|s| {
                let handles: Vec<_> = (0..clients)
                    .map(|c| {
                        let (sched, q, want) = (&sched, &q, &want);
                        s.spawn(move || {
                            sched
                                .replay_slice(q, c, clients)
                                .unwrap()
                                .into_iter()
                                .all(|(i, o)| bits_eq(o.data(), want[i].data()))
                        })
                    })
                    .collect();
                handles.into_iter().all(|h| h.join().unwrap())
            });
            assert!(ok, "bits changed at shards={shards} clients={clients}");
            let mut seen: Vec<u64> =
                sched.trace().into_iter().flat_map(|b| b.tickets).collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..36u64).collect::<Vec<u64>>());
            let log = sched.log().unwrap();
            assert_eq!(log.len(), 36, "every answered ticket must be logged");
            let rep = sched.replay(0..36).unwrap();
            assert_eq!(rep.replayed, 36);
            assert!(rep.verified(), "shards={shards} clients={clients}: {rep:?}");
        }
    }
}

/// close() racing concurrent submitters: every submit either resolves
/// with correct bits or fails with the typed `Closed` error — never a
/// hang, never a dropped channel, never a stringly error.
#[test]
fn close_submit_race_is_typed_and_never_hangs() {
    for round in 0..8u64 {
        let srv = server(16, 4, 8, 40 + round);
        let q = queue(24, 16, 1000 + round);
        let want = reference(&srv, &q);
        let sched = Arc::new(
            ServeScheduler::sharded(Arc::clone(&srv), 2, 4, WorkerPool::shared(1)).unwrap(),
        );
        let outcome = std::thread::scope(|s| {
            let submitters: Vec<_> = (0..3usize)
                .map(|c| {
                    let (sched, q, want) = (Arc::clone(&sched), &q, &want);
                    s.spawn(move || {
                        let mut served = 0usize;
                        let mut closed = 0usize;
                        for i in (c..q.len()).step_by(3) {
                            match sched.submit(q[i].clone()) {
                                Ok(p) => {
                                    sched.flush();
                                    let o = p.wait().expect("accepted ⇒ answered");
                                    assert!(bits_eq(o.data(), want[i].data()));
                                    served += 1;
                                }
                                Err(Error::Closed) => closed += 1,
                                Err(e) => panic!("want Closed, got {e:?}"),
                            }
                        }
                        (served, closed)
                    })
                })
                .collect();
            // close somewhere in the middle of the submission storm
            let closer = s.spawn(|| sched.close());
            let mut served = 0;
            let mut closed = 0;
            for h in submitters {
                let (sv, cl) = h.join().unwrap();
                served += sv;
                closed += cl;
            }
            closer.join().unwrap();
            (served, closed)
        });
        assert_eq!(outcome.0 + outcome.1, 24, "round {round}: every submit resolved");
    }
}

/// The log's content addresses are honest: entries carry the hash of
/// exactly the logged request/response tensors, batch ids are the batch
/// head tickets from the trace, and a sub-range replay touches only its
/// slice.
#[test]
fn log_entries_match_trace_and_subrange_replay() {
    let srv = server(24, 4, 8, 5);
    let q = queue(11, 24, 80);
    let sched = ServeScheduler::sharded_with(
        Arc::clone(&srv),
        2,
        WorkerPool::shared(1),
        cfg(3, None, 0, true),
    )
    .unwrap();
    let outs = sched.process_all(&q).unwrap();
    let log = sched.log().unwrap();
    assert_eq!(log.len(), 11);
    // batch_id must be the first ticket of the trace batch containing
    // the entry's ticket
    for b in sched.trace() {
        for &t in &b.tickets {
            let e = log.get(t).unwrap();
            assert_eq!(e.batch_id, b.tickets[0], "ticket {t}");
        }
    }
    for (t, (r, o)) in q.iter().zip(outs.iter()).enumerate() {
        let e = log.get(t as u64).unwrap();
        assert_eq!(e.request_hash, hash_tensor(r));
        assert_eq!(e.response_hash, hash_tensor(o));
        assert!(e.request.bit_eq(r), "log must retain the exact request");
    }
    assert_eq!(sched.replay(4..9).unwrap().replayed, 5);
    assert!(sched.replay(0..11).unwrap().verified());
}

/// Eviction pressure: a cache smaller than the working set must still
/// serve bit-identical responses, and its occupancy obeys the
/// insertion-ticket rule (the held tickets are the largest inserted).
#[test]
fn tiny_cache_under_eviction_stays_bit_identical() {
    let srv = server(32, 4, 8, 13);
    let base = queue(12, 32, 300);
    let q: Vec<Tensor> = base.iter().chain(base.iter()).cloned().collect();
    let want = reference(&srv, &q);
    let sched = ServeScheduler::sharded_with(
        Arc::clone(&srv),
        1,
        WorkerPool::shared(1),
        cfg(4, None, 3, false),
    )
    .unwrap();
    let outs = sched.process_all(&q).unwrap();
    for (i, (o, w)) in outs.iter().zip(want.iter()).enumerate() {
        assert!(bits_eq(o.data(), w.data()), "request {i}");
    }
    let s = sched.cache_stats().unwrap();
    assert_eq!(s.len, 3, "capacity bound holds");
    assert!(s.evictions > 0, "working set 12 > capacity 3 must evict");
}
