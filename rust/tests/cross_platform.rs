//! E2 integration — cross-platform reproducibility over the simulated
//! platform zoo, plus pool-size invariance of the RepDL kernels.
//!
//! Thread counts are injected as explicit [`WorkerPool`]s: the seed
//! version mutated `REPDL_THREADS` mid-run, which races under the
//! parallel test harness (and is a no-op now that the env var is read
//! once at pool init).

use repdl::baseline::PlatformProfile;
use repdl::coordinator::{compare_runs, NumericsMode, Trainer, TrainerConfig};
use repdl::rng::uniform_tensor;
use repdl::tensor::{conv2d, matmul_in, Conv2dParams, WorkerPool};
use std::sync::Arc;

#[test]
fn baseline_training_diverges_across_simulated_platforms() {
    let cfg = TrainerConfig { steps: 20, ..Default::default() };
    let runs: Vec<_> = PlatformProfile::zoo()
        .iter()
        .map(|p| Trainer::new(cfg, NumericsMode::Baseline(*p)).run().unwrap())
        .collect();
    let mut divergent_pairs = 0;
    for r in &runs[1..] {
        let c = compare_runs(
            &runs[0].loss_curve,
            &r.loss_curve,
            &runs[0].param_hash,
            &r.param_hash,
        );
        if !c.hashes_equal {
            divergent_pairs += 1;
            assert!(c.first_divergence.is_some());
        }
    }
    assert!(divergent_pairs >= 3, "only {divergent_pairs} platforms diverged");
}

#[test]
fn repro_training_is_identical_regardless_of_pool_size() {
    let cfg = TrainerConfig { steps: 15, ..Default::default() };
    let a = Trainer::with_pool(cfg, NumericsMode::Repro, Arc::new(WorkerPool::new(1)))
        .run()
        .unwrap();
    let b = Trainer::with_pool(cfg, NumericsMode::Repro, Arc::new(WorkerPool::new(7)))
        .run()
        .unwrap();
    assert_eq!(a.param_hash, b.param_hash);
}

#[test]
fn kernels_pool_invariance_property() {
    // property-style sweep over shapes with the mini harness
    let one = WorkerPool::new(1);
    let five = WorkerPool::new(5);
    repdl::proptest::forall(
        9,
        12,
        |g| {
            (
                1 + g.below(24),
                1 + g.below(48),
                1 + g.below(24),
                g.u64(),
            )
        },
        |&(m, k, n, seed)| {
            let a = uniform_tensor(&[m, k], -2.0, 2.0, seed);
            let b = uniform_tensor(&[k, n], -2.0, 2.0, seed ^ 1);
            matmul_in(&one, &a, &b)
                .unwrap()
                .bit_eq(&matmul_in(&five, &a, &b).unwrap())
        },
    );
}

#[test]
fn conv_direct_and_im2col_agree_across_shapes() {
    repdl::proptest::forall(
        11,
        8,
        |g| (1 + g.below(2), 1 + g.below(3), 5 + g.below(5), g.u64()),
        |&(b, c, hw, seed)| {
            let x = uniform_tensor(&[b, c, hw, hw], -1.0, 1.0, seed);
            let w = uniform_tensor(&[2, c, 3, 3], -1.0, 1.0, seed ^ 2);
            let p = Conv2dParams { stride: 1, padding: 1 };
            let d = conv2d(&x, &w, None, p).unwrap();
            let g2 = repdl::tensor::conv2d_im2col(&x, &w, None, p).unwrap();
            d.bit_eq(&g2)
        },
    );
}
