//! KV-session conformance suite (DESIGN.md §10).
//!
//! The tentpole claim: KV-cached incremental decode through the serve
//! stack is **bit-identical** to the full recompute it replaces, for
//! every prefix of every stream, across pool sizes, shard counts and
//! session-store capacities — including capacity 1, where interleaved
//! streams evict each other every round and force the mid-stream
//! fallback-and-rebuild path. Sessions may change serving *cost*, never
//! bits.
//!
//! Also here: the serve-path panic shield — a tower panic inside one
//! dispatcher must yield typed errors for that batch and leave the
//! scheduler fully serviceable for every later submit (no poisoned
//! locks, no wedged dispatcher).

use repdl::coordinator::{ModelTower, ServeConfig, ServeScheduler, TransformerTower};
use repdl::nn::{CharTransformer, TransformerConfig};
use repdl::tensor::{Tensor, WorkerPool};
use repdl::Result;
use std::sync::Arc;

const VOCAB: usize = 12;
const CONTEXT: usize = 6;
const STREAMS: [[usize; CONTEXT]; 3] =
    [[1, 4, 2, 9, 3, 7], [5, 0, 11, 8, 2, 1], [7, 7, 1, 3, 10, 4]];

fn model() -> CharTransformer {
    let cfg = TransformerConfig {
        vocab: VOCAB,
        dim: 8,
        heads: 2,
        layers: 2,
        context: CONTEXT,
        mlp_ratio: 2,
    };
    CharTransformer::new(cfg, 21).unwrap()
}

fn prefix_request(stream: &[usize; CONTEXT], tt: usize) -> Tensor {
    Tensor::from_vec(&[tt], stream[..tt].iter().map(|&i| i as f32).collect()).unwrap()
}

#[test]
fn incremental_decode_is_bit_identical_to_full_recompute_everywhere() {
    let reference = model();
    let ref_pool = WorkerPool::new(1);
    let n = (CONTEXT * STREAMS.len()) as u64;
    for lanes in [1usize, 2, 8] {
        for shards in [1usize, 2] {
            // capacity 1 thrashes: three interleaved streams over one
            // slot evict each other every round, so prefixes routinely
            // arrive after their session is gone and must rebuild
            for capacity in [1usize, 64] {
                let tower = Arc::new(
                    TransformerTower::new(model()).unwrap().with_sessions(capacity),
                );
                let sched = ServeScheduler::sharded_with(
                    Arc::clone(&tower) as Arc<dyn ModelTower>,
                    shards,
                    WorkerPool::shared(lanes),
                    ServeConfig { batch_window: 4, log: true, ..Default::default() },
                )
                .unwrap();
                // interleave the streams by prefix length, the decode
                // pattern a multi-client server actually sees
                let mut pending = Vec::new();
                let mut meta = Vec::new();
                for tt in 1..=CONTEXT {
                    for s in &STREAMS {
                        pending.push(sched.submit(prefix_request(s, tt)).unwrap());
                        meta.push((s, tt));
                    }
                }
                sched.flush();
                for (p, (s, tt)) in pending.into_iter().zip(meta) {
                    let got = p.wait().unwrap();
                    let want = reference.forward_logits_infer_in(&ref_pool, &s[..tt]).unwrap();
                    assert_eq!(
                        got.data(),
                        &want.data()[(tt - 1) * VOCAB..tt * VOCAB],
                        "lanes={lanes} shards={shards} capacity={capacity} \
                         stream={s:?} len={tt}: session serving changed bits"
                    );
                }
                let stats = sched.session_stats().unwrap();
                if capacity == 1 {
                    // the forced-eviction cells: fallbacks really happened
                    assert!(
                        stats.evictions > 0 && stats.misses > 0,
                        "capacity 1 must thrash: {stats:?}"
                    );
                    assert_eq!(stats.len, 1, "{stats:?}");
                } else if shards == 1 {
                    // one dispatcher executes in ticket order, so every
                    // length-(t−1) insert lands before the length-t
                    // lookup: all 15 extension lookups hit (counters are
                    // only timing-stable with a single dispatcher)
                    assert_eq!(stats.hits, ((CONTEXT - 1) * STREAMS.len()) as u64, "{stats:?}");
                    assert_eq!(stats.misses, 0, "{stats:?}");
                }
                // replay audits every logged response against the
                // NON-ticketed full recompute, bit for bit — the
                // fallback contract, checked from the log side
                let rep = sched.replay(0..n).unwrap();
                assert_eq!(rep.replayed, n as usize);
                assert!(
                    rep.verified(),
                    "lanes={lanes} shards={shards} capacity={capacity}: {rep:?}"
                );
            }
        }
    }
}

#[test]
fn sessions_off_towers_report_no_stats() {
    let tower = Arc::new(TransformerTower::new(model()).unwrap());
    let sched = ServeScheduler::sharded(
        Arc::clone(&tower) as Arc<dyn ModelTower>,
        1,
        4,
        WorkerPool::shared(1),
    )
    .unwrap();
    assert!(sched.session_stats().is_none());
    // and with_sessions(0) means "off" too
    let off = TransformerTower::new(model()).unwrap().with_sessions(0);
    assert!(off.session_stats().is_none());
}

/// A tower that panics on a magic request — stands in for any latent
/// bug reached inside a dispatcher thread.
struct PanicTower {
    hash: String,
}

const MAGIC: f32 = 13.0;

impl ModelTower for PanicTower {
    fn model_id(&self) -> &str {
        "panic-tower"
    }
    fn d_in(&self) -> usize {
        4
    }
    fn d_out(&self) -> usize {
        4
    }
    fn weights_hash(&self) -> &str {
        &self.hash
    }
    fn forward_batch(&self, _pool: &WorkerPool, batch: &[Tensor]) -> Result<Vec<Tensor>> {
        batch
            .iter()
            .map(|r| {
                if r.data()[0] == MAGIC {
                    panic!("injected tower bug");
                }
                Ok(r.clone())
            })
            .collect()
    }
}

fn req(lead: f32) -> Tensor {
    Tensor::from_vec(&[4], vec![lead, 1.0, 2.0, 3.0]).unwrap()
}

#[test]
fn a_tower_panic_is_a_typed_error_and_never_wedges_the_scheduler() {
    let tower: Arc<dyn ModelTower> = Arc::new(PanicTower { hash: "panic-hash".into() });
    // window 1: the magic request is a singleton batch, so its panic
    // can only hurt itself
    let sched = ServeScheduler::sharded(Arc::clone(&tower), 1, 1, WorkerPool::shared(1)).unwrap();
    let before = sched.submit(req(0.0)).unwrap();
    let boom = sched.submit(req(MAGIC)).unwrap();
    let after = sched.submit(req(1.0)).unwrap();
    sched.flush();
    assert!(before.wait().unwrap().bit_eq(&req(0.0)));
    let e = boom.wait().unwrap_err();
    assert!(
        format!("{e}").contains("panicked"),
        "want the typed panic-shield error, got: {e}"
    );
    assert!(after.wait().unwrap().bit_eq(&req(1.0)), "dispatcher must survive the panic");
    // the scheduler stays fully serviceable from another thread — a
    // poisoned queue lock or dead dispatcher would hang or panic here
    std::thread::scope(|scope| {
        scope
            .spawn(|| {
                let p = sched.submit(req(2.0)).unwrap();
                sched.flush();
                assert!(p.wait().unwrap().bit_eq(&req(2.0)));
            })
            .join()
            .unwrap();
    });
}

#[test]
fn a_shared_batch_panic_fails_the_whole_batch_with_one_typed_cause() {
    let tower: Arc<dyn ModelTower> = Arc::new(PanicTower { hash: "panic-hash".into() });
    // window 4: the magic request shares its batch with an innocent one
    let sched = ServeScheduler::sharded(Arc::clone(&tower), 1, 4, WorkerPool::shared(1)).unwrap();
    let a = sched.submit(req(5.0)).unwrap();
    let b = sched.submit(req(MAGIC)).unwrap();
    sched.flush();
    for p in [a, b] {
        let e = p.wait().unwrap_err();
        assert!(format!("{e}").contains("panicked"), "got: {e}");
    }
    // and the next batch is served normally
    let p = sched.submit(req(6.0)).unwrap();
    sched.flush();
    assert!(p.wait().unwrap().bit_eq(&req(6.0)));
}

#[test]
fn a_poisoned_session_store_keeps_serving_exact_bits_from_other_threads() {
    use repdl::coordinator::PanicAtTicket;
    // a session-holding tower whose ticketed dispatch panics at ticket 1
    // — the deterministic stand-in for a latent bug inside a session
    // dispatch (the panic shield turns it into a typed batch error)
    let tower = Arc::new(PanicAtTicket::new(
        TransformerTower::new(model()).unwrap().with_sessions(8),
        1,
    ));
    let sched = ServeScheduler::sharded_with(
        Arc::clone(&tower) as Arc<dyn ModelTower>,
        1,
        WorkerPool::shared(1),
        ServeConfig { batch_window: 2, ..Default::default() },
    )
    .unwrap();
    // tickets 0 and 1 share a window-2 batch: the injected panic inside
    // the session dispatch fails both with the typed shield error
    let p0 = sched.submit(prefix_request(&STREAMS[0], 1)).unwrap();
    let p1 = sched.submit(prefix_request(&STREAMS[1], 1)).unwrap();
    sched.flush();
    for p in [p0, p1] {
        let e = p.wait().unwrap_err();
        assert!(format!("{e}").contains("panicked"), "want the shield error, got: {e}");
    }
    // now poison the SessionStore's internal lock FOR REAL: a thread
    // panics while holding it (std marks the mutex poisoned on unwind)
    let store = tower.inner().sessions_for_test().expect("sessions enabled");
    let poisoned = std::thread::scope(|s| s.spawn(|| store.poison_for_test()).join());
    assert!(poisoned.is_err(), "the poisoning thread must have panicked");
    assert_eq!(store.stats().hits, 0, "nothing served yet: counters start clean");
    // from ANOTHER thread, the whole decode stream must still serve:
    // lock_recover hands out the (update-atomic) poisoned store, session
    // hits and misses keep counting, and the bits stay the reference
    // bits for every prefix length
    let reference = model();
    let ref_pool = WorkerPool::new(1);
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut pending = Vec::new();
            for tt in 1..=CONTEXT {
                pending.push((tt, sched.submit(prefix_request(&STREAMS[2], tt)).unwrap()));
            }
            sched.flush();
            for (tt, p) in pending {
                let got = p.wait().unwrap();
                let want =
                    reference.forward_logits_infer_in(&ref_pool, &STREAMS[2][..tt]).unwrap();
                assert_eq!(
                    got.data(),
                    &want.data()[(tt - 1) * VOCAB..tt * VOCAB],
                    "poisoned-store serving changed bits at prefix length {tt}"
                );
            }
        })
        .join()
        .unwrap();
    });
    // single dispatcher ⇒ counters are event-sequence-pure: the length-1
    // prefix does no lookup, every extension hits the session inserted
    // one ticket earlier, and all six sessions land — hits, misses and
    // inserts all counted through the poisoned lock
    let stats = store.stats();
    assert_eq!(stats.misses, 0, "{stats:?}");
    assert_eq!(stats.hits, (CONTEXT - 1) as u64, "{stats:?}");
    assert_eq!(stats.len, CONTEXT, "{stats:?}");
}
