//! Hand-rolled measurement harness (criterion is not in the offline
//! crate set — DESIGN.md §5): warmup + N samples, median / MAD / min,
//! throughput helpers, and stable aligned text output shared by every
//! `benches/e*.rs` target.

use std::time::Instant;

/// One measured statistic set (nanoseconds).
#[derive(Clone, Debug)]
pub struct Stats {
    /// Median of samples.
    pub median_ns: f64,
    /// Minimum sample.
    pub min_ns: f64,
    /// Median absolute deviation.
    pub mad_ns: f64,
    /// Samples taken.
    pub samples: usize,
}

impl Stats {
    /// Throughput implied by the median sample: `items` processed per
    /// median period, in items/second.
    pub fn per_sec(&self, items: usize) -> f64 {
        items as f64 * 1e9 / self.median_ns
    }

    /// ns → human string.
    pub fn human(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

/// Measure `f`, autoscaling iterations so each sample is ≳2 ms.
pub fn bench<R>(name: &str, samples: usize, mut f: impl FnMut() -> R) -> Stats {
    // warmup + iteration scaling
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((2e6 / one).ceil() as usize).clamp(1, 1_000_000);
    let mut xs = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        xs.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = xs[xs.len() / 2];
    let min = xs[0];
    let mut devs: Vec<f64> = xs.iter().map(|x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    let s = Stats { median_ns: median, min_ns: min, mad_ns: mad, samples };
    println!(
        "{name:<46} {:>12} ± {:<10} (min {})",
        Stats::human(s.median_ns),
        Stats::human(s.mad_ns),
        Stats::human(s.min_ns)
    );
    s
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print an aligned key/value row (for non-timing results).
pub fn row(key: &str, value: impl std::fmt::Display) {
    println!("{key:<46} {value}");
}

/// Print a throughput row: `items` per median period as items/second
/// (used by the serve benchmarks to report req/s).
pub fn row_rate(key: &str, stats: &Stats, items: usize, unit: &str) {
    println!("{key:<46} {:>12.0} {unit}/s", stats.per_sec(items));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let s = bench("noop-ish", 3, || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
    }

    #[test]
    fn per_sec_inverts_median() {
        let s = Stats { median_ns: 2e9, min_ns: 1e9, mad_ns: 0.0, samples: 1 };
        // 100 items every 2 seconds = 50 items/s
        assert!((s.per_sec(100) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn human_formatting() {
        assert_eq!(Stats::human(500.0), "500 ns");
        assert_eq!(Stats::human(1500.0), "1.50 µs");
        assert_eq!(Stats::human(2.5e6), "2.50 ms");
        assert_eq!(Stats::human(3.21e9), "3.210 s");
    }
}
