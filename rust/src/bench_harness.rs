//! Hand-rolled measurement harness (criterion is not in the offline
//! crate set — DESIGN.md §5): warmup + N samples, median / MAD / min,
//! throughput helpers, stable aligned text output shared by every
//! `benches/e*.rs` target, a machine-readable `BENCH_*.json` emitter
//! (the repo's perf trajectory) and an allocation-counting global
//! allocator shim for allocations-per-call metrics.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// One measured statistic set (nanoseconds).
#[derive(Clone, Debug)]
pub struct Stats {
    /// Median of samples.
    pub median_ns: f64,
    /// Minimum sample.
    pub min_ns: f64,
    /// Median absolute deviation.
    pub mad_ns: f64,
    /// Samples taken.
    pub samples: usize,
}

impl Stats {
    /// Throughput implied by the median sample: `items` processed per
    /// median period, in items/second.
    pub fn per_sec(&self, items: usize) -> f64 {
        items as f64 * 1e9 / self.median_ns
    }

    /// ns → human string.
    pub fn human(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

/// Shared stats tail: sort the samples, derive median/min/MAD, print
/// the aligned result row (one format for every measurement helper).
fn summarize(name: &str, mut xs: Vec<f64>, samples: usize) -> Stats {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = xs[xs.len() / 2];
    let min = xs[0];
    let mut devs: Vec<f64> = xs.iter().map(|x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    let s = Stats { median_ns: median, min_ns: min, mad_ns: mad, samples };
    println!(
        "{name:<46} {:>12} ± {:<10} (min {})",
        Stats::human(s.median_ns),
        Stats::human(s.mad_ns),
        Stats::human(s.min_ns)
    );
    s
}

/// Measure `f`, autoscaling iterations so each sample is ≳2 ms.
pub fn bench<R>(name: &str, samples: usize, mut f: impl FnMut() -> R) -> Stats {
    // warmup + iteration scaling
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((2e6 / one).ceil() as usize).clamp(1, 1_000_000);
    let mut xs = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        xs.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    summarize(name, xs, samples)
}

/// Measure a concurrent workload: each sample wall-clocks `threads`
/// scoped client threads all running `f(thread_index)` to completion
/// (no iteration autoscaling — one sample is one full multi-client
/// replay, the unit the serve-scheduler benchmarks care about).
pub fn bench_threads(
    name: &str,
    samples: usize,
    threads: usize,
    f: impl Fn(usize) + Sync,
) -> Stats {
    let threads = threads.max(1);
    let mut xs = Vec::with_capacity(samples.max(1));
    for _ in 0..samples.max(1) {
        let t = Instant::now();
        std::thread::scope(|s| {
            for tid in 0..threads {
                let f = &f;
                s.spawn(move || f(tid));
            }
        });
        xs.push(t.elapsed().as_nanos().max(1) as f64);
    }
    summarize(name, xs, samples.max(1))
}

/// Measure a **stateful** workload: one sample = exactly one call of
/// `f`, no warmup call and no iteration autoscaling. Use this when the
/// workload mutates shared state the measurement cares about (a memo
/// cache warming up, an admission gate accumulating rejections) —
/// [`bench`]'s hidden warmup + inner iteration loop would silently run
/// the workload extra times and distort those counters.
pub fn bench_once<R>(name: &str, samples: usize, mut f: impl FnMut() -> R) -> Stats {
    let samples = samples.max(1);
    let mut xs = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        std::hint::black_box(f());
        xs.push(t.elapsed().as_nanos().max(1) as f64);
    }
    summarize(name, xs, samples)
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print an aligned key/value row (for non-timing results).
pub fn row(key: &str, value: impl std::fmt::Display) {
    println!("{key:<46} {value}");
}

/// Print a throughput row: `items` per median period as items/second
/// (used by the serve benchmarks to report req/s).
pub fn row_rate(key: &str, stats: &Stats, items: usize, unit: &str) {
    println!("{key:<46} {:>12.0} {unit}/s", stats.per_sec(items));
}

// ---------------------------------------------------------------------
// Allocation counting
// ---------------------------------------------------------------------

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

/// Global-allocator shim that counts heap acquisitions (alloc +
/// grow-reallocs) process-wide. Install it in a bench binary with
/// `#[global_allocator] static A: CountingAllocator = CountingAllocator;`
/// and read deltas via [`allocs_during`]. Without installation the
/// counter simply stays at zero.
pub struct CountingAllocator;

// SAFETY: pure pass-through to `System` plus a relaxed counter bump —
// no additional aliasing or layout assumptions.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        // delegate so `vec![0.0; n]` keeps the calloc zero-page path
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Heap allocations performed process-wide (all threads, including pool
/// workers) during `f`. Zero when [`CountingAllocator`] is not the
/// installed global allocator.
pub fn allocs_during<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = ALLOC_COUNT.load(Ordering::Relaxed);
    let r = f();
    (ALLOC_COUNT.load(Ordering::Relaxed) - before, r)
}

// ---------------------------------------------------------------------
// Machine-readable perf trajectory (BENCH_*.json)
// ---------------------------------------------------------------------

/// One flat JSON object, hand-rolled (no serde in the offline crate
/// set). Field order is insertion order; values are JSON-escaped /
/// finite-checked.
#[derive(Clone, Debug, Default)]
pub struct JsonObj {
    parts: Vec<String>,
}

impl JsonObj {
    /// Empty object.
    pub fn new() -> Self {
        JsonObj { parts: Vec::new() }
    }

    /// Add a string field.
    pub fn s(mut self, key: &str, v: &str) -> Self {
        let escaped: String = v
            .chars()
            .flat_map(|c| match c {
                '"' => vec!['\\', '"'],
                '\\' => vec!['\\', '\\'],
                c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
                c => vec![c],
            })
            .collect();
        self.parts.push(format!("\"{key}\":\"{escaped}\""));
        self
    }

    /// Add a float field (non-finite values serialise as `null`).
    pub fn num(mut self, key: &str, v: f64) -> Self {
        let rendered = if v.is_finite() { format!("{v:.6}") } else { "null".to_string() };
        self.parts.push(format!("\"{key}\":{rendered}"));
        self
    }

    /// Add an integer field.
    pub fn int(mut self, key: &str, v: u64) -> Self {
        self.parts.push(format!("\"{key}\":{v}"));
        self
    }

    /// Render as a JSON object.
    pub fn build(&self) -> String {
        format!("{{{}}}", self.parts.join(","))
    }
}

/// Write one perf-trajectory file:
/// `{"bench": <name>, "entries": [<entry objects>]}` — consumed by CI
/// (uploaded as an artifact) and by trend tooling; committed snapshots
/// live at the repository root as `BENCH_<name>.json`.
pub fn write_bench_json(path: &str, name: &str, entries: &[JsonObj]) -> std::io::Result<()> {
    let body: Vec<String> = entries.iter().map(|e| format!("    {}", e.build())).collect();
    let doc = format!(
        "{{\n  \"bench\": \"{name}\",\n  \"entries\": [\n{}\n  ]\n}}\n",
        body.join(",\n")
    );
    std::fs::write(path, doc)
}

/// Resolve where a `BENCH_<name>.json` should land: the repository root
/// when the bench runs from `rust/` (the normal cargo working dir),
/// else the current directory.
pub fn bench_json_path(name: &str) -> String {
    if std::path::Path::new("../ROADMAP.md").exists() {
        format!("../BENCH_{name}.json")
    } else {
        format!("BENCH_{name}.json")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let s = bench("noop-ish", 3, || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
    }

    #[test]
    fn per_sec_inverts_median() {
        let s = Stats { median_ns: 2e9, min_ns: 1e9, mad_ns: 0.0, samples: 1 };
        // 100 items every 2 seconds = 50 items/s
        assert!((s.per_sec(100) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn human_formatting() {
        assert_eq!(Stats::human(500.0), "500 ns");
        assert_eq!(Stats::human(1500.0), "1.50 µs");
        assert_eq!(Stats::human(2.5e6), "2.50 ms");
        assert_eq!(Stats::human(3.21e9), "3.210 s");
    }

    #[test]
    fn json_obj_renders_flat_objects() {
        let o = JsonObj::new()
            .s("kernel", "packed")
            .int("m", 512)
            .num("gflops", 12.5)
            .num("bad", f64::NAN);
        assert_eq!(
            o.build(),
            "{\"kernel\":\"packed\",\"m\":512,\"gflops\":12.500000,\"bad\":null}"
        );
        let esc = JsonObj::new().s("k", "a\"b\\c\n");
        assert_eq!(esc.build(), "{\"k\":\"a\\\"b\\\\c\\u000a\"}");
    }

    #[test]
    fn bench_json_document_shape() {
        let entries = [JsonObj::new().s("kernel", "a").int("n", 1)];
        let tmp = std::env::temp_dir().join("repdl_bench_json_test.json");
        let path = tmp.to_str().unwrap();
        write_bench_json(path, "gemm", &entries).unwrap();
        let doc = std::fs::read_to_string(path).unwrap();
        std::fs::remove_file(path).ok();
        assert!(doc.contains("\"bench\": \"gemm\""));
        assert!(doc.contains("{\"kernel\":\"a\",\"n\":1}"));
    }

    #[test]
    fn bench_once_calls_exactly_samples_times() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = AtomicUsize::new(0);
        let st = bench_once("bench_once smoke", 3, || {
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 3, "no hidden warmup or autoscaling");
        assert!(st.median_ns > 0.0);
    }

    #[test]
    fn bench_threads_runs_every_client() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        let st = bench_threads("bench_threads smoke", 2, 4, |tid| {
            hits[tid].fetch_add(1, Ordering::Relaxed);
        });
        assert!(st.median_ns > 0.0);
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 2); // once per sample
        }
    }

    #[test]
    fn allocs_during_returns_result_and_count() {
        // the test harness does not install CountingAllocator, so the
        // count is 0 here — the API must still pass the value through
        let (n, v) = allocs_during(|| vec![1u8; 32].len());
        assert_eq!(v, 32);
        let _ = n; // counter only advances under #[global_allocator]
    }
}
