//! Conventional (order-unstable) kernels, parameterised by platform.

use super::PlatformProfile;
#[cfg(test)]
use super::MathImpl;
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// SIMD-style chunked sum: accumulate into `width` lanes (lane = i mod
/// width), then combine lanes sequentially. width=1 is plain sequential.
pub fn baseline_sum(xs: &[f32], width: usize) -> f32 {
    let width = width.max(1);
    if width == 1 {
        let mut acc = 0.0f32;
        for &x in xs {
            acc += x;
        }
        return acc;
    }
    let mut lanes = vec![0.0f32; width];
    for (i, &x) in xs.iter().enumerate() {
        lanes[i % width] += x;
    }
    let mut acc = 0.0f32;
    for l in lanes {
        acc += l;
    }
    acc
}

/// Chunked dot with optional FMA contraction.
pub fn baseline_dot(a: &[f32], b: &[f32], width: usize, fma: bool) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let width = width.max(1);
    let mut lanes = vec![0.0f32; width];
    for i in 0..a.len() {
        let l = i % width;
        if fma {
            lanes[l] = a[i].mul_add(b[i], lanes[l]);
        } else {
            lanes[l] += a[i] * b[i];
        }
    }
    let mut acc = 0.0f32;
    for l in lanes {
        acc += l;
    }
    acc
}

/// The dispatch rule a size-dispatching platform uses: bigger problems
/// get wider kernels (like oneDNN/cuDNN picking implementations by
/// shape — the paper's "dynamic code paths" and "dynamic batching").
fn effective_width(p: &PlatformProfile, rows: usize) -> usize {
    if p.size_dispatch {
        if rows >= 32 {
            p.simd_width * 2
        } else if rows >= 8 {
            p.simd_width
        } else {
            (p.simd_width / 2).max(1)
        }
    } else {
        p.simd_width
    }
}

/// Conventional GEMM under a platform profile. The reduction width (and
/// hence bits) depends on the platform — and, with `size_dispatch`, on
/// the *batch size*, which is exactly the E7 hazard.
pub fn baseline_matmul(a: &Tensor, b: &Tensor, p: &PlatformProfile) -> Result<Tensor> {
    let (da, db) = (a.dims(), b.dims());
    if da.len() != 2 || db.len() != 2 || da[1] != db[0] {
        return Err(Error::shape(format!("baseline_matmul: {da:?} x {db:?}")));
    }
    let (m, k, n) = (da[0], da[1], db[1]);
    let width = effective_width(p, m);
    let bt = b.transpose2d()?;
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            out.data_mut()[i * n + j] = baseline_dot(
                &a.data()[i * k..(i + 1) * k],
                &bt.data()[j * k..(j + 1) * k],
                width,
                p.fma,
            );
        }
    }
    Ok(out)
}

/// Conventional softmax: uses the platform's math library and chunked
/// sums (contrast with `nn::softmax_rows`).
pub fn baseline_softmax_rows(x: &Tensor, p: &PlatformProfile) -> Result<Tensor> {
    let d = x.dims();
    if d.len() != 2 {
        return Err(Error::shape("baseline_softmax_rows: want rank 2"));
    }
    if d[1] == 0 {
        // same degenerate-shape policy as nn::softmax_rows: a row of no
        // logits is a shape error, not a w[0] panic
        return Err(Error::shape(format!(
            "baseline_softmax_rows: zero-length rows in {d:?}"
        )));
    }
    let (rows, c) = (d[0], d[1]);
    let width = effective_width(p, rows);
    let mut out = Tensor::zeros(d);
    for r in 0..rows {
        let w = x.row(r);
        // INTENTIONALLY the old plain `v > m` scan (NaN never wins): this
        // models the conventional, non-reproducible stack and is exempt
        // from the NaN-rule unification migration (DESIGN.md §8) — do NOT
        // route it through `tensor::reduce::max_wins`.
        let mut m = w[0];
        for &v in &w[1..] {
            if v > m {
                m = v;
            }
        }
        let mut es = vec![0.0f32; c];
        for j in 0..c {
            es[j] = super::exp_variant(w[j] - m, p.mathlib);
        }
        let denom = baseline_sum(&es, width);
        for j in 0..c {
            out.data_mut()[r * c + j] = es[j] / denom;
        }
    }
    Ok(out)
}

/// exp under the platform's libm (convenience).
pub fn baseline_exp(x: f32, p: &PlatformProfile) -> f32 {
    super::exp_variant(x, p.mathlib)
}

/// log under the platform's libm (convenience).
pub fn baseline_log(x: f32, p: &PlatformProfile) -> f32 {
    super::log_variant(x, p.mathlib)
}

static ATOMIC_EPOCH: AtomicU64 = AtomicU64::new(0x1234_5678);

/// Simulated atomic-add reduction (§2.2.2): the summation order is a
/// pseudo-random permutation seeded from a *process-global counter*, so
/// two calls on the same data generally reduce in different orders —
/// run-to-run non-determinism, exactly like GPU atomics.
pub fn atomic_sum(xs: &[f32]) -> f32 {
    let seed = ATOMIC_EPOCH.fetch_add(0x9e37_79b9, Ordering::Relaxed);
    let mut order: Vec<usize> = (0..xs.len()).collect();
    // cheap seeded shuffle
    let mut s = seed;
    for i in (1..order.len()).rev() {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let j = ((s >> 33) as usize) % (i + 1);
        order.swap(i, j);
    }
    let mut acc = 0.0f32;
    for i in order {
        acc += xs[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 100.0
            })
            .collect()
    }

    #[test]
    fn widths_change_bits_but_not_value_much() {
        let xs = lcg_vec(10_000, 1);
        let w1 = baseline_sum(&xs, 1);
        let w4 = baseline_sum(&xs, 4);
        let w8 = baseline_sum(&xs, 8);
        assert!((w1 - w4).abs() < 1.0);
        assert!((w1 - w8).abs() < 1.0);
        // at least one pair differs in bits (overwhelmingly likely)
        assert!(
            w1.to_bits() != w4.to_bits() || w4.to_bits() != w8.to_bits(),
            "chunked sums all identical?"
        );
    }

    #[test]
    fn profiles_give_divergent_matmuls() {
        let a = Tensor::from_vec(&[16, 64], lcg_vec(1024, 2)).unwrap();
        let b = Tensor::from_vec(&[64, 16], lcg_vec(1024, 3)).unwrap();
        let outs: Vec<Tensor> = PlatformProfile::zoo()
            .iter()
            .map(|p| baseline_matmul(&a, &b, p).unwrap())
            .collect();
        let mut any_diff = false;
        for o in &outs[1..] {
            any_diff |= !o.bit_eq(&outs[0]);
        }
        assert!(any_diff, "all simulated platforms agreed bitwise");
        // but numerically close
        for o in &outs[1..] {
            for (x, y) in o.data().iter().zip(outs[0].data()) {
                assert!((x - y).abs() < 0.2 * (1.0 + y.abs()));
            }
        }
    }

    #[test]
    fn size_dispatch_changes_bits_with_batch_size() {
        // same row computed under different batch sizes diverges on a
        // size-dispatching platform
        let p = PlatformProfile { name: "t", simd_width: 8, fma: true, mathlib: MathImpl::IntelLike, size_dispatch: true };
        let k = 256;
        let row = lcg_vec(k, 5);
        let w = Tensor::from_vec(&[k, 4], lcg_vec(k * 4, 6)).unwrap();
        let small = Tensor::from_vec(&[1, k], row.clone()).unwrap();
        let mut big_data = row.clone();
        for i in 1..64 {
            big_data.extend(lcg_vec(k, 100 + i));
        }
        let big = Tensor::from_vec(&[64, k], big_data).unwrap();
        let o_small = baseline_matmul(&small, &w, &p).unwrap();
        let o_big = baseline_matmul(&big, &w, &p).unwrap();
        let diverged = (0..4).any(|j| o_small.data()[j].to_bits() != o_big.data()[j].to_bits());
        assert!(diverged, "batch size did not affect per-request bits");
    }

    #[test]
    fn atomic_sum_is_nondeterministic_run_to_run() {
        let xs = lcg_vec(5000, 7);
        let a = atomic_sum(&xs);
        let mut diverged = false;
        for _ in 0..10 {
            if atomic_sum(&xs).to_bits() != a.to_bits() {
                diverged = true;
                break;
            }
        }
        assert!(diverged, "simulated atomics were accidentally deterministic");
        // value still close
        assert!((atomic_sum(&xs) - a).abs() < 1.0);
    }
}
