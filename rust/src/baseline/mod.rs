//! The **non-reproducible control group**: conventional implementations
//! parameterised by a simulated [`PlatformProfile`].
//!
//! The paper's §2.2 taxonomy says cross-platform numerical divergence has
//! exactly two mechanisms — (1) precision differences in basic ops and
//! (2) computation-order differences — plus run-to-run non-determinism
//! from scheduling (atomics, dynamic code paths, dynamic batching). This
//! module reproduces each mechanism in controlled form (we have one CPU,
//! not the paper's CPU/GPU zoo — see DESIGN.md §5):
//!
//! * **SIMD-width reduction chunking** — `sum`/`dot` accumulate into
//!   `simd_width` lanes then combine, exactly how vectorised BLAS
//!   reductions reassociate. Different widths ⇒ different bits.
//! * **FMA contraction** — on/off per profile (the compiler/ISA switch).
//! * **Math-library variant** — two polynomial `exp`/`log`
//!   implementations standing in for glibc vs Intel Math (§2.2.1's
//!   motivating example), each ≤ ~2 ulp but *different*.
//! * **Batch-size-dependent kernel dispatch** — like cuDNN/oneDNN, the
//!   baseline GEMM picks its reduction width from the problem size, the
//!   §2.2.2 "dynamic batching / dynamic code paths" hazard.
//! * **Simulated atomics** — [`atomic_sum`] reduces in an
//!   arrival order drawn from a process-global counter-seeded RNG:
//!   deterministic nowhere, like a GPU atomic-add race.

pub mod mathlib;
pub mod ops;

pub use mathlib::{exp_variant, log_variant, MathImpl};
pub use ops::{atomic_sum, baseline_dot, baseline_matmul, baseline_softmax_rows, baseline_sum};

/// A simulated execution platform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlatformProfile {
    /// Display name.
    pub name: &'static str,
    /// Reduction lane count (SIMD width the BLAS was compiled for).
    pub simd_width: usize,
    /// Whether mul+add contract to FMA.
    pub fma: bool,
    /// Which math library the platform links.
    pub mathlib: MathImpl,
    /// Kernel dispatch: if true, reduction width also depends on the
    /// problem size (dynamic code path).
    pub size_dispatch: bool,
}

impl PlatformProfile {
    /// The six simulated platforms used across E2/E5/E7.
    pub fn zoo() -> Vec<PlatformProfile> {
        vec![
            PlatformProfile { name: "cpu-scalar-glibc", simd_width: 1, fma: false, mathlib: MathImpl::GlibcLike, size_dispatch: false },
            PlatformProfile { name: "cpu-sse-glibc", simd_width: 4, fma: false, mathlib: MathImpl::GlibcLike, size_dispatch: false },
            PlatformProfile { name: "cpu-avx2-intel", simd_width: 8, fma: true, mathlib: MathImpl::IntelLike, size_dispatch: false },
            PlatformProfile { name: "cpu-avx512-intel", simd_width: 16, fma: true, mathlib: MathImpl::IntelLike, size_dispatch: true },
            PlatformProfile { name: "gpu-warp32", simd_width: 32, fma: true, mathlib: MathImpl::IntelLike, size_dispatch: true },
            PlatformProfile { name: "accel-vec128", simd_width: 128, fma: true, mathlib: MathImpl::GlibcLike, size_dispatch: true },
        ]
    }

    /// The reference profile (what "this machine" runs).
    pub fn reference() -> PlatformProfile {
        Self::zoo()[0]
    }
}
