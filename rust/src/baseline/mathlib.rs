//! Two deliberately different (but individually reasonable) math-library
//! implementations of `exp`/`log` — the §2.2.1 glibc-vs-Intel stand-in.
//! Each is accurate to a couple of ulps; they disagree on a few percent
//! of inputs, exactly like real libms do.

/// Which simulated libm a platform links.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MathImpl {
    /// f64-evaluated Cody–Waite + Taylor (like glibc: high accuracy).
    GlibcLike,
    /// f32-native table-free polynomial (like a fast vector libm).
    IntelLike,
}

/// exp(x) under the chosen implementation.
pub fn exp_variant(x: f32, which: MathImpl) -> f32 {
    match which {
        MathImpl::GlibcLike => {
            // reuse the fixed f64 path *without* the CR fallback — this is
            // "very accurate but not correctly rounded"
            if x > 89.0 {
                return f32::INFINITY;
            }
            if x < -104.0 {
                return 0.0;
            }
            crate::rnum::exp::exp_f64(x as f64) as f32
        }
        MathImpl::IntelLike => {
            // f32-native: k = round(x/ln2), degree-6 poly in f32
            if x > 89.0 {
                return f32::INFINITY;
            }
            if x < -104.0 {
                return 0.0;
            }
            const LOG2E: f32 = 1.442_695;
            const LN2: f32 = 0.693_147_2;
            let k = (x * LOG2E).round();
            let r = x - k * LN2;
            // Taylor to r^6 in f32 (≈1-2 ulp on the reduced range)
            let p = 1.0
                + r * (1.0
                    + r * (0.5
                        + r * (0.166_666_67
                            + r * (0.041_666_668 + r * (0.008_333_334 + r * 0.001_388_889)))));
            let scale = crate::rnum::fbits::pow2_f64(k as i32) as f32;
            p * scale
        }
    }
}

/// log(x) under the chosen implementation.
pub fn log_variant(x: f32, which: MathImpl) -> f32 {
    if x < 0.0 || x.is_nan() {
        return f32::NAN;
    }
    if x == 0.0 {
        return f32::NEG_INFINITY;
    }
    if x.is_infinite() {
        return x;
    }
    match which {
        MathImpl::GlibcLike => {
            // accurate f64 evaluation, single rounding at the end
            let (m, e) = {
                let bits = (x as f64).to_bits();
                let mut e = (((bits >> 52) & 0x7ff) as i32) - 1023;
                let mut m = f64::from_bits(
                    (bits & 0x000f_ffff_ffff_ffff) | 0x3ff0_0000_0000_0000,
                );
                if m >= std::f64::consts::SQRT_2 {
                    m *= 0.5;
                    e += 1;
                }
                (m, e)
            };
            let z = (m - 1.0) / (m + 1.0);
            let z2 = z * z;
            let mut p = 1.0 / 23.0;
            for k in (1..11).rev() {
                p = 1.0 / (2.0 * k as f64 + 1.0) + z2 * p;
            }
            let lnm = 2.0 * z * (1.0 + z2 * p);
            ((e as f64) * std::f64::consts::LN_2 + lnm) as f32
        }
        MathImpl::IntelLike => {
            // f32-native atanh series, fewer terms
            let bits = x.to_bits();
            let e = ((bits >> 23) & 0xff) as i32 - 127;
            let m = f32::from_bits((bits & 0x007f_ffff) | 0x3f80_0000); // [1,2)
            let z = (m - 1.0) / (m + 1.0);
            let z2 = z * z;
            let p = 0.333_333_34 + z2 * (0.2 + z2 * (0.142_857_15 + z2 * 0.111_111_11));
            let lnm = 2.0 * z * (1.0 + z2 * p);
            const LN2: f32 = 0.693_147_2;
            e as f32 * LN2 + lnm
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rnum::fbits::ulp_diff;
    use crate::rnum::{rexp, rlog};

    #[test]
    fn both_variants_are_accurate() {
        let mut x = -20.0f32;
        while x < 20.0 {
            for which in [MathImpl::GlibcLike, MathImpl::IntelLike] {
                let d = ulp_diff(exp_variant(x, which), rexp(x));
                // fast vector libms really do drift to tens of ulps at
                // larger |x| (f32 Cody–Waite cancellation) — allow it
                assert!(d <= 64, "exp {which:?} off by {d} ulp at {x}");
            }
            x += 0.173;
        }
        let mut x = 0.01f32;
        while x < 1e4 {
            for which in [MathImpl::GlibcLike, MathImpl::IntelLike] {
                let d = ulp_diff(log_variant(x, which), rlog(x));
                assert!(d <= 64, "log {which:?} off by {d} ulp at {x}");
            }
            x *= 1.37;
        }
    }

    #[test]
    fn variants_disagree_somewhere() {
        // the paper's point: both reasonable, not bit-identical
        let mut exp_diffs = 0;
        let mut log_diffs = 0;
        let mut x = -10.0f32;
        while x < 10.0 {
            if exp_variant(x, MathImpl::GlibcLike).to_bits()
                != exp_variant(x, MathImpl::IntelLike).to_bits()
            {
                exp_diffs += 1;
            }
            let y = x.abs() + 0.1;
            if log_variant(y, MathImpl::GlibcLike).to_bits()
                != log_variant(y, MathImpl::IntelLike).to_bits()
            {
                log_diffs += 1;
            }
            x += 0.01;
        }
        assert!(exp_diffs > 10, "exp variants identical?! ({exp_diffs})");
        assert!(log_diffs > 10, "log variants identical?! ({log_diffs})");
    }
}
