//! Crate-wide error type.

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors produced by RepDL.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Shape mismatch or invalid dimension arguments.
    #[error("shape error: {0}")]
    Shape(String),

    /// Configuration file / CLI problems.
    #[error("config error: {0}")]
    Config(String),

    /// Artifact loading / PJRT execution problems.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Underlying XLA error.
    #[error("xla error: {0}")]
    Xla(String),

    /// I/O error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Convenience constructor for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Convenience constructor for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Convenience constructor for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
}
