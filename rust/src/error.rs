//! Crate-wide error type (hand-rolled — `thiserror` is not in the
//! offline crate set, DESIGN.md §5).

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors produced by RepDL.
#[derive(Debug)]
pub enum Error {
    /// Shape mismatch or invalid dimension arguments.
    Shape(String),

    /// Configuration file / CLI problems.
    Config(String),

    /// Artifact loading / PJRT execution problems.
    Runtime(String),

    /// Deterministic admission rejection: the serve scheduler's
    /// queue-depth cap fired. `ticket` is the next unassigned ticket at
    /// the moment of rejection (the ticket the request *would* have
    /// received); rejection never consumes a ticket, so the accepted
    /// ticket sequence stays a pure function of the accepted submits.
    Rejected {
        /// Next unassigned ticket when the cap fired.
        ticket: u64,
    },

    /// Submission to a serve scheduler that has been closed. Typed (not
    /// a stringly runtime error) so a submit racing `close()` gets a
    /// deterministic, matchable outcome — never a hang or a silently
    /// dropped channel.
    Closed,

    /// Replay of a ticket range that reaches below the response log's
    /// truncation watermark (`ResponseLog::truncate_below`). Typed so a
    /// rotated-away audit range is a matchable outcome — never a silent
    /// "0 entries verified" that would read as a passing audit.
    Truncated {
        /// First requested ticket that falls below the watermark.
        ticket: u64,
        /// The log's truncation watermark at the time of the request.
        watermark: u64,
    },

    /// Serve-journal I/O or framing failure (durable journal append,
    /// header/record decode, recovery consistency). Typed so the
    /// scheduler's degradation policy can match on it: `FailStop`
    /// surfaces it to the submitting client, `DegradeToMemory` counts
    /// it — either way never a silent hole in the journal.
    Journal(String),

    /// Wire-protocol violation from an untrusted peer (bad hello magic,
    /// unknown frame tag, oversized or short payload, digest mismatch).
    /// Typed so the serve front end can answer with an error frame and
    /// drop the connection — malformed socket bytes must never panic,
    /// allocate unboundedly, or be mistaken for local journal
    /// corruption (`Error::Journal` stays the trusted-file case).
    Protocol(String),

    /// Underlying XLA error.
    Xla(String),

    /// I/O error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Rejected { ticket } => {
                write!(f, "rejected: serve queue-depth cap hit at ticket {ticket}")
            }
            Error::Closed => write!(f, "closed: serve scheduler accepts no new requests"),
            Error::Truncated { ticket, watermark } => write!(
                f,
                "truncated: ticket {ticket} is below the response-log watermark {watermark}"
            ),
            Error::Journal(m) => write!(f, "journal error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Convenience constructor for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Convenience constructor for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Convenience constructor for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    /// Convenience constructor for serve-journal errors.
    pub fn journal(msg: impl Into<String>) -> Self {
        Error::Journal(msg.into())
    }
    /// Convenience constructor for wire-protocol errors.
    pub fn protocol(msg: impl Into<String>) -> Self {
        Error::Protocol(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        assert_eq!(format!("{}", Error::shape("bad dims")), "shape error: bad dims");
        assert_eq!(format!("{}", Error::config("oops")), "config error: oops");
        assert_eq!(
            format!("{}", Error::runtime("no manifest")),
            "runtime error: no manifest"
        );
        assert_eq!(
            format!("{}", Error::Rejected { ticket: 7 }),
            "rejected: serve queue-depth cap hit at ticket 7"
        );
        assert!(format!("{}", Error::Closed).starts_with("closed:"));
        assert_eq!(
            format!("{}", Error::journal("torn tail")),
            "journal error: torn tail"
        );
        assert_eq!(
            format!("{}", Error::protocol("bad hello")),
            "protocol error: bad hello"
        );
    }

    #[test]
    fn io_conversion_preserves_source() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(format!("{e}").contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
