//! Minimal JSON parser + typed config (serde is not in the offline crate
//! set — DESIGN.md §5). Supports the JSON subset configs need: objects,
//! arrays, strings (with escapes), numbers, bools, null.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// number (f64)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object (sorted keys — deterministic iteration)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::config(format!("trailing garbage at byte {}", p.i)));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// f64 accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// usize accessor.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    /// string accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Typed field with default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    /// Typed field with default.
    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(Json::as_f64).map(|v| v as f32).unwrap_or(default)
    }

    /// Typed field with default.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::config(format!(
                "expected '{}' at byte {}",
                c as char, self.i
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(Error::config(format!("unexpected byte {}", self.i))),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::config(format!("bad literal at byte {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| Error::config(format!("bad number at byte {start}")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::config("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).unwrap_or(b""),
                            )
                            .map_err(|_| Error::config("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::config("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(Error::config("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // pass UTF-8 bytes through
                    let start = self.i;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.i += len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| Error::config("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(Error::config("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(Error::config("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let j = Json::parse(
            r#"{"steps": 100, "lr": 0.01, "name": "mlp", "causal": true,
                "dims": [8, 32, 4], "nested": {"a": null}}"#,
        )
        .unwrap();
        assert_eq!(j.usize_or("steps", 0), 100);
        assert!((j.f32_or("lr", 0.0) - 0.01).abs() < 1e-9);
        assert_eq!(j.str_or("name", ""), "mlp");
        assert_eq!(j.get("causal").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("dims").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("nested").unwrap().get("a"), Some(&Json::Null));
        assert_eq!(j.usize_or("missing", 7), 7);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }
}
