//! Optimizers as fixed computation graphs (paper §3: "optimizers defined
//! in PyTorch, keeping their names and parameter definitions intact").
//!
//! Parameters are updated **in registration order**, each element by the
//! same fixed sequence of correctly-rounded `f32` ops — so an optimizer
//! step is exactly as reproducible as a forward pass. `Adam`'s √ uses the
//! IEEE-correct hardware sqrt; nothing calls libm.

use crate::tensor::Tensor;
use crate::{Error, Result};

/// Exported [`SGD`] slot state: the momentum buffers, in parameter
/// registration order. Empty = momentum disabled or no step taken yet
/// (both resume identically: buffers lazily initialize to zeros).
#[derive(Clone, Debug, Default)]
pub struct SgdState {
    /// Momentum buffers (one per parameter; may be empty).
    pub bufs: Vec<Tensor>,
}

/// Exported [`Adam`] slot state: first/second moment buffers plus the
/// bias-correction step counter `t`. `import_state(export_state())`
/// round-trips exactly; a resumed optimizer's next step is bit-identical
/// to the uninterrupted one (the whole update is a pure function of
/// (params, grads, m, v, t)).
#[derive(Clone, Debug, Default)]
pub struct AdamState {
    /// First-moment buffers (one per parameter; may be empty pre-step).
    pub m: Vec<Tensor>,
    /// Second-moment buffers (aligned with `m`).
    pub v: Vec<Tensor>,
    /// Bias-correction step counter (number of steps taken).
    pub t: u32,
}

/// Check an imported slot buffer list against itself: every buffer must
/// be present exactly once per parameter *when the list is non-empty* —
/// per-parameter shape agreement is then enforced at `step()` time,
/// where the parameter shapes are first known.
fn check_aligned(what: &str, a: &[Tensor], b: &[Tensor]) -> Result<()> {
    if a.len() != b.len() {
        return Err(Error::shape(format!(
            "{what}: moment buffer lists misaligned ({} vs {})",
            a.len(),
            b.len()
        )));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if x.dims() != y.dims() {
            return Err(Error::shape(format!("{what}: moment buffer {i} shape mismatch")));
        }
    }
    Ok(())
}

/// Slot-vs-param shape check shared by both optimizers' `step`: an
/// imported buffer set that does not match the parameter list is a
/// typed [`Error::Shape`], never an index panic.
fn check_slots(what: &str, bufs: &[Tensor], params: &[&mut Tensor]) -> Result<()> {
    if bufs.is_empty() {
        return Ok(());
    }
    if bufs.len() != params.len() {
        return Err(Error::shape(format!(
            "{what}: {} slot buffers for {} params",
            bufs.len(),
            params.len()
        )));
    }
    for (i, (b, p)) in bufs.iter().zip(params.iter()).enumerate() {
        if b.dims() != p.dims() {
            return Err(Error::shape(format!(
                "{what}: slot buffer {i} shape {:?} does not match param shape {:?}",
                b.dims(),
                p.dims()
            )));
        }
    }
    Ok(())
}

/// Stochastic gradient descent with optional momentum + weight decay.
pub struct SGD {
    /// Learning rate.
    pub lr: f32,
    /// Momentum coefficient (0 disables).
    pub momentum: f32,
    /// L2 weight decay.
    pub weight_decay: f32,
    bufs: Vec<Tensor>,
}

impl SGD {
    /// New optimizer.
    pub fn new(lr: f32, momentum: f32, weight_decay: f32) -> Self {
        SGD { lr, momentum, weight_decay, bufs: Vec::new() }
    }

    /// Export the slot state (momentum buffers) for checkpointing.
    pub fn export_state(&self) -> SgdState {
        SgdState { bufs: self.bufs.clone() }
    }

    /// Import checkpointed slot state. Internal consistency is checked
    /// here; buffer-vs-parameter shapes are checked on the next `step`.
    pub fn import_state(&mut self, state: SgdState) -> Result<()> {
        self.bufs = state.bufs;
        Ok(())
    }

    /// Apply one step. `params` and `grads` must align (fixed order).
    /// Update graph per element: `g ← g + wd·p; v ← μ·v + g; p ← p − lr·v`.
    pub fn step(&mut self, params: Vec<&mut Tensor>, grads: &[Tensor]) -> Result<()> {
        if params.len() != grads.len() {
            return Err(Error::shape("SGD::step: params/grads length mismatch"));
        }
        check_slots("SGD::step", &self.bufs, &params)?;
        if self.bufs.is_empty() && self.momentum != 0.0 {
            self.bufs = params.iter().map(|p| Tensor::zeros(p.dims())).collect();
        }
        for (i, (p, g)) in params.into_iter().zip(grads.iter()).enumerate() {
            if p.dims() != g.dims() {
                return Err(Error::shape(format!("SGD::step: param {i} shape mismatch")));
            }
            for j in 0..p.numel() {
                let mut gv = g.data()[j];
                if self.weight_decay != 0.0 {
                    gv += self.weight_decay * p.data()[j];
                }
                let upd = if self.momentum != 0.0 {
                    let v = self.momentum * self.bufs[i].data()[j] + gv;
                    self.bufs[i].data_mut()[j] = v;
                    v
                } else {
                    gv
                };
                p.data_mut()[j] -= self.lr * upd;
            }
        }
        Ok(())
    }
}

/// Adam / AdamW (decoupled weight decay when `decoupled_wd` is set).
pub struct Adam {
    /// Learning rate.
    pub lr: f32,
    /// β₁.
    pub beta1: f32,
    /// β₂.
    pub beta2: f32,
    /// ε.
    pub eps: f32,
    /// Weight decay.
    pub weight_decay: f32,
    /// true = AdamW (decoupled), false = L2-in-gradient Adam.
    pub decoupled_wd: bool,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: u32,
}

impl Adam {
    /// Adam with PyTorch defaults.
    pub fn new(lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            decoupled_wd: false,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }

    /// AdamW with decoupled weight decay.
    pub fn new_adamw(lr: f32, weight_decay: f32) -> Self {
        let mut a = Self::new(lr);
        a.weight_decay = weight_decay;
        a.decoupled_wd = true;
        a
    }

    /// The bias-correction step counter (steps taken so far). Read-only:
    /// `t` advances only through [`Adam::step`] or a state import.
    pub fn t(&self) -> u32 {
        self.t
    }

    /// Export the slot state (moments + `t`) for checkpointing.
    pub fn export_state(&self) -> AdamState {
        AdamState { m: self.m.clone(), v: self.v.clone(), t: self.t }
    }

    /// Import checkpointed slot state. `m`/`v` must align with each
    /// other ([`Error::Shape`] otherwise); alignment with the parameter
    /// list is checked on the next `step`, where param shapes are known.
    pub fn import_state(&mut self, state: AdamState) -> Result<()> {
        check_aligned("Adam::import_state", &state.m, &state.v)?;
        self.m = state.m;
        self.v = state.v;
        self.t = state.t;
        Ok(())
    }

    /// One step; fixed per-element graph:
    /// `m ← β₁m + (1−β₁)g; v ← β₂v + (1−β₂)g²;`
    /// `p ← p − lr·m̂ · rsqrt-free (√v̂ + ε)⁻¹` using hardware √ (CR).
    pub fn step(&mut self, params: Vec<&mut Tensor>, grads: &[Tensor]) -> Result<()> {
        if params.len() != grads.len() {
            return Err(Error::shape("Adam::step: params/grads length mismatch"));
        }
        check_slots("Adam::step", &self.m, &params)?;
        if self.m.is_empty() {
            self.m = params.iter().map(|p| Tensor::zeros(p.dims())).collect();
            self.v = params.iter().map(|p| Tensor::zeros(p.dims())).collect();
        }
        self.t += 1;
        // bias corrections via rpow (correctly rounded)
        let bc1 = 1.0 - crate::rnum::rpow(self.beta1, self.t as f32);
        let bc2 = 1.0 - crate::rnum::rpow(self.beta2, self.t as f32);
        for (i, (p, g)) in params.into_iter().zip(grads.iter()).enumerate() {
            if p.dims() != g.dims() {
                return Err(Error::shape(format!("Adam::step: param {i} shape mismatch")));
            }
            for j in 0..p.numel() {
                let mut gv = g.data()[j];
                if !self.decoupled_wd && self.weight_decay != 0.0 {
                    gv += self.weight_decay * p.data()[j];
                }
                let m = self.beta1 * self.m[i].data()[j] + (1.0 - self.beta1) * gv;
                let v = self.beta2 * self.v[i].data()[j] + (1.0 - self.beta2) * gv * gv;
                self.m[i].data_mut()[j] = m;
                self.v[i].data_mut()[j] = v;
                let mhat = m / bc1;
                let vhat = v / bc2;
                let mut upd = mhat / (vhat.sqrt() + self.eps);
                if self.decoupled_wd && self.weight_decay != 0.0 {
                    upd += self.weight_decay * p.data()[j];
                }
                p.data_mut()[j] -= self.lr * upd;
            }
        }
        Ok(())
    }
}

/// Cosine LR schedule with warmup — a fixed graph over step count
/// (`rcos` is correctly rounded, so schedules match across platforms).
pub fn cosine_lr(step: u32, warmup: u32, total: u32, base: f32, min_lr: f32) -> f32 {
    if step < warmup {
        return base * (step as f32 + 1.0) / warmup as f32;
    }
    let t = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
    let c = crate::rnum::rcos(std::f32::consts::PI * t.min(1.0));
    min_lr + 0.5 * (base - min_lr) * (1.0 + c)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_problem() -> (Tensor, Tensor) {
        // minimise ||p - c||² for c = [1, -2, 3]
        let p = Tensor::zeros(&[3]);
        let c = Tensor::from_vec(&[3], vec![1., -2., 3.]).unwrap();
        (p, c)
    }

    fn grad_of(p: &Tensor, c: &Tensor) -> Tensor {
        p.zip(c, |a, b| 2.0 * (a - b)).unwrap()
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let (mut p, c) = quad_problem();
        let mut opt = SGD::new(0.05, 0.9, 0.0);
        for _ in 0..400 {
            let g = grad_of(&p, &c);
            opt.step(vec![&mut p], &[g]).unwrap();
        }
        for j in 0..3 {
            assert!((p.data()[j] - c.data()[j]).abs() < 1e-3);
        }
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let (mut p, c) = quad_problem();
        let mut opt = Adam::new(0.2);
        for _ in 0..200 {
            let g = grad_of(&p, &c);
            opt.step(vec![&mut p], &[g]).unwrap();
        }
        for j in 0..3 {
            assert!((p.data()[j] - c.data()[j]).abs() < 1e-2, "p={:?}", p.data());
        }
    }

    #[test]
    fn steps_are_bit_deterministic() {
        let run = |seed_unused: u32| -> Tensor {
            let _ = seed_unused;
            let (mut p, c) = quad_problem();
            let mut opt = Adam::new_adamw(0.05, 0.01);
            for _ in 0..50 {
                let g = grad_of(&p, &c);
                opt.step(vec![&mut p], &[g]).unwrap();
            }
            p
        };
        assert!(run(0).bit_eq(&run(1)));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut p = Tensor::zeros(&[3]);
        let g = Tensor::zeros(&[4]);
        assert!(SGD::new(0.1, 0.0, 0.0).step(vec![&mut p], &[g.clone()]).is_err());
        assert!(Adam::new(0.1).step(vec![&mut p], &[g]).is_err());
        let g2 = Tensor::zeros(&[3]);
        assert!(SGD::new(0.1, 0.0, 0.0)
            .step(vec![&mut p], &[g2.clone(), g2])
            .is_err());
    }

    #[test]
    fn adam_state_round_trips_mid_run() {
        let (mut p, c) = quad_problem();
        let mut opt = Adam::new(0.2);
        for _ in 0..7 {
            let g = grad_of(&p, &c);
            opt.step(vec![&mut p], &[g]).unwrap();
        }
        let snap_p = p.clone();
        let snap = opt.export_state();
        assert_eq!(snap.t, 7);
        // continue the original
        for _ in 0..5 {
            let g = grad_of(&p, &c);
            opt.step(vec![&mut p], &[g]).unwrap();
        }
        // resume a fresh optimizer from the snapshot — bits must match
        let mut p2 = snap_p;
        let mut opt2 = Adam::new(0.2);
        opt2.import_state(snap).unwrap();
        assert_eq!(opt2.t(), 7);
        for _ in 0..5 {
            let g = grad_of(&p2, &c);
            opt2.step(vec![&mut p2], &[g]).unwrap();
        }
        assert!(p.bit_eq(&p2));
    }

    #[test]
    fn sgd_momentum_state_round_trips_mid_run() {
        let (mut p, c) = quad_problem();
        let mut opt = SGD::new(0.05, 0.9, 0.01);
        for _ in 0..7 {
            let g = grad_of(&p, &c);
            opt.step(vec![&mut p], &[g]).unwrap();
        }
        let snap_p = p.clone();
        let snap = opt.export_state();
        for _ in 0..5 {
            let g = grad_of(&p, &c);
            opt.step(vec![&mut p], &[g]).unwrap();
        }
        let mut p2 = snap_p;
        let mut opt2 = SGD::new(0.05, 0.9, 0.01);
        opt2.import_state(snap).unwrap();
        for _ in 0..5 {
            let g = grad_of(&p2, &c);
            opt2.step(vec![&mut p2], &[g]).unwrap();
        }
        assert!(p.bit_eq(&p2));
    }

    #[test]
    fn mismatched_imports_are_typed_errors_not_panics() {
        // m/v misaligned with each other → rejected at import
        let bad = AdamState {
            m: vec![Tensor::zeros(&[3])],
            v: vec![Tensor::zeros(&[4])],
            t: 1,
        };
        assert!(matches!(Adam::new(0.1).import_state(bad), Err(Error::Shape(_))));
        // slot count / slot shape misaligned with params → rejected at step
        let mut p = Tensor::zeros(&[3]);
        let g = Tensor::zeros(&[3]);
        let mut adam = Adam::new(0.1);
        adam.import_state(AdamState {
            m: vec![Tensor::zeros(&[4])],
            v: vec![Tensor::zeros(&[4])],
            t: 1,
        })
        .unwrap();
        assert!(matches!(
            adam.step(vec![&mut p], &[g.clone()]),
            Err(Error::Shape(_))
        ));
        let mut sgd = SGD::new(0.1, 0.9, 0.0);
        sgd.import_state(SgdState { bufs: vec![Tensor::zeros(&[4])] }).unwrap();
        assert!(matches!(sgd.step(vec![&mut p], &[g]), Err(Error::Shape(_))));
    }

    #[test]
    fn cosine_schedule_shape() {
        assert!(cosine_lr(0, 10, 100, 1.0, 0.1) < 0.2); // warmup start
        assert!((cosine_lr(9, 10, 100, 1.0, 0.1) - 1.0).abs() < 1e-6); // warmup end
        assert!(cosine_lr(55, 10, 100, 1.0, 0.1) < 1.0);
        assert!((cosine_lr(100, 10, 100, 1.0, 0.1) - 0.1).abs() < 1e-5); // floor
        // deterministic
        assert_eq!(
            cosine_lr(33, 10, 100, 1.0, 0.1).to_bits(),
            cosine_lr(33, 10, 100, 1.0, 0.1).to_bits()
        );
    }
}
