//! Deterministic shuffle + batch iterator (paper §2.1: data shuffling is
//! an RNG consumer that must be seeded and ordered deterministically).

use crate::rng::{derive_seed, Mt19937, ReproRng};

/// Epoch-seeded batch index loader.
pub struct BatchLoader {
    /// Dataset length.
    pub len: usize,
    /// Batch size.
    pub batch: usize,
    /// Base seed.
    pub seed: u64,
}

impl BatchLoader {
    /// New loader.
    pub fn new(len: usize, batch: usize, seed: u64) -> Self {
        BatchLoader { len, batch, seed }
    }

    /// The index order for an epoch: Fisher–Yates with seed f(base, epoch).
    pub fn epoch_order(&self, epoch: u64) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.len).collect();
        let mut rng = Mt19937::new64(derive_seed(self.seed, epoch));
        rng.shuffle(&mut idx);
        idx
    }

    /// Batches for an epoch (last partial batch dropped, like PyTorch's
    /// `drop_last=True` — a *fixed choice*, because a varying tail batch
    /// size is exactly the paper's dynamic-batching hazard).
    pub fn epoch_batches(&self, epoch: u64) -> Vec<Vec<usize>> {
        let order = self.epoch_order(epoch);
        order
            .chunks(self.batch)
            .filter(|c| c.len() == self.batch)
            .map(|c| c.to_vec())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epochs_are_deterministic_and_distinct() {
        let l = BatchLoader::new(100, 8, 5);
        assert_eq!(l.epoch_order(0), l.epoch_order(0));
        assert_ne!(l.epoch_order(0), l.epoch_order(1));
    }

    #[test]
    fn batches_cover_without_repeats() {
        let l = BatchLoader::new(50, 8, 1);
        let batches = l.epoch_batches(3);
        assert_eq!(batches.len(), 6); // 48 of 50 used, tail dropped
        let mut seen = std::collections::HashSet::new();
        for b in &batches {
            assert_eq!(b.len(), 8);
            for &i in b {
                assert!(seen.insert(i), "duplicate index {i}");
            }
        }
    }

    #[test]
    fn different_seeds_shuffle_differently() {
        let a = BatchLoader::new(64, 4, 1).epoch_order(0);
        let b = BatchLoader::new(64, 4, 2).epoch_order(0);
        assert_ne!(a, b);
    }
}
