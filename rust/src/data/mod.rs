//! Deterministic data pipeline (the substrate the paper's training
//! experiments assume). Synthetic datasets generated from seeded RNG +
//! deterministic shuffling/batching: the entire input stream is a pure
//! function of (seed, epoch).

pub mod corpus;
pub mod loader;
pub mod synth;

pub use corpus::{CharTokenizer, SyntheticCorpus};
pub use loader::BatchLoader;
pub use synth::GaussianMixtureImages;
