//! Synthetic image classification data: a Gaussian-mixture "MNIST-like"
//! generator. Each class is a set of blob centres; images are rendered
//! deterministically from the class template + per-sample seeded noise.

use crate::rng::{derive_seed, Philox, ReproRng};
use crate::tensor::Tensor;

/// Deterministic Gaussian-blob image dataset.
pub struct GaussianMixtureImages {
    /// Image side (images are 1×side×side).
    pub side: usize,
    /// Number of classes.
    pub classes: usize,
    /// Samples in the dataset.
    pub len: usize,
    seed: u64,
}

impl GaussianMixtureImages {
    /// New dataset description (generation is lazy and pure).
    pub fn new(side: usize, classes: usize, len: usize, seed: u64) -> Self {
        GaussianMixtureImages { side, classes, len, seed }
    }

    /// Class blob centres (fixed function of class id).
    fn centres(&self, class: usize) -> Vec<(f32, f32)> {
        let mut rng = Philox::new(derive_seed(self.seed, 1000 + class as u64), 0);
        let k = 2 + class % 3;
        (0..k)
            .map(|_| {
                (
                    0.2 + 0.6 * rng.next_f32(),
                    0.2 + 0.6 * rng.next_f32(),
                )
            })
            .collect()
    }

    /// Render sample `i`: (image 1×S×S flattened into a Tensor, label).
    pub fn sample(&self, i: usize) -> (Tensor, usize) {
        let label = i % self.classes;
        let mut rng = Philox::new(derive_seed(self.seed, i as u64), 1);
        let s = self.side;
        let mut img = vec![0.0f32; s * s];
        let centres = self.centres(label);
        // jitter centres per sample
        let jit: Vec<(f32, f32)> = centres
            .iter()
            .map(|&(cx, cy)| (cx + 0.05 * rng.normal(), cy + 0.05 * rng.normal()))
            .collect();
        for (yi, v) in img.iter_mut().enumerate() {
            let (py, px) = (yi / s, yi % s);
            let (fy, fx) = ((py as f32 + 0.5) / s as f32, (px as f32 + 0.5) / s as f32);
            let mut acc = 0.0f32;
            for &(cx, cy) in &jit {
                let d2 = (fx - cx) * (fx - cx) + (fy - cy) * (fy - cy);
                // fixed graph: rexp of a product
                acc += crate::rnum::rexp(-d2 * 40.0);
            }
            *v = acc + 0.05 * rng.normal();
        }
        (
            Tensor::from_vec(&[1, s, s], img).unwrap(),
            label,
        )
    }

    /// Materialise a batch `(x: (B,1,S,S), labels)` from sample indices.
    pub fn batch(&self, idxs: &[usize]) -> (Tensor, Vec<usize>) {
        let s = self.side;
        let mut x = Tensor::zeros(&[idxs.len(), 1, s, s]);
        let mut labels = Vec::with_capacity(idxs.len());
        for (b, &i) in idxs.iter().enumerate() {
            let (img, lab) = self.sample(i);
            x.data_mut()[b * s * s..(b + 1) * s * s].copy_from_slice(img.data());
            labels.push(lab);
        }
        (x, labels)
    }

    /// Flattened batch `(B, S²)` for MLP models.
    pub fn batch_flat(&self, idxs: &[usize]) -> (Tensor, Vec<usize>) {
        let (x, labels) = self.batch(idxs);
        let b = idxs.len();
        let n = self.side * self.side;
        (x.reshape(&[b, n]).unwrap(), labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_are_pure_functions() {
        let ds = GaussianMixtureImages::new(8, 3, 100, 42);
        let (a, la) = ds.sample(17);
        let (b, lb) = ds.sample(17);
        assert!(a.bit_eq(&b));
        assert_eq!(la, lb);
        let (c, _) = ds.sample(18);
        assert!(!a.bit_eq(&c));
    }

    #[test]
    fn labels_cycle_through_classes() {
        let ds = GaussianMixtureImages::new(4, 5, 50, 1);
        for i in 0..10 {
            assert_eq!(ds.sample(i).1, i % 5);
        }
    }

    #[test]
    fn batches_stack_correctly() {
        let ds = GaussianMixtureImages::new(6, 2, 20, 7);
        let (x, labels) = ds.batch(&[0, 3, 5]);
        assert_eq!(x.dims(), &[3, 1, 6, 6]);
        assert_eq!(labels, vec![0, 1, 1]);
        let (xf, _) = ds.batch_flat(&[0, 3, 5]);
        assert_eq!(xf.dims(), &[3, 36]);
        // same content
        assert_eq!(x.data(), xf.data());
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean image of class 0 differs from class 1
        let ds = GaussianMixtureImages::new(8, 2, 40, 3);
        let mut m0 = vec![0.0f32; 64];
        let mut m1 = vec![0.0f32; 64];
        for i in 0..20 {
            let (x, l) = ds.sample(i);
            let m = if l == 0 { &mut m0 } else { &mut m1 };
            for (a, b) in m.iter_mut().zip(x.data()) {
                *a += b;
            }
        }
        let diff: f32 = m0.iter().zip(m1.iter()).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1.0, "classes look identical: {diff}");
    }
}
