//! Tiny argument parser (clap is not in the offline crate set).
//!
//! Grammar: `repdl <subcommand> [--flag value | --switch] ...`

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    /// First positional (the subcommand).
    pub command: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    /// `--key value` pairs (switches get "true").
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                out.flags.insert(key.to_string(), val);
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process command line.
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Typed flag with default.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Typed flag with default, clamped to at least `min` — for count
    /// flags where 0 is meaningless (`--shards`, `--batch-window`): the
    /// serve scheduler needs ≥ 1 replica and a ≥ 1 request window.
    pub fn get_usize_at_least(&self, key: &str, default: usize, min: usize) -> usize {
        self.get_usize(key, default).max(min)
    }

    /// Optional count flag where `0` (or absence, or garbage) means
    /// "off" — for limits like `--max-queue-depth`, whose unset state is
    /// "unbounded" rather than a number.
    pub fn get_opt_usize(&self, key: &str) -> Option<usize> {
        match self.get_usize(key, 0) {
            0 => None,
            n => Some(n),
        }
    }

    /// Typed flag with default.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Typed flag with default.
    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// String flag with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag: `None` when absent (e.g. a path flag like
    /// `--journal FILE`).
    pub fn get_opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).filter(|v| !v.is_empty()).cloned()
    }

    /// Boolean switch.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// The shared `--threads` flag: explicit worker-pool lanes, or None
    /// to use the global pool (`REPDL_THREADS` / machine parallelism).
    /// `0` means sequential (1 lane), matching `REPDL_THREADS=0`;
    /// unparsable values are rejected as None.
    pub fn threads(&self) -> Option<usize> {
        self.flags
            .get("threads")
            .and_then(|v| v.parse::<usize>().ok())
            .map(|n| n.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = p("train --steps 100 --lr 0.5 extra --verbose");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_f32("lr", 0.0), 0.5);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults() {
        let a = p("verify");
        assert_eq!(a.get_usize("steps", 42), 42);
        assert_eq!(a.get_str("mode", "repro"), "repro");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn at_least_clamp() {
        let a = p("serve --shards 0 --batch-window 7");
        assert_eq!(a.get_usize_at_least("shards", 1, 1), 1);
        assert_eq!(a.get_usize_at_least("batch-window", 16, 1), 7);
        assert_eq!(p("serve").get_usize_at_least("shards", 2, 1), 2);
    }

    #[test]
    fn opt_usize_zero_and_absent_mean_off() {
        assert_eq!(p("serve --max-queue-depth 32").get_opt_usize("max-queue-depth"), Some(32));
        assert_eq!(p("serve --max-queue-depth 0").get_opt_usize("max-queue-depth"), None);
        assert_eq!(p("serve").get_opt_usize("max-queue-depth"), None);
        assert_eq!(p("serve --max-queue-depth lots").get_opt_usize("max-queue-depth"), None);
    }

    #[test]
    fn threads_flag() {
        assert_eq!(p("serve --threads 4").threads(), Some(4));
        assert_eq!(p("serve").threads(), None);
        // 0 = sequential, same semantics as REPDL_THREADS=0
        assert_eq!(p("serve --threads 0").threads(), Some(1));
        assert_eq!(p("serve --threads lots").threads(), None);
    }
}
