//! The `Tensor` type: contiguous row-major `f32` storage + shape.

use super::shape::Shape;
use crate::{Error, Result};

/// A dense row-major `f32` tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Shape,
    data: Vec<f32>,
}

impl Tensor {
    /// All-zeros tensor.
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Tensor filled with a constant.
    pub fn full(dims: &[usize], v: f32) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Tensor { shape, data: vec![v; n] }
    }

    /// Tensor whose contents are produced by `fill`, which receives the
    /// freshly allocated (zeroed) buffer. This replaces the kernels'
    /// old `Tensor::zeros` + `buf.fill(0.0)` double-zeroing pattern:
    /// the allocation is calloc-backed (`vec![0.0; n]` lowers to
    /// `alloc_zeroed`, i.e. OS zero pages for large buffers — no
    /// explicit memset pass), and kernels either accumulate straight
    /// onto the zeros or overwrite every element, so no second zeroing
    /// sweep ever runs.
    ///
    /// Deliberately *not* genuinely uninitialised storage: handing out
    /// `&mut [f32]` over uninit memory is undefined behaviour
    /// (`Vec::set_len` over uninit elements), and in a bit-exactness
    /// crate a fill that missed an element must read back a
    /// deterministic 0.0, never nondeterministic garbage.
    pub fn filled_by(dims: &[usize], fill: impl FnOnce(&mut [f32])) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        let mut data = vec![0.0f32; n];
        fill(&mut data);
        Tensor { shape, data }
    }

    /// Build from data (len must equal the shape's element count).
    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Result<Self> {
        let shape = Shape::new(dims);
        if shape.numel() != data.len() {
            return Err(Error::shape(format!(
                "shape {:?} needs {} elements, got {}",
                dims,
                shape.numel(),
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    /// Scalar tensor.
    pub fn scalar(v: f32) -> Self {
        Tensor { shape: Shape::new(&[]), data: vec![v] }
    }

    /// Shape accessor.
    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    /// Dimensions.
    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Immutable data slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable data slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the raw buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Element access by multi-index.
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.shape.offset(idx)]
    }

    /// Mutable element access by multi-index.
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let o = self.shape.offset(idx);
        &mut self.data[o]
    }

    /// Reshape without moving data (element count must match).
    pub fn reshape(&self, dims: &[usize]) -> Result<Tensor> {
        let shape = Shape::new(dims);
        if shape.numel() != self.data.len() {
            return Err(Error::shape(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims(),
                dims
            )));
        }
        Ok(Tensor { shape, data: self.data.clone() })
    }

    /// 2-D transpose (copies; fixed element order).
    pub fn transpose2d(&self) -> Result<Tensor> {
        let d = self.dims();
        if d.len() != 2 {
            return Err(Error::shape(format!("transpose2d on rank {}", d.len())));
        }
        let (m, n) = (d[0], d[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Ok(Tensor { shape: Shape::new(&[n, m]), data: out })
    }

    /// General axis permutation (copies; fixed element order).
    pub fn permute(&self, perm: &[usize]) -> Result<Tensor> {
        let d = self.dims();
        if perm.len() != d.len() {
            return Err(Error::shape(format!(
                "permute {:?} on rank {}",
                perm,
                d.len()
            )));
        }
        let mut seen = vec![false; d.len()];
        for &p in perm {
            if p >= d.len() || seen[p] {
                return Err(Error::shape(format!("invalid permutation {perm:?}")));
            }
            seen[p] = true;
        }
        let new_dims: Vec<usize> = perm.iter().map(|&p| d[p]).collect();
        let old_strides = self.shape.strides();
        let new_shape = Shape::new(&new_dims);
        let new_strides = new_shape.strides();
        let mut out = vec![0.0f32; self.data.len()];
        // iterate output linearly, gather from the permuted source offset
        for (flat, v) in out.iter_mut().enumerate() {
            let mut src = 0usize;
            let mut rem = flat;
            for a in 0..new_dims.len() {
                let coord = rem / new_strides[a];
                rem %= new_strides[a];
                src += coord * old_strides[perm[a]];
            }
            *v = self.data[src];
        }
        Ok(Tensor { shape: new_shape, data: out })
    }

    /// Row view for a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let n = *self.dims().last().unwrap();
        &self.data[i * n..(i + 1) * n]
    }

    /// SHA-256 hash of shape + raw little-endian bit patterns — the
    /// bitwise fingerprint used throughout the verification harness.
    pub fn bit_hash(&self) -> [u8; 32] {
        use crate::sha256::Sha256;
        let mut h = Sha256::new();
        for &d in self.dims() {
            h.update((d as u64).to_le_bytes());
        }
        for &v in &self.data {
            h.update(v.to_bits().to_le_bytes());
        }
        h.finalize()
    }

    /// Hex string of [`Tensor::bit_hash`] (for logs).
    pub fn bit_hash_hex(&self) -> String {
        self.bit_hash().iter().map(|b| format!("{b:02x}")).collect()
    }

    /// True iff `other` has identical shape and identical bit patterns.
    pub fn bit_eq(&self, other: &Tensor) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(other.data.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.at(&[0, 0]), 1.0);
        assert_eq!(t.at(&[1, 2]), 6.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert!(Tensor::from_vec(&[2, 2], vec![1.0]).is_err());
    }

    #[test]
    fn reshape_and_transpose() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let r = t.reshape(&[3, 2]).unwrap();
        assert_eq!(r.at(&[2, 1]), 6.0);
        let tt = t.transpose2d().unwrap();
        assert_eq!(tt.dims(), &[3, 2]);
        assert_eq!(tt.at(&[2, 0]), 3.0);
        assert_eq!(tt.at(&[0, 1]), 4.0);
    }

    #[test]
    fn filled_by_matches_zeros_plus_fill() {
        let a = Tensor::filled_by(&[3, 4], |buf| {
            for (i, v) in buf.iter_mut().enumerate() {
                *v = i as f32 * 0.5;
            }
        });
        let mut b = Tensor::zeros(&[3, 4]);
        for (i, v) in b.data_mut().iter_mut().enumerate() {
            *v = i as f32 * 0.5;
        }
        assert!(a.bit_eq(&b));
        // zero-sized shapes are fine and never invoke writes
        let e = Tensor::filled_by(&[0, 5], |buf| assert!(buf.is_empty()));
        assert_eq!(e.numel(), 0);
    }

    #[test]
    fn bit_hash_distinguishes_signed_zero() {
        // bitwise fingerprinting must see -0.0 != +0.0 (value-equal!)
        let a = Tensor::from_vec(&[1], vec![0.0]).unwrap();
        let b = Tensor::from_vec(&[1], vec![-0.0]).unwrap();
        assert_ne!(a.bit_hash(), b.bit_hash());
        assert!(!a.bit_eq(&b));
        assert!(a.bit_eq(&a));
    }

    #[test]
    fn bit_hash_depends_on_shape() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]).unwrap();
        assert_ne!(a.bit_hash(), b.bit_hash());
    }

    #[test]
    fn hash_is_stable() {
        let t = Tensor::full(&[3, 3], 0.5);
        assert_eq!(t.bit_hash_hex(), t.clone().bit_hash_hex());
        assert_eq!(t.bit_hash_hex().len(), 64);
    }
}
