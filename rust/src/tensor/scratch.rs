//! Reusable scratch-buffer arena (allocation elimination, bit-neutral).
//!
//! The packed GEMM and the fused im2col convolution need transient
//! buffers (B panels, im2col columns, batch staging) whose size repeats
//! from call to call — a serve loop or a training loop would otherwise
//! pay a fresh heap allocation and a page-fault sweep per step. This
//! module parks those buffers in a **thread-local** free list: the first
//! call allocates, every later call of similar size reuses.
//!
//! Thread-locality keeps the arena lock-free and compatible with the
//! worker pool: scratch is always taken and returned on the *caller*
//! thread (kernels dispatch pool tasks that only borrow slices of it),
//! so pool workers never touch the arena and concurrent dispatchers
//! (e.g. several servers sharing one pool) each get their own list.
//!
//! **Reproducibility contract.** A scratch buffer's contents are
//! *unspecified* — typically stale bytes from an earlier call of a
//! possibly different shape. Every kernel using scratch must write each
//! element it later reads (the pack routines overwrite their whole
//! region, including tile padding), so stale state can never reach an
//! output bit. The `scratch_arena_reuse` tests cross-call this with
//! shape-alternating kernels and assert bit-equality against fresh
//! references.

use std::cell::RefCell;
use std::ops::{Deref, DerefMut};

/// Buffers parked per thread (excess ones are simply freed on drop).
const MAX_PARKED: usize = 8;

thread_local! {
    static ARENA: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// Exclusive lease on a scratch buffer of exactly the requested length.
/// Dereferences to `[f32]`; returns the buffer to the thread's arena on
/// drop. Contents on acquisition are unspecified (see module docs).
pub struct ScratchGuard {
    buf: Vec<f32>,
    len: usize,
}

impl Deref for ScratchGuard {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.buf[..self.len]
    }
}

impl DerefMut for ScratchGuard {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf[..self.len]
    }
}

impl Drop for ScratchGuard {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        ARENA.with(|a| {
            let mut a = a.borrow_mut();
            if a.len() < MAX_PARKED {
                a.push(buf);
            } else if let Some(i) = (0..a.len()).min_by_key(|&i| a[i].capacity()) {
                // full arena: keep the larger buffer, so a burst of tiny
                // leases can never permanently evict the big pack/im2col
                // buffers the hot loops rely on
                if a[i].capacity() < buf.capacity() {
                    a[i] = buf;
                }
            }
        });
    }
}

/// Lease `len` f32s of scratch from the calling thread's arena,
/// allocating only if no parked buffer is large enough. The returned
/// slice's contents are unspecified; the caller must write every element
/// before reading it.
pub fn scratch_f32(len: usize) -> ScratchGuard {
    let mut buf = ARENA.with(|a| {
        let mut a = a.borrow_mut();
        // Prefer the largest parked buffer: it is the most likely to fit
        // without regrowing, and keeps the arena converging on the
        // workload's peak sizes.
        match (0..a.len()).max_by_key(|&i| a[i].capacity()) {
            Some(i) => a.swap_remove(i),
            None => Vec::new(),
        }
    });
    if buf.len() < len {
        // resize zero-fills only the grown region; reused prefixes keep
        // stale contents, which the contract makes unobservable
        buf.resize(len, 0.0);
    }
    ScratchGuard { buf, len }
}

/// Number of buffers currently parked on this thread (observability for
/// tests and the allocation-count benchmarks).
pub fn parked_buffers() -> usize {
    ARENA.with(|a| a.borrow().len())
}

/// Largest capacity currently parked on this thread (observability for
/// the eviction policy: big pack/im2col buffers must survive bursts of
/// small leases).
pub fn parked_capacity_max() -> usize {
    ARENA.with(|a| a.borrow().iter().map(|b| b.capacity()).max().unwrap_or(0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_exposes_exactly_the_requested_len() {
        let g = scratch_f32(37);
        assert_eq!(g.len(), 37);
        let g2 = scratch_f32(0);
        assert_eq!(g2.len(), 0);
    }

    #[test]
    fn buffers_are_reused_across_takes() {
        // drain whatever earlier tests parked, then check round-trips
        let drained: Vec<ScratchGuard> =
            (0..MAX_PARKED + 1).map(|_| scratch_f32(1)).collect();
        drop(drained);
        let before = parked_buffers();
        {
            let mut g = scratch_f32(1024);
            g[0] = 1.0;
            g[1023] = 2.0;
        } // returned to arena here
        assert!(parked_buffers() >= before.min(MAX_PARKED - 1));
        let g = scratch_f32(512); // must fit in the parked 1024 buffer
        assert_eq!(g.len(), 512);
    }

    #[test]
    fn growth_is_handled() {
        {
            let _small = scratch_f32(8);
        }
        let big = scratch_f32(100_000);
        assert_eq!(big.len(), 100_000);
    }

    #[test]
    fn interleaved_leases_are_distinct_buffers() {
        let mut a = scratch_f32(64);
        let mut b = scratch_f32(64);
        a.fill(1.0);
        b.fill(2.0);
        assert!(a.iter().all(|&v| v == 1.0));
        assert!(b.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn arena_is_bounded() {
        let guards: Vec<ScratchGuard> = (0..MAX_PARKED * 2).map(|_| scratch_f32(16)).collect();
        drop(guards);
        assert!(parked_buffers() <= MAX_PARKED);
    }

    #[test]
    fn large_buffers_survive_a_full_arena() {
        // each #[test] runs on its own thread, so the arena starts empty
        let smalls: Vec<ScratchGuard> = (0..MAX_PARKED).map(|_| scratch_f32(4)).collect();
        let big = scratch_f32(100_000);
        drop(smalls); // arena now holds MAX_PARKED small buffers
        drop(big); // full arena: must displace a small one, not be dropped
        assert!(parked_capacity_max() >= 100_000);
        assert!(parked_buffers() <= MAX_PARKED);
    }
}
