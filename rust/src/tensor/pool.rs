//! Persistent reproducible worker pool (paper §3.2.2, CPU translation).
//!
//! The paper's efficiency argument is that fixing reduction order costs
//! little *because* parallelism survives across independent summation
//! tasks. The seed implementation spawned fresh scoped threads on every
//! tensor op, paying thread-creation cost per GEMM call. This module
//! replaces that with a lazily-initialised, process-lifetime pool:
//!
//! * **Lanes, not threads.** A pool of `L` lanes runs lane 0 on the
//!   calling thread and lanes `1..L` on `L−1` persistent workers parked
//!   on channel receives. `REPDL_THREADS=1` therefore means *zero*
//!   background threads — pure sequential execution.
//! * **Static chunk→lane assignment.** [`WorkerPool::run`] splits task
//!   indices `0..n` into `L` contiguous ranges of `ceil(n/L)`; lane `l`
//!   always executes exactly the range `[l·ceil(n/L), (l+1)·ceil(n/L))`.
//!   The map depends only on `(n, L)` — never on scheduling, load, or
//!   which worker finishes first.
//! * **Pool-size invariance by construction.** Each task computes one
//!   output region from read-only inputs with a fixed internal order, so
//!   *which lane* runs it cannot change its bits. Static assignment is
//!   still valuable: it makes execution traces reproducible and keeps
//!   the per-lane work deterministic for performance analysis. The
//!   `pool_invariance` integration suite asserts bit-equality across
//!   pool sizes {1, 2, 3, 5, 8, 16} for GEMM, convolution and
//!   reductions.
//!
//! The global pool is [`OnceLock`]-held and sized from `REPDL_THREADS`
//! **read exactly once** at first use (fixing the seed's env-var race:
//! tests used to `set_var` mid-run, which races under the parallel test
//! harness). Code that needs a specific size — tests, benchmarks, the
//! `--threads` CLI flag — constructs its own [`WorkerPool`] and calls
//! the `*_in` tensor APIs.
//!
//! **Do not call [`WorkerPool::run`] from inside a pool task.** Nested
//! dispatch on the same pool can deadlock (every lane blocked waiting on
//! work queued behind itself). The tensor kernels never nest: composite
//! ops (im2col + GEMM, serve batching) dispatch from the caller thread.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// A unit of dispatched work: run `task(i)` for every `i` in `[lo, hi)`,
/// then signal the latch. The `'static` on `task` is a lifetime erasure;
/// [`WorkerPool::run`] guarantees the borrow outlives the job.
struct Job {
    task: &'static (dyn Fn(usize) + Sync),
    lo: usize,
    hi: usize,
    latch: Arc<Latch>,
}

/// Countdown latch with panic flag: `run` blocks on it until every
/// dispatched job has finished (or panicked — workers always count
/// down, so a task panic can never strand the caller).
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { remaining: Mutex::new(n), cv: Condvar::new(), panicked: AtomicBool::new(false) }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.cv.wait(r).unwrap();
        }
    }
}

/// Shareable, clonable handle to a [`WorkerPool`]. Concurrent
/// dispatchers are supported (dispatch is serialised per lane sender),
/// so several owners — e.g. the serve scheduler's replicas — can drive
/// one pool at once; pool size never changes kernel bits, so sharing
/// vs. private pools is a pure capacity decision.
pub type PoolHandle = std::sync::Arc<WorkerPool>;

/// Persistent worker pool with `lanes` parallel execution lanes
/// (`lanes − 1` background threads plus the calling thread).
pub struct WorkerPool {
    lanes: usize,
    /// One sender per background worker (lane `w + 1`). The mutex makes
    /// the pool `Sync` on every supported toolchain (std's `Sender` only
    /// became `Sync` in 1.72) and serialises concurrent dispatchers.
    txs: Vec<Mutex<Sender<Job>>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Build a pool with the given number of lanes (clamped to ≥ 1).
    /// `lanes == 1` spawns no threads and runs everything inline.
    pub fn new(lanes: usize) -> WorkerPool {
        let lanes = lanes.max(1);
        let mut txs = Vec::with_capacity(lanes - 1);
        let mut handles = Vec::with_capacity(lanes - 1);
        for w in 0..lanes - 1 {
            let (tx, rx) = channel::<Job>();
            let handle = std::thread::Builder::new()
                .name(format!("repdl-pool-{}", w + 1))
                .spawn(move || worker_loop(rx))
                .expect("failed to spawn pool worker");
            txs.push(Mutex::new(tx));
            handles.push(handle);
        }
        WorkerPool { lanes, txs, handles }
    }

    /// Build a pool wrapped in a shareable [`PoolHandle`] (the form the
    /// serve scheduler's replicas take, so one pool can back N shards).
    pub fn shared(lanes: usize) -> PoolHandle {
        std::sync::Arc::new(WorkerPool::new(lanes))
    }

    /// Number of parallel lanes (1 = sequential).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Execute `task(i)` for every `i` in `0..ntasks`, split statically
    /// across the lanes. Blocks until all tasks complete; propagates the
    /// first observed panic. Tasks must be independent (they run
    /// concurrently) and must not dispatch on the same pool.
    pub fn run(&self, ntasks: usize, task: &(dyn Fn(usize) + Sync)) {
        if ntasks == 0 {
            return;
        }
        if self.lanes <= 1 || ntasks == 1 {
            for i in 0..ntasks {
                task(i);
            }
            return;
        }
        let per_lane = ntasks.div_ceil(self.lanes);
        let used = ntasks.div_ceil(per_lane); // ≤ self.lanes
        let latch = Arc::new(Latch::new(used - 1));
        // SAFETY: lifetime erasure only. `run` does not return (not even
        // by unwinding — see the catch below) until every dispatched job
        // has counted the latch down, so no worker can observe `task`
        // after the borrow it erases has ended.
        let task_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(task) };
        let mut dispatched_ok = true;
        for lane in 1..used {
            let job = Job {
                task: task_static,
                lo: lane * per_lane,
                hi: ((lane + 1) * per_lane).min(ntasks),
                latch: Arc::clone(&latch),
            };
            if self.txs[lane - 1].lock().unwrap().send(job).is_err() {
                // This job (returned unsent) and every remaining lane
                // will never run: count them down ourselves so wait()
                // terminates once the already-sent jobs finish. We must
                // NOT unwind yet — earlier workers may still hold the
                // erased borrow.
                for _ in lane..used {
                    latch.count_down();
                }
                dispatched_ok = false;
                break;
            }
        }
        // Lane 0 runs on the calling thread. A panic here must not
        // unwind past the latch wait — workers may still hold the
        // erased borrow — so catch, wait, then resume.
        let own = if dispatched_ok {
            catch_unwind(AssertUnwindSafe(|| {
                for i in 0..per_lane.min(ntasks) {
                    task(i);
                }
            }))
        } else {
            Ok(())
        };
        latch.wait();
        if !dispatched_ok {
            panic!("worker pool thread died");
        }
        if let Err(p) = own {
            resume_unwind(p);
        }
        if latch.panicked.load(Ordering::Relaxed) {
            panic!("worker pool task panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Disconnect the channels so the workers' recv() fails and the
        // loops exit, then reap the threads.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        let res = catch_unwind(AssertUnwindSafe(|| {
            for i in job.lo..job.hi {
                (job.task)(i);
            }
        }));
        if res.is_err() {
            job.latch.panicked.store(true, Ordering::Relaxed);
        }
        // Always count down, even on panic, so the dispatcher never
        // deadlocks; the worker itself survives for the next job.
        job.latch.count_down();
    }
}

/// Number of lanes for the global pool: `REPDL_THREADS` if set and
/// parseable, else the machine's available parallelism. The env var is
/// read **once** per process (cached), so mid-run `set_var` can never
/// change kernel behaviour — inject a [`WorkerPool`] instead.
pub fn default_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("REPDL_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            // 0 keeps its historical meaning: sequential (1 lane)
            .map(|n| n.max(1))
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

fn global_cell() -> &'static PoolHandle {
    static GLOBAL: OnceLock<PoolHandle> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::shared(default_threads()))
}

/// The process-wide pool, lazily created at first use with
/// [`default_threads`] lanes.
pub fn global_pool() -> &'static WorkerPool {
    global_cell()
}

/// A shareable handle to the *same* process-wide pool (for consumers
/// that need an owned [`PoolHandle`], e.g. serve-scheduler replicas —
/// this never spawns a second pool alongside [`global_pool`]).
pub fn global_pool_handle() -> PoolHandle {
    Arc::clone(global_cell())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_index_exactly_once() {
        for lanes in [1, 2, 3, 5, 8, 16] {
            let pool = WorkerPool::new(lanes);
            for n in [0usize, 1, 2, 7, 16, 100, 1003] {
                let hits: Vec<std::sync::atomic::AtomicUsize> =
                    (0..n).map(|_| std::sync::atomic::AtomicUsize::new(0)).collect();
                pool.run(n, &|i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1, "lanes={lanes} n={n} i={i}");
                }
            }
        }
    }

    #[test]
    fn static_assignment_is_a_pure_function_of_n_and_lanes() {
        // record which lane ran each task; two runs must agree exactly
        let pool = WorkerPool::new(4);
        let record = || {
            let lane_of: Vec<std::sync::atomic::AtomicUsize> =
                (0..37).map(|_| std::sync::atomic::AtomicUsize::new(usize::MAX)).collect();
            pool.run(37, &|i| {
                // lane identity proxy: thread name index (0 for caller)
                let name = std::thread::current().name().map(str::to_string);
                let lane = name
                    .as_deref()
                    .and_then(|n| n.strip_prefix("repdl-pool-"))
                    .and_then(|s| s.parse::<usize>().ok())
                    .unwrap_or(0);
                lane_of[i].store(lane, Ordering::Relaxed);
            });
            lane_of.iter().map(|a| a.load(Ordering::Relaxed)).collect::<Vec<_>>()
        };
        let a = record();
        let b = record();
        assert_eq!(a, b, "chunk→lane assignment drifted between runs");
        // contiguous ranges: lane ids must be non-decreasing
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "assignment not contiguous: {a:?}");
    }

    #[test]
    fn pool_is_reusable_across_many_dispatches() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0u64; 64];
        for round in 0..100u64 {
            let cells: Vec<std::sync::atomic::AtomicU64> =
                out.iter().map(|&v| std::sync::atomic::AtomicU64::new(v)).collect();
            pool.run(64, &|i| {
                cells[i].fetch_add(round + i as u64, Ordering::Relaxed);
            });
            for (o, c) in out.iter_mut().zip(cells.iter()) {
                *o = c.load(Ordering::Relaxed);
            }
        }
        for (i, v) in out.iter().enumerate() {
            let want: u64 = (0..100u64).map(|r| r + i as u64).sum();
            assert_eq!(*v, want, "i={i}");
        }
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(32, &|i| {
                if i == 17 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic was swallowed");
        // the pool must still work after a task panicked
        let ok: Vec<std::sync::atomic::AtomicUsize> =
            (0..8).map(|_| std::sync::atomic::AtomicUsize::new(0)).collect();
        pool.run(8, &|i| {
            ok[i].store(i + 1, Ordering::Relaxed);
        });
        for (i, c) in ok.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), i + 1);
        }
    }

    #[test]
    fn concurrent_dispatchers_share_the_pool() {
        let pool = Arc::new(WorkerPool::new(4));
        let mut joins = Vec::new();
        for t in 0..4usize {
            let pool = Arc::clone(&pool);
            joins.push(std::thread::spawn(move || {
                let cells: Vec<std::sync::atomic::AtomicUsize> =
                    (0..200).map(|_| std::sync::atomic::AtomicUsize::new(0)).collect();
                pool.run(200, &|i| {
                    cells[i].store(i * (t + 1), Ordering::Relaxed);
                });
                (0..200).all(|i| cells[i].load(Ordering::Relaxed) == i * (t + 1))
            }));
        }
        for j in joins {
            assert!(j.join().unwrap());
        }
    }

    #[test]
    fn global_pool_handle_is_the_global_pool() {
        // same instance, not a second pool (no duplicate worker threads)
        assert!(std::ptr::eq(global_pool(), &*global_pool_handle()));
        assert_eq!(global_pool_handle().lanes(), global_pool().lanes());
    }

    #[test]
    fn default_threads_is_cached_once() {
        // Whatever the first read returned, later env changes must not
        // alter it (the seed's race is structurally gone).
        let first = default_threads();
        std::env::set_var("REPDL_THREADS", "9999");
        assert_eq!(default_threads(), first);
        std::env::remove_var("REPDL_THREADS");
        assert_eq!(default_threads(), first);
        assert!(first >= 1);
    }
}
