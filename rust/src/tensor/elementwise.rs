//! Elementwise tensor operations with (limited numpy) broadcasting.
//!
//! Elementwise `f32` arithmetic is exactly rounded by IEEE 754, so these
//! are reproducible with no further care; what matters is a *fixed
//! element order* for any op that could be fused or reassociated — here
//! each output element depends only on its own inputs, so order is moot.

use super::shape::Shape;
use super::tensor::Tensor;
use crate::Result;

impl Tensor {
    /// Apply a scalar function to every element.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        let data = self.data().iter().map(|&x| f(x)).collect();
        Tensor::from_vec(self.dims(), data).unwrap()
    }

    /// Combine with another tensor elementwise, broadcasting shapes.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.dims() == other.dims() {
            let data = self
                .data()
                .iter()
                .zip(other.data().iter())
                .map(|(&a, &b)| f(a, b))
                .collect();
            return Tensor::from_vec(self.dims(), data);
        }
        let out_shape = self.shape().broadcast(other.shape())?;
        let n = out_shape.numel();
        let mut data = vec![0.0f32; n];
        let r = out_shape.rank();
        let os = out_shape.strides();
        let idx_of = |shape: &Shape, flat: usize| -> usize {
            // map output multi-index to this operand's offset under
            // broadcasting (right-aligned, dim-1 pinned)
            let sr = shape.rank();
            let ss = shape.strides();
            let mut off = 0usize;
            for d in 0..sr {
                let od = d + (r - sr);
                let coord = (flat / os[od]) % out_shape.dims()[od];
                let c = if shape.dims()[d] == 1 { 0 } else { coord };
                off += c * ss[d];
            }
            off
        };
        for (flat, v) in data.iter_mut().enumerate() {
            let a = self.data()[idx_of(self.shape(), flat)];
            let b = other.data()[idx_of(other.shape(), flat)];
            *v = f(a, b);
        }
        Tensor::from_vec(out_shape.dims(), data)
    }

    /// Elementwise add (broadcasting).
    pub fn add_t(&self, o: &Tensor) -> Result<Tensor> {
        self.zip(o, |a, b| a + b)
    }
    /// Elementwise subtract (broadcasting).
    pub fn sub_t(&self, o: &Tensor) -> Result<Tensor> {
        self.zip(o, |a, b| a - b)
    }
    /// Elementwise multiply (broadcasting).
    pub fn mul_t(&self, o: &Tensor) -> Result<Tensor> {
        self.zip(o, |a, b| a * b)
    }
    /// Elementwise divide (broadcasting).
    pub fn div_t(&self, o: &Tensor) -> Result<Tensor> {
        self.zip(o, |a, b| a / b)
    }
    /// Add a scalar.
    pub fn add_scalar(&self, s: f32) -> Tensor {
        self.map(|x| x + s)
    }
    /// Multiply by a scalar.
    pub fn mul_scalar(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_shape_ops() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![10., 20., 30., 40.]).unwrap();
        assert_eq!(a.add_t(&b).unwrap().data(), &[11., 22., 33., 44.]);
        assert_eq!(b.sub_t(&a).unwrap().data(), &[9., 18., 27., 36.]);
        assert_eq!(a.mul_t(&a).unwrap().data(), &[1., 4., 9., 16.]);
    }

    #[test]
    fn broadcast_row_and_col() {
        let m = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let row = Tensor::from_vec(&[3], vec![10., 20., 30.]).unwrap();
        let got = m.add_t(&row).unwrap();
        assert_eq!(got.data(), &[11., 22., 33., 14., 25., 36.]);
        let col = Tensor::from_vec(&[2, 1], vec![100., 200.]).unwrap();
        let got = m.add_t(&col).unwrap();
        assert_eq!(got.data(), &[101., 102., 103., 204., 205., 206.]);
    }

    #[test]
    fn broadcast_scalar_tensor() {
        let m = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let s = Tensor::scalar(2.0);
        assert_eq!(m.mul_t(&s).unwrap().data(), &[2., 4., 6., 8.]);
    }

    #[test]
    fn broadcast_mismatch_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 4]);
        assert!(a.add_t(&b).is_err());
    }

    #[test]
    fn map_preserves_shape() {
        let a = Tensor::from_vec(&[3], vec![-1., 0., 2.]).unwrap();
        let r = a.map(|x| x.max(0.0));
        assert_eq!(r.data(), &[0., 0., 2.]);
    }
}
