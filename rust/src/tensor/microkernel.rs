//! Packed register-tiled GEMM microkernel (perf core, bit-neutral).
//!
//! Where real BLAS speed comes from, translated under RepDL's ordering
//! constraint: **pack** B into contiguous cache-aligned column panels
//! once, then run a fixed-size **register tile** whose inner loops have
//! no bounds checks and fully vectorise. Both transformations are
//! invisible at the bit level *by construction*:
//!
//! * **Packing is layout-only.** [`pack_b_panels`] copies B's values
//!   into [`NR`]-wide panels; no arithmetic happens, so no rounding can
//!   change. Panel tails are zero-filled — those lanes compute columns
//!   that are never written back (columns are independent summation
//!   tasks; discarding a padded one cannot affect a real one).
//! * **Tiling reorders only independent elements.** Inside a tile the
//!   k-loop is outermost and all [`MR`]`×`[`NR`] accumulators advance
//!   together, but each accumulator `(r, j)` still receives exactly the
//!   sequence `acc += a[r,k]·b[k,j]` for `k = 0, 1, …` — the identical
//!   unfused sequential-k graph of [`crate::rnum::dot::dot_strided`].
//!   Interleaving work *between* output elements is unobservable because
//!   IEEE-754 ops are deterministic functions of their operands and no
//!   element reads another's accumulator.
//!
//! Hence `packed GEMM == blocked GEMM == per-element dot form`, bit for
//! bit — asserted by unit tests here, the conformance suites under
//! `rust/tests/`, and the randomized properties in
//! `tests/packed_fast_paths.rs`. The same microkernel backs the fused
//! im2col convolution (`tensor/conv.rs`) and the serving fast path
//! (`coordinator/serve.rs`), which emit or pre-pack their B operands
//! directly in panel form.

use super::par::par_chunks_in;
use super::pool::WorkerPool;

/// Register-tile rows (output rows accumulated together per block).
pub const MR: usize = 8;
/// Register-tile columns = panel width. An MR×NR f32 accumulator tile is
/// 8×16×4 B = 512 B — it fits the 16 × 256-bit vector register file of
/// an AVX2-class core exactly, so the inner loops keep every accumulator
/// in registers.
pub const NR: usize = 16;

/// f32 slots needed to pack a `k × n` B matrix into NR-wide panels.
pub fn packed_b_len(k: usize, n: usize) -> usize {
    n.div_ceil(NR) * k * NR
}

/// Pack row-major B (`k × n`) into column panels: panel `p` holds
/// columns `[p·NR, p·NR + NR)` as `packed[(p·k + kk)·NR + j] = B[kk,
/// p·NR + j]`, so the microkernel streams one contiguous NR-row per k
/// step. Columns past `n` are zero-filled (their results are discarded
/// — see module docs). Parallel over panels on `pool`; `packed` must be
/// exactly [`packed_b_len`]`(k, n)` long and is fully overwritten.
pub fn pack_b_panels(pool: &WorkerPool, bd: &[f32], k: usize, n: usize, packed: &mut [f32]) {
    debug_assert_eq!(bd.len(), k * n);
    debug_assert_eq!(packed.len(), packed_b_len(k, n));
    par_chunks_in(pool, packed, k * NR, |start, panel| {
        let j0 = (start / (k * NR).max(1)) * NR;
        let w = NR.min(n - j0);
        for kk in 0..k {
            let dst = &mut panel[kk * NR..kk * NR + NR];
            dst[..w].copy_from_slice(&bd[kk * n + j0..kk * n + j0 + w]);
            for v in &mut dst[w..] {
                *v = 0.0;
            }
        }
    });
}

/// Compute one block of `nrows ≤ MR` output rows against every panel of
/// a packed B: `out[r, j] = Σ_k a[r·k + kk]·B[kk, j]` (+ `bias[r]` once,
/// after the reduction), written for all `j in 0..n`.
///
/// The k-loop is outermost inside the tile and the accumulators live in
/// a fixed-size local array, so each output element sees exactly the
/// sequential-k unfused (or FMA, per `fma`) order — bit-identical to
/// the dot forms in `tensor/matmul.rs`. Every element of `out` is
/// overwritten, so callers never need to pre-clear it.
pub fn gemm_block(
    a_block: &[f32],
    k: usize,
    nrows: usize,
    packed: &[f32],
    n: usize,
    bias: Option<&[f32]>,
    fma: bool,
    out: &mut [f32],
) {
    debug_assert!(nrows >= 1 && nrows <= MR);
    debug_assert!(a_block.len() >= nrows * k);
    debug_assert_eq!(out.len(), nrows * n);
    debug_assert_eq!(packed.len(), packed_b_len(k, n));
    let npanels = n.div_ceil(NR);
    for p in 0..npanels {
        let panel = &packed[p * k * NR..(p + 1) * k * NR];
        let j0 = p * NR;
        let w = NR.min(n - j0);
        let mut acc = [[0.0f32; NR]; MR];
        for kk in 0..k {
            let bv: &[f32; NR] = panel[kk * NR..kk * NR + NR].try_into().unwrap();
            for r in 0..nrows {
                let av = a_block[r * k + kk];
                let arow = &mut acc[r];
                if fma {
                    for j in 0..NR {
                        arow[j] = av.mul_add(bv[j], arow[j]);
                    }
                } else {
                    for j in 0..NR {
                        arow[j] += av * bv[j];
                    }
                }
            }
        }
        for r in 0..nrows {
            let dst = &mut out[r * n + j0..r * n + j0 + w];
            match bias {
                Some(bs) => {
                    for (j, d) in dst.iter_mut().enumerate() {
                        *d = acc[r][j] + bs[r];
                    }
                }
                None => dst.copy_from_slice(&acc[r][..w]),
            }
        }
    }
}

/// Full packed GEMM into a caller-provided output region:
/// `out (m × n) = A (m × k) · B` with B already in panel form,
/// parallelised over MR-row blocks on `pool`. `bias`, when given, is a
/// per-output-row addend of length `m` (the conv bias). Every element of
/// `out` is written exactly once; no pre-clearing needed.
pub fn gemm_packed_into(
    pool: &WorkerPool,
    a: &[f32],
    m: usize,
    k: usize,
    packed: &[f32],
    n: usize,
    bias: Option<&[f32]>,
    fma: bool,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    par_chunks_in(pool, out, MR * n, |start, rows| {
        let i0 = start / n;
        let nrows = rows.len() / n;
        gemm_block(
            &a[i0 * k..(i0 + nrows) * k],
            k,
            nrows,
            packed,
            n,
            bias.map(|b| &b[i0..i0 + nrows]),
            fma,
            rows,
        );
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rnum::dot::{dot_strided, dot_strided_fma};

    fn lcg(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 2.0
            })
            .collect()
    }

    fn dotform(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, fma: bool) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[i * n + j] = if fma {
                    dot_strided_fma(&a[i * k..], 1, &b[j..], n, k)
                } else {
                    dot_strided(&a[i * k..], 1, &b[j..], n, k)
                };
            }
        }
        out
    }

    #[test]
    fn packing_is_a_pure_relayout() {
        let pool = WorkerPool::new(3);
        let (k, n) = (5, 37); // n straddles two panels + a ragged tail
        let b = lcg(k * n, 7);
        let mut packed = vec![f32::NAN; packed_b_len(k, n)];
        pack_b_panels(&pool, &b, k, n, &mut packed);
        for p in 0..n.div_ceil(NR) {
            for kk in 0..k {
                for j in 0..NR {
                    let got = packed[(p * k + kk) * NR + j];
                    let want = if p * NR + j < n { b[kk * n + p * NR + j] } else { 0.0 };
                    assert_eq!(got.to_bits(), want.to_bits(), "p={p} kk={kk} j={j}");
                }
            }
        }
    }

    #[test]
    fn microkernel_matches_dot_strided_bitwise() {
        let pool = WorkerPool::new(4);
        // shapes straddling every MR/NR boundary, plus degenerate k
        for (m, k, n) in [
            (1usize, 1usize, 1usize),
            (7, 13, 15),
            (8, 13, 16),
            (9, 13, 17),
            (16, 40, 31),
            (17, 40, 33),
            (3, 1, 100),
            (MR, 64, NR * 3),
        ] {
            let a = lcg(m * k, (m * 7 + n) as u64);
            let b = lcg(k * n, (n * 13 + k) as u64);
            let mut packed = vec![0.0f32; packed_b_len(k, n)];
            pack_b_panels(&pool, &b, k, n, &mut packed);
            for fma in [false, true] {
                let mut out = vec![f32::NAN; m * n];
                gemm_packed_into(&pool, &a, m, k, &packed, n, None, fma, &mut out);
                let want = dotform(&a, &b, m, k, n, fma);
                assert!(
                    out.iter().zip(want.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "m={m} k={k} n={n} fma={fma}"
                );
            }
        }
    }

    #[test]
    fn bias_is_added_once_after_the_reduction() {
        let pool = WorkerPool::new(2);
        let (m, k, n) = (10, 6, 20);
        let a = lcg(m * k, 1);
        let b = lcg(k * n, 2);
        let bias = lcg(m, 3);
        let mut packed = vec![0.0f32; packed_b_len(k, n)];
        pack_b_panels(&pool, &b, k, n, &mut packed);
        let mut out = vec![0.0f32; m * n];
        gemm_packed_into(&pool, &a, m, k, &packed, n, Some(&bias), false, &mut out);
        let plain = dotform(&a, &b, m, k, n, false);
        for i in 0..m {
            for j in 0..n {
                let want = plain[i * n + j] + bias[i];
                assert_eq!(out[i * n + j].to_bits(), want.to_bits(), "i={i} j={j}");
            }
        }
    }

    #[test]
    fn pool_size_never_changes_bits() {
        let (m, k, n) = (23, 31, 45);
        let a = lcg(m * k, 11);
        let b = lcg(k * n, 12);
        let run = |lanes: usize| {
            let pool = WorkerPool::new(lanes);
            let mut packed = vec![0.0f32; packed_b_len(k, n)];
            pack_b_panels(&pool, &b, k, n, &mut packed);
            let mut out = vec![0.0f32; m * n];
            gemm_packed_into(&pool, &a, m, k, &packed, n, None, false, &mut out);
            out
        };
        let base = run(1);
        for lanes in [2, 3, 5, 8, 16] {
            let got = run(lanes);
            assert!(
                base.iter().zip(got.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "lanes={lanes}"
            );
        }
    }

    #[test]
    fn zero_k_yields_the_empty_sum() {
        let pool = WorkerPool::new(2);
        let (m, k, n) = (4, 0, 9);
        let packed = vec![0.0f32; packed_b_len(k, n)];
        let mut out = vec![f32::NAN; m * n];
        gemm_packed_into(&pool, &[], m, k, &packed, n, None, false, &mut out);
        assert!(out.iter().all(|v| v.to_bits() == 0.0f32.to_bits()));
    }
}
