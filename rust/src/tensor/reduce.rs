//! Axis reductions with specified association order (paper §3.2.2).
//!
//! `sum_axis` reduces sequentially along the axis; `sum_axis_pairwise` is
//! the separately-named pairwise variant. `mean`/`var` are **fixed
//! computation graphs** (paper §3.2.3): mean = sum/n, var = sum((x−μ)²)/n
//! (two-pass, biased) — the one-pass E[x²]−E[x]² graph would be a
//! different API if ever added.
//!
//! All reductions dispatch over *independent output elements* on the
//! persistent [`WorkerPool`] (each element's reduction order stays
//! fixed, so pool size never changes bits); the `*_in` variants take an
//! explicit pool.
//!
//! ## Degenerate axes (error, not panic / not NaN)
//!
//! A zero-length reduced axis has a well-defined *sum* (the empty sum,
//! exactly `0.0` — [`sum_axis`] keeps that), but no maximum and no mean:
//! [`max_axis`], [`argmax_last`], [`mean_axis`] and [`var_axis`] return
//! [`Error::shape`] instead of reading out of bounds (`w[0]`, the seed's
//! panic) or silently emitting NaN from `0/0`.
//!
//! ## The deterministic tie/NaN rule (single source of truth)
//!
//! Comparison reductions share one fixed rule, implemented once in
//! [`max_wins`]: **NaN beats every number, and the first occurrence
//! wins** — among equal maxima and among NaNs alike (so `max_axis`
//! keeps the first NaN's payload bits and `argmax_last` reports the
//! first NaN's index). This makes the two APIs agree: the index
//! `argmax_last` picks always holds the value `max_axis` returns. Since
//! the NaN-rule unification migration (DESIGN.md §8) the same function
//! drives every other reproducible max scan too — max pooling, the
//! softmax/log-softmax/attention row maxes and the cross-entropy tape
//! max; only `baseline/` intentionally keeps plain `v > m`.
//!
//! Both seed implementations contradicted the rule the seed itself
//! documented ("NaN wins, …, first occurrence"): `argmax_last` used
//! plain `v > best`, under which NaN *never* won, and `max_axis` let
//! every later NaN overwrite the accumulator, keeping the *last* NaN's
//! payload/sign bits. Aligning both to the documented rule is a
//! bit-visible in-place fix only for rows holding ≥ 2 NaNs with
//! differing payloads (spec-conformance bugfix, not a new reduction
//! graph — so no new API name per DESIGN.md §2).

use super::par::par_chunks_in;
use super::pool::{global_pool, WorkerPool};
use super::tensor::Tensor;
use crate::rnum::sum::pairwise_split;
use crate::{Error, Result};

/// Iterate (outer, inner) decomposition around `axis`:
/// shape = [outer..., axis_len, inner...] flattened.
fn axis_geometry(t: &Tensor, axis: usize) -> Result<(usize, usize, usize)> {
    let d = t.dims();
    if axis >= d.len() {
        return Err(Error::shape(format!("axis {axis} out of range for {d:?}")));
    }
    let outer: usize = d[..axis].iter().product();
    let len = d[axis];
    let inner: usize = d[axis + 1..].iter().product();
    Ok((outer, len, inner))
}

fn reduced_dims(t: &Tensor, axis: usize) -> Vec<usize> {
    let mut nd: Vec<usize> = t.dims().to_vec();
    nd.remove(axis);
    nd
}

/// Chunk size for parallel reductions: batch tiny per-element
/// reductions so one task is ≳1k scalar ops (any chunking is
/// bit-neutral — elements are independent).
fn reduce_chunk(len: usize) -> usize {
    (1024 / len.max(1)).max(1)
}

fn reduce_with_in(
    pool: &WorkerPool,
    t: &Tensor,
    axis: usize,
    f: impl Fn(&[f32], usize, usize) -> f32 + Sync, // (data window, stride, len)
) -> Result<Tensor> {
    let (_outer, len, inner) = axis_geometry(t, axis)?;
    let data = t.data();
    let inner1 = inner.max(1);
    // every element written exactly once; filled_by adds no extra sweep
    let out = Tensor::filled_by(&reduced_dims(t, axis), |buf| {
        par_chunks_in(pool, buf, reduce_chunk(len), |start, c| {
            for (off, v) in c.iter_mut().enumerate() {
                let e = start + off; // flat output index = o * inner + i
                let (o, i) = (e / inner1, e % inner1);
                let base = o * len * inner + i;
                *v = f(&data[base..], inner, len);
            }
        });
    });
    Ok(out)
}

/// Sequential sum along `axis` (RepDL default order).
pub fn sum_axis(t: &Tensor, axis: usize) -> Result<Tensor> {
    sum_axis_in(global_pool(), t, axis)
}

/// [`sum_axis`] on an explicit pool.
pub fn sum_axis_in(pool: &WorkerPool, t: &Tensor, axis: usize) -> Result<Tensor> {
    reduce_with_in(pool, t, axis, |w, s, n| {
        let mut acc = 0.0f32;
        for k in 0..n {
            acc += w[k * s];
        }
        acc
    })
}

/// Pairwise sum along `axis` (alternative order, own API; tree shape
/// shared with `rnum::sum::sum_pairwise`).
pub fn sum_axis_pairwise(t: &Tensor, axis: usize) -> Result<Tensor> {
    sum_axis_pairwise_in(global_pool(), t, axis)
}

/// [`sum_axis_pairwise`] on an explicit pool.
pub fn sum_axis_pairwise_in(pool: &WorkerPool, t: &Tensor, axis: usize) -> Result<Tensor> {
    fn pw(w: &[f32], s: usize, n: usize) -> f32 {
        if n <= 8 {
            let mut acc = 0.0f32;
            for k in 0..n {
                acc += w[k * s];
            }
            return acc;
        }
        let m = pairwise_split(n);
        pw(w, s, m) + pw(&w[m * s..], s, n - m)
    }
    reduce_with_in(pool, t, axis, pw)
}

/// Reject a zero-length reduced axis for reductions that have no
/// identity (max) or divide by the length (mean, var) — see module docs.
fn check_nonempty_axis(t: &Tensor, axis: usize, op: &str) -> Result<(usize, usize, usize)> {
    let geo = axis_geometry(t, axis)?;
    if geo.1 == 0 {
        return Err(Error::shape(format!(
            "{op}: axis {axis} of {:?} has length 0 — undefined for this reduction",
            t.dims()
        )));
    }
    Ok(geo)
}

/// Mean along `axis`: the fixed graph `sum / n`. Errors on a zero-length
/// axis (`0/0` would silently be NaN).
pub fn mean_axis(t: &Tensor, axis: usize) -> Result<Tensor> {
    mean_axis_in(global_pool(), t, axis)
}

/// [`mean_axis`] on an explicit pool.
pub fn mean_axis_in(pool: &WorkerPool, t: &Tensor, axis: usize) -> Result<Tensor> {
    let (_, len, _) = check_nonempty_axis(t, axis, "mean_axis")?;
    let s = sum_axis_in(pool, t, axis)?;
    Ok(s.map(|v| v / len as f32))
}

/// Biased variance along `axis`: the fixed two-pass graph
/// `sum((x − mean)²) / n` with sequential sums. Errors on a zero-length
/// axis (`0/0` would silently be NaN).
pub fn var_axis(t: &Tensor, axis: usize) -> Result<Tensor> {
    var_axis_in(global_pool(), t, axis)
}

/// [`var_axis`] on an explicit pool.
pub fn var_axis_in(pool: &WorkerPool, t: &Tensor, axis: usize) -> Result<Tensor> {
    let (_outer, len, inner) = check_nonempty_axis(t, axis, "var_axis")?;
    let mean = mean_axis_in(pool, t, axis)?;
    let data = t.data();
    let mean_d = mean.data();
    let inner1 = inner.max(1);
    let out = Tensor::filled_by(&reduced_dims(t, axis), |buf| {
        par_chunks_in(pool, buf, reduce_chunk(len), |start, c| {
            for (off, v) in c.iter_mut().enumerate() {
                let e = start + off;
                let (o, i) = (e / inner1, e % inner1);
                let base = o * len * inner + i;
                let mu = mean_d[e];
                let mut acc = 0.0f32;
                for k in 0..len {
                    let d = data[base + k * inner] - mu;
                    acc += d * d;
                }
                *v = acc / len as f32;
            }
        });
    });
    Ok(out)
}

/// The shared comparison-reduction update rule (see module docs): does
/// candidate `v` displace the current winner `cur`? NaN beats every
/// number; otherwise only strictly-greater wins, so the *first* of equal
/// maxima — and the first NaN — is kept.
///
/// This is the **single source of truth** for every reproducible max
/// scan in the crate. Since the NaN-rule unification migration
/// (DESIGN.md §8), `max_pool2d`'s in-window scan, the `nn::softmax`
/// row maxes, the attention score max and the cross-entropy tape max
/// all route through it — only `baseline/` keeps the old plain `v > m`
/// scan, because it models the non-reproducible conventional stack.
#[inline]
pub fn max_wins(v: f32, cur: f32) -> bool {
    (v.is_nan() && !cur.is_nan()) || v > cur
}

/// Maximum along `axis` (fixed comparison order; tie/NaN rule in the
/// module docs — NaN wins, first occurrence kept). Errors on a
/// zero-length axis, which has no maximum.
pub fn max_axis(t: &Tensor, axis: usize) -> Result<Tensor> {
    max_axis_in(global_pool(), t, axis)
}

/// [`max_axis`] on an explicit pool.
pub fn max_axis_in(pool: &WorkerPool, t: &Tensor, axis: usize) -> Result<Tensor> {
    check_nonempty_axis(t, axis, "max_axis")?;
    reduce_with_in(pool, t, axis, |w, s, n| {
        let mut m = w[0];
        for k in 1..n {
            let v = w[k * s];
            if max_wins(v, m) {
                m = v;
            }
        }
        m
    })
}

/// Argmax over the last axis — same tie/NaN rule as [`max_axis`] (module
/// docs): the returned index always holds the value `max_axis` would
/// return for that row. Errors on a zero-length last axis.
pub fn argmax_last(t: &Tensor) -> Result<Vec<usize>> {
    let d = t.dims();
    if d.is_empty() {
        return Err(Error::shape("argmax_last on scalar"));
    }
    let n = *d.last().unwrap();
    if n == 0 {
        return Err(Error::shape(format!(
            "argmax_last: zero-length last axis of {d:?} has no argmax"
        )));
    }
    let rows = t.numel() / n;
    let mut out = Vec::with_capacity(rows);
    for r in 0..rows {
        let w = &t.data()[r * n..(r + 1) * n];
        let mut best = 0usize;
        for (k, &v) in w.iter().enumerate().skip(1) {
            if max_wins(v, w[best]) {
                best = k;
            }
        }
        out.push(best);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t123() -> Tensor {
        Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap()
    }

    #[test]
    fn sum_axes() {
        let t = t123();
        assert_eq!(sum_axis(&t, 0).unwrap().data(), &[5., 7., 9.]);
        assert_eq!(sum_axis(&t, 1).unwrap().data(), &[6., 15.]);
        assert!(sum_axis(&t, 2).is_err());
    }

    #[test]
    fn sum_3d_middle_axis() {
        let t = Tensor::from_vec(&[2, 2, 2], (1..=8).map(|v| v as f32).collect()).unwrap();
        let s = sum_axis(&t, 1).unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.data(), &[4., 6., 12., 14.]);
    }

    #[test]
    fn mean_and_var_graphs() {
        let t = t123();
        assert_eq!(mean_axis(&t, 1).unwrap().data(), &[2., 5.]);
        // var([1,2,3]) biased = 2/3
        let v = var_axis(&t, 1).unwrap();
        assert!((v.data()[0] - 2.0 / 3.0).abs() < 1e-7);
        assert!((v.data()[1] - 2.0 / 3.0).abs() < 1e-7);
    }

    #[test]
    fn pairwise_vs_sequential_determinism() {
        let n = 1000;
        let data: Vec<f32> = (0..n).map(|i| ((i * 37 % 113) as f32 - 56.0) * 0.01).collect();
        let t = Tensor::from_vec(&[1, n], data).unwrap();
        let s = sum_axis(&t, 1).unwrap();
        let p = sum_axis_pairwise(&t, 1).unwrap();
        assert!(s.bit_eq(&sum_axis(&t, 1).unwrap()));
        assert!(p.bit_eq(&sum_axis_pairwise(&t, 1).unwrap()));
        assert!((s.data()[0] - p.data()[0]).abs() < 1e-2);
    }

    #[test]
    fn pairwise_matches_rnum_spec() {
        let data: Vec<f32> = (0..777).map(|i| (i as f32).sin_cos().0 * 0.1).collect();
        let t = Tensor::from_vec(&[777], data.clone()).unwrap();
        let via_tensor = sum_axis_pairwise(&t, 0).unwrap().data()[0];
        let via_rnum = crate::rnum::sum::sum_pairwise(&data);
        assert_eq!(via_tensor.to_bits(), via_rnum.to_bits());
    }

    #[test]
    fn pool_size_invariance() {
        let data: Vec<f32> = (0..6 * 35).map(|i| ((i * 53 % 97) as f32 - 48.0) * 0.013).collect();
        let t = Tensor::from_vec(&[6, 35], data).unwrap();
        for axis in [0usize, 1] {
            let one_seq = sum_axis_in(&WorkerPool::new(1), &t, axis).unwrap();
            let one_pw = sum_axis_pairwise_in(&WorkerPool::new(1), &t, axis).unwrap();
            for lanes in [2, 3, 8] {
                let pool = WorkerPool::new(lanes);
                assert!(one_seq.bit_eq(&sum_axis_in(&pool, &t, axis).unwrap()));
                assert!(one_pw.bit_eq(&sum_axis_pairwise_in(&pool, &t, axis).unwrap()));
            }
        }
    }

    #[test]
    fn max_and_argmax() {
        let t = Tensor::from_vec(&[2, 3], vec![3., 1., 3., -5., -1., -1.]).unwrap();
        assert_eq!(max_axis(&t, 1).unwrap().data(), &[3., -1.]);
        // deterministic first-max tie rule
        assert_eq!(argmax_last(&t).unwrap(), vec![0, 1]);
        let nan = Tensor::from_vec(&[1, 2], vec![1.0, f32::NAN]).unwrap();
        assert!(max_axis(&nan, 1).unwrap().data()[0].is_nan());
    }

    #[test]
    fn max_and_argmax_agree_on_nans() {
        // one shared rule: NaN wins, first occurrence kept — the index
        // argmax picks must hold the value max_axis returns
        let rows = [
            vec![1.0f32, f32::NAN, 2.0, f32::NAN], // NaN mid-row
            vec![f32::NAN, 5.0, 7.0, 1.0],         // NaN first
            vec![2.0, 7.0, 7.0, 7.0],              // plain tie
        ];
        let want_idx = [1usize, 0, 1];
        for (row, &wi) in rows.iter().zip(want_idx.iter()) {
            let t = Tensor::from_vec(&[1, 4], row.clone()).unwrap();
            let idx = argmax_last(&t).unwrap()[0];
            assert_eq!(idx, wi, "row {row:?}");
            let m = max_axis(&t, 1).unwrap().data()[0];
            assert_eq!(
                m.to_bits(),
                row[idx].to_bits(),
                "argmax index must hold the max_axis value for {row:?}"
            );
        }
    }

    #[test]
    fn zero_length_axes_error_instead_of_panicking_or_nan() {
        let empty = Tensor::zeros(&[2, 0]);
        // no identity / division by zero: shape errors, not panics/NaN
        assert!(max_axis(&empty, 1).is_err());
        assert!(mean_axis(&empty, 1).is_err());
        assert!(var_axis(&empty, 1).is_err());
        assert!(argmax_last(&empty).is_err());
        // the empty *sum* is well-defined: exactly 0.0 per output element
        let s = sum_axis(&empty, 1).unwrap();
        assert_eq!(s.dims(), &[2]);
        assert!(s.data().iter().all(|v| v.to_bits() == 0.0f32.to_bits()));
        assert!(sum_axis_pairwise(&empty, 1).unwrap().bit_eq(&s));
        // reducing an axis of a fully-empty tensor stays fine when the
        // *output* is empty (nothing is read)
        assert_eq!(sum_axis(&Tensor::zeros(&[0, 3]), 0).unwrap().dims(), &[3]);
        assert!(max_axis(&Tensor::zeros(&[0, 3]), 1).unwrap().numel() == 0);
    }
}
