//! Reproducible GEMM (paper §3.2.2, fully-connected analysis).
//!
//! Specification: `C[i,j] = Σ_k A[i,k]·B[k,j]` with the k-loop strictly
//! sequential (multiply then add, unfused — matching what the JAX/Pallas
//! kernel lowers to). There are `t_fc = M·N` independent summation tasks;
//! parallelism is across those tasks only, so lane count never changes
//! bits — the paper's core efficiency argument (as long as `t_fc` exceeds
//! the core count, fixing the order costs little).
//!
//! Implementation note (perf, bit-neutral): three interchangeable
//! kernels compute the same graph, fastest first.
//!
//! * **Packed** ([`matmul_packed`], default for large shapes): B is
//!   packed once into NR-wide column panels in scratch-arena storage and
//!   an MR×NR register-tiled microkernel runs over it
//!   (`tensor/microkernel.rs`). Packing is layout-only; tiling reorders
//!   only independent elements.
//! * **Blocked** ([`matmul_blocked`], default for small shapes where
//!   packing doesn't amortise): output rows in blocks of [`ROW_BLOCK`],
//!   columns in blocks of [`COL_BLOCK`], k-loop outermost inside each
//!   block so every B row-segment is reused across the block's rows.
//! * **Dot form** ([`matmul_dotform`]): the pre-optimisation per-element
//!   reference, kept for the bit-equality regression tests and the E5
//!   perf ablation.
//!
//! All three give each output element exactly the sequential-k unfused
//! mul/add graph, so they are bit-identical — asserted in unit tests,
//! the property suites (`src/proptest.rs`, `tests/packed_fast_paths.rs`)
//! and the `pool_invariance` conformance suite.
//!
//! Every kernel has an `*_in` variant taking an explicit
//! [`WorkerPool`]; the plain names dispatch on the global pool. The
//! `pool_invariance` integration suite checks bit-equality across pool
//! sizes for all of them.

use super::microkernel::{gemm_packed_into, pack_b_panels, packed_b_len, MR};
use super::par::par_chunks_in;
use super::pool::{global_pool, WorkerPool};
use super::scratch::scratch_f32;
use super::tensor::Tensor;
use crate::rnum::dot::{dot_strided, dot_strided_fma, dot_strided_pairwise};
use crate::{Error, Result};

/// Output rows per parallel task (one i-block).
const ROW_BLOCK: usize = 8;
/// Columns per j-block: 256 f32 = 1 KiB per accumulator row; an 8-row
/// accumulator panel is 8 KiB — comfortably L1 — and each B row-segment
/// (1 KiB) is reused across all 8 rows before eviction.
const COL_BLOCK: usize = 256;
/// Routing threshold: packed pays one extra pass over B (the pack), so
/// it wins once the `2·m·n·k` flops dominate the `k·n` pack traffic —
/// i.e. for all but small/skinny products. Routing never changes bits
/// (both kernels compute the identical graph), only wall-clock.
const PACKED_MIN_WORK: usize = 64 * 1024;

fn check_dims(a: &Tensor, b: &Tensor) -> Result<(usize, usize, usize)> {
    let (da, db) = (a.dims(), b.dims());
    if da.len() != 2 || db.len() != 2 || da[1] != db[0] {
        return Err(Error::shape(format!(
            "matmul: incompatible shapes {da:?} x {db:?}"
        )));
    }
    Ok((da[0], da[1], db[1]))
}

/// Cache-blocked k-outer row kernel (perf form of the sequential spec).
///
/// Within one (i-block, j-block) tile the k loop is outermost and all
/// block elements accumulate simultaneously: `acc[r][j] += A[i0+r,k]·B[k,j]`.
/// Each output element still sees exactly the sequential-k order with the
/// chosen mul/add graph — blocking and loop interchange only reorder
/// *independent* elements' work, so results are bit-identical to the
/// per-element dot (asserted in tests) while the inner j-loop
/// auto-vectorises and B stays cache-resident.
fn matmul_rowkernel_in(pool: &WorkerPool, a: &Tensor, b: &Tensor, fma: bool) -> Result<Tensor> {
    let (m, k, n) = check_dims(a, b)?;
    let (ad, bd) = (a.data(), b.data());
    // single zeroing: `filled_by` hands each task calloc-zeroed rows to
    // accumulate onto directly (the old code zeroed a second time here)
    let out = Tensor::filled_by(&[m, n], |buf| {
        par_chunks_in(pool, buf, ROW_BLOCK * n.max(1), |start, rows| {
            let i0 = start / n;
            let nrows = rows.len() / n;
            for jb in (0..n).step_by(COL_BLOCK) {
                let jn = COL_BLOCK.min(n - jb);
                for kk in 0..k {
                    let brow = &bd[kk * n + jb..kk * n + jb + jn];
                    for r in 0..nrows {
                        let aik = ad[(i0 + r) * k + kk];
                        let acc = &mut rows[r * n + jb..r * n + jb + jn];
                        if fma {
                            for (v, &bv) in acc.iter_mut().zip(brow) {
                                *v = aik.mul_add(bv, *v);
                            }
                        } else {
                            for (v, &bv) in acc.iter_mut().zip(brow) {
                                *v += aik * bv;
                            }
                        }
                    }
                }
            }
        });
    });
    Ok(out)
}

/// Packed register-tiled kernel: pack B into panels (scratch-arena
/// storage, reused across calls), then run the MR×NR microkernel.
fn matmul_packkernel_in(pool: &WorkerPool, a: &Tensor, b: &Tensor, fma: bool) -> Result<Tensor> {
    let (m, k, n) = check_dims(a, b)?;
    if m == 0 || n == 0 {
        return Ok(Tensor::zeros(&[m, n]));
    }
    let (ad, bd) = (a.data(), b.data());
    let mut packed = scratch_f32(packed_b_len(k, n));
    pack_b_panels(pool, bd, k, n, &mut packed);
    Ok(Tensor::filled_by(&[m, n], |buf| {
        gemm_packed_into(pool, ad, m, k, &packed, n, None, fma, buf);
    }))
}

fn matmul_routed_in(pool: &WorkerPool, a: &Tensor, b: &Tensor, fma: bool) -> Result<Tensor> {
    let (m, k, n) = check_dims(a, b)?;
    if m >= MR && m * k * n >= PACKED_MIN_WORK {
        matmul_packkernel_in(pool, a, b, fma)
    } else {
        matmul_rowkernel_in(pool, a, b, fma)
    }
}

fn matmul_with_in(
    pool: &WorkerPool,
    a: &Tensor,
    b: &Tensor,
    dot: impl Fn(&[f32], &[f32], usize) -> f32 + Sync,
) -> Result<Tensor> {
    let (m, k, n) = check_dims(a, b)?;
    let bt = b.transpose2d()?; // layout-only change; order-neutral
    let (ad, btd) = (a.data(), bt.data());
    let out = Tensor::filled_by(&[m, n], |buf| {
        par_chunks_in(pool, buf, n.max(1), |start, c| {
            let i = start / n;
            for (j, v) in c.iter_mut().enumerate() {
                *v = dot(&ad[i * k..(i + 1) * k], &btd[j * k..(j + 1) * k], k);
            }
        });
    });
    Ok(out)
}

/// RepDL default GEMM: sequential-k, unfused multiply-add. Routes
/// between the packed and blocked kernels by size (bit-identical
/// either way; global pool).
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_in(global_pool(), a, b)
}

/// [`matmul`] on an explicit pool.
pub fn matmul_in(pool: &WorkerPool, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_routed_in(pool, a, b, false)
}

/// Packed register-tiled GEMM (perf form; bit-identical to [`matmul`]
/// and [`matmul_dotform`] — the E5 ablation measures the three side by
/// side).
pub fn matmul_packed(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_packed_in(global_pool(), a, b)
}

/// [`matmul_packed`] on an explicit pool.
pub fn matmul_packed_in(pool: &WorkerPool, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_packkernel_in(pool, a, b, false)
}

/// Cache-blocked k-outer GEMM (the PR-1 kernel, kept as an explicitly
/// addressable ablation stage and as the small-shape route).
pub fn matmul_blocked(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_blocked_in(global_pool(), a, b)
}

/// [`matmul_blocked`] on an explicit pool.
pub fn matmul_blocked_in(pool: &WorkerPool, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_rowkernel_in(pool, a, b, false)
}

/// GEMM with FMA contraction (separate API; paper §3.2.4 allows FMA).
pub fn matmul_fma(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_fma_in(global_pool(), a, b)
}

/// [`matmul_fma`] on an explicit pool.
pub fn matmul_fma_in(pool: &WorkerPool, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_routed_in(pool, a, b, true)
}

/// The per-element dot formulation (pre-optimisation reference; kept for
/// the bit-equality regression tests and the perf ablation in §Perf).
pub fn matmul_dotform(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_dotform_in(global_pool(), a, b)
}

/// [`matmul_dotform`] on an explicit pool.
pub fn matmul_dotform_in(pool: &WorkerPool, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_with_in(pool, a, b, |x, y, k| dot_strided(x, 1, y, 1, k))
}

/// Per-element FMA dot formulation (ablation partner of [`matmul_fma`]).
pub fn matmul_fma_dotform(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_fma_dotform_in(global_pool(), a, b)
}

/// [`matmul_fma_dotform`] on an explicit pool.
pub fn matmul_fma_dotform_in(pool: &WorkerPool, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_with_in(pool, a, b, |x, y, k| dot_strided_fma(x, 1, y, 1, k))
}

/// GEMM with the pairwise reduction order (separate API; paper §3.2.2's
/// "alternative version" for parallelism-starved shapes).
pub fn matmul_pairwise(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_pairwise_in(global_pool(), a, b)
}

/// [`matmul_pairwise`] on an explicit pool.
pub fn matmul_pairwise_in(pool: &WorkerPool, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    matmul_with_in(pool, a, b, |x, y, k| dot_strided_pairwise(x, 1, y, 1, k))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg_tensor(dims: &[usize], seed: u64) -> Tensor {
        let n: usize = dims.iter().product();
        let mut s = seed;
        let data: Vec<f32> = (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 2.0
            })
            .collect();
        Tensor::from_vec(dims, data).unwrap()
    }

    /// Reference: naive triple loop, strided B access, no transpose.
    fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.dims()[0], a.dims()[1], b.dims()[1]);
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += a.data()[i * k + kk] * b.data()[kk * n + j];
                }
                out.data_mut()[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn small_known_product() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![5., 6., 7., 8.]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn rowkernel_equals_dotform_bitwise() {
        // the perf loop-interchange must not change a single bit
        let a = lcg_tensor(&[23, 77], 8);
        let b = lcg_tensor(&[77, 19], 9);
        assert!(matmul(&a, &b).unwrap().bit_eq(&matmul_dotform(&a, &b).unwrap()));
        assert!(matmul_fma(&a, &b)
            .unwrap()
            .bit_eq(&matmul_fma_dotform(&a, &b).unwrap()));
    }

    #[test]
    fn blocking_is_bit_neutral_across_tile_boundaries() {
        // shapes straddling ROW_BLOCK and COL_BLOCK boundaries: the
        // blocked kernel must agree with the unblocked dot form exactly
        for (m, k, n) in [
            (1usize, 5usize, 1usize),
            (7, 13, 255),
            (8, 31, 256),
            (9, 31, 257),
            (17, 64, 300),
        ] {
            let a = lcg_tensor(&[m, k], (m * 1000 + n) as u64);
            let b = lcg_tensor(&[k, n], (n * 1000 + k) as u64);
            let blocked = matmul(&a, &b).unwrap();
            let dotform = matmul_dotform(&a, &b).unwrap();
            assert!(blocked.bit_eq(&dotform), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn packed_equals_dotform_across_tile_boundaries() {
        // shapes straddling the microkernel's MR/NR boundaries: packing
        // + register tiling must not move a single bit
        for (m, k, n) in [
            (1usize, 3usize, 1usize),
            (7, 9, 15),
            (8, 9, 16),
            (9, 9, 17),
            (16, 33, 31),
            (17, 33, 48),
            (24, 64, 100),
        ] {
            let a = lcg_tensor(&[m, k], (m * 131 + n) as u64);
            let b = lcg_tensor(&[k, n], (n * 131 + k) as u64);
            let packed = matmul_packed(&a, &b).unwrap();
            let dotform = matmul_dotform(&a, &b).unwrap();
            let blocked = matmul_blocked(&a, &b).unwrap();
            assert!(packed.bit_eq(&dotform), "packed m={m} k={k} n={n}");
            assert!(blocked.bit_eq(&dotform), "blocked m={m} k={k} n={n}");
        }
    }

    #[test]
    fn size_routing_is_bit_neutral() {
        // large enough that the default route takes the packed kernel
        let a = lcg_tensor(&[40, 80], 31);
        let b = lcg_tensor(&[80, 50], 32);
        assert!(40 * 80 * 50 >= PACKED_MIN_WORK);
        let routed = matmul(&a, &b).unwrap();
        assert!(routed.bit_eq(&matmul_packed(&a, &b).unwrap()));
        assert!(routed.bit_eq(&matmul_blocked(&a, &b).unwrap()));
        assert!(routed.bit_eq(&matmul_dotform(&a, &b).unwrap()));
        let fma = matmul_fma(&a, &b).unwrap();
        assert!(fma.bit_eq(&matmul_fma_dotform(&a, &b).unwrap()));
    }

    #[test]
    fn transpose_optimisation_is_bit_neutral() {
        let a = lcg_tensor(&[17, 33], 1);
        let b = lcg_tensor(&[33, 9], 2);
        let fast = matmul(&a, &b).unwrap();
        let naive = matmul_naive(&a, &b);
        assert!(fast.bit_eq(&naive), "layout change altered bits!");
    }

    #[test]
    fn pool_size_invariance() {
        // explicit pools — no env-var mutation (the seed's set_var here
        // raced with other tests under the parallel harness)
        let a = lcg_tensor(&[31, 64], 3);
        let b = lcg_tensor(&[64, 23], 4);
        let one = matmul_in(&WorkerPool::new(1), &a, &b).unwrap();
        for lanes in [2, 5, 16] {
            let pool = WorkerPool::new(lanes);
            assert!(one.bit_eq(&matmul_in(&pool, &a, &b).unwrap()), "lanes={lanes}");
            assert!(matmul_fma_in(&WorkerPool::new(1), &a, &b)
                .unwrap()
                .bit_eq(&matmul_fma_in(&pool, &a, &b).unwrap()));
        }
    }

    #[test]
    fn variants_are_distinct_specs() {
        let a = lcg_tensor(&[24, 100], 5);
        let b = lcg_tensor(&[100, 24], 6);
        let seq = matmul(&a, &b).unwrap();
        let fma = matmul_fma(&a, &b).unwrap();
        let pw = matmul_pairwise(&a, &b).unwrap();
        // each deterministic
        assert!(seq.bit_eq(&matmul(&a, &b).unwrap()));
        assert!(fma.bit_eq(&matmul_fma(&a, &b).unwrap()));
        assert!(pw.bit_eq(&matmul_pairwise(&a, &b).unwrap()));
        // and at least one pair differs somewhere (k=100 random data)
        assert!(!seq.bit_eq(&fma) || !seq.bit_eq(&pw));
        // numerically close
        for i in 0..seq.numel() {
            assert!((seq.data()[i] - fma.data()[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        assert!(matmul(&a, &b).is_err());
        assert!(matmul(&a, &Tensor::zeros(&[3])).is_err());
    }

    #[test]
    fn identity_and_zero() {
        let a = lcg_tensor(&[5, 5], 7);
        let mut eye = Tensor::zeros(&[5, 5]);
        for i in 0..5 {
            eye.data_mut()[i * 5 + i] = 1.0;
        }
        assert!(matmul(&a, &eye).unwrap().bit_eq(&a));
        let z = Tensor::zeros(&[5, 5]);
        assert!(matmul(&a, &z).unwrap().bit_eq(&z));
    }
}
