//! Order-invariant parallelism (paper §3.2.2).
//!
//! RepDL retains parallelism while fixing reduction order by parallelising
//! only across *independent* output elements: each output element is
//! produced by exactly one lane with a fixed inner order, so the result
//! is identical for every lane count (the E2/E4 experiments verify this
//! bit-for-bit). This is the CPU translation of the paper's "one CUDA
//! thread per summation task, no atomics" design.
//!
//! Execution goes through the persistent [`WorkerPool`] (see
//! [`super::pool`]) instead of spawning scoped threads per call — the
//! hot path no longer pays thread-creation cost per GEMM. The legacy
//! spawn-per-call implementation survives as [`par_chunks_spawn`]: it is
//! the before/after baseline in `benches/e5_overhead.rs` and a second,
//! independently-scheduled implementation for the invariance tests.

pub use super::pool::{default_threads, global_pool, WorkerPool};

/// Process `out` in contiguous chunks of `chunk` elements on an explicit
/// pool. `f(start_index, chunk_slice)` must fill the chunk from
/// read-only context. Bitwise result is independent of the pool size:
/// every chunk is computed by exactly one lane with the order `f` fixes.
pub fn par_chunks_in<F>(pool: &WorkerPool, out: &mut [f32], chunk: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let chunk = chunk.max(1);
    let len = out.len();
    if len == 0 {
        return;
    }
    let nchunks = len.div_ceil(chunk);
    if pool.lanes() == 1 || nchunks == 1 {
        for (ci, c) in out.chunks_mut(chunk).enumerate() {
            f(ci * chunk, c);
        }
        return;
    }
    let base = out.as_mut_ptr() as usize;
    pool.run(nchunks, &|ci| {
        let start = ci * chunk;
        let n = chunk.min(len - start);
        // SAFETY: chunk index `ci` is executed exactly once, chunks
        // [start, start+n) are pairwise disjoint, and `out` outlives
        // `run` (which blocks until every task has finished).
        let slice =
            unsafe { std::slice::from_raw_parts_mut((base as *mut f32).add(start), n) };
        f(start, slice);
    });
}

/// [`par_chunks_in`] on the process-wide pool (sized once from
/// `REPDL_THREADS` — see [`default_threads`]).
pub fn par_chunks<F>(out: &mut [f32], chunk: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    par_chunks_in(global_pool(), out, chunk, f);
}

/// Legacy spawn-per-call implementation (scoped threads created on every
/// invocation). Same chunk semantics and the same static chunk→worker
/// split as the pool, so its bits are identical — kept as the E5
/// benchmark baseline and as an independent cross-check in tests.
pub fn par_chunks_spawn<F>(out: &mut [f32], chunk: usize, nthreads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let chunk = chunk.max(1);
    let nthreads = nthreads.max(1);
    if nthreads == 1 || out.len() <= chunk {
        for (ci, c) in out.chunks_mut(chunk).enumerate() {
            f(ci * chunk, c);
        }
        return;
    }
    let nchunks = out.len().div_ceil(chunk);
    let per_worker = nchunks.div_ceil(nthreads);
    let span = per_worker * chunk; // elements per worker
    std::thread::scope(|s| {
        for (w, piece) in out.chunks_mut(span).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (ci, c) in piece.chunks_mut(chunk).enumerate() {
                    f(w * span + ci * chunk, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(start: usize, c: &mut [f32]) {
        for (i, v) in c.iter_mut().enumerate() {
            let idx = start + i;
            // order-sensitive accumulation inside one element
            let mut acc = 0.0f32;
            for k in 0..64 {
                acc += ((idx * 31 + k * 7) % 101) as f32 * 1e-3;
            }
            *v = acc;
        }
    }

    fn run_pooled(lanes: usize) -> Vec<f32> {
        let pool = WorkerPool::new(lanes);
        let mut out = vec![0.0f32; 1003];
        par_chunks_in(&pool, &mut out, 17, fill);
        out
    }

    #[test]
    fn pool_size_does_not_change_bits() {
        let base = run_pooled(1);
        for n in [2, 3, 4, 7, 16] {
            let got = run_pooled(n);
            assert!(
                base.iter().zip(got.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "lanes={n} diverged"
            );
        }
    }

    #[test]
    fn spawn_impl_matches_pool_impl_bitwise() {
        let base = run_pooled(1);
        for n in [1, 2, 5, 8] {
            let mut out = vec![0.0f32; 1003];
            par_chunks_spawn(&mut out, 17, n, fill);
            assert!(
                base.iter().zip(out.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "spawn nthreads={n} diverged from pool"
            );
        }
    }

    #[test]
    fn covers_every_element() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0.0f32; 100];
        par_chunks_in(&pool, &mut out, 7, |start, c| {
            for (i, v) in c.iter_mut().enumerate() {
                *v = (start + i) as f32;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn adversarial_chunk_sizes() {
        // chunk > len, chunk == len, chunk == 0 (clamped to 1)
        for (len, chunk) in [(5usize, 100usize), (8, 8), (9, 0), (1, 1), (0, 4)] {
            let pool = WorkerPool::new(4);
            let mut out = vec![0.0f32; len];
            par_chunks_in(&pool, &mut out, chunk, |start, c| {
                for (i, v) in c.iter_mut().enumerate() {
                    *v = (start + i) as f32 + 1.0;
                }
            });
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as f32 + 1.0, "len={len} chunk={chunk}");
            }
        }
    }

    #[test]
    fn global_pool_path_works() {
        let mut out = vec![0.0f32; 257];
        par_chunks(&mut out, 13, |start, c| {
            for (i, v) in c.iter_mut().enumerate() {
                *v = ((start + i) * 2) as f32;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i * 2) as f32);
        }
    }
}
