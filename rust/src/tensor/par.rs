//! Order-invariant parallelism (paper §3.2.2).
//!
//! RepDL retains parallelism while fixing reduction order by parallelising
//! only across *independent* output elements: each output element is
//! produced by exactly one worker with a fixed inner order, so the result
//! is identical for every thread count (the E2/E4 experiments verify this
//! bit-for-bit). This is the CPU translation of the paper's "one CUDA
//! thread per summation task, no atomics" design.

use crossbeam_utils::thread;

/// Process `out` in contiguous chunks of `chunk` elements, `nthreads`
/// workers. `f(start_index, chunk_slice)` must fill the chunk from
/// read-only context. Bitwise result is independent of `nthreads`.
pub fn par_chunks<F>(out: &mut [f32], chunk: usize, nthreads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let nthreads = nthreads.max(1);
    if nthreads == 1 || out.len() <= chunk {
        for (ci, c) in out.chunks_mut(chunk).enumerate() {
            f(ci * chunk, c);
        }
        return;
    }
    let nchunks = out.len().div_ceil(chunk);
    let per_worker = nchunks.div_ceil(nthreads);
    let span = per_worker * chunk; // elements per worker
    thread::scope(|s| {
        for (w, piece) in out.chunks_mut(span).enumerate() {
            let f = &f;
            s.spawn(move |_| {
                for (ci, c) in piece.chunks_mut(chunk).enumerate() {
                    f(w * span + ci * chunk, c);
                }
            });
        }
    })
    .expect("worker panicked");
}

/// Number of worker threads to use (overridable via REPDL_THREADS).
pub fn default_threads() -> usize {
    std::env::var("REPDL_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(nthreads: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; 1003];
        par_chunks(&mut out, 17, nthreads, |start, c| {
            for (i, v) in c.iter_mut().enumerate() {
                let idx = start + i;
                // order-sensitive accumulation inside one element
                let mut acc = 0.0f32;
                for k in 0..64 {
                    acc += ((idx * 31 + k * 7) % 101) as f32 * 1e-3;
                }
                *v = acc;
            }
        });
        out
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let base = run(1);
        for n in [2, 3, 4, 7, 16] {
            let got = run(n);
            assert!(
                base.iter().zip(got.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "nthreads={n} diverged"
            );
        }
    }

    #[test]
    fn covers_every_element() {
        let mut out = vec![0.0f32; 100];
        par_chunks(&mut out, 7, 3, |start, c| {
            for (i, v) in c.iter_mut().enumerate() {
                *v = (start + i) as f32;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }
}
