//! Reproducible tensor library — the substrate the paper assumes.
//!
//! Row-major, `f32`, owned storage. Every reduction-bearing operation
//! (GEMM, convolution, axis reductions) has a *specified* association
//! order per paper §3.2.2: sequential by default, pairwise under a
//! separate API name. Parallelism never changes results: work is split
//! over *independent output elements* with a fixed per-element order, so
//! any thread count produces identical bits (verified in tests).

pub mod conv;
pub mod elementwise;
pub mod matmul;
pub mod par;
pub mod reduce;
pub mod shape;
#[allow(clippy::module_inception)]
pub mod tensor;

pub use conv::{avg_pool2d, conv2d, conv2d_direct, conv2d_im2col, max_pool2d, Conv2dParams};
pub use matmul::{matmul, matmul_dotform, matmul_fma, matmul_fma_dotform, matmul_pairwise};
pub use reduce::{argmax_last, max_axis, mean_axis, sum_axis, sum_axis_pairwise, var_axis};
pub use shape::Shape;
pub use tensor::Tensor;
