//! Reproducible tensor library — the substrate the paper assumes.
//!
//! Row-major, `f32`, owned storage. Every reduction-bearing operation
//! (GEMM, convolution, axis reductions) has a *specified* association
//! order per paper §3.2.2: sequential by default, pairwise under a
//! separate API name. Parallelism never changes results: work is split
//! over *independent output elements* with a fixed per-element order, so
//! any lane count produces identical bits (verified in tests and the
//! `pool_invariance` integration suite).
//!
//! Execution runs on the persistent [`pool::WorkerPool`] (lazily
//! created, sized once from `REPDL_THREADS`); every kernel also has an
//! `*_in` variant taking an explicit pool for tests, benchmarks and the
//! `--threads` CLI flag.
//!
//! The perf layer (DESIGN.md §6) — the packed register-tiled GEMM
//! [`microkernel`], the fused im2col convolution pipeline and the
//! thread-local [`scratch`] arena — is bit-neutral by construction:
//! packing/im2col are layout-only, tiling reorders only independent
//! output elements, and scratch contents are always fully overwritten
//! before use.

pub mod conv;
pub mod elementwise;
pub mod matmul;
pub mod microkernel;
pub mod par;
pub mod pool;
pub mod reduce;
pub mod scratch;
pub mod shape;
#[allow(clippy::module_inception)]
pub mod tensor;

pub use conv::{
    avg_pool2d, avg_pool2d_in, conv2d, conv2d_direct, conv2d_direct_in, conv2d_im2col,
    conv2d_im2col_in, conv2d_in, im2col, max_pool2d, max_pool2d_argmax, max_pool2d_in,
    Conv2dParams,
};
pub use matmul::{
    matmul, matmul_blocked, matmul_blocked_in, matmul_dotform, matmul_dotform_in, matmul_fma,
    matmul_fma_dotform, matmul_fma_dotform_in, matmul_fma_in, matmul_in, matmul_packed,
    matmul_packed_in, matmul_pairwise, matmul_pairwise_in,
};
pub use scratch::{scratch_f32, ScratchGuard};
pub use pool::{default_threads, global_pool, global_pool_handle, PoolHandle, WorkerPool};
pub use reduce::{
    argmax_last, max_axis, max_axis_in, max_wins, mean_axis, mean_axis_in, sum_axis,
    sum_axis_in, sum_axis_pairwise, sum_axis_pairwise_in, var_axis, var_axis_in,
};
pub use shape::Shape;
pub use tensor::Tensor;
