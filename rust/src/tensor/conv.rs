//! Reproducible 2-D convolution and pooling (paper §3.2.2, conv analysis).
//!
//! Specification: NCHW input `(B, C, H, W)`, OIHW weight `(O, C, KH, KW)`;
//! each output element is one independent summation task of
//! `n_conv = C·KH·KW` elements, reduced **sequentially in (c, kh, kw)
//! order** with unfused multiply-add. `t_conv = B·O·OH·OW` tasks carry the
//! parallelism (the paper's ResNet-50 worked example: t_conv = B·802816
//! for the 256×56×56 layers — E4 regenerates that table).
//!
//! Two APIs, one spec: [`conv2d`] (direct loops) and [`conv2d_im2col`]
//! (im2col + GEMM). The im2col column ordering is chosen so the GEMM's
//! sequential k-loop visits (c, kh, kw) in exactly the direct order —
//! making the two *bit-identical*, which the tests assert. This is the
//! paper's §3.1 order-invariance principle: same basic ops, same order ⇒
//! one API; had the order differed, it would need a different name.
//!
//! Perf (bit-neutral, DESIGN.md §6): the im2col path is **fused** — the
//! column matrix is emitted directly in the microkernel's packed panel
//! layout (skipping the seed's materialise-then-transpose round trip),
//! its construction is parallelised on the worker pool together with the
//! batch dimension, and the GEMM writes straight into the NCHW output
//! plane (no per-element scatter). The weight matrix needs no relayout
//! at all: OIHW rows are already in (c, kh, kw) order. Scratch comes
//! from the thread-local arena, so serve/train loops stop paying a fresh
//! im2col allocation per call.

use super::microkernel::{gemm_block, MR, NR};
use super::par::par_chunks_in;
use super::pool::{global_pool, WorkerPool};
use super::reduce::max_wins;
use super::scratch::scratch_f32;
use super::tensor::Tensor;
use crate::{Error, Result};

/// Cap on the fused path's packed-im2col scratch (f32 slots ≈ 16 MiB);
/// batches are processed in groups sized to stay under it. Grouping
/// changes only which tasks run concurrently — never any bits.
const CONV_SCRATCH_F32: usize = 1 << 22;

/// Convolution hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct Conv2dParams {
    /// Spatial stride (same in h and w).
    pub stride: usize,
    /// Zero padding (same in h and w).
    pub padding: usize,
}

impl Default for Conv2dParams {
    fn default() -> Self {
        Conv2dParams { stride: 1, padding: 0 }
    }
}

/// Output spatial size.
fn out_hw(h: usize, w: usize, kh: usize, kw: usize, p: &Conv2dParams) -> Result<(usize, usize)> {
    let oh = (h + 2 * p.padding).checked_sub(kh).map(|v| v / p.stride + 1);
    let ow = (w + 2 * p.padding).checked_sub(kw).map(|v| v / p.stride + 1);
    match (oh, ow) {
        (Some(a), Some(b)) if a > 0 && b > 0 => Ok((a, b)),
        _ => Err(Error::shape("conv2d: kernel larger than padded input")),
    }
}

fn check_conv(x: &Tensor, w: &Tensor) -> Result<(usize, usize, usize, usize, usize, usize, usize)> {
    let (xd, wd) = (x.dims(), w.dims());
    if xd.len() != 4 || wd.len() != 4 || xd[1] != wd[1] {
        return Err(Error::shape(format!(
            "conv2d: bad shapes x{xd:?} w{wd:?} (want NCHW / OIHW, C match)"
        )));
    }
    Ok((xd[0], xd[1], xd[2], xd[3], wd[0], wd[2], wd[3]))
}

/// Reproducible convolution (default API).
/// `bias` (length O) is added once per output element after the reduction.
///
/// Perf routing (bit-neutral): for large shapes this delegates to the
/// im2col+GEMM path, which computes the *identical* fixed-order graph
/// (`im2col_matches_direct_bitwise` asserts equality) ~10× faster via the
/// vectorised row-kernel GEMM. Small shapes stay on the direct loops
/// (im2col materialisation overhead dominates there).
pub fn conv2d(x: &Tensor, w: &Tensor, bias: Option<&Tensor>, p: Conv2dParams) -> Result<Tensor> {
    conv2d_in(global_pool(), x, w, bias, p)
}

/// [`conv2d`] on an explicit pool (size routing included).
pub fn conv2d_in(
    pool: &WorkerPool,
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    p: Conv2dParams,
) -> Result<Tensor> {
    let (_, c, h, wd, _, kh, kw) = check_conv(x, w)?;
    if let Ok((oh, ow)) = out_hw(h, wd, kh, kw, &p) {
        let work = c * kh * kw * oh * ow;
        if work >= 16_384 {
            return conv2d_im2col_in(pool, x, w, bias, p);
        }
    }
    conv2d_direct_in(pool, x, w, bias, p)
}

/// Direct-loop formulation of the same spec (ablation / small shapes).
pub fn conv2d_direct(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    p: Conv2dParams,
) -> Result<Tensor> {
    conv2d_direct_in(global_pool(), x, w, bias, p)
}

/// [`conv2d_direct`] on an explicit pool.
pub fn conv2d_direct_in(
    pool: &WorkerPool,
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    p: Conv2dParams,
) -> Result<Tensor> {
    let (b, c, h, wd, o, kh, kw) = check_conv(x, w)?;
    let (oh, ow) = out_hw(h, wd, kh, kw, &p)?;
    if let Some(bs) = bias {
        if bs.dims() != [o] {
            return Err(Error::shape("conv2d: bias must be (O,)"));
        }
    }
    let mut out = Tensor::zeros(&[b, o, oh, ow]);
    let xd = x.data();
    let wdat = w.data();
    let bias_d = bias.map(|t| t.data());
    // one chunk = one (b, o) output plane: t_conv parallel tasks grouped
    par_chunks_in(pool, out.data_mut(), oh * ow, |start, plane| {
        let plane_idx = start / (oh * ow);
        let (bi, oi) = (plane_idx / o, plane_idx % o);
        for ohh in 0..oh {
            for oww in 0..ow {
                let mut acc = 0.0f32;
                // fixed (c, kh, kw) sequential order — the spec
                for ci in 0..c {
                    for khh in 0..kh {
                        let ih = (ohh * p.stride + khh) as isize - p.padding as isize;
                        if ih < 0 || ih >= h as isize {
                            continue; // zero-padding contributes exact 0s: skipped
                        }
                        for kww in 0..kw {
                            let iw = (oww * p.stride + kww) as isize - p.padding as isize;
                            if iw < 0 || iw >= wd as isize {
                                continue;
                            }
                            let xv = xd[((bi * c + ci) * h + ih as usize) * wd + iw as usize];
                            let wv = wdat[((oi * c + ci) * kh + khh) * kw + kww];
                            acc += xv * wv;
                        }
                    }
                }
                if let Some(bd) = bias_d {
                    acc += bd[oi];
                }
                plane[ohh * ow + oww] = acc;
            }
        }
    });
    Ok(out)
}

/// im2col: unfold `(C,H,W)` into a `(OH·OW, C·KH·KW)` matrix whose k axis
/// enumerates (c, kh, kw) in the *direct-conv order*.
pub fn im2col(
    x: &Tensor,
    batch: usize,
    kh: usize,
    kw: usize,
    p: &Conv2dParams,
) -> Result<Tensor> {
    let xd = x.dims();
    let (c, h, w) = (xd[1], xd[2], xd[3]);
    let (oh, ow) = out_hw(h, w, kh, kw, p)?;
    let k = c * kh * kw;
    let mut out = Tensor::zeros(&[oh * ow, k]);
    let data = x.data();
    for ohh in 0..oh {
        for oww in 0..ow {
            let row = ohh * ow + oww;
            for ci in 0..c {
                for khh in 0..kh {
                    for kww in 0..kw {
                        let ih = (ohh * p.stride + khh) as isize - p.padding as isize;
                        let iw = (oww * p.stride + kww) as isize - p.padding as isize;
                        let v = if ih < 0 || iw < 0 || ih >= h as isize || iw >= w as isize {
                            0.0
                        } else {
                            data[((batch * c + ci) * h + ih as usize) * w + iw as usize]
                        };
                        out.data_mut()[row * k + (ci * kh + khh) * kw + kww] = v;
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Emit one NR-wide panel of the packed im2col matrix for one image:
/// `dst[ck·NR + j] = x[img, c, ih, iw]` for output position
/// `s = pidx·NR + j`, with k rows enumerating (c, kh, kw) in the
/// direct-conv order and zero-fill for padding taps and the ragged
/// spatial tail (tail columns feed microkernel lanes that are never
/// written back). Layout-only: no arithmetic, so no rounding.
#[allow(clippy::too_many_arguments)]
fn fill_im2col_panel(
    xd: &[f32],
    img: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    p: &Conv2dParams,
    oh: usize,
    ow: usize,
    pidx: usize,
    dst: &mut [f32],
) {
    let ohw = oh * ow;
    let s0 = pidx * NR;
    let wlen = NR.min(ohw - s0);
    for ci in 0..c {
        for khh in 0..kh {
            for kww in 0..kw {
                let ck = (ci * kh + khh) * kw + kww;
                let row = &mut dst[ck * NR..ck * NR + NR];
                for (j, v) in row[..wlen].iter_mut().enumerate() {
                    let s = s0 + j;
                    let (ohh, oww) = (s / ow, s % ow);
                    let ih = (ohh * p.stride + khh) as isize - p.padding as isize;
                    let iw = (oww * p.stride + kww) as isize - p.padding as isize;
                    *v = if ih < 0 || iw < 0 || ih >= h as isize || iw >= w as isize {
                        0.0
                    } else {
                        xd[((img * c + ci) * h + ih as usize) * w + iw as usize]
                    };
                }
                for v in &mut row[wlen..] {
                    *v = 0.0;
                }
            }
        }
    }
}

/// im2col + GEMM convolution. **Bit-identical** to [`conv2d`] when the
/// padding contributes only exact zeros (0·w then +0 round-trips exactly,
/// except that a `-0.0` product can flip the sign of an all-zero prefix —
/// the spec therefore defines padding contributions as *skipped*, and
/// im2col matches because +0·w = ±0 added to a ±0 prefix keeps bits for
/// every finite w; tests assert equality on random data).
pub fn conv2d_im2col(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    p: Conv2dParams,
) -> Result<Tensor> {
    conv2d_im2col_in(global_pool(), x, w, bias, p)
}

/// [`conv2d_im2col`] on an explicit pool — the fused pipeline: packed
/// im2col emission (parallel over image × panel), then one microkernel
/// GEMM row-block per (image, O-block) task writing directly into the
/// NCHW output plane with the bias folded into the write-back.
pub fn conv2d_im2col_in(
    pool: &WorkerPool,
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    p: Conv2dParams,
) -> Result<Tensor> {
    let (b, c, h, wd, o, kh, kw) = check_conv(x, w)?;
    let (oh, ow) = out_hw(h, wd, kh, kw, &p)?;
    if let Some(bs) = bias {
        if bs.dims() != [o] {
            return Err(Error::shape("conv2d: bias must be (O,)"));
        }
    }
    if b == 0 || o == 0 {
        return Ok(Tensor::zeros(&[b, o, oh, ow]));
    }
    let k = c * kh * kw;
    let ohw = oh * ow;
    let npanels = ohw.div_ceil(NR);
    let per_image = npanels * k * NR; // packed im2col slots per image
    let group = (CONV_SCRATCH_F32 / per_image.max(1)).clamp(1, b);
    let rb = o.div_ceil(MR);
    let xd = x.data();
    let wmat = w.data(); // OIHW rows are already the (O, K) GEMM operand
    let bias_d = bias.map(|t| t.data());
    let out = Tensor::filled_by(&[b, o, oh, ow], |outbuf| {
        let mut cols = scratch_f32(group * per_image);
        for g0 in (0..b).step_by(group) {
            let gn = group.min(b - g0);
            // stage 1: packed im2col, one task per (image, panel)
            par_chunks_in(pool, &mut cols[..gn * per_image], k * NR, |start, panel| {
                let t = start / (k * NR);
                let (gi, pi) = (t / npanels, t % npanels);
                fill_im2col_panel(xd, g0 + gi, c, h, wd, kh, kw, &p, oh, ow, pi, panel);
            });
            // stage 2: one GEMM row-block per (image, O-block) task —
            // the batch dimension parallelises here, and each block
            // lands directly in its NCHW plane (no scatter loop)
            let base = outbuf.as_mut_ptr() as usize;
            let gcols = &cols[..gn * per_image];
            pool.run(gn * rb, &|t| {
                let (gi, blk) = (t / rb, t % rb);
                let i0 = blk * MR;
                let nrows = MR.min(o - i0);
                let packed = &gcols[gi * per_image..(gi + 1) * per_image];
                // SAFETY: tasks cover pairwise-disjoint
                // (image, row-block) regions of `outbuf`, each task runs
                // exactly once, and `outbuf` outlives `run` (which
                // blocks until every task finishes).
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(
                        (base as *mut f32).add(((g0 + gi) * o + i0) * ohw),
                        nrows * ohw,
                    )
                };
                gemm_block(
                    &wmat[i0 * k..(i0 + nrows) * k],
                    k,
                    nrows,
                    packed,
                    ohw,
                    bias_d.map(|bd| &bd[i0..i0 + nrows]),
                    false,
                    dst,
                );
            });
        }
    });
    Ok(out)
}

fn check_pool(x: &Tensor, k: usize, name: &str) -> Result<(usize, usize, usize, usize)> {
    let d = x.dims();
    if d.len() != 4 || k == 0 || d[2] % k != 0 || d[3] % k != 0 {
        return Err(Error::shape(format!("{name}: bad dims {d:?} k={k}")));
    }
    Ok((d[0], d[1], d[2], d[3]))
}

/// Max pooling (kernel = stride, valid padding) — comparison-only, so
/// trivially reproducible. The in-window scan seeds on the window's
/// first element and updates via the canonical [`super::reduce::max_wins`]
/// rule (NaN wins, first occurrence kept — the same rule as `max_axis`;
/// NaN-rule unification migration, DESIGN.md §8). Dispatches one output
/// plane per worker-pool task (planes are independent; the in-window
/// comparison order stays fixed, so pool size never changes bits —
/// covered by the `pool_invariance` suite).
pub fn max_pool2d(x: &Tensor, k: usize) -> Result<Tensor> {
    max_pool2d_in(global_pool(), x, k)
}

/// The canonical pooling-window scan, shared by the pooled forward and
/// the argmax variant so the two agree **by construction**: seed on the
/// window's first element, visit in (di, dj) order, update via
/// [`max_wins`]. Returns the winning flat input index — the winning
/// value is `xd[index]`.
#[inline]
fn pool_window_argmax(xd: &[f32], base: usize, k: usize, w: usize) -> usize {
    let mut best = base;
    let mut m = xd[base];
    for di in 0..k {
        for dj in 0..k {
            let v = xd[base + di * w + dj];
            if max_wins(v, m) {
                m = v;
                best = base + di * w + dj;
            }
        }
    }
    best
}

/// [`max_pool2d`] on an explicit pool.
pub fn max_pool2d_in(pool: &WorkerPool, x: &Tensor, k: usize) -> Result<Tensor> {
    let (b, c, h, w) = check_pool(x, k, "max_pool2d")?;
    let (oh, ow) = (h / k, w / k);
    let xd = x.data();
    let out = Tensor::filled_by(&[b, c, oh, ow], |buf| {
        par_chunks_in(pool, buf, oh * ow, |start, plane| {
            let bc = start / (oh * ow);
            for i in 0..oh {
                for j in 0..ow {
                    let base = bc * h * w + i * k * w + j * k;
                    plane[i * ow + j] = xd[pool_window_argmax(xd, base, k, w)];
                }
            }
        });
    });
    Ok(out)
}

/// [`max_pool2d`] that also returns the winning **flat input index** per
/// output element — the autograd forward (`Tape::max_pool2d`) needs the
/// argmax to scatter gradients. Both this and [`max_pool2d_in`] call the
/// one [`pool_window_argmax`] scan, and the value is read back *from*
/// the recorded index (`x[argmax[e]]`), so output bits and gradient
/// target cannot disagree by construction (pinned in tests anyway,
/// NaN payloads and ties included). Serial over planes: the callers are
/// training-path tapes whose backward is serial anyway.
pub fn max_pool2d_argmax(x: &Tensor, k: usize) -> Result<(Tensor, Vec<usize>)> {
    let (b, c, h, w) = check_pool(x, k, "max_pool2d")?;
    let (oh, ow) = (h / k, w / k);
    let xd = x.data();
    let mut argmax = vec![0usize; b * c * oh * ow];
    for bc in 0..b * c {
        for i in 0..oh {
            for j in 0..ow {
                let base = bc * h * w + i * k * w + j * k;
                argmax[(bc * oh + i) * ow + j] = pool_window_argmax(xd, base, k, w);
            }
        }
    }
    let out = Tensor::from_vec(
        &[b, c, oh, ow],
        argmax.iter().map(|&s| xd[s]).collect(),
    )?;
    Ok((out, argmax))
}

/// Average pooling: fixed graph — sequential window sum, then ÷ k².
/// Same plane-per-task dispatch as [`max_pool2d`].
pub fn avg_pool2d(x: &Tensor, k: usize) -> Result<Tensor> {
    avg_pool2d_in(global_pool(), x, k)
}

/// [`avg_pool2d`] on an explicit pool.
pub fn avg_pool2d_in(pool: &WorkerPool, x: &Tensor, k: usize) -> Result<Tensor> {
    let (b, c, h, w) = check_pool(x, k, "avg_pool2d")?;
    let (oh, ow) = (h / k, w / k);
    let inv = 1.0 / (k * k) as f32; // k² a small int: division exact-rounded
    let xd = x.data();
    let out = Tensor::filled_by(&[b, c, oh, ow], |buf| {
        par_chunks_in(pool, buf, oh * ow, |start, plane| {
            let bc = start / (oh * ow);
            for i in 0..oh {
                for j in 0..ow {
                    let mut acc = 0.0f32;
                    for di in 0..k {
                        for dj in 0..k {
                            acc += xd[bc * h * w + (i * k + di) * w + (j * k + dj)];
                        }
                    }
                    plane[i * ow + j] = acc * inv;
                }
            }
        });
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(dims: &[usize], seed: u64) -> Tensor {
        let n: usize = dims.iter().product();
        let mut s = seed;
        Tensor::from_vec(
            dims,
            (0..n)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(99);
                    (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 2.0
                })
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn known_small_conv() {
        // 1x1x3x3 input, 1x1x2x2 kernel of ones → window sums
        let x = Tensor::from_vec(&[1, 1, 3, 3], (1..=9).map(|v| v as f32).collect()).unwrap();
        let w = Tensor::full(&[1, 1, 2, 2], 1.0);
        let y = conv2d(&x, &w, None, Conv2dParams::default()).unwrap();
        assert_eq!(y.dims(), &[1, 1, 2, 2]);
        assert_eq!(y.data(), &[12., 16., 24., 28.]);
    }

    #[test]
    fn padding_and_stride() {
        let x = Tensor::full(&[1, 1, 4, 4], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let y = conv2d(&x, &w, None, Conv2dParams { stride: 2, padding: 1 }).unwrap();
        assert_eq!(y.dims(), &[1, 1, 2, 2]);
        // corners see 4 ones, etc.
        assert_eq!(y.data(), &[4., 6., 6., 9.]);
    }

    #[test]
    fn bias_is_added_after_reduction() {
        let x = Tensor::full(&[1, 1, 2, 2], 1.0);
        let w = Tensor::full(&[2, 1, 2, 2], 0.5);
        let b = Tensor::from_vec(&[2], vec![10.0, -10.0]).unwrap();
        let y = conv2d(&x, &w, Some(&b), Conv2dParams::default()).unwrap();
        assert_eq!(y.data(), &[12.0, -8.0]);
    }

    #[test]
    fn im2col_matches_direct_bitwise() {
        let x = lcg(&[2, 3, 8, 8], 1);
        let w = lcg(&[4, 3, 3, 3], 2);
        let b = lcg(&[4], 3);
        for p in [
            Conv2dParams { stride: 1, padding: 0 },
            Conv2dParams { stride: 2, padding: 1 },
            Conv2dParams { stride: 1, padding: 2 },
        ] {
            let direct = conv2d_direct(&x, &w, Some(&b), p).unwrap();
            let gemm = conv2d_im2col(&x, &w, Some(&b), p).unwrap();
            let routed = conv2d(&x, &w, Some(&b), p).unwrap();
            assert!(routed.bit_eq(&direct), "routing changed bits");
            assert!(
                direct.bit_eq(&gemm),
                "im2col diverged from direct at stride={} pad={}",
                p.stride,
                p.padding
            );
        }
    }

    #[test]
    fn pool_size_invariance() {
        // explicit pools — no env-var mutation (the seed's set_var here
        // raced with other tests under the parallel harness)
        let x = lcg(&[1, 4, 10, 10], 5);
        let w = lcg(&[8, 4, 3, 3], 6);
        let one = conv2d_in(&WorkerPool::new(1), &x, &w, None, Conv2dParams::default()).unwrap();
        for lanes in [2, 4, 16] {
            let pool = WorkerPool::new(lanes);
            let got = conv2d_in(&pool, &x, &w, None, Conv2dParams::default()).unwrap();
            assert!(one.bit_eq(&got), "lanes={lanes}");
        }
    }

    #[test]
    fn fused_pipeline_matches_direct_across_panel_boundaries() {
        // spatial sizes straddling the NR panel width (15/16/17 output
        // columns) and O straddling MR; batch > group-of-1 exercises the
        // batch-parallel stage
        for (b, c, hw, o, kk) in [
            (1usize, 2usize, 5usize, 3usize, 2usize), // ohw = 16 exactly
            (2, 2, 6, 8, 2),                          // ohw = 25, o == MR
            (3, 1, 6, 9, 3),                          // o straddles MR
            (2, 3, 4, 1, 1),                          // single filter
        ] {
            let x = lcg(&[b, c, hw, hw], (b * 100 + hw) as u64);
            let w = lcg(&[o, c, kk, kk], (o * 100 + kk) as u64);
            let bias = lcg(&[o], 77);
            let p = Conv2dParams { stride: 1, padding: 0 };
            let direct = conv2d_direct(&x, &w, Some(&bias), p).unwrap();
            let fused = conv2d_im2col(&x, &w, Some(&bias), p).unwrap();
            assert!(
                direct.bit_eq(&fused),
                "fused diverged at b={b} c={c} hw={hw} o={o} k={kk}"
            );
        }
    }

    #[test]
    fn fused_pipeline_validates_bias_shape() {
        let x = lcg(&[1, 2, 6, 6], 1);
        let w = lcg(&[4, 2, 3, 3], 2);
        let bad = lcg(&[3], 3);
        assert!(conv2d_im2col(&x, &w, Some(&bad), Conv2dParams::default()).is_err());
    }

    #[test]
    fn pooling_ops_are_pool_size_invariant() {
        let x = lcg(&[2, 3, 8, 8], 9);
        let base_max = max_pool2d_in(&WorkerPool::new(1), &x, 2).unwrap();
        let base_avg = avg_pool2d_in(&WorkerPool::new(1), &x, 2).unwrap();
        for lanes in [2, 3, 5, 8, 16] {
            let pool = WorkerPool::new(lanes);
            assert!(base_max.bit_eq(&max_pool2d_in(&pool, &x, 2).unwrap()), "max lanes={lanes}");
            assert!(base_avg.bit_eq(&avg_pool2d_in(&pool, &x, 2).unwrap()), "avg lanes={lanes}");
        }
        // the global-pool names route through the same kernels
        assert!(base_max.bit_eq(&max_pool2d(&x, 2).unwrap()));
        assert!(base_avg.bit_eq(&avg_pool2d(&x, 2).unwrap()));
    }

    #[test]
    fn pooling() {
        let x = Tensor::from_vec(
            &[1, 1, 4, 4],
            vec![
                1., 2., 3., 4., //
                5., 6., 7., 8., //
                9., 10., 11., 12., //
                13., 14., 15., 16.,
            ],
        )
        .unwrap();
        let mp = max_pool2d(&x, 2).unwrap();
        assert_eq!(mp.data(), &[6., 8., 14., 16.]);
        let ap = avg_pool2d(&x, 2).unwrap();
        assert_eq!(ap.data(), &[3.5, 5.5, 11.5, 13.5]);
        assert!(max_pool2d(&x, 3).is_err());
    }

    #[test]
    fn argmax_variant_agrees_with_pooled_kernel_bitwise() {
        // finite, NaN-laced (distinct payloads) and tie-heavy inputs:
        // the argmax variant's values must equal max_pool2d's bits, and
        // every recorded index must hold exactly those bits
        let mut x = lcg(&[2, 2, 6, 6], 11);
        x.data_mut()[3] = f32::from_bits(0x7fc0_0001);
        x.data_mut()[40] = f32::from_bits(0x7fc0_0002);
        x.data_mut()[41] = f32::from_bits(0x7fc0_0003); // two NaNs, one window
        let tie = x.data()[71];
        x.data_mut()[70] = tie; // exact tie inside a window
        for k in [1usize, 2, 3] {
            let want = max_pool2d(&x, k).unwrap();
            let (got, argmax) = max_pool2d_argmax(&x, k).unwrap();
            assert!(got.bit_eq(&want), "k={k}");
            for (e, &src) in argmax.iter().enumerate() {
                assert_eq!(
                    got.data()[e].to_bits(),
                    x.data()[src].to_bits(),
                    "k={k} e={e}: argmax must hold the output bits"
                );
            }
        }
        assert!(max_pool2d_argmax(&x, 4).is_err()); // same shape policy
    }

    #[test]
    fn shape_validation() {
        let x = Tensor::zeros(&[1, 2, 5, 5]);
        let w = Tensor::zeros(&[3, 99, 3, 3]);
        assert!(conv2d(&x, &w, None, Conv2dParams::default()).is_err());
        let w2 = Tensor::zeros(&[3, 2, 7, 7]);
        assert!(conv2d(&x, &w2, None, Conv2dParams::default()).is_err());
    }
}
