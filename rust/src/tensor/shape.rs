//! Shapes, strides and broadcasting rules (numpy-compatible).

use crate::{Error, Result};

/// A tensor shape (row-major).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    /// Construct from a slice.
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Total element count (empty shape = scalar = 1 element).
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    /// Dimensions as a slice.
    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.0.len()];
        for i in (0..self.0.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.0[i + 1];
        }
        s
    }

    /// Flatten a multi-index into a linear offset.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.0.len());
        let strides = self.strides();
        idx.iter().zip(strides.iter()).map(|(i, s)| i * s).sum()
    }

    /// numpy broadcast of two shapes (align right; 1 stretches).
    pub fn broadcast(&self, other: &Shape) -> Result<Shape> {
        let r = self.rank().max(other.rank());
        let mut out = vec![0usize; r];
        for i in 0..r {
            let a = if i < r - self.rank() { 1 } else { self.0[i - (r - self.rank())] };
            let b = if i < r - other.rank() { 1 } else { other.0[i - (r - other.rank())] };
            out[i] = if a == b {
                a
            } else if a == 1 {
                b
            } else if b == 1 {
                a
            } else {
                return Err(Error::shape(format!(
                    "cannot broadcast {:?} with {:?}",
                    self.0, other.0
                )));
            };
        }
        Ok(Shape(out))
    }
}

impl From<&[usize]> for Shape {
    fn from(d: &[usize]) -> Self {
        Shape(d.to_vec())
    }
}

impl From<Vec<usize>> for Shape {
    fn from(d: Vec<usize>) -> Self {
        Shape(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(Shape::new(&[2, 3, 4]).strides(), vec![12, 4, 1]);
        assert_eq!(Shape::new(&[5]).strides(), vec![1]);
        assert_eq!(Shape::new(&[]).strides(), Vec::<usize>::new());
    }

    #[test]
    fn offsets() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.offset(&[0, 0, 0]), 0);
        assert_eq!(s.offset(&[1, 2, 3]), 23);
        assert_eq!(s.offset(&[1, 0, 1]), 13);
    }

    #[test]
    fn numel_and_rank() {
        assert_eq!(Shape::new(&[2, 3, 4]).numel(), 24);
        assert_eq!(Shape::new(&[]).numel(), 1);
        assert_eq!(Shape::new(&[0, 5]).numel(), 0);
    }

    #[test]
    fn broadcasting_rules() {
        let a = Shape::new(&[4, 1, 3]);
        let b = Shape::new(&[2, 3]);
        assert_eq!(a.broadcast(&b).unwrap(), Shape::new(&[4, 2, 3]));
        let c = Shape::new(&[1]);
        assert_eq!(b.broadcast(&c).unwrap(), b);
        assert!(Shape::new(&[2]).broadcast(&Shape::new(&[3])).is_err());
    }
}
