//! # RepDL — bit-level reproducible deep learning training and inference
//!
//! Rust reproduction of *"RepDL: Bit-level Reproducible Deep Learning
//! Training and Inference"* (Xie, Zhang, Chen — Microsoft Research, 2025).
//!
//! RepDL eliminates floating-point non-determinism and non-reproducibility
//! by enforcing two principles (paper §3.1):
//!
//! 1. **Correct rounding for basic operations** — every scalar math
//!    operation ([`rnum`]) rounds the infinitely-precise result with
//!    IEEE-754 round-to-nearest-even, so its bits are identical on every
//!    conforming platform.
//! 2. **Order invariance for composite operations** — every reduction
//!    ([`rnum::sum`], [`tensor`]) uses a *specified* association order
//!    (sequential by default, pairwise as a separately-named API), and
//!    every DL function ([`nn`]) is a *specified* computation graph of
//!    basic operations.
//!
//! The crate is organised as the paper's system plus every substrate it
//! assumes:
//!
//! * [`rnum`] — correctly-rounded scalar ops + the `BigFloat` rounding
//!   oracle + reproducible summation algorithms.
//! * [`tensor`] — shape/stride tensor library with fixed-order GEMM
//!   (packed register-tiled microkernel routed with a cache-blocked
//!   small-shape kernel, both bit-identical to the per-element dot
//!   form), fused im2col convolution and reductions, all dispatched on
//!   the persistent [`tensor::pool::WorkerPool`]: a lazily-initialised
//!   worker pool with static chunk→lane assignment, so pool size is a
//!   pure performance knob that never changes a single bit (see
//!   `DESIGN.md` §3/§6 and the `pool_invariance` / `golden_vectors` /
//!   `packed_fast_paths` conformance suites under `rust/tests/`).
//!   Transient pack/im2col buffers come from the thread-local
//!   [`tensor::scratch`] arena (allocation-free steady state).
//! * [`autograd`] — tape autograd with deterministic gradient-accumulation
//!   order.
//! * [`nn`] — PyTorch-named modules (`Linear`, `Conv2d`, `BatchNorm2d`,
//!   `LayerNorm`, `MultiheadAttention`, ...) as fixed computation graphs.
//! * [`optim`] — `SGD` / `Adam` / `AdamW` with fixed update graphs.
//! * [`rng`] — MT19937 + Philox4x32-10, per-worker deterministic seeding
//!   (paper §2.1), reproducible initialisers.
//! * [`data`] — deterministic synthetic datasets and batching.
//! * [`baseline`] — *non*-reproducible conventional implementations
//!   parameterised by a simulated [`baseline::PlatformProfile`]; the
//!   control group for every experiment.
//! * [`runtime`] — PJRT loader/executor for the JAX/Pallas AOT artifacts
//!   (the second, independent implementation of the RepDL op spec);
//!   gated behind the `pjrt` feature, stubbed otherwise.
//! * [`coordinator`] — trainer, the deterministic serving subsystem
//!   (pooled batch dispatch, sharded replicas, the ticket-ordered
//!   dynamic-batching scheduler, ticket-arithmetic admission control,
//!   the content-addressed memo cache and the replayable response log —
//!   DESIGN.md §7–§8), bitwise-verification harness.
//! * [`sha256`] — in-crate FIPS 180-4 digest backing all bitwise
//!   fingerprints (zero external dependencies — DESIGN.md §5).
//!
//! See `DESIGN.md` for the experiment index (E1–E9) and `EXPERIMENTS.md`
//! for paper-vs-measured results.

pub mod autograd;
pub mod baseline;
pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod nn;
pub mod optim;
pub mod proptest;
pub mod rng;
pub mod rnum;
pub mod runtime;
pub mod sha256;
pub mod tensor;

pub use error::{Error, Result};
