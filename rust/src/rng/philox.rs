//! Philox4x32-10 — the counter-based generator used by CUDA's cuRAND and
//! JAX. Counter-based RNGs are the natural fit for the paper's per-worker
//! determinism: stream `w` is just a different key, with no sequential
//! state to race on.

use super::ReproRng;

const PHILOX_M0: u32 = 0xD251_1F53;
const PHILOX_M1: u32 = 0xCD9E_8D57;
const PHILOX_W0: u32 = 0x9E37_79B9;
const PHILOX_W1: u32 = 0xBB67_AE85;

/// Philox4x32-10 state: 128-bit counter + 64-bit key, 4-word buffer.
pub struct Philox {
    counter: [u32; 4],
    key: [u32; 2],
    buf: [u32; 4],
    idx: usize,
}

/// A serialized [`Philox`] position: everything the generator holds,
/// including the partially-consumed output buffer, so a restored stream
/// resumes **mid-block** — the next draw after restore is bit-identical
/// to the next draw the snapshotted generator would have produced.
/// Plain-old-data so checkpoints can write it as 11 little-endian words.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PhiloxState {
    /// 128-bit block counter (the *next* block to generate).
    pub counter: [u32; 4],
    /// 64-bit key (the seed).
    pub key: [u32; 2],
    /// Current output block.
    pub buf: [u32; 4],
    /// Words of `buf` already consumed (0..=4; 4 = buffer exhausted).
    pub idx: u32,
}

impl Philox {
    /// New stream: `seed` is the key, `stream` offsets the counter's high
    /// word so different workers get disjoint counter spaces.
    pub fn new(seed: u64, stream: u64) -> Self {
        Philox {
            counter: [0, 0, stream as u32, (stream >> 32) as u32],
            key: [seed as u32, (seed >> 32) as u32],
            buf: [0; 4],
            idx: 4,
        }
    }

    /// Snapshot the full generator position (see [`PhiloxState`]).
    pub fn snapshot(&self) -> PhiloxState {
        PhiloxState {
            counter: self.counter,
            key: self.key,
            buf: self.buf,
            idx: self.idx as u32,
        }
    }

    /// Rebuild a generator at a snapshotted position. `restore(snapshot())`
    /// is the identity on the output stream: draw-for-draw bit equality,
    /// even when the snapshot was taken mid-block.
    pub fn restore(state: PhiloxState) -> Self {
        Philox {
            counter: state.counter,
            key: state.key,
            buf: state.buf,
            idx: (state.idx as usize).min(4),
        }
    }

    #[inline]
    fn mulhilo(a: u32, b: u32) -> (u32, u32) {
        let p = a as u64 * b as u64;
        ((p >> 32) as u32, p as u32)
    }

    fn round(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
        let (hi0, lo0) = Self::mulhilo(PHILOX_M0, ctr[0]);
        let (hi1, lo1) = Self::mulhilo(PHILOX_M1, ctr[2]);
        [hi1 ^ ctr[1] ^ key[0], lo1, hi0 ^ ctr[3] ^ key[1], lo0]
    }

    fn block(&mut self) {
        let mut c = self.counter;
        let mut k = self.key;
        for _ in 0..10 {
            c = Self::round(c, k);
            k[0] = k[0].wrapping_add(PHILOX_W0);
            k[1] = k[1].wrapping_add(PHILOX_W1);
        }
        self.buf = c;
        // 128-bit counter increment
        for w in self.counter.iter_mut() {
            *w = w.wrapping_add(1);
            if *w != 0 {
                break;
            }
        }
        self.idx = 0;
    }
}

impl ReproRng for Philox {
    fn next_u32(&mut self) -> u32 {
        if self.idx >= 4 {
            self.block();
        }
        let v = self.buf[self.idx];
        self.idx += 1;
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::ReproRng;

    #[test]
    fn deterministic_per_seed_and_stream() {
        let take = |seed, stream| -> Vec<u32> {
            let mut r = Philox::new(seed, stream);
            (0..64).map(|_| r.next_u32()).collect()
        };
        assert_eq!(take(1, 0), take(1, 0));
        assert_ne!(take(1, 0), take(2, 0));
        assert_ne!(take(1, 0), take(1, 1));
    }

    #[test]
    fn streams_are_disjointish() {
        // different streams should share no 8-gram prefix
        let mut a = Philox::new(9, 0);
        let mut b = Philox::new(9, 1);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn counter_increments_across_blocks() {
        let mut r = Philox::new(5, 0);
        let first: Vec<u32> = (0..4).map(|_| r.next_u32()).collect();
        let second: Vec<u32> = (0..4).map(|_| r.next_u32()).collect();
        assert_ne!(first, second);
    }

    #[test]
    fn snapshot_restore_resumes_mid_block() {
        // snapshot at every offset within a block (idx 0..4) and across
        // block boundaries: the restored stream must continue bit-exactly
        for consumed in 0..10usize {
            let mut a = Philox::new(77, 3);
            for _ in 0..consumed {
                a.next_u32();
            }
            let snap = a.snapshot();
            let rest: Vec<u32> = (0..32).map(|_| a.next_u32()).collect();
            let mut b = Philox::restore(snap);
            let resumed: Vec<u32> = (0..32).map(|_| b.next_u32()).collect();
            assert_eq!(rest, resumed, "consumed={consumed}");
        }
    }

    #[test]
    fn snapshot_is_plain_data_round_trip() {
        let mut r = Philox::new(5, 1);
        r.next_u32();
        let s = r.snapshot();
        // field-by-field copy through the POD struct is a faithful clone
        let copy = PhiloxState { counter: s.counter, key: s.key, buf: s.buf, idx: s.idx };
        assert_eq!(s, copy);
        assert_eq!(Philox::restore(copy).next_u32(), r.next_u32());
    }

    #[test]
    fn uniformity_smoke() {
        let mut r = Philox::new(123, 7);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.next_f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
