//! MT19937 — the Mersenne Twister, exactly as specified by
//! Matsumoto & Nishimura (and used by PyTorch's CPU generator, the
//! paper's §2.1 example). Integer-only: bit-reproducible everywhere.

use super::ReproRng;

const N: usize = 624;
const M: usize = 397;
const MATRIX_A: u32 = 0x9908_b0df;
const UPPER_MASK: u32 = 0x8000_0000;
const LOWER_MASK: u32 = 0x7fff_ffff;

/// MT19937 state.
pub struct Mt19937 {
    mt: [u32; N],
    mti: usize,
}

/// A serialized [`Mt19937`] position: the full 624-word state vector
/// plus the intra-block index, so a restored stream resumes mid-block
/// bit-exactly. 625 little-endian words on the wire.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Mt19937State {
    /// The 624-word twister state.
    pub mt: Vec<u32>,
    /// Words of the current block already consumed (0..=624).
    pub mti: u32,
}

impl Mt19937 {
    /// Seed with the standard initialisation routine.
    pub fn new(seed: u32) -> Self {
        let mut mt = [0u32; N];
        mt[0] = seed;
        for i in 1..N {
            mt[i] = 1_812_433_253u32
                .wrapping_mul(mt[i - 1] ^ (mt[i - 1] >> 30))
                .wrapping_add(i as u32);
        }
        Mt19937 { mt, mti: N }
    }

    /// Seed from a u64 (folds the high bits in; convenient for
    /// [`super::derive_seed`] outputs).
    pub fn new64(seed: u64) -> Self {
        Self::new((seed ^ (seed >> 32)) as u32)
    }

    /// Snapshot the full generator position (see [`Mt19937State`]).
    pub fn snapshot(&self) -> Mt19937State {
        Mt19937State { mt: self.mt.to_vec(), mti: self.mti as u32 }
    }

    /// Rebuild a generator at a snapshotted position; the restored
    /// stream continues draw-for-draw bit-exactly. A state vector that
    /// is not exactly 624 words is a shape error (a checkpoint decoding
    /// bug, never a panic).
    pub fn restore(state: &Mt19937State) -> crate::Result<Self> {
        if state.mt.len() != N {
            return Err(crate::Error::shape(format!(
                "mt19937 restore: state vector has {} words, want {N}",
                state.mt.len()
            )));
        }
        let mut mt = [0u32; N];
        mt.copy_from_slice(&state.mt);
        Ok(Mt19937 { mt, mti: (state.mti as usize).min(N) })
    }

    fn generate(&mut self) {
        for i in 0..N {
            let y = (self.mt[i] & UPPER_MASK) | (self.mt[(i + 1) % N] & LOWER_MASK);
            let mut next = self.mt[(i + M) % N] ^ (y >> 1);
            if y & 1 == 1 {
                next ^= MATRIX_A;
            }
            self.mt[i] = next;
        }
        self.mti = 0;
    }
}

impl ReproRng for Mt19937 {
    fn next_u32(&mut self) -> u32 {
        if self.mti >= N {
            self.generate();
        }
        let mut y = self.mt[self.mti];
        self.mti += 1;
        y ^= y >> 11;
        y ^= (y << 7) & 0x9d2c_5680;
        y ^= (y << 15) & 0xefc6_0000;
        y ^ (y >> 18)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::ReproRng;

    #[test]
    fn matches_reference_vector() {
        // Canonical reference outputs for seed 5489 (the MT19937 default):
        // first values of genrand_int32().
        let mut rng = Mt19937::new(5489);
        let expect: [u32; 10] = [
            3499211612, 581869302, 3890346734, 3586334585, 545404204,
            4161255391, 3922919429, 949333985, 2715962298, 1323567403,
        ];
        for (i, &e) in expect.iter().enumerate() {
            assert_eq!(rng.next_u32(), e, "output {i}");
        }
    }

    #[test]
    fn snapshot_restore_resumes_mid_stream() {
        for consumed in [0usize, 1, 17, 623, 624, 1000] {
            let mut a = Mt19937::new(42);
            for _ in 0..consumed {
                a.next_u32();
            }
            let snap = a.snapshot();
            let rest: Vec<u32> = (0..64).map(|_| a.next_u32()).collect();
            let mut b = Mt19937::restore(&snap).unwrap();
            let resumed: Vec<u32> = (0..64).map(|_| b.next_u32()).collect();
            assert_eq!(rest, resumed, "consumed={consumed}");
        }
    }

    #[test]
    fn restore_rejects_wrong_state_length() {
        let bad = Mt19937State { mt: vec![0u32; 100], mti: 0 };
        assert!(Mt19937::restore(&bad).is_err());
    }

    #[test]
    fn streams_differ_by_seed_and_repeat_by_seed() {
        let a: Vec<u32> = {
            let mut r = Mt19937::new(1);
            (0..100).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Mt19937::new(1);
            (0..100).map(|_| r.next_u32()).collect()
        };
        let c: Vec<u32> = {
            let mut r = Mt19937::new(2);
            (0..100).map(|_| r.next_u32()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
