//! Deterministic, reproducible random number generation (paper §2.1).
//!
//! The paper's prescription: a reproducible RNG algorithm used in a
//! thread-safe manner, with each worker's seed a *deterministic function*
//! of the base seed and the worker index. We ship the two standard DL
//! generators — MT19937 (PyTorch CPU) and Philox4x32-10 (CUDA / JAX) —
//! plus [`derive_seed`] (SplitMix64 mixing) for per-worker streams, and
//! reproducible initialisers built from the correctly-rounded `rnum` ops
//! so that *initial weights* are bit-identical across platforms too.

pub mod init;
pub mod mt19937;
pub mod philox;

pub use init::{kaiming_uniform, normal_tensor, uniform_tensor, xavier_uniform};
pub use mt19937::{Mt19937, Mt19937State};
pub use philox::{Philox, PhiloxState};

/// Derive worker seed `w` from a base seed: SplitMix64 of (base, w).
/// The paper: "the local seed is calculated from a deterministic function
/// of the base seed and the thread index".
pub fn derive_seed(base: u64, worker: u64) -> u64 {
    let mut z = base
        .wrapping_add(0x9e3779b97f4a7c15u64.wrapping_mul(worker.wrapping_add(1)));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Common interface over the two generators.
pub trait ReproRng {
    /// Next u32 from the stream.
    fn next_u32(&mut self) -> u32;

    /// f32 uniform in [0,1): fixed mapping (top 24 bits / 2²⁴) — exact
    /// arithmetic, identical on every platform.
    fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi): fixed graph `lo + u·(hi−lo)`.
    fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.next_f32() * (hi - lo)
    }

    /// Standard normal via Box–Muller, fixed graph over correctly-rounded
    /// ops: `√(−2·ln u₁) · cos(2π·u₂)` (u₁ nudged off zero).
    fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(f32::MIN_POSITIVE);
        let u2 = self.next_f32();
        let r = crate::rnum::rsqrt_f32(-2.0 * crate::rnum::rlog(u1));
        const TWO_PI: f32 = 6.283_185_5;
        r * crate::rnum::rcos(TWO_PI * u2)
    }

    /// Fisher–Yates shuffle (fixed visitation order).
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            // rejection-free bounded sample: floor(u32 * (i+1) / 2^32)
            let j = ((self.next_u32() as u64 * (i as u64 + 1)) >> 32) as usize;
            xs.swap(i, j);
        }
    }

    /// Bernoulli 0/1 mask values with probability `keep` of 1.
    fn bernoulli(&mut self, keep: f32) -> f32 {
        if self.next_f32() < keep {
            1.0
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_deterministic_and_spread() {
        assert_eq!(derive_seed(42, 0), derive_seed(42, 0));
        assert_ne!(derive_seed(42, 0), derive_seed(42, 1));
        assert_ne!(derive_seed(42, 0), derive_seed(43, 0));
        // no trivial collisions across 1000 workers
        let mut seen = std::collections::HashSet::new();
        for w in 0..1000 {
            assert!(seen.insert(derive_seed(7, w)));
        }
    }

    #[test]
    fn f32_mapping_range() {
        let mut rng = Mt19937::new(1);
        for _ in 0..10_000 {
            let v = rng.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_reproducible() {
        let mut a: Vec<u32> = (0..100).collect();
        let mut b: Vec<u32> = (0..100).collect();
        Mt19937::new(9).shuffle(&mut a);
        Mt19937::new(9).shuffle(&mut b);
        assert_eq!(a, b);
        let mut c: Vec<u32> = (0..100).collect();
        Mt19937::new(10).shuffle(&mut c);
        assert_ne!(a, c);
        // permutation property
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments_are_sane() {
        let mut rng = Philox::new(3, 0);
        let n = 20_000;
        let mut s = 0.0f64;
        let mut s2 = 0.0f64;
        for _ in 0..n {
            let v = rng.normal() as f64;
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
