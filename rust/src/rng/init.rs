//! Reproducible weight initialisers (PyTorch-compatible formulas).
//!
//! Each initialiser is a fixed computation graph over a seeded generator:
//! the same (seed, shape) always produces the same bits, on any platform,
//! because the u32→f32 mapping, the Box–Muller graph, and the fan-in
//! arithmetic are all exact or correctly rounded.

use super::{Mt19937, ReproRng};
use crate::rnum::rrsqrt;
use crate::tensor::Tensor;

/// Uniform tensor in [lo, hi).
pub fn uniform_tensor(dims: &[usize], lo: f32, hi: f32, seed: u64) -> Tensor {
    let mut rng = Mt19937::new64(seed);
    let n: usize = dims.iter().product();
    let data = (0..n).map(|_| rng.uniform(lo, hi)).collect();
    Tensor::from_vec(dims, data).unwrap()
}

/// Normal(μ, σ) tensor via the Box–Muller fixed graph.
pub fn normal_tensor(dims: &[usize], mean: f32, std: f32, seed: u64) -> Tensor {
    let mut rng = Mt19937::new64(seed);
    let n: usize = dims.iter().product();
    let data = (0..n).map(|_| mean + std * rng.normal()).collect();
    Tensor::from_vec(dims, data).unwrap()
}

/// Fan-in/fan-out for 2-D (out, in) or 4-D (O, C, KH, KW) weights.
fn fans(dims: &[usize]) -> (usize, usize) {
    match dims.len() {
        2 => (dims[1], dims[0]),
        4 => {
            let rf = dims[2] * dims[3];
            (dims[1] * rf, dims[0] * rf)
        }
        _ => {
            let n: usize = dims.iter().product();
            (n, n)
        }
    }
}

/// Kaiming (He) uniform: U(−b, b), b = √3 · √(2 / fan_in)  (gain for ReLU).
pub fn kaiming_uniform(dims: &[usize], seed: u64) -> Tensor {
    let (fan_in, _) = fans(dims);
    // fixed graph: gain·rsqrt(fan_in), √3 a fixed f32 constant
    const SQRT3: f32 = 1.732_050_8;
    const GAIN: f32 = std::f32::consts::SQRT_2; // relu gain √2
    let bound = SQRT3 * GAIN * rrsqrt(fan_in as f32);
    uniform_tensor(dims, -bound, bound, seed)
}

/// Xavier (Glorot) uniform: U(−b, b), b = √6 · rsqrt(fan_in + fan_out).
pub fn xavier_uniform(dims: &[usize], seed: u64) -> Tensor {
    let (fan_in, fan_out) = fans(dims);
    const SQRT6: f32 = 2.449_489_8;
    let bound = SQRT6 * rrsqrt((fan_in + fan_out) as f32);
    uniform_tensor(dims, -bound, bound, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initialisers_are_bit_reproducible() {
        let a = kaiming_uniform(&[64, 128], 42);
        let b = kaiming_uniform(&[64, 128], 42);
        assert!(a.bit_eq(&b));
        let c = kaiming_uniform(&[64, 128], 43);
        assert!(!a.bit_eq(&c));
        let d = normal_tensor(&[10, 10], 0.0, 0.02, 7);
        assert!(d.bit_eq(&normal_tensor(&[10, 10], 0.0, 0.02, 7)));
    }

    #[test]
    fn kaiming_bound_respected() {
        let t = kaiming_uniform(&[32, 50], 1);
        let bound = 1.732_050_8 * std::f32::consts::SQRT_2 * (1.0 / (50f32).sqrt());
        for &v in t.data() {
            assert!(v.abs() <= bound * 1.0001, "v={v} bound={bound}");
        }
    }

    #[test]
    fn xavier_variance_plausible() {
        let t = xavier_uniform(&[100, 100], 3);
        let var: f64 = t.data().iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / t.numel() as f64;
        // uniform(−b,b) variance = b²/3 = 6/(fan_in+fan_out)/3 = 0.01
        assert!((var - 0.01).abs() < 0.002, "var={var}");
    }

    #[test]
    fn conv_fans() {
        let (fi, fo) = fans(&[8, 4, 3, 3]);
        assert_eq!(fi, 36);
        assert_eq!(fo, 72);
    }
}
