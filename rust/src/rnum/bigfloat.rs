//! Arbitrary-precision binary floating point — the rounding oracle.
//!
//! The paper builds its correctly-rounded basic operations on MPFR [5] and
//! RLIBM [10]; neither library is available in this offline environment, so
//! `BigFloat` is our substitute (see DESIGN.md §5). It provides:
//!
//! * **exactly-sticky** `+ − × ÷ √` — the operation is computed with full
//!   internal precision and the discarded tail is *exactly* summarised in a
//!   sticky bit (round-to-odd). Rounding such a value to `f32`/`f64` with
//!   round-to-nearest-even gives the *correctly rounded* result of the
//!   exact operation (the classic round-to-odd double-rounding theorem,
//!   valid because our working precision ≥ target precision + 2).
//! * series-evaluated `exp ln sin cos tan tanh` with truncation error far
//!   below 2⁻³⁰⁰. Transcendence of these functions at nonzero rational
//!   points (Lindemann–Weierstrass) means no f32 input lands exactly on a
//!   rounding boundary, so 320-bit evaluation rounds correctly (known
//!   worst cases for binary32 need < 60 bits of agreement).
//!
//! Representation: `value = sign · 0.mant · 2^exp` with the mantissa a
//! big-endian limb vector whose top bit is set (`0.mant ∈ [1/2, 1)`).
//! Precision is the limb count; operations produce
//! `max(precision of inputs)` limbs.

use std::cmp::Ordering;

/// Default oracle precision in limbs (320 bits).
pub const PREC_ORACLE: usize = 5;
/// Working precision for trigonometric argument reduction (768 bits —
/// enough to absorb the ≤128-bit exponent range of f32 inputs).
pub const PREC_TRIG: usize = 12;

/// Arbitrary-precision binary float. See module docs.
#[derive(Clone, Debug)]
pub struct BigFloat {
    sign: i8,       // -1, 0, +1
    exp: i64,       // value = sign * 0.mant * 2^exp
    mant: Vec<u64>, // big-endian, mant[0] MSB set when sign != 0
}

// ---------------------------------------------------------------------
// mantissa helpers (big-endian limb slices)
// ---------------------------------------------------------------------

fn mant_is_zero(a: &[u64]) -> bool {
    a.iter().all(|&l| l == 0)
}

fn mant_leading_zeros(a: &[u64]) -> u64 {
    let mut lz = 0u64;
    for &l in a {
        if l == 0 {
            lz += 64;
        } else {
            lz += l.leading_zeros() as u64;
            break;
        }
    }
    lz
}

/// Compare two equal-length mantissas.
fn mant_cmp(a: &[u64], b: &[u64]) -> Ordering {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        match x.cmp(y) {
            Ordering::Equal => continue,
            o => return o,
        }
    }
    Ordering::Equal
}

/// `a += b` (equal length); returns carry out of the top.
fn mant_add_assign(a: &mut [u64], b: &[u64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut carry = 0u64;
    for i in (0..a.len()).rev() {
        let (s1, c1) = a[i].overflowing_add(b[i]);
        let (s2, c2) = s1.overflowing_add(carry);
        a[i] = s2;
        carry = (c1 | c2) as u64;
    }
    carry != 0
}

/// `a -= b` (equal length); requires `a >= b`.
fn mant_sub_assign(a: &mut [u64], b: &[u64]) {
    debug_assert_eq!(a.len(), b.len());
    let mut borrow = 0u64;
    for i in (0..a.len()).rev() {
        let (d1, b1) = a[i].overflowing_sub(b[i]);
        let (d2, b2) = d1.overflowing_sub(borrow);
        a[i] = d2;
        borrow = (b1 | b2) as u64;
    }
    debug_assert_eq!(borrow, 0, "mant_sub_assign underflow");
}

/// Subtract 1 in the last place (used for the sticky-borrow correction).
fn mant_sub_one_ulp(a: &mut [u64]) {
    for i in (0..a.len()).rev() {
        let (d, borrow) = a[i].overflowing_sub(1);
        a[i] = d;
        if !borrow {
            return;
        }
    }
    debug_assert!(false, "mant_sub_one_ulp underflowed");
}

/// Shift right by `k` bits in place; returns true if any 1-bit was lost.
fn mant_shr_sticky(a: &mut [u64], k: u64) -> bool {
    if k == 0 {
        return false;
    }
    let n = a.len();
    if k >= 64 * n as u64 {
        let sticky = !mant_is_zero(a);
        a.iter_mut().for_each(|l| *l = 0);
        return sticky;
    }
    let limb = (k / 64) as usize;
    let bit = (k % 64) as u32;
    // sticky: whole dropped limbs + low `bit` bits of the last surviving one
    let mut sticky = a[n - limb..].iter().any(|&l| l != 0);
    if bit > 0 {
        sticky |= a[n - 1 - limb] & ((1u64 << bit) - 1) != 0;
    }
    for i in (0..n).rev() {
        let src = i as isize - limb as isize;
        a[i] = if src < 0 {
            0
        } else if bit == 0 {
            a[src as usize]
        } else {
            let hi = if src >= 1 {
                a[(src - 1) as usize] << (64 - bit)
            } else {
                0
            };
            (a[src as usize] >> bit) | hi
        };
    }
    sticky
}

/// Shift left by `k` bits in place; the top `k` bits must be zero.
fn mant_shl(a: &mut [u64], k: u64) {
    if k == 0 {
        return;
    }
    let n = a.len();
    debug_assert!(k <= mant_leading_zeros(a) || mant_is_zero(a));
    let limb = (k / 64) as usize;
    let bit = (k % 64) as u32;
    for i in 0..n {
        let src = i + limb;
        a[i] = if src >= n {
            0
        } else if bit == 0 {
            a[src]
        } else {
            let lo = if src + 1 < n { a[src + 1] >> (64 - bit) } else { 0 };
            (a[src] << bit) | lo
        };
    }
}

/// Full schoolbook product: `a × b`, result `a.len() + b.len()` limbs.
fn mant_mul(a: &[u64], b: &[u64]) -> Vec<u64> {
    let (la, lb) = (a.len(), b.len());
    let mut out = vec![0u64; la + lb];
    for i in (0..la).rev() {
        let mut carry = 0u128;
        for j in (0..lb).rev() {
            let idx = i + j + 1;
            let cur = a[i] as u128 * b[j] as u128 + out[idx] as u128 + carry;
            out[idx] = cur as u64;
            carry = cur >> 64;
        }
        // propagate carry into out[i]
        let mut idx = i as isize;
        let mut c = carry;
        while c != 0 {
            let cur = out[idx as usize] as u128 + c;
            out[idx as usize] = cur as u64;
            c = cur >> 64;
            idx -= 1;
        }
    }
    out
}

impl BigFloat {
    // -----------------------------------------------------------------
    // construction
    // -----------------------------------------------------------------

    /// Positive/negative zero is represented as a single zero.
    pub fn zero(prec: usize) -> Self {
        BigFloat { sign: 0, exp: 0, mant: vec![0; prec.max(1)] }
    }

    /// The value 1 at the given precision.
    pub fn one(prec: usize) -> Self {
        let mut mant = vec![0u64; prec.max(1)];
        mant[0] = 1 << 63;
        BigFloat { sign: 1, exp: 1, mant }
    }

    /// Exact conversion from `u64`.
    pub fn from_u64(v: u64, prec: usize) -> Self {
        if v == 0 {
            return Self::zero(prec);
        }
        let lz = v.leading_zeros() as u64;
        let mut mant = vec![0u64; prec.max(1)];
        mant[0] = v << lz;
        BigFloat { sign: 1, exp: 64 - lz as i64, mant }
    }

    /// Exact conversion from `i64`.
    pub fn from_i64(v: i64, prec: usize) -> Self {
        let mut r = Self::from_u64(v.unsigned_abs(), prec);
        if v < 0 {
            r.sign = -r.sign;
        }
        r
    }

    /// Exact conversion from `f64` (every finite f64 is representable).
    pub fn from_f64(x: f64, prec: usize) -> Self {
        assert!(x.is_finite(), "BigFloat::from_f64 of non-finite {x}");
        if x == 0.0 {
            return Self::zero(prec);
        }
        let bits = x.to_bits();
        let sign = if bits >> 63 == 1 { -1i8 } else { 1 };
        let biased = ((bits >> 52) & 0x7ff) as i64;
        let frac = bits & 0xf_ffff_ffff_ffff;
        let (sig, e) = if biased == 0 {
            (frac, -1074i64) // subnormal: value = frac * 2^-1074
        } else {
            (frac | (1 << 52), biased - 1023 - 52)
        };
        // value = sig * 2^e, sig has <= 53 bits
        let lz = sig.leading_zeros() as u64;
        let mut mant = vec![0u64; prec.max(1)];
        mant[0] = sig << lz;
        BigFloat { sign, exp: e + 64 - lz as i64, mant }
    }

    /// Exact conversion from `f32`.
    pub fn from_f32(x: f32, prec: usize) -> Self {
        Self::from_f64(x as f64, prec) // f32 -> f64 is exact
    }

    /// Build `sign · int(limbs) · 2^pow2` from a big-endian integer limb
    /// vector (exact-sticky if wider than `prec`). Used by the Kulisch
    /// accumulator to hand its exact fixed-point sum to the rounder.
    pub fn from_integer_be(sign: i8, limbs: Vec<u64>, pow2: i64, prec: usize) -> Self {
        if sign == 0 || mant_is_zero(&limbs) {
            return Self::zero(prec);
        }
        // int(limbs) = 0.limbs · 2^(64·len)
        let exp = 64 * limbs.len() as i64 + pow2;
        Self::normalize_in(sign, exp, limbs, prec, false)
    }

    // -----------------------------------------------------------------
    // queries
    // -----------------------------------------------------------------

    /// True iff the value is zero.
    pub fn is_zero(&self) -> bool {
        self.sign == 0
    }

    /// Sign: -1, 0 or +1.
    pub fn signum(&self) -> i8 {
        self.sign
    }

    /// `floor(log2 |value|)` for nonzero values.
    pub fn log2_floor(&self) -> i64 {
        debug_assert!(self.sign != 0);
        self.exp - 1
    }

    /// Precision in limbs.
    pub fn prec(&self) -> usize {
        self.mant.len()
    }

    /// Change precision. Extending is exact; shrinking jams the lost bits
    /// into the new last bit (round-to-odd).
    pub fn with_prec(&self, prec: usize) -> Self {
        let prec = prec.max(1);
        let mut r = self.clone();
        if prec >= r.mant.len() {
            r.mant.resize(prec, 0);
        } else {
            let sticky = r.mant[prec..].iter().any(|&l| l != 0);
            r.mant.truncate(prec);
            if sticky {
                let last = r.mant.len() - 1;
                r.mant[last] |= 1;
            }
        }
        r
    }

    fn normalize_in(sign: i8, mut exp: i64, mut work: Vec<u64>, prec: usize, mut sticky: bool) -> Self {
        if mant_is_zero(&work) {
            if sticky {
                // value is a pure sticky residue: representable as the
                // smallest odd mantissa at the working exponent floor —
                // callers never hit this for exact-input subtraction (see
                // module docs); keep a conservative tiny value.
                let mut mant = vec![0u64; prec];
                mant[0] = 1 << 63;
                // 2^(exp - 64*work_len) magnitude bound; round-to-odd tag
                let e = exp - 64 * work.len() as i64;
                let last = prec - 1;
                mant[last] |= 1;
                return BigFloat { sign, exp: e, mant };
            }
            return Self::zero(prec);
        }
        let lz = mant_leading_zeros(&work);
        mant_shl(&mut work, lz);
        exp -= lz as i64;
        // truncate to prec limbs with sticky jam
        if work.len() > prec {
            sticky |= work[prec..].iter().any(|&l| l != 0);
            work.truncate(prec);
        } else {
            work.resize(prec, 0);
        }
        if sticky {
            let last = work.len() - 1;
            work[last] |= 1;
        }
        BigFloat { sign, exp, mant: work }
    }

    // -----------------------------------------------------------------
    // comparison
    // -----------------------------------------------------------------

    /// Total order on values.
    pub fn cmp_val(&self, other: &Self) -> Ordering {
        match self.sign.cmp(&other.sign) {
            Ordering::Equal => {}
            o => return o,
        }
        if self.sign == 0 {
            return Ordering::Equal;
        }
        let mag = self.cmp_mag(other);
        if self.sign > 0 {
            mag
        } else {
            mag.reverse()
        }
    }

    /// Compare |self| with |other|.
    pub fn cmp_mag(&self, other: &Self) -> Ordering {
        match (self.sign == 0, other.sign == 0) {
            (true, true) => return Ordering::Equal,
            (true, false) => return Ordering::Less,
            (false, true) => return Ordering::Greater,
            _ => {}
        }
        match self.exp.cmp(&other.exp) {
            Ordering::Equal => {}
            o => return o,
        }
        let n = self.mant.len().max(other.mant.len());
        for i in 0..n {
            let a = self.mant.get(i).copied().unwrap_or(0);
            let b = other.mant.get(i).copied().unwrap_or(0);
            match a.cmp(&b) {
                Ordering::Equal => continue,
                o => return o,
            }
        }
        Ordering::Equal
    }

    // -----------------------------------------------------------------
    // sign / scale
    // -----------------------------------------------------------------

    /// Negation (exact).
    pub fn neg(&self) -> Self {
        let mut r = self.clone();
        r.sign = -r.sign;
        r
    }

    /// Absolute value (exact).
    pub fn abs(&self) -> Self {
        let mut r = self.clone();
        r.sign = r.sign.abs() as i8;
        r
    }

    /// Multiply by 2^k (exact).
    pub fn mul_pow2(&self, k: i64) -> Self {
        if self.sign == 0 {
            return self.clone();
        }
        let mut r = self.clone();
        r.exp += k;
        r
    }

    // -----------------------------------------------------------------
    // add / sub (exact sticky)
    // -----------------------------------------------------------------

    /// Addition with exact sticky (round-to-odd at `max(prec)` limbs).
    pub fn add(&self, other: &Self) -> Self {
        let prec = self.prec().max(other.prec());
        if self.sign == 0 {
            return other.with_prec(prec);
        }
        if other.sign == 0 {
            return self.with_prec(prec);
        }
        // order by magnitude
        let (hi, lo) = match self.cmp_mag(other) {
            Ordering::Less => (other, self),
            _ => (self, other),
        };
        if hi.sign != lo.sign && hi.cmp_mag(lo) == Ordering::Equal {
            return Self::zero(prec);
        }
        let w = prec + 1; // one guard limb
        let mut hw = hi.mant.clone();
        hw.resize(w, 0);
        let mut lw = lo.mant.clone();
        lw.resize(w, 0);
        let d = (hi.exp - lo.exp) as u64;
        let sticky = mant_shr_sticky(&mut lw, d);
        if hi.sign == lo.sign {
            let carry = mant_add_assign(&mut hw, &lw);
            let mut exp = hi.exp;
            let mut st = sticky;
            if carry {
                st |= mant_shr_sticky(&mut hw, 1);
                hw[0] |= 1 << 63;
                exp += 1;
            }
            Self::normalize_in(hi.sign, exp, hw, prec, st)
        } else {
            mant_sub_assign(&mut hw, &lw);
            if sticky {
                // true lo was slightly larger than its truncation: the
                // true difference is (hw - lw) - frac with 0 < frac < 1ulp
                mant_sub_one_ulp(&mut hw);
            }
            Self::normalize_in(hi.sign, hi.exp, hw, prec, sticky)
        }
    }

    /// Subtraction (via negated addition; exact sticky).
    pub fn sub(&self, other: &Self) -> Self {
        self.add(&other.neg())
    }

    // -----------------------------------------------------------------
    // mul / div / sqrt (exact sticky)
    // -----------------------------------------------------------------

    /// Multiplication with exact sticky.
    pub fn mul(&self, other: &Self) -> Self {
        let prec = self.prec().max(other.prec());
        if self.sign == 0 || other.sign == 0 {
            return Self::zero(prec);
        }
        let work = mant_mul(&self.mant, &other.mant);
        // 0.a * 0.b in [1/4, 1): at most one leading zero bit
        let exp = self.exp + other.exp;
        Self::normalize_in(self.sign * other.sign, exp, work, prec, false)
    }

    /// Division with exact sticky (restoring long division).
    pub fn div(&self, other: &Self) -> Self {
        let prec = self.prec().max(other.prec());
        assert!(other.sign != 0, "BigFloat division by zero");
        if self.sign == 0 {
            return Self::zero(prec);
        }
        let w = prec + 1; // quotient limbs
        // rem/den as (w+1)-limb integers with a high headroom limb
        let mut rem = vec![0u64; w + 1];
        let mut den = vec![0u64; w + 1];
        for (i, &l) in self.mant.iter().enumerate().take(w) {
            rem[i + 1] = l;
        }
        for (i, &l) in other.mant.iter().enumerate().take(w) {
            den[i + 1] = l;
        }
        let ge = mant_cmp(&rem, &den) != Ordering::Less;
        let exp = self.exp - other.exp + if ge { 1 } else { 0 };
        if !ge {
            mant_shl(&mut rem, 1);
        }
        let mut q = vec![0u64; w];
        for bit in 0..w * 64 {
            if mant_cmp(&rem, &den) != Ordering::Less {
                mant_sub_assign(&mut rem, &den);
                q[bit / 64] |= 1 << (63 - bit % 64);
            }
            mant_shl(&mut rem, 1);
        }
        let sticky = !mant_is_zero(&rem);
        Self::normalize_in(self.sign * other.sign, exp, q, prec, sticky)
    }

    /// Square root with exact sticky (digit-by-digit integer sqrt).
    /// Requires `self >= 0`.
    pub fn sqrt(&self) -> Self {
        assert!(self.sign >= 0, "BigFloat sqrt of negative value");
        let prec = self.prec();
        if self.sign == 0 {
            return Self::zero(prec);
        }
        // Make the exponent even: value = f * 2^e with f in [1/4, 1).
        let (mut frac, e) = if self.exp % 2 == 0 {
            (self.mant.clone(), self.exp)
        } else {
            // shift right one bit into [1/4, 1/2); keep the lost bit by
            // extending one limb first (exact)
            let mut m = self.mant.clone();
            m.push(0);
            let s = mant_shr_sticky(&mut m, 1);
            debug_assert!(!s);
            (m, self.exp + 1)
        };
        // Radicand N = frac as integer << pad so N has 2*(prec+1) limbs.
        let nl = 2 * (prec + 1);
        frac.resize(nl, 0); // low-side zero padding = exact scaling
        // Digit-by-digit square root over bit pairs.
        let sl = prec + 1; // result limbs
        let mut s = vec![0u64; sl]; // partial root (integer, low-aligned)
        let mut rem = vec![0u64; sl + 2]; // remainder with headroom
        let mut t = vec![0u64; sl + 2]; // trial subtrahend
        for i in 0..sl * 64 {
            // rem = rem*4 + next two bits of N
            mant_shl(&mut rem, 2);
            let b0 = (frac[(2 * i) / 64] >> (63 - (2 * i) % 64)) & 1;
            let b1 = (frac[(2 * i + 1) / 64] >> (63 - (2 * i + 1) % 64)) & 1;
            let last = rem.len() - 1;
            rem[last] |= (b0 << 1) | b1;
            // trial = 4*s + 1 (s currently holds i high bits, low-aligned)
            t.iter_mut().for_each(|l| *l = 0);
            // copy s into t shifted left by 2, into the low-aligned tail
            for (k, &l) in s.iter().enumerate() {
                t[k + 2] = l;
            }
            mant_shl(&mut t, 2);
            let tl = t.len() - 1;
            t[tl] |= 1;
            if mant_cmp(&rem, &t) != Ordering::Less {
                mant_sub_assign(&mut rem, &t);
                // s = s*2 + 1
                mant_shl(&mut s, 1);
                let sl_ = s.len() - 1;
                s[sl_] |= 1;
            } else {
                mant_shl(&mut s, 1);
            }
        }
        let sticky = !mant_is_zero(&rem);
        // s is the floor-sqrt with sl*64 bits; value = s * 2^(e/2 - sl*64)
        // Interpreted as a fraction: 0.s * 2^(e/2)  (s MSB set by
        // construction since frac >= 1/4).
        Self::normalize_in(1, e / 2, s, prec, sticky)
    }

    // -----------------------------------------------------------------
    // small-integer scaling (fast paths for series)
    // -----------------------------------------------------------------

    /// Divide by a small positive integer (exact sticky, O(prec)).
    pub fn div_u64(&self, d: u64) -> Self {
        assert!(d != 0);
        if self.sign == 0 || d == 1 {
            return self.clone();
        }
        let n = self.prec();
        let mut q = vec![0u64; n + 2];
        let mut rem: u128 = 0;
        for (i, slot) in q.iter_mut().enumerate() {
            let limb = self.mant.get(i).copied().unwrap_or(0);
            let cur = (rem << 64) | limb as u128;
            *slot = (cur / d as u128) as u64;
            rem = cur % d as u128;
        }
        let sticky = rem != 0;
        Self::normalize_in(self.sign, self.exp, q, n, sticky)
    }

    /// Multiply by a small positive integer (exact sticky, O(prec)).
    pub fn mul_u64(&self, m: u64) -> Self {
        assert!(m != 0);
        if self.sign == 0 || m == 1 {
            return self.clone();
        }
        let n = self.prec();
        let mut out = vec![0u64; n + 1];
        let mut carry: u128 = 0;
        for i in (0..n).rev() {
            let cur = self.mant[i] as u128 * m as u128 + carry;
            out[i + 1] = cur as u64;
            carry = cur >> 64;
        }
        out[0] = carry as u64;
        // out is a fraction with the radix point shifted 64 bits left:
        // value = 0.out * 2^(exp + 64)
        Self::normalize_in(self.sign, self.exp + 64, out, n, false)
    }

    // -----------------------------------------------------------------
    // integer extraction
    // -----------------------------------------------------------------

    /// Truncate toward zero (exact).
    pub fn trunc(&self) -> Self {
        if self.sign == 0 || self.exp <= 0 {
            return Self::zero(self.prec());
        }
        let int_bits = self.exp as u64;
        let total_bits = 64 * self.mant.len() as u64;
        if int_bits >= total_bits {
            return self.clone(); // already an integer
        }
        let mut m = self.mant.clone();
        // zero everything below bit `int_bits`
        let limb = (int_bits / 64) as usize;
        let bit = (int_bits % 64) as u32;
        if bit > 0 {
            m[limb] &= !((1u64 << (64 - bit)) - 1);
            for l in m.iter_mut().skip(limb + 1) {
                *l = 0;
            }
        } else {
            for l in m.iter_mut().skip(limb) {
                *l = 0;
            }
        }
        if mant_is_zero(&m) {
            return Self::zero(self.prec());
        }
        Self::normalize_in(self.sign, self.exp, m, self.prec(), false)
    }

    /// Round to nearest i64, ties away from zero. Requires |value| < 2^62.
    pub fn round_i64(&self) -> i64 {
        if self.sign == 0 {
            return 0;
        }
        assert!(self.exp <= 62, "round_i64 out of range");
        if self.exp <= -1 {
            return 0; // |value| < 1/2
        }
        if self.exp == 0 {
            // |value| ∈ [1/2, 1): rounds to ±1 (ties away from zero)
            return self.sign as i64;
        }
        let k = self.exp as u32; // number of integer bits (1..=62)
        let hi128 = (self.mant[0] as u128) << 64
            | self.mant.get(1).copied().unwrap_or(0) as u128;
        let int = (hi128 >> (128 - k)) as i64;
        let round_bit = (hi128 >> (128 - k - 1)) & 1 == 1;
        let v = int + if round_bit { 1 } else { 0 };
        if self.sign < 0 {
            -v
        } else {
            v
        }
    }

    /// Low two bits of an integer-valued BigFloat (for trig quadrants).
    pub fn integer_low2(&self) -> u8 {
        if self.sign == 0 || self.exp <= 0 {
            return 0;
        }
        let k = self.exp as u64; // integer bit count
        let bit = |p: u64| -> u8 {
            // bit p of the big-endian bit stream (0 = MSB)
            if p >= 64 * self.mant.len() as u64 {
                0
            } else {
                ((self.mant[(p / 64) as usize] >> (63 - p % 64)) & 1) as u8
            }
        };
        if k == 1 {
            bit(0)
        } else {
            (bit(k - 2) << 1) | bit(k - 1)
        }
    }

    // -----------------------------------------------------------------
    // rounding to machine formats
    // -----------------------------------------------------------------

    /// Extract the top `k` bits plus a round bit and exact sticky.
    fn extract(&self, k: u32) -> (u64, bool, bool) {
        debug_assert!(k <= 62);
        let hi128 = (self.mant[0] as u128) << 64
            | self.mant.get(1).copied().unwrap_or(0) as u128;
        let top = if k == 0 { 0 } else { (hi128 >> (128 - k)) as u64 };
        let round = (hi128 >> (128 - k - 1)) & 1 == 1;
        let mask = (1u128 << (128 - k - 1)) - 1;
        let mut sticky = hi128 & mask != 0;
        sticky |= self.mant.iter().skip(2).any(|&l| l != 0);
        (top, round, sticky)
    }

    /// Round to `f32` with round-to-nearest-even. Correct by the
    /// round-to-odd double-rounding theorem for every exactly-sticky
    /// `BigFloat` value.
    pub fn to_f32(&self) -> f32 {
        if self.sign == 0 {
            return 0.0;
        }
        let e_unb = self.exp - 1; // floor(log2 |value|)
        let neg = self.sign < 0;
        if e_unb > 128 {
            return if neg { f32::NEG_INFINITY } else { f32::INFINITY };
        }
        if e_unb < -150 {
            return if neg { -0.0 } else { 0.0 };
        }
        let keep: i64 = if e_unb >= -126 { 24 } else { 24 - (-126 - e_unb) };
        if keep < 0 {
            return if neg { -0.0 } else { 0.0 };
        }
        let (mut top, round, sticky) = self.extract(keep as u32);
        let mut e = e_unb;
        if round && (sticky || top & 1 == 1) {
            top += 1;
            if top == 1 << keep {
                // carry into the next binade
                e += 1;
                if keep == 24 {
                    top = 1 << 23;
                } else {
                    // subnormal carried up; re-derive layout below
                    top = 1 << keep; // becomes the implicit-1 pattern
                }
            }
        }
        if top == 0 {
            return if neg { -0.0 } else { 0.0 };
        }
        // assemble
        let bits: u32;
        if e >= -126 && top >= 1 << 23 {
            if e > 127 {
                return if neg { f32::NEG_INFINITY } else { f32::INFINITY };
            }
            // normal: top has 24 bits with MSB the implicit 1
            debug_assert!(top < 1 << 24);
            bits = (((e + 127) as u32) << 23) | (top as u32 & 0x7f_ffff);
        } else {
            // subnormal (top < 2^23, value = top * 2^-149), or the carry
            // case where top == 2^23 which is exactly the min normal
            debug_assert!(top <= 1 << 23);
            bits = top as u32;
        }
        let bits = bits | if neg { 1 << 31 } else { 0 };
        f32::from_bits(bits)
    }

    /// Round to `f64` with round-to-nearest-even (same guarantees).
    pub fn to_f64(&self) -> f64 {
        if self.sign == 0 {
            return 0.0;
        }
        let e_unb = self.exp - 1;
        let neg = self.sign < 0;
        if e_unb > 1024 {
            return if neg { f64::NEG_INFINITY } else { f64::INFINITY };
        }
        if e_unb < -1075 {
            return if neg { -0.0 } else { 0.0 };
        }
        let keep: i64 = if e_unb >= -1022 { 53 } else { 53 - (-1022 - e_unb) };
        if keep < 0 {
            return if neg { -0.0 } else { 0.0 };
        }
        let (mut top, round, sticky) = self.extract(keep as u32);
        let mut e = e_unb;
        if round && (sticky || top & 1 == 1) {
            top += 1;
            if top == 1 << keep {
                e += 1;
                if keep == 53 {
                    top = 1 << 52;
                } else {
                    top = 1 << keep;
                }
            }
        }
        if top == 0 {
            return if neg { -0.0 } else { 0.0 };
        }
        let bits: u64;
        if e >= -1022 && top >= 1 << 52 {
            if e > 1023 {
                return if neg { f64::NEG_INFINITY } else { f64::INFINITY };
            }
            debug_assert!(top < 1 << 53);
            bits = (((e + 1023) as u64) << 52) | (top & 0xf_ffff_ffff_ffff);
        } else {
            debug_assert!(top <= 1 << 52);
            bits = top;
        }
        let bits = bits | if neg { 1 << 63 } else { 0 };
        f64::from_bits(bits)
    }
}

// ---------------------------------------------------------------------
// constants (cached per precision)
// ---------------------------------------------------------------------

/// Cached high-precision constants.
pub mod consts {
    use super::BigFloat;
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};

    #[derive(PartialEq, Eq, Hash, Clone, Copy)]
    enum Kind {
        Ln2,
        Pi,
    }

    fn cache() -> &'static Mutex<HashMap<(Kind, usize), BigFloat>> {
        static C: OnceLock<Mutex<HashMap<(Kind, usize), BigFloat>>> = OnceLock::new();
        C.get_or_init(|| Mutex::new(HashMap::new()))
    }

    /// ln 2 at `prec` limbs, via ln 2 = Σ_{k≥1} 1/(k·2^k).
    pub fn ln2(prec: usize) -> BigFloat {
        if let Some(v) = cache().lock().unwrap().get(&(Kind::Ln2, prec)) {
            return v.clone();
        }
        let w = prec + 1;
        let mut sum = BigFloat::zero(w);
        let bits = 64 * w as u64 + 16;
        let mut k = 1u64;
        while k <= bits {
            let term = BigFloat::from_u64(1, w).div_u64(k).mul_pow2(-(k as i64));
            sum = sum.add(&term);
            k += 1;
        }
        let out = sum.with_prec(prec);
        cache().lock().unwrap().insert((Kind::Ln2, prec), out.clone());
        out
    }

    /// π at `prec` limbs, via Machin: π = 16·atan(1/5) − 4·atan(1/239).
    pub fn pi(prec: usize) -> BigFloat {
        if let Some(v) = cache().lock().unwrap().get(&(Kind::Pi, prec)) {
            return v.clone();
        }
        let w = prec + 1;
        let out = atan_inv(5, w)
            .mul_u64(16)
            .sub(&atan_inv(239, w).mul_u64(4))
            .with_prec(prec);
        cache().lock().unwrap().insert((Kind::Pi, prec), out.clone());
        out
    }

    /// π/2 at `prec` limbs.
    pub fn half_pi(prec: usize) -> BigFloat {
        pi(prec).mul_pow2(-1)
    }

    /// atan(1/m) by its Taylor series (m ≥ 2 so m² fits u64 comfortably).
    fn atan_inv(m: u64, prec: usize) -> BigFloat {
        let m2 = m * m;
        let target = -(64 * prec as i64) - 16;
        let mut pw = BigFloat::from_u64(1, prec).div_u64(m); // 1/m^(2j+1)
        let mut sum = BigFloat::zero(prec);
        let mut j = 0u64;
        loop {
            let term = pw.div_u64(2 * j + 1);
            sum = if j % 2 == 0 { sum.add(&term) } else { sum.sub(&term) };
            pw = pw.div_u64(m2);
            if pw.is_zero() || pw.log2_floor() < target {
                break;
            }
            j += 1;
        }
        sum
    }
}

// ---------------------------------------------------------------------
// transcendental functions
// ---------------------------------------------------------------------

impl BigFloat {
    /// e^x by argument reduction (x = k·ln2 + r) and Taylor series.
    /// Requires |x| < 2^32 (callers clamp earlier — f32 exp over/underflows
    /// long before that).
    pub fn exp_bf(&self) -> Self {
        let n = self.prec();
        if self.sign == 0 {
            return Self::one(n);
        }
        assert!(self.exp <= 32, "exp_bf argument out of supported range");
        let ln2 = consts::ln2(n);
        let k = self.div(&ln2).round_i64();
        let r = self.sub(&Self::from_i64(k, n).mul(&ln2)); // |r| <= ln2/2 + eps
        let target = -(64 * n as i64) - 16;
        let mut term = Self::one(n);
        let mut sum = Self::one(n);
        let mut i = 1u64;
        loop {
            term = term.mul(&r).div_u64(i);
            if term.is_zero() || term.log2_floor() < target {
                break;
            }
            sum = sum.add(&term);
            i += 1;
        }
        sum.mul_pow2(k)
    }

    /// ln x via atanh series: ln m = 2·atanh((m−1)/(m+1)), plus e·ln 2.
    /// Requires x > 0.
    pub fn ln_bf(&self) -> Self {
        assert!(self.sign > 0, "ln_bf requires a positive argument");
        let n = self.prec();
        let e = self.exp - 1; // x = m · 2^e with m in [1, 2)
        let mut m = self.clone();
        m.exp = 1;
        let one = Self::one(n);
        let z = m.sub(&one).div(&m.add(&one)); // |z| <= 1/3
        let ln_m = if z.is_zero() {
            Self::zero(n)
        } else {
            let z2 = z.mul(&z);
            let target = -(64 * n as i64) - 16;
            let mut pw = z.clone();
            let mut sum = z.clone();
            let mut j = 1u64;
            loop {
                pw = pw.mul(&z2);
                if pw.is_zero() || pw.log2_floor() < target {
                    break;
                }
                sum = sum.add(&pw.div_u64(2 * j + 1));
                j += 1;
            }
            sum.mul_pow2(1)
        };
        if e == 0 {
            ln_m
        } else {
            ln_m.add(&Self::from_i64(e, n).mul(&consts::ln2(n)))
        }
    }

    /// Reduce |x| modulo π/2 at trig working precision.
    /// Returns (r, quadrant) with x ≡ quadrant·π/2 + r and |r| ≲ π/2.
    fn trig_reduce(&self) -> (Self, u8) {
        let w = self.prec().max(PREC_TRIG);
        let x = self.abs().with_prec(w);
        let hp = consts::half_pi(w);
        if x.cmp_mag(&hp) == Ordering::Less {
            return (x, 0);
        }
        let q = x.div(&hp);
        let k = q.trunc();
        let quad = k.integer_low2();
        let r = x.sub(&k.mul(&hp));
        (r, quad)
    }

    /// Taylor series for sin on a reduced argument (|r| ≲ π/2).
    fn sin_series(r: &Self) -> Self {
        let n = r.prec();
        if r.sign == 0 {
            return Self::zero(n);
        }
        let r2 = r.mul(r);
        let target = -(64 * n as i64) - 16;
        let mut term = r.clone();
        let mut sum = r.clone();
        let mut j = 1u64;
        loop {
            term = term.mul(&r2).div_u64(2 * j).div_u64(2 * j + 1).neg();
            if term.is_zero() || term.log2_floor() < target {
                break;
            }
            sum = sum.add(&term);
            j += 1;
        }
        sum
    }

    /// Taylor series for cos on a reduced argument.
    fn cos_series(r: &Self) -> Self {
        let n = r.prec();
        let r2 = r.mul(r);
        let target = -(64 * n as i64) - 16;
        let mut term = Self::one(n);
        let mut sum = Self::one(n);
        let mut j = 1u64;
        loop {
            term = term.mul(&r2).div_u64(2 * j - 1).div_u64(2 * j).neg();
            if term.is_zero() || term.log2_floor() < target {
                break;
            }
            sum = sum.add(&term);
            j += 1;
        }
        sum
    }

    /// sin x (any finite x; argument reduction at `PREC_TRIG`).
    pub fn sin_bf(&self) -> Self {
        let n = self.prec();
        if self.sign == 0 {
            return Self::zero(n);
        }
        let (r, quad) = self.trig_reduce();
        let v = match quad {
            0 => Self::sin_series(&r),
            1 => Self::cos_series(&r),
            2 => Self::sin_series(&r).neg(),
            _ => Self::cos_series(&r).neg(),
        };
        let v = v.with_prec(n.max(PREC_ORACLE));
        if self.sign < 0 {
            v.neg()
        } else {
            v
        }
    }

    /// cos x (any finite x).
    pub fn cos_bf(&self) -> Self {
        let n = self.prec();
        let (r, quad) = self.trig_reduce();
        let v = match quad {
            0 => Self::cos_series(&r),
            1 => Self::sin_series(&r).neg(),
            2 => Self::cos_series(&r).neg(),
            _ => Self::sin_series(&r),
        };
        v.with_prec(n.max(PREC_ORACLE))
    }

    /// tan x = sin x / cos x (exact division of the series results).
    pub fn tan_bf(&self) -> Self {
        let (r, quad) = self.trig_reduce();
        let s = Self::sin_series(&r);
        let c = Self::cos_series(&r);
        let v = match quad & 1 {
            0 => s.div(&c),
            _ => c.div(&s).neg(),
        };
        let v = v.with_prec(self.prec().max(PREC_ORACLE));
        if self.sign < 0 {
            v.neg()
        } else {
            v
        }
    }

    /// tanh x = (e^{2x} − 1)/(e^{2x} + 1). |x| must stay in exp_bf range.
    pub fn tanh_bf(&self) -> Self {
        let n = self.prec();
        if self.sign == 0 {
            return Self::zero(n);
        }
        let t = self.mul_pow2(1).exp_bf();
        let one = Self::one(n);
        t.sub(&one).div(&t.add(&one))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bf(x: f64) -> BigFloat {
        BigFloat::from_f64(x, PREC_ORACLE)
    }

    #[test]
    fn roundtrip_f64() {
        for &x in &[
            0.0, 1.0, -1.0, 0.5, 3.141592653589793, 1e-300, -1e300,
            f64::MIN_POSITIVE, 4.9e-324, 2.2250738585072014e-308,
        ] {
            assert_eq!(bf(x).to_f64().to_bits(), x.to_bits(), "x={x}");
        }
    }

    #[test]
    fn roundtrip_f32_incl_subnormals() {
        for &x in &[
            0.0f32, 1.0, -2.5, 1e-40, -1e-40, f32::MIN_POSITIVE,
            f32::from_bits(1), 3.4028235e38, 0.1,
        ] {
            assert_eq!(
                BigFloat::from_f32(x, PREC_ORACLE).to_f32().to_bits(),
                x.to_bits(),
                "x={x}"
            );
        }
    }

    #[test]
    fn add_matches_f64_when_exact() {
        // Sums of doubles that are exactly representable in f64.
        let cases = [(1.5, 2.25), (1e10, 1.0), (0.5, 0.25), (-3.0, 1.0)];
        for &(a, b) in &cases {
            assert_eq!(bf(a).add(&bf(b)).to_f64(), a + b);
        }
    }

    #[test]
    fn add_is_correctly_rounded_vs_f64() {
        // 1 + 2^-60 is inexact in f64; BigFloat holds it exactly and
        // rounds back to f64 the way IEEE does.
        let a = bf(1.0);
        let b = bf(2f64.powi(-60));
        let s = a.add(&b);
        assert_eq!(s.to_f64(), 1.0); // RNE: below half-ulp
        let c = bf(2f64.powi(-53)); // exactly half-ulp of 1.0 -> ties to even
        assert_eq!(bf(1.0).add(&c).to_f64(), 1.0);
        let d = bf(2f64.powi(-52));
        assert_eq!(bf(1.0).add(&d).to_f64(), 1.0 + 2f64.powi(-52));
    }

    #[test]
    fn sub_cancellation_is_exact() {
        let a = bf(1.0 + 2f64.powi(-50));
        let b = bf(1.0);
        assert_eq!(a.sub(&b).to_f64(), 2f64.powi(-50));
        assert!(bf(5.0).sub(&bf(5.0)).is_zero());
    }

    #[test]
    fn mul_matches_f64_exact_products() {
        for &(a, b) in &[(1.5, 2.0), (0.1, 1.0), (3.0, 7.0), (-2.5, 4.0)] {
            assert_eq!(bf(a).mul(&bf(b)).to_f64(), a * b);
        }
        // Product needing the full 106 bits: (1+2^-52)^2
        let x = 1.0 + 2f64.powi(-52);
        let p = bf(x).mul(&bf(x));
        // exact value 1 + 2^-51 + 2^-104; f64 RNE keeps 1 + 2^-51
        assert_eq!(p.to_f64(), 1.0 + 2f64.powi(-51));
    }

    #[test]
    fn div_exact_and_inexact() {
        assert_eq!(bf(1.0).div(&bf(4.0)).to_f64(), 0.25);
        assert_eq!(bf(10.0).div(&bf(2.0)).to_f64(), 5.0);
        // 1/3 correctly rounded in f64
        assert_eq!(bf(1.0).div(&bf(3.0)).to_f64(), 1.0 / 3.0);
        // quotient that is an exact f32 tie: (2^24+1)/2 -> ties to even
        let a = BigFloat::from_f64((1u64 << 24) as f64 + 1.0, PREC_ORACLE);
        let q = a.div(&bf(2.0));
        assert_eq!(q.to_f32(), 8_388_608.0); // 2^23, tie rounded to even
    }

    #[test]
    fn sqrt_exact_squares_and_known_values() {
        assert_eq!(bf(4.0).sqrt().to_f64(), 2.0);
        assert_eq!(bf(2.25).sqrt().to_f64(), 1.5);
        assert_eq!(bf(2.0).sqrt().to_f64(), 2f64.sqrt()); // hw sqrt is CR
        assert_eq!(bf(0.5).sqrt().to_f64(), 0.5f64.sqrt());
        // odd exponent path
        assert_eq!(bf(8.0).sqrt().to_f64(), 8f64.sqrt());
    }

    #[test]
    fn small_int_scaling() {
        assert_eq!(bf(1.0).div_u64(8).to_f64(), 0.125);
        assert_eq!(bf(3.0).mul_u64(7).to_f64(), 21.0);
        assert_eq!(bf(1.0).div_u64(3).to_f64(), 1.0 / 3.0);
        assert_eq!(bf(1.0).div_u64(3).mul_u64(3).to_f64(), 1.0);
    }

    #[test]
    fn comparison_and_sign_ops() {
        assert_eq!(bf(1.0).cmp_val(&bf(2.0)), Ordering::Less);
        assert_eq!(bf(-1.0).cmp_val(&bf(1.0)), Ordering::Less);
        assert_eq!(bf(1.5).cmp_val(&bf(1.5)), Ordering::Equal);
        assert_eq!(bf(-2.0).abs().to_f64(), 2.0);
        assert_eq!(bf(2.0).neg().to_f64(), -2.0);
        assert_eq!(bf(3.0).mul_pow2(2).to_f64(), 12.0);
    }

    #[test]
    fn integer_helpers() {
        assert_eq!(bf(3.7).trunc().to_f64(), 3.0);
        assert_eq!(bf(-3.7).trunc().to_f64(), -3.0);
        assert_eq!(bf(0.3).trunc().to_f64(), 0.0);
        assert_eq!(bf(5.0).trunc().to_f64(), 5.0);
        assert_eq!(bf(2.5).round_i64(), 3);
        assert_eq!(bf(-2.5).round_i64(), -3);
        assert_eq!(bf(2.4).round_i64(), 2);
        assert_eq!(bf(0.1).round_i64(), 0);
        assert_eq!(bf(5.0).integer_low2(), 1);
        assert_eq!(bf(6.0).integer_low2(), 2);
        assert_eq!(bf(7.0).integer_low2(), 3);
        assert_eq!(bf(8.0).integer_low2(), 0);
        assert_eq!(bf(1.0).integer_low2(), 1);
    }

    #[test]
    fn constants_match_f64() {
        assert_eq!(consts::ln2(PREC_ORACLE).to_f64(), std::f64::consts::LN_2);
        assert_eq!(consts::pi(PREC_ORACLE).to_f64(), std::f64::consts::PI);
        assert_eq!(
            consts::half_pi(PREC_TRIG).to_f64(),
            std::f64::consts::FRAC_PI_2
        );
    }

    #[test]
    fn exp_known_values() {
        assert_eq!(bf(0.0).exp_bf().to_f64(), 1.0);
        assert_eq!(bf(1.0).exp_bf().to_f64(), std::f64::consts::E);
        // glibc exp is not proven CR; compare loosely in ULP terms
        for &x in &[0.5, -0.5, 3.0, -10.0, 20.0, 0.001] {
            let got = bf(x).exp_bf().to_f64();
            let want = x.exp();
            let du = (got.to_bits() as i64 - want.to_bits() as i64).abs();
            assert!(du <= 1, "exp({x}): got {got}, libm {want}");
        }
    }

    #[test]
    fn ln_known_values() {
        assert_eq!(bf(1.0).ln_bf().to_f64(), 0.0);
        assert_eq!(bf(2.0).ln_bf().to_f64(), std::f64::consts::LN_2);
        assert_eq!(bf(4.0).ln_bf().to_f64(), 2.0 * std::f64::consts::LN_2);
        for &x in &[0.5, 3.0, 10.0, 1e-30, 1e30, 1.0000001] {
            let got = bf(x).ln_bf().to_f64();
            let want = x.ln();
            let du = (got.to_bits() as i64 - want.to_bits() as i64).abs();
            assert!(du <= 1, "ln({x}): got {got}, libm {want}");
        }
    }

    #[test]
    fn exp_ln_roundtrip() {
        for &x in &[0.5f64, 1.0, 2.0, 10.0, 0.001] {
            let y = bf(x).ln_bf().exp_bf().to_f64();
            let du = (y.to_bits() as i64 - x.to_bits() as i64).abs();
            assert!(du <= 1, "exp(ln({x})) = {y}");
        }
    }

    #[test]
    fn trig_known_values() {
        assert_eq!(bf(0.0).sin_bf().to_f64(), 0.0);
        assert_eq!(bf(0.0).cos_bf().to_f64(), 1.0);
        for &x in &[0.5, 1.0, -1.0, 3.0, 100.0, 1e8, -12345.678] {
            let (gs, gc) = (bf(x).sin_bf().to_f64(), bf(x).cos_bf().to_f64());
            let (ws, wc) = (x.sin(), x.cos());
            assert!(
                (gs.to_bits() as i64 - ws.to_bits() as i64).abs() <= 1,
                "sin({x}) got {gs} want {ws}"
            );
            assert!(
                (gc.to_bits() as i64 - wc.to_bits() as i64).abs() <= 1,
                "cos({x}) got {gc} want {wc}"
            );
        }
    }

    #[test]
    fn trig_huge_argument_reduction() {
        // 2^100 — catastrophic for naive reduction, fine at PREC_TRIG.
        let x = 2f64.powi(100);
        let got = BigFloat::from_f64(x, PREC_ORACLE).sin_bf().to_f64();
        let want = x.sin();
        let du = (got.to_bits() as i64 - want.to_bits() as i64).abs();
        // glibc sin for huge args is itself good; allow 1 ulp slack
        assert!(du <= 1, "sin(2^100) got {got} want {want}");
    }

    #[test]
    fn tan_and_tanh() {
        for &x in &[0.5, 1.0, -2.0, 10.0] {
            let gt = bf(x).tan_bf().to_f64();
            let du = (gt.to_bits() as i64 - x.tan().to_bits() as i64).abs();
            assert!(du <= 1, "tan({x}) got {gt}");
        }
        for &x in &[0.5, -0.5, 2.0, -3.0, 0.001] {
            let gh = bf(x).tanh_bf().to_f64();
            let du = (gh.to_bits() as i64 - x.tanh().to_bits() as i64).abs();
            assert!(du <= 1, "tanh({x}) got {gh}");
        }
        assert!(bf(0.0).tanh_bf().is_zero());
    }

    #[test]
    fn precision_change_round_to_odd() {
        let x = bf(1.0).div_u64(3); // 0.0101... repeating
        let narrow = x.with_prec(1);
        // narrowing must jam a sticky bit -> last bit odd
        assert_eq!(narrow.mant.last().unwrap() & 1, 1);
        // widening is exact
        let wide = narrow.with_prec(8);
        assert_eq!(wide.to_f64(), narrow.to_f64());
    }

    #[test]
    fn to_f32_overflow_and_subnormal_edges() {
        // just over f32 max -> rounds to max or inf depending on magnitude
        let max = BigFloat::from_f32(f32::MAX, PREC_ORACLE);
        let a = max.mul_u64(3).div_u64(2); // 1.5 * MAX -> inf
        assert!(a.to_f32().is_infinite());
        // halfway between 0 and min subnormal ties to even (0)
        let half_min = BigFloat::from_f32(f32::from_bits(1), PREC_ORACLE).mul_pow2(-1);
        assert_eq!(half_min.to_f32(), 0.0);
        // just above the halfway point rounds up to the min subnormal
        let just_above = half_min.mul_u64(3).div_u64(2);
        assert_eq!(just_above.to_f32(), f32::from_bits(1));
    }

    #[test]
    fn div_u64_equals_generic_div() {
        for &x in &[1.0, 3.7, 1e-20, 123456.789] {
            for &d in &[3u64, 7, 10, 97, 1_000_003] {
                let a = bf(x).div_u64(d).to_f64();
                let b = bf(x).div(&BigFloat::from_u64(d, PREC_ORACLE)).to_f64();
                assert_eq!(a, b, "x={x} d={d}");
            }
        }
    }
}
