//! Strided dot products — the innermost loops of GEMM / convolution.
//!
//! Same reduction-order specification as [`super::sum`]: sequential over
//! the k index (the paper's fixed summation order for fully-connected and
//! convolution layers, §3.2.2), with an unfused multiply-then-add graph by
//! default and an explicitly-named FMA variant.

/// Sequential dot over strided views: Σ a[i·sa] · b[i·sb], i = 0..n.
/// Unfused (RepDL default graph).
#[inline]
pub fn dot_strided(a: &[f32], sa: usize, b: &[f32], sb: usize, n: usize) -> f32 {
    debug_assert!(n == 0 || (n - 1) * sa < a.len());
    debug_assert!(n == 0 || (n - 1) * sb < b.len());
    let mut acc = 0.0f32;
    let (mut ia, mut ib) = (0usize, 0usize);
    for _ in 0..n {
        acc += a[ia] * b[ib];
        ia += sa;
        ib += sb;
    }
    acc
}

/// Sequential strided dot with FMA contraction (separate API; see
/// [`super::sum::dot_sequential_fma`]).
#[inline]
pub fn dot_strided_fma(a: &[f32], sa: usize, b: &[f32], sb: usize, n: usize) -> f32 {
    let mut acc = 0.0f32;
    let (mut ia, mut ib) = (0usize, 0usize);
    for _ in 0..n {
        acc = a[ia].mul_add(b[ib], acc);
        ia += sa;
        ib += sb;
    }
    acc
}

/// Pairwise strided dot (tree order shared with `sum_pairwise`'s spec:
/// split at the largest power of two below n, sequential base ≤ 8).
pub fn dot_strided_pairwise(a: &[f32], sa: usize, b: &[f32], sb: usize, n: usize) -> f32 {
    if n <= 8 {
        return dot_strided(a, sa, b, sb, n);
    }
    let m = super::sum::pairwise_split(n);
    dot_strided_pairwise(a, sa, b, sb, m)
        + dot_strided_pairwise(&a[m * sa..], sa, &b[m * sb..], sb, n - m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rnum::sum::{dot_sequential, dot_sequential_fma};

    fn vecs(n: usize) -> (Vec<f32>, Vec<f32>) {
        let a: Vec<f32> = (0..n).map(|i| ((i * 37 % 113) as f32 - 56.0) * 0.043).collect();
        let b: Vec<f32> = (0..n).map(|i| ((i * 91 % 127) as f32 - 63.0) * 0.029).collect();
        (a, b)
    }

    #[test]
    fn unit_stride_matches_dense() {
        let (a, b) = vecs(501);
        assert_eq!(
            dot_strided(&a, 1, &b, 1, 501).to_bits(),
            dot_sequential(&a, &b).to_bits()
        );
        assert_eq!(
            dot_strided_fma(&a, 1, &b, 1, 501).to_bits(),
            dot_sequential_fma(&a, &b).to_bits()
        );
    }

    #[test]
    fn strided_equals_gathered_sequential() {
        let (a, b) = vecs(600);
        // stride-3 view of a vs an explicit gather
        let ga: Vec<f32> = a.iter().step_by(3).copied().collect();
        let gb: Vec<f32> = b.iter().step_by(2).copied().take(ga.len()).collect();
        let n = ga.len().min(gb.len());
        assert_eq!(
            dot_strided(&a, 3, &b, 2, n).to_bits(),
            dot_sequential(&ga[..n], &gb[..n]).to_bits()
        );
    }

    #[test]
    fn pairwise_tree_shape_is_fixed() {
        let (a, b) = vecs(1000);
        let x = dot_strided_pairwise(&a, 1, &b, 1, 1000);
        assert_eq!(x.to_bits(), dot_strided_pairwise(&a, 1, &b, 1, 1000).to_bits());
        // differs from sequential in general, but is close
        let s = dot_strided(&a, 1, &b, 1, 1000);
        assert!((x - s).abs() < 1e-2);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(dot_strided(&[], 1, &[], 1, 0), 0.0);
        assert_eq!(dot_strided(&[2.0], 1, &[3.5], 1, 1), 7.0);
    }
}
