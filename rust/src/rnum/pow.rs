//! Correctly-rounded power function for `f32` (paper §3.2.1).
//!
//! `pow` is the one basic operation whose exact cases are non-trivial:
//! `x^y` can be exactly representable (and can land exactly on rounding
//! ties) whenever `y` is dyadic. The decomposition below handles every
//! such family exactly, and routes the remaining — provably irrational —
//! results through the high-precision series evaluation:
//!
//! * `y` integer, |y| ≤ 64 → exact binary exponentiation in a wide
//!   `BigFloat` (all products exact), optional exact-sticky reciprocal.
//! * `y = p·2^−q`, q ≤ 6, |p| ≤ 64 → `sqrt^q(x^p)`: the `BigFloat`
//!   square root has an *exact* sticky bit, and a chain of exact-sticky
//!   operations rounds correctly.
//! * `x` a power of two → `2^(m·y)` with `m·y` computed exactly in `f64`;
//!   integer products are exact, non-integer dyadic exponents give
//!   irrational results (safe for the series path).
//! * everything else → `exp(y·ln x)` at 512-bit precision. By
//!   Gelfond–Schneider these results are transcendental except for the
//!   families above, so no rounding boundary can be hit. (Astronomically
//!   hard cases needing >490 bits of agreement are out of reach of any
//!   known f32 input — same caveat RLIBM documents.)

use super::bigfloat::BigFloat;
use super::log::rlog;

/// Wide precision for exact integer powers (fits 24·64 = 1536 bits).
const PREC_POWI: usize = 26;
/// Precision for the transcendental path.
const PREC_POW_GEN: usize = 8;

/// Exact x^p for integer p ≥ 0 by binary exponentiation.
/// All intermediate products fit PREC_POWI limbs, so every step is exact.
fn powi_exact(x: f32, p: u32) -> BigFloat {
    let mut base = BigFloat::from_f32(x, PREC_POWI);
    let mut acc = BigFloat::one(PREC_POWI);
    let mut e = p;
    while e > 0 {
        if e & 1 == 1 {
            acc = acc.mul(&base);
        }
        base = base.mul(&base);
        e >>= 1;
    }
    acc
}

/// Classify a finite nonzero f32 `y` as `p · 2^-q` with odd `p`.
/// Returns (p, q) when |p| ≤ 64 and 0 ≤ q ≤ 6, else None.
fn small_dyadic(y: f32) -> Option<(i64, u32)> {
    let (s, sig, exp) = super::fbits::decompose(y);
    // strip trailing zeros from the significand → odd p
    let tz = sig.trailing_zeros();
    let p = (sig >> tz) as i64;
    let e = exp + tz as i32; // y = p * 2^e
    if p > 64 {
        return None;
    }
    if e >= 0 {
        // integer y = p << e; representable as (p', q=0) if small
        let v = p.checked_shl(e as u32)?;
        if v > 64 {
            return None;
        }
        Some((s as i64 * v, 0))
    } else {
        let q = (-e) as u32;
        if q > 6 {
            return None;
        }
        Some((s as i64 * p, q))
    }
}

/// Correctly-rounded x^y for `f32` (finite-math cases per IEEE 754 pow).
pub fn rpow(x: f32, y: f32) -> f32 {
    // IEEE special cases (the order matters).
    if y == 0.0 {
        return 1.0; // even for NaN x
    }
    if x == 1.0 {
        return 1.0; // even for NaN y
    }
    if x.is_nan() || y.is_nan() {
        return f32::NAN;
    }
    if y == 1.0 {
        return x;
    }
    let y_int = y == y.trunc() && y.is_finite();
    let y_odd = y_int && (y.abs() < 1e18) && (y.abs() as u64) & 1 == 1;
    if x == 0.0 {
        let neg = x.is_sign_negative() && y_odd;
        return if y > 0.0 {
            if neg {
                -0.0
            } else {
                0.0
            }
        } else if neg {
            f32::NEG_INFINITY
        } else {
            f32::INFINITY
        };
    }
    if x.is_infinite() || y.is_infinite() {
        // standard saturation table
        let ax = x.abs();
        let grows = if y > 0.0 { ax > 1.0 } else { ax < 1.0 };
        if y.is_infinite() {
            if ax == 1.0 {
                return 1.0;
            }
            return if grows { f32::INFINITY } else { 0.0 };
        }
        // x infinite, y finite
        let neg = x.is_sign_negative() && y_odd;
        return if y > 0.0 {
            if neg {
                f32::NEG_INFINITY
            } else {
                f32::INFINITY
            }
        } else if neg {
            -0.0
        } else {
            0.0
        };
    }
    // x finite nonzero, y finite nonzero
    if x < 0.0 && !y_int {
        return f32::NAN;
    }
    let sign = if x < 0.0 && y_odd { -1.0f32 } else { 1.0 };
    let ax = x.abs();

    // Family 1+2: small dyadic exponents — exact-sticky evaluation.
    if let Some((p, q)) = small_dyadic(y) {
        let t = powi_exact(ax, p.unsigned_abs() as u32);
        let t = if p < 0 {
            BigFloat::one(PREC_POWI).div(&t)
        } else {
            t
        };
        let mut t = t;
        for _ in 0..q {
            t = t.sqrt();
        }
        return sign * t.to_f32();
    }

    // Family 3: x an exact power of two → 2^(m·y), m·y exact in f64.
    let bits = ax.to_bits();
    let m: Option<i32> = if bits & 0x007f_ffff == 0 && bits >> 23 != 0 {
        Some((bits >> 23) as i32 - 127)
    } else if bits < 0x0080_0000 && bits.count_ones() == 1 {
        Some(bits.trailing_zeros() as i32 - 149)
    } else {
        None
    };
    if let Some(m) = m {
        let t = m as f64 * y as f64; // exact: ≤ 8 + 24 bits
        if t >= 129.0 {
            return sign * f32::INFINITY;
        }
        if t <= -150.0 {
            return sign * 0.0;
        }
        if t == t.trunc() {
            return sign * super::fbits::pow2_f64(t as i32) as f32;
        }
        // irrational 2^t via the exp path at high precision
        let tb = BigFloat::from_f64(t, PREC_POW_GEN);
        let v = tb
            .mul(&super::bigfloat::consts::ln2(PREC_POW_GEN))
            .exp_bf();
        return sign * v.to_f32();
    }

    // General transcendental path. Range-guard with the CR log (any
    // routing near the guard is consistent: both sides agree).
    let s = y as f64 * rlog(ax) as f64;
    if s > 92.0 {
        return sign * f32::INFINITY;
    }
    if s < -106.0 {
        return sign * 0.0;
    }
    let xb = BigFloat::from_f32(ax, PREC_POW_GEN);
    let yb = BigFloat::from_f32(y, PREC_POW_GEN);
    sign * yb.mul(&xb.ln_bf()).exp_bf().to_f32()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rnum::fbits::ulp_diff;

    #[test]
    fn ieee_special_cases() {
        assert_eq!(rpow(f32::NAN, 0.0), 1.0);
        assert_eq!(rpow(1.0, f32::NAN), 1.0);
        assert!(rpow(f32::NAN, 1.5).is_nan());
        assert!(rpow(-2.0, 0.5).is_nan());
        assert_eq!(rpow(0.0, 2.0), 0.0);
        assert_eq!(rpow(0.0, -2.0), f32::INFINITY);
        assert_eq!(rpow(-0.0, 3.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(rpow(-0.0, -3.0), f32::NEG_INFINITY);
        assert_eq!(rpow(2.0, f32::INFINITY), f32::INFINITY);
        assert_eq!(rpow(0.5, f32::INFINITY), 0.0);
        assert_eq!(rpow(-1.0, f32::INFINITY), 1.0);
        assert_eq!(rpow(f32::INFINITY, 2.0), f32::INFINITY);
        assert_eq!(rpow(f32::NEG_INFINITY, 3.0), f32::NEG_INFINITY);
        assert_eq!(rpow(f32::NEG_INFINITY, 2.0), f32::INFINITY);
    }

    #[test]
    fn exact_integer_powers() {
        assert_eq!(rpow(2.0, 10.0), 1024.0);
        assert_eq!(rpow(-2.0, 3.0), -8.0);
        assert_eq!(rpow(-2.0, 4.0), 16.0);
        assert_eq!(rpow(3.0, 4.0), 81.0);
        assert_eq!(rpow(1.5, 2.0), 2.25);
        assert_eq!(rpow(10.0, -2.0), 0.01);
        assert_eq!(rpow(2.0, -10.0), 2f32.powi(-10));
        // overflow saturates correctly
        assert_eq!(rpow(10.0, 39.0), f32::INFINITY);
        assert_eq!(rpow(10.0, -46.0), 0.0);
    }

    #[test]
    fn exact_dyadic_exponents() {
        assert_eq!(rpow(4.0, 0.5), 2.0);
        assert_eq!(rpow(4.0, 1.5), 8.0);
        assert_eq!(rpow(16.0, 0.25), 2.0);
        assert_eq!(rpow(16.0, 0.75), 8.0);
        assert_eq!(rpow(256.0, 0.125), 2.0);
        assert_eq!(rpow(4.0, -0.5), 0.5);
        assert_eq!(rpow(2.25, 0.5), 1.5);
        assert_eq!(rpow(5.0625, 0.25), 1.5);
    }

    #[test]
    fn powers_of_two_base() {
        assert_eq!(rpow(2.0, 100.0), 2f32.powi(100));
        assert_eq!(rpow(2.0, 0.123), 2f32.powf(0.123)); // libm sanity ±
        assert_eq!(rpow(0.5, -100.0), 2f32.powi(100));
        // 2^(m*y) integer product
        assert_eq!(rpow(4.0, 25.0), 2f32.powi(50));
    }

    #[test]
    fn close_to_libm_general() {
        let cases = [
            (3.0f32, 2.7f32),
            (0.3, 4.1),
            (7.7, -1.3),
            (1.0001, 500.0),
            (123.456, 0.789),
            (0.9999, -12345.0),
        ];
        for &(x, y) in &cases {
            let got = rpow(x, y);
            let libm = x.powf(y);
            assert!(
                ulp_diff(got, libm) <= 2,
                "pow({x},{y}) got={got} libm={libm}"
            );
        }
    }

    #[test]
    fn matches_oracle_general_path() {
        // independent oracle at even higher precision
        let cases = [(3.0f32, 2.7f32), (0.3, 4.1), (7.7, -1.3), (42.0, 3.3)];
        for &(x, y) in &cases {
            let xb = BigFloat::from_f32(x, 12);
            let yb = BigFloat::from_f32(y, 12);
            let want = yb.mul(&xb.ln_bf()).exp_bf().to_f32();
            assert_eq!(rpow(x, y).to_bits(), want.to_bits(), "({x},{y})");
        }
    }

    #[test]
    fn negative_base_integer_exponents_large() {
        assert_eq!(rpow(-1.5, 7.0), -(1.5f32.powi(7)));
        assert_eq!(rpow(-1.5, 8.0), 1.5f32.powi(8));
    }
}
