//! Fixed pairwise-tree reduction — the public combinator behind every
//! multi-partial reduction in this repo (paper §3.2.2 applied to
//! *partial results*, not just scalars).
//!
//! Floating-point addition is not associative, so "the combined partial"
//! is only defined once an association order is fixed. This module fixes
//! it once, as a **specification**: partials `p₀ … p_{n−1}` (indexed by
//! their *logical* position — microbatch index, tensor-parallel segment
//! index, …) combine in the [`sum_pairwise`](super::sum::sum_pairwise)
//! tree shape — split at the largest power of two below `n`
//! ([`pairwise_split`]), left subtree first. The tree is a pure function
//! of the logical partial **count**, never of worker scheduling, lane
//! count, or tensor-parallel width — which is exactly why
//! `DataParallelTrainer` lanes and `ShardedTower` TP widths are pure
//! performance knobs (DESIGN.md §12–§13).
//!
//! Two entry points:
//!
//! * [`fixed_tree_reduce`] — generic: any partial type, any combine
//!   closure. The closure is *applied* in the fixed tree order; it is
//!   the caller's obligation that the closure itself is deterministic
//!   (element-wise `+` in a fixed element order qualifies).
//! * [`fixed_tree_reduce_into`] — element-wise over equal-length `f32`
//!   partial slices (the tensor-partial case): output element `j` is
//!   the fixed-tree sum of `parts[0][j] … parts[n−1][j]`.

pub use super::sum::pairwise_split;

/// Reduce `parts` (in logical index order) with `combine`, associated in
/// the fixed pairwise tree: `combine` is applied exactly `n − 1` times,
/// at the internal nodes of the tree whose shape [`pairwise_split`]
/// specifies. Returns `None` for an empty input, the sole element
/// (untouched) for `n == 1`.
///
/// The association for a given `n` is a specification shared with the
/// other fixed-tree users (gradient reduction, tensor-parallel partial
/// sums, the Python golden-vector emulator) — change it nowhere or
/// everywhere.
pub fn fixed_tree_reduce<T, F>(parts: Vec<T>, combine: &mut F) -> Option<T>
where
    F: FnMut(T, T) -> T,
{
    fn rec<T, F>(slots: &mut [Option<T>], lo: usize, hi: usize, combine: &mut F) -> T
    where
        F: FnMut(T, T) -> T,
    {
        debug_assert!(lo < hi);
        if hi - lo == 1 {
            return slots[lo].take().expect("fixed_tree_reduce: partial consumed twice");
        }
        let split = lo + pairwise_split(hi - lo);
        let left = rec(slots, lo, split, combine);
        let right = rec(slots, split, hi, combine);
        combine(left, right)
    }
    if parts.is_empty() {
        return None;
    }
    let n = parts.len();
    let mut slots: Vec<Option<T>> = parts.into_iter().map(Some).collect();
    Some(rec(&mut slots, 0, n, combine))
}

/// Element-wise fixed-tree sum of equal-length `f32` partial slices into
/// `out`: `out[j] = tree(parts[0][j], …, parts[n−1][j])` with the same
/// association as [`fixed_tree_reduce`]. `parts` must be non-empty and
/// every slice must have `out.len()` elements (debug-asserted — callers
/// construct the partials, so a mismatch is a programming error, not a
/// user error).
pub fn fixed_tree_reduce_into(parts: &[&[f32]], out: &mut [f32]) {
    debug_assert!(!parts.is_empty(), "fixed_tree_reduce_into: no partials");
    for p in parts {
        debug_assert_eq!(p.len(), out.len(), "fixed_tree_reduce_into: ragged partial");
    }
    fn elem(parts: &[&[f32]], lo: usize, hi: usize, j: usize) -> f32 {
        if hi - lo == 1 {
            return parts[lo][j];
        }
        let split = lo + pairwise_split(hi - lo);
        elem(parts, lo, split, j) + elem(parts, split, hi, j)
    }
    let n = parts.len();
    if n == 1 {
        out.copy_from_slice(parts[0]);
        return;
    }
    for (j, o) in out.iter_mut().enumerate() {
        *o = elem(parts, 0, n, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The split rule is part of the cross-implementation spec (moved
    /// here alongside the public API; the Pallas kernel and the Python
    /// emulator use the identical shape).
    #[test]
    fn pairwise_split_spec() {
        assert_eq!(pairwise_split(9), 8);
        assert_eq!(pairwise_split(16), 8);
        assert_eq!(pairwise_split(17), 16);
        assert_eq!(pairwise_split(1000), 512);
        assert_eq!(pairwise_split(2), 1);
    }

    /// The association order, spelled out: reduce strings and check the
    /// parenthesisation for every small n.
    #[test]
    fn tree_association_spec() {
        let shape = |n: usize| -> String {
            let parts: Vec<String> = (0..n).map(|i| i.to_string()).collect();
            fixed_tree_reduce(parts, &mut |a, b| format!("({a}+{b})")).unwrap()
        };
        assert_eq!(shape(1), "0");
        assert_eq!(shape(2), "(0+1)");
        assert_eq!(shape(3), "((0+1)+2)");
        assert_eq!(shape(4), "((0+1)+(2+3))");
        assert_eq!(shape(5), "(((0+1)+(2+3))+4)");
        assert_eq!(shape(6), "(((0+1)+(2+3))+(4+5))");
        assert_eq!(shape(8), "(((0+1)+(2+3))+((4+5)+(6+7)))");
        assert_eq!(shape(9), "((((0+1)+(2+3))+((4+5)+(6+7)))+8)");
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(fixed_tree_reduce(Vec::<f32>::new(), &mut |a, b| a + b), None);
        assert_eq!(fixed_tree_reduce(vec![7.0f32], &mut |a, b| a + b), Some(7.0));
    }

    /// Element-wise reduce equals the scalar tree applied per element —
    /// bit-for-bit, including non-associative cancellation cases.
    #[test]
    fn elementwise_matches_scalar_tree_bitwise() {
        let mut s = 12345u64;
        let mut next = || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (((s >> 40) as f32) / (1u64 << 24) as f32 - 0.5) * 2.0e6
        };
        for n in 1..=9usize {
            let parts: Vec<Vec<f32>> = (0..n).map(|_| (0..17).map(|_| next()).collect()).collect();
            let views: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
            let mut out = vec![0.0f32; 17];
            fixed_tree_reduce_into(&views, &mut out);
            for j in 0..17 {
                let scalars: Vec<f32> = parts.iter().map(|p| p[j]).collect();
                let want = fixed_tree_reduce(scalars, &mut |a, b| a + b).unwrap();
                assert_eq!(out[j].to_bits(), want.to_bits(), "n={n} j={j}");
            }
        }
    }

    /// The non-associativity the tree exists to pin down: a different
    /// association of the same partials gives different bits, the fixed
    /// tree gives the same bits every time.
    #[test]
    fn tree_is_deterministic_where_association_matters(){
        let parts = vec![0.5f32, 1e9, -1e9, 0.25];
        let tree = |p: Vec<f32>| fixed_tree_reduce(p, &mut |a, b| a + b).unwrap();
        // ((0.5+1e9)+(-1e9+0.25)) = 1e9 + (-1e9+0.25) = 0.25… per RNE:
        let want = (0.5f32 + 1e9) + (-1e9 + 0.25);
        assert_eq!(tree(parts.clone()).to_bits(), want.to_bits());
        assert_eq!(tree(parts.clone()).to_bits(), tree(parts).to_bits());
        // sequential association differs on this data
        let seq = ((0.5f32 + 1e9) + -1e9) + 0.25;
        assert_ne!(want.to_bits(), seq.to_bits());
    }

    /// Grouping contiguous leaves and reducing group results does NOT in
    /// general reproduce the flat tree — which is exactly why
    /// tensor-parallel shards emit their *logical* partials individually
    /// instead of pre-combining per shard (DESIGN.md §13)… except for
    /// the power-of-two case, where subtree alignment makes them equal.
    #[test]
    fn power_of_two_groups_are_aligned_subtrees() {
        let parts = vec![0.5f32, 1e9, -1e9, 0.25];
        let flat = fixed_tree_reduce(parts.clone(), &mut |a, b| a + b).unwrap();
        let g0 = fixed_tree_reduce(parts[..2].to_vec(), &mut |a, b| a + b).unwrap();
        let g1 = fixed_tree_reduce(parts[2..].to_vec(), &mut |a, b| a + b).unwrap();
        let grouped = fixed_tree_reduce(vec![g0, g1], &mut |a, b| a + b).unwrap();
        assert_eq!(flat.to_bits(), grouped.to_bits());
    }
}
