//! Square root and reciprocal square root (paper §3.2.1 and §2.2.1).
//!
//! `sqrt` is the one basic operation IEEE 754 already requires to be
//! correctly rounded, so hardware `sqrtss` is reproducible as-is —
//! [`rsqrt_f32`] is a documented wrapper (and the test suite *verifies*
//! the claim against the BigFloat oracle rather than trusting it).
//!
//! `rsqrt` (1/√x) is the paper's §2.2.1 cautionary example in disguise:
//! the x86 `RCPSS`/`RSQRTSS` approximation instructions have *different
//! precision on different CPUs*. RepDL's [`rrsqrt`] is correctly rounded
//! instead: `f64` double-op fast path (each op exactly rounded, composed
//! error < 1.3·2⁻⁵³) + unambiguity check + BigFloat fallback.

use super::bigfloat::{BigFloat, PREC_ORACLE};
use super::exp::round_unambiguous;

/// Correctly-rounded √x (IEEE-754 guaranteed; verified in tests).
#[inline]
pub fn rsqrt_f32(x: f32) -> f32 {
    x.sqrt()
}

/// Correctly-rounded 1/√x.
pub fn rrsqrt(x: f32) -> f32 {
    if x.is_nan() || x < 0.0 {
        return f32::NAN;
    }
    if x == 0.0 {
        return f32::INFINITY; // IEEE: rsqrt(±0) = +inf (sign convention: +)
    }
    if x.is_infinite() {
        return 0.0;
    }
    // Exact family: x = 2^(2k) → 1/√x = 2^-k exactly.
    let bits = x.to_bits();
    if bits & 0x007f_ffff == 0 {
        let e = (bits >> 23) as i32 - 127;
        if e % 2 == 0 {
            return super::fbits::pow2_f64(-e / 2) as f32;
        }
    }
    // f64 fast path: two correctly-rounded f64 ops.
    let y = 1.0 / (x as f64).sqrt();
    if let Some(r) = round_unambiguous(y, 1.0e-15) {
        return r;
    }
    let b = BigFloat::from_f32(x, PREC_ORACLE);
    BigFloat::one(PREC_ORACLE).div(&b.sqrt()).to_f32()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn oracle_sqrt(x: f32) -> f32 {
        BigFloat::from_f32(x, PREC_ORACLE).sqrt().to_f32()
    }

    fn oracle_rsqrt(x: f32) -> f32 {
        let b = BigFloat::from_f32(x, PREC_ORACLE);
        BigFloat::one(PREC_ORACLE).div(&b.sqrt()).to_f32()
    }

    #[test]
    fn hardware_sqrt_is_correctly_rounded() {
        // Verify (not assume) the IEEE claim on a pseudo-random sweep.
        let mut bits = 0x3f80_0000u32;
        for _ in 0..20_000 {
            bits = bits.wrapping_mul(1664525).wrapping_add(1013904223);
            let x = f32::from_bits(bits % 0x7f80_0000);
            assert_eq!(
                rsqrt_f32(x).to_bits(),
                oracle_sqrt(x).to_bits(),
                "x={x}"
            );
        }
    }

    #[test]
    fn sqrt_subnormals_and_edges() {
        for &x in &[
            f32::from_bits(1),
            f32::from_bits(7),
            f32::MIN_POSITIVE,
            f32::MAX,
            1.0,
            2.0,
            0.25,
        ] {
            assert_eq!(rsqrt_f32(x).to_bits(), oracle_sqrt(x).to_bits());
        }
    }

    #[test]
    fn rsqrt_specials_and_exact_powers() {
        assert!(rrsqrt(-1.0).is_nan());
        assert_eq!(rrsqrt(0.0), f32::INFINITY);
        assert_eq!(rrsqrt(f32::INFINITY), 0.0);
        assert_eq!(rrsqrt(4.0), 0.5);
        assert_eq!(rrsqrt(0.25), 2.0);
        assert_eq!(rrsqrt(1.0), 1.0);
        assert_eq!(rrsqrt(2f32.powi(20)), 2f32.powi(-10));
    }

    #[test]
    fn rsqrt_matches_oracle_sweep() {
        let mut bits = 0x0080_0000u32;
        for _ in 0..20_000 {
            bits = bits.wrapping_mul(22695477).wrapping_add(1);
            let x = f32::from_bits(bits % 0x7f80_0000);
            if x == 0.0 {
                continue;
            }
            assert_eq!(
                rrsqrt(x).to_bits(),
                oracle_rsqrt(x).to_bits(),
                "x={x}"
            );
        }
    }

    #[test]
    fn rsqrt_odd_exponent_powers_of_two() {
        // 1/√2 is irrational — exercise the generic path on 2^odd.
        for k in [-3i32, -1, 1, 3, 21] {
            let x = crate::rnum::fbits::pow2_f64(k) as f32;
            assert_eq!(rrsqrt(x).to_bits(), oracle_rsqrt(x).to_bits());
        }
    }
}
