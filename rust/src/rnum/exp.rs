//! Correctly-rounded `exp`, `exp2` and `expm1` for `f32` (paper §3.2.1).
//!
//! Strategy (Ziv's two-step, identical on every IEEE-754 platform):
//!
//! 1. **Fast path**: evaluate in `f64` with a *fixed* algorithm — Cody–
//!    Waite argument reduction against split ln 2 constants and a Taylor
//!    polynomial evaluated in a fixed order. Every `f64` operation used is
//!    itself correctly rounded by IEEE 754, so the computed `f64` value is
//!    bit-identical everywhere. Its relative error is bounded well below
//!    2⁻⁴⁵.
//! 2. **Ambiguity check**: if the interval `y·(1 ± margin)` rounds to a
//!    single `f32`, the true result rounds there too (monotonicity of
//!    rounding) — accept.
//! 3. **Fallback**: re-evaluate with the 320-bit [`BigFloat`] oracle.
//!    Exercised roughly once per 2²⁰ inputs; also deterministic.
//!
//! No libm call appears anywhere on any path.

use super::bigfloat::{BigFloat, PREC_ORACLE};
use super::fbits::pow2_f64;

/// log2(e) to f64 precision.
const LOG2E: f64 = std::f64::consts::LOG2_E;
/// High part of ln 2 (fdlibm split: 32 trailing zero bits, so products
/// with |k| < 2^20 are exact).
const LN2_HI: f64 = 6.93147180369123816490e-01; // 0x3FE62E42FEE00000
/// Low part of ln 2.
const LN2_LO: f64 = 1.90821492927058770002e-10; // 0x3DEA39EF35793C76

/// Check whether every value in `y · (1 ± margin)` rounds to the same
/// `f32`; if so return it. `margin` must over-approximate the relative
/// error of `y` (plus the two boundary multiplications' own rounding).
#[inline]
pub(crate) fn round_unambiguous(y: f64, margin: f64) -> Option<f32> {
    let lo = (y.abs() * (1.0 - margin)).copysign(y);
    let hi = (y.abs() * (1.0 + margin)).copysign(y);
    let a = lo as f32;
    let b = hi as f32;
    if a.to_bits() == b.to_bits() {
        Some(a)
    } else {
        None
    }
}

/// Fixed-order Taylor core: e^r for |r| ≤ ln2/2 + ε, relative error
/// below 2⁻⁵⁰ (truncation ≈ 2⁻⁶³, accumulation ≈ 30·2⁻⁵³).
#[inline]
pub(crate) fn exp_poly(r: f64) -> f64 {
    // 1 + r·(1 + r/2·(1 + r/3·(··· (1 + r/14) ···)))
    // Reciprocal constants are fixed f64 literals — the same bits in every
    // build — so the whole evaluation is a fixed computation graph.
    const INV: [f64; 14] = [
        1.0,
        0.5,
        0.333333333333333333,
        0.25,
        0.2,
        0.166666666666666667,
        0.142857142857142857,
        0.125,
        0.111111111111111111,
        0.1,
        0.0909090909090909091,
        0.0833333333333333333,
        0.0769230769230769231,
        0.0714285714285714286,
    ];
    let mut p = 1.0 + r * INV[13];
    for i in (1..13).rev() {
        p = 1.0 + r * INV[i] * p;
    }
    1.0 + r * p
}

/// `f64` fast path shared by `rexp`/`rexpm1`: returns (e^x, k) where the
/// value was assembled as poly(r)·2^k.
#[inline]
pub(crate) fn exp_f64(xd: f64) -> f64 {
    let k = (xd * LOG2E).round();
    let r = (xd - k * LN2_HI) - k * LN2_LO;
    exp_poly(r) * pow2_f64(k as i32)
}

/// The fixed f64 exp graph, exposed publicly: it is the shared
/// cross-implementation spec (the `exp_fixed` AOT artifact implements the
/// same graph in JAX — experiment E6 compares the two bitwise).
pub fn exp_fixed_graph_f64(x: f64) -> f64 {
    exp_f64(x)
}

/// Relative-error margin for the exp fast path (conservative).
const EXP_MARGIN: f64 = 2.3e-14; // ≈ 2^-45.3

/// Correctly-rounded e^x for `f32`.
///
/// For every finite input the result is the IEEE-754 round-to-nearest-even
/// rounding of the exact real value — verified against the [`BigFloat`]
/// oracle in the E3 experiment.
pub fn rexp(x: f32) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    // exp(89) > 2^128·(1+2^-25): certainly +inf. exp(-104) < 2^-150: 0.
    if x > 89.0 {
        return f32::INFINITY;
    }
    if x < -104.0 {
        return 0.0;
    }
    if x == 0.0 {
        return 1.0; // exact
    }
    let y = exp_f64(x as f64);
    if let Some(r) = round_unambiguous(y, EXP_MARGIN) {
        return r;
    }
    BigFloat::from_f32(x, PREC_ORACLE).exp_bf().to_f32()
}

/// Correctly-rounded 2^x for `f32`.
pub fn rexp2(x: f32) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    if x > 128.5 {
        return f32::INFINITY;
    }
    if x < -150.5 {
        return 0.0;
    }
    if x == x.trunc() {
        // integer exponent: exactly a power of two (or exact over/underflow)
        return pow2_f64(x as i32) as f32;
    }
    let xd = x as f64;
    let k = xd.round();
    let r = xd - k; // exact (both are multiples of the same ulp)
    // 2^r = e^(r·ln2); r·ln2 via the split constants (one rounding each)
    let t = r * LN2_HI + r * LN2_LO;
    let y = exp_poly(t) * pow2_f64(k as i32);
    if let Some(v) = round_unambiguous(y, EXP_MARGIN) {
        return v;
    }
    let xb = BigFloat::from_f32(x, PREC_ORACLE);
    xb.mul(&super::bigfloat::consts::ln2(PREC_ORACLE))
        .exp_bf()
        .to_f32()
}

/// Fixed-order Taylor for e^x − 1 on |x| ≤ 0.35 (relative error < 2⁻⁵⁰).
#[inline]
pub(crate) fn expm1_poly(r: f64) -> f64 {
    // x·(1 + x/2·(1 + x/3·(···)))
    const INV: [f64; 14] = [
        1.0,
        0.5,
        0.333333333333333333,
        0.25,
        0.2,
        0.166666666666666667,
        0.142857142857142857,
        0.125,
        0.111111111111111111,
        0.1,
        0.0909090909090909091,
        0.0833333333333333333,
        0.0769230769230769231,
        0.0714285714285714286,
    ];
    let mut p = 1.0 + r * INV[13];
    for i in (2..13).rev() {
        p = 1.0 + r * INV[i] * p;
    }
    r * (1.0 + r * INV[1] * p)
}

/// Correctly-rounded e^x − 1 for `f32`.
pub fn rexpm1(x: f32) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    if x > 89.0 {
        return f32::INFINITY;
    }
    if x < -17.35 {
        // e^x < 2^-25 = ulp(1)/2: the exact value -1 + e^x rounds to -1
        // (never a tie: e^x = 2^-25 has no f32 solution).
        return -1.0;
    }
    if x == 0.0 {
        return x; // ±0 preserved
    }
    let xd = x as f64;
    let y = if xd.abs() <= 0.35 {
        expm1_poly(xd)
    } else {
        // No harmful cancellation outside [-0.35, 0.35]: |e^x − 1| stays
        // above 0.29, so the subtraction amplifies the error by < 4×.
        exp_f64(xd) - 1.0
    };
    // extra margin for the subtraction path
    if let Some(r) = round_unambiguous(y, 1.0e-13) {
        return r;
    }
    let e = BigFloat::from_f32(x, PREC_ORACLE).exp_bf();
    e.sub(&BigFloat::one(PREC_ORACLE)).to_f32()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rnum::fbits::ulp_diff;

    /// Oracle: exp via BigFloat.
    fn oracle_exp(x: f32) -> f32 {
        if x > 89.0 {
            return f32::INFINITY;
        }
        if x < -104.0 {
            return 0.0;
        }
        BigFloat::from_f32(x, PREC_ORACLE).exp_bf().to_f32()
    }

    #[test]
    fn exact_and_special_cases() {
        assert_eq!(rexp(0.0), 1.0);
        assert_eq!(rexp(-0.0), 1.0);
        assert!(rexp(f32::NAN).is_nan());
        assert_eq!(rexp(f32::INFINITY), f32::INFINITY);
        assert_eq!(rexp(f32::NEG_INFINITY), 0.0);
        assert_eq!(rexp(200.0), f32::INFINITY);
        assert_eq!(rexp(-200.0), 0.0);
    }

    #[test]
    fn matches_oracle_on_sweep() {
        // Deterministic sweep over the interesting range.
        let mut x = -104.5f32;
        while x < 89.5 {
            let got = rexp(x);
            let want = oracle_exp(x);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "exp({x}): got {got}, oracle {want}"
            );
            x += 0.7891; // irrational-ish stride to avoid pattern aliasing
        }
    }

    #[test]
    fn matches_oracle_near_boundaries() {
        for &x in &[
            88.72283f32,
            88.722839,
            -103.97208,
            -87.33655,
            1e-20,
            -1e-20,
            0.5,
            -0.5,
            f32::from_bits(0x42b17218), // ~88.7228
        ] {
            assert_eq!(rexp(x).to_bits(), oracle_exp(x).to_bits(), "x={x}");
        }
    }

    #[test]
    fn subnormal_results_are_correct() {
        // exp(x) subnormal for x in (-103.97, -87.34)
        for i in 0..200 {
            let x = -88.0 - i as f32 * 0.08;
            assert_eq!(rexp(x).to_bits(), oracle_exp(x).to_bits(), "x={x}");
        }
    }

    #[test]
    fn close_to_libm() {
        // Sanity: within 1 ulp of the platform libm (which is good but not
        // guaranteed CR — that's the whole point of RepDL).
        for i in 0..1000 {
            let x = -20.0 + i as f32 * 0.04;
            let got = rexp(x);
            let libm = x.exp();
            assert!(ulp_diff(got, libm) <= 1, "x={x} got={got} libm={libm}");
        }
    }

    #[test]
    fn exp2_integer_exactness() {
        for k in -149..=127 {
            let got = rexp2(k as f32);
            let want = pow2_f64(k) as f32;
            assert_eq!(got.to_bits(), want.to_bits(), "k={k}");
        }
        assert_eq!(rexp2(3.0), 8.0);
        assert_eq!(rexp2(-1.0), 0.5);
    }

    #[test]
    fn exp2_matches_libm_closely() {
        let mut x = -20.0f32;
        while x < 20.0 {
            let got = rexp2(x);
            assert!(ulp_diff(got, x.exp2()) <= 1, "x={x}");
            x += 0.0371;
        }
    }

    #[test]
    fn expm1_small_and_large() {
        assert_eq!(rexpm1(0.0), 0.0);
        assert_eq!(rexpm1(-0.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(rexpm1(f32::NEG_INFINITY), -1.0);
        assert_eq!(rexpm1(-50.0), -1.0);
        for &x in &[1e-10f32, -1e-10, 0.1, -0.1, 1.0, -1.0, 10.0, -17.0] {
            let got = rexpm1(x);
            assert!(
                ulp_diff(got, x.exp_m1()) <= 1,
                "x={x} got={got} libm={}",
                x.exp_m1()
            );
        }
    }

    #[test]
    fn expm1_matches_oracle() {
        let one = BigFloat::one(PREC_ORACLE);
        let mut x = -17.0f32;
        while x < 60.0 {
            let want = BigFloat::from_f32(x, PREC_ORACLE)
                .exp_bf()
                .sub(&one)
                .to_f32();
            assert_eq!(rexpm1(x).to_bits(), want.to_bits(), "x={x}");
            x += 0.913;
        }
    }

    #[test]
    fn deterministic_repeated_eval() {
        // run-to-run bit equality (trivially true, but documents intent)
        for i in 0..100 {
            let x = (i as f32) * 0.37 - 18.0;
            assert_eq!(rexp(x).to_bits(), rexp(x).to_bits());
        }
    }
}
