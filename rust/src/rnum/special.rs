//! DL activation functions: `tanh` (correctly rounded) plus `sigmoid`,
//! `erf` and the two GELU variants as **fixed computation graphs**
//! (paper §3.2.3).
//!
//! The paper distinguishes two tiers:
//!
//! * *basic operations* must be correctly rounded (§3.2.1) — here `tanh`;
//! * *deep-learning functions* are combinations of basic operations whose
//!   **graph** is fixed, and every distinct graph gets its own API name —
//!   here `rsigmoid`, `rerf`, and the two deliberately separate GELUs
//!   [`rgelu_erf`] / [`rgelu_tanh`] (PyTorch's `approximate=` flag made
//!   into two names, exactly the paper's batch-norm example pattern).

use super::bigfloat::{BigFloat, PREC_ORACLE};
use super::exp::{exp_f64, expm1_poly, round_unambiguous, rexp};

/// Correctly-rounded tanh for `f32`.
///
/// Fast path: tanh x = −t/(t+2) with t = e^(−2|x|) − 1 evaluated by the
/// fixed `f64` expm1 graph (no cancellation: t ∈ (−1, 0]). Fallback:
/// BigFloat `tanh_bf`. For |x| ≥ 10, 1 − tanh x < 2⁻²⁸ < ulp(1)/2, so the
/// correctly-rounded result is exactly ±1.
pub fn rtanh(x: f32) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    if x == 0.0 {
        return x; // ±0 preserved
    }
    if x.abs() >= 10.0 {
        return 1.0f32.copysign(x);
    }
    let a = -2.0 * (x.abs() as f64); // exact
    let t = if a >= -0.35 {
        expm1_poly(a)
    } else {
        exp_f64(a) - 1.0
    };
    let y = (-t / (t + 2.0)).copysign(x as f64);
    if let Some(r) = round_unambiguous(y, 1.0e-13) {
        return r;
    }
    BigFloat::from_f32(x, PREC_ORACLE).tanh_bf().to_f32()
}

/// Sigmoid as a **fixed computation graph**: σ(x) = 1 / (1 + e^(−x)),
/// with `e^(−x)` the correctly-rounded [`rexp`] and the remaining add /
/// divide IEEE-exact `f32` ops. Reproducible bit-for-bit everywhere;
/// *as a whole* it carries ≤ ~1.5 ulp error (documented, per the paper's
/// composite-function tier).
pub fn rsigmoid(x: f32) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    1.0 / (1.0 + rexp(-x))
}

/// erf as a fixed computation graph (Abramowitz–Stegun 7.1.26 with the
/// published constants, evaluated in a fixed order over correctly-rounded
/// primitives). Absolute error ≤ 1.5e−7 — adequate for GELU — and
/// bit-reproducible everywhere.
pub fn rerf(x: f32) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    if x == 0.0 {
        return x;
    }
    let ax = x.abs();
    if ax >= 4.0 {
        return 1.0f32.copysign(x); // erf saturates below f32 resolution
    }
    const P: f32 = 0.3275911;
    const A: [f32; 5] = [0.254829592, -0.284496736, 1.421413741, -1.453152027, 1.061405429];
    let t = 1.0 / (1.0 + P * ax);
    // Horner, fixed order
    let poly = ((((A[4] * t + A[3]) * t + A[2]) * t + A[1]) * t + A[0]) * t;
    let e = rexp(-(ax * ax));
    (1.0 - poly * e).copysign(x)
}

/// GELU, erf graph (PyTorch `approximate="none"`):
/// `0.5 · x · (1 + erf(x / √2))`. Distinct API from [`rgelu_tanh`]
/// because the two are different computation graphs (paper §3.2.3).
pub fn rgelu_erf(x: f32) -> f32 {
    const INV_SQRT2: f32 = 0.707_106_77; // f32(1/√2), a fixed constant
    0.5 * x * (1.0 + rerf(x * INV_SQRT2))
}

/// GELU, tanh graph (PyTorch `approximate="tanh"`):
/// `0.5 · x · (1 + tanh(√(2/π) · (x + 0.044715·x³)))`.
pub fn rgelu_tanh(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    const C: f32 = 0.044_715;
    let x3 = x * x * x;
    0.5 * x * (1.0 + rtanh(SQRT_2_OVER_PI * (x + C * x3)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rnum::fbits::ulp_diff;

    fn oracle_tanh(x: f32) -> f32 {
        if x.abs() >= 10.0 {
            return 1.0f32.copysign(x);
        }
        BigFloat::from_f32(x, PREC_ORACLE).tanh_bf().to_f32()
    }

    #[test]
    fn tanh_specials_and_saturation() {
        assert!(rtanh(f32::NAN).is_nan());
        assert_eq!(rtanh(0.0), 0.0);
        assert_eq!(rtanh(-0.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(rtanh(f32::INFINITY), 1.0);
        assert_eq!(rtanh(f32::NEG_INFINITY), -1.0);
        assert_eq!(rtanh(50.0), 1.0);
        assert_eq!(rtanh(-12.0), -1.0);
    }

    #[test]
    fn tanh_matches_oracle() {
        let mut x = -9.9f32;
        while x < 9.9 {
            assert_eq!(
                rtanh(x).to_bits(),
                oracle_tanh(x).to_bits(),
                "tanh({x}) got={} want={}",
                rtanh(x),
                oracle_tanh(x)
            );
            x += 0.0713;
        }
    }

    #[test]
    fn tanh_tiny_arguments_round_to_x() {
        for &x in &[1e-10f32, -1e-10, 1e-30] {
            assert_eq!(rtanh(x).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn tanh_close_to_libm() {
        for i in 0..500 {
            let x = -8.0 + i as f32 * 0.032;
            assert!(ulp_diff(rtanh(x), x.tanh()) <= 1, "x={x}");
        }
    }

    #[test]
    fn sigmoid_graph_properties() {
        assert_eq!(rsigmoid(0.0), 0.5);
        assert_eq!(rsigmoid(100.0), 1.0);
        assert_eq!(rsigmoid(-200.0), 0.0);
        // symmetry holds only approximately (graph is not symmetric) —
        // but determinism is exact:
        for i in 0..100 {
            let x = i as f32 * 0.2 - 10.0;
            assert_eq!(rsigmoid(x).to_bits(), rsigmoid(x).to_bits());
        }
        // monotone on a grid
        let mut prev = rsigmoid(-20.0);
        for i in 1..400 {
            let v = rsigmoid(-20.0 + i as f32 * 0.1);
            assert!(v >= prev);
            prev = v;
        }
    }

    #[test]
    fn erf_accuracy_and_symmetry() {
        // |rerf - true erf| <= 2e-7 (A&S bound 1.5e-7 + f32 noise)
        let cases = [
            (0.5f32, 0.5204999f32),
            (1.0, 0.8427008),
            (2.0, 0.9953223),
            (0.1, 0.1124629),
        ];
        for &(x, want) in &cases {
            assert!((rerf(x) - want).abs() < 2e-6, "erf({x}) = {}", rerf(x));
            assert_eq!(rerf(-x), -rerf(x)); // graph is explicitly odd
        }
        assert_eq!(rerf(0.0), 0.0);
        assert_eq!(rerf(10.0), 1.0);
    }

    #[test]
    fn gelu_variants_differ_but_each_is_deterministic() {
        // The two graphs are intentionally different APIs; they agree to
        // ~1e-3 but NOT bitwise — exactly the paper's point.
        let mut any_diff = false;
        for i in 0..200 {
            let x = -5.0 + i as f32 * 0.05;
            let a = rgelu_erf(x);
            let b = rgelu_tanh(x);
            assert!((a - b).abs() <= 3e-3 * (1.0 + x.abs()), "x={x}");
            any_diff |= a.to_bits() != b.to_bits();
            assert_eq!(rgelu_erf(x).to_bits(), rgelu_erf(x).to_bits());
            assert_eq!(rgelu_tanh(x).to_bits(), rgelu_tanh(x).to_bits());
        }
        assert!(any_diff, "graphs should not coincide bitwise everywhere");
    }

    #[test]
    fn gelu_reference_values() {
        // PyTorch reference: gelu(1.0) ≈ 0.8413447, gelu_tanh(1.0) ≈ 0.841192
        assert!((rgelu_erf(1.0) - 0.8413447).abs() < 1e-5);
        assert!((rgelu_tanh(1.0) - 0.841192).abs() < 1e-5);
        assert_eq!(rgelu_erf(0.0), 0.0);
        assert_eq!(rgelu_tanh(0.0), 0.0);
    }
}
