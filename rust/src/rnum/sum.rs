//! Reproducible summation (paper §3.2.2).
//!
//! Floating-point addition is not associative, so "the sum" of a vector
//! is only defined once an association order is fixed. RepDL ships:
//!
//! * [`sum_sequential`] — **the default**: plain left-to-right
//!   accumulation. Cache-friendly; efficient whenever the number of
//!   *independent* summation tasks exceeds the processor count (the
//!   paper's t_fc / t_conv analysis — see experiment E4).
//! * [`sum_pairwise`] — **the alternative API** (different name, per the
//!   paper's order-invariance rule): a balanced binary tree with a
//!   sequential base case of 8, exposing log-depth parallelism. The tree
//!   shape is a *specification* (split at the largest power of two below
//!   `n`), shared bit-for-bit with the Pallas kernel implementation.
//! * [`sum_kahan`] — fixed-order compensated summation (a third distinct
//!   API; more accurate, still deterministic).
//! * [`KulischAcc`] — the order-*irrelevant* exact superaccumulator the
//!   paper cites as too inefficient for DL ([1,3,4] in the paper); we
//!   implement it as the ablation baseline (E4) and as a gold reference
//!   for tests: its result is the correctly-rounded exact sum under any
//!   permutation.

use super::bigfloat::BigFloat;

/// Sequential (left-to-right) sum — RepDL's default reduction order.
#[inline]
pub fn sum_sequential(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &x in xs {
        acc += x;
    }
    acc
}

/// Split point of the pairwise tree: the largest power of two < n.
/// This is part of the cross-implementation specification — the Pallas
/// kernel uses the identical shape, and [`super::reduce`] generalises it
/// from scalar sums to arbitrary partial results (its spec test lives
/// there, alongside the public combinator).
#[inline]
pub fn pairwise_split(n: usize) -> usize {
    debug_assert!(n > 1);
    let p = usize::BITS - (n - 1).leading_zeros(); // ceil_log2(n)
    1usize << (p - 1)
}

/// Pairwise (tree) sum — the alternative reduction order, own API name.
/// Base case: sequential sum of ≤ 8 elements.
pub fn sum_pairwise(xs: &[f32]) -> f32 {
    if xs.len() <= 8 {
        return sum_sequential(xs);
    }
    let m = pairwise_split(xs.len());
    sum_pairwise(&xs[..m]) + sum_pairwise(&xs[m..])
}

/// Kahan (compensated) sequential sum — deterministic, more accurate,
/// exposed as its own API because its result differs bitwise from
/// [`sum_sequential`].
pub fn sum_kahan(xs: &[f32]) -> f32 {
    let mut s = 0.0f32;
    let mut c = 0.0f32;
    for &x in xs {
        let y = x - c;
        let t = s + y;
        c = (t - s) - y;
        s = t;
    }
    s
}

/// Sequential dot product, unfused (`t = aᵢ·bᵢ` rounded, then `acc += t`).
/// This is the RepDL default spec — it matches the elementwise
/// multiply-then-add graph the JAX/Pallas implementation lowers to.
#[inline]
pub fn dot_sequential(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Sequential dot product with FMA contraction — the paper explicitly
/// *enables* FMA (§3.2.4: higher precision and performance, and `fma` is
/// itself an IEEE-754 correctly-rounded operation, hence reproducible).
/// A different computation graph ⇒ a different API name.
#[inline]
pub fn dot_sequential_fma(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc = a[i].mul_add(b[i], acc);
    }
    acc
}

/// Number of 64-bit limbs in the Kulisch accumulator.
/// f32 values span 2^-149 … <2^128; in units of 2^-149 that is 277 bits.
/// 384 bits leaves > 2^100 of headroom for the running sum.
const KULISCH_LIMBS: usize = 6;

/// Exact fixed-point superaccumulator for `f32` (Kulisch-style).
///
/// Every `f32` is an integer multiple of 2⁻¹⁴⁹; adding it into a 384-bit
/// two's-complement fixed-point register is *exact*, so the final value
/// is the exact real sum — **independent of summation order** — and
/// [`KulischAcc::round_f32`] returns its correct rounding. This is the
/// order-irrelevant algorithm the paper rejects for performance (we
/// quantify that rejection in E4) and the test suite's gold reference.
#[derive(Clone, Debug)]
pub struct KulischAcc {
    /// little-endian limbs, two's complement, units of 2^-149
    limbs: [u64; KULISCH_LIMBS],
}

impl Default for KulischAcc {
    fn default() -> Self {
        Self::new()
    }
}

impl KulischAcc {
    /// Fresh zero accumulator.
    pub fn new() -> Self {
        KulischAcc { limbs: [0; KULISCH_LIMBS] }
    }

    /// Add a finite `f32` exactly.
    pub fn add(&mut self, x: f32) {
        if x == 0.0 {
            return;
        }
        debug_assert!(x.is_finite(), "KulischAcc::add of non-finite {x}");
        let (sign, sig, exp) = super::fbits::decompose(x);
        let shift = (exp + 149) as u32; // 0 ..= 276
        let limb = (shift / 64) as usize;
        let off = shift % 64;
        let wide = (sig as u128) << off; // ≤ 24 + 63 bits, fits
        let lo = wide as u64;
        let hi = (wide >> 64) as u64;
        if sign > 0 {
            self.add_at(limb, lo, hi);
        } else {
            self.sub_at(limb, lo, hi);
        }
    }

    fn add_at(&mut self, limb: usize, lo: u64, hi: u64) {
        let mut carry: u128 = 0;
        for i in limb..KULISCH_LIMBS {
            let add = if i == limb {
                lo
            } else if i == limb + 1 {
                hi
            } else {
                0
            };
            if carry == 0 && add == 0 {
                if i > limb + 1 {
                    break;
                }
                continue;
            }
            let cur = self.limbs[i] as u128 + add as u128 + carry;
            self.limbs[i] = cur as u64;
            carry = cur >> 64;
        }
        // carry past the top limb wraps (two's-complement register)
    }

    fn sub_at(&mut self, limb: usize, lo: u64, hi: u64) {
        // two's-complement subtraction with borrow propagation
        let mut borrow: u128 = 0;
        for i in limb..KULISCH_LIMBS {
            let piece = if i == limb {
                lo
            } else if i == limb + 1 {
                hi
            } else {
                0
            };
            let sub = piece as u128 + borrow;
            if sub == 0 {
                if i > limb + 1 {
                    break;
                }
                continue;
            }
            let cur = self.limbs[i] as u128;
            if cur >= sub {
                self.limbs[i] = (cur - sub) as u64;
                borrow = 0;
            } else {
                self.limbs[i] = ((1u128 << 64) + cur - sub) as u64;
                borrow = 1;
            }
        }
        // borrow past the top limb wraps (two's-complement register)
    }

    /// True iff the accumulated sum is negative (top bit of the register).
    fn is_negative(&self) -> bool {
        self.limbs[KULISCH_LIMBS - 1] >> 63 == 1
    }

    /// Correctly-rounded `f32` of the exact accumulated sum.
    pub fn round_f32(&self) -> f32 {
        let mut mag = self.limbs;
        let neg = self.is_negative();
        if neg {
            // two's-complement negate
            let mut carry = 1u128;
            for l in mag.iter_mut() {
                let cur = (!*l) as u128 + carry;
                *l = cur as u64;
                carry = cur >> 64;
            }
        }
        if mag.iter().all(|&l| l == 0) {
            return 0.0;
        }
        // big-endian for BigFloat
        let be: Vec<u64> = mag.iter().rev().copied().collect();
        let bf = BigFloat::from_integer_be(if neg { -1 } else { 1 }, be, -149, 7);
        bf.to_f32()
    }

    /// Merge another accumulator (exact, order-irrelevant).
    pub fn merge(&mut self, other: &KulischAcc) {
        let mut carry: u128 = 0;
        for i in 0..KULISCH_LIMBS {
            let cur = self.limbs[i] as u128 + other.limbs[i] as u128 + carry;
            self.limbs[i] = cur as u64;
            carry = cur >> 64;
        }
    }
}

/// Exact (correctly-rounded) sum of a slice via the superaccumulator.
pub fn sum_exact(xs: &[f32]) -> f32 {
    let mut acc = KulischAcc::new();
    for &x in xs {
        acc.add(x);
    }
    acc.round_f32()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg_vec(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let u = ((s >> 40) as f32) / (1u64 << 24) as f32; // [0,1)
                (u - 0.5) * scale
            })
            .collect()
    }

    #[test]
    fn sequential_is_order_dependent_but_deterministic() {
        // The paper's §2.2.2 example: (0.5 + 1e9) - 1e9 vs 0.5 + (1e9 - 1e9)
        let a = [0.5f32, 1e9, -1e9];
        let b = [1e9f32, -1e9, 0.5];
        assert_eq!(sum_sequential(&a), 0.0);
        assert_eq!(sum_sequential(&b), 0.5);
        // but deterministic per-order
        assert_eq!(sum_sequential(&a).to_bits(), sum_sequential(&a).to_bits());
    }

    #[test]
    fn pairwise_differs_from_sequential_in_general() {
        let xs = lcg_vec(1000, 42, 2.0);
        let s = sum_sequential(&xs);
        let p = sum_pairwise(&xs);
        // different association orders may (and here do) differ in bits …
        assert!((s - p).abs() < 1e-3);
        // … while each is self-consistent
        assert_eq!(p.to_bits(), sum_pairwise(&xs).to_bits());
    }

    #[test]
    fn kulisch_is_exact_and_permutation_invariant() {
        let mut xs = lcg_vec(2000, 7, 1e6);
        let direct = sum_exact(&xs);
        // adversarial permutation: sort by magnitude descending
        xs.sort_by(|a, b| b.abs().partial_cmp(&a.abs()).unwrap());
        assert_eq!(sum_exact(&xs).to_bits(), direct.to_bits());
        xs.reverse();
        assert_eq!(sum_exact(&xs).to_bits(), direct.to_bits());
    }

    #[test]
    fn kulisch_matches_known_exact_sums() {
        assert_eq!(sum_exact(&[0.5, 1e9, -1e9]), 0.5); // exact, any order
        assert_eq!(sum_exact(&[1e9, -1e9, 0.5]), 0.5);
        assert_eq!(sum_exact(&[]), 0.0);
        assert_eq!(sum_exact(&[-2.5]), -2.5);
        assert_eq!(sum_exact(&[1.0; 1000]), 1000.0);
        // cancellation to zero
        let xs = [3.5f32, -1.25, -2.25];
        assert_eq!(sum_exact(&xs), 0.0);
        // tiny values that sequential f32 loses entirely
        let mut v = vec![1.0f32];
        v.extend(std::iter::repeat(1e-10f32).take(1 << 12));
        let exact = 1.0f64 + (1 << 12) as f64 * 1e-10f64;
        assert_eq!(sum_exact(&v), exact as f32);
        assert_eq!(sum_sequential(&v), 1.0); // the motivating failure
    }

    #[test]
    fn kulisch_subnormals_and_extremes() {
        let tiny = f32::from_bits(1); // 2^-149
        assert_eq!(sum_exact(&[tiny, tiny]), f32::from_bits(2));
        assert_eq!(sum_exact(&[tiny, -tiny]), 0.0);
        assert_eq!(sum_exact(&[f32::MAX, f32::MAX, -f32::MAX]), f32::MAX);
        // overflow of the f32 range (not the accumulator) saturates
        assert_eq!(sum_exact(&[f32::MAX, f32::MAX]), f32::INFINITY);
        assert_eq!(sum_exact(&[f32::MAX, f32::MAX, f32::MIN_POSITIVE]), f32::INFINITY);
    }

    #[test]
    fn kulisch_merge_equals_single_pass() {
        let xs = lcg_vec(512, 3, 10.0);
        let mut a = KulischAcc::new();
        let mut b = KulischAcc::new();
        for &x in &xs[..200] {
            a.add(x);
        }
        for &x in &xs[200..] {
            b.add(x);
        }
        a.merge(&b);
        assert_eq!(a.round_f32().to_bits(), sum_exact(&xs).to_bits());
    }

    #[test]
    fn kulisch_vs_f64_reference_on_moderate_data() {
        // With values ~1e3 and n=4096, f64 accumulation is exact enough
        // to be a second oracle.
        let xs = lcg_vec(4096, 99, 1e3);
        let f64sum: f64 = xs.iter().map(|&x| x as f64).sum();
        assert_eq!(sum_exact(&xs), f64sum as f32);
    }

    #[test]
    fn dot_variants_deterministic_and_distinct() {
        let a = lcg_vec(333, 11, 2.0);
        let b = lcg_vec(333, 22, 2.0);
        let d1 = dot_sequential(&a, &b);
        let d2 = dot_sequential_fma(&a, &b);
        assert_eq!(d1.to_bits(), dot_sequential(&a, &b).to_bits());
        assert_eq!(d2.to_bits(), dot_sequential_fma(&a, &b).to_bits());
        // FMA keeps the products exact pre-add: generally different bits
        assert!((d1 - d2).abs() < 1e-2);
    }

    #[test]
    fn kahan_beats_sequential_accuracy() {
        let xs = lcg_vec(100_000, 5, 1.0);
        let exact = sum_exact(&xs) as f64;
        let seq = sum_sequential(&xs) as f64;
        let kah = sum_kahan(&xs) as f64;
        assert!((kah - exact).abs() <= (seq - exact).abs() + 1e-9);
    }
}
