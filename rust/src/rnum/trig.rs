//! Correctly-rounded `sin`, `cos`, `tan` for `f32` (paper §3.2.1).
//!
//! Same Ziv two-step shape as [`super::exp`]:
//!
//! * `|x| ≤ π/4`  — polynomial directly.
//! * `|x| ≤ 2²⁰`  — fdlibm-style two-stage Cody–Waite reduction against
//!   split π/2 constants (each product/difference exact or exactly
//!   rounded), then a fixed-order Taylor polynomial. The absolute
//!   reduction error is < 2⁻⁶⁸ while the worst-case reduced argument for
//!   f32 inputs in this range stays above ≈2⁻³⁰, giving a relative bound
//!   well inside the 2⁻³⁵ acceptance margin.
//! * otherwise    — 768-bit BigFloat Payne–Hanek-equivalent reduction
//!   (`trig_reduce`), which also backs the rare ambiguous fast-path
//!   results.

use super::bigfloat::{BigFloat, PREC_ORACLE};
use super::exp::round_unambiguous;

// fdlibm split of π/2 into 33-bit chunks (each head piece has enough
// trailing zero bits that products with |k| < 2^21 are exact).
const PIO2_1: f64 = 1.57079632673412561417e+00; // 0x3FF921FB54400000
const PIO2_2: f64 = 6.07710050630396597660e-11; // 0x3DD0B4611A600000
const PIO2_2T: f64 = 2.02226624879595063154e-21; // 0x3BA3198A2E037073
const INV_PIO2: f64 = 6.36619772367581382433e-01; // 2/π

/// Acceptance margin for the trig fast paths (dominated by the
/// reduction-error / minimum-reduced-argument ratio).
const TRIG_MARGIN: f64 = 2.0e-11; // ≈ 2^-35.5

/// Two-stage Cody–Waite reduction (the fdlibm medium path, run
/// unconditionally): x = k·π/2 + y, |y| ≲ π/4. Valid for |x| ≤ 2²⁰.
/// π/2 ≈ PIO2_1 + PIO2_2 + PIO2_2T with the dropped tail below 2⁻¹²¹,
/// so the absolute error of y is ≲ 2⁻¹⁰⁰ — far inside the margin even
/// against the worst-case reduced argument (≈2⁻³⁰ for f32 inputs here).
#[inline]
fn rem_pio2_medium(x: f64) -> (f64, i64) {
    let fk = (x * INV_PIO2).round();
    let k = fk as i64;
    // First stage: exact (fk·PIO2_1 is exact for |fk| < 2^21 and the
    // subtraction cancels to a small difference).
    let t = x - fk * PIO2_1;
    // Second stage with error compensation (Fast2Sum-style).
    let w = fk * PIO2_2;
    let z = t - w;
    let wc = fk * PIO2_2T - ((t - z) - w);
    (z - wc, k)
}

/// Fixed-order Taylor for sin on |y| ≤ π/4 + ε (relative error < 2⁻⁵⁰).
#[inline]
fn sin_poly(y: f64) -> f64 {
    let z = y * y;
    // Exact-rational Taylor coefficients as fixed f64 literals.
    const C: [f64; 8] = [
        -1.66666666666666666667e-1, // -1/3!
        8.33333333333333333333e-3,  // 1/5!
        -1.98412698412698412698e-4, // -1/7!
        2.75573192239858906526e-6,  // 1/9!
        -2.50521083854417187751e-8, // -1/11!
        1.60590438368216145994e-10, // 1/13!
        -7.64716373181981647590e-13,
        2.81145725434552076320e-15,
    ];
    let mut p = C[7];
    for i in (0..7).rev() {
        p = C[i] + z * p;
    }
    y + y * z * p
}

/// Fixed-order Taylor for cos on |y| ≤ π/4 + ε.
#[inline]
fn cos_poly(y: f64) -> f64 {
    let z = y * y;
    const C: [f64; 8] = [
        -0.5,
        4.16666666666666666667e-2,  // 1/4!
        -1.38888888888888888889e-3, // -1/6!
        2.48015873015873015873e-5,  // 1/8!
        -2.75573192239858906526e-7, // -1/10!
        2.08767569878680989792e-9,  // 1/12!
        -1.14707455977297247139e-11,
        4.77947733238738529744e-14,
    ];
    let mut p = C[7];
    for i in (0..7).rev() {
        p = C[i] + z * p;
    }
    1.0 + z * p
}

const MEDIUM_LIMIT: f32 = 1_048_576.0; // 2^20

/// Correctly-rounded sin x for `f32`.
pub fn rsin(x: f32) -> f32 {
    if !x.is_finite() {
        return f32::NAN;
    }
    if x == 0.0 {
        return x; // ±0 preserved
    }
    let xd = x as f64;
    if x.abs() <= MEDIUM_LIMIT {
        let (y, k) = if xd.abs() <= std::f64::consts::FRAC_PI_4 {
            (xd, 0i64)
        } else {
            rem_pio2_medium(xd)
        };
        let v = match k & 3 {
            0 => sin_poly(y),
            1 => cos_poly(y),
            2 => -sin_poly(y),
            _ => -cos_poly(y),
        };
        if let Some(r) = round_unambiguous(v, TRIG_MARGIN) {
            return r;
        }
    }
    BigFloat::from_f32(x, PREC_ORACLE).sin_bf().to_f32()
}

/// Correctly-rounded cos x for `f32`.
pub fn rcos(x: f32) -> f32 {
    if !x.is_finite() {
        return f32::NAN;
    }
    if x == 0.0 {
        return 1.0;
    }
    let xd = x as f64;
    if x.abs() <= MEDIUM_LIMIT {
        let (y, k) = if xd.abs() <= std::f64::consts::FRAC_PI_4 {
            (xd, 0i64)
        } else {
            rem_pio2_medium(xd)
        };
        let v = match k & 3 {
            0 => cos_poly(y),
            1 => -sin_poly(y),
            2 => -cos_poly(y),
            _ => sin_poly(y),
        };
        if let Some(r) = round_unambiguous(v, TRIG_MARGIN) {
            return r;
        }
    }
    BigFloat::from_f32(x, PREC_ORACLE).cos_bf().to_f32()
}

/// Correctly-rounded tan x for `f32`.
pub fn rtan(x: f32) -> f32 {
    if !x.is_finite() {
        return f32::NAN;
    }
    if x == 0.0 {
        return x;
    }
    let xd = x as f64;
    if x.abs() <= MEDIUM_LIMIT {
        let (y, k) = if xd.abs() <= std::f64::consts::FRAC_PI_4 {
            (xd, 0i64)
        } else {
            rem_pio2_medium(xd)
        };
        let v = if k & 1 == 0 {
            sin_poly(y) / cos_poly(y)
        } else {
            -cos_poly(y) / sin_poly(y)
        };
        // one extra division rounding → slightly wider margin
        if let Some(r) = round_unambiguous(v, 2.0 * TRIG_MARGIN) {
            return r;
        }
    }
    BigFloat::from_f32(x, PREC_ORACLE).tan_bf().to_f32()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rnum::fbits::ulp_diff;

    fn osin(x: f32) -> f32 {
        BigFloat::from_f32(x, PREC_ORACLE).sin_bf().to_f32()
    }
    fn ocos(x: f32) -> f32 {
        BigFloat::from_f32(x, PREC_ORACLE).cos_bf().to_f32()
    }
    fn otan(x: f32) -> f32 {
        BigFloat::from_f32(x, PREC_ORACLE).tan_bf().to_f32()
    }

    #[test]
    fn specials() {
        assert!(rsin(f32::NAN).is_nan());
        assert!(rsin(f32::INFINITY).is_nan());
        assert_eq!(rsin(0.0), 0.0);
        assert_eq!(rsin(-0.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(rcos(0.0), 1.0);
        assert_eq!(rtan(0.0), 0.0);
    }

    #[test]
    fn small_arguments_round_to_x() {
        // sin x ≈ x − x³/6: for |x| < 2^-13 the cubic term is below half
        // an ulp, so CR sin must return x exactly (RNE).
        for &x in &[1e-10f32, -1e-10, 1e-20, 2e-5] {
            assert_eq!(rsin(x).to_bits(), x.to_bits(), "x={x}");
            assert_eq!(rtan(x).to_bits(), x.to_bits(), "x={x}");
        }
    }

    #[test]
    fn matches_oracle_medium_range() {
        let mut x = -30.0f32;
        while x < 30.0 {
            assert_eq!(rsin(x).to_bits(), osin(x).to_bits(), "sin({x})");
            assert_eq!(rcos(x).to_bits(), ocos(x).to_bits(), "cos({x})");
            x += 0.0917;
        }
    }

    #[test]
    fn matches_oracle_near_multiples_of_pi_over_2() {
        // The cancellation-critical region.
        for k in 1..200 {
            let near = (k as f64 * std::f64::consts::FRAC_PI_2) as f32;
            for d in [-2i32, -1, 0, 1, 2] {
                let x = f32::from_bits((near.to_bits() as i32 + d) as u32);
                assert_eq!(rsin(x).to_bits(), osin(x).to_bits(), "sin({x})");
                assert_eq!(rcos(x).to_bits(), ocos(x).to_bits(), "cos({x})");
            }
        }
    }

    #[test]
    fn huge_arguments_use_bigfloat_reduction() {
        for &x in &[1e7f32, 1e20, 3.0e38, -2.5e33, 16_777_215.0] {
            assert_eq!(rsin(x).to_bits(), osin(x).to_bits(), "sin({x})");
            assert_eq!(rcos(x).to_bits(), ocos(x).to_bits(), "cos({x})");
        }
    }

    #[test]
    fn tan_matches_oracle() {
        let mut x = -10.0f32;
        while x < 10.0 {
            assert_eq!(
                rtan(x).to_bits(),
                otan(x).to_bits(),
                "tan({x}) got={} want={}",
                rtan(x),
                otan(x)
            );
            x += 0.0531;
        }
    }

    #[test]
    fn close_to_libm() {
        let mut x = -100.0f32;
        while x < 100.0 {
            assert!(ulp_diff(rsin(x), x.sin()) <= 1, "sin({x})");
            assert!(ulp_diff(rcos(x), x.cos()) <= 1, "cos({x})");
            x += 0.317;
        }
    }
}
