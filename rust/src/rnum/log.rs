//! Correctly-rounded `ln`, `log2` and `log1p` for `f32` (paper §3.2.1).
//!
//! The paper's motivating example (§2.2.1) is precisely this function:
//! `log x` differs between GNU libc and the Intel Math Library. RepDL
//! instead computes it with a fixed, platform-independent algorithm —
//! Ziv's two-step strategy, like [`super::exp`]: a fixed-graph `f64`
//! evaluation with a proven error bound, an unambiguity check, and a
//! 320-bit [`BigFloat`] fallback for the rare hard cases.

use super::bigfloat::{consts, BigFloat, PREC_ORACLE};
use super::exp::round_unambiguous;

const LN2_HI: f64 = 6.93147180369123816490e-01; // 32 trailing zero bits
const LN2_LO: f64 = 1.90821492927058770002e-10;
const SQRT2: f64 = std::f64::consts::SQRT_2;

/// Decompose a positive finite `f64` into `(m, e)` with `x = m·2^e` and
/// `m ∈ [√2/2, √2)`. Exact (pure bit surgery).
#[inline]
fn frexp_centered(x: f64) -> (f64, i32) {
    let bits = x.to_bits();
    let mut e = (((bits >> 52) & 0x7ff) as i32) - 1023;
    // f32 inputs converted to f64 are never subnormal in f64.
    let mut m = f64::from_bits((bits & 0x000f_ffff_ffff_ffff) | 0x3ff0_0000_0000_0000);
    if m >= SQRT2 {
        m *= 0.5;
        e += 1;
    }
    (m, e)
}

/// atanh-series core: ln(m) for m ∈ [√2/2, √2), relative error < 2⁻⁵⁰.
/// z = (m−1)/(m+1) ≤ 0.1716; ln m = 2z·(1 + z²/3 + z⁴/5 + … + z²²/23).
#[inline]
fn ln_core(m: f64) -> f64 {
    let z = (m - 1.0) / (m + 1.0);
    let z2 = z * z;
    const INV_ODD: [f64; 11] = [
        0.333333333333333333,  // 1/3
        0.2,                   // 1/5
        0.142857142857142857,  // 1/7
        0.111111111111111111,  // 1/9
        0.0909090909090909091, // 1/11
        0.0769230769230769231, // 1/13
        0.0666666666666666667, // 1/15
        0.0588235294117647059, // 1/17
        0.0526315789473684211, // 1/19
        0.0476190476190476190, // 1/21
        0.0434782608695652174, // 1/23
    ];
    let mut p = INV_ODD[10];
    for i in (0..10).rev() {
        p = INV_ODD[i] + z2 * p;
    }
    2.0 * z * (1.0 + z2 * p)
}

/// Margin for the log fast paths (covers series truncation ≈ 2⁻⁵⁶,
/// rounding accumulation, and the mild e·ln2 cancellation).
const LOG_MARGIN: f64 = 4.0e-14;

/// Correctly-rounded natural logarithm for `f32`.
pub fn rlog(x: f32) -> f32 {
    if x.is_nan() || x < 0.0 {
        return f32::NAN;
    }
    if x == 0.0 {
        return f32::NEG_INFINITY;
    }
    if x.is_infinite() {
        return f32::INFINITY;
    }
    if x == 1.0 {
        return 0.0; // the only exact finite case
    }
    let (m, e) = frexp_centered(x as f64);
    let ed = e as f64;
    // ed·LN2_HI is exact (|e| ≤ 149 fits the 21-bit constant headroom).
    let y = ed * LN2_HI + (ln_core(m) + ed * LN2_LO);
    if let Some(r) = round_unambiguous(y, LOG_MARGIN) {
        return r;
    }
    BigFloat::from_f32(x, PREC_ORACLE).ln_bf().to_f32()
}

/// Correctly-rounded log₂ for `f32`.
pub fn rlog2(x: f32) -> f32 {
    if x.is_nan() || x < 0.0 {
        return f32::NAN;
    }
    if x == 0.0 {
        return f32::NEG_INFINITY;
    }
    if x.is_infinite() {
        return f32::INFINITY;
    }
    // Exact for powers of two (the common exact family).
    let bits = x.to_bits();
    if bits & 0x007f_ffff == 0 && bits >> 23 != 0 {
        return (bits >> 23) as f32 - 127.0;
    }
    if super::fbits::is_subnormal(x) && x.to_bits().count_ones() == 1 {
        return x.to_bits().trailing_zeros() as f32 - 149.0;
    }
    let (m, e) = frexp_centered(x as f64);
    // log2 x = e + ln(m)/ln2; the division is one extra rounding.
    const INV_LN2: f64 = std::f64::consts::LOG2_E;
    let y = e as f64 + ln_core(m) * INV_LN2;
    if let Some(r) = round_unambiguous(y, LOG_MARGIN) {
        return r;
    }
    let b = BigFloat::from_f32(x, PREC_ORACLE);
    b.ln_bf().div(&consts::ln2(PREC_ORACLE)).to_f32()
}

/// Correctly-rounded ln(1+x) for `f32`.
pub fn rlog1p(x: f32) -> f32 {
    if x.is_nan() || x < -1.0 {
        return f32::NAN;
    }
    if x == -1.0 {
        return f32::NEG_INFINITY;
    }
    if x == 0.0 {
        return x; // ±0 preserved
    }
    if x.is_infinite() {
        return f32::INFINITY;
    }
    let xd = x as f64;
    let y = if xd.abs() < 0.4 {
        // ln(1+x) with the same atanh series but z = x/(x+2): avoids
        // forming 1+x (which would lose low bits of tiny x).
        let z = xd / (xd + 2.0);
        let z2 = z * z;
        const INV_ODD: [f64; 11] = [
            0.333333333333333333,
            0.2,
            0.142857142857142857,
            0.111111111111111111,
            0.0909090909090909091,
            0.0769230769230769231,
            0.0666666666666666667,
            0.0588235294117647059,
            0.0526315789473684211,
            0.0476190476190476190,
            0.0434782608695652174,
        ];
        let mut p = INV_ODD[10];
        for i in (0..10).rev() {
            p = INV_ODD[i] + z2 * p;
        }
        2.0 * z * (1.0 + z2 * p)
    } else {
        // 1+x is exact in f64 here (x ≥ 0.4 or x ∈ (-1, -0.4]: the sum
        // stays within one binade of x and f64 has 29 spare bits).
        let (m, e) = frexp_centered(1.0 + xd);
        let ed = e as f64;
        ed * LN2_HI + (ln_core(m) + ed * LN2_LO)
    };
    if let Some(r) = round_unambiguous(y, LOG_MARGIN) {
        return r;
    }
    let one = BigFloat::one(PREC_ORACLE);
    BigFloat::from_f32(x, PREC_ORACLE).add(&one).ln_bf().to_f32()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rnum::fbits::ulp_diff;

    fn oracle_ln(x: f32) -> f32 {
        BigFloat::from_f32(x, PREC_ORACLE).ln_bf().to_f32()
    }

    #[test]
    fn specials() {
        assert!(rlog(f32::NAN).is_nan());
        assert!(rlog(-1.0).is_nan());
        assert_eq!(rlog(0.0), f32::NEG_INFINITY);
        assert_eq!(rlog(f32::INFINITY), f32::INFINITY);
        assert_eq!(rlog(1.0), 0.0);
    }

    #[test]
    fn matches_oracle_on_sweep() {
        // pseudo-random sweep across the full positive range incl. subnormals
        let mut bits = 1u32; // smallest subnormal
        for _ in 0..3000 {
            let x = f32::from_bits(bits);
            assert_eq!(
                rlog(x).to_bits(),
                oracle_ln(x).to_bits(),
                "x={x} bits={bits:#x}"
            );
            bits = bits.wrapping_mul(1664525).wrapping_add(1013904223) % 0x7f80_0000;
            if bits == 0 {
                bits = 1;
            }
        }
    }

    #[test]
    fn dense_near_one() {
        // the hardest region: ln(x) tiny, heavy cancellation hazards
        for i in 0..4000 {
            let x = f32::from_bits(1.0f32.to_bits() - 2000 + i);
            assert_eq!(rlog(x).to_bits(), oracle_ln(x).to_bits(), "x={x}");
        }
    }

    #[test]
    fn close_to_libm() {
        for i in 1..2000 {
            let x = i as f32 * 0.013;
            assert!(ulp_diff(rlog(x), x.ln()) <= 1, "x={x}");
        }
    }

    #[test]
    fn log2_exact_powers() {
        for k in -149..=127 {
            let x = crate::rnum::fbits::pow2_f64(k) as f32;
            assert_eq!(rlog2(x), k as f32, "k={k}");
        }
    }

    #[test]
    fn log2_matches_oracle() {
        let ln2 = consts::ln2(PREC_ORACLE);
        let mut x = 0.001f32;
        while x < 1e6 {
            let want = BigFloat::from_f32(x, PREC_ORACLE)
                .ln_bf()
                .div(&ln2)
                .to_f32();
            assert_eq!(rlog2(x).to_bits(), want.to_bits(), "x={x}");
            x *= 1.097;
        }
    }

    #[test]
    fn log1p_small_inputs_preserved() {
        assert_eq!(rlog1p(0.0), 0.0);
        assert_eq!(rlog1p(-0.0).to_bits(), (-0.0f32).to_bits());
        // ln(1+x) ≈ x for tiny x: must round to x itself
        for &x in &[1e-30f32, -1e-30, 1e-20, -1e-20] {
            assert_eq!(rlog1p(x).to_bits(), x.to_bits(), "x={x}");
        }
    }

    #[test]
    fn log1p_matches_oracle() {
        let one = BigFloat::one(PREC_ORACLE);
        let mut x = -0.9999f32;
        while x < 50.0 {
            let want = BigFloat::from_f32(x, PREC_ORACLE)
                .add(&one)
                .ln_bf()
                .to_f32();
            assert_eq!(
                rlog1p(x).to_bits(),
                want.to_bits(),
                "x={x} got={} want={want}",
                rlog1p(x)
            );
            x += 0.0717;
        }
    }

    #[test]
    fn log1p_close_to_libm() {
        for i in 0..1000 {
            let x = -0.99 + i as f32 * 0.05;
            assert!(ulp_diff(rlog1p(x), x.ln_1p()) <= 1, "x={x}");
        }
    }
}
