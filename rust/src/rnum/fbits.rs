//! Bit-level utilities for IEEE-754 binary32/binary64.
//!
//! These helpers are the vocabulary of the whole library: ULP distances
//! for verification, monotone integer mappings for comparisons, and
//! exponent/significand surgery for the correctly-rounded kernels.

/// Bias of the binary32 exponent.
pub const F32_EXP_BIAS: i32 = 127;
/// Number of explicit significand bits in binary32.
pub const F32_SIG_BITS: u32 = 23;
/// Smallest positive normal binary32.
pub const F32_MIN_NORMAL: f32 = 1.175_494_4e-38;

/// Map an `f32` to an integer such that the ordering of finite floats is
/// the ordering of the integers (signed-magnitude unfolding; ±0 both map
/// to 0, so they count as the same value for ULP purposes).
#[inline]
pub fn ordered_i64(x: f32) -> i64 {
    let b = x.to_bits();
    let mag = (b & 0x7fff_ffff) as i64;
    if b >> 31 == 1 {
        -mag
    } else {
        mag
    }
}

/// Distance in units-in-the-last-place between two floats, computed on the
/// monotone integer mapping. `ulp_diff(a, b) == 0` iff the two are the
/// same value (±0 counted equal; both-NaN counted equal).
#[inline]
pub fn ulp_diff(a: f32, b: f32) -> u32 {
    if a.is_nan() && b.is_nan() {
        return 0;
    }
    let (ia, ib) = (ordered_i64(a), ordered_i64(b));
    (ia - ib).unsigned_abs().min(u32::MAX as u64) as u32
}

/// One unit in the last place of `x` (the gap to the next representable
/// float away from zero). For `x == 0` this is the smallest subnormal.
#[inline]
pub fn ulp_f32(x: f32) -> f32 {
    if !x.is_finite() {
        return f32::NAN;
    }
    let a = x.abs();
    let next = f32::from_bits(a.to_bits() + 1);
    if next.is_infinite() {
        a - f32::from_bits(a.to_bits() - 1)
    } else {
        next - a
    }
}

/// The next representable `f32` after `x` in the direction of `dir`.
#[inline]
pub fn next_after(x: f32, dir: f32) -> f32 {
    if x.is_nan() || dir.is_nan() {
        return f32::NAN;
    }
    if x == dir {
        return dir;
    }
    let bits = x.to_bits();
    let next = if (x < dir) == (x >= 0.0) && x != 0.0 {
        bits + 1
    } else if x == 0.0 {
        // from ±0 step into the smallest subnormal of the right sign
        if dir > 0.0 {
            1
        } else {
            0x8000_0001
        }
    } else {
        bits - 1
    };
    f32::from_bits(next)
}

/// True if `x` is subnormal (nonzero, biased exponent 0).
#[inline]
pub fn is_subnormal(x: f32) -> bool {
    x != 0.0 && (x.to_bits() & 0x7f80_0000) == 0
}

/// Decompose a finite nonzero `f32` into `(sign, significand, exponent)`
/// with `value = sign * significand * 2^exponent` and
/// `significand` a 24-bit-or-less odd-capable integer (subnormals give
/// smaller significands). Exact.
pub fn decompose(x: f32) -> (i32, u64, i32) {
    debug_assert!(x.is_finite() && x != 0.0);
    let bits = x.to_bits();
    let sign = if bits >> 31 == 1 { -1 } else { 1 };
    let biased = ((bits >> 23) & 0xff) as i32;
    let frac = (bits & 0x7f_ffff) as u64;
    if biased == 0 {
        // subnormal: value = frac * 2^-149
        (sign, frac, -149)
    } else {
        (sign, frac | (1 << 23), biased - F32_EXP_BIAS - 23)
    }
}

/// Compose `sign * significand * 2^exponent` into the nearest `f32` using
/// round-to-nearest-even. `significand` may be wider than 24 bits.
/// Used by tests to cross-check `BigFloat::to_f32`.
pub fn compose_rne(sign: i32, mut sig: u64, mut exp: i32) -> f32 {
    if sig == 0 {
        return if sign < 0 { -0.0 } else { 0.0 };
    }
    // Normalise to exactly 25 bits (24 + round) with sticky.
    let mut sticky = false;
    while sig >= 1 << 25 {
        sticky |= sig & 1 == 1;
        sig >>= 1;
        exp += 1;
    }
    while sig < 1 << 24 {
        sig <<= 1;
        exp -= 1;
    }
    // Now sig in [2^24, 2^25), value = sig * 2^exp. Unbiased exponent of
    // the leading bit is exp + 24.
    let e_unb = exp + 24;
    if e_unb > 127 + 1 {
        return if sign < 0 { f32::NEG_INFINITY } else { f32::INFINITY };
    }
    let mut keep = 24i32;
    if e_unb < -126 {
        keep -= -126 - e_unb; // subnormal: fewer significand bits survive
    }
    if keep < 0 {
        // Magnitude below 2^-150: rounds to (signed) zero.
        return if sign < 0 { -0.0 } else { 0.0 };
    }
    // keep == 0 handles the [2^-150, 2^-149) band: the round bit is the
    // leading bit itself and the kept significand is empty.
    let drop = 25 - keep;
    let round_bit = (sig >> (drop - 1)) & 1 == 1;
    let low_mask = (1u64 << (drop - 1)) - 1;
    sticky |= sig & low_mask != 0;
    let mut kept = sig >> drop;
    if round_bit && (sticky || kept & 1 == 1) {
        kept += 1;
    }
    // kept now has at most `keep` bits (+1 on carry).
    let mut val = kept as f32;
    // value = kept * 2^(exp + drop)
    let scale_exp = exp + drop;
    val = scale_f32_by_pow2(val, scale_exp);
    if sign < 0 {
        -val
    } else {
        val
    }
}

/// Multiply by 2^k exactly (with correct over/underflow to inf/0,
/// rounding subnormals correctly via two-step scaling).
#[inline]
pub fn scale_f32_by_pow2(x: f32, k: i32) -> f32 {
    // Split the scale so each factor is a normal power of two.
    let mut r = x as f64;
    r *= pow2_f64(k);
    r as f32 // f64->f32 RNE; r is exact (x*2^k fits f64 when x kept <= 2^25)
}

/// 2^k as f64 (k in a range where this is exact or saturates sensibly).
#[inline]
pub fn pow2_f64(k: i32) -> f64 {
    if k >= 1024 {
        f64::INFINITY
    } else if k < -1074 {
        0.0
    } else if k >= -1022 {
        f64::from_bits(((k + 1023) as u64) << 52)
    } else {
        // subnormal power of two
        f64::from_bits(1u64 << (k + 1074))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_mapping_is_monotone() {
        let xs = [
            -f32::INFINITY,
            -1e30,
            -2.5,
            -1.0,
            -f32::MIN_POSITIVE,
            -0.0,
            0.0,
            f32::MIN_POSITIVE,
            1.0,
            2.5,
            1e30,
            f32::INFINITY,
        ];
        for w in xs.windows(2) {
            assert!(
                ordered_i64(w[0]) <= ordered_i64(w[1]),
                "{} vs {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn ulp_diff_adjacent_is_one() {
        for &x in &[1.0f32, -1.0, 0.1, 1e-40, 3.4e38] {
            let y = next_after(x, f32::INFINITY);
            assert_eq!(ulp_diff(x, y), 1, "x={x}");
        }
    }

    #[test]
    fn ulp_diff_across_zero() {
        // -0.0 and +0.0 are 0 apart in the ordered mapping? They differ by
        // bit pattern but compare equal; ordered mapping puts them 1 apart.
        assert_eq!(ulp_diff(f32::from_bits(1), -f32::from_bits(1)), 2);
    }

    #[test]
    fn decompose_compose_roundtrip() {
        let cases = [
            1.0f32,
            -1.0,
            0.5,
            3.141_592_7,
            1e-40,
            -1e-40,
            f32::MIN_POSITIVE,
            3.402_823_5e38,
            f32::from_bits(1),
        ];
        for &x in &cases {
            let (s, m, e) = decompose(x);
            let back = compose_rne(s, m, e);
            assert_eq!(back.to_bits(), x.to_bits(), "x={x}");
        }
    }

    #[test]
    fn compose_rounds_to_nearest_even() {
        // 2^24 + 1 is not representable in f32; 25-bit value rounds to even.
        assert_eq!(compose_rne(1, (1 << 24) + 1, 0), 16_777_216.0);
        // 2^24 + 3 rounds up to 2^24 + 4.
        assert_eq!(compose_rne(1, (1 << 24) + 3, 0), 16_777_220.0);
    }

    #[test]
    fn compose_handles_overflow_and_underflow() {
        assert!(compose_rne(1, 1 << 24, 150).is_infinite());
        assert_eq!(compose_rne(1, 1, -200), 0.0);
        // Smallest subnormal survives.
        assert_eq!(compose_rne(1, 1, -149), f32::from_bits(1));
    }

    #[test]
    fn subnormal_detection() {
        assert!(is_subnormal(f32::from_bits(1)));
        assert!(!is_subnormal(f32::MIN_POSITIVE));
        assert!(!is_subnormal(0.0));
    }

    #[test]
    fn pow2_f64_exact_values() {
        assert_eq!(pow2_f64(0), 1.0);
        assert_eq!(pow2_f64(10), 1024.0);
        assert_eq!(pow2_f64(-1), 0.5);
        assert_eq!(pow2_f64(-1074), f64::from_bits(1));
        assert_eq!(pow2_f64(-1075), 0.0);
        assert!(pow2_f64(1024).is_infinite());
    }
}
