//! Reproducible numerics — the core of RepDL (paper §3).
//!
//! Two principles (paper §3.1):
//!
//! 1. **Correct rounding for basic operations.** Every function here that
//!    is documented as *correctly rounded* returns, for every input, the
//!    IEEE-754 round-to-nearest-even rounding of the infinitely precise
//!    mathematical result. Its bit pattern is therefore identical on every
//!    IEEE-754-conforming platform, independent of libm, compiler, or ISA.
//! 2. **Order invariance for reductions.** Floating-point summation has no
//!    canonical "correct" result, so RepDL instead *specifies the
//!    association order*: [`sum::sum_sequential`] (default) and
//!    [`sum::sum_pairwise`] (alternative API, different name — paper
//!    §3.2.2) are both bit-deterministic for a given input order.
//!
//! The paper builds on MPFR and RLIBM for correct rounding; neither is
//! available in this environment, so [`bigfloat::BigFloat`] — an
//! arbitrary-precision binary float with exactly-rounded `+ − × ÷ √` and
//! series-evaluated transcendentals — plays both roles:
//!
//! * the **test oracle** every production op is validated against, and
//! * the **hard-case fallback** inside the production ops (Ziv's two-step
//!   strategy: evaluate in `f64` with a fixed, platform-independent
//!   algorithm; if the result provably rounds unambiguously to `f32`,
//!   accept it, otherwise re-evaluate in `BigFloat`). Both steps are
//!   deterministic, so the composition is deterministic.

pub mod bigfloat;
pub mod dot;
pub mod exp;
pub mod fbits;
pub mod log;
pub mod pow;
pub mod reduce;
pub mod special;
pub mod sqrt;
pub mod sum;
pub mod trig;

pub use bigfloat::BigFloat;
pub use exp::{rexp, rexp2, rexpm1};
pub use log::{rlog, rlog1p, rlog2};
pub use pow::rpow;
pub use special::{rgelu_erf, rgelu_tanh, rsigmoid, rtanh};
pub use sqrt::{rrsqrt, rsqrt_f32};
pub use reduce::{fixed_tree_reduce, fixed_tree_reduce_into};
pub use sum::{
    dot_sequential, pairwise_split, sum_exact, sum_kahan, sum_pairwise, sum_sequential, KulischAcc,
};
pub use trig::{rcos, rsin, rtan};
