//! Bitwise run comparison — the measurement instrument for E1/E2/E8.

use crate::rnum::fbits::ulp_diff;

/// Result of comparing two runs.
#[derive(Clone, Debug)]
pub struct Comparison {
    /// Bitwise identical loss curves?
    pub curves_identical: bool,
    /// First step at which the curves differ in bits.
    pub first_divergence: Option<usize>,
    /// Maximum ULP distance across the curves.
    pub max_ulp: u32,
    /// Final-state hashes equal?
    pub hashes_equal: bool,
}

/// First index where the two curves differ in bit pattern.
pub fn first_divergence(a: &[f32], b: &[f32]) -> Option<usize> {
    a.iter()
        .zip(b.iter())
        .position(|(x, y)| x.to_bits() != y.to_bits())
        .or(if a.len() != b.len() { Some(a.len().min(b.len())) } else { None })
}

/// Compare two runs (loss curves + state hashes).
pub fn compare_runs(
    curve_a: &[f32],
    curve_b: &[f32],
    hash_a: &str,
    hash_b: &str,
) -> Comparison {
    let fd = first_divergence(curve_a, curve_b);
    let max_ulp = curve_a
        .iter()
        .zip(curve_b.iter())
        .map(|(&x, &y)| ulp_diff(x, y))
        .max()
        .unwrap_or(0);
    Comparison {
        curves_identical: fd.is_none(),
        first_divergence: fd,
        max_ulp,
        hashes_equal: hash_a == hash_b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_runs() {
        let c = compare_runs(&[1.0, 0.5], &[1.0, 0.5], "aa", "aa");
        assert!(c.curves_identical);
        assert!(c.hashes_equal);
        assert_eq!(c.max_ulp, 0);
        assert_eq!(c.first_divergence, None);
    }

    #[test]
    fn detects_divergence_step() {
        let a = [1.0f32, 0.5, 0.25];
        let b = [1.0f32, 0.5, 0.2500001];
        let c = compare_runs(&a, &b, "aa", "bb");
        assert!(!c.curves_identical);
        assert_eq!(c.first_divergence, Some(2));
        assert!(c.max_ulp >= 1);
        assert!(!c.hashes_equal);
    }

    #[test]
    fn length_mismatch_is_divergence() {
        assert_eq!(first_divergence(&[1.0, 2.0], &[1.0]), Some(1));
    }
}
