//! Bitwise fingerprints for model state and tensors.

use crate::sha256::Sha256;
use crate::tensor::Tensor;

/// Hex-encode bytes.
pub fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// SHA-256 over a parameter list (order-sensitive, includes shapes and
/// raw bit patterns) — the model-state fingerprint used by E1/E2/E8.
pub fn hash_params(params: &[&Tensor]) -> String {
    let mut h = Sha256::new();
    for p in params {
        h.update((p.dims().len() as u64).to_le_bytes());
        for &d in p.dims() {
            h.update((d as u64).to_le_bytes());
        }
        for &v in p.data() {
            h.update(v.to_bits().to_le_bytes());
        }
    }
    hex(&h.finalize())
}

/// SHA-256 of a loss curve (bit patterns).
pub fn hash_curve(curve: &[f32]) -> String {
    let mut h = Sha256::new();
    for &v in curve {
        h.update(v.to_bits().to_le_bytes());
    }
    hex(&h.finalize())
}

/// SHA-256 fingerprint of one tensor — shape-framed raw f32 bit
/// patterns, exactly the [`hash_params`] framing for a single-tensor
/// list. This is the content address the serve subsystem uses for
/// requests (memo-cache keys) and responses (audit-log entries): two
/// tensors share a hash iff they share shape and every payload bit
/// (-0.0 vs 0.0 and NaN payloads all distinguish).
pub fn hash_tensor(t: &Tensor) -> String {
    hash_params(&[t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_and_bits_sensitive() {
        let a = Tensor::full(&[2], 1.0);
        let b = Tensor::full(&[2], 2.0);
        assert_ne!(hash_params(&[&a, &b]), hash_params(&[&b, &a]));
        let c = Tensor::from_vec(&[1], vec![0.0]).unwrap();
        let d = Tensor::from_vec(&[1], vec![-0.0]).unwrap();
        assert_ne!(hash_params(&[&c]), hash_params(&[&d]));
        assert_eq!(hash_params(&[&a]), hash_params(&[&a.clone()]));
    }

    #[test]
    fn curve_hash() {
        assert_eq!(hash_curve(&[1.0, 2.0]), hash_curve(&[1.0, 2.0]));
        assert_ne!(hash_curve(&[1.0, 2.0]), hash_curve(&[2.0, 1.0]));
    }

    #[test]
    fn tensor_hash_is_shape_and_bit_sensitive() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        // same payload, different shape → different content address
        assert_ne!(hash_tensor(&a), hash_tensor(&b));
        assert_eq!(hash_tensor(&a), hash_params(&[&a]));
        // NaN payload bits distinguish (the serve log must notice a
        // response whose NaN payload drifted)
        let n1 = Tensor::from_vec(&[1], vec![f32::from_bits(0x7fc0_0001)]).unwrap();
        let n2 = Tensor::from_vec(&[1], vec![f32::from_bits(0x7fc0_0002)]).unwrap();
        assert_ne!(hash_tensor(&n1), hash_tensor(&n2));
    }
}
