//! Bitwise fingerprints for model state and tensors.

use crate::sha256::Sha256;
use crate::tensor::Tensor;

/// Hex-encode bytes.
pub fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// SHA-256 over a parameter list (order-sensitive, includes shapes and
/// raw bit patterns) — the model-state fingerprint used by E1/E2/E8.
pub fn hash_params(params: &[&Tensor]) -> String {
    let mut h = Sha256::new();
    for p in params {
        h.update((p.dims().len() as u64).to_le_bytes());
        for &d in p.dims() {
            h.update((d as u64).to_le_bytes());
        }
        for &v in p.data() {
            h.update(v.to_bits().to_le_bytes());
        }
    }
    hex(&h.finalize())
}

/// SHA-256 of a loss curve (bit patterns).
pub fn hash_curve(curve: &[f32]) -> String {
    let mut h = Sha256::new();
    for &v in curve {
        h.update(v.to_bits().to_le_bytes());
    }
    hex(&h.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_and_bits_sensitive() {
        let a = Tensor::full(&[2], 1.0);
        let b = Tensor::full(&[2], 2.0);
        assert_ne!(hash_params(&[&a, &b]), hash_params(&[&b, &a]));
        let c = Tensor::from_vec(&[1], vec![0.0]).unwrap();
        let d = Tensor::from_vec(&[1], vec![-0.0]).unwrap();
        assert_ne!(hash_params(&[&c]), hash_params(&[&d]));
        assert_eq!(hash_params(&[&a]), hash_params(&[&a.clone()]));
    }

    #[test]
    fn curve_hash() {
        assert_eq!(hash_curve(&[1.0, 2.0]), hash_curve(&[1.0, 2.0]));
        assert_ne!(hash_curve(&[1.0, 2.0]), hash_curve(&[2.0, 1.0]));
    }
}
