//! Bit-exact training checkpoints (`REPDLCKP`), DESIGN.md §12.
//!
//! The format reuses the serve journal's framing discipline
//! ([`crate::coordinator::serve::journal`]): an 8-byte magic + u32 LE
//! version header, then length-prefixed records each carrying the
//! SHA-256 of its own payload (`frame` / `scan_payloads` are literally
//! the journal's). A checkpoint is exactly six records, in order:
//!
//! | # | record   | contents                                            |
//! |---|----------|-----------------------------------------------------|
//! | 0 | META     | trainer config, optimizer selection, microbatch, step |
//! | 1 | CURVE    | the loss curve so far (f32 bit patterns)            |
//! | 2 | PARAMS   | parameter tensors, registration order               |
//! | 3 | OPT      | optimizer slot state (momenta / moments + `t`)      |
//! | 4 | RNG      | the noise stream's full Philox position             |
//! | 5 | MANIFEST | step, `hash_params` fingerprint, and the SHA-256 of |
//! |   |          | every preceding record payload                      |
//!
//! Unlike the serve journal — an append-only log whose torn tail is
//! *repaired* — a checkpoint is a point-in-time snapshot: **any** defect
//! (torn tail, missing manifest, digest mismatch, fingerprint mismatch)
//! refuses the whole file with a typed error. Crash-consistency comes
//! from writing step-numbered files into a directory and resuming from
//! the newest file that loads cleanly ([`latest_checkpoint`]): a crash
//! mid-save tears exactly one file, which is skipped, never half-read.
//!
//! Resume ≡ uninterrupted, bit-for-bit: `Trainer::step` is a pure
//! transition on [`TrainState`], and a checkpoint round-trips every
//! field of that state exactly (f32s as bit patterns, the RNG
//! mid-stream). So `stepᵏ(load(save(s))) ≡ stepᵏ(s)` for all k — pinned
//! at every k by `tests/train_checkpoint.rs`.

use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::coordinator::hashing::hash_params;
use crate::coordinator::serve::journal::{digest_hex, frame, scan_payloads};
use crate::coordinator::train::state::{OptState, TrainOptimizer, TrainState};
use crate::coordinator::trainer::{OptimizerCfg, TrainerConfig};
use crate::nn::{Act, Linear, Mlp};
use crate::optim::{AdamState, SgdState};
use crate::rng::{Philox, PhiloxState};
use crate::tensor::Tensor;
use crate::{Error, Result};

const MAGIC: [u8; 8] = *b"REPDLCKP";
const VERSION: u32 = 1;
const HEADER_LEN: usize = 12;

const TAG_META: u8 = 1;
const TAG_CURVE: u8 = 2;
const TAG_PARAMS: u8 = 3;
const TAG_OPT: u8 = 4;
const TAG_RNG: u8 = 5;
const TAG_MANIFEST: u8 = 6;
/// META..RNG — the five records the manifest hashes.
const BODY_RECORDS: usize = 5;

const OPT_KIND_SGD: u8 = 0;
const OPT_KIND_ADAM: u8 = 1;

/// Everything a resumed run must agree on before adopting a state: the
/// trainer config, the optimizer selection, and the microbatch size
/// (part of the gradient-reduction spec, so it changes bits).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CheckpointMeta {
    /// The trainer configuration of the checkpointed run.
    pub cfg: TrainerConfig,
    /// Optimizer family + hyperparameters.
    pub opt: OptimizerCfg,
    /// Microbatch size of the data-parallel reduction spec
    /// (`cfg.batch` for the single-microbatch [`super::super::Trainer`]).
    pub microbatch: usize,
}

impl CheckpointMeta {
    /// Refuse a meta that differs from what the resuming engine would
    /// run: resuming under a different config/optimizer/microbatch would
    /// silently produce a *different* deterministic run. Lane count is
    /// deliberately absent — it never changes bits.
    pub fn ensure_matches(&self, other: &CheckpointMeta) -> Result<()> {
        if self != other {
            return Err(Error::config(format!(
                "checkpoint meta mismatch: saved {self:?}, resuming engine wants {other:?}"
            )));
        }
        Ok(())
    }
}

/// A decoded, fully verified checkpoint.
pub struct Checkpoint {
    /// Run identity (config + optimizer + reduction spec).
    pub meta: CheckpointMeta,
    /// Steps completed when the checkpoint was taken.
    pub step: u64,
    /// Loss curve up to `step` (one entry per completed step).
    pub curve: Vec<f32>,
    /// Parameters, registration order (w1, b1, w2, b2).
    pub params: Vec<Tensor>,
    /// Optimizer slot state.
    pub opt_state: OptState,
    /// Noise-stream position.
    pub noise: PhiloxState,
}

impl Checkpoint {
    /// Capture a checkpoint from live run state (no I/O).
    pub fn capture(meta: CheckpointMeta, st: &TrainState, curve: &[f32]) -> Checkpoint {
        Checkpoint {
            meta,
            step: st.step,
            curve: curve.to_vec(),
            params: st.params.clone(),
            opt_state: st.opt.export_state(),
            noise: st.noise.snapshot(),
        }
    }

    /// SHA-256 fingerprint of the checkpointed parameters.
    pub fn param_hash(&self) -> String {
        let refs: Vec<&Tensor> = self.params.iter().collect();
        hash_params(&refs)
    }

    /// Rebuild the live run state: parameters as saved, optimizer slots
    /// imported, the noise stream restored mid-position. The returned
    /// state's next step is bit-identical to the uninterrupted run's.
    pub fn into_state(self) -> Result<(TrainState, Vec<f32>)> {
        let mut opt = TrainOptimizer::from_cfg(self.meta.opt, self.meta.cfg.lr);
        opt.import_state(self.opt_state)?;
        let st = TrainState {
            step: self.step,
            params: self.params,
            opt,
            noise: Philox::restore(self.noise),
        };
        Ok((st, self.curve))
    }

    /// View the checkpointed parameters as an inference [`Mlp`] (for
    /// promotion into the serve registry). The trainer's layout is
    /// `h = relu(x·w1 + b1)` with w1 shaped (in, out); [`Linear`] is the
    /// PyTorch (out, in) layout computing `x·Wᵀ + b` — so each weight is
    /// transposed (layout-only, bit-neutral) and the forward graphs are
    /// identical: the tower serves exactly the trained function.
    pub fn to_mlp(&self) -> Result<Mlp> {
        if self.params.len() < 2 || self.params.len() % 2 != 0 {
            return Err(Error::shape(format!(
                "checkpoint has {} params, want (weight, bias) pairs",
                self.params.len()
            )));
        }
        let mut layers = Vec::with_capacity(self.params.len() / 2);
        for pair in self.params.chunks(2) {
            let (w, b) = (&pair[0], &pair[1]);
            if w.dims().len() != 2 || b.dims().len() != 1 || w.dims()[1] != b.dims()[0] {
                return Err(Error::shape(format!(
                    "checkpoint layer shapes {:?}/{:?} are not a (in,out)/(out,) pair",
                    w.dims(),
                    b.dims()
                )));
            }
            layers.push(Linear { weight: w.transpose2d()?, bias: b.clone() });
        }
        Ok(Mlp { layers, act: Act::Relu })
    }
}

// ---------------------------------------------------------------------
// encoding
// ---------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn put_tensor(buf: &mut Vec<u8>, t: &Tensor) {
    put_u64(buf, t.dims().len() as u64);
    for &d in t.dims() {
        put_u64(buf, d as u64);
    }
    for &v in t.data() {
        put_u32(buf, v.to_bits());
    }
}

fn encode_meta(meta: &CheckpointMeta, step: u64) -> Vec<u8> {
    let c = &meta.cfg;
    let mut buf = vec![TAG_META];
    put_u64(&mut buf, c.side as u64);
    put_u64(&mut buf, c.hidden as u64);
    put_u64(&mut buf, c.classes as u64);
    put_u64(&mut buf, c.batch as u64);
    put_u64(&mut buf, c.steps as u64);
    put_u32(&mut buf, c.lr.to_bits());
    put_u64(&mut buf, c.seed);
    put_u32(&mut buf, c.dropout.to_bits());
    match meta.opt {
        OptimizerCfg::Sgd { momentum, weight_decay } => {
            buf.push(OPT_KIND_SGD);
            put_u32(&mut buf, momentum.to_bits());
            put_u32(&mut buf, weight_decay.to_bits());
        }
        OptimizerCfg::Adam => {
            buf.push(OPT_KIND_ADAM);
            put_u32(&mut buf, 0);
            put_u32(&mut buf, 0);
        }
    }
    put_u64(&mut buf, meta.microbatch as u64);
    put_u64(&mut buf, step);
    buf
}

fn encode_curve(curve: &[f32]) -> Vec<u8> {
    let mut buf = vec![TAG_CURVE];
    put_u64(&mut buf, curve.len() as u64);
    for &v in curve {
        put_u32(&mut buf, v.to_bits());
    }
    buf
}

fn encode_params(params: &[Tensor]) -> Vec<u8> {
    let mut buf = vec![TAG_PARAMS];
    put_u64(&mut buf, params.len() as u64);
    for t in params {
        put_tensor(&mut buf, t);
    }
    buf
}

fn encode_opt(state: &OptState) -> Vec<u8> {
    let mut buf = vec![TAG_OPT];
    match state {
        OptState::Sgd(s) => {
            buf.push(OPT_KIND_SGD);
            put_u64(&mut buf, s.bufs.len() as u64);
            for t in &s.bufs {
                put_tensor(&mut buf, t);
            }
        }
        OptState::Adam(s) => {
            buf.push(OPT_KIND_ADAM);
            put_u32(&mut buf, s.t);
            put_u64(&mut buf, s.m.len() as u64);
            for t in s.m.iter().chain(s.v.iter()) {
                put_tensor(&mut buf, t);
            }
        }
    }
    buf
}

fn encode_rng(s: &PhiloxState) -> Vec<u8> {
    let mut buf = vec![TAG_RNG];
    for w in s.counter.iter().chain(s.key.iter()).chain(s.buf.iter()) {
        put_u32(&mut buf, *w);
    }
    put_u32(&mut buf, s.idx);
    buf
}

fn encode_manifest(step: u64, param_hash: &str, body_payloads: &[&[u8]]) -> Vec<u8> {
    let mut buf = vec![TAG_MANIFEST];
    put_u64(&mut buf, step);
    put_str(&mut buf, param_hash);
    put_u64(&mut buf, body_payloads.len() as u64);
    for p in body_payloads {
        put_str(&mut buf, &digest_hex(p));
    }
    buf
}

// ---------------------------------------------------------------------
// decoding
// ---------------------------------------------------------------------

struct Cursor<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, off: 0 }
    }
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.off < n {
            return Err(Error::journal(format!(
                "checkpoint record truncated: wanted {n} bytes at offset {} of {}",
                self.off,
                self.b.len()
            )));
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().expect("4 bytes")))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().expect("8 bytes")))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u64()? as usize;
        let s = self.bytes(n)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| Error::journal("checkpoint record holds a non-UTF-8 string"))
    }
    fn tensor(&mut self) -> Result<Tensor> {
        let rank = self.u64()? as usize;
        if rank > 8 {
            return Err(Error::journal(format!("checkpoint tensor rank {rank} exceeds 8")));
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(self.u64()? as usize);
        }
        let numel = dims
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| Error::journal("checkpoint tensor dims overflow"))?;
        if numel.checked_mul(4).map_or(true, |b| self.b.len() - self.off < b) {
            return Err(Error::journal(format!(
                "checkpoint tensor claims {numel} elements but the record is short"
            )));
        }
        let mut data = Vec::with_capacity(numel);
        for _ in 0..numel {
            data.push(self.f32()?);
        }
        Tensor::from_vec(&dims, data)
            .map_err(|e| Error::journal(format!("checkpoint tensor is malformed: {e}")))
    }
    fn expect_tag(&mut self, tag: u8, name: &str) -> Result<()> {
        let got = self.u8()?;
        if got != tag {
            return Err(Error::journal(format!(
                "checkpoint record {name}: tag {got}, want {tag} (records out of order?)"
            )));
        }
        Ok(())
    }
    fn done(&self) -> Result<()> {
        if self.off != self.b.len() {
            return Err(Error::journal(format!(
                "checkpoint record has {} trailing bytes",
                self.b.len() - self.off
            )));
        }
        Ok(())
    }
}

fn decode_meta(payload: &[u8]) -> Result<(CheckpointMeta, u64)> {
    let mut c = Cursor::new(payload);
    c.expect_tag(TAG_META, "META")?;
    let cfg = TrainerConfig {
        side: c.u64()? as usize,
        hidden: c.u64()? as usize,
        classes: c.u64()? as usize,
        batch: c.u64()? as usize,
        steps: c.u64()? as usize,
        lr: c.f32()?,
        seed: c.u64()?,
        dropout: c.f32()?,
    };
    let kind = c.u8()?;
    let (a, b) = (c.f32()?, c.f32()?);
    let opt = match kind {
        OPT_KIND_SGD => OptimizerCfg::Sgd { momentum: a, weight_decay: b },
        OPT_KIND_ADAM => OptimizerCfg::Adam,
        k => return Err(Error::journal(format!("checkpoint META: unknown optimizer kind {k}"))),
    };
    let microbatch = c.u64()? as usize;
    let step = c.u64()?;
    c.done()?;
    Ok((CheckpointMeta { cfg, opt, microbatch }, step))
}

fn decode_curve(payload: &[u8]) -> Result<Vec<f32>> {
    let mut c = Cursor::new(payload);
    c.expect_tag(TAG_CURVE, "CURVE")?;
    let n = c.u64()? as usize;
    if n.checked_mul(4).map_or(true, |b| payload.len().saturating_sub(c.off) < b) {
        return Err(Error::journal("checkpoint CURVE record is short"));
    }
    let mut curve = Vec::with_capacity(n);
    for _ in 0..n {
        curve.push(c.f32()?);
    }
    c.done()?;
    Ok(curve)
}

fn decode_params(payload: &[u8]) -> Result<Vec<Tensor>> {
    let mut c = Cursor::new(payload);
    c.expect_tag(TAG_PARAMS, "PARAMS")?;
    let n = c.u64()? as usize;
    let mut params = Vec::with_capacity(n.min(64));
    for _ in 0..n {
        params.push(c.tensor()?);
    }
    c.done()?;
    Ok(params)
}

fn decode_opt(payload: &[u8]) -> Result<OptState> {
    let mut c = Cursor::new(payload);
    c.expect_tag(TAG_OPT, "OPT")?;
    let state = match c.u8()? {
        OPT_KIND_SGD => {
            let n = c.u64()? as usize;
            let mut bufs = Vec::with_capacity(n.min(64));
            for _ in 0..n {
                bufs.push(c.tensor()?);
            }
            OptState::Sgd(SgdState { bufs })
        }
        OPT_KIND_ADAM => {
            let t = c.u32()?;
            let n = c.u64()? as usize;
            let mut m = Vec::with_capacity(n.min(64));
            for _ in 0..n {
                m.push(c.tensor()?);
            }
            let mut v = Vec::with_capacity(n.min(64));
            for _ in 0..n {
                v.push(c.tensor()?);
            }
            OptState::Adam(AdamState { m, v, t })
        }
        k => return Err(Error::journal(format!("checkpoint OPT: unknown optimizer kind {k}"))),
    };
    c.done()?;
    Ok(state)
}

fn decode_rng(payload: &[u8]) -> Result<PhiloxState> {
    let mut c = Cursor::new(payload);
    c.expect_tag(TAG_RNG, "RNG")?;
    let mut words = [0u32; 10];
    for w in words.iter_mut() {
        *w = c.u32()?;
    }
    let idx = c.u32()?;
    c.done()?;
    Ok(PhiloxState {
        counter: [words[0], words[1], words[2], words[3]],
        key: [words[4], words[5]],
        buf: [words[6], words[7], words[8], words[9]],
        idx,
    })
}

fn decode_manifest(payload: &[u8]) -> Result<(u64, String, Vec<String>)> {
    let mut c = Cursor::new(payload);
    c.expect_tag(TAG_MANIFEST, "MANIFEST")?;
    let step = c.u64()?;
    let param_hash = c.str()?;
    let n = c.u64()? as usize;
    let mut digests = Vec::with_capacity(n.min(16));
    for _ in 0..n {
        digests.push(c.str()?);
    }
    c.done()?;
    Ok((step, param_hash, digests))
}

// ---------------------------------------------------------------------
// save / load / resume
// ---------------------------------------------------------------------

/// The canonical checkpoint file name for a step (sortable zero-padded
/// step number, so directory order = step order).
pub fn checkpoint_path(dir: &Path, step: u64) -> PathBuf {
    dir.join(format!("step-{step:08}.repdlckp"))
}

/// Serialize a checkpoint to bytes (header + six framed records).
/// Fallible because [`frame`] refuses payloads that overflow its `u32`
/// length field (a >4 GiB parameter record would otherwise wrap).
fn encode_checkpoint(meta: &CheckpointMeta, st: &TrainState, curve: &[f32]) -> Result<Vec<u8>> {
    let opt_state = st.opt.export_state();
    let noise = st.noise.snapshot();
    let refs: Vec<&Tensor> = st.params.iter().collect();
    let body = [
        encode_meta(meta, st.step),
        encode_curve(curve),
        encode_params(&st.params),
        encode_opt(&opt_state),
        encode_rng(&noise),
    ];
    let body_refs: Vec<&[u8]> = body.iter().map(|p| p.as_slice()).collect();
    let manifest = encode_manifest(st.step, &hash_params(&refs), &body_refs);
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    for payload in body.iter().chain(std::iter::once(&manifest)) {
        out.extend_from_slice(&frame(payload)?);
    }
    Ok(out)
}

/// Write a checkpoint file and fsync it. The write targets the final
/// path directly: a crash mid-write leaves a torn file, which
/// [`load_checkpoint`] refuses and [`latest_checkpoint`] skips — the
/// previous checkpoint file stays the resume point (same crash story as
/// the serve journal, adapted to snapshot semantics).
pub fn save_checkpoint(
    path: &Path,
    meta: &CheckpointMeta,
    st: &TrainState,
    curve: &[f32],
) -> Result<()> {
    let bytes = encode_checkpoint(meta, st, curve)?;
    let mut f = File::create(path)?;
    f.write_all(&bytes)?;
    f.sync_all()?;
    Ok(())
}

/// Read and fully verify a checkpoint file. Refusals (all typed, never
/// a panic): wrong magic/version; torn tail; fewer than six records
/// (crash before the manifest); record decode failures; a manifest
/// whose per-record digests or parameter fingerprint disagree with the
/// decoded contents; META/MANIFEST step disagreement; a curve whose
/// length is not the step count.
pub fn load_checkpoint(path: &Path) -> Result<Checkpoint> {
    let mut bytes = Vec::new();
    File::open(path)?.read_to_end(&mut bytes)?;
    if bytes.len() < HEADER_LEN || bytes[..8] != MAGIC {
        return Err(Error::journal(format!(
            "{} is not a repdl checkpoint (bad magic)",
            path.display()
        )));
    }
    let version = u32::from_le_bytes(bytes[8..HEADER_LEN].try_into().expect("4 bytes"));
    if version != VERSION {
        return Err(Error::journal(format!(
            "{}: checkpoint format version {version}, this build reads {VERSION}",
            path.display()
        )));
    }
    let records = &bytes[HEADER_LEN..];
    let (payloads, valid) = scan_payloads(records);
    if valid != records.len() {
        return Err(Error::journal(format!(
            "{}: torn checkpoint tail ({} bytes after the last intact record) — refusing the file",
            path.display(),
            records.len() - valid
        )));
    }
    if payloads.len() != BODY_RECORDS + 1 {
        return Err(Error::journal(format!(
            "{}: {} records, want {} (crash before the manifest record?)",
            path.display(),
            payloads.len(),
            BODY_RECORDS + 1
        )));
    }
    let (meta, step) = decode_meta(payloads[0])?;
    let curve = decode_curve(payloads[1])?;
    let params = decode_params(payloads[2])?;
    let opt_state = decode_opt(payloads[3])?;
    let noise = decode_rng(payloads[4])?;
    let (m_step, m_param_hash, digests) = decode_manifest(payloads[5])?;
    if digests.len() != BODY_RECORDS {
        return Err(Error::journal(format!(
            "{}: manifest lists {} record digests, want {BODY_RECORDS}",
            path.display(),
            digests.len()
        )));
    }
    for (i, (payload, want)) in payloads[..BODY_RECORDS].iter().zip(digests.iter()).enumerate() {
        if &digest_hex(payload) != want {
            return Err(Error::journal(format!(
                "{}: manifest mismatch on record {i} — refusing the checkpoint",
                path.display()
            )));
        }
    }
    let refs: Vec<&Tensor> = params.iter().collect();
    if hash_params(&refs) != m_param_hash {
        return Err(Error::journal(format!(
            "{}: manifest parameter fingerprint mismatch",
            path.display()
        )));
    }
    if m_step != step {
        return Err(Error::journal(format!(
            "{}: META step {step} disagrees with MANIFEST step {m_step}",
            path.display()
        )));
    }
    if curve.len() as u64 != step {
        return Err(Error::journal(format!(
            "{}: loss curve has {} entries for {step} steps",
            path.display(),
            curve.len()
        )));
    }
    Ok(Checkpoint { meta, step, curve, params, opt_state, noise })
}

/// Result of scanning a checkpoint directory (see [`latest_checkpoint`]).
pub struct CheckpointScan {
    /// The newest checkpoint that loaded and verified cleanly.
    pub loaded: Option<(PathBuf, Checkpoint)>,
    /// Files that were refused, newest-first, with the refusal reason —
    /// surfaced so a torn tail is reported, never silently skipped.
    pub rejected: Vec<(PathBuf, String)>,
}

/// Find the newest resumable checkpoint in a directory: `.repdlckp`
/// files are tried newest-step-first (file-name order) and the first
/// one that fully verifies wins; defective files — e.g. the torn last
/// save of a crashed run — are recorded in `rejected` and skipped.
pub fn latest_checkpoint(dir: &Path) -> Result<CheckpointScan> {
    let mut names: Vec<PathBuf> = std::fs::read_dir(dir)?
        .collect::<std::io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .filter(|p| p.extension().map_or(false, |x| x == "repdlckp"))
        .collect();
    names.sort();
    let mut rejected = Vec::new();
    for path in names.into_iter().rev() {
        match load_checkpoint(&path) {
            Ok(ckpt) => return Ok(CheckpointScan { loaded: Some((path, ckpt)), rejected }),
            Err(e) => rejected.push((path, e.to_string())),
        }
    }
    Ok(CheckpointScan { loaded: None, rejected })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::{NumericsMode, Trainer};

    fn small_meta() -> (Trainer, CheckpointMeta) {
        let cfg = TrainerConfig { steps: 6, dropout: 0.25, ..Default::default() };
        let meta = CheckpointMeta { cfg, opt: OptimizerCfg::default(), microbatch: cfg.batch };
        (Trainer::new(cfg, NumericsMode::Repro), meta)
    }

    #[test]
    fn save_load_round_trips_every_field() {
        let (tr, meta) = small_meta();
        let mut st = tr.init_state();
        let mut curve = Vec::new();
        for _ in 0..3 {
            curve.push(tr.step(&mut st).unwrap());
        }
        let dir = std::env::temp_dir().join("repdl-ckpt-roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = checkpoint_path(&dir, st.step);
        save_checkpoint(&path, &meta, &st, &curve).unwrap();
        let ckpt = load_checkpoint(&path).unwrap();
        assert_eq!(ckpt.meta, meta);
        assert_eq!(ckpt.step, 3);
        assert_eq!(ckpt.param_hash(), st.param_hash());
        assert_eq!(
            crate::coordinator::hashing::hash_curve(&ckpt.curve),
            crate::coordinator::hashing::hash_curve(&curve)
        );
        // resume and finish: bits must match the uninterrupted run
        let (mut st2, mut curve2) = ckpt.into_state().unwrap();
        for _ in 3..6 {
            curve2.push(tr.step(&mut st2).unwrap());
            curve.push(tr.step(&mut st).unwrap());
        }
        assert_eq!(st.param_hash(), st2.param_hash());
        assert_eq!(
            crate::coordinator::hashing::hash_curve(&curve),
            crate::coordinator::hashing::hash_curve(&curve2)
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn latest_checkpoint_skips_torn_files() {
        let (tr, meta) = small_meta();
        let mut st = tr.init_state();
        let mut curve = Vec::new();
        let dir = std::env::temp_dir().join("repdl-ckpt-latest");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for _ in 0..2 {
            curve.push(tr.step(&mut st).unwrap());
            save_checkpoint(&checkpoint_path(&dir, st.step), &meta, &st, &curve).unwrap();
        }
        // tear the newest file mid-record (simulated crash during save)
        let newest = checkpoint_path(&dir, 2);
        let bytes = std::fs::read(&newest).unwrap();
        std::fs::write(&newest, &bytes[..bytes.len() - 7]).unwrap();
        let scan = latest_checkpoint(&dir).unwrap();
        let (path, ckpt) = scan.loaded.expect("step-1 checkpoint must load");
        assert_eq!(path, checkpoint_path(&dir, 1));
        assert_eq!(ckpt.step, 1);
        assert_eq!(scan.rejected.len(), 1);
        assert!(scan.rejected[0].1.contains("torn"), "{}", scan.rejected[0].1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn meta_mismatch_is_refused_on_resume() {
        let (_, meta) = small_meta();
        let other = CheckpointMeta {
            cfg: TrainerConfig { lr: 0.123, ..meta.cfg },
            ..meta
        };
        assert!(meta.ensure_matches(&other).is_err());
        assert!(meta.ensure_matches(&meta).is_ok());
    }
}
