//! [`TrainState`] — the complete mutable state of a training run.
//!
//! The step engine's contract is that **everything** a step reads or
//! writes besides the immutable config lives here, so checkpointing the
//! state checkpoints the run: params, optimizer slot buffers, the step
//! counter, and the RNG stream position. `Trainer::step` is then a pure
//! state transition, and resume≡uninterrupted reduces to this struct
//! round-tripping bit-exactly (proof sketch in DESIGN.md §12).

use crate::coordinator::hashing::hash_params;
use crate::coordinator::trainer::OptimizerCfg;
use crate::optim::{Adam, AdamState, SgdState, SGD};
use crate::rng::Philox;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// The optimizer instance owned by a [`TrainState`] — a closed enum so
/// the engine can step, export and import without generics leaking into
/// the checkpoint format.
pub enum TrainOptimizer {
    /// SGD (optionally with momentum slots).
    Sgd(SGD),
    /// Adam (moment slots + bias-correction counter).
    Adam(Adam),
}

/// Exported optimizer slot state, mirroring [`TrainOptimizer`].
#[derive(Clone, Debug)]
pub enum OptState {
    /// SGD momentum buffers.
    Sgd(SgdState),
    /// Adam moments + step counter.
    Adam(AdamState),
}

impl TrainOptimizer {
    /// Build a fresh optimizer from the config selection.
    pub fn from_cfg(cfg: OptimizerCfg, lr: f32) -> TrainOptimizer {
        match cfg {
            OptimizerCfg::Sgd { momentum, weight_decay } => {
                TrainOptimizer::Sgd(SGD::new(lr, momentum, weight_decay))
            }
            OptimizerCfg::Adam => TrainOptimizer::Adam(Adam::new(lr)),
        }
    }

    /// Apply one optimizer step to `params` (fixed registration order).
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) -> Result<()> {
        let refs: Vec<&mut Tensor> = params.iter_mut().collect();
        match self {
            TrainOptimizer::Sgd(o) => o.step(refs, grads),
            TrainOptimizer::Adam(o) => o.step(refs, grads),
        }
    }

    /// Export the slot state for checkpointing.
    pub fn export_state(&self) -> OptState {
        match self {
            TrainOptimizer::Sgd(o) => OptState::Sgd(o.export_state()),
            TrainOptimizer::Adam(o) => OptState::Adam(o.export_state()),
        }
    }

    /// Import checkpointed slot state. The state's family must match
    /// this optimizer's ([`Error::Config`] otherwise — a checkpoint from
    /// a different optimizer selection must never be silently adopted).
    pub fn import_state(&mut self, state: OptState) -> Result<()> {
        match (self, state) {
            (TrainOptimizer::Sgd(o), OptState::Sgd(s)) => o.import_state(s),
            (TrainOptimizer::Adam(o), OptState::Adam(s)) => o.import_state(s),
            (TrainOptimizer::Sgd(_), OptState::Adam(_)) => {
                Err(Error::config("optimizer state is Adam but the trainer runs SGD"))
            }
            (TrainOptimizer::Adam(_), OptState::Sgd(_)) => {
                Err(Error::config("optimizer state is SGD but the trainer runs Adam"))
            }
        }
    }
}

/// All mutable state of a training run (see module docs).
pub struct TrainState {
    /// Logical steps completed so far.
    pub step: u64,
    /// Parameters, fixed order: w1, b1, w2, b2.
    pub params: Vec<Tensor>,
    /// Optimizer instance (hyperparameters + slot buffers).
    pub opt: TrainOptimizer,
    /// Noise stream for dropout-style draws; its position is part of
    /// the state so draws resume mid-stream.
    pub noise: Philox,
}

impl TrainState {
    /// SHA-256 fingerprint of the current parameters.
    pub fn param_hash(&self) -> String {
        let refs: Vec<&Tensor> = self.params.iter().collect();
        hash_params(&refs)
    }
}
