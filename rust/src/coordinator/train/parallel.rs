//! Data-parallel training with pool-size-invariant bits.
//!
//! Each batch is split into fixed-size microbatches (`ceil(B/m)` of
//! them); the worker pool computes one gradient *sum* per microbatch
//! (static microbatch→lane map, `tensor/pool.rs` discipline), and the
//! partial sums are combined in a **fixed pairwise-tree order** over the
//! microbatch index — the same split rule as `rnum/sum.rs::sum_pairwise`
//! (left subtree = largest power of two below n). The combined sum is
//! divided by the full batch size exactly once, after all combination.
//!
//! **Why lane count cannot change bits** (DESIGN.md §12): the microbatch
//! decomposition is a function of (batch, microbatch) only; each partial
//! sum is a pure function of (params, microbatch data, mask rows); and
//! the combination tree is a function of the microbatch *count*. Lanes
//! decide only *where* each partial is computed — never which partials
//! exist nor the order they combine — so lanes ∈ {1,2,4,8,…} produce
//! identical parameter bits. Changing `microbatch` is a different
//! (equally deterministic) reduction spec, exactly like choosing
//! pairwise vs sequential summation in `rnum`.
//!
//! GEMMs *inside* a pool task dispatch on a private 1-lane pool (inline
//! execution — nested dispatch on the outer pool would deadlock, see
//! `tensor/pool.rs`); pool size never changes GEMM bits, so this choice
//! is invisible in the output.

use crate::coordinator::trainer::{
    batch_indices, draw_mask, finalize_grads, report, MicroGrad, NumericsMode, OptimizerCfg,
    Trainer, TrainerConfig, TrainReport,
};
use crate::coordinator::train::TrainState;
use crate::rnum::reduce::fixed_tree_reduce;
use crate::tensor::{Tensor, WorkerPool};
use crate::{Error, Result};
use std::sync::{Arc, Mutex};

/// Data-parallel step engine over a worker pool (see module docs).
/// Bits depend on (config, optimizer, microbatch) — never on `lanes`.
pub struct DataParallelTrainer {
    trainer: Trainer,
    pool: Arc<WorkerPool>,
    /// Sequential pool for GEMMs inside pool tasks (1 lane = inline).
    seq: Arc<WorkerPool>,
    microbatch: usize,
}

impl DataParallelTrainer {
    /// New engine: `lanes` parallel lanes, `microbatch` samples per
    /// partial gradient sum. `microbatch` must be in `1..=cfg.batch`
    /// (the last microbatch may be ragged). Runs Repro numerics — the
    /// baseline modes exist to *demonstrate* non-determinism and have no
    /// data-parallel story.
    pub fn new(cfg: TrainerConfig, lanes: usize, microbatch: usize) -> Result<Self> {
        if microbatch == 0 || microbatch > cfg.batch {
            return Err(Error::config(format!(
                "microbatch {microbatch} must be in 1..={}",
                cfg.batch
            )));
        }
        Ok(DataParallelTrainer {
            trainer: Trainer::new(cfg, NumericsMode::Repro),
            pool: WorkerPool::shared(lanes),
            seq: WorkerPool::shared(1),
            microbatch,
        })
    }

    /// Select the optimizer family (builder style).
    pub fn optimizer(mut self, opt: OptimizerCfg) -> Self {
        self.trainer = self.trainer.optimizer(opt);
        self
    }

    /// The wrapped single-engine trainer (config access).
    pub fn trainer(&self) -> &Trainer {
        &self.trainer
    }

    /// Parallel lanes (a pure performance knob).
    pub fn lanes(&self) -> usize {
        self.pool.lanes()
    }

    /// Microbatch size (part of the reduction spec: changes bits).
    pub fn microbatch(&self) -> usize {
        self.microbatch
    }

    /// Fresh run state — identical bits to [`Trainer::init_state`].
    pub fn init_state(&self) -> TrainState {
        self.trainer.init_state()
    }

    /// One data-parallel optimizer step (see module docs for the
    /// fixed-order reduction argument). With `microbatch == batch` this
    /// is a single-partial tree and bit-matches [`Trainer::step`].
    pub fn step(&self, st: &mut TrainState) -> Result<f32> {
        let c = &self.trainer.cfg;
        let ds = self.trainer.dataset();
        let idxs = batch_indices(c, st.step);
        let (x, labels) = ds.batch_flat(&idxs);
        // the mask is drawn row-major on this thread, before the fan-out,
        // so the stream position advance is lane-independent
        let mask = draw_mask(c, &mut st.noise)?;
        let n_in = c.side * c.side;
        let nmb = c.batch.div_ceil(self.microbatch);
        // static decomposition: microbatch i owns rows [i·m, min((i+1)·m, B))
        let jobs: Vec<(Tensor, Vec<usize>, Option<Tensor>)> = (0..nmb)
            .map(|i| {
                let r0 = i * self.microbatch;
                let r1 = ((i + 1) * self.microbatch).min(c.batch);
                let rows = r1 - r0;
                let x_mb = Tensor::from_vec(
                    &[rows, n_in],
                    x.data()[r0 * n_in..r1 * n_in].to_vec(),
                )?;
                let mask_mb = match &mask {
                    Some(m) => Some(Tensor::from_vec(
                        &[rows, c.hidden],
                        m.data()[r0 * c.hidden..r1 * c.hidden].to_vec(),
                    )?),
                    None => None,
                };
                Ok((x_mb, labels[r0..r1].to_vec(), mask_mb))
            })
            .collect::<Result<Vec<_>>>()?;
        let slots: Vec<Mutex<Option<Result<MicroGrad>>>> =
            (0..nmb).map(|_| Mutex::new(None)).collect();
        let trainer = &self.trainer;
        let seq = &self.seq;
        let params = &st.params;
        self.pool.run(nmb, &|i| {
            let (x_mb, labels_mb, mask_mb) = &jobs[i];
            let r = trainer.grad_microbatch(seq, x_mb, labels_mb, mask_mb.as_ref(), params);
            *slots[i].lock().expect("micrograd slot") = Some(r);
        });
        let mut parts: Vec<MicroGrad> = Vec::with_capacity(nmb);
        for s in slots {
            let r = s
                .into_inner()
                .expect("micrograd slot")
                .ok_or_else(|| Error::runtime("data-parallel step: a lane produced no result"))?;
            parts.push(r?);
        }
        let combined = fixed_tree_reduce(parts, &mut combine)
            .ok_or_else(|| Error::runtime("data-parallel step: zero microbatches"))?;
        let (grads, loss) = finalize_grads(combined, c.batch);
        st.opt.step(&mut st.params, &grads)?;
        st.step += 1;
        Ok(loss)
    }

    /// Run `cfg.steps` steps from a fresh state.
    pub fn run(&self) -> Result<TrainReport> {
        let mut st = self.init_state();
        let mut curve = Vec::with_capacity(self.trainer.cfg.steps);
        for _ in 0..self.trainer.cfg.steps {
            curve.push(self.step(&mut st)?);
        }
        Ok(report(st, curve))
    }
}

/// Combine two partial sums: left subtree + right subtree, elementwise,
/// in parameter order — one fixed association per tree node. The tree
/// shape itself is `rnum::reduce::fixed_tree_reduce` over the microbatch
/// index (a pure function of the microbatch count).
fn combine(mut a: MicroGrad, b: MicroGrad) -> MicroGrad {
    for (ga, gb) in a.grads.iter_mut().zip(b.grads.iter()) {
        for (x, y) in ga.data_mut().iter_mut().zip(gb.data().iter()) {
            *x += *y;
        }
    }
    a.loss_sum += b.loss_sum;
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_count_never_changes_parameter_bits() {
        // the acceptance grid (short form; the integration suite runs
        // the full matrix): lanes {1,2,4,8} × {SGD, Adam}
        for opt in [OptimizerCfg::default(), OptimizerCfg::Adam] {
            let cfg = TrainerConfig { steps: 8, ..Default::default() };
            let reference = DataParallelTrainer::new(cfg, 1, 4)
                .unwrap()
                .optimizer(opt)
                .run()
                .unwrap();
            for lanes in [2usize, 4, 8] {
                let r = DataParallelTrainer::new(cfg, lanes, 4)
                    .unwrap()
                    .optimizer(opt)
                    .run()
                    .unwrap();
                assert_eq!(reference.param_hash, r.param_hash, "lanes={lanes} opt={opt:?}");
                assert_eq!(
                    crate::coordinator::hashing::hash_curve(&reference.loss_curve),
                    crate::coordinator::hashing::hash_curve(&r.loss_curve),
                    "lanes={lanes} opt={opt:?}"
                );
            }
        }
    }

    #[test]
    fn single_microbatch_bit_matches_the_plain_trainer() {
        let cfg = TrainerConfig { steps: 8, dropout: 0.2, ..Default::default() };
        let plain = Trainer::new(cfg, NumericsMode::Repro).run().unwrap();
        let dp = DataParallelTrainer::new(cfg, 4, cfg.batch).unwrap().run().unwrap();
        assert_eq!(plain.param_hash, dp.param_hash);
    }

    #[test]
    fn ragged_tail_microbatch_is_deterministic() {
        // batch 16, microbatch 5 → partials of 5,5,5,1
        let cfg = TrainerConfig { steps: 6, ..Default::default() };
        let a = DataParallelTrainer::new(cfg, 3, 5).unwrap().run().unwrap();
        let b = DataParallelTrainer::new(cfg, 8, 5).unwrap().run().unwrap();
        assert_eq!(a.param_hash, b.param_hash);
    }

    #[test]
    fn microbatch_bounds_are_config_errors() {
        let cfg = TrainerConfig::default();
        assert!(DataParallelTrainer::new(cfg, 2, 0).is_err());
        assert!(DataParallelTrainer::new(cfg, 2, cfg.batch + 1).is_err());
    }
}
