//! The step-driven training engine (DESIGN.md §12).
//!
//! PR 8 splits training into three orthogonal pieces:
//!
//! * [`TrainState`] — *all* mutable run state: parameters, optimizer
//!   slots, the step counter, and the noise-stream position. A training
//!   run is a fold of [`crate::coordinator::Trainer::step`] (or
//!   [`DataParallelTrainer::step`]) over this state; everything else
//!   (dataset, schedules, kernels) is a pure function of the config.
//! * [`DataParallelTrainer`] — splits each batch into fixed-size
//!   microbatches, computes per-microbatch gradient *sums* on the worker
//!   pool (static microbatch→lane map), and combines them in a fixed
//!   pairwise-tree order — so lane count is a pure performance knob:
//!   lanes ∈ {1,2,4,8} produce identical parameter bits.
//! * [`checkpoint`] — a binary checkpoint format with the serve
//!   journal's framing discipline (length-prefixed SHA-256-verified
//!   records, torn-tail refusal, a manifest record binding all
//!   sections), such that `load(save(s))` resumes bit-identically to an
//!   uninterrupted run at every step.

pub mod checkpoint;
pub mod parallel;
pub mod state;

pub use checkpoint::{
    checkpoint_path, latest_checkpoint, load_checkpoint, save_checkpoint, Checkpoint,
    CheckpointMeta, CheckpointScan,
};
pub use parallel::DataParallelTrainer;
pub use state::{OptState, TrainOptimizer, TrainState};
