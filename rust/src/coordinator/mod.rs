//! Layer-3 coordinator.
//!
//! RepDL's contribution lives at the numerics layer, so L3 is a thin-plus
//! driver (per the architecture in DESIGN.md §1): training loops, a
//! deterministic inference server, and the bitwise-verification harness
//! that powers experiments E1/E2/E7/E8. Rust owns process lifecycle,
//! metrics and the CLI; Python never appears at run time.

pub mod hashing;
pub mod serve;
pub mod train;
pub mod trainer;
pub mod verifier;

pub use hashing::{hash_curve, hash_params, hash_tensor, hex};
pub use train::{
    checkpoint_path, latest_checkpoint, load_checkpoint, save_checkpoint, Checkpoint,
    CheckpointMeta, CheckpointScan, DataParallelTrainer, OptState, TrainOptimizer, TrainState,
};
pub use serve::{
    read_journal, token_key, BatchTrace, CacheStats, DeterministicServer, FaultPlan,
    FaultyWriter, FileJournalWriter, Journal, JournalEvent, JournalPolicy, JournalReadout,
    JournalStats, JournalWriter, LogEntry, MemoCache, MlpTower, ModelInfo, ModelRegistry,
    ModelTower, NamedTower, NetClient, NetServer, PanicAtTicket, Pending, Promotion,
    RecoveryReport, ReplayReport, ResponseLog, ServeConfig, ServeReplica, ServeReport,
    ServeScheduler, ServeThroughput, Session, SessionStats, SessionStore, ShardedTower,
    TransformerTower, VecWriter, WireFrame, MAX_WIRE_PAYLOAD, WIRE_MAGIC, WIRE_VERSION,
};
pub use trainer::{batch_indices, NumericsMode, OptimizerCfg, TrainReport, Trainer, TrainerConfig};
pub use verifier::{compare_runs, first_divergence, Comparison};
