//! Multi-model routing: model id → scheduler (DESIGN.md §9).
//!
//! PR 3–4's scheduler serves one model. Production serving hosts many —
//! and the determinism story must survive the composition. The registry
//! keeps it simple by making *every* per-model mechanism per-scheduler:
//! each registered [`ServeScheduler`] owns its own ticket space,
//! admission gate, memo cache and response log (exactly as DESIGN §8
//! anticipated — "admission + log are per-scheduler already, so this
//! composes"), and the registry adds only the routing step.
//!
//! **One gate lock.** [`ModelRegistry::submit`] resolves the model id
//! and stamps the ticket under a single registry-wide router lock, so
//! the interleaved multi-model submit order maps to per-model ticket
//! sequences **atomically**: if client A's submit to model X returns
//! before client B's submit to model Y starts, A's ticket in X's space
//! precedes every ticket B's interleaving could have claimed — the
//! per-model ticket sequence is a pure function of the global submit
//! order, with no window where two racing submits to different models
//! can observe each other half-routed. (Bits never depend on this —
//! towers are independent — but traces, admission decisions and audit
//! logs are part of the reproducibility contract too.)
//!
//! **Cross-model isolation.** Responses can never leak across models
//! even in principle: every memo-cache key and log entry embeds the
//! serving model's `weights_hash`, so two models given bit-identical
//! requests keep disjoint cache key spaces and per-model audit trails
//! (`tests/serve_models.rs` pins both).

use std::collections::BTreeMap;
use std::ops::Range;
use std::path::Path;
use std::sync::{Arc, Mutex};

use super::journal::read_journal;
use super::lock_recover;
use super::scheduler::{Pending, RecoveryReport, ReplayReport, ServeConfig, ServeScheduler};
use super::tower::MlpTower;
use crate::coordinator::hashing::hash_params;
use crate::coordinator::train::Checkpoint;
use crate::nn::Module;
use crate::tensor::{PoolHandle, Tensor};
use crate::{Error, Result};

/// Routes requests to per-model [`ServeScheduler`]s by model id (see
/// module docs). Build the registry up front (`register` each model's
/// scheduler), then serve through `&self`.
#[derive(Default)]
pub struct ModelRegistry {
    /// The router gate: held across id-resolution + ticket stamping so
    /// the global submit order maps atomically onto per-model ticket
    /// sequences.
    gate: Mutex<()>,
    /// id → scheduler. `BTreeMap` so every iteration (flush_all,
    /// close_all, model_ids) runs in deterministic id order.
    models: BTreeMap<String, ServeScheduler>,
    /// Promotion routing table: base id → concrete (promoted) id.
    /// Consulted *before* the concrete map, so a promoted base id routes
    /// to its newest checkpoint; see [`ModelRegistry::promote`].
    aliases: BTreeMap<String, String>,
}

/// One row of [`ModelRegistry::model_table`]: everything a network
/// client needs to form a valid request for (and audit a response
/// from) a served model. Sent in the wire hello (see [`super::wire`]),
/// so a client never guesses shapes — and the `weights_hash` lets two
/// clients on different machines verify they are talking to
/// bit-identical weights before comparing response bits.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelInfo {
    /// Routing id (the key [`ModelRegistry::submit`] resolves).
    pub model_id: String,
    /// Parameter fingerprint of the serving tower.
    pub weights_hash: String,
    /// Request length in f32 elements.
    pub d_in: u64,
    /// Response length in f32 elements.
    pub d_out: u64,
}

/// Outcome of [`ModelRegistry::promote`]: where the checkpoint now
/// serves and the deterministic swap point.
#[derive(Clone, Debug)]
pub struct Promotion {
    /// Concrete id the checkpoint is registered under:
    /// `{base_id}@{weights_hash[..12]}` — keyed by the served weights'
    /// fingerprint, so promoting two different checkpoints can never
    /// collide and promoting the *same* bits twice is a config error.
    pub model_id: String,
    /// The promoted tower's full parameter fingerprint (the hash every
    /// memo-cache key and log entry of the new model embeds).
    pub weights_hash: String,
    /// The swap watermark: the predecessor scheduler's `next_ticket` at
    /// the swap, after its queue was flushed. **Watermark rule**: every
    /// ticket `< watermark` in the predecessor's ticket space was served
    /// under the old weights; every base-id submit after the promotion
    /// claims tickets in the new scheduler's space (starting at 0).
    /// Together with the per-entry `weights_hash` stamp, an audit can
    /// attribute any logged response to exactly one weight set.
    pub watermark: u64,
    /// The concrete id the base routed to before this promotion, if any.
    pub previous: Option<String>,
}

impl ModelRegistry {
    /// Empty registry.
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// File a scheduler under its model id
    /// ([`ServeScheduler::model_id`]). Duplicate ids are a config error
    /// — registration happens at startup, before serving, so this is
    /// `&mut self` and needs no lock.
    pub fn register(&mut self, sched: ServeScheduler) -> Result<()> {
        let id = sched.model_id().to_string();
        if self.models.contains_key(&id) {
            return Err(Error::config(format!(
                "model registry: duplicate model id '{id}'"
            )));
        }
        self.models.insert(id, sched);
        Ok(())
    }

    /// Registered model ids, in deterministic (sorted) order.
    pub fn model_ids(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Identity rows for every registered model, in deterministic
    /// (sorted-id) order — the payload of the wire hello. A pure
    /// function of the registry contents: two servers built from the
    /// same models advertise byte-identical tables.
    pub fn model_table(&self) -> Vec<ModelInfo> {
        self.models
            .iter()
            .map(|(id, sched)| ModelInfo {
                model_id: id.clone(),
                weights_hash: sched.weights_hash().to_string(),
                d_in: sched.d_in() as u64,
                d_out: sched.d_out() as u64,
            })
            .collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// True when no model is registered.
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// The scheduler serving `model_id`, if registered. Promotion
    /// aliases are followed (a promoted base id yields its newest
    /// checkpoint's scheduler — use [`Self::get_exact`] for a specific
    /// concrete id). Direct access is fine for per-model operations
    /// (waiting, stats, replay); submitting through it bypasses the
    /// registry's global submit order, which only matters to callers who
    /// want cross-model trace reproducibility.
    pub fn get(&self, model_id: &str) -> Option<&ServeScheduler> {
        self.models.get(self.resolve_id(model_id))
    }

    /// A concrete scheduler by its exact id, ignoring promotion aliases
    /// — audit access to a superseded model's log/replay after its base
    /// id has been re-routed.
    pub fn get_exact(&self, model_id: &str) -> Option<&ServeScheduler> {
        self.models.get(model_id)
    }

    /// The concrete id a promoted base id currently routes to, if any.
    pub fn alias_of(&self, base_id: &str) -> Option<&str> {
        self.aliases.get(base_id).map(String::as_str)
    }

    /// Follow the (single-hop) promotion alias, if one is set.
    fn resolve_id<'a>(&'a self, model_id: &'a str) -> &'a str {
        self.aliases.get(model_id).map(String::as_str).unwrap_or(model_id)
    }

    fn resolve(&self, model_id: &str) -> Result<&ServeScheduler> {
        self.models.get(self.resolve_id(model_id)).ok_or_else(|| {
            Error::config(format!("model registry: unknown model id '{model_id}'"))
        })
    }

    /// Install a finished training checkpoint as the live model behind
    /// `base_id` — the deterministic hot weight swap closing the
    /// train→serve loop (DESIGN.md §12).
    ///
    /// The checkpoint's parameters become an [`MlpTower`] (identical
    /// forward graph to the trainer's — promotion is layout-only, so the
    /// promoted model's bits match direct inference on the final
    /// weights), registered under the concrete id
    /// `{base_id}@{weights_hash[..12]}`. If the base id already routed
    /// to a model, that predecessor is flushed and its `next_ticket`
    /// recorded as the swap [`Promotion::watermark`]; the alias then
    /// re-routes `base_id` to the new scheduler. `&mut self` makes the
    /// swap a point on the global submit order by construction: no
    /// submit can interleave with it, so which tickets ran under which
    /// weights is a pure function of the event sequence.
    pub fn promote(
        &mut self,
        base_id: &str,
        ckpt: &Checkpoint,
        shards: usize,
        pool: PoolHandle,
        cfg: ServeConfig,
    ) -> Result<Promotion> {
        let mlp = ckpt.to_mlp()?;
        // serve-side weights fingerprint: hashed over the inference
        // layout, the same fingerprint every memo-cache key and log
        // entry of the new model will embed
        let weights_hash = hash_params(&Module::params(&mlp));
        let model_id = format!("{base_id}@{}", &weights_hash[..12.min(weights_hash.len())]);
        if self.models.contains_key(&model_id) {
            return Err(Error::config(format!(
                "model registry: checkpoint already promoted as '{model_id}'"
            )));
        }
        let tower = MlpTower::with_model_id(mlp, &model_id)?;
        let sched = ServeScheduler::sharded_with(Arc::new(tower), shards, pool, cfg)?;
        let previous = self.resolve_id(base_id);
        let (previous, watermark) = match self.models.get(previous) {
            Some(prev) => {
                // drain the predecessor so the watermark is a completed
                // cut: everything below it is answered under old weights
                prev.flush();
                (Some(previous.to_string()), prev.next_ticket())
            }
            None => (None, 0),
        };
        self.models.insert(model_id.clone(), sched);
        self.aliases.insert(base_id.to_string(), model_id.clone());
        Ok(Promotion { model_id, weights_hash, watermark, previous })
    }

    /// Route one request to `model_id` under the registry gate: the
    /// per-model ticket this submit claims is a pure function of the
    /// global submit order (see module docs). Typed failures pass
    /// through from the scheduler (`Error::Rejected`, `Error::Closed`)
    /// plus `Error::Config` for an unknown id — none consume a ticket.
    pub fn submit(&self, model_id: &str, request: Tensor) -> Result<Pending> {
        let _gate = lock_recover(&self.gate);
        self.resolve(model_id)?.submit(request)
    }

    /// [`Self::submit`] that honours admission backpressure instead of
    /// surfacing it (flush-and-retry against the target model's own
    /// gate; other models are untouched).
    ///
    /// Deliberately NOT delegated to the scheduler's own
    /// `submit_flushing_rejections`: each retry here must route through
    /// [`Self::submit`] so every accepted ticket is stamped under the
    /// router gate (the cross-model trace contract), while holding that
    /// gate *across* the whole retry loop would block every other
    /// model's submits behind one model's backpressure.
    pub fn submit_with_backpressure(&self, model_id: &str, request: &Tensor) -> Result<Pending> {
        loop {
            match self.submit(model_id, request.clone()) {
                Err(Error::Rejected { .. }) => self.resolve(model_id)?.flush(),
                other => return other,
            }
        }
    }

    /// Flush one model's scheduler (a per-model logical-clock event).
    pub fn flush(&self, model_id: &str) -> Result<()> {
        self.resolve(model_id)?.flush();
        Ok(())
    }

    /// Flush every registered scheduler, in deterministic id order,
    /// under the router gate (so the cut set corresponds to one point
    /// in the global submit order).
    pub fn flush_all(&self) {
        let _gate = lock_recover(&self.gate);
        for sched in self.models.values() {
            sched.flush();
        }
    }

    /// Replay a ticket range on one model's scheduler (see
    /// [`ServeScheduler::replay`]).
    pub fn replay(&self, model_id: &str, tickets: Range<u64>) -> Result<ReplayReport> {
        self.resolve(model_id)?.replay(tickets)
    }

    /// Stop accepting requests on every scheduler; in-flight requests
    /// are drained and answered.
    pub fn close_all(&self) {
        let _gate = lock_recover(&self.gate);
        for sched in self.models.values() {
            sched.close();
        }
    }

    /// Crash recovery for a whole registry: each registered model whose
    /// journal file `<dir>/<model_id>.journal` exists is rebuilt via
    /// [`ServeScheduler::recover`] (torn tails repaired in place by
    /// [`read_journal`] first). Models without a journal file are
    /// skipped — a registry may mix journaled and unjournaled models.
    /// Runs under the router gate, before serving, in deterministic id
    /// order; any per-model failure aborts with that model named, so a
    /// half-recovered registry is never served silently.
    pub fn recover_all(&self, dir: &Path) -> Result<BTreeMap<String, RecoveryReport>> {
        let _gate = lock_recover(&self.gate);
        let mut reports = BTreeMap::new();
        for (id, sched) in &self.models {
            let path = dir.join(format!("{id}.journal"));
            if !path.exists() {
                continue;
            }
            let readout = read_journal(&path)
                .map_err(|e| Error::journal(format!("recover_all: model '{id}': {e}")))?;
            if readout.events.is_empty() {
                continue; // header-only journal: nothing to rebuild
            }
            let report = sched
                .recover(&readout)
                .map_err(|e| Error::journal(format!("recover_all: model '{id}': {e}")))?;
            reports.insert(id.clone(), report);
        }
        Ok(reports)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serve::{
        DeterministicServer, Journal, JournalPolicy, ServeConfig, ServeScheduler,
    };
    use crate::tensor::WorkerPool;
    use std::sync::Arc;

    fn linear_sched(d_in: usize, seed: u64, cfg: ServeConfig) -> ServeScheduler {
        let w = crate::rng::uniform_tensor(&[d_in, 4], -0.3, 0.3, seed);
        let srv = Arc::new(DeterministicServer::new(w, 8).unwrap());
        ServeScheduler::sharded_with(srv, 2, WorkerPool::shared(1), cfg).unwrap()
    }

    #[test]
    fn duplicate_and_unknown_ids_are_config_errors() {
        let mut reg = ModelRegistry::new();
        reg.register(linear_sched(8, 1, ServeConfig::default())).unwrap();
        // both schedulers serve model id "linear" → duplicate
        assert!(reg.register(linear_sched(8, 2, ServeConfig::default())).is_err());
        assert_eq!(reg.model_ids(), vec!["linear".to_string()]);
        assert_eq!(reg.len(), 1);
        // the rename wrapper lets a second linear model register
        let w2 = crate::rng::uniform_tensor(&[8, 4], -0.3, 0.3, 9);
        let srv2 = Arc::new(crate::coordinator::serve::NamedTower::new(
            DeterministicServer::new(w2, 8).unwrap(),
            "linear-b",
        ));
        reg.register(ServeScheduler::sharded(srv2, 1, 4, WorkerPool::shared(1)).unwrap())
            .unwrap();
        assert_eq!(
            reg.model_ids(),
            vec!["linear".to_string(), "linear-b".to_string()]
        );
        let req = crate::rng::uniform_tensor(&[8], -1.0, 1.0, 3);
        assert!(reg.submit("nope", req).is_err());
        assert!(reg.get("nope").is_none());
        assert!(reg.flush("nope").is_err());
    }

    #[test]
    fn routes_to_the_right_scheduler_and_tickets_follow_submit_order() {
        let mut reg = ModelRegistry::new();
        reg.register(linear_sched(8, 1, ServeConfig::default())).unwrap();
        let mlp = crate::coordinator::serve::MlpTower::new(crate::nn::Mlp::new(
            &[8, 6, 4],
            crate::nn::Act::Relu,
            5,
        ))
        .unwrap();
        reg.register(
            ServeScheduler::sharded(Arc::new(mlp), 1, 4, WorkerPool::shared(1)).unwrap(),
        )
        .unwrap();
        let reqs: Vec<_> =
            (0..6).map(|i| crate::rng::uniform_tensor(&[8], -1.0, 1.0, 10 + i)).collect();
        // interleave: linear, mlp, linear, mlp, …
        let mut pending = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            let id = if i % 2 == 0 { "linear" } else { "mlp" };
            pending.push((id, reg.submit(id, r.clone()).unwrap()));
        }
        // per-model ticket sequences are dense and in submit order
        for (i, (_, p)) in pending.iter().enumerate() {
            assert_eq!(p.ticket(), (i / 2) as u64, "submit {i}");
        }
        reg.flush_all();
        for (_, p) in pending {
            p.wait().unwrap();
        }
        reg.close_all();
        assert!(matches!(
            reg.submit("linear", reqs[0].clone()),
            Err(Error::Closed)
        ));
    }

    #[test]
    fn recover_all_rebuilds_each_journaled_model_bit_exactly() {
        let dir = std::env::temp_dir().join("repdl-registry-recover");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("linear.journal");
        let reqs: Vec<_> =
            (0..5).map(|i| crate::rng::uniform_tensor(&[8], -1.0, 1.0, 40 + i)).collect();
        // run 1: journaled, then dropped (the drop syncs the journal)
        let uninterrupted: Vec<String> = {
            let j = Journal::create(&path, JournalPolicy::FailStop).unwrap();
            let cfg = ServeConfig {
                log: true,
                journal: Some(Arc::new(j)),
                ..Default::default()
            };
            let sched = linear_sched(8, 1, cfg);
            let outs = sched.process_all(&reqs).unwrap();
            outs.iter().map(crate::coordinator::hashing::hash_tensor).collect()
        };
        // run 2: a fresh process — same model (same seed ⇒ same weight
        // bits), rebuilt purely from <dir>/linear.journal
        let mut reg = ModelRegistry::new();
        reg.register(linear_sched(8, 1, ServeConfig { log: true, ..Default::default() }))
            .unwrap();
        let reports = reg.recover_all(&dir).unwrap();
        assert_eq!(reports.len(), 1);
        let rep = &reports["linear"];
        assert!(rep.consistent());
        assert_eq!((rep.submits, rep.responses_restored, rep.next_ticket), (5, 5, 5));
        let sched = reg.get("linear").unwrap();
        let log = sched.log().unwrap();
        for (t, want) in uninterrupted.iter().enumerate() {
            assert_eq!(
                &log.get(t as u64).unwrap().response_hash,
                want,
                "recovered ticket {t} must carry the uninterrupted run's bits"
            );
        }
        // and the rebuilt log replays bit-exactly on the new process
        assert!(reg.replay("linear", 0..5).unwrap().verified());
        // models without a journal file are skipped, not errors
        let reports2 = reg
            .recover_all(&std::env::temp_dir().join("repdl-registry-recover-none"))
            .unwrap();
        assert!(reports2.is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn promotion_swaps_routing_at_a_watermark() {
        use crate::coordinator::train::{Checkpoint, CheckpointMeta};
        use crate::coordinator::trainer::{NumericsMode, OptimizerCfg, Trainer, TrainerConfig};

        let cfg = TrainerConfig { steps: 6, ..Default::default() };
        let tr = Trainer::new(cfg, NumericsMode::Repro);
        let mut st = tr.init_state();
        let mut curve = Vec::new();
        for _ in 0..4 {
            curve.push(tr.step(&mut st).unwrap());
        }
        let meta = CheckpointMeta { cfg, opt: OptimizerCfg::default(), microbatch: cfg.batch };
        let ckpt = Checkpoint::capture(meta, &st, &curve);

        let mut reg = ModelRegistry::new();
        // first promotion: no predecessor → watermark 0
        let p1 = reg
            .promote("mlp", &ckpt, 1, WorkerPool::shared(1), ServeConfig::default())
            .unwrap();
        assert_eq!(p1.watermark, 0);
        assert!(p1.previous.is_none());
        assert_eq!(reg.alias_of("mlp"), Some(p1.model_id.as_str()));
        let sched = reg.get("mlp").unwrap();
        assert_eq!(sched.model_id(), p1.model_id);
        assert_eq!(sched.weights_hash(), p1.weights_hash);
        assert_eq!((sched.d_in(), sched.d_out()), (cfg.side * cfg.side, cfg.classes));

        // serve three requests under the first promoted weights
        let d_in = cfg.side * cfg.side;
        let reqs: Vec<_> = (0..3)
            .map(|i| crate::rng::uniform_tensor(&[d_in], -1.0, 1.0, 70 + i))
            .collect();
        let pend: Vec<_> =
            reqs.iter().map(|r| reg.submit("mlp", r.clone()).unwrap()).collect();
        reg.flush("mlp").unwrap();
        for p in pend {
            p.wait().unwrap();
        }

        // two more steps → new weights → second promotion swaps routing
        for _ in 0..2 {
            curve.push(tr.step(&mut st).unwrap());
        }
        let ckpt2 = Checkpoint::capture(meta, &st, &curve);
        let p2 = reg
            .promote("mlp", &ckpt2, 1, WorkerPool::shared(1), ServeConfig::default())
            .unwrap();
        assert_eq!(p2.previous.as_deref(), Some(p1.model_id.as_str()));
        assert_eq!(p2.watermark, 3, "three tickets were served under the old weights");
        assert_ne!(p2.model_id, p1.model_id);
        assert_ne!(p2.weights_hash, p1.weights_hash);
        // the base id routes to the successor; the predecessor stays
        // reachable by exact id for audit
        assert_eq!(reg.get("mlp").unwrap().model_id(), p2.model_id);
        assert!(reg.get_exact(&p1.model_id).is_some());
        // promoting bit-identical weights twice is a config error
        assert!(reg
            .promote("mlp", &ckpt2, 1, WorkerPool::shared(1), ServeConfig::default())
            .is_err());
    }
}
