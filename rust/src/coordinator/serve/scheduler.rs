//! Deterministic dynamic-batching scheduler.
//!
//! The hazard (paper §2.2.2, and the serving-time analysis of
//! arXiv 2511.17826): batch composition in a production server depends
//! on *when* requests arrive relative to each other and to the
//! dispatcher — inherently racy state that conventional stacks let leak
//! into numerics via size-dispatched kernels. RepDL's kernels are batch
//! invariant, so any composition yields the same per-request bits; this
//! scheduler closes the remaining gap by making the composition itself
//! **trace-reproducible**:
//!
//! * **Tickets, not timestamps.** Every accepted request is stamped with
//!   a monotone ticket under one gate lock, and is enqueued to its shard
//!   *under that same lock*, so each shard's queue is always in ticket
//!   order. Arrival order is thereby *defined* as ticket order — the one
//!   racy event (who wins the gate) is captured in the ticket and never
//!   consulted again.
//! * **Pure batch composition.** Shard choice is `ticket % shards`;
//!   within a shard, every flush point is a *cut* segmenting the ticket
//!   sequence, and each segment is dispatched in consecutive
//!   `batch_window`-sized chunks (the segment tail, and the close tail,
//!   are the only partial batches). Composition is a pure function of
//!   (ticket sequence, shards, batch_window, flush points) — never of
//!   dispatcher wake-ups or thread timing: cuts are queued rather than
//!   coalesced and are honoured *before* the full-window rule, so a
//!   dispatcher that sleeps through a flush-then-more-submissions
//!   interleaving still emits exactly the segmented batches.
//! * **Bit-neutral sharding.** Which replica executes a batch cannot
//!   change output bits (pool-size and batch invariance, asserted by
//!   `tests/serve_scheduler.rs` across shard counts {1, 2, 4}), so
//!   `ticket % shards` is chosen for trace reproducibility, not
//!   numerics.
//! * **Responses in ticket order.** Each request carries its own
//!   response channel; [`ServeScheduler::process_all`] returns outputs
//!   indexed by ticket.
//!
//! Requests are validated at submit time (before a ticket is consumed),
//! so a malformed request errors out on its own — it can never poison a
//! batch or shift another request's ticket.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::replica::{check_request, DeterministicServer, ServeReplica};
use crate::tensor::{PoolHandle, Tensor};
use crate::{Error, Result};

/// One executed batch, for trace-reproducibility checks: which shard ran
/// which tickets together.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchTrace {
    /// Replica index that executed the batch.
    pub shard: usize,
    /// Tickets batched together, in ticket order.
    pub tickets: Vec<u64>,
}

/// A submitted request's handle: resolves to the output row (or the
/// batch's error) when its batch has executed.
pub struct Pending {
    ticket: u64,
    rx: Receiver<Result<Tensor>>,
}

impl Pending {
    /// The monotone arrival ticket this request was stamped with.
    pub fn ticket(&self) -> u64 {
        self.ticket
    }

    /// Block until the batch containing this request has executed.
    pub fn wait(self) -> Result<Tensor> {
        self.rx.recv().unwrap_or_else(|_| {
            Err(Error::runtime("serve scheduler shut down before responding"))
        })
    }
}

struct ShardQueue {
    /// Ticket-ordered (enqueue happens under the ticket gate).
    pending: VecDeque<(u64, Tensor, Sender<Result<Tensor>>)>,
    /// Flush boundaries (strictly increasing ticket counts), kept as a
    /// queue — NOT coalesced into one max — so every flush point
    /// remains a batch cut even if the dispatcher sleeps through
    /// several flushes. Tickets below a boundary never share a batch
    /// with tickets at or above it.
    cuts: VecDeque<u64>,
    closed: bool,
}

/// Executed batches kept per shard for [`ServeScheduler::trace`]: a
/// bounded ring, so a long-lived server's dispatch hot path cannot grow
/// memory without bound (old entries fall off; conformance tests run
/// far below the cap and always see the complete trace).
const TRACE_CAP: usize = 4096;

struct Shard {
    replica: ServeReplica,
    q: Mutex<ShardQueue>,
    cv: Condvar,
    /// Last [`TRACE_CAP`] executed batch compositions, in execution
    /// order (per shard, execution order == ticket order by
    /// construction).
    trace: Mutex<VecDeque<Vec<u64>>>,
}

struct Gate {
    next_ticket: u64,
    closed: bool,
}

/// Deterministic dynamic-batching front end over N sharded
/// [`ServeReplica`]s (one dispatcher thread per shard). See module docs
/// for the determinism argument.
pub struct ServeScheduler {
    shards: Arc<Vec<Shard>>,
    gate: Mutex<Gate>,
    d_in: usize,
    batch_window: usize,
    dispatchers: Vec<JoinHandle<()>>,
}

impl ServeScheduler {
    /// Build a scheduler over explicit replicas. All replicas must serve
    /// the same weight shape (they may — and usually should — share one
    /// `Arc`'d [`DeterministicServer`]); `batch_window` is the maximum
    /// requests per dispatched batch.
    pub fn new(replicas: Vec<ServeReplica>, batch_window: usize) -> Result<ServeScheduler> {
        if replicas.is_empty() {
            return Err(Error::config("serve scheduler: need at least one replica"));
        }
        if batch_window == 0 {
            return Err(Error::config("serve scheduler: batch window must be >= 1"));
        }
        let d_in = replicas[0].server().d_in();
        let d_out = replicas[0].server().d_out();
        for (i, r) in replicas.iter().enumerate() {
            if r.server().d_in() != d_in || r.server().d_out() != d_out {
                return Err(Error::config(format!(
                    "serve scheduler: replica {i} weights are {}x{}, replica 0 has {d_in}x{d_out}",
                    r.server().d_in(),
                    r.server().d_out()
                )));
            }
        }
        let shards: Arc<Vec<Shard>> = Arc::new(
            replicas
                .into_iter()
                .map(|replica| Shard {
                    replica,
                    q: Mutex::new(ShardQueue {
                        pending: VecDeque::new(),
                        cuts: VecDeque::new(),
                        closed: false,
                    }),
                    cv: Condvar::new(),
                    trace: Mutex::new(VecDeque::new()),
                })
                .collect(),
        );
        let mut dispatchers = Vec::with_capacity(shards.len());
        for i in 0..shards.len() {
            let sh = Arc::clone(&shards);
            dispatchers.push(
                std::thread::Builder::new()
                    .name(format!("repdl-serve-{i}"))
                    .spawn(move || dispatcher_loop(&sh[i], batch_window))
                    .expect("failed to spawn serve dispatcher"),
            );
        }
        Ok(ServeScheduler {
            shards,
            gate: Mutex::new(Gate { next_ticket: 0, closed: false }),
            d_in,
            batch_window,
            dispatchers,
        })
    }

    /// Convenience: `shards` replicas of one shared server, all
    /// dispatching on one shared pool handle (the common deployment —
    /// one packed weight copy, one worker pool, N batching lanes).
    pub fn sharded(
        server: Arc<DeterministicServer>,
        shards: usize,
        batch_window: usize,
        pool: PoolHandle,
    ) -> Result<ServeScheduler> {
        let replicas = (0..shards.max(1))
            .map(|_| ServeReplica::new(Arc::clone(&server), Arc::clone(&pool)))
            .collect();
        ServeScheduler::new(replicas, batch_window)
    }

    /// Number of replica shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Maximum requests per dispatched batch.
    pub fn batch_window(&self) -> usize {
        self.batch_window
    }

    /// Submit one request from any thread. Validates the shape *before*
    /// consuming a ticket (a malformed request can never shift another
    /// request's ticket or poison a batch), stamps the monotone ticket,
    /// and enqueues to shard `ticket % shards` under the same gate lock
    /// — so every shard queue stays ticket-ordered by construction.
    pub fn submit(&self, request: Tensor) -> Result<Pending> {
        check_request(&request, self.d_in)?;
        let (tx, rx) = channel();
        let mut gate = self.gate.lock().unwrap();
        if gate.closed {
            return Err(Error::runtime("serve scheduler is closed"));
        }
        let ticket = gate.next_ticket;
        gate.next_ticket += 1;
        let shard = &self.shards[(ticket % self.shards.len() as u64) as usize];
        {
            let mut q = shard.q.lock().unwrap();
            q.pending.push_back((ticket, request, tx));
            if q.pending.len() >= self.batch_window {
                shard.cv.notify_one();
            }
        }
        drop(gate);
        Ok(Pending { ticket, rx })
    }

    /// Force every ticket assigned so far out, in (possibly partial)
    /// batches. The flush point is a ticket count recorded as a batch
    /// *cut*: tickets below it never share a batch with tickets at or
    /// above it, so the resulting composition stays a pure function of
    /// the (submit, flush) event sequence — not of when dispatchers
    /// observe the barrier (cuts queue up rather than coalescing, so a
    /// sleeping dispatcher sees every boundary).
    pub fn flush(&self) {
        // hold the gate across cut publication (same gate → shard lock
        // order as submit): concurrent flushers serialise, so every
        // shard sees the same cut sequence — without this, two racing
        // flushes could publish their cuts in opposite orders on
        // different shards and the smaller cut would survive on some
        // shards but be suppressed on others
        let gate = self.gate.lock().unwrap();
        let upto = gate.next_ticket;
        for shard in self.shards.iter() {
            let mut q = shard.q.lock().unwrap();
            if upto > 0 && q.cuts.back().map_or(true, |&b| upto > b) {
                q.cuts.push_back(upto);
            }
            shard.cv.notify_one();
        }
        drop(gate);
    }

    /// Stop accepting new requests; already-submitted requests are
    /// drained (in windows, then one trailing partial batch per shard)
    /// and answered before the dispatchers exit.
    pub fn close(&self) {
        self.gate.lock().unwrap().closed = true;
        for shard in self.shards.iter() {
            shard.q.lock().unwrap().closed = true;
            shard.cv.notify_all();
        }
    }

    /// Submit a whole queue from the calling thread (ticket i == queue
    /// index i), flush, and return the outputs **in ticket order**.
    pub fn process_all(&self, queue: &[Tensor]) -> Result<Vec<Tensor>> {
        let pending = queue
            .iter()
            .map(|r| self.submit(r.clone()))
            .collect::<Result<Vec<Pending>>>()?;
        self.flush();
        pending.into_iter().map(|p| p.wait()).collect()
    }

    /// One concurrent client's share of a multi-client replay: caller
    /// `client` of `clients` submits the interleaved queue slice
    /// `{client, client + clients, …}`, flushes, and waits for its own
    /// responses. Returns `(queue index, output)` pairs in submission
    /// order. The CLI, the e5 scheduler bench and the conformance tests
    /// all drive concurrent clients through this one helper so the
    /// submit/flush/wait protocol lives in a single place.
    pub fn replay_slice(
        &self,
        queue: &[Tensor],
        client: usize,
        clients: usize,
    ) -> Result<Vec<(usize, Tensor)>> {
        let idx: Vec<usize> = (client..queue.len()).step_by(clients.max(1)).collect();
        let pending = idx
            .iter()
            .map(|&i| self.submit(queue[i].clone()))
            .collect::<Result<Vec<Pending>>>()?;
        self.flush();
        idx.into_iter()
            .zip(pending)
            .map(|(i, p)| p.wait().map(|o| (i, o)))
            .collect()
    }

    /// Executed batch compositions, sorted by first ticket (a canonical
    /// cross-shard order). Complete once every submitted request has
    /// been answered (trace entries are recorded before responses are
    /// sent) — e.g. after [`Self::process_all`] returns or after
    /// [`Self::close`] + drop. Bounded: only the most recent
    /// [`TRACE_CAP`] batches per shard are retained.
    pub fn trace(&self) -> Vec<BatchTrace> {
        let mut out = Vec::new();
        for (i, shard) in self.shards.iter().enumerate() {
            for tickets in shard.trace.lock().unwrap().iter() {
                out.push(BatchTrace { shard: i, tickets: tickets.clone() });
            }
        }
        out.sort_by_key(|b| b.tickets.first().copied().unwrap_or(u64::MAX));
        out
    }
}

impl Drop for ServeScheduler {
    fn drop(&mut self) {
        self.close();
        for h in self.dispatchers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Per-shard dispatcher: waits until the batching rule fires, takes
/// exactly the ticket-ordered prefix the rule names — the current flush
/// segment's next chunk, else a full window — executes it on the
/// shard's replica, and answers each request on its own channel. Taking
/// "exactly the rule's prefix" (never "whatever is there") is what
/// keeps batch composition independent of when this thread wakes.
fn dispatcher_loop(shard: &Shard, window: usize) {
    loop {
        let batch = {
            let mut q = shard.q.lock().unwrap();
            let take = loop {
                // drop flush boundaries that are already satisfied
                // (no pending ticket below them)
                while let Some(&b) = q.cuts.front() {
                    if q.pending.front().map_or(false, |(t, _, _)| *t < b) {
                        break;
                    }
                    q.cuts.pop_front();
                }
                if let Some(&b) = q.cuts.front() {
                    // flush segment first — BEFORE the full-window rule —
                    // so tickets submitted after the flush can never merge
                    // into a pre-flush batch no matter how late we wake
                    let n_before =
                        q.pending.iter().take_while(|(t, _, _)| *t < b).count();
                    break n_before.min(window); // ≥ 1: front is below b
                }
                if q.pending.len() >= window {
                    break window; // full window: take exactly `window`
                }
                if q.closed {
                    if q.pending.is_empty() {
                        return;
                    }
                    break q.pending.len(); // trailing partial batch (close)
                }
                q = shard.cv.wait(q).unwrap();
            };
            q.pending.drain(..take).collect::<Vec<_>>()
        };
        let mut tickets = Vec::with_capacity(batch.len());
        let mut inputs = Vec::with_capacity(batch.len());
        let mut senders = Vec::with_capacity(batch.len());
        for (t, x, tx) in batch {
            tickets.push(t);
            inputs.push(x);
            senders.push(tx);
        }
        {
            let mut trace = shard.trace.lock().unwrap();
            if trace.len() == TRACE_CAP {
                trace.pop_front();
            }
            trace.push_back(tickets);
        }
        match shard.replica.process(&inputs) {
            Ok(outs) => {
                for (tx, o) in senders.iter().zip(outs) {
                    let _ = tx.send(Ok(o)); // receiver may have given up
                }
            }
            Err(e) => {
                // shapes are validated at submit, so this is exceptional;
                // every request in the batch learns the same cause
                let msg = format!("serve batch failed: {e}");
                for tx in &senders {
                    let _ = tx.send(Err(Error::runtime(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, WorkerPool};

    fn queue(n: usize, d: usize, seed: u64) -> Vec<Tensor> {
        (0..n)
            .map(|i| crate::rng::uniform_tensor(&[d], -1.0, 1.0, seed + i as u64))
            .collect()
    }

    fn server(d_in: usize, d_out: usize, mb: usize) -> Arc<DeterministicServer> {
        let w = crate::rng::uniform_tensor(&[d_in, d_out], -0.3, 0.3, 7);
        Arc::new(DeterministicServer::new(w, mb).unwrap())
    }

    #[test]
    fn process_all_returns_ticket_ordered_exact_bits() {
        let srv = server(48, 6, 8);
        let q = queue(19, 48, 100);
        let sched =
            ServeScheduler::sharded(Arc::clone(&srv), 3, 4, WorkerPool::shared(2)).unwrap();
        let outs = sched.process_all(&q).unwrap();
        assert_eq!(outs.len(), q.len());
        for (r, o) in q.iter().zip(outs.iter()) {
            let want = matmul(&r.reshape(&[1, 48]).unwrap(), &srv.weights).unwrap();
            assert_eq!(o.data(), want.data(), "scheduler changed bits");
        }
    }

    #[test]
    fn shard_choice_is_ticket_mod_shards_and_batches_are_window_chunks() {
        let srv = server(16, 4, 8);
        let q = queue(11, 16, 50);
        let sched =
            ServeScheduler::sharded(Arc::clone(&srv), 2, 3, WorkerPool::shared(1)).unwrap();
        sched.process_all(&q).unwrap();
        let trace = sched.trace();
        // pure function: shard s gets tickets ≡ s (mod 2) chunked by 3
        let want = [
            (0usize, vec![0u64, 2, 4]),
            (1, vec![1, 3, 5]),
            (0, vec![6, 8, 10]),
            (1, vec![7, 9]), // trailing partial batch from the flush
        ];
        assert_eq!(trace.len(), want.len(), "trace: {trace:?}");
        for (got, (shard, tickets)) in trace.iter().zip(want.iter()) {
            assert_eq!(got.shard, *shard, "trace: {trace:?}");
            assert_eq!(&got.tickets, tickets, "trace: {trace:?}");
        }
    }

    #[test]
    fn flush_boundaries_segment_batches_independently_of_timing() {
        // the racy interleaving: flush, then MORE submissions that could
        // top the pending queue up to a full window before the
        // dispatcher wakes. The cut must still split the batch — run
        // repeatedly so dispatcher timing varies both ways.
        for round in 0..10u64 {
            let srv = server(16, 4, 8);
            let sched =
                ServeScheduler::sharded(Arc::clone(&srv), 1, 4, WorkerPool::shared(1))
                    .unwrap();
            let q = queue(7, 16, 300 + round);
            let mut pending = Vec::new();
            for r in &q[..3] {
                pending.push(sched.submit(r.clone()).unwrap());
            }
            sched.flush(); // cut at 3
            for r in &q[3..5] {
                pending.push(sched.submit(r.clone()).unwrap());
            }
            sched.flush(); // cut at 5
            for r in &q[5..7] {
                pending.push(sched.submit(r.clone()).unwrap());
            }
            sched.close(); // drains the tail
            for p in pending {
                p.wait().unwrap();
            }
            let got: Vec<Vec<u64>> =
                sched.trace().into_iter().map(|b| b.tickets).collect();
            assert_eq!(
                got,
                vec![vec![0, 1, 2], vec![3, 4], vec![5, 6]],
                "round {round}: flush cuts must segment batches"
            );
        }
    }

    #[test]
    fn submit_rejects_malformed_without_consuming_a_ticket() {
        let srv = server(16, 4, 8);
        let sched =
            ServeScheduler::sharded(Arc::clone(&srv), 2, 4, WorkerPool::shared(1)).unwrap();
        assert!(sched.submit(Tensor::zeros(&[15])).is_err());
        let good = queue(3, 16, 9);
        let outs = sched.process_all(&good).unwrap();
        assert_eq!(outs.len(), 3);
        // the malformed request consumed no ticket: tickets start at 0
        assert_eq!(sched.trace()[0].tickets[0], 0);
    }

    #[test]
    fn close_drains_then_rejects() {
        let srv = server(16, 4, 8);
        let sched =
            ServeScheduler::sharded(Arc::clone(&srv), 1, 4, WorkerPool::shared(1)).unwrap();
        let p = sched.submit(queue(1, 16, 1).pop().unwrap()).unwrap();
        sched.close();
        assert!(p.wait().is_ok(), "in-flight request must be answered");
        assert!(sched.submit(queue(1, 16, 2).pop().unwrap()).is_err());
    }

    #[test]
    fn mismatched_replicas_are_a_config_error() {
        let a = server(16, 4, 8);
        let b = server(8, 4, 8);
        let pool = WorkerPool::shared(1);
        let replicas = vec![
            ServeReplica::new(a, Arc::clone(&pool)),
            ServeReplica::new(b, pool),
        ];
        assert!(ServeScheduler::new(replicas, 4).is_err());
    }
}
