//! Deterministic dynamic-batching scheduler.
//!
//! The hazard (paper §2.2.2, and the serving-time analysis of
//! arXiv 2511.17826): batch composition in a production server depends
//! on *when* requests arrive relative to each other and to the
//! dispatcher — inherently racy state that conventional stacks let leak
//! into numerics via size-dispatched kernels. RepDL's kernels are batch
//! invariant, so any composition yields the same per-request bits; this
//! scheduler closes the remaining gap by making the composition itself
//! **trace-reproducible**:
//!
//! * **Tickets, not timestamps.** Every accepted request is stamped with
//!   a monotone ticket under one gate lock, and is enqueued to its shard
//!   *under that same lock*, so each shard's queue is always in ticket
//!   order. Arrival order is thereby *defined* as ticket order — the one
//!   racy event (who wins the gate) is captured in the ticket and never
//!   consulted again.
//! * **Pure batch composition.** Shard choice is `ticket % shards`;
//!   within a shard, every flush point is a *cut* segmenting the ticket
//!   sequence, and each segment is dispatched in consecutive
//!   `batch_window`-sized chunks (the segment tail, and the close tail,
//!   are the only partial batches). Composition is a pure function of
//!   (ticket sequence, shards, batch_window, flush points) — never of
//!   dispatcher wake-ups or thread timing: cuts are queued rather than
//!   coalesced and are honoured *before* the full-window rule, so a
//!   dispatcher that sleeps through a flush-then-more-submissions
//!   interleaving still emits exactly the segmented batches.
//! * **Bit-neutral sharding.** Which replica executes a batch cannot
//!   change output bits (pool-size and batch invariance, asserted by
//!   `tests/serve_scheduler.rs` across shard counts {1, 2, 4}), so
//!   `ticket % shards` is chosen for trace reproducibility, not
//!   numerics.
//! * **Responses in ticket order.** Each request carries its own
//!   response channel; [`ServeScheduler::process_all`] returns outputs
//!   indexed by ticket.
//!
//! Requests are validated at submit time (before a ticket is consumed),
//! so a malformed request errors out on its own — it can never poison a
//! batch or shift another request's ticket.
//!
//! On top of the batching core, the scheduler is an **admission + audit
//! subsystem** (DESIGN.md §8):
//!
//! * **Deterministic admission control.** With
//!   [`ServeConfig::max_queue_depth`] set, `submit` rejects by *ticket
//!   arithmetic*: the in-flight count is `next_ticket − flushed_upto`
//!   (tickets admitted since the latest flush cut) — never a wall-clock
//!   or drain-progress quantity — so the accept/reject ticket set is a
//!   pure function of the submit/flush event sequence: **for a fixed
//!   event sequence** it is identical across shard counts, pool sizes
//!   and cache on/off (concurrent clients racing the gate produce
//!   whatever event sequence the OS interleaving makes — single-
//!   submitter protocols like
//!   [`ServeScheduler::process_all_with_backpressure`] fix the sequence
//!   and are therefore fully reproducible, which is what
//!   `tests/serve_admission.rs` pins). Rejection is the typed
//!   [`Error::Rejected`] and consumes no ticket; capacity is released
//!   by the `flush` *event* (the logical clock), not by dispatchers
//!   draining (timing).
//! * **Ticket-addressed response log** ([`super::log::ResponseLog`],
//!   [`ServeConfig::log`]): every answered request records its request/
//!   response content hashes and batch id; [`ServeScheduler::replay`]
//!   re-executes a ticket range and verifies bit-equality.
//! * **Content-addressed memo cache** ([`super::cache::MemoCache`],
//!   [`ServeConfig::cache_capacity`]): consulted at *dispatch* time, so
//!   tickets, batches and the trace are identical with the cache on or
//!   off — and hits are bit-identical to recomputation because the
//!   kernels are batch invariant.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::cache::{CacheStats, MemoCache};
use super::journal::{Journal, JournalEvent, JournalReadout, JournalStats};
use super::lock_recover;
use super::log::{LogEntry, ResponseLog};
use super::replica::ServeReplica;
use super::session::SessionStats;
use super::tower::ModelTower;
use crate::coordinator::hashing::hash_tensor;
use crate::tensor::{PoolHandle, Tensor};
use crate::{Error, Result};

/// One executed batch, for trace-reproducibility checks: which shard ran
/// which tickets together.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchTrace {
    /// Replica index that executed the batch.
    pub shard: usize,
    /// Tickets batched together, in ticket order.
    pub tickets: Vec<u64>,
}

/// A submitted request's handle: resolves to the output row (or the
/// batch's error) when its batch has executed.
pub struct Pending {
    ticket: u64,
    rx: Receiver<Result<Tensor>>,
}

impl Pending {
    /// The monotone arrival ticket this request was stamped with.
    pub fn ticket(&self) -> u64 {
        self.ticket
    }

    /// Block until the batch containing this request has executed.
    pub fn wait(self) -> Result<Tensor> {
        self.rx.recv().unwrap_or_else(|_| {
            Err(Error::runtime("serve scheduler shut down before responding"))
        })
    }
}

struct ShardQueue {
    /// Ticket-ordered (enqueue happens under the ticket gate).
    pending: VecDeque<(u64, Tensor, Sender<Result<Tensor>>)>,
    /// Flush boundaries (strictly increasing ticket counts), kept as a
    /// queue — NOT coalesced into one max — so every flush point
    /// remains a batch cut even if the dispatcher sleeps through
    /// several flushes. Tickets below a boundary never share a batch
    /// with tickets at or above it.
    cuts: VecDeque<u64>,
    closed: bool,
}

/// Executed batches kept per shard for [`ServeScheduler::trace`]: a
/// bounded ring, so a long-lived server's dispatch hot path cannot grow
/// memory without bound (old entries fall off; conformance tests run
/// far below the cap and always see the complete trace).
const TRACE_CAP: usize = 4096;

struct Shard {
    replica: ServeReplica,
    q: Mutex<ShardQueue>,
    cv: Condvar,
    /// Last [`TRACE_CAP`] executed batch compositions, in execution
    /// order (per shard, execution order == ticket order by
    /// construction).
    trace: Mutex<VecDeque<Vec<u64>>>,
}

struct Gate {
    next_ticket: u64,
    /// Latest published flush cut — the logical clock that releases
    /// admission capacity. In-flight = `next_ticket − flushed_upto`.
    flushed_upto: u64,
    /// Depth-cap rejections so far (event-sequence-pure, see `submit`).
    rejected: u64,
    closed: bool,
}

/// Scheduler policy knobs beyond the replica set. `Default` reproduces
/// the PR 3 behaviour exactly: unbounded admission, no cache, no log.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Maximum requests per dispatched batch (≥ 1).
    pub batch_window: usize,
    /// Deterministic queue-depth cap: at most this many tickets may be
    /// in flight (`next_ticket − flushed_upto`) between flushes — a
    /// submit arriving with the count already *at* the cap is rejected,
    /// so the `depth + 1`-th consecutive unflushed submit is the first
    /// refused (≥ 1 when set; `None` = unbounded). Measured purely in
    /// ticket arithmetic against the flush logical clock, so overload
    /// behaviour is a function of the event sequence, never of timing.
    pub max_queue_depth: Option<usize>,
    /// Memo-cache capacity in responses (`0` = cache disabled).
    pub cache_capacity: usize,
    /// Record every answered request in the ticket-addressed
    /// [`ResponseLog`] (enables [`ServeScheduler::replay`]). The log
    /// retains request tensors and grows with traffic — an audit tool,
    /// not an always-on production default.
    pub log: bool,
    /// Durable event journal (see [`super::journal`]): submit, flush
    /// cut and truncation records are appended under the gate lock;
    /// response records are buffered and drained at sync barriers. A
    /// fresh journal gets this scheduler's `Ident` record at
    /// construction; a non-fresh one is expected to go through
    /// [`ServeScheduler::recover`] before any new submits.
    pub journal: Option<Arc<Journal>>,
    /// Logical-clock flush: publish a flush cut automatically whenever
    /// the ticket counter reaches a multiple of `K` (≥ 1 when set;
    /// `None` = only explicit [`ServeScheduler::flush`] calls cut).
    /// This is the deterministic replacement for a wall-clock batching
    /// timer, which stays banned by design: the cut points are a pure
    /// function of the submit count, so batch composition remains a
    /// function of the logical event sequence — and since the every-K
    /// cuts are journaled like any explicit flush, recovery replays
    /// them exactly. Gives latency control at low load (a lone request
    /// no longer waits for a full window) without admitting time into
    /// the event stream.
    pub flush_every: Option<u64>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            batch_window: 16,
            max_queue_depth: None,
            cache_capacity: 0,
            log: false,
            journal: None,
            flush_every: None,
        }
    }
}

/// Outcome of [`ServeScheduler::replay`] over a ticket range.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReplayReport {
    /// Logged entries re-executed.
    pub replayed: usize,
    /// Re-executions whose response hash differed from the log.
    pub response_mismatches: usize,
    /// Entries whose stored request no longer matches its own logged
    /// request hash (log corruption; such entries are not re-executed).
    pub request_mismatches: usize,
}

impl ReplayReport {
    /// True when every replayed entry verified bit-exactly.
    pub fn verified(&self) -> bool {
        self.response_mismatches == 0 && self.request_mismatches == 0
    }
}

/// Outcome of [`ServeScheduler::recover`]: what the journal held, what
/// was restored verbatim, and what had to be re-derived. Every field is
/// a logical count — two recoveries of the same journal produce
/// identical reports.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Bytes the torn-tail rule truncated when the journal was read.
    pub torn_bytes: u64,
    /// Submit records in the journal (= the restored ticket counter).
    pub submits: u64,
    /// Distinct non-zero flush cuts in the journal.
    pub flush_cuts: u64,
    /// Response records restored into the log without re-execution.
    pub responses_restored: u64,
    /// Response records that failed their consistency check (request
    /// hash or weights hash mismatch against the journaled submit) —
    /// counted, dropped, and re-executed instead.
    pub restore_mismatches: u64,
    /// Tickets journaled as failed batches: skipped, because their
    /// clients saw a typed error, not a response.
    pub failed_skipped: u64,
    /// Un-responded tickets re-executed through the non-ticketed replay
    /// path (bit-identical to the lost originals by batch invariance).
    pub re_executed: u64,
    /// Re-executions that errored (the tower rejected a journaled
    /// request — possible only if the journal predates a weights or
    /// validation change, which the `Ident` check normally refuses).
    pub re_execute_failures: u64,
    /// The restored ticket counter (`== submits`).
    pub next_ticket: u64,
    /// The restored admission flush clock (highest journaled cut).
    pub flushed_upto: u64,
    /// The restored response-log truncation watermark.
    pub watermark: u64,
}

impl RecoveryReport {
    /// True when every journaled ticket was accounted for cleanly:
    /// restored, re-executed, rotated below the watermark, or journaled
    /// as failed — with no consistency mismatches.
    pub fn consistent(&self) -> bool {
        self.restore_mismatches == 0 && self.re_execute_failures == 0
    }
}

/// Deterministic dynamic-batching front end over N sharded
/// [`ServeReplica`]s (one dispatcher thread per shard). See module docs
/// for the determinism argument.
pub struct ServeScheduler {
    shards: Arc<Vec<Shard>>,
    gate: Mutex<Gate>,
    /// The model every replica serves — kept for submit-time request
    /// validation (tower-specific: length for linear/MLP, length *and*
    /// token-id domain for the transformer) and for the scheduler's
    /// identity (`model_id`, `weights_hash`).
    tower: Arc<dyn ModelTower>,
    batch_window: usize,
    max_queue_depth: Option<usize>,
    flush_every: Option<u64>,
    cache: Option<Arc<MemoCache>>,
    log: Option<Arc<ResponseLog>>,
    journal: Option<Arc<Journal>>,
    dispatchers: Vec<JoinHandle<()>>,
}

impl ServeScheduler {
    /// Build a scheduler over explicit replicas with default policy
    /// (unbounded admission, no cache, no log). All replicas must serve
    /// the **same model** — same id, shape and weight bits (they may —
    /// and usually should — share one `Arc`'d [`ModelTower`]);
    /// `batch_window` is the maximum requests per dispatched batch.
    pub fn new(replicas: Vec<ServeReplica>, batch_window: usize) -> Result<ServeScheduler> {
        ServeScheduler::with_config(replicas, ServeConfig { batch_window, ..Default::default() })
    }

    /// Build a scheduler over explicit replicas with an explicit
    /// [`ServeConfig`] (admission cap, memo cache, response log).
    pub fn with_config(
        replicas: Vec<ServeReplica>,
        cfg: ServeConfig,
    ) -> Result<ServeScheduler> {
        if replicas.is_empty() {
            return Err(Error::config("serve scheduler: need at least one replica"));
        }
        let batch_window = cfg.batch_window;
        if batch_window == 0 {
            return Err(Error::config("serve scheduler: batch window must be >= 1"));
        }
        if cfg.max_queue_depth == Some(0) {
            return Err(Error::config(
                "serve scheduler: max queue depth must be >= 1 when set (0 rejects everything)",
            ));
        }
        if cfg.flush_every == Some(0) {
            return Err(Error::config(
                "serve scheduler: flush_every must be >= 1 when set (0 never divides a ticket)",
            ));
        }
        // every replica must serve the *same model*: identical id,
        // shape AND weight bits — a shard serving stale weights would
        // silently break bit-reproducibility across shard routing, so
        // the fingerprint check is structural, not advisory
        let tower = Arc::clone(replicas[0].tower());
        for (i, r) in replicas.iter().enumerate() {
            let t = r.tower();
            if t.model_id() != tower.model_id()
                || t.d_in() != tower.d_in()
                || t.d_out() != tower.d_out()
            {
                return Err(Error::config(format!(
                    "serve scheduler: replica {i} serves model '{}' ({}→{}), replica 0 \
                     serves '{}' ({}→{})",
                    t.model_id(),
                    t.d_in(),
                    t.d_out(),
                    tower.model_id(),
                    tower.d_in(),
                    tower.d_out()
                )));
            }
            if t.weights_hash() != tower.weights_hash() {
                return Err(Error::config(format!(
                    "serve scheduler: replica {i} weights differ from replica 0 \
                     (weights_hash {} vs {})",
                    t.weights_hash(),
                    tower.weights_hash()
                )));
            }
        }
        let shards: Arc<Vec<Shard>> = Arc::new(
            replicas
                .into_iter()
                .map(|replica| Shard {
                    replica,
                    q: Mutex::new(ShardQueue {
                        pending: VecDeque::new(),
                        cuts: VecDeque::new(),
                        closed: false,
                    }),
                    cv: Condvar::new(),
                    trace: Mutex::new(VecDeque::new()),
                })
                .collect(),
        );
        let cache = (cfg.cache_capacity > 0).then(|| Arc::new(MemoCache::new(cfg.cache_capacity)));
        let log = cfg.log.then(|| Arc::new(ResponseLog::new()));
        let journal = cfg.journal.clone();
        if let Some(j) = &journal {
            // a fresh journal opens with this scheduler's identity —
            // recovery refuses an event stream whose serving layout
            // (weights, shards, window) would not reproduce the run.
            // Written before dispatchers spawn, so the ident is always
            // record 0 and never races a buffered-response drain.
            if j.is_fresh() {
                j.append_event(&JournalEvent::Ident {
                    model_id: tower.model_id().to_string(),
                    weights_hash: tower.weights_hash().to_string(),
                    d_in: tower.d_in() as u64,
                    d_out: tower.d_out() as u64,
                    shards: shards.len() as u64,
                    batch_window: batch_window as u64,
                })?;
            }
        }
        let mut dispatchers = Vec::with_capacity(shards.len());
        for i in 0..shards.len() {
            let sh = Arc::clone(&shards);
            let cache = cache.clone();
            let log = log.clone();
            let journal = journal.clone();
            let weights_hash = tower.weights_hash().to_string();
            dispatchers.push(
                std::thread::Builder::new()
                    .name(format!("repdl-serve-{i}"))
                    .spawn(move || {
                        dispatcher_loop(
                            &sh[i],
                            batch_window,
                            cache.as_deref(),
                            log.as_deref(),
                            journal.as_deref(),
                            &weights_hash,
                        )
                    })
                    .expect("failed to spawn serve dispatcher"),
            );
        }
        Ok(ServeScheduler {
            shards,
            gate: Mutex::new(Gate {
                next_ticket: 0,
                flushed_upto: 0,
                rejected: 0,
                closed: false,
            }),
            tower,
            batch_window,
            max_queue_depth: cfg.max_queue_depth,
            flush_every: cfg.flush_every,
            cache,
            log,
            journal,
            dispatchers,
        })
    }

    /// Convenience: `shards` replicas of one shared model tower, all
    /// dispatching on one shared pool handle (the common deployment —
    /// one weight copy, one worker pool, N batching lanes). `Arc`s of
    /// concrete towers (`DeterministicServer`, `MlpTower`,
    /// `TransformerTower`) coerce into the `Arc<dyn ModelTower>`
    /// parameter.
    pub fn sharded(
        tower: Arc<dyn ModelTower>,
        shards: usize,
        batch_window: usize,
        pool: PoolHandle,
    ) -> Result<ServeScheduler> {
        ServeScheduler::sharded_with(
            tower,
            shards,
            pool,
            ServeConfig { batch_window, ..Default::default() },
        )
    }

    /// [`ServeScheduler::sharded`] with an explicit [`ServeConfig`].
    pub fn sharded_with(
        tower: Arc<dyn ModelTower>,
        shards: usize,
        pool: PoolHandle,
        cfg: ServeConfig,
    ) -> Result<ServeScheduler> {
        let replicas = (0..shards.max(1))
            .map(|_| ServeReplica::new(Arc::clone(&tower), Arc::clone(&pool)))
            .collect();
        ServeScheduler::with_config(replicas, cfg)
    }

    /// Number of replica shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// The id of the model every replica serves — the routing key a
    /// [`super::ModelRegistry`] files this scheduler under.
    pub fn model_id(&self) -> &str {
        self.tower.model_id()
    }

    /// The served model's parameter fingerprint. Embedded in every
    /// memo-cache key and response-log entry, so cached responses and
    /// audit records can never cross models.
    pub fn weights_hash(&self) -> &str {
        self.tower.weights_hash()
    }

    /// Request length in f32 elements.
    pub fn d_in(&self) -> usize {
        self.tower.d_in()
    }

    /// Response length in f32 elements.
    pub fn d_out(&self) -> usize {
        self.tower.d_out()
    }

    /// Maximum requests per dispatched batch.
    pub fn batch_window(&self) -> usize {
        self.batch_window
    }

    /// The admission cap, if one is configured.
    pub fn max_queue_depth(&self) -> Option<usize> {
        self.max_queue_depth
    }

    /// In-flight ticket count by the admission rule's own arithmetic:
    /// tickets admitted since the latest flush cut.
    pub fn in_flight(&self) -> u64 {
        let gate = lock_recover(&self.gate);
        gate.next_ticket - gate.flushed_upto
    }

    /// The next unassigned ticket — equivalently, the number of tickets
    /// this scheduler has admitted so far. A registry promotion records
    /// this as the swap **watermark**: every ticket below it was served
    /// by this scheduler's weights, every later submit routes to the
    /// successor (see [`super::ModelRegistry::promote`]).
    pub fn next_ticket(&self) -> u64 {
        lock_recover(&self.gate).next_ticket
    }

    /// Depth-cap rejections so far.
    pub fn rejected(&self) -> u64 {
        lock_recover(&self.gate).rejected
    }

    /// Memo-cache counters, when a cache is configured.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// KV session-store counters, when the served tower holds one (see
    /// [`super::TransformerTower::with_sessions`]).
    pub fn session_stats(&self) -> Option<SessionStats> {
        self.tower.session_stats()
    }

    /// The ticket-addressed response log, when logging is configured.
    pub fn log(&self) -> Option<&ResponseLog> {
        self.log.as_deref()
    }

    /// Submit one request from any thread. Validates the shape *before*
    /// consuming a ticket (a malformed request can never shift another
    /// request's ticket or poison a batch), applies the deterministic
    /// admission rule, stamps the monotone ticket, and enqueues to shard
    /// `ticket % shards` under the same gate lock — so every shard queue
    /// stays ticket-ordered by construction.
    ///
    /// Typed failure modes, both ticket-free: [`Error::Closed`] after
    /// [`ServeScheduler::close`] (a submit racing close gets this error,
    /// never a hang or a dropped channel), and [`Error::Rejected`] when
    /// the queue-depth cap fires. The cap counts **in-flight tickets**
    /// (`next_ticket − flushed_upto`): admitted tickets count against it
    /// until a `flush` event publishes a cut — dispatchers draining work
    /// does *not* release capacity, because drain progress is timing and
    /// admission must be a pure function of the event sequence. Clients
    /// under backpressure flush (an event) and retry — see
    /// [`ServeScheduler::process_all_with_backpressure`].
    pub fn submit(&self, request: Tensor) -> Result<Pending> {
        // tower-specific validation (length; token-id domain for the
        // transformer): anything accepted here must execute, so a bad
        // request can never poison a composed batch
        self.tower.validate_request(&request)?;
        let mut gate = lock_recover(&self.gate);
        if gate.closed {
            return Err(Error::Closed);
        }
        if let Some(depth) = self.max_queue_depth {
            if (gate.next_ticket - gate.flushed_upto) as usize >= depth {
                gate.rejected += 1;
                return Err(Error::Rejected { ticket: gate.next_ticket });
            }
        }
        // journal the submit under the gate, BEFORE the ticket is
        // consumed: record order is ticket order by construction, and a
        // fail-stop journal error refuses this submit ticket-free (the
        // typed `Error::Journal`) — so the accepted ticket sequence
        // stays a pure function of the event sequence even when the
        // disk dies mid-run
        if let Some(j) = &self.journal {
            j.append_submit(gate.next_ticket, &request)?;
        }
        // channel only after the gate checks: the hot rejection path
        // (submit → Rejected → flush → resubmit under overload) must not
        // churn the allocator on every refused attempt
        let (tx, rx) = channel();
        let ticket = gate.next_ticket;
        gate.next_ticket += 1;
        let shard = &self.shards[(ticket % self.shards.len() as u64) as usize];
        {
            let mut q = lock_recover(&shard.q);
            q.pending.push_back((ticket, request, tx));
            if q.pending.len() >= self.batch_window {
                shard.cv.notify_one();
            }
        }
        // the logical-clock flush: every K-th admitted ticket publishes
        // a cut, under the same gate hold and AFTER the enqueue — so
        // the cut never names a ticket its shard queue does not yet
        // hold, and the cut points are a pure function of the submit
        // count (journaled like any explicit flush, so recovery and
        // replay see the identical event sequence)
        if let Some(k) = self.flush_every {
            if gate.next_ticket % k == 0 {
                let upto = gate.next_ticket;
                self.publish_cut(&mut gate, upto);
            }
        }
        drop(gate);
        Ok(Pending { ticket, rx })
    }

    /// Publish a flush cut at `upto` while already holding the gate —
    /// the shared core of [`ServeScheduler::flush`] and the every-K
    /// logical-clock flush inside [`ServeScheduler::submit`]. Takes the
    /// shard queue locks under the gate (the crate-wide gate → shard.q
    /// lock order), so every shard sees the same cut sequence.
    fn publish_cut(&self, gate: &mut Gate, upto: u64) {
        // the flush event is the admission logical clock: everything
        // admitted so far is now cut into formed batches, so it no
        // longer counts against the queue-depth cap
        gate.flushed_upto = upto;
        // journal every flush event under the gate (recovery dedups):
        // cut publication cannot surface errors, so a fail-stop journal
        // error latches in the journal and refuses the NEXT submit
        // instead — loud, just one event late
        if let Some(j) = &self.journal {
            let _ = j.append_flush(upto);
        }
        for shard in self.shards.iter() {
            let mut q = lock_recover(&shard.q);
            if upto > 0 && q.cuts.back().map_or(true, |&b| upto > b) {
                q.cuts.push_back(upto);
            }
            shard.cv.notify_one();
        }
    }

    /// Force every ticket assigned so far out, in (possibly partial)
    /// batches. The flush point is a ticket count recorded as a batch
    /// *cut*: tickets below it never share a batch with tickets at or
    /// above it, so the resulting composition stays a pure function of
    /// the (submit, flush) event sequence — not of when dispatchers
    /// observe the barrier (cuts queue up rather than coalescing, so a
    /// sleeping dispatcher sees every boundary).
    pub fn flush(&self) {
        // hold the gate across cut publication (same gate → shard lock
        // order as submit): concurrent flushers serialise, so every
        // shard sees the same cut sequence — without this, two racing
        // flushes could publish their cuts in opposite orders on
        // different shards and the smaller cut would survive on some
        // shards but be suppressed on others
        let mut gate = lock_recover(&self.gate);
        let upto = gate.next_ticket;
        self.publish_cut(&mut gate, upto);
        drop(gate);
    }

    /// Stop accepting new requests; already-submitted requests are
    /// drained (in windows, then one trailing partial batch per shard)
    /// and answered before the dispatchers exit.
    pub fn close(&self) {
        lock_recover(&self.gate).closed = true;
        for shard in self.shards.iter() {
            lock_recover(&shard.q).closed = true;
            shard.cv.notify_all();
        }
    }

    /// Submit a whole queue from the calling thread (ticket i == queue
    /// index i), flush, and return the outputs **in ticket order**.
    pub fn process_all(&self, queue: &[Tensor]) -> Result<Vec<Tensor>> {
        let pending = queue
            .iter()
            .map(|r| self.submit(r.clone()))
            .collect::<Result<Vec<Pending>>>()?;
        self.flush();
        pending.into_iter().map(|p| p.wait()).collect()
    }

    /// The one backpressure loop both public protocols share: submit,
    /// and on every [`Error::Rejected`] publish a flush (the event that
    /// releases capacity) and resubmit. Cannot deadlock — `flush` never
    /// blocks — and terminates as soon as this thread's own flush leaves
    /// room at the gate. Returns the accepted handle and how many
    /// rejections were absorbed on the way in.
    fn submit_flushing_rejections(&self, request: &Tensor) -> Result<(Pending, u64)> {
        let mut rejections = 0u64;
        loop {
            match self.submit(request.clone()) {
                Ok(p) => return Ok((p, rejections)),
                Err(Error::Rejected { .. }) => {
                    rejections += 1;
                    self.flush();
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// [`ServeScheduler::submit`] that honours backpressure instead of
    /// surfacing it (see [`Self::submit_flushing_rejections`] for the
    /// loop); with no depth cap configured it is exactly `submit`.
    /// Other errors pass through.
    pub fn submit_with_backpressure(&self, request: &Tensor) -> Result<Pending> {
        self.submit_flushing_rejections(request).map(|(p, _)| p)
    }

    /// One concurrent client's share of a multi-client replay: caller
    /// `client` of `clients` submits the interleaved queue slice
    /// `{client, client + clients, …}` (flushing through any admission
    /// rejections — see [`ServeScheduler::submit_with_backpressure`]),
    /// flushes, and waits for its own responses. Returns
    /// `(queue index, output)` pairs in submission order. The CLI, the
    /// e5 scheduler bench and the conformance tests all drive concurrent
    /// clients through this one helper so the submit/flush/wait protocol
    /// lives in a single place.
    pub fn replay_slice(
        &self,
        queue: &[Tensor],
        client: usize,
        clients: usize,
    ) -> Result<Vec<(usize, Tensor)>> {
        let idx: Vec<usize> = (client..queue.len()).step_by(clients.max(1)).collect();
        let pending = idx
            .iter()
            .map(|&i| self.submit_with_backpressure(&queue[i]))
            .collect::<Result<Vec<Pending>>>()?;
        self.flush();
        idx.into_iter()
            .zip(pending)
            .map(|(i, p)| p.wait().map(|o| (i, o)))
            .collect()
    }

    /// [`ServeScheduler::process_all`] under an admission cap: the
    /// client-driven backpressure protocol. Submits in queue order
    /// through the shared [`Self::submit_flushing_rejections`] loop
    /// (concurrent submitters racing the released capacity just loop
    /// again, never surface a spurious error). Returns the outputs in
    /// submission order plus how many rejections were absorbed. When the
    /// caller is the only submitter, the whole accept/reject/flush event
    /// sequence — and therefore the rejection count, every ticket and
    /// every batch — is a pure function of
    /// `(queue.len(), max_queue_depth, batch_window, shards)`.
    pub fn process_all_with_backpressure(
        &self,
        queue: &[Tensor],
    ) -> Result<(Vec<Tensor>, u64)> {
        let mut rejections = 0u64;
        let mut pending = Vec::with_capacity(queue.len());
        for r in queue {
            let (p, rej) = self.submit_flushing_rejections(r)?;
            rejections += rej;
            pending.push(p);
        }
        self.flush();
        let outs = pending.into_iter().map(|p| p.wait()).collect::<Result<Vec<Tensor>>>()?;
        Ok((outs, rejections))
    }

    /// Re-execute the logged requests with tickets in `tickets` and
    /// verify each against its logged response hash, bit for bit. Every
    /// entry runs as a **singleton batch** on the shard that originally
    /// served it (`ticket % shards`) — valid because the towers are
    /// batch invariant, so the original batch-mates cannot have
    /// influenced the logged bits. Errors when logging is disabled, and
    /// with the typed [`Error::Truncated`] when the range reaches below
    /// the log's rotation watermark (a rotated-away audit must never
    /// read as a passing one). A corrupt entry — stored request no
    /// longer matching its own hash, or a `weights_hash` that is not
    /// this scheduler's model — is counted and skipped rather than
    /// executed.
    pub fn replay(&self, tickets: Range<u64>) -> Result<ReplayReport> {
        let log = self.log.as_deref().ok_or_else(|| {
            Error::config("serve replay: response log is disabled (ServeConfig::log)")
        })?;
        let weights_hash = self.tower.weights_hash();
        let mut report = ReplayReport::default();
        // watermark check + range read are one lock acquisition, so a
        // concurrent truncate_log_below can never rotate part of the
        // range away between them (which would shrink the audit into a
        // silent pass)
        for e in log.range_checked(tickets)? {
            if hash_tensor(&e.request) != e.request_hash || e.weights_hash != weights_hash {
                report.request_mismatches += 1;
                continue;
            }
            let shard =
                &self.shards[(e.ticket % self.shards.len() as u64) as usize];
            // deliberately the NON-ticketed path: replay always runs the
            // full recompute, so it audits the fallback numerics every
            // session hit must match — and never mutates session state
            let outs = shard.replica.process(std::slice::from_ref(&e.request))?;
            report.replayed += 1;
            if hash_tensor(&outs[0]) != e.response_hash {
                report.response_mismatches += 1;
            }
        }
        Ok(report)
    }

    /// Rotate the response log: drop retained entries below `watermark`
    /// (see [`ResponseLog::truncate_below`]). Returns the number of
    /// entries dropped; errors when logging is disabled. Replays that
    /// reach below the watermark afterwards get the typed
    /// [`Error::Truncated`].
    ///
    /// A watermark beyond `next_ticket` is a config error (pure ticket
    /// arithmetic — deterministic): it names tickets that do not exist
    /// yet, which is always an operator mistake (e.g. an entry count
    /// passed as a ticket) and would pre-drop their future audit
    /// records. A watermark ≤ `next_ticket` can still overtake a
    /// formed-but-unexecuted batch — drain progress is timing, which
    /// admission logic must not consult — so that case is allowed and
    /// accounted instead: [`ResponseLog::late_drops`] counts any audit
    /// record lost to the race.
    pub fn truncate_log_below(&self, watermark: u64) -> Result<usize> {
        let log = self.log.as_deref().ok_or_else(|| {
            Error::config("serve truncate: response log is disabled (ServeConfig::log)")
        })?;
        let next_ticket = lock_recover(&self.gate).next_ticket;
        if watermark > next_ticket {
            return Err(Error::config(format!(
                "serve truncate: watermark {watermark} exceeds next ticket {next_ticket}"
            )));
        }
        let dropped = log.truncate_below(watermark);
        // journal the rotation AFTER it takes effect in memory, so a
        // journal that records the watermark implies the log really
        // rotated (recovery applies the max journaled watermark)
        if let Some(j) = &self.journal {
            j.append_truncate(watermark)?;
        }
        Ok(dropped)
    }

    /// Executed batch compositions, sorted by first ticket (a canonical
    /// cross-shard order). Complete once every submitted request has
    /// been answered (trace entries are recorded before responses are
    /// sent) — e.g. after [`Self::process_all`] returns or after
    /// [`Self::close`] + drop. Bounded: only the most recent
    /// [`TRACE_CAP`] batches per shard are retained.
    pub fn trace(&self) -> Vec<BatchTrace> {
        let mut out = Vec::new();
        for (i, shard) in self.shards.iter().enumerate() {
            for tickets in lock_recover(&shard.trace).iter() {
                out.push(BatchTrace { shard: i, tickets: tickets.clone() });
            }
        }
        out.sort_by_key(|b| b.tickets.first().copied().unwrap_or(u64::MAX));
        out
    }

    /// The attached journal, if one is configured.
    pub fn journal(&self) -> Option<&Journal> {
        self.journal.as_deref()
    }

    /// Journal health counters, when a journal is configured.
    pub fn journal_stats(&self) -> Option<JournalStats> {
        self.journal.as_ref().map(|j| j.stats())
    }

    /// Sync barrier on the attached journal: drain buffered response
    /// records (in ticket order) and fsync. Deterministic journal bytes
    /// are guaranteed when this runs at quiescence — after every
    /// submitted request has been answered — which is when the
    /// scheduler itself calls it (on drop, after the dispatchers have
    /// joined). A no-op without a journal.
    pub fn sync_journal(&self) -> Result<()> {
        match &self.journal {
            Some(j) => j.sync(),
            None => Ok(()),
        }
    }

    /// Rebuild this freshly-built scheduler's serving state from a
    /// journal readout so the recovered process is **bit-identical to
    /// an uninterrupted one** (`tests/serve_recovery.rs` pins it cell
    /// by cell):
    ///
    /// 1. verify the journal's `Ident` against this scheduler (same
    ///    model, weights, shards, batch window — a different layout
    ///    would deterministically produce a *different* run);
    /// 2. restore the ticket counter, admission flush clock and
    ///    truncation watermark from the event stream;
    /// 3. restore journaled response records into the [`ResponseLog`]
    ///    (consistency-checked against their own journaled submits —
    ///    mismatches are counted and re-derived, never trusted);
    /// 4. re-execute every un-responded ticket at or above the
    ///    watermark through the **non-ticketed** replay path, batch
    ///    ids recomputed closed-form from the journaled submit/cut
    ///    sequence (the dispatcher rule is a pure function, so the
    ///    recomputed ids equal the ones the lost batches would have
    ///    had). Tickets journaled as failed are skipped: their clients
    ///    saw a typed error, and recovery must not invent a response
    ///    the original run never sent.
    ///
    /// Requires `ServeConfig::log` (recovery rebuilds the log) and a
    /// scheduler that has issued no tickets yet. If a journal is
    /// attached, re-derived responses are buffered to it and synced, so
    /// a recovered journal file converges to the uninterrupted run's
    /// bytes. A journal with degraded-mode drops has holes and is
    /// refused — its submit record stream can no longer prove what ran.
    pub fn recover(&self, readout: &JournalReadout) -> Result<RecoveryReport> {
        let log = self.log.as_deref().ok_or_else(|| {
            Error::config("serve recover: response log is disabled (ServeConfig::log)")
        })?;
        let mut report = RecoveryReport { torn_bytes: readout.torn_bytes, ..Default::default() };
        let mut submits: BTreeMap<u64, Tensor> = BTreeMap::new();
        let mut cuts: Vec<u64> = Vec::new();
        let mut responses: BTreeMap<u64, (u64, String, String, String)> = BTreeMap::new();
        let mut failed: BTreeSet<u64> = BTreeSet::new();
        let mut ident_seen = false;
        let mut watermark = 0u64;
        for ev in &readout.events {
            match ev {
                JournalEvent::Ident {
                    model_id,
                    weights_hash,
                    d_in,
                    d_out,
                    shards,
                    batch_window,
                } => {
                    let t = &self.tower;
                    if model_id != t.model_id()
                        || weights_hash != t.weights_hash()
                        || *d_in != t.d_in() as u64
                        || *d_out != t.d_out() as u64
                    {
                        return Err(Error::journal(format!(
                            "recover: journal is for model '{model_id}' (weights {weights_hash}, \
                             {d_in}→{d_out}), this scheduler serves '{}' (weights {}, {}→{})",
                            t.model_id(),
                            t.weights_hash(),
                            t.d_in(),
                            t.d_out()
                        )));
                    }
                    if *shards != self.shards.len() as u64
                        || *batch_window != self.batch_window as u64
                    {
                        return Err(Error::journal(format!(
                            "recover: journal ran {shards} shards / window {batch_window}, this \
                             scheduler has {} / {} — batch composition would differ",
                            self.shards.len(),
                            self.batch_window
                        )));
                    }
                    ident_seen = true;
                }
                JournalEvent::Submit { ticket, request } => {
                    submits.entry(*ticket).or_insert_with(|| request.clone());
                }
                JournalEvent::FlushCut { upto } => cuts.push(*upto),
                JournalEvent::Truncate { watermark: w } => watermark = watermark.max(*w),
                JournalEvent::Response {
                    ticket,
                    batch_id,
                    request_hash,
                    response_hash,
                    weights_hash,
                } => {
                    responses.entry(*ticket).or_insert_with(|| {
                        (*batch_id, request_hash.clone(), response_hash.clone(), weights_hash.clone())
                    });
                }
                JournalEvent::Failed { ticket } => {
                    failed.insert(*ticket);
                }
            }
        }
        if !ident_seen {
            return Err(Error::journal("recover: journal has no ident record"));
        }
        // submit tickets must be exactly 0..n: the gate assigns them
        // contiguously, so a gap means records were dropped (a
        // degraded-to-memory run) and the stream no longer proves what ran
        let n = submits.len() as u64;
        let contiguous = submits.keys().next().map_or(true, |&f| f == 0)
            && submits.keys().next_back().map_or(true, |&l| l + 1 == n);
        if !contiguous {
            return Err(Error::journal(
                "recover: journal submit tickets are not contiguous from 0 \
                 (degraded-to-memory drops?)",
            ));
        }
        cuts.sort_unstable();
        cuts.dedup();
        cuts.retain(|&c| c > 0);
        let flushed_upto = cuts.last().copied().unwrap_or(0);
        {
            let mut gate = lock_recover(&self.gate);
            if gate.next_ticket != 0 {
                return Err(Error::journal(
                    "recover: scheduler has already issued tickets — recovery needs a \
                     freshly built one",
                ));
            }
            gate.next_ticket = n;
            // faithful restore: submits after the last journaled cut
            // are re-executed below but were never *flushed*, so they
            // still count as in-flight for admission until the next
            // flush event
            gate.flushed_upto = flushed_upto;
        }
        let weights_hash = self.tower.weights_hash().to_string();
        // 3. restore journaled responses (skipping rotated tickets)
        let mut restored: BTreeSet<u64> = BTreeSet::new();
        for (&t, (batch_id, req_h, resp_h, w_h)) in &responses {
            if t < watermark {
                continue; // rotated away — must not be resurrected
            }
            let consistent = submits
                .get(&t)
                .map_or(false, |req| hash_tensor(req) == *req_h && *w_h == weights_hash);
            if !consistent {
                report.restore_mismatches += 1;
                continue; // re-derived below (if a submit exists)
            }
            log.record(LogEntry {
                ticket: t,
                request: submits[&t].clone(),
                request_hash: req_h.clone(),
                response_hash: resp_h.clone(),
                batch_id: *batch_id,
                weights_hash: w_h.clone(),
            });
            restored.insert(t);
        }
        log.truncate_below(watermark);
        // 4. re-execute the un-responded remainder, batch ids recomputed
        // closed-form from the journaled event sequence
        let shards_n = self.shards.len() as u64;
        let mut batch_ids: BTreeMap<u64, u64> = BTreeMap::new();
        for s in 0..shards_n {
            let shard_tickets: Vec<u64> =
                submits.keys().copied().filter(|t| t % shards_n == s).collect();
            batch_ids.extend(recovered_batch_ids(&shard_tickets, &cuts, self.batch_window));
        }
        for (&t, req) in &submits {
            if t < watermark || restored.contains(&t) {
                continue;
            }
            if failed.contains(&t) {
                report.failed_skipped += 1;
                continue;
            }
            let shard = &self.shards[(t % shards_n) as usize];
            // the NON-ticketed path, as replay: a singleton full
            // recompute is bit-identical to the lost batched original
            // (batch invariance) and never mutates session state
            match shard.replica.process(std::slice::from_ref(req)) {
                Ok(outs) => {
                    let entry = LogEntry {
                        ticket: t,
                        request_hash: hash_tensor(req),
                        response_hash: hash_tensor(&outs[0]),
                        request: req.clone(),
                        batch_id: batch_ids.get(&t).copied().unwrap_or(t),
                        weights_hash: weights_hash.clone(),
                    };
                    if let Some(j) = &self.journal {
                        j.buffer_response(&entry);
                    }
                    log.record(entry);
                    report.re_executed += 1;
                }
                Err(_) => {
                    if let Some(j) = &self.journal {
                        j.buffer_failed(t);
                    }
                    report.re_execute_failures += 1;
                }
            }
        }
        report.responses_restored = restored.len() as u64;
        report.submits = n;
        report.flush_cuts = cuts.len() as u64;
        report.next_ticket = n;
        report.flushed_upto = flushed_upto;
        report.watermark = watermark;
        // make the re-derived records durable before serving resumes
        self.sync_journal()?;
        Ok(report)
    }
}

/// Batch ids for one shard's ticket sequence, recomputed from the
/// journaled submit/cut stream by simulating the dispatcher's batching
/// rule (cut segments first, chunked by `window`; then full windows;
/// then the close-drain tail). The rule is a pure function of the event
/// sequence — that is the scheduler's core determinism claim — so these
/// ids equal the ones the crashed run's lost batches carried.
fn recovered_batch_ids(
    shard_tickets: &[u64],
    cuts: &[u64],
    window: usize,
) -> BTreeMap<u64, u64> {
    let mut ids = BTreeMap::new();
    let mut i = 0usize;
    let mut chunk = |i: &mut usize, seg_len: usize| {
        let take = seg_len.min(window);
        let head = shard_tickets[*i];
        for &t in &shard_tickets[*i..*i + take] {
            ids.insert(t, head);
        }
        *i += take;
    };
    for &c in cuts {
        while i < shard_tickets.len() && shard_tickets[i] < c {
            let seg = shard_tickets[i..].iter().take_while(|&&t| t < c).count();
            chunk(&mut i, seg);
        }
    }
    while i < shard_tickets.len() {
        let rest = shard_tickets.len() - i;
        chunk(&mut i, rest);
    }
    ids
}

impl Drop for ServeScheduler {
    fn drop(&mut self) {
        self.close();
        for h in self.dispatchers.drain(..) {
            let _ = h.join();
        }
        // dispatchers have quiesced: every response record is buffered,
        // so this final sync drains them in ticket order — the step
        // that makes two identical runs' journal files byte-identical
        if let Some(j) = &self.journal {
            let _ = j.sync();
        }
    }
}

/// Per-shard dispatcher: waits until the batching rule fires, takes
/// exactly the ticket-ordered prefix the rule names — the current flush
/// segment's next chunk, else a full window — executes it on the
/// shard's replica, and answers each request on its own channel. Taking
/// "exactly the rule's prefix" (never "whatever is there") is what
/// keeps batch composition independent of when this thread wakes.
///
/// Cache and log sit entirely inside the batch-execution step, *after*
/// composition is fixed: hits skip the replica arithmetic and misses
/// fill the cache under their tickets, but tickets, batches and the
/// trace are byte-for-byte the same as a cache-off run.
fn dispatcher_loop(
    shard: &Shard,
    window: usize,
    cache: Option<&MemoCache>,
    log: Option<&ResponseLog>,
    journal: Option<&Journal>,
    weights_hash: &str,
) {
    loop {
        let batch = {
            let mut q = lock_recover(&shard.q);
            let take = loop {
                // drop flush boundaries that are already satisfied
                // (no pending ticket below them)
                while let Some(&b) = q.cuts.front() {
                    if q.pending.front().map_or(false, |(t, _, _)| *t < b) {
                        break;
                    }
                    q.cuts.pop_front();
                }
                if let Some(&b) = q.cuts.front() {
                    // flush segment first — BEFORE the full-window rule —
                    // so tickets submitted after the flush can never merge
                    // into a pre-flush batch no matter how late we wake
                    let n_before =
                        q.pending.iter().take_while(|(t, _, _)| *t < b).count();
                    break n_before.min(window); // ≥ 1: front is below b
                }
                if q.pending.len() >= window {
                    break window; // full window: take exactly `window`
                }
                if q.closed {
                    if q.pending.is_empty() {
                        return;
                    }
                    break q.pending.len(); // trailing partial batch (close)
                }
                q = shard.cv.wait(q).unwrap_or_else(|e| e.into_inner());
            };
            q.pending.drain(..take).collect::<Vec<_>>()
        };
        let mut tickets = Vec::with_capacity(batch.len());
        let mut inputs = Vec::with_capacity(batch.len());
        let mut senders = Vec::with_capacity(batch.len());
        for (t, x, tx) in batch {
            tickets.push(t);
            inputs.push(x);
            senders.push(tx);
        }
        {
            let mut trace = lock_recover(&shard.trace);
            if trace.len() == TRACE_CAP {
                trace.pop_front();
            }
            trace.push_back(tickets.clone());
        }
        execute_batch(shard, cache, log, journal, weights_hash, &tickets, &inputs, &senders);
    }
}

/// Run one composed batch on the replica through the **ticketed** path
/// (session-holding towers key their KV stores by the requests'
/// admission tickets; other towers fall through to plain
/// `forward_batch`), behind a panic shield: a tower that panics
/// mid-batch must become a typed error for *this* batch's clients —
/// never unwind the dispatcher thread, which would poison the shard's
/// queue lock and strand every later request on that shard.
/// `AssertUnwindSafe` is sound here for the same reason
/// [`super::lock_recover`] is: every `&`-reachable structure the
/// closure touches (session store, memo cache, worker pool) mutates
/// only under its own lock in update-atomic steps, so an unwind cannot
/// leave a half-written invariant behind.
fn run_replica(replica: &ServeReplica, inputs: &[Tensor], tickets: &[u64]) -> Result<Vec<Tensor>> {
    catch_unwind(AssertUnwindSafe(|| replica.process_ticketed(inputs, tickets))).unwrap_or_else(
        |p| {
            let what = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            Err(Error::runtime(format!("serve replica panicked: {what}")))
        },
    )
}

/// Execute one already-composed batch: resolve cache hits, run the
/// misses on the replica, fill cache/log, answer every request.
fn execute_batch(
    shard: &Shard,
    cache: Option<&MemoCache>,
    log: Option<&ResponseLog>,
    journal: Option<&Journal>,
    weights_hash: &str,
    tickets: &[u64],
    inputs: &[Tensor],
    senders: &[Sender<Result<Tensor>>],
) {
    let n = tickets.len();
    // content addresses, computed once per batch, shared by cache + log
    let hashes: Option<Vec<String>> = (cache.is_some() || log.is_some() || journal.is_some())
        .then(|| inputs.iter().map(hash_tensor).collect());
    // cache keys embed the model's weights_hash: a response memo can
    // never cross models — even a cache shared by several schedulers
    // (or two towers differing in one weight bit) keeps disjoint key
    // spaces per model (DESIGN.md §9)
    let cache_key = |h: &str| format!("{weights_hash}:{h}");
    let mut outs: Vec<Option<Tensor>> = vec![None; n];
    let mut miss: Vec<usize> = Vec::with_capacity(n);
    if let (Some(c), Some(hs)) = (cache, hashes.as_ref()) {
        for i in 0..n {
            match c.lookup(&cache_key(&hs[i])) {
                Some(hit) => outs[i] = Some(hit),
                None => miss.push(i),
            }
        }
    } else {
        miss.extend(0..n);
    }
    // batch invariance makes serving only the misses bit-neutral: each
    // row is an independent fixed-order reduction, so removing the hit
    // rows cannot change any miss row's bits
    let computed: Result<Vec<Tensor>> = if miss.is_empty() {
        Ok(Vec::new())
    } else if miss.len() == n {
        run_replica(&shard.replica, inputs, tickets) // no per-request clones on this path
    } else {
        let miss_inputs: Vec<Tensor> = miss.iter().map(|&i| inputs[i].clone()).collect();
        let miss_tickets: Vec<u64> = miss.iter().map(|&i| tickets[i]).collect();
        run_replica(&shard.replica, &miss_inputs, &miss_tickets)
    };
    match computed {
        Ok(mouts) => {
            for (&i, o) in miss.iter().zip(mouts) {
                if let (Some(c), Some(hs)) = (cache, hashes.as_ref()) {
                    c.insert(&cache_key(&hs[i]), tickets[i], &o);
                }
                outs[i] = Some(o);
            }
            let batch_id = tickets[0];
            for i in 0..n {
                let o = outs[i].take().expect("every batch slot resolved");
                if log.is_some() || journal.is_some() {
                    let hs = hashes.as_ref().expect("hashes computed when log/journal on");
                    let entry = LogEntry {
                        ticket: tickets[i],
                        request: inputs[i].clone(),
                        request_hash: hs[i].clone(),
                        response_hash: hash_tensor(&o),
                        batch_id,
                        weights_hash: weights_hash.to_string(),
                    };
                    // buffered, not appended: dispatchers race, so
                    // response records only reach the stream at sync
                    // barriers, drained in ticket order
                    if let Some(j) = journal {
                        j.buffer_response(&entry);
                    }
                    if let Some(l) = log {
                        l.record(entry);
                    }
                }
                let _ = senders[i].send(Ok(o)); // receiver may have given up
            }
        }
        Err(e) => {
            // shapes are validated at submit, so this is exceptional;
            // every request in the batch — cache hits included, matching
            // the cache-off outcome — learns the same cause, and nothing
            // is logged. The journal records the failure per ticket so
            // recovery never re-executes (and answers) a request whose
            // client already saw a typed error.
            if let Some(j) = journal {
                for &t in tickets {
                    j.buffer_failed(t);
                }
            }
            let msg = format!("serve batch failed: {e}");
            for tx in senders {
                let _ = tx.send(Err(Error::runtime(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serve::DeterministicServer;
    use crate::tensor::{matmul, WorkerPool};

    fn queue(n: usize, d: usize, seed: u64) -> Vec<Tensor> {
        (0..n)
            .map(|i| crate::rng::uniform_tensor(&[d], -1.0, 1.0, seed + i as u64))
            .collect()
    }

    fn server(d_in: usize, d_out: usize, mb: usize) -> Arc<DeterministicServer> {
        let w = crate::rng::uniform_tensor(&[d_in, d_out], -0.3, 0.3, 7);
        Arc::new(DeterministicServer::new(w, mb).unwrap())
    }

    #[test]
    fn process_all_returns_ticket_ordered_exact_bits() {
        let srv = server(48, 6, 8);
        let q = queue(19, 48, 100);
        let sched =
            ServeScheduler::sharded(Arc::clone(&srv), 3, 4, WorkerPool::shared(2)).unwrap();
        let outs = sched.process_all(&q).unwrap();
        assert_eq!(outs.len(), q.len());
        for (r, o) in q.iter().zip(outs.iter()) {
            let want = matmul(&r.reshape(&[1, 48]).unwrap(), &srv.weights).unwrap();
            assert_eq!(o.data(), want.data(), "scheduler changed bits");
        }
    }

    #[test]
    fn shard_choice_is_ticket_mod_shards_and_batches_are_window_chunks() {
        let srv = server(16, 4, 8);
        let q = queue(11, 16, 50);
        let sched =
            ServeScheduler::sharded(Arc::clone(&srv), 2, 3, WorkerPool::shared(1)).unwrap();
        sched.process_all(&q).unwrap();
        let trace = sched.trace();
        // pure function: shard s gets tickets ≡ s (mod 2) chunked by 3
        let want = [
            (0usize, vec![0u64, 2, 4]),
            (1, vec![1, 3, 5]),
            (0, vec![6, 8, 10]),
            (1, vec![7, 9]), // trailing partial batch from the flush
        ];
        assert_eq!(trace.len(), want.len(), "trace: {trace:?}");
        for (got, (shard, tickets)) in trace.iter().zip(want.iter()) {
            assert_eq!(got.shard, *shard, "trace: {trace:?}");
            assert_eq!(&got.tickets, tickets, "trace: {trace:?}");
        }
    }

    #[test]
    fn flush_boundaries_segment_batches_independently_of_timing() {
        // the racy interleaving: flush, then MORE submissions that could
        // top the pending queue up to a full window before the
        // dispatcher wakes. The cut must still split the batch — run
        // repeatedly so dispatcher timing varies both ways.
        for round in 0..10u64 {
            let srv = server(16, 4, 8);
            let sched =
                ServeScheduler::sharded(Arc::clone(&srv), 1, 4, WorkerPool::shared(1))
                    .unwrap();
            let q = queue(7, 16, 300 + round);
            let mut pending = Vec::new();
            for r in &q[..3] {
                pending.push(sched.submit(r.clone()).unwrap());
            }
            sched.flush(); // cut at 3
            for r in &q[3..5] {
                pending.push(sched.submit(r.clone()).unwrap());
            }
            sched.flush(); // cut at 5
            for r in &q[5..7] {
                pending.push(sched.submit(r.clone()).unwrap());
            }
            sched.close(); // drains the tail
            for p in pending {
                p.wait().unwrap();
            }
            let got: Vec<Vec<u64>> =
                sched.trace().into_iter().map(|b| b.tickets).collect();
            assert_eq!(
                got,
                vec![vec![0, 1, 2], vec![3, 4], vec![5, 6]],
                "round {round}: flush cuts must segment batches"
            );
        }
    }

    #[test]
    fn every_k_logical_flush_cuts_without_explicit_flush_calls() {
        // flush_every = 3 under a window far too large to fire on its
        // own: the cut points must be a pure function of the submit
        // count, so the batch trace is exactly the K-chunking
        let srv = server(16, 4, 8);
        let sched = ServeScheduler::sharded_with(
            Arc::clone(&srv),
            1,
            WorkerPool::shared(1),
            ServeConfig { batch_window: 100, flush_every: Some(3), ..Default::default() },
        )
        .unwrap();
        let q = queue(7, 16, 400);
        let pending: Vec<_> = q.iter().map(|r| sched.submit(r.clone()).unwrap()).collect();
        sched.close(); // drains the un-cut tail (ticket 6)
        for p in pending {
            p.wait().unwrap();
        }
        let got: Vec<Vec<u64>> = sched.trace().into_iter().map(|b| b.tickets).collect();
        assert_eq!(got, vec![vec![0, 1, 2], vec![3, 4, 5], vec![6]]);
        // and the every-K cut releases admission capacity like any flush
        assert_eq!(sched.in_flight(), 1, "tickets past the last cut stay in flight");
        // flush_every = 0 is a config error, not an infinite loop
        assert!(ServeScheduler::sharded_with(
            srv,
            1,
            WorkerPool::shared(1),
            ServeConfig { flush_every: Some(0), ..Default::default() },
        )
        .is_err());
    }

    #[test]
    fn submit_rejects_malformed_without_consuming_a_ticket() {
        let srv = server(16, 4, 8);
        let sched =
            ServeScheduler::sharded(Arc::clone(&srv), 2, 4, WorkerPool::shared(1)).unwrap();
        assert!(sched.submit(Tensor::zeros(&[15])).is_err());
        let good = queue(3, 16, 9);
        let outs = sched.process_all(&good).unwrap();
        assert_eq!(outs.len(), 3);
        // the malformed request consumed no ticket: tickets start at 0
        assert_eq!(sched.trace()[0].tickets[0], 0);
    }

    #[test]
    fn close_drains_then_rejects() {
        let srv = server(16, 4, 8);
        let sched =
            ServeScheduler::sharded(Arc::clone(&srv), 1, 4, WorkerPool::shared(1)).unwrap();
        let p = sched.submit(queue(1, 16, 1).pop().unwrap()).unwrap();
        sched.close();
        assert!(p.wait().is_ok(), "in-flight request must be answered");
        assert!(sched.submit(queue(1, 16, 2).pop().unwrap()).is_err());
    }

    fn cfg(window: usize) -> ServeConfig {
        ServeConfig { batch_window: window, ..Default::default() }
    }

    #[test]
    fn admission_rejects_by_ticket_arithmetic_and_flush_releases() {
        let srv = server(16, 4, 8);
        let sched = ServeScheduler::sharded_with(
            Arc::clone(&srv),
            2,
            WorkerPool::shared(1),
            ServeConfig { max_queue_depth: Some(3), ..cfg(4) },
        )
        .unwrap();
        let q = queue(8, 16, 11);
        let mut pending = Vec::new();
        for r in &q[..3] {
            pending.push(sched.submit(r.clone()).unwrap());
        }
        assert_eq!(sched.in_flight(), 3);
        // the cap fires on the 4th submit with the typed error carrying
        // the next unassigned ticket — and consumes no ticket
        match sched.submit(q[3].clone()) {
            Err(Error::Rejected { ticket }) => assert_eq!(ticket, 3),
            Ok(_) => panic!("want Rejected, got Ok"),
            Err(other) => panic!("want Rejected, got {other:?}"),
        }
        assert_eq!(sched.rejected(), 1);
        assert_eq!(sched.in_flight(), 3, "rejection must not consume a ticket");
        // flush is the event that releases capacity…
        sched.flush();
        assert_eq!(sched.in_flight(), 0);
        for r in &q[3..6] {
            pending.push(sched.submit(r.clone()).unwrap());
        }
        // …and draining is NOT: wait for everything, capacity unchanged
        sched.flush();
        for p in pending {
            p.wait().unwrap();
        }
        // accepted tickets are exactly 0..6 — the rejected submit left
        // no hole in the sequence
        let seen: Vec<u64> =
            sched.trace().into_iter().flat_map(|b| b.tickets).collect();
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..6).collect::<Vec<u64>>());
    }

    #[test]
    fn backpressure_protocol_is_deterministic() {
        let srv = server(16, 4, 8);
        let q = queue(10, 16, 77);
        let run = || {
            let sched = ServeScheduler::sharded_with(
                Arc::clone(&srv),
                1,
                WorkerPool::shared(1),
                ServeConfig { max_queue_depth: Some(4), ..cfg(2) },
            )
            .unwrap();
            let (outs, rejections) = sched.process_all_with_backpressure(&q).unwrap();
            let trace: Vec<Vec<u64>> =
                sched.trace().into_iter().map(|b| b.tickets).collect();
            (outs, rejections, trace)
        };
        let (o1, r1, t1) = run();
        let (o2, r2, t2) = run();
        // 10 submits against depth 4: rejected (and flushed) at index
        // 4 and 8 — a pure function of (len, depth), same every run
        assert_eq!(r1, 2);
        assert_eq!(r1, r2);
        assert_eq!(t1, t2, "event sequence fixed ⇒ identical batches");
        for (a, b) in o1.iter().zip(o2.iter()) {
            assert!(a.bit_eq(b));
        }
        for (r, o) in q.iter().zip(o1.iter()) {
            let want = matmul(&r.reshape(&[1, 16]).unwrap(), &srv.weights).unwrap();
            assert_eq!(o.data(), want.data());
        }
    }

    #[test]
    fn close_then_submit_is_typed_closed_never_a_hang() {
        let srv = server(16, 4, 8);
        let sched =
            ServeScheduler::sharded(Arc::clone(&srv), 2, 4, WorkerPool::shared(1)).unwrap();
        sched.close();
        match sched.submit(queue(1, 16, 1).pop().unwrap()) {
            Err(Error::Closed) => {}
            Ok(_) => panic!("want Closed, got Ok"),
            Err(other) => panic!("want Closed, got {other:?}"),
        }
        // a depth-capped scheduler reports Closed too (close dominates)
        let capped = ServeScheduler::sharded_with(
            Arc::clone(&srv),
            1,
            WorkerPool::shared(1),
            ServeConfig { max_queue_depth: Some(1), ..cfg(4) },
        )
        .unwrap();
        capped.close();
        assert!(matches!(
            capped.submit(queue(1, 16, 2).pop().unwrap()),
            Err(Error::Closed)
        ));
    }

    #[test]
    fn cache_serves_bit_identical_and_keeps_trace_identical() {
        let srv = server(32, 4, 8);
        let base = queue(6, 32, 40);
        let cached = ServeScheduler::sharded_with(
            Arc::clone(&srv),
            2,
            WorkerPool::shared(1),
            ServeConfig { cache_capacity: 16, ..cfg(4) },
        )
        .unwrap();
        let plain =
            ServeScheduler::sharded(Arc::clone(&srv), 2, 4, WorkerPool::shared(1)).unwrap();
        // first replay fills the memo, the second is answered from it —
        // bits and batch composition must match the cache-off scheduler
        // on both replays
        for replay in 0..2 {
            let oc = cached.process_all(&base).unwrap();
            let op = plain.process_all(&base).unwrap();
            for (i, (a, b)) in oc.iter().zip(op.iter()).enumerate() {
                assert!(a.bit_eq(b), "replay {replay} request {i}: cache changed bits");
            }
        }
        assert_eq!(
            cached.trace(),
            plain.trace(),
            "cache must not change tickets or batch composition"
        );
        let s = cached.cache_stats().unwrap();
        assert_eq!(s.misses, 6, "first replay computes");
        assert_eq!(s.hits, 6, "second replay is served from the memo");
        assert!(plain.cache_stats().is_none());
    }

    #[test]
    fn log_records_every_answer_and_replay_verifies() {
        let srv = server(24, 4, 8);
        let q = queue(9, 24, 90);
        let sched = ServeScheduler::sharded_with(
            Arc::clone(&srv),
            3,
            WorkerPool::shared(2),
            ServeConfig { log: true, ..cfg(4) },
        )
        .unwrap();
        let outs = sched.process_all(&q).unwrap();
        let log = sched.log().unwrap();
        assert_eq!(log.len(), 9);
        for (t, (r, o)) in q.iter().zip(outs.iter()).enumerate() {
            let e = log.get(t as u64).unwrap();
            assert_eq!(e.request_hash, crate::coordinator::hashing::hash_tensor(r));
            assert_eq!(e.response_hash, crate::coordinator::hashing::hash_tensor(o));
            // batch id = first ticket of the batch that served it: with 3
            // shards and window 4, every batch is one flush segment, so
            // the batch id is the request's shard index (tickets 0,1,2
            // lead the three shard batches)
            assert_eq!(e.batch_id, (t % 3) as u64);
        }
        let rep = sched.replay(0..9).unwrap();
        assert_eq!(rep.replayed, 9);
        assert!(rep.verified());
        // a sub-range replays only its slice
        assert_eq!(sched.replay(3..5).unwrap().replayed, 2);
        // logging off → replay is a config error
        let plain =
            ServeScheduler::sharded(Arc::clone(&srv), 1, 4, WorkerPool::shared(1)).unwrap();
        assert!(plain.replay(0..1).is_err());
    }

    #[test]
    fn log_rotation_keeps_upper_replays_and_types_lower_ones() {
        let srv = server(16, 4, 8);
        let q = queue(10, 16, 130);
        let sched = ServeScheduler::sharded_with(
            Arc::clone(&srv),
            2,
            WorkerPool::shared(1),
            ServeConfig { log: true, ..cfg(4) },
        )
        .unwrap();
        sched.process_all(&q).unwrap();
        assert_eq!(sched.log().unwrap().len(), 10);
        // a watermark past the issued tickets is a config error (it
        // would pre-drop future audit records), checked by pure ticket
        // arithmetic: 10 tickets issued, so 10 is the highest legal cut
        assert!(sched.truncate_log_below(11).is_err());
        // rotate away tickets 0..6
        assert_eq!(sched.truncate_log_below(6).unwrap(), 6);
        // above the watermark: replay still verifies bit-exactly
        let rep = sched.replay(6..10).unwrap();
        assert_eq!(rep.replayed, 4);
        assert!(rep.verified());
        // reaching below the watermark: typed error, never "0 verified"
        match sched.replay(0..10) {
            Err(Error::Truncated { ticket, watermark }) => {
                assert_eq!((ticket, watermark), (0, 6));
            }
            Ok(r) => panic!("want Truncated, got Ok({r:?})"),
            Err(other) => panic!("want Truncated, got {other:?}"),
        }
        assert!(matches!(sched.replay(5..7), Err(Error::Truncated { .. })));
        // rotation on a log-less scheduler is a config error
        let plain =
            ServeScheduler::sharded(srv, 1, 4, WorkerPool::shared(1)).unwrap();
        assert!(plain.truncate_log_below(1).is_err());
    }

    #[test]
    fn cache_keys_embed_the_weights_hash() {
        let srv = server(16, 4, 8);
        let q = queue(3, 16, 60);
        let sched = ServeScheduler::sharded_with(
            Arc::clone(&srv),
            1,
            WorkerPool::shared(1),
            ServeConfig { cache_capacity: 8, ..cfg(4) },
        )
        .unwrap();
        sched.process_all(&q).unwrap();
        let held = sched.cache.as_ref().unwrap().held_keys_by_ticket();
        assert_eq!(held.len(), 3);
        let prefix = format!("{}:", sched.weights_hash());
        for (t, key) in &held {
            assert!(
                key.starts_with(&prefix),
                "cache key for ticket {t} lacks the weights_hash prefix: {key}"
            );
            assert_eq!(
                key[prefix.len()..],
                crate::coordinator::hashing::hash_tensor(&q[*t as usize]),
                "key suffix must be the request's content address"
            );
        }
    }

    #[test]
    fn scheduler_exposes_model_identity() {
        let srv = server(16, 4, 8);
        let sched =
            ServeScheduler::sharded(Arc::clone(&srv), 2, 4, WorkerPool::shared(1)).unwrap();
        assert_eq!(sched.model_id(), "linear");
        assert_eq!(sched.weights_hash(), srv.weights_hash());
        assert_eq!((sched.d_in(), sched.d_out()), (16, 4));
    }

    #[test]
    fn depth_zero_is_a_config_error() {
        let srv = server(16, 4, 8);
        assert!(ServeScheduler::sharded_with(
            srv,
            1,
            WorkerPool::shared(1),
            ServeConfig { max_queue_depth: Some(0), ..cfg(4) },
        )
        .is_err());
    }

    #[test]
    fn mismatched_replicas_are_a_config_error() {
        let a = server(16, 4, 8);
        let b = server(8, 4, 8);
        let pool = WorkerPool::shared(1);
        let replicas = vec![
            ServeReplica::new(a, Arc::clone(&pool)),
            ServeReplica::new(b, pool),
        ];
        assert!(ServeScheduler::new(replicas, 4).is_err());
    }
}
