//! Durable, crash-consistent serve journal (DESIGN.md §11).
//!
//! The serve stack is deterministic end to end but lives in memory: a
//! crash loses the response log, the ticket watermark and every
//! in-flight batch, so the paper's cross-environment reproducibility
//! claim stops at the process boundary. This module extends it across
//! crashes and machines: an **append-only, length-prefixed, per-record
//! SHA-256-framed binary journal** of the *logical* serve events —
//! submit, flush cut, truncation watermark, response record — written
//! at ticket boundaries, so the journal bytes are a pure function of
//! the submit/flush event sequence. Two identical runs produce
//! **byte-identical** journal files: no wall clock, no pids, no thread
//! ids ever reach the encoder.
//!
//! **Record framing.** A journal file is a 12-byte header (8-byte magic
//! + `u32` LE format version) followed by records. Each record is
//! `u32 LE payload_len ‖ payload ‖ SHA-256(payload)` (32 bytes). The
//! per-record digest makes torn tails *detectable*: a crash mid-append
//! leaves a final record whose length field, payload or digest is
//! incomplete, and [`read_journal`] stops at the last intact record
//! boundary, physically truncates the tail, and reports the dropped
//! bytes — never a silent misparse, never an error for an honest crash.
//! A file whose *header* is wrong (not a journal at all) is the typed
//! [`Error::Journal`] instead: tearing can only happen at the tail.
//!
//! **Why journal bytes are deterministic.** Submit, flush-cut, truncate
//! and ident records are appended synchronously under the scheduler's
//! gate lock — the same lock that makes ticket order *the* arrival
//! order — so their file order is the event order by construction.
//! Response records are produced by racing dispatcher threads, so they
//! are **buffered** (keyed by ticket) and only drained to the file, in
//! ticket order, at explicit barriers: [`Journal::sync`], which the
//! scheduler calls on drop after its dispatchers have quiesced. A crash
//! loses only buffered response records — exactly the records recovery
//! can re-derive bit-identically by re-executing the journaled submits.
//!
//! **Degradation policy.** Journal I/O can fail (disk full, volume
//! yanked). [`JournalPolicy::FailStop`] fails the submit that hit the
//! error (typed [`Error::Journal`], no ticket consumed — ticket
//! arithmetic keeps the accepted set pure) and every submit after it;
//! [`JournalPolicy::DegradeToMemory`] disables the writer on first
//! error and keeps serving, counting every record it can no longer
//! persist in [`JournalStats::drops`] — degraded, but never silently.
//!
//! Fault injection for all of the above lives in [`super::faults`]:
//! a deterministic [`super::faults::FaultPlan`] keyed only by logical
//! counters, threaded through the [`JournalWriter`] trait (production
//! code pays one vtable indirection and nothing else).

use super::lock_recover;
use crate::coordinator::hashing::hex;
use crate::sha256::Sha256;
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::fmt;
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

/// File magic: identifies a RepDL serve journal (8 bytes).
pub const JOURNAL_MAGIC: [u8; 8] = *b"REPDLJNL";
/// Journal format version (bumped on any framing/payload change).
pub const JOURNAL_VERSION: u32 = 1;
/// Header length: magic + LE version.
const HEADER_LEN: usize = 12;
/// Digest length appended to every record.
const DIGEST_LEN: usize = 32;

const TAG_IDENT: u8 = 0;
const TAG_SUBMIT: u8 = 1;
const TAG_FLUSH_CUT: u8 = 2;
const TAG_TRUNCATE: u8 = 3;
const TAG_RESPONSE: u8 = 4;
const TAG_FAILED: u8 = 5;

/// The canonical 12-byte journal header.
fn header() -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(&JOURNAL_MAGIC);
    h[8..].copy_from_slice(&JOURNAL_VERSION.to_le_bytes());
    h
}

/// One logical serve event, as journaled. The encoding of every variant
/// is a pure function of its fields — no timestamps, no process state —
/// which is what makes journal files byte-comparable across runs and
/// machines.
#[derive(Clone, Debug, PartialEq)]
pub enum JournalEvent {
    /// Written once, as the first record of a fresh journal: the serving
    /// configuration the event stream is only meaningful under.
    /// Recovery refuses a scheduler whose identity differs — replaying
    /// tickets onto different weights or a different shard/window
    /// layout would silently produce a *different* deterministic run.
    Ident {
        /// Serving model id.
        model_id: String,
        /// Parameter fingerprint of the serving tower.
        weights_hash: String,
        /// Request length in f32 elements.
        d_in: u64,
        /// Response length in f32 elements.
        d_out: u64,
        /// Shard count (batch composition depends on it).
        shards: u64,
        /// Batch window (batch composition depends on it).
        batch_window: u64,
    },
    /// One accepted request: its ticket and the full request tensor
    /// (shape-framed f32 bit patterns — exact, not a decimal rendering).
    Submit {
        /// The monotone arrival ticket.
        ticket: u64,
        /// The request itself, retained so recovery can re-execute it.
        request: Tensor,
    },
    /// A flush event: every ticket below `upto` is cut into formed
    /// batches (the admission logical clock).
    FlushCut {
        /// The flush point (a ticket count).
        upto: u64,
    },
    /// A response-log rotation: entries below `watermark` were dropped.
    Truncate {
        /// The rotation watermark (a ticket count).
        watermark: u64,
    },
    /// One answered request: content hashes only (the request bytes are
    /// already journaled by its `Submit` record).
    Response {
        /// The answered ticket.
        ticket: u64,
        /// First ticket of the batch that served it.
        batch_id: u64,
        /// Content address of the request (`hash_tensor`).
        request_hash: String,
        /// Content address of the response.
        response_hash: String,
        /// Parameter fingerprint of the model that answered.
        weights_hash: String,
    },
    /// A ticket whose batch failed (tower error or panic-shield catch):
    /// the client saw a typed error, so recovery must neither stall on
    /// this ticket nor re-execute it into a response the original run
    /// never sent.
    Failed {
        /// The failed ticket.
        ticket: u64,
    },
}

// ---------------------------------------------------------------------
// encoding
// ---------------------------------------------------------------------

pub(super) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(super) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(super) fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

pub(super) fn put_tensor(buf: &mut Vec<u8>, t: &Tensor) {
    put_u64(buf, t.dims().len() as u64);
    for &d in t.dims() {
        put_u64(buf, d as u64);
    }
    for &v in t.data() {
        put_u32(buf, v.to_bits());
    }
}

/// Encode a submit record's payload without cloning the tensor (the
/// submit hot path appends under the gate lock).
pub(super) fn encode_submit(ticket: u64, request: &Tensor) -> Vec<u8> {
    let mut buf = Vec::with_capacity(32 + request.numel() * 4);
    buf.push(TAG_SUBMIT);
    put_u64(&mut buf, ticket);
    put_tensor(&mut buf, request);
    buf
}

/// Encode one event's record payload (tag byte + fields, all LE).
pub fn encode_event(ev: &JournalEvent) -> Vec<u8> {
    let mut buf = Vec::new();
    match ev {
        JournalEvent::Ident { model_id, weights_hash, d_in, d_out, shards, batch_window } => {
            buf.push(TAG_IDENT);
            put_str(&mut buf, model_id);
            put_str(&mut buf, weights_hash);
            put_u64(&mut buf, *d_in);
            put_u64(&mut buf, *d_out);
            put_u64(&mut buf, *shards);
            put_u64(&mut buf, *batch_window);
        }
        JournalEvent::Submit { ticket, request } => return encode_submit(*ticket, request),
        JournalEvent::FlushCut { upto } => {
            buf.push(TAG_FLUSH_CUT);
            put_u64(&mut buf, *upto);
        }
        JournalEvent::Truncate { watermark } => {
            buf.push(TAG_TRUNCATE);
            put_u64(&mut buf, *watermark);
        }
        JournalEvent::Response { ticket, batch_id, request_hash, response_hash, weights_hash } => {
            buf.push(TAG_RESPONSE);
            put_u64(&mut buf, *ticket);
            put_u64(&mut buf, *batch_id);
            put_str(&mut buf, request_hash);
            put_str(&mut buf, response_hash);
            put_str(&mut buf, weights_hash);
        }
        JournalEvent::Failed { ticket } => {
            buf.push(TAG_FAILED);
            put_u64(&mut buf, *ticket);
        }
    }
    buf
}

/// Bounds-check a payload length against the `u32` frame length field.
/// Factored out of [`frame`] so the >4 GiB refusal is unit-testable on
/// a synthetic length without allocating a >4 GiB payload.
fn frame_len(len: usize) -> Result<u32> {
    u32::try_from(len).map_err(|_| {
        Error::journal(format!(
            "record payload of {len} bytes exceeds the u32 frame length field"
        ))
    })
}

/// Frame one payload into a full journal record:
/// `u32 LE len ‖ payload ‖ SHA-256(payload)`.
///
/// A payload longer than `u32::MAX` bytes is the typed
/// [`Error::Journal`]: the length used to be written as
/// `payload.len() as u32`, which wraps silently and frames a record
/// whose digest can never verify against its truncated length —
/// corrupting the journal at append time instead of refusing loudly.
pub fn frame(payload: &[u8]) -> Result<Vec<u8>> {
    let len = frame_len(payload.len())?;
    let mut rec = Vec::with_capacity(4 + payload.len() + DIGEST_LEN);
    put_u32(&mut rec, len);
    rec.extend_from_slice(payload);
    let mut h = Sha256::new();
    h.update(payload);
    rec.extend_from_slice(&h.finalize());
    Ok(rec)
}

// ---------------------------------------------------------------------
// decoding
// ---------------------------------------------------------------------

/// Bounds-checked reader over one record payload. Shared by journal
/// recovery and the wire codec ([`super::wire`]), so it is hardened
/// for **untrusted** input: every length prefix is bounded against the
/// bytes actually remaining *before* it sizes an allocation, and no
/// path panics — a hostile peer can claim any length it likes, and the
/// remaining buffer is the only honest upper bound.
pub(super) struct Cursor<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    pub(super) fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, off: 0 }
    }
    pub(super) fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.off < n {
            return Err(Error::journal(format!(
                "record payload truncated: wanted {n} bytes at offset {} of {}",
                self.off,
                self.b.len()
            )));
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }
    pub(super) fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    pub(super) fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    pub(super) fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
    /// Read a `u64` length prefix and bound it against the remaining
    /// buffer before it is ever used to size an allocation.
    pub(super) fn len_prefix(&mut self) -> Result<usize> {
        let n = self.u64()?;
        let remaining = self.b.len() - self.off;
        match usize::try_from(n) {
            Ok(n) if n <= remaining => Ok(n),
            _ => Err(Error::journal(format!(
                "length prefix {n} exceeds the {remaining} bytes remaining"
            ))),
        }
    }
    pub(super) fn str(&mut self) -> Result<String> {
        let n = self.len_prefix()?;
        let s = self.bytes(n)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| Error::journal("record payload holds a non-UTF-8 string"))
    }
    pub(super) fn tensor(&mut self) -> Result<Tensor> {
        let rank = self.u64()?;
        if rank > 8 {
            return Err(Error::journal(format!("journaled tensor rank {rank} exceeds 8")));
        }
        let rank = rank as usize;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            let d = usize::try_from(self.u64()?)
                .map_err(|_| Error::journal("journaled tensor dim exceeds usize"))?;
            dims.push(d);
        }
        let numel = dims
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| Error::journal("journaled tensor dims overflow"))?;
        // bound before allocating: the payload must actually hold the data
        if numel.checked_mul(4).map_or(true, |b| self.b.len() - self.off < b) {
            return Err(Error::journal(format!(
                "journaled tensor claims {numel} elements but the payload is short"
            )));
        }
        let mut data = Vec::with_capacity(numel);
        for _ in 0..numel {
            data.push(f32::from_bits(self.u32()?));
        }
        Tensor::from_vec(&dims, data)
            .map_err(|e| Error::journal(format!("journaled tensor is malformed: {e}")))
    }
    pub(super) fn done(&self) -> Result<()> {
        if self.off != self.b.len() {
            return Err(Error::journal(format!(
                "record payload has {} trailing bytes",
                self.b.len() - self.off
            )));
        }
        Ok(())
    }
}

/// Decode one hash-verified record payload. Failing here means an
/// encoder/decoder version mismatch or a software bug — the framing
/// digest already rules out bit rot and torn writes — so it is the
/// typed [`Error::Journal`], never a silent skip.
pub fn decode_event(payload: &[u8]) -> Result<JournalEvent> {
    let mut c = Cursor::new(payload);
    let ev = match c.u8()? {
        TAG_IDENT => JournalEvent::Ident {
            model_id: c.str()?,
            weights_hash: c.str()?,
            d_in: c.u64()?,
            d_out: c.u64()?,
            shards: c.u64()?,
            batch_window: c.u64()?,
        },
        TAG_SUBMIT => JournalEvent::Submit { ticket: c.u64()?, request: c.tensor()? },
        TAG_FLUSH_CUT => JournalEvent::FlushCut { upto: c.u64()? },
        TAG_TRUNCATE => JournalEvent::Truncate { watermark: c.u64()? },
        TAG_RESPONSE => JournalEvent::Response {
            ticket: c.u64()?,
            batch_id: c.u64()?,
            request_hash: c.str()?,
            response_hash: c.str()?,
            weights_hash: c.str()?,
        },
        TAG_FAILED => JournalEvent::Failed { ticket: c.u64()? },
        tag => return Err(Error::journal(format!("unknown record tag {tag}"))),
    };
    c.done()?;
    Ok(ev)
}

/// Scan a headerless record stream: returns the hash-verified payload
/// slices and the byte length of the intact prefix. Scanning stops at
/// the first frame-level defect — short length field, short payload,
/// digest mismatch — which is by definition the torn tail: records are
/// appended atomically with respect to their own digest, so anything
/// after the first bad frame is unrecoverable.
pub fn scan_payloads(bytes: &[u8]) -> (Vec<&[u8]>, usize) {
    let mut out = Vec::new();
    let mut off = 0usize;
    loop {
        if bytes.len() - off < 4 {
            break;
        }
        let len =
            u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
                as usize;
        // checked: on 32-bit targets `len + DIGEST_LEN` can wrap for a
        // hostile length field, turning a torn tail into a misparse
        let need = match len.checked_add(DIGEST_LEN) {
            Some(n) => n,
            None => break,
        };
        if bytes.len() - off - 4 < need {
            break;
        }
        let payload = &bytes[off + 4..off + 4 + len];
        let digest = &bytes[off + 4 + len..off + 4 + len + DIGEST_LEN];
        let mut h = Sha256::new();
        h.update(payload);
        if h.finalize().as_slice() != digest {
            break;
        }
        out.push(payload);
        off += 4 + len + DIGEST_LEN;
    }
    (out, off)
}

/// Parse a headerless record stream into events plus the intact prefix
/// length (see [`scan_payloads`] for the torn-tail rule).
pub fn parse_records(bytes: &[u8]) -> Result<(Vec<JournalEvent>, usize)> {
    let (payloads, valid) = scan_payloads(bytes);
    let events = payloads.iter().map(|p| decode_event(p)).collect::<Result<Vec<_>>>()?;
    Ok((events, valid))
}

/// Everything recovery needs from a journal file, after torn-tail
/// repair.
#[derive(Debug)]
pub struct JournalReadout {
    /// The decoded event stream, in file (= logical) order.
    pub events: Vec<JournalEvent>,
    /// Bytes truncated from the tail (0 for a cleanly closed journal).
    pub torn_bytes: u64,
}

impl JournalReadout {
    /// True when the file carried an incomplete trailing record.
    pub fn truncated_tail(&self) -> bool {
        self.torn_bytes > 0
    }
}

/// Open a journal file, verify its header, decode its records, and
/// **physically truncate** any torn tail so a subsequent
/// [`Journal::open_append`] continues from an intact record boundary.
///
/// Torn tails (the expected crash signature) are repaired and reported;
/// a wrong magic or version — the file is not a journal, or is from an
/// incompatible build — is the typed [`Error::Journal`]: truncating
/// someone else's file would be data loss, not recovery. A torn
/// *header* (crash before the very first record) is repaired to an
/// empty stream only when the partial bytes prefix-match the canonical
/// header.
pub fn read_journal(path: &Path) -> Result<JournalReadout> {
    let mut bytes = Vec::new();
    File::open(path)?.read_to_end(&mut bytes)?;
    let hdr = header();
    if bytes.len() < HEADER_LEN {
        if bytes.is_empty() {
            return Ok(JournalReadout { events: Vec::new(), torn_bytes: 0 });
        }
        // A non-empty sub-header file is only repairable when it is
        // provably *our* torn header: the full 8-byte magic must be
        // present and every byte must prefix-match the canonical
        // header. Anything shorter or different is refused — a
        // `set_len(0)` on a file we cannot verify would be data loss
        // masquerading as recovery (mirrors `open_append`'s alien-file
        // refusal).
        if bytes.len() < JOURNAL_MAGIC.len() || bytes[..] != hdr[..bytes.len()] {
            return Err(Error::journal(format!(
                "{} is not a serve journal (bad magic)",
                path.display()
            )));
        }
        let torn = bytes.len() as u64;
        OpenOptions::new().write(true).open(path)?.set_len(0)?;
        return Ok(JournalReadout { events: Vec::new(), torn_bytes: torn });
    }
    if bytes[..8] != JOURNAL_MAGIC {
        return Err(Error::journal(format!(
            "{} is not a serve journal (bad magic)",
            path.display()
        )));
    }
    let version = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
    if version != JOURNAL_VERSION {
        return Err(Error::journal(format!(
            "{}: journal format version {version}, this build reads {JOURNAL_VERSION}",
            path.display()
        )));
    }
    let (events, valid) = parse_records(&bytes[HEADER_LEN..])?;
    let torn = (bytes.len() - HEADER_LEN - valid) as u64;
    if torn > 0 {
        OpenOptions::new()
            .write(true)
            .open(path)?
            .set_len((HEADER_LEN + valid) as u64)?;
    }
    Ok(JournalReadout { events, torn_bytes: torn })
}

// ---------------------------------------------------------------------
// writers
// ---------------------------------------------------------------------

/// The journal's byte sink. Production uses [`FileJournalWriter`]; the
/// fault harness ([`super::faults::FaultyWriter`]) wraps any writer to
/// inject failures at deterministic record counts — this one vtable
/// indirection is the entire cost the production path pays for
/// injectability.
pub trait JournalWriter: Send {
    /// Append one complete framed record. Must be a single logical
    /// write: the torn-tail rule assumes a crash can split a record but
    /// the writer itself never interleaves or reorders records.
    fn append(&mut self, record: &[u8]) -> std::io::Result<()>;
    /// Make everything appended so far durable.
    fn sync(&mut self) -> std::io::Result<()>;
}

/// Appends records to a file with one unbuffered `write_all` each —
/// records reach the OS page cache immediately (so a `kill -9` loses at
/// most the record being written, the torn tail recovery repairs) and
/// `fsync` cost is only paid at explicit [`JournalWriter::sync`]
/// barriers. Process-crash durable by construction; machine-crash
/// durable up to the last sync.
pub struct FileJournalWriter {
    file: File,
}

impl FileJournalWriter {
    /// Wrap an open journal file positioned at its end.
    pub fn new(file: File) -> FileJournalWriter {
        FileJournalWriter { file }
    }
}

impl JournalWriter for FileJournalWriter {
    fn append(&mut self, record: &[u8]) -> std::io::Result<()> {
        self.file.write_all(record)
    }
    fn sync(&mut self) -> std::io::Result<()> {
        self.file.sync_data()
    }
}

/// An in-memory writer over a shared buffer — the byte-determinism
/// tests compare two runs' buffers without touching the filesystem.
pub struct VecWriter {
    buf: Arc<Mutex<Vec<u8>>>,
}

impl VecWriter {
    /// Write into `buf` (the caller keeps a handle to read it back).
    pub fn new(buf: Arc<Mutex<Vec<u8>>>) -> VecWriter {
        VecWriter { buf }
    }
}

impl JournalWriter for VecWriter {
    fn append(&mut self, record: &[u8]) -> std::io::Result<()> {
        lock_recover(&self.buf).extend_from_slice(record);
        Ok(())
    }
    fn sync(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------
// the journal
// ---------------------------------------------------------------------

/// How the scheduler behaves when a journal append fails (see module
/// docs). Both policies are *loud*: one by typed errors, one by a
/// counter — a journal hole is never silent.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum JournalPolicy {
    /// The erroring submit gets [`Error::Journal`] and consumes no
    /// ticket; every later submit is refused the same way. Durability
    /// outranks availability.
    #[default]
    FailStop,
    /// Disable the writer on first error and keep serving from memory,
    /// counting every unpersisted record in [`JournalStats::drops`].
    /// Availability outranks durability.
    DegradeToMemory,
}

/// Journal health counters (all logical — no timestamps).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JournalStats {
    /// Records physically appended.
    pub appends: u64,
    /// Response/failure records buffered, awaiting the next sync barrier.
    pub buffered: u64,
    /// Records dropped after `DegradeToMemory` tripped. Non-zero means
    /// the journal is incomplete and recovery from it is refused.
    pub drops: u64,
    /// True once `FailStop` has latched an append error.
    pub failed: bool,
}

struct JournalInner {
    writer: Box<dyn JournalWriter>,
    /// Encoded response/failure payloads keyed by ticket — drained to
    /// the writer in ticket order at sync barriers, which is what keeps
    /// the file's response section deterministic despite racing
    /// dispatchers (module docs).
    buffered: BTreeMap<u64, Vec<u8>>,
    /// `DegradeToMemory` tripped: the writer is permanently disabled.
    disabled: bool,
    /// `FailStop` latched: the first append error, surfaced verbatim to
    /// every later append.
    failed: Option<String>,
    appends: u64,
    drops: u64,
}

impl JournalInner {
    fn append_payload(&mut self, payload: &[u8], policy: JournalPolicy) -> Result<()> {
        if self.disabled {
            self.drops += 1;
            return Ok(());
        }
        if let Some(msg) = &self.failed {
            return Err(Error::journal(msg.clone()));
        }
        // An oversized payload is unpersistable by *any* writer, so it
        // is surfaced directly under both policies: the submit fails
        // typed, no ticket is consumed, and nothing is silently dropped.
        let rec = frame(payload)?;
        match self.writer.append(&rec) {
            Ok(()) => {
                self.appends += 1;
                Ok(())
            }
            Err(e) => match policy {
                JournalPolicy::FailStop => {
                    let msg = format!("append failed (fail-stop): {e}");
                    self.failed = Some(msg.clone());
                    Err(Error::journal(msg))
                }
                JournalPolicy::DegradeToMemory => {
                    self.disabled = true;
                    self.drops += 1;
                    Ok(())
                }
            },
        }
    }
}

/// A serve scheduler's durable event journal. Cheap to share
/// (`Arc<Journal>` in [`super::ServeConfig`]); all methods take `&self`
/// and serialise on one internal lock. See the module docs for the
/// format, determinism and degradation contracts.
pub struct Journal {
    inner: Mutex<JournalInner>,
    policy: JournalPolicy,
    /// True when this handle started an empty journal (the scheduler
    /// writes the `Ident` record exactly once, on a fresh journal).
    fresh: bool,
}

impl Journal {
    /// Create (or truncate to empty) a journal file and write its
    /// header. The header is written directly — not through the
    /// [`JournalWriter`] — so a fault plan's record counter indexes
    /// records exactly, starting at 0.
    pub fn create(path: &Path, policy: JournalPolicy) -> Result<Journal> {
        let mut file =
            OpenOptions::new().write(true).create(true).truncate(true).open(path)?;
        file.write_all(&header())?;
        file.sync_data()?;
        Ok(Journal::from_writer(Box::new(FileJournalWriter::new(file)), policy, true))
    }

    /// Open a journal file for continued appends. An empty file gets
    /// the header (and reads as fresh); an existing file's header is
    /// verified. Does **not** repair torn tails — run [`read_journal`]
    /// first (it truncates the tail in place), then open, so every
    /// append lands on an intact record boundary.
    pub fn open_append(path: &Path, policy: JournalPolicy) -> Result<Journal> {
        let mut file =
            OpenOptions::new().read(true).append(true).create(true).open(path)?;
        let len = file.metadata()?.len();
        if len == 0 {
            file.write_all(&header())?;
            file.sync_data()?;
            return Ok(Journal::from_writer(
                Box::new(FileJournalWriter::new(file)),
                policy,
                true,
            ));
        }
        if (len as usize) < HEADER_LEN {
            return Err(Error::journal(format!(
                "{}: torn header — run recovery (read_journal) before appending",
                path.display()
            )));
        }
        let mut hdr = [0u8; HEADER_LEN];
        file.read_exact(&mut hdr)?;
        if hdr != header() {
            return Err(Error::journal(format!(
                "{} is not a version-{JOURNAL_VERSION} serve journal",
                path.display()
            )));
        }
        let fresh = len as usize == HEADER_LEN;
        Ok(Journal::from_writer(Box::new(FileJournalWriter::new(file)), policy, fresh))
    }

    /// A journal over an arbitrary writer — headerless, used by the
    /// in-memory byte-determinism tests and the fault harness. The
    /// record stream it produces parses with [`parse_records`].
    pub fn with_writer(writer: Box<dyn JournalWriter>, policy: JournalPolicy) -> Journal {
        Journal::from_writer(writer, policy, true)
    }

    fn from_writer(writer: Box<dyn JournalWriter>, policy: JournalPolicy, fresh: bool) -> Journal {
        Journal {
            inner: Mutex::new(JournalInner {
                writer,
                buffered: BTreeMap::new(),
                disabled: false,
                failed: None,
                appends: 0,
                drops: 0,
            }),
            policy,
            fresh,
        }
    }

    /// True when this handle started an empty journal (no records yet).
    pub fn is_fresh(&self) -> bool {
        self.fresh
    }

    /// The configured degradation policy.
    pub fn policy(&self) -> JournalPolicy {
        self.policy
    }

    /// Append any event synchronously (gate-ordered record classes:
    /// ident, submit via [`Self::append_submit`], flush cut, truncate).
    pub fn append_event(&self, ev: &JournalEvent) -> Result<()> {
        lock_recover(&self.inner).append_payload(&encode_event(ev), self.policy)
    }

    /// Append one submit record (no tensor clone — the hot path).
    pub fn append_submit(&self, ticket: u64, request: &Tensor) -> Result<()> {
        lock_recover(&self.inner).append_payload(&encode_submit(ticket, request), self.policy)
    }

    /// Append one flush-cut record.
    pub fn append_flush(&self, upto: u64) -> Result<()> {
        self.append_event(&JournalEvent::FlushCut { upto })
    }

    /// Append one truncation-watermark record.
    pub fn append_truncate(&self, watermark: u64) -> Result<()> {
        self.append_event(&JournalEvent::Truncate { watermark })
    }

    /// Buffer one response record for the next sync barrier (dispatcher
    /// side — see module docs for why responses are not appended
    /// inline). First record per ticket wins, mirroring the response
    /// log.
    pub fn buffer_response(&self, entry: &super::log::LogEntry) {
        let payload = encode_event(&JournalEvent::Response {
            ticket: entry.ticket,
            batch_id: entry.batch_id,
            request_hash: entry.request_hash.clone(),
            response_hash: entry.response_hash.clone(),
            weights_hash: entry.weights_hash.clone(),
        });
        lock_recover(&self.inner).buffered.entry(entry.ticket).or_insert(payload);
    }

    /// Buffer one batch-failure record for the next sync barrier.
    pub fn buffer_failed(&self, ticket: u64) {
        let payload = encode_event(&JournalEvent::Failed { ticket });
        lock_recover(&self.inner).buffered.entry(ticket).or_insert(payload);
    }

    /// Sync barrier: drain every buffered response record to the writer
    /// in ticket order, then make the file durable. On a `FailStop`
    /// append error the un-drained records stay buffered (visible in
    /// [`JournalStats::buffered`]) and the error surfaces here.
    pub fn sync(&self) -> Result<()> {
        let mut inner = lock_recover(&self.inner);
        while let Some((ticket, payload)) = inner.buffered.pop_first() {
            if let Err(e) = inner.append_payload(&payload, self.policy) {
                inner.buffered.insert(ticket, payload);
                return Err(e);
            }
        }
        if inner.disabled {
            return Ok(());
        }
        if let Some(msg) = &inner.failed {
            return Err(Error::journal(msg.clone()));
        }
        match inner.writer.sync() {
            Ok(()) => Ok(()),
            Err(e) => match self.policy {
                JournalPolicy::FailStop => {
                    let msg = format!("sync failed (fail-stop): {e}");
                    inner.failed = Some(msg.clone());
                    Err(Error::journal(msg))
                }
                JournalPolicy::DegradeToMemory => {
                    inner.disabled = true;
                    Ok(())
                }
            },
        }
    }

    /// Current health counters.
    pub fn stats(&self) -> JournalStats {
        let inner = lock_recover(&self.inner);
        JournalStats {
            appends: inner.appends,
            buffered: inner.buffered.len() as u64,
            drops: inner.drops,
            failed: inner.failed.is_some(),
        }
    }
}

impl fmt::Debug for Journal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // counters only: the writer is opaque and stats() takes the
        // internal lock, so never Debug-print while holding it
        let s = self.stats();
        f.debug_struct("Journal")
            .field("policy", &self.policy)
            .field("fresh", &self.fresh)
            .field("appends", &s.appends)
            .field("buffered", &s.buffered)
            .field("drops", &s.drops)
            .field("failed", &s.failed)
            .finish()
    }
}

/// SHA-256 of a byte buffer as lowercase hex — convenience for
/// comparing whole journal files in tests and tooling.
pub fn digest_hex(bytes: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(bytes);
    hex(&h.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ident() -> JournalEvent {
        JournalEvent::Ident {
            model_id: "linear".into(),
            weights_hash: "abc123".into(),
            d_in: 16,
            d_out: 4,
            shards: 2,
            batch_window: 4,
        }
    }

    fn events() -> Vec<JournalEvent> {
        vec![
            ident(),
            JournalEvent::Submit {
                ticket: 0,
                request: Tensor::from_vec(&[3], vec![1.5, -0.0, f32::NAN]).unwrap(),
            },
            JournalEvent::FlushCut { upto: 1 },
            JournalEvent::Response {
                ticket: 0,
                batch_id: 0,
                request_hash: "rh".into(),
                response_hash: "sh".into(),
                weights_hash: "abc123".into(),
            },
            JournalEvent::Truncate { watermark: 1 },
            JournalEvent::Failed { ticket: 9 },
        ]
    }

    fn stream(evs: &[JournalEvent]) -> Vec<u8> {
        let mut bytes = Vec::new();
        for ev in evs {
            bytes.extend_from_slice(&frame(&encode_event(ev)).unwrap());
        }
        bytes
    }

    #[test]
    fn events_roundtrip_bit_exactly() {
        let evs = events();
        let (got, valid) = parse_records(&stream(&evs)).unwrap();
        assert_eq!(valid, stream(&evs).len());
        assert_eq!(got.len(), evs.len());
        for (a, b) in got.iter().zip(evs.iter()) {
            match (a, b) {
                // NaN != NaN under PartialEq; the journal stores raw bit
                // patterns, so compare those
                (
                    JournalEvent::Submit { ticket: t1, request: r1 },
                    JournalEvent::Submit { ticket: t2, request: r2 },
                ) => {
                    assert_eq!(t1, t2);
                    assert!(r1.bit_eq(r2), "tensor bits must survive the roundtrip");
                }
                _ => assert_eq!(a, b),
            }
        }
    }

    #[test]
    fn encoding_is_a_pure_function_of_the_event() {
        let evs = events();
        assert_eq!(stream(&evs), stream(&evs), "same events ⇒ same bytes");
        assert_eq!(digest_hex(&stream(&evs)), digest_hex(&stream(&evs)));
    }

    #[test]
    fn every_torn_tail_is_detected_at_the_last_intact_boundary() {
        let evs = events();
        let bytes = stream(&evs);
        // chop the stream at every possible byte length; the parser must
        // recover exactly the records whose full frame survived
        let mut boundaries = vec![0usize];
        for ev in &evs {
            boundaries.push(boundaries.last().unwrap() + frame(&encode_event(ev)).unwrap().len());
        }
        for cut in 0..=bytes.len() {
            let (got, valid) = parse_records(&bytes[..cut]).unwrap();
            let whole = boundaries.iter().take_while(|&&b| b <= cut).count() - 1;
            assert_eq!(got.len(), whole, "cut at {cut}");
            assert_eq!(valid, boundaries[whole], "cut at {cut}");
        }
    }

    #[test]
    fn a_flipped_bit_inside_a_record_stops_the_scan_there() {
        let evs = events();
        let mut bytes = stream(&evs);
        // corrupt one payload byte of the third record (offset: past two
        // frames, past the length field)
        let off = frame(&encode_event(&evs[0])).unwrap().len()
            + frame(&encode_event(&evs[1])).unwrap().len()
            + 4;
        bytes[off] ^= 0x40;
        let (got, valid) = parse_records(&bytes).unwrap();
        assert_eq!(got.len(), 2, "the corrupted record and everything after it are dropped");
        assert_eq!(valid, off - 4);
    }

    #[test]
    fn journal_drains_buffered_responses_in_ticket_order_at_sync() {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let j = Journal::with_writer(
            Box::new(VecWriter::new(Arc::clone(&buf))),
            JournalPolicy::FailStop,
        );
        j.append_event(&ident()).unwrap();
        let req = Tensor::from_vec(&[1], vec![2.0]).unwrap();
        j.append_submit(0, &req).unwrap();
        j.append_submit(1, &req).unwrap();
        j.append_flush(2).unwrap();
        // buffer out of ticket order, as racing dispatchers would
        j.buffer_failed(1);
        j.buffer_response(&crate::coordinator::serve::log::LogEntry {
            ticket: 0,
            request: req.clone(),
            request_hash: "r".into(),
            response_hash: "s".into(),
            batch_id: 0,
            weights_hash: "w".into(),
        });
        assert_eq!(j.stats().buffered, 2);
        j.sync().unwrap();
        let s = j.stats();
        assert_eq!((s.buffered, s.appends, s.drops), (0, 6, 0));
        let (evs, _) = parse_records(&lock_recover(&buf)[..]).unwrap();
        assert!(matches!(evs[4], JournalEvent::Response { ticket: 0, .. }));
        assert!(matches!(evs[5], JournalEvent::Failed { ticket: 1 }));
    }

    #[test]
    fn file_journal_roundtrips_and_rejects_foreign_files() {
        let dir = std::env::temp_dir().join("repdl-journal-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.journal");
        {
            let j = Journal::create(&path, JournalPolicy::FailStop).unwrap();
            assert!(j.is_fresh());
            j.append_event(&ident()).unwrap();
            j.append_submit(0, &Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap()).unwrap();
            j.sync().unwrap();
        }
        let out = read_journal(&path).unwrap();
        assert_eq!(out.events.len(), 2);
        assert!(!out.truncated_tail());
        // reopening is not fresh: the ident must not be written twice
        let j2 = Journal::open_append(&path, JournalPolicy::FailStop).unwrap();
        assert!(!j2.is_fresh());
        drop(j2);
        // a non-journal file is a typed error, not a truncation
        let alien = dir.join("alien.bin");
        std::fs::write(&alien, b"definitely not a journal, but >12 bytes").unwrap();
        match read_journal(&alien) {
            Err(Error::Journal(m)) => assert!(m.contains("bad magic"), "{m}"),
            other => panic!("want Error::Journal, got {other:?}"),
        }
        assert!(Journal::open_append(&alien, JournalPolicy::FailStop).is_err());
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&alien).unwrap();
    }

    #[test]
    fn oversized_payload_is_a_typed_error_not_a_wrapped_length() {
        // the length check, on a synthetic length — no 4 GiB allocation
        assert_eq!(frame_len(0).unwrap(), 0);
        assert_eq!(frame_len(u32::MAX as usize).unwrap(), u32::MAX);
        #[cfg(target_pointer_width = "64")]
        match frame_len(u32::MAX as usize + 1) {
            Err(Error::Journal(m)) => {
                assert!(m.contains("exceeds the u32 frame length field"), "{m}")
            }
            other => panic!("want Error::Journal, got {other:?}"),
        }
        // and frame() itself still works on ordinary payloads
        let rec = frame(b"hello").unwrap();
        assert_eq!(rec.len(), 4 + 5 + DIGEST_LEN);
        assert_eq!(&rec[..4], &5u32.to_le_bytes());
    }

    #[test]
    fn short_files_are_refused_unless_the_full_magic_verifies() {
        let dir = std::env::temp_dir().join("repdl-journal-unit");
        std::fs::create_dir_all(&dir).unwrap();
        // empty file: a clean (if degenerate) journal, nothing to repair
        let empty = dir.join("empty.journal");
        std::fs::write(&empty, b"").unwrap();
        let out = read_journal(&empty).unwrap();
        assert!(out.events.is_empty() && out.torn_bytes == 0);
        // sub-magic prefix match ("REPDL"): cannot verify the magic, so
        // refuse — and the file must be left untouched, not set_len(0)
        let short = dir.join("short.bin");
        std::fs::write(&short, b"REPDL").unwrap();
        assert!(matches!(read_journal(&short), Err(Error::Journal(_))));
        assert_eq!(std::fs::metadata(&short).unwrap().len(), 5, "refusal must not truncate");
        // full magic but a foreign byte after it: refuse, leave intact
        let foreign = dir.join("foreign.bin");
        std::fs::write(&foreign, b"REPDLJNL\xff\xff").unwrap();
        assert!(matches!(read_journal(&foreign), Err(Error::Journal(_))));
        assert_eq!(std::fs::metadata(&foreign).unwrap().len(), 10);
        // a verified torn header (full magic + canonical prefix): repaired
        let torn = dir.join("torn-header.journal");
        std::fs::write(&torn, &header()[..10]).unwrap();
        let out = read_journal(&torn).unwrap();
        assert_eq!(out.torn_bytes, 10);
        assert_eq!(std::fs::metadata(&torn).unwrap().len(), 0);
        for p in [&empty, &short, &foreign, &torn] {
            std::fs::remove_file(p).unwrap();
        }
    }

    #[test]
    fn decoder_bounds_length_prefixes_before_allocating() {
        // a hash-valid record whose *payload* lies about lengths — the
        // decoder must bound every claimed length against the remaining
        // bytes before sizing any allocation, and return a typed error
        let mut huge_str = vec![TAG_RESPONSE];
        put_u64(&mut huge_str, 3); // ticket
        put_u64(&mut huge_str, 0); // batch_id
        put_u64(&mut huge_str, u64::MAX); // request_hash length: hostile
        assert!(matches!(decode_event(&huge_str), Err(Error::Journal(_))));

        let mut huge_dim = vec![TAG_SUBMIT];
        put_u64(&mut huge_dim, 7); // ticket
        put_u64(&mut huge_dim, 1); // rank
        put_u64(&mut huge_dim, u64::MAX); // dim: hostile
        assert!(matches!(decode_event(&huge_dim), Err(Error::Journal(_))));

        let mut huge_rank = vec![TAG_SUBMIT];
        put_u64(&mut huge_rank, 7);
        put_u64(&mut huge_rank, u64::MAX); // rank: hostile
        assert!(matches!(decode_event(&huge_rank), Err(Error::Journal(_))));
    }

    #[test]
    fn prop_mutated_streams_never_panic_or_overallocate() {
        // mutation fuzz over the shared decoder (journal recovery and
        // the wire codec both ride on it): random byte flips and
        // truncations of a valid stream must always yield either a
        // clean torn-tail report or a typed error — never a panic, and
        // never an allocation sized by an unvalidated length field
        let base = stream(&events());
        crate::proptest::forall(
            0xCAFE,
            400,
            |g| {
                let mut bytes = base.clone();
                // truncate to a random length...
                let cut = g.below(bytes.len() + 1);
                bytes.truncate(cut);
                // ...then flip up to 4 random bytes
                for _ in 0..g.below(5) {
                    if bytes.is_empty() {
                        break;
                    }
                    let i = g.below(bytes.len());
                    bytes[i] ^= 1 << g.below(8);
                }
                bytes
            },
            |bytes| match parse_records(bytes) {
                Ok((_, valid)) => valid <= bytes.len(),
                Err(Error::Journal(_)) => true,
                Err(_) => false,
            },
        );
    }

    #[test]
    fn read_journal_physically_truncates_a_torn_tail() {
        let dir = std::env::temp_dir().join("repdl-journal-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.journal");
        {
            let j = Journal::create(&path, JournalPolicy::FailStop).unwrap();
            j.append_event(&ident()).unwrap();
            j.append_flush(1).unwrap();
            j.sync().unwrap();
        }
        let clean_len = std::fs::metadata(&path).unwrap().len();
        // simulate a crash mid-append: half a record at the tail
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        let torn = frame(&encode_event(&JournalEvent::FlushCut { upto: 2 })).unwrap();
        f.write_all(&torn[..torn.len() - 7]).unwrap();
        drop(f);
        let out = read_journal(&path).unwrap();
        assert_eq!(out.events.len(), 2, "intact records survive");
        assert_eq!(out.torn_bytes, (torn.len() - 7) as u64);
        assert!(out.truncated_tail());
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            clean_len,
            "the tail must be truncated in place"
        );
        // a second read sees a clean journal
        assert!(!read_journal(&path).unwrap().truncated_tail());
        std::fs::remove_file(&path).unwrap();
    }
}
