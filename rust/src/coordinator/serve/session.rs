//! Deterministic session store for KV-cached incremental decoding.
//!
//! A *session* is one live decode stream: the per-layer KV caches
//! ([`crate::nn::TransformerKv`]) for a token prefix, keyed by that
//! prefix's content hash ([`token_key`]). A request whose prefix hash
//! matches a stored session can run ONE incremental step (O(T)) instead
//! of a full recompute (O(T²)); any miss — unknown prefix, evicted
//! session, length mismatch — falls back to the full recompute, which
//! is bit-identical by construction (the per-row reduction graphs are
//! position-independent; DESIGN.md §10).
//!
//! Eviction mirrors [`super::cache::MemoCache`] exactly: deterministic
//! logical-clock FIFO by **insertion ticket**. Which sessions the store
//! holds after a given insert sequence is a pure function of the (key,
//! ticket) pairs inserted — never of wall-clock or lookup timing. A hit
//! does not refresh an entry; a duplicate insert (either axis) keeps
//! the existing entry (first insertion wins). The same single-shard
//! scope note as the memo cache applies: with one dispatcher the insert
//! sequence is event-sequence-pure, so contents and counters are fully
//! reproducible; with several, hit/miss *counters* can vary with thread
//! timing under eviction pressure — bits never can, because a session
//! hit is bit-equal to the recompute it replaces.

use crate::coordinator::hashing::hex;
use crate::nn::TransformerKv;
use crate::sha256::Sha256;
use std::collections::BTreeMap;
use std::sync::Mutex;

use super::lock_recover;

/// Content address of a token prefix: SHA-256 over the ids as u64 LE
/// (length-framed by construction — the id stream IS the content).
/// Sessions for different prefixes can never collide onto one key.
pub fn token_key(ids: &[usize]) -> String {
    let mut h = Sha256::new();
    for &i in ids {
        h.update((i as u64).to_le_bytes());
    }
    hex(&h.finalize())
}

/// One stored decode stream: the KV caches for a prefix plus the
/// prefix's content hash (= its store key, kept for auditability).
#[derive(Clone)]
pub struct Session {
    /// Per-layer KV caches; `kv.steps()` is the prefix length.
    pub kv: TransformerKv,
    /// [`token_key`] of the prefix the caches were built from.
    pub prefix_hash: String,
}

/// Store occupancy and traffic counters (all monotone except `len`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Lookups answered from the store.
    pub hits: u64,
    /// Lookups that fell through to full recompute.
    pub misses: u64,
    /// Sessions evicted by the capacity rule.
    pub evictions: u64,
    /// Sessions currently held.
    pub len: usize,
    /// Maximum sessions held.
    pub capacity: usize,
}

struct StoreInner {
    /// prefix-hash → (insertion ticket, session).
    by_key: BTreeMap<String, (u64, Session)>,
    /// insertion ticket → prefix-hash (the deterministic eviction order).
    by_ticket: BTreeMap<u64, String>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Thread-safe session store (see module docs). `BTreeMap`s on both
/// indices — no hash-seed dependence anywhere.
pub struct SessionStore {
    inner: Mutex<StoreInner>,
    capacity: usize,
}

impl SessionStore {
    /// New store holding at most `capacity` sessions (`capacity ≥ 1`;
    /// zero means "sessions off" and is handled by the tower never
    /// constructing one).
    pub fn new(capacity: usize) -> SessionStore {
        SessionStore {
            inner: Mutex::new(StoreInner {
                by_key: BTreeMap::new(),
                by_ticket: BTreeMap::new(),
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
            capacity: capacity.max(1),
        }
    }

    /// Look up a session by prefix hash. Returns a **clone** — the
    /// stored session is never mutated in place, so a later fallback
    /// re-reads exactly what was inserted. Counts a hit or a miss;
    /// deliberately does not refresh the entry's eviction position.
    pub fn lookup(&self, key: &str) -> Option<Session> {
        let mut inner = lock_recover(&self.inner);
        let hit = inner.by_key.get(key).map(|(_, s)| s.clone());
        match hit {
            Some(s) => {
                inner.hits += 1;
                Some(s)
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Insert a session under the inserting request's ticket. Duplicate
    /// keys and duplicate tickets keep the existing entry (first
    /// insertion wins on both axes — the indices can never desync);
    /// over capacity, the smallest-ticket session is evicted.
    pub fn insert(&self, key: &str, ticket: u64, session: &Session) {
        let mut inner = lock_recover(&self.inner);
        if inner.by_key.contains_key(key) || inner.by_ticket.contains_key(&ticket) {
            return;
        }
        inner.by_key.insert(key.to_string(), (ticket, session.clone()));
        inner.by_ticket.insert(ticket, key.to_string());
        while inner.by_key.len() > self.capacity {
            // deterministic: evict the smallest insertion ticket present
            let (&t, _) = inner.by_ticket.iter().next().unwrap();
            let victim = inner.by_ticket.remove(&t).unwrap();
            inner.by_key.remove(&victim);
            inner.evictions += 1;
        }
    }

    /// Current counters and occupancy.
    pub fn stats(&self) -> SessionStats {
        let inner = lock_recover(&self.inner);
        SessionStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            len: inner.by_key.len(),
            capacity: self.capacity,
        }
    }

    /// The prefix hashes currently held, in insertion-ticket order —
    /// exposed so tests can pin the eviction rule as a pure function of
    /// tickets (mirror of `MemoCache::held_keys_by_ticket`).
    pub fn held_keys_by_ticket(&self) -> Vec<(u64, String)> {
        let inner = lock_recover(&self.inner);
        inner.by_ticket.iter().map(|(&t, k)| (t, k.clone())).collect()
    }

    /// Test hook: panic **while holding the store's internal lock**, so
    /// the caller's thread poisons it for real. The poison-recovery
    /// suite (`tests/serve_sessions.rs`) uses this to prove
    /// [`super::lock_recover`]'s update-atomicity argument on an
    /// actually-poisoned store — every mutation either completed or
    /// never started, so serving continues on the guarded value.
    #[doc(hidden)]
    pub fn poison_for_test(&self) {
        let _inner = lock_recover(&self.inner);
        panic!("SessionStore poisoned by test hook");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{CharTransformer, TransformerConfig};
    use crate::tensor::WorkerPool;

    fn sess(model: &CharTransformer, ids: &[usize]) -> Session {
        let pool = WorkerPool::new(1);
        let mut kv = model.begin_kv();
        let _ = model.forward_logits_packed_in(&pool, ids, None, Some(&mut kv)).unwrap();
        Session { kv, prefix_hash: token_key(ids) }
    }

    fn tiny() -> CharTransformer {
        let cfg = TransformerConfig {
            vocab: 10,
            dim: 8,
            heads: 2,
            layers: 1,
            context: 6,
            mlp_ratio: 2,
        };
        CharTransformer::new(cfg, 5).unwrap()
    }

    #[test]
    fn token_key_is_injective_on_prefix_content_and_length() {
        assert_ne!(token_key(&[1, 2]), token_key(&[2, 1]));
        assert_ne!(token_key(&[1, 2]), token_key(&[1, 2, 0]));
        assert_ne!(token_key(&[]), token_key(&[0]));
        assert_eq!(token_key(&[3, 7, 1]), token_key(&[3, 7, 1]));
    }

    #[test]
    fn eviction_is_a_pure_function_of_insertion_tickets() {
        // mirror of the MemoCache test: two arrival orders, same held set
        let m = tiny();
        let streams: [&[usize]; 5] = [&[1], &[2], &[3], &[4], &[5]];
        let orders: [&[(u64, usize)]; 2] = [
            &[(10, 0), (2, 1), (7, 2), (20, 3), (15, 4)],
            &[(20, 3), (2, 1), (15, 4), (10, 0), (7, 2)],
        ];
        let mut finals = Vec::new();
        for inserts in orders {
            let st = SessionStore::new(3);
            for &(t, i) in inserts {
                st.insert(&token_key(streams[i]), t, &sess(&m, streams[i]));
            }
            finals.push(st.held_keys_by_ticket());
        }
        assert_eq!(finals[0], finals[1]);
        let tickets: Vec<u64> = finals[0].iter().map(|(t, _)| *t).collect();
        assert_eq!(tickets, vec![10, 15, 20]);
        let st = SessionStore::new(3);
        for &(t, i) in orders[0] {
            st.insert(&token_key(streams[i]), t, &sess(&m, streams[i]));
        }
        assert_eq!(st.stats().evictions, 2);
    }

    #[test]
    fn duplicates_keep_first_and_hits_do_not_refresh() {
        let m = tiny();
        let st = SessionStore::new(2);
        let (a, b, c) = (sess(&m, &[1]), sess(&m, &[2]), sess(&m, &[3]));
        st.insert("x", 1, &a);
        st.insert("x", 9, &b); // duplicate key: first wins
        assert_eq!(st.lookup("x").unwrap().kv.steps(), a.kv.steps());
        st.insert("y", 1, &b); // duplicate ticket: dropped, no desync
        assert!(st.lookup("y").is_none());
        st.insert("y", 2, &b);
        for _ in 0..10 {
            st.lookup("x").unwrap(); // hits must not refresh
        }
        st.insert("z", 3, &c);
        assert!(st.lookup("x").is_none(), "x held the smallest ticket: evicted");
        assert!(st.lookup("y").is_some() && st.lookup("z").is_some());
        let s = st.stats();
        assert_eq!(s.capacity, 2);
        assert_eq!(s.len, 2);
    }

    #[test]
    fn lookup_returns_a_clone_stored_state_is_immutable() {
        let m = tiny();
        let st = SessionStore::new(4);
        let s = sess(&m, &[1, 2]);
        st.insert(&s.prefix_hash, 1, &s);
        let pool = WorkerPool::new(1);
        // advance the clone; the stored session must not move
        let mut got = st.lookup(&s.prefix_hash).unwrap();
        let _ = m.forward_logits_step_infer_in(&pool, 3, &mut got.kv).unwrap();
        assert_eq!(got.kv.steps(), 3);
        assert_eq!(st.lookup(&s.prefix_hash).unwrap().kv.steps(), 2);
    }
}
