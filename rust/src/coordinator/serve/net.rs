//! std-only TCP front end for the serve registry (DESIGN.md §14).
//!
//! [`NetServer`] puts a length-prefixed, digest-checked socket protocol
//! (see [`super::wire`]) in front of a [`ModelRegistry`]: one accept
//! thread, and per connection one *reader* thread (decode frame →
//! route) plus one *writer* thread (answer in request order). No
//! dependencies beyond `std::net` / `std::thread`, no wall-clock
//! anywhere — batching latency is controlled by the logical clock only
//! (`flush_every` cuts and explicit [`WireFrame::Flush`] frames;
//! timers stay banned).
//!
//! **Where determinism lives.** The network adds exactly one
//! nondeterministic input: the order in which request frames from
//! *different* connections reach the registry gate (OS scheduling of
//! reader threads). Everything after that gate is already a pure
//! function of the arrival order — tickets are stamped and shard
//! queues filled under the same lock ([`super::scheduler`]), and a
//! journaled server records that order as the submit event sequence.
//! So cross-process replay is exact: recover the journal in a fresh
//! process and every response bit is pinned, even though a re-*run*
//! with racing clients may interleave differently. Per-connection
//! order is fully deterministic (one reader thread, FIFO frames, FIFO
//! replies).
//!
//! **Untrusted bytes.** Reader threads only ever see socket data
//! through [`super::wire::read_frame`], which bounds every length
//! before allocating and types every defect as [`Error::Protocol`] —
//! a malformed peer gets an error frame and a closed connection,
//! never a panic and never a poisoned scheduler.

use super::registry::{ModelInfo, ModelRegistry};
use super::scheduler::Pending;
use super::wire::{code, read_frame, write_frame, WireFrame, WIRE_VERSION};
use super::lock_recover;
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One queued reply on a connection's writer channel. The channel *is*
/// the FIFO contract: the reader enqueues in frame-arrival order, the
/// writer resolves strictly in that order, so a connection's responses
/// come back in the order its requests went in.
enum Reply {
    /// An admitted request: resolve the pending response, then write
    /// [`WireFrame::Response`] (or a typed error frame on failure).
    Answer { req_id: u64, pending: Pending },
    /// An already-formed frame (errors, flush acks, stats).
    Immediate(WireFrame),
}

/// The serve TCP front end: a [`ModelRegistry`] behind a listener.
///
/// Bind with [`NetServer::bind`] (use port 0 to let the OS pick, then
/// read [`NetServer::local_addr`]); stop with [`NetServer::shutdown`]
/// (also run on drop). Each accepted connection is served until the
/// peer says [`WireFrame::Bye`], disconnects, or violates the
/// protocol.
pub struct NetServer {
    registry: Arc<ModelRegistry>,
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    conn_streams: Arc<Mutex<Vec<TcpStream>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and start accepting
    /// connections against `registry`.
    pub fn bind(registry: Arc<ModelRegistry>, addr: &str) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let conn_streams: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let registry = Arc::clone(&registry);
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let conn_streams = Arc::clone(&conn_streams);
            std::thread::spawn(move || {
                accept_loop(listener, registry, stop, conns, conn_streams)
            })
        };
        Ok(NetServer { registry, local_addr, stop, accept: Some(accept), conns, conn_streams })
    }

    /// The bound address — read this after binding port 0.
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, tear down live connections, and join every
    /// thread. Idempotent; also run on drop. The registry itself stays
    /// open — closing models is its owner's decision.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // wake the accept loop with a throwaway connection; it checks
        // the stop flag before handling anything it accepts
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // unblock reader threads parked in read_frame
        for s in lock_recover(&self.conn_streams).drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        // release writer threads parked in Pending::wait on a partial
        // batch: a flush is a logical event the journal records like
        // any other, so this stays replay-exact
        self.registry.flush_all();
        let handles: Vec<JoinHandle<()>> = lock_recover(&self.conns).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    conn_streams: Arc<Mutex<Vec<TcpStream>>>,
) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Ok(dup) = stream.try_clone() {
                    lock_recover(&conn_streams).push(dup);
                }
                let registry = Arc::clone(&registry);
                let h = std::thread::spawn(move || {
                    // connection-level errors (protocol violations,
                    // vanished peers) end this connection only; they
                    // were already answered with an error frame where
                    // a peer could still hear it
                    let _ = serve_connection(&registry, stream);
                });
                lock_recover(&conns).push(h);
            }
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
            }
        }
    }
}

/// Map a registry/scheduler failure to a wire error code.
fn classify(e: &Error) -> &'static str {
    match e {
        Error::Closed => code::CLOSED,
        Error::Config(m) if m.contains("unknown model id") => code::UNKNOWN_MODEL,
        Error::Config(_) | Error::Shape(_) => code::BAD_REQUEST,
        _ => code::INTERNAL,
    }
}

fn error_frame(req_id: u64, code: &str, message: impl Into<String>) -> WireFrame {
    WireFrame::Error { req_id, code: code.to_string(), message: message.into() }
}

/// Serve one connection to completion: hello handshake, then the
/// reader loop feeding a FIFO writer thread.
fn serve_connection(registry: &ModelRegistry, stream: TcpStream) -> Result<()> {
    let mut reader = stream.try_clone()?;
    let mut writer = stream.try_clone()?;
    // handshake, synchronously on this thread: hello must be the first
    // frame, and its version must match
    match read_frame(&mut reader) {
        Ok(Some(WireFrame::HelloClient { version })) if version == WIRE_VERSION => {}
        Ok(Some(WireFrame::HelloClient { version })) => {
            let _ = write_frame(
                &mut writer,
                &error_frame(
                    0,
                    code::PROTOCOL,
                    format!("unsupported wire version {version} (server speaks {WIRE_VERSION})"),
                ),
            );
            return Err(Error::protocol(format!("unsupported wire version {version}")));
        }
        Ok(Some(f)) => {
            let _ = write_frame(
                &mut writer,
                &error_frame(0, code::PROTOCOL, format!("expected hello, got {f:?}")),
            );
            return Err(Error::protocol("first frame was not a hello"));
        }
        Ok(None) => return Ok(()), // connected and left — fine
        Err(e) => {
            let _ = write_frame(&mut writer, &error_frame(0, code::PROTOCOL, e.to_string()));
            return Err(e);
        }
    }
    write_frame(
        &mut writer,
        &WireFrame::HelloServer { version: WIRE_VERSION, models: registry.model_table() },
    )?;
    let (tx, rx) = channel::<Reply>();
    let writer_thread = std::thread::spawn(move || writer_loop(writer, rx));
    let result = reader_loop(registry, &mut reader, &tx);
    // dropping the sender ends the writer's queue; it drains whatever
    // is already enqueued, then exits
    drop(tx);
    let _ = writer_thread.join();
    let _ = stream.shutdown(Shutdown::Both);
    result
}

/// Resolve replies strictly in enqueue order and write them out. After
/// the first write failure (the peer vanished mid-request) the queue is
/// still drained — but pendings are *dropped*, not waited on: their
/// batches execute and are journaled regardless, and nobody is left to
/// read the bits, so blocking a server thread on them would leak.
fn writer_loop(mut w: TcpStream, rx: Receiver<Reply>) {
    let mut alive = true;
    for reply in rx {
        let frame = match reply {
            Reply::Immediate(f) => f,
            Reply::Answer { req_id, pending } => {
                if !alive {
                    drop(pending);
                    continue;
                }
                let ticket = pending.ticket();
                match pending.wait() {
                    Ok(response) => WireFrame::Response { req_id, ticket, response },
                    Err(e) => error_frame(req_id, classify(&e), e.to_string()),
                }
            }
        };
        if alive && write_frame(&mut w, &frame).is_err() {
            alive = false;
        }
    }
}

/// Decode and route frames until the peer is done. Per-request
/// failures (unknown model, bad shape) answer with a typed error frame
/// and keep the connection; protocol violations answer with a
/// [`code::PROTOCOL`] frame and close it.
fn reader_loop(
    registry: &ModelRegistry,
    reader: &mut TcpStream,
    tx: &Sender<Reply>,
) -> Result<()> {
    loop {
        let frame = match read_frame(reader) {
            Ok(Some(f)) => f,
            Ok(None) => return Ok(()), // clean disconnect between frames
            Err(Error::Protocol(m)) => {
                let _ = tx.send(Reply::Immediate(error_frame(0, code::PROTOCOL, m.clone())));
                return Err(Error::Protocol(m));
            }
            Err(e) => return Err(e),
        };
        let reply = match frame {
            WireFrame::Request { req_id, model_id, request } => {
                // backpressure is absorbed here (flush-and-retry is
                // the admission protocol, not an error a remote client
                // can act on); every other failure is typed per request
                match registry.submit_with_backpressure(&model_id, &request) {
                    Ok(pending) => Reply::Answer { req_id, pending },
                    Err(e) => Reply::Immediate(error_frame(req_id, classify(&e), e.to_string())),
                }
            }
            WireFrame::Flush { req_id, model_id } => {
                let res = if model_id.is_empty() {
                    registry.flush_all();
                    Ok(())
                } else {
                    registry.flush(&model_id)
                };
                Reply::Immediate(match res {
                    Ok(()) => WireFrame::Flushed { req_id },
                    Err(e) => error_frame(req_id, classify(&e), e.to_string()),
                })
            }
            WireFrame::Stats { req_id, model_id } => {
                Reply::Immediate(match registry.get(&model_id) {
                    Some(s) => WireFrame::StatsReply {
                        req_id,
                        next_ticket: s.next_ticket(),
                        in_flight: s.in_flight(),
                        rejected: s.rejected(),
                        journal_appends: s.journal_stats().map_or(0, |j| j.appends),
                    },
                    None => error_frame(
                        req_id,
                        code::UNKNOWN_MODEL,
                        format!("model registry: unknown model id '{model_id}'"),
                    ),
                })
            }
            WireFrame::Bye => return Ok(()),
            other => {
                // server-role frames (hello-server, response, …) from
                // a client are a protocol violation: close
                let _ = tx.send(Reply::Immediate(error_frame(
                    0,
                    code::PROTOCOL,
                    format!("unexpected frame from client: {other:?}"),
                )));
                return Err(Error::protocol("client sent a server-role frame"));
            }
        };
        if tx.send(reply).is_err() {
            return Ok(()); // writer gone ⇒ connection is down
        }
    }
}

/// A synchronous client for the serve wire protocol.
///
/// Connecting performs the hello handshake and learns the server's
/// model table — shapes and weight fingerprints come from the server,
/// the client never guesses. Requests are **pipelined**: call
/// [`NetClient::send_request`] any number of times, publish a cut with
/// [`NetClient::flush`] (unless the server's batch window or
/// `flush_every` does it), then collect with
/// [`NetClient::recv_response`] — replies arrive in send order
/// (per-connection FIFO is part of the protocol). For strict
/// one-at-a-time use, [`NetClient::request_flushed`] bundles
/// send + flush + recv.
pub struct NetClient {
    stream: TcpStream,
    models: Vec<ModelInfo>,
    next_req: u64,
}

impl NetClient {
    /// Connect and complete the hello handshake.
    pub fn connect(addr: &str) -> Result<NetClient> {
        let mut stream = TcpStream::connect(addr)?;
        write_frame(&mut stream, &WireFrame::HelloClient { version: WIRE_VERSION })?;
        match read_frame(&mut stream)? {
            Some(WireFrame::HelloServer { version, models }) if version == WIRE_VERSION => {
                Ok(NetClient { stream, models, next_req: 0 })
            }
            Some(WireFrame::HelloServer { version, .. }) => Err(Error::protocol(format!(
                "server speaks wire version {version}, client speaks {WIRE_VERSION}"
            ))),
            Some(WireFrame::Error { code, message, .. }) => {
                Err(Error::protocol(format!("server refused hello [{code}]: {message}")))
            }
            Some(f) => Err(Error::protocol(format!("expected server hello, got {f:?}"))),
            None => Err(Error::protocol("server closed the connection during hello")),
        }
    }

    /// The server's model table, as advertised in its hello.
    pub fn models(&self) -> &[ModelInfo] {
        &self.models
    }

    /// One model's identity row, by id.
    pub fn model(&self, model_id: &str) -> Option<&ModelInfo> {
        self.models.iter().find(|m| m.model_id == model_id)
    }

    fn next_req_id(&mut self) -> u64 {
        self.next_req += 1;
        self.next_req
    }

    /// Send one request frame (no waiting). Returns the correlation id
    /// the response will echo.
    pub fn send_request(&mut self, model_id: &str, request: &Tensor) -> Result<u64> {
        let req_id = self.next_req_id();
        write_frame(
            &mut self.stream,
            &WireFrame::Request {
                req_id,
                model_id: model_id.to_string(),
                request: request.clone(),
            },
        )?;
        Ok(req_id)
    }

    /// Send a flush frame (`""` flushes every model). The
    /// [`WireFrame::Flushed`] ack arrives in FIFO position — after the
    /// responses to every request sent before it.
    pub fn send_flush(&mut self, model_id: &str) -> Result<u64> {
        let req_id = self.next_req_id();
        write_frame(
            &mut self.stream,
            &WireFrame::Flush { req_id, model_id: model_id.to_string() },
        )?;
        Ok(req_id)
    }

    /// Read the next frame, whatever it is.
    pub fn recv(&mut self) -> Result<WireFrame> {
        match read_frame(&mut self.stream)? {
            Some(f) => Ok(f),
            None => Err(Error::protocol("server closed the connection")),
        }
    }

    /// Read the next frame, requiring a response: returns
    /// `(req_id, ticket, response)`. A server error frame becomes a
    /// typed [`Error::Runtime`] carrying its code and message.
    pub fn recv_response(&mut self) -> Result<(u64, u64, Tensor)> {
        match self.recv()? {
            WireFrame::Response { req_id, ticket, response } => Ok((req_id, ticket, response)),
            WireFrame::Error { code, message, .. } => {
                Err(Error::runtime(format!("server error [{code}]: {message}")))
            }
            f => Err(Error::protocol(format!("expected response, got {f:?}"))),
        }
    }

    /// Read the next frame, requiring a flush ack; returns its req_id.
    pub fn recv_flushed(&mut self) -> Result<u64> {
        match self.recv()? {
            WireFrame::Flushed { req_id } => Ok(req_id),
            WireFrame::Error { code, message, .. } => {
                Err(Error::runtime(format!("server error [{code}]: {message}")))
            }
            f => Err(Error::protocol(format!("expected flush ack, got {f:?}"))),
        }
    }

    /// One-at-a-time convenience: send, flush the model, read the
    /// response and the flush ack. Returns `(ticket, response)`.
    pub fn request_flushed(&mut self, model_id: &str, request: &Tensor) -> Result<(u64, Tensor)> {
        let req_id = self.send_request(model_id, request)?;
        self.send_flush(model_id)?;
        let (got, ticket, response) = self.recv_response()?;
        if got != req_id {
            return Err(Error::protocol(format!(
                "response correlation id {got} does not match request {req_id} (FIFO broken)"
            )));
        }
        self.recv_flushed()?;
        Ok((ticket, response))
    }

    /// Fetch one model's logical counters: `(next_ticket, in_flight,
    /// rejected, journal_appends)`. Call at a quiet point — the reply
    /// rides the same FIFO as responses.
    pub fn stats(&mut self, model_id: &str) -> Result<(u64, u64, u64, u64)> {
        let req_id = self.next_req_id();
        write_frame(
            &mut self.stream,
            &WireFrame::Stats { req_id, model_id: model_id.to_string() },
        )?;
        match self.recv()? {
            WireFrame::StatsReply { next_ticket, in_flight, rejected, journal_appends, .. } => {
                Ok((next_ticket, in_flight, rejected, journal_appends))
            }
            WireFrame::Error { code, message, .. } => {
                Err(Error::runtime(format!("server error [{code}]: {message}")))
            }
            f => Err(Error::protocol(format!("expected stats reply, got {f:?}"))),
        }
    }

    /// Orderly goodbye: tell the server we are done and close.
    pub fn bye(mut self) -> Result<()> {
        write_frame(&mut self.stream, &WireFrame::Bye)?;
        let _ = self.stream.shutdown(Shutdown::Both);
        Ok(())
    }
}
