//! Wire codec for the serve TCP front end (DESIGN.md §14).
//!
//! The network protocol reuses the journal's record framing verbatim:
//! every frame on the socket is `u32 LE len ‖ payload ‖
//! SHA-256(payload)` ([`super::journal::frame`]), and every payload is
//! `tag byte + LE fields` decoded through the journal's hardened
//! [`super::journal::Cursor`]. One codec, two transports — the framing
//! that makes journal files torn-tail-detectable makes socket streams
//! corruption-detectable, and hardening the shared decoder hardens
//! both.
//!
//! **Trust model.** Socket bytes are *untrusted*: a malformed frame
//! must never panic, never size an allocation from an unvalidated
//! length field, and never be mistaken for local journal corruption.
//! Frame payloads are bounded by [`MAX_WIRE_PAYLOAD`] *before*
//! allocation, every decode failure is surfaced as the typed
//! [`Error::Protocol`], and the per-frame digest rejects line noise
//! before the payload decoder ever runs.
//!
//! **Determinism scope.** The wire carries logical events only — no
//! timestamps, no connection ids reach any encoder — so everything
//! downstream of frame decode (ticket assignment, batch composition,
//! response bits) stays a pure function of the logical event sequence.
//! See [`super::net`] for the accept-order → ticket-order argument.

use super::journal::{frame, put_str, put_tensor, put_u32, put_u64, Cursor};
use super::registry::ModelInfo;
use crate::sha256::Sha256;
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::io::{Read, Write};

/// Hello magic: identifies a RepDL serve wire peer (8 bytes). Distinct
/// from the journal file magic — a journal shipped down a socket (or a
/// socket stream written to disk) must never parse as the other.
pub const WIRE_MAGIC: [u8; 8] = *b"REPDLNET";
/// Wire protocol version (bumped on any framing/payload change).
pub const WIRE_VERSION: u32 = 1;
/// Hard per-frame payload bound, enforced *before* any allocation on
/// the receive path. Generous for request/response tensors (16M f32
/// elements) while capping what a hostile length field can make the
/// server reserve.
pub const MAX_WIRE_PAYLOAD: usize = 64 * 1024 * 1024;
/// Digest length appended to every frame (same framing as the journal).
const DIGEST_LEN: usize = 32;

const TAG_HELLO_CLIENT: u8 = 0;
const TAG_HELLO_SERVER: u8 = 1;
const TAG_REQUEST: u8 = 2;
const TAG_RESPONSE: u8 = 3;
const TAG_FLUSH: u8 = 4;
const TAG_FLUSHED: u8 = 5;
const TAG_STATS: u8 = 6;
const TAG_STATS_REPLY: u8 = 7;
const TAG_ERROR: u8 = 8;
const TAG_BYE: u8 = 9;

/// Error codes carried in [`WireFrame::Error`] — strings, not numerics,
/// so a hand-rolled client can match them without a shared enum.
pub mod code {
    /// Malformed frame or protocol-order violation; the server closes
    /// the connection after sending this.
    pub const PROTOCOL: &str = "protocol";
    /// The request named a model id the registry does not serve.
    pub const UNKNOWN_MODEL: &str = "unknown-model";
    /// The request tensor failed the tower's validation (shape, token
    /// domain) — typed per request, the connection stays up.
    pub const BAD_REQUEST: &str = "bad-request";
    /// The scheduler was closed while the request was in flight.
    pub const CLOSED: &str = "closed";
    /// Server-side execution failure (tower error, journal fail-stop).
    pub const INTERNAL: &str = "internal";
}

/// One wire frame, as exchanged between [`super::net::NetClient`] and
/// [`super::net::NetServer`]. Every variant's encoding is a pure
/// function of its fields.
#[derive(Clone, Debug, PartialEq)]
pub enum WireFrame {
    /// First frame on every connection, client → server: magic +
    /// version. A server refuses a version it does not speak.
    HelloClient {
        /// The client's wire protocol version.
        version: u32,
    },
    /// The server's reply to a valid hello: its version and the full
    /// model table (id, weights fingerprint, shapes) — a client never
    /// guesses request shapes, and can verify cross-machine weight
    /// identity before comparing response bits.
    HelloServer {
        /// The server's wire protocol version.
        version: u32,
        /// Identity rows for every served model, in sorted-id order.
        models: Vec<ModelInfo>,
    },
    /// One inference request. `req_id` is a client-chosen correlation
    /// id echoed on the response — per-connection FIFO makes it
    /// redundant, but it keeps client bookkeeping trivial.
    Request {
        /// Client correlation id, echoed verbatim.
        req_id: u64,
        /// Routing id (see [`super::ModelRegistry::submit`]).
        model_id: String,
        /// The request tensor (shape-framed f32 bit patterns — exact).
        request: Tensor,
    },
    /// One inference response: the admission ticket the request drew
    /// (the server-side logical position, for audit against a journal)
    /// and the exact response bits.
    Response {
        /// Echoed client correlation id.
        req_id: u64,
        /// The server-side admission ticket this request was stamped
        /// with in its model's ticket space.
        ticket: u64,
        /// The response tensor.
        response: Tensor,
    },
    /// Explicit client-driven flush — the logical-clock latency control
    /// (`""` as the model id flushes every model). Answered with
    /// [`WireFrame::Flushed`] after the cut is published.
    Flush {
        /// Client correlation id, echoed on the `Flushed` reply.
        req_id: u64,
        /// Model to flush; empty string = all models.
        model_id: String,
    },
    /// Acknowledges a [`WireFrame::Flush`]: the cut is published.
    Flushed {
        /// Echoed client correlation id.
        req_id: u64,
    },
    /// Request one model's logical counters.
    Stats {
        /// Client correlation id, echoed on the reply.
        req_id: u64,
        /// Model to report on.
        model_id: String,
    },
    /// The counters — all logical (ticket arithmetic and append
    /// counts), so two identical runs report identical stats.
    StatsReply {
        /// Echoed client correlation id.
        req_id: u64,
        /// Next unassigned ticket (= admitted count).
        next_ticket: u64,
        /// Tickets admitted since the latest flush cut.
        in_flight: u64,
        /// Depth-cap rejections so far.
        rejected: u64,
        /// Journal records appended (0 when unjournaled).
        journal_appends: u64,
    },
    /// A typed failure for one request (or for the connection, when
    /// `code` is [`code::PROTOCOL`]). Never a panic, never a hang.
    Error {
        /// Echoed client correlation id (0 when no request parsed).
        req_id: u64,
        /// Machine-matchable error class (see [`code`]).
        code: String,
        /// Human-readable detail.
        message: String,
    },
    /// Orderly goodbye: the peer is done and will close.
    Bye,
}

/// Encode one frame's payload (tag byte + LE fields).
pub fn encode_frame(f: &WireFrame) -> Vec<u8> {
    let mut buf = Vec::new();
    match f {
        WireFrame::HelloClient { version } => {
            buf.push(TAG_HELLO_CLIENT);
            buf.extend_from_slice(&WIRE_MAGIC);
            put_u32(&mut buf, *version);
        }
        WireFrame::HelloServer { version, models } => {
            buf.push(TAG_HELLO_SERVER);
            put_u32(&mut buf, *version);
            put_u64(&mut buf, models.len() as u64);
            for m in models {
                put_str(&mut buf, &m.model_id);
                put_str(&mut buf, &m.weights_hash);
                put_u64(&mut buf, m.d_in);
                put_u64(&mut buf, m.d_out);
            }
        }
        WireFrame::Request { req_id, model_id, request } => {
            buf.push(TAG_REQUEST);
            put_u64(&mut buf, *req_id);
            put_str(&mut buf, model_id);
            put_tensor(&mut buf, request);
        }
        WireFrame::Response { req_id, ticket, response } => {
            buf.push(TAG_RESPONSE);
            put_u64(&mut buf, *req_id);
            put_u64(&mut buf, *ticket);
            put_tensor(&mut buf, response);
        }
        WireFrame::Flush { req_id, model_id } => {
            buf.push(TAG_FLUSH);
            put_u64(&mut buf, *req_id);
            put_str(&mut buf, model_id);
        }
        WireFrame::Flushed { req_id } => {
            buf.push(TAG_FLUSHED);
            put_u64(&mut buf, *req_id);
        }
        WireFrame::Stats { req_id, model_id } => {
            buf.push(TAG_STATS);
            put_u64(&mut buf, *req_id);
            put_str(&mut buf, model_id);
        }
        WireFrame::StatsReply { req_id, next_ticket, in_flight, rejected, journal_appends } => {
            buf.push(TAG_STATS_REPLY);
            put_u64(&mut buf, *req_id);
            put_u64(&mut buf, *next_ticket);
            put_u64(&mut buf, *in_flight);
            put_u64(&mut buf, *rejected);
            put_u64(&mut buf, *journal_appends);
        }
        WireFrame::Error { req_id, code, message } => {
            buf.push(TAG_ERROR);
            put_u64(&mut buf, *req_id);
            put_str(&mut buf, code);
            put_str(&mut buf, message);
        }
        WireFrame::Bye => buf.push(TAG_BYE),
    }
    buf
}

/// Re-class a shared-decoder failure for the wire: the cursor reports
/// [`Error::Journal`] (its trusted-file caller), but on the socket the
/// same defect is a peer protocol violation.
fn as_protocol(e: Error) -> Error {
    match e {
        Error::Journal(m) => Error::Protocol(m),
        other => other,
    }
}

/// Decode one digest-verified frame payload. Every failure is the typed
/// [`Error::Protocol`]; no path panics or allocates beyond the payload
/// it was handed (the shared cursor bounds every claimed length against
/// the remaining bytes first).
pub fn decode_frame(payload: &[u8]) -> Result<WireFrame> {
    let mut c = Cursor::new(payload);
    let f = match c.u8().map_err(as_protocol)? {
        TAG_HELLO_CLIENT => {
            let magic = c.bytes(8).map_err(as_protocol)?;
            if magic != WIRE_MAGIC {
                return Err(Error::protocol("bad hello magic — not a repdl wire peer"));
            }
            WireFrame::HelloClient { version: c.u32().map_err(as_protocol)? }
        }
        TAG_HELLO_SERVER => {
            let version = c.u32().map_err(as_protocol)?;
            let n = c.u64().map_err(as_protocol)?;
            // no capacity pre-reservation from the claimed count: each
            // decoded row consumes ≥ 32 payload bytes or errors, so
            // memory stays bounded by the (already-bounded) payload
            let mut models = Vec::new();
            for _ in 0..n {
                models.push(ModelInfo {
                    model_id: c.str().map_err(as_protocol)?,
                    weights_hash: c.str().map_err(as_protocol)?,
                    d_in: c.u64().map_err(as_protocol)?,
                    d_out: c.u64().map_err(as_protocol)?,
                });
            }
            WireFrame::HelloServer { version, models }
        }
        TAG_REQUEST => WireFrame::Request {
            req_id: c.u64().map_err(as_protocol)?,
            model_id: c.str().map_err(as_protocol)?,
            request: c.tensor().map_err(as_protocol)?,
        },
        TAG_RESPONSE => WireFrame::Response {
            req_id: c.u64().map_err(as_protocol)?,
            ticket: c.u64().map_err(as_protocol)?,
            response: c.tensor().map_err(as_protocol)?,
        },
        TAG_FLUSH => WireFrame::Flush {
            req_id: c.u64().map_err(as_protocol)?,
            model_id: c.str().map_err(as_protocol)?,
        },
        TAG_FLUSHED => WireFrame::Flushed { req_id: c.u64().map_err(as_protocol)? },
        TAG_STATS => WireFrame::Stats {
            req_id: c.u64().map_err(as_protocol)?,
            model_id: c.str().map_err(as_protocol)?,
        },
        TAG_STATS_REPLY => WireFrame::StatsReply {
            req_id: c.u64().map_err(as_protocol)?,
            next_ticket: c.u64().map_err(as_protocol)?,
            in_flight: c.u64().map_err(as_protocol)?,
            rejected: c.u64().map_err(as_protocol)?,
            journal_appends: c.u64().map_err(as_protocol)?,
        },
        TAG_ERROR => WireFrame::Error {
            req_id: c.u64().map_err(as_protocol)?,
            code: c.str().map_err(as_protocol)?,
            message: c.str().map_err(as_protocol)?,
        },
        TAG_BYE => WireFrame::Bye,
        tag => return Err(Error::protocol(format!("unknown wire frame tag {tag}"))),
    };
    c.done().map_err(as_protocol)?;
    Ok(f)
}

/// Write one frame to a socket (journal framing: `u32 LE len ‖ payload
/// ‖ SHA-256(payload)`), then flush the stream.
pub fn write_frame(w: &mut impl Write, f: &WireFrame) -> Result<()> {
    let payload = encode_frame(f);
    if payload.len() > MAX_WIRE_PAYLOAD {
        return Err(Error::protocol(format!(
            "outgoing frame payload of {} bytes exceeds MAX_WIRE_PAYLOAD ({MAX_WIRE_PAYLOAD})",
            payload.len()
        )));
    }
    let rec = frame(&payload).map_err(as_protocol)?;
    w.write_all(&rec)?;
    w.flush()?;
    Ok(())
}

/// Read one frame from a socket. Returns `Ok(None)` on a clean EOF at
/// a frame boundary (the peer closed between frames); every other
/// defect — EOF mid-frame, a length field past [`MAX_WIRE_PAYLOAD`], a
/// digest mismatch, a payload that fails [`decode_frame`] — is the
/// typed [`Error::Protocol`]. The length bound is enforced **before**
/// the payload buffer is allocated: a hostile 4-byte length prefix can
/// make this function read at most `MAX_WIRE_PAYLOAD + 32` bytes, never
/// reserve 4 GiB.
pub fn read_frame(r: &mut impl Read) -> Result<Option<WireFrame>> {
    // length prefix, tolerating clean EOF before its first byte
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(Error::protocol(format!(
                    "connection closed mid-frame ({got} of 4 length bytes)"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(Error::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_WIRE_PAYLOAD {
        return Err(Error::protocol(format!(
            "incoming frame claims {len} payload bytes, limit is {MAX_WIRE_PAYLOAD}"
        )));
    }
    let mut body = vec![0u8; len + DIGEST_LEN];
    r.read_exact(&mut body).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Error::protocol("connection closed mid-frame (short payload)")
        } else {
            Error::Io(e)
        }
    })?;
    let (payload, digest) = body.split_at(len);
    let mut h = Sha256::new();
    h.update(payload);
    if h.finalize().as_slice() != digest {
        return Err(Error::protocol("frame digest mismatch — corrupt or non-repdl stream"));
    }
    decode_frame(payload).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frames() -> Vec<WireFrame> {
        vec![
            WireFrame::HelloClient { version: WIRE_VERSION },
            WireFrame::HelloServer {
                version: WIRE_VERSION,
                models: vec![
                    ModelInfo {
                        model_id: "linear".into(),
                        weights_hash: "abc".into(),
                        d_in: 16,
                        d_out: 4,
                    },
                    ModelInfo {
                        model_id: "mlp".into(),
                        weights_hash: "def".into(),
                        d_in: 8,
                        d_out: 2,
                    },
                ],
            },
            WireFrame::Request {
                req_id: 7,
                model_id: "linear".into(),
                request: Tensor::from_vec(&[3], vec![1.5, -0.0, f32::NAN]).unwrap(),
            },
            WireFrame::Response {
                req_id: 7,
                ticket: 42,
                response: Tensor::from_vec(&[2], vec![0.25, -3.0]).unwrap(),
            },
            WireFrame::Flush { req_id: 8, model_id: String::new() },
            WireFrame::Flushed { req_id: 8 },
            WireFrame::Stats { req_id: 9, model_id: "linear".into() },
            WireFrame::StatsReply {
                req_id: 9,
                next_ticket: 5,
                in_flight: 1,
                rejected: 0,
                journal_appends: 11,
            },
            WireFrame::Error { req_id: 3, code: code::BAD_REQUEST.into(), message: "len".into() },
            WireFrame::Bye,
        ]
    }

    #[test]
    fn frames_roundtrip_bit_exactly_over_a_byte_stream() {
        let fs = frames();
        let mut stream = Vec::new();
        for f in &fs {
            write_frame(&mut stream, f).unwrap();
        }
        let mut r = &stream[..];
        for want in &fs {
            let got = read_frame(&mut r).unwrap().expect("frame expected");
            match (&got, want) {
                // NaN != NaN under PartialEq; compare tensor bits
                (
                    WireFrame::Request { req_id: a, model_id: m1, request: r1 },
                    WireFrame::Request { req_id: b, model_id: m2, request: r2 },
                ) => {
                    assert_eq!((a, m1), (b, m2));
                    assert!(r1.bit_eq(r2), "request bits must survive the roundtrip");
                }
                _ => assert_eq!(&got, want),
            }
        }
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF at a frame boundary");
        // encoding is a pure function of the frame
        let mut again = Vec::new();
        for f in &fs {
            write_frame(&mut again, f).unwrap();
        }
        assert_eq!(stream, again);
    }

    #[test]
    fn hostile_length_fields_never_reserve_memory() {
        // a 4 GiB length claim must be refused before allocation
        let mut hostile = Vec::new();
        hostile.extend_from_slice(&u32::MAX.to_le_bytes());
        hostile.extend_from_slice(&[0u8; 64]);
        match read_frame(&mut &hostile[..]) {
            Err(Error::Protocol(m)) => assert!(m.contains("limit"), "{m}"),
            other => panic!("want Error::Protocol, got {other:?}"),
        }
    }

    #[test]
    fn torn_and_corrupt_frames_are_typed_protocol_errors() {
        let mut stream = Vec::new();
        write_frame(&mut stream, &WireFrame::Flushed { req_id: 1 }).unwrap();
        // EOF mid-length and mid-payload
        for cut in [2usize, 10] {
            assert!(
                matches!(read_frame(&mut &stream[..cut]), Err(Error::Protocol(_))),
                "cut at {cut}"
            );
        }
        // a flipped payload bit fails the digest
        let mut bent = stream.clone();
        bent[5] ^= 0x10;
        assert!(matches!(read_frame(&mut &bent[..]), Err(Error::Protocol(_))));
        // an unknown tag inside a digest-valid frame
        let rec = frame(&[0xEE]).unwrap();
        match read_frame(&mut &rec[..]) {
            Err(Error::Protocol(m)) => assert!(m.contains("unknown wire frame tag"), "{m}"),
            other => panic!("want Error::Protocol, got {other:?}"),
        }
        // a wrong hello magic
        let mut hello = vec![0u8]; // TAG_HELLO_CLIENT
        hello.extend_from_slice(b"NOTREPDL");
        hello.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        let rec = frame(&hello).unwrap();
        match read_frame(&mut &rec[..]) {
            Err(Error::Protocol(m)) => assert!(m.contains("bad hello magic"), "{m}"),
            other => panic!("want Error::Protocol, got {other:?}"),
        }
    }

    #[test]
    fn prop_mutated_wire_streams_never_panic() {
        // the wire face of the shared-decoder fuzz: flips and
        // truncations of a valid frame stream must always come back as
        // a decoded frame, a clean EOF, or a typed error — never a
        // panic, never an allocation sized by a hostile length
        let mut base = Vec::new();
        for f in frames() {
            write_frame(&mut base, &f).unwrap();
        }
        crate::proptest::forall(
            0xBEEF,
            400,
            |g| {
                let mut bytes = base.clone();
                let cut = g.below(bytes.len() + 1);
                bytes.truncate(cut);
                for _ in 0..g.below(5) {
                    if bytes.is_empty() {
                        break;
                    }
                    let i = g.below(bytes.len());
                    bytes[i] ^= 1 << g.below(8);
                }
                bytes
            },
            |bytes| {
                let mut r = &bytes[..];
                loop {
                    match read_frame(&mut r) {
                        Ok(Some(_)) => continue,
                        Ok(None) => return true,
                        Err(Error::Protocol(_)) => return true,
                        Err(_) => return false,
                    }
                }
            },
        );
    }
}
