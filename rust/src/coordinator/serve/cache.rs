//! Content-addressed memo cache for served responses.
//!
//! Keyed by the request's SHA-256 content address
//! ([`crate::coordinator::hashing::hash_tensor`]); a hit returns a clone
//! of the stored response tensor — **bit-identical** to recomputation by
//! construction, because the stored response was itself produced by the
//! batch-invariant kernels (any batch composition yields the same
//! per-request bits, so "the batch that filled the cache" and "the batch
//! that would have recomputed" agree on every bit).
//!
//! Eviction is deterministic *logical-clock* FIFO: each entry carries
//! the ticket of the request that inserted it, and when the cache is
//! over capacity the entry with the **smallest insertion ticket** is
//! evicted. No wall-clock LRU: which entries a cache holds after a given
//! insert sequence is a pure function of the (key, ticket) pairs
//! inserted — never of when lookups happened. A hit does not refresh an
//! entry (that would reintroduce access-order — i.e. timing — into the
//! eviction decision), and a duplicate insert keeps the existing entry
//! (first insertion wins, the same first-occurrence discipline as the
//! `max_wins` comparison rule).
//!
//! Scope of the determinism claim: the eviction *rule* is a pure
//! function of the insert sequence it is fed. With a **single shard**
//! (one dispatcher) that sequence is itself event-sequence-pure, so
//! contents and hit/miss/eviction counters are fully reproducible. With
//! multiple shards, concurrent dispatchers interleave their inserts in
//! thread-timing order, so under eviction pressure *which* lookups hit —
//! the counters, never the bits — can vary run to run; served bits stay
//! identical in every case because a hit is bit-equal to recomputation.
//! (The deterministic-stats bench cells therefore run single-shard.)
//!
//! The scheduler consults the cache at **dispatch** time, not at submit
//! time: hits and misses travel through the same ticket/batch machinery,
//! so admission arithmetic, batch composition and the executed trace are
//! identical with the cache on or off — only the arithmetic actually
//! performed shrinks (DESIGN.md §8).

use super::lock_recover;
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Cache occupancy and traffic counters (all monotone except `len`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that fell through to computation.
    pub misses: u64,
    /// Entries evicted by the capacity rule.
    pub evictions: u64,
    /// Entries currently held.
    pub len: usize,
    /// Maximum entries held (the capacity rule's bound).
    pub capacity: usize,
}

struct CacheInner {
    /// request-hash → (insertion ticket, response).
    by_key: BTreeMap<String, (u64, Tensor)>,
    /// insertion ticket → request-hash (the deterministic eviction order).
    by_ticket: BTreeMap<u64, String>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Thread-safe memo cache (see module docs). `BTreeMap`s on both
/// indices, so even internal iteration order is deterministic — no
/// hash-seed dependence anywhere.
pub struct MemoCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
}

impl MemoCache {
    /// New cache holding at most `capacity` responses (`capacity ≥ 1`;
    /// a capacity of zero means "no cache" and is handled by the
    /// scheduler never constructing one).
    pub fn new(capacity: usize) -> MemoCache {
        MemoCache {
            inner: Mutex::new(CacheInner {
                by_key: BTreeMap::new(),
                by_ticket: BTreeMap::new(),
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
            capacity: capacity.max(1),
        }
    }

    /// Look up a request by content address. Counts a hit or a miss;
    /// deliberately does **not** refresh the entry's eviction position.
    pub fn lookup(&self, key: &str) -> Option<Tensor> {
        let mut inner = lock_recover(&self.inner);
        let hit = inner.by_key.get(key).map(|(_, response)| response.clone());
        match hit {
            Some(r) => {
                inner.hits += 1;
                Some(r)
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Insert a computed response under the inserting request's ticket.
    /// Duplicate keys — and duplicate tickets, which the scheduler never
    /// produces but an external caller could — keep the existing entry
    /// (first insertion wins on both axes, so the two indices can never
    /// fall out of lockstep); over capacity, the smallest-ticket entry
    /// is evicted.
    pub fn insert(&self, key: &str, ticket: u64, response: &Tensor) {
        let mut inner = lock_recover(&self.inner);
        if inner.by_key.contains_key(key) || inner.by_ticket.contains_key(&ticket) {
            return;
        }
        inner.by_key.insert(key.to_string(), (ticket, response.clone()));
        inner.by_ticket.insert(ticket, key.to_string());
        while inner.by_key.len() > self.capacity {
            // deterministic: evict the smallest insertion ticket present
            let (&t, _) = inner.by_ticket.iter().next().unwrap();
            let victim = inner.by_ticket.remove(&t).unwrap();
            inner.by_key.remove(&victim);
            inner.evictions += 1;
        }
    }

    /// Current counters and occupancy.
    pub fn stats(&self) -> CacheStats {
        let inner = lock_recover(&self.inner);
        CacheStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            len: inner.by_key.len(),
            capacity: self.capacity,
        }
    }

    /// The keys currently held, in insertion-ticket order — exposed so
    /// tests can pin the eviction rule as a pure function of tickets.
    pub fn held_keys_by_ticket(&self) -> Vec<(u64, String)> {
        let inner = lock_recover(&self.inner);
        inner.by_ticket.iter().map(|(&t, k)| (t, k.clone())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(v: f32) -> Tensor {
        Tensor::from_vec(&[2], vec![v, v + 1.0]).unwrap()
    }

    #[test]
    fn hit_returns_bit_identical_response() {
        let c = MemoCache::new(4);
        let r = Tensor::from_vec(&[3], vec![0.1, -0.0, f32::from_bits(0x7fc0_0007)]).unwrap();
        c.insert("k", 5, &r);
        let got = c.lookup("k").unwrap();
        assert!(got.bit_eq(&r), "hit must preserve every bit, -0.0 and NaN payload included");
        assert!(c.lookup("absent").is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.len), (1, 1, 1));
    }

    #[test]
    fn eviction_is_a_pure_function_of_insertion_tickets() {
        // capacity 3, inserts at tickets 10, 2, 7, 20, 15: after each
        // overflow the smallest ticket present is evicted — regardless of
        // the order the inserts arrived in
        let orders: [&[(u64, &str)]; 2] = [
            &[(10, "a"), (2, "b"), (7, "c"), (20, "d"), (15, "e")],
            &[(20, "d"), (2, "b"), (15, "e"), (10, "a"), (7, "c")],
        ];
        let mut finals = Vec::new();
        for inserts in orders {
            let c = MemoCache::new(3);
            for &(t, k) in inserts {
                c.insert(k, t, &resp(t as f32));
            }
            finals.push(c.held_keys_by_ticket());
        }
        // the held set is the 3 largest insertion tickets, whatever the
        // arrival interleaving was
        assert_eq!(finals[0], finals[1]);
        let keys: Vec<u64> = finals[0].iter().map(|(t, _)| *t).collect();
        assert_eq!(keys, vec![10, 15, 20]);
        let c = MemoCache::new(3);
        for &(t, k) in orders[0] {
            c.insert(k, t, &resp(t as f32));
        }
        assert_eq!(c.stats().evictions, 2);
    }

    #[test]
    fn duplicate_ticket_with_distinct_key_is_dropped_not_desynced() {
        // the scheduler never reuses a ticket, but MemoCache is public:
        // a ticket collision must not desync by_key/by_ticket (which
        // would leave unevictable entries and could drain by_ticket
        // empty while by_key is over capacity → eviction panic)
        let c = MemoCache::new(1);
        c.insert("a", 5, &resp(1.0));
        c.insert("b", 5, &resp(2.0)); // same ticket, different key: dropped
        c.insert("c", 5, &resp(3.0));
        assert!(c.lookup("a").unwrap().bit_eq(&resp(1.0)));
        assert!(c.lookup("b").is_none() && c.lookup("c").is_none());
        assert_eq!(c.held_keys_by_ticket(), vec![(5, "a".to_string())]);
        // and eviction still works past the collision
        c.insert("d", 9, &resp(4.0));
        assert_eq!(c.held_keys_by_ticket(), vec![(9, "d".to_string())]);
    }

    #[test]
    fn duplicate_insert_keeps_first_and_hits_do_not_refresh() {
        let c = MemoCache::new(2);
        c.insert("x", 1, &resp(1.0));
        c.insert("x", 9, &resp(9.0)); // duplicate key: first wins
        assert!(c.lookup("x").unwrap().bit_eq(&resp(1.0)));
        c.insert("y", 2, &resp(2.0));
        // many hits on x must NOT save it: eviction ignores access order
        for _ in 0..10 {
            c.lookup("x").unwrap();
        }
        c.insert("z", 3, &resp(3.0));
        assert!(c.lookup("x").is_none(), "x held the smallest ticket: evicted");
        assert!(c.lookup("y").is_some() && c.lookup("z").is_some());
    }
}
