//! Deterministic fault injection for the serve journal and towers
//! (DESIGN.md §11).
//!
//! The empirical bug study in PAPERS.md (arXiv 2109.03991) finds that
//! reproducibility failures in practice come as much from crash /
//! restart / state-handling bugs as from numerics. Pinning that class
//! needs faults that are themselves reproducible: a [`FaultPlan`] is
//! keyed **only by logical counters** — fail the Nth journal append,
//! short-write the Nth record to K bytes, panic the tower at ticket t —
//! never by randomness, wall time or thread identity, so a failing
//! fault cell re-runs identically under `cargo test` forever.
//!
//! The injection points mirror the two real-world failure surfaces:
//!
//! * **Journal I/O** — [`FaultyWriter`] wraps any
//!   [`super::journal::JournalWriter`] and counts appends; the wrapped
//!   writer is what [`super::ServeConfig`] threads into the scheduler,
//!   so production code pays exactly one vtable indirection whether or
//!   not faults are armed.
//! * **Model execution** — [`PanicAtTicket`] wraps any
//!   [`ModelTower`] and panics inside the ticketed dispatch path at one
//!   chosen ticket, standing in for any latent bug reached inside a
//!   dispatcher thread (the panic-shield and lock-poisoning suites
//!   drive it). The non-ticketed path (replay, recovery re-execution)
//!   is deliberately left intact: replay audits numerics, not bugs.

use super::journal::JournalWriter;
use super::session::SessionStats;
use super::tower::ModelTower;
use crate::tensor::{Tensor, WorkerPool};
use crate::Result;

/// A deterministic fault schedule, keyed by logical counters only.
/// `Default` is the empty plan (no faults), so a [`FaultyWriter`] with
/// a default plan is byte-transparent.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Fail the Nth append (0-based) with an I/O error, writing
    /// nothing.
    pub fail_append: Option<u64>,
    /// Short-write the Nth append (0-based): persist only the first K
    /// bytes of the record, then report an I/O error — the on-disk
    /// signature of a crash mid-`write`.
    pub short_append: Option<(u64, usize)>,
}

impl FaultPlan {
    /// The empty plan.
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Fail the `n`-th append outright.
    pub fn fail_append(mut self, n: u64) -> FaultPlan {
        self.fail_append = Some(n);
        self
    }

    /// Truncate the `n`-th append to its first `k` bytes.
    pub fn short_append(mut self, n: u64, k: usize) -> FaultPlan {
        self.short_append = Some((n, k));
        self
    }
}

fn injected(what: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::Other, format!("injected fault: {what}"))
}

/// A [`JournalWriter`] that executes a [`FaultPlan`] against an inner
/// writer. The append counter is the writer's own — deterministic
/// because the scheduler appends gate-ordered records under one lock
/// and drains buffered responses in ticket order.
pub struct FaultyWriter {
    inner: Box<dyn JournalWriter>,
    plan: FaultPlan,
    appends: u64,
}

impl FaultyWriter {
    /// Wrap `inner` under `plan`.
    pub fn new(inner: Box<dyn JournalWriter>, plan: FaultPlan) -> FaultyWriter {
        FaultyWriter { inner, plan, appends: 0 }
    }
}

impl JournalWriter for FaultyWriter {
    fn append(&mut self, record: &[u8]) -> std::io::Result<()> {
        let n = self.appends;
        self.appends += 1;
        if self.plan.fail_append == Some(n) {
            return Err(injected("append failure"));
        }
        if let Some((m, k)) = self.plan.short_append {
            if m == n {
                self.inner.append(&record[..k.min(record.len())])?;
                return Err(injected("short write"));
            }
        }
        self.inner.append(record)
    }
    fn sync(&mut self) -> std::io::Result<()> {
        self.inner.sync()
    }
}

/// A [`ModelTower`] that panics when the **ticketed** dispatch path
/// serves `ticket` — a deterministic stand-in for a latent bug inside a
/// dispatcher thread. Everything else (identity, validation, the
/// non-ticketed `forward_batch` used by replay and recovery) delegates
/// untouched, so the wrapped tower's bits are the wrapped tower's bits.
pub struct PanicAtTicket<T> {
    inner: T,
    ticket: u64,
}

impl<T: ModelTower> PanicAtTicket<T> {
    /// Panic when `ticket` reaches the ticketed dispatch path of
    /// `inner`.
    pub fn new(inner: T, ticket: u64) -> PanicAtTicket<T> {
        PanicAtTicket { inner, ticket }
    }

    /// The wrapped tower.
    pub fn inner(&self) -> &T {
        &self.inner
    }
}

impl<T: ModelTower> ModelTower for PanicAtTicket<T> {
    fn model_id(&self) -> &str {
        self.inner.model_id()
    }
    fn d_in(&self) -> usize {
        self.inner.d_in()
    }
    fn d_out(&self) -> usize {
        self.inner.d_out()
    }
    fn weights_hash(&self) -> &str {
        self.inner.weights_hash()
    }
    fn forward_batch(&self, pool: &WorkerPool, batch: &[Tensor]) -> Result<Vec<Tensor>> {
        self.inner.forward_batch(pool, batch)
    }
    fn validate_request(&self, request: &Tensor) -> Result<()> {
        self.inner.validate_request(request)
    }
    fn forward_batch_ticketed(
        &self,
        pool: &WorkerPool,
        batch: &[Tensor],
        tickets: &[u64],
    ) -> Result<Vec<Tensor>> {
        if tickets.contains(&self.ticket) {
            panic!("injected tower panic at ticket {}", self.ticket);
        }
        self.inner.forward_batch_ticketed(pool, batch, tickets)
    }
    fn session_stats(&self) -> Option<SessionStats> {
        self.inner.session_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::super::journal::{
        parse_records, Journal, JournalEvent, JournalPolicy, VecWriter,
    };
    use super::super::lock_recover;
    use super::*;
    use std::sync::{Arc, Mutex};

    fn buf_journal(plan: FaultPlan, policy: JournalPolicy) -> (Journal, Arc<Mutex<Vec<u8>>>) {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let writer = FaultyWriter::new(Box::new(VecWriter::new(Arc::clone(&buf))), plan);
        (Journal::with_writer(Box::new(writer), policy), buf)
    }

    #[test]
    fn an_empty_plan_is_byte_transparent() {
        let (faulty, fb) = buf_journal(FaultPlan::new(), JournalPolicy::FailStop);
        let clean = Arc::new(Mutex::new(Vec::new()));
        let plain = Journal::with_writer(
            Box::new(VecWriter::new(Arc::clone(&clean))),
            JournalPolicy::FailStop,
        );
        for j in [&faulty, &plain] {
            j.append_flush(1).unwrap();
            j.append_truncate(0).unwrap();
            j.sync().unwrap();
        }
        assert_eq!(*lock_recover(&fb), *lock_recover(&clean));
    }

    #[test]
    fn fail_stop_surfaces_the_nth_append_and_latches() {
        let (j, buf) = buf_journal(FaultPlan::new().fail_append(1), JournalPolicy::FailStop);
        j.append_flush(1).unwrap();
        let e = j.append_flush(2).unwrap_err();
        assert!(format!("{e}").contains("injected fault"), "{e}");
        // latched: later appends fail with the original cause, and the
        // stream still holds exactly the pre-fault record
        assert!(j.append_flush(3).is_err());
        let s = j.stats();
        assert!(s.failed);
        assert_eq!(s.appends, 1);
        let (evs, _) = parse_records(&lock_recover(&buf)[..]).unwrap();
        assert_eq!(evs, vec![JournalEvent::FlushCut { upto: 1 }]);
    }

    #[test]
    fn degrade_to_memory_counts_every_drop_and_never_errors() {
        let (j, buf) =
            buf_journal(FaultPlan::new().fail_append(0), JournalPolicy::DegradeToMemory);
        j.append_flush(1).unwrap();
        j.append_flush(2).unwrap();
        j.buffer_failed(0);
        j.sync().unwrap();
        let s = j.stats();
        assert!(!s.failed);
        assert_eq!(s.appends, 0);
        assert_eq!(s.drops, 3, "the tripped writer counts every unpersisted record");
        assert!(lock_recover(&buf).is_empty());
    }

    #[test]
    fn a_short_append_leaves_a_recoverable_torn_tail() {
        let (j, buf) =
            buf_journal(FaultPlan::new().short_append(1, 5), JournalPolicy::DegradeToMemory);
        j.append_flush(1).unwrap();
        j.append_flush(2).unwrap(); // short-written: 5 bytes of frame land
        j.append_flush(3).unwrap(); // degraded: dropped, counted
        let (evs, valid) = parse_records(&lock_recover(&buf)[..]).unwrap();
        assert_eq!(evs, vec![JournalEvent::FlushCut { upto: 1 }]);
        assert_eq!(lock_recover(&buf).len() - valid, 5, "the torn 5 bytes are detected");
        // the short-written record and the post-trip record both count
        assert_eq!(j.stats().drops, 2);
    }
}
