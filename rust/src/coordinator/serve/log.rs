//! Ticket-addressed response log — the serve scheduler's audit trail.
//!
//! Every answered request appends one [`LogEntry`]: its ticket, the
//! SHA-256 content address of the request and of the response
//! ([`crate::coordinator::hashing::hash_tensor`] — shape-framed raw f32
//! bit patterns), and the id of the batch that served it (`batch_id` =
//! the batch's first ticket, itself a pure function of the submit/flush
//! event sequence). The request tensor is retained so a later audit can
//! *re-execute* it: [`super::ServeScheduler::replay`] walks a ticket
//! range, runs each logged request as a singleton batch on the shard
//! that originally served it, and verifies bit-equality against the
//! logged response hash — batch invariance is what makes a singleton
//! re-execution a valid check of a batched original.
//!
//! Entries are keyed by ticket in a `BTreeMap`, so iteration order is
//! ticket order regardless of which shard's dispatcher recorded first.
//! The log records only *answered* requests: a batch that fails
//! (exceptional — shapes are validated at submit) logs nothing, and
//! rejected/closed submissions never reach a batch at all.

use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::Mutex;

/// One served request, as recorded by the shard dispatcher that
/// answered it.
#[derive(Clone, Debug)]
pub struct LogEntry {
    /// Arrival ticket (the log's address).
    pub ticket: u64,
    /// The request tensor itself, retained for replay.
    pub request: Tensor,
    /// Content address of the request (`hash_tensor`).
    pub request_hash: String,
    /// Content address of the response that was sent.
    pub response_hash: String,
    /// First ticket of the batch that served this request — a pure
    /// function of the submit/flush event sequence, so two runs with the
    /// same events log identical batch ids.
    pub batch_id: u64,
}

/// Thread-safe ticket-addressed log (see module docs). Shared by the
/// shard dispatchers via `Arc`; all reads return clones so no caller
/// ever holds the internal lock across its own work.
#[derive(Default)]
pub struct ResponseLog {
    entries: Mutex<BTreeMap<u64, LogEntry>>,
}

impl ResponseLog {
    /// Empty log.
    pub fn new() -> ResponseLog {
        ResponseLog::default()
    }

    /// Append one entry (dispatcher-side). A ticket is answered exactly
    /// once, so an existing entry for the same ticket would indicate a
    /// scheduler bug — the first record wins and the duplicate is
    /// dropped, keeping the log append-only.
    pub fn record(&self, entry: LogEntry) {
        self.entries.lock().unwrap().entry(entry.ticket).or_insert(entry);
    }

    /// Entry for one ticket, if that ticket has been answered.
    pub fn get(&self, ticket: u64) -> Option<LogEntry> {
        self.entries.lock().unwrap().get(&ticket).cloned()
    }

    /// Logged entries with tickets in `range`, in ticket order.
    pub fn range(&self, range: Range<u64>) -> Vec<LogEntry> {
        self.entries.lock().unwrap().range(range).map(|(_, e)| e.clone()).collect()
    }

    /// Number of answered requests recorded.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.entries.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::hashing::hash_tensor;

    fn entry(ticket: u64, v: f32, batch_id: u64) -> LogEntry {
        let request = Tensor::from_vec(&[2], vec![v, -v]).unwrap();
        let response = Tensor::from_vec(&[1], vec![v * 2.0]).unwrap();
        LogEntry {
            ticket,
            request_hash: hash_tensor(&request),
            response_hash: hash_tensor(&response),
            request,
            batch_id,
        }
    }

    #[test]
    fn range_is_ticket_ordered_regardless_of_record_order() {
        let log = ResponseLog::new();
        for t in [5u64, 1, 3, 0, 4, 2] {
            log.record(entry(t, t as f32, t / 2));
        }
        let got: Vec<u64> = log.range(0..6).iter().map(|e| e.ticket).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
        let mid: Vec<u64> = log.range(2..5).iter().map(|e| e.ticket).collect();
        assert_eq!(mid, vec![2, 3, 4]);
        assert_eq!(log.len(), 6);
        assert!(log.get(3).is_some());
        assert!(log.get(9).is_none());
    }

    #[test]
    fn duplicate_tickets_keep_the_first_record() {
        let log = ResponseLog::new();
        log.record(entry(7, 1.0, 7));
        let first_hash = log.get(7).unwrap().response_hash.clone();
        log.record(entry(7, 2.0, 7)); // would be a scheduler bug; dropped
        assert_eq!(log.len(), 1);
        assert_eq!(log.get(7).unwrap().response_hash, first_hash);
    }
}
