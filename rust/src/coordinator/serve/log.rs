//! Ticket-addressed response log — the serve scheduler's audit trail.
//!
//! Every answered request appends one [`LogEntry`]: its ticket, the
//! SHA-256 content address of the request and of the response
//! ([`crate::coordinator::hashing::hash_tensor`] — shape-framed raw f32
//! bit patterns), and the id of the batch that served it (`batch_id` =
//! the batch's first ticket, itself a pure function of the submit/flush
//! event sequence). The request tensor is retained so a later audit can
//! *re-execute* it: [`super::ServeScheduler::replay`] walks a ticket
//! range, runs each logged request as a singleton batch on the shard
//! that originally served it, and verifies bit-equality against the
//! logged response hash — batch invariance is what makes a singleton
//! re-execution a valid check of a batched original.
//!
//! Entries are keyed by ticket in a `BTreeMap`, so iteration order is
//! ticket order regardless of which shard's dispatcher recorded first.
//! The log records only *answered* requests: a batch that fails
//! (exceptional — shapes are validated at submit) logs nothing, and
//! rejected/closed submissions never reach a batch at all.
//!
//! **Rotation.** The log retains request tensors, so an unbounded
//! long-lived server would grow without limit. [`ResponseLog::
//! truncate_below`] drops every entry under a replay **watermark** — a
//! ticket count, the same logical-clock currency as flush cuts — and
//! the watermark is remembered: replaying a truncated ticket afterwards
//! is the typed [`crate::Error::Truncated`], never a silent
//! "0 entries verified". Entries at or above the watermark are
//! untouched and still replay bit-exactly.

use super::lock_recover;
use crate::tensor::Tensor;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::Mutex;

/// One served request, as recorded by the shard dispatcher that
/// answered it.
#[derive(Clone, Debug)]
pub struct LogEntry {
    /// Arrival ticket (the log's address).
    pub ticket: u64,
    /// The request tensor itself, retained for replay.
    pub request: Tensor,
    /// Content address of the request (`hash_tensor`).
    pub request_hash: String,
    /// Content address of the response that was sent.
    pub response_hash: String,
    /// First ticket of the batch that served this request — a pure
    /// function of the submit/flush event sequence, so two runs with the
    /// same events log identical batch ids.
    pub batch_id: u64,
    /// Parameter fingerprint of the model that served this request
    /// ([`crate::coordinator::serve::ModelTower::weights_hash`]) — so a
    /// log entry can never be replayed, or verified, against a
    /// different model's tower.
    pub weights_hash: String,
}

#[derive(Default)]
struct LogInner {
    entries: BTreeMap<u64, LogEntry>,
    /// Lowest ticket still eligible for retention: everything below has
    /// been dropped by [`ResponseLog::truncate_below`]. Monotone.
    watermark: u64,
    /// Records that arrived *after* a truncation had already raised the
    /// watermark past their ticket — an answered request with no audit
    /// record. Zero unless a truncation raced in-flight work; exposed so
    /// an aggressive rotation can never silently cost audit coverage.
    late_drops: u64,
}

/// Thread-safe ticket-addressed log (see module docs). Shared by the
/// shard dispatchers via `Arc`; all reads return clones so no caller
/// ever holds the internal lock across its own work.
#[derive(Default)]
pub struct ResponseLog {
    inner: Mutex<LogInner>,
}

impl ResponseLog {
    /// Empty log.
    pub fn new() -> ResponseLog {
        ResponseLog::default()
    }

    /// Append one entry (dispatcher-side). A ticket is answered exactly
    /// once, so an existing entry for the same ticket would indicate a
    /// scheduler bug — the first record wins and the duplicate is
    /// dropped, keeping the log append-only. Entries below the
    /// truncation watermark are dropped too — a truncated range cannot
    /// be resurrected — but counted in [`Self::late_drops`]: a
    /// truncation that overtakes a still-in-flight ticket (the batch
    /// executes *after* the rotation) silently losing that request's
    /// audit record would be unobservable otherwise.
    pub fn record(&self, entry: LogEntry) {
        let mut inner = lock_recover(&self.inner);
        if entry.ticket < inner.watermark {
            inner.late_drops += 1;
            return;
        }
        inner.entries.entry(entry.ticket).or_insert(entry);
    }

    /// Entry for one ticket, if that ticket has been answered.
    pub fn get(&self, ticket: u64) -> Option<LogEntry> {
        lock_recover(&self.inner).entries.get(&ticket).cloned()
    }

    /// Logged entries with tickets in `range`, in ticket order.
    pub fn range(&self, range: Range<u64>) -> Vec<LogEntry> {
        lock_recover(&self.inner).entries.range(range).map(|(_, e)| e.clone()).collect()
    }

    /// [`Self::range`] with the truncation-watermark check done under
    /// the **same lock acquisition** as the read: errors with the typed
    /// [`Error::Truncated`] when `range.start` falls below the
    /// watermark. Checking and reading separately would leave a window
    /// for a concurrent [`Self::truncate_below`] to rotate part of the
    /// range away between the two — and a half-rotated audit range must
    /// error, never silently shrink to a passing replay.
    pub fn range_checked(&self, range: Range<u64>) -> Result<Vec<LogEntry>> {
        let inner = lock_recover(&self.inner);
        if range.start < inner.watermark {
            return Err(Error::Truncated { ticket: range.start, watermark: inner.watermark });
        }
        Ok(inner.entries.range(range).map(|(_, e)| e.clone()).collect())
    }

    /// Number of answered requests recorded (and still retained).
    pub fn len(&self) -> usize {
        lock_recover(&self.inner).entries.len()
    }

    /// True when nothing is retained.
    pub fn is_empty(&self) -> bool {
        lock_recover(&self.inner).entries.is_empty()
    }

    /// Drop every retained entry with `ticket < watermark` and raise
    /// the truncation watermark (monotone: a lower watermark than the
    /// current one is a no-op). Returns the number of entries dropped.
    /// The watermark is a ticket count — the same logical-clock
    /// currency as flush cuts — so *what a rotated log still proves* is
    /// a pure function of the event sequence plus the explicit
    /// truncation calls, never of wall time.
    pub fn truncate_below(&self, watermark: u64) -> usize {
        let mut inner = lock_recover(&self.inner);
        if watermark <= inner.watermark {
            return 0;
        }
        inner.watermark = watermark;
        let keep = inner.entries.split_off(&watermark);
        let dropped = inner.entries.len();
        inner.entries = keep;
        dropped
    }

    /// The current truncation watermark: tickets below it have been
    /// dropped and can no longer be replayed (0 = nothing truncated).
    pub fn watermark(&self) -> u64 {
        lock_recover(&self.inner).watermark
    }

    /// How many served requests arrived for recording after a
    /// truncation had already passed their ticket (see [`Self::record`]).
    /// Non-zero means some answered requests have no audit record.
    pub fn late_drops(&self) -> u64 {
        lock_recover(&self.inner).late_drops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::hashing::hash_tensor;

    fn entry(ticket: u64, v: f32, batch_id: u64) -> LogEntry {
        let request = Tensor::from_vec(&[2], vec![v, -v]).unwrap();
        let response = Tensor::from_vec(&[1], vec![v * 2.0]).unwrap();
        LogEntry {
            ticket,
            request_hash: hash_tensor(&request),
            response_hash: hash_tensor(&response),
            request,
            batch_id,
            weights_hash: "test-weights".to_string(),
        }
    }

    #[test]
    fn range_is_ticket_ordered_regardless_of_record_order() {
        let log = ResponseLog::new();
        for t in [5u64, 1, 3, 0, 4, 2] {
            log.record(entry(t, t as f32, t / 2));
        }
        let got: Vec<u64> = log.range(0..6).iter().map(|e| e.ticket).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
        let mid: Vec<u64> = log.range(2..5).iter().map(|e| e.ticket).collect();
        assert_eq!(mid, vec![2, 3, 4]);
        assert_eq!(log.len(), 6);
        assert!(log.get(3).is_some());
        assert!(log.get(9).is_none());
    }

    #[test]
    fn duplicate_tickets_keep_the_first_record() {
        let log = ResponseLog::new();
        log.record(entry(7, 1.0, 7));
        let first_hash = log.get(7).unwrap().response_hash.clone();
        log.record(entry(7, 2.0, 7)); // would be a scheduler bug; dropped
        assert_eq!(log.len(), 1);
        assert_eq!(log.get(7).unwrap().response_hash, first_hash);
    }

    #[test]
    fn truncate_below_drops_exactly_the_sub_watermark_range() {
        let log = ResponseLog::new();
        for t in 0..10u64 {
            log.record(entry(t, t as f32, t));
        }
        assert_eq!(log.watermark(), 0);
        assert_eq!(log.truncate_below(4), 4, "tickets 0..4 dropped");
        assert_eq!(log.watermark(), 4);
        assert_eq!(log.len(), 6);
        assert!(log.get(3).is_none());
        assert!(log.get(4).is_some());
        // the retained range is bit-untouched
        let kept: Vec<u64> = log.range(0..10).iter().map(|e| e.ticket).collect();
        assert_eq!(kept, vec![4, 5, 6, 7, 8, 9]);
        assert_eq!(log.get(5).unwrap().response_hash, entry(5, 5.0, 5).response_hash);
    }

    #[test]
    fn range_checked_is_atomic_with_the_watermark() {
        let log = ResponseLog::new();
        for t in 0..8u64 {
            log.record(entry(t, t as f32, t));
        }
        assert_eq!(log.range_checked(0..8).unwrap().len(), 8);
        log.truncate_below(3);
        // reaching below the watermark: the typed error, with the same
        // values replay() surfaces
        match log.range_checked(0..8) {
            Err(crate::Error::Truncated { ticket, watermark }) => {
                assert_eq!((ticket, watermark), (0, 3));
            }
            other => panic!("want Truncated, got {other:?}"),
        }
        // at and above the watermark: the retained slice, bit-untouched
        let got: Vec<u64> =
            log.range_checked(3..8).unwrap().iter().map(|e| e.ticket).collect();
        assert_eq!(got, vec![3, 4, 5, 6, 7]);
    }

    #[test]
    fn watermark_is_monotone_and_blocks_resurrection() {
        let log = ResponseLog::new();
        for t in 0..6u64 {
            log.record(entry(t, t as f32, t));
        }
        assert_eq!(log.truncate_below(5), 5);
        // lowering the watermark is a no-op…
        assert_eq!(log.truncate_below(2), 0);
        assert_eq!(log.watermark(), 5);
        // …and a truncated ticket cannot be re-recorded — but the lost
        // audit record is counted, never silent
        assert_eq!(log.late_drops(), 0);
        log.record(entry(1, 1.0, 1));
        assert!(log.get(1).is_none());
        assert_eq!(log.len(), 1);
        assert_eq!(log.late_drops(), 1);
        // truncating everything leaves an empty log with the watermark up
        assert_eq!(log.truncate_below(100), 1);
        assert!(log.is_empty());
        assert_eq!(log.watermark(), 100);
    }
}
