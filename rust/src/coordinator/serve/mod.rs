//! Deterministic inference serving subsystem — the §2.2.2 "dynamic
//! batching" hazard and RepDL's answer (experiment E7), grown to a
//! concurrent, sharded serving stack.
//!
//! A serving system batches whatever requests are in the queue. The same
//! request can therefore run in a batch of 1 today and 64 tomorrow.
//! RepDL inference is **batch-size invariant**: every output row is an
//! independent fixed-order reduction, so a request's bits don't depend on
//! its batch-mates. The conventional baseline dispatches kernels by
//! problem size (like cuDNN), so its per-request bits change with batch
//! size — [`ServeReport`] quantifies that.
//!
//! The subsystem has six layers (DESIGN.md §7–§9):
//!
//! * [`tower`] — [`ModelTower`], the model-generic replica surface
//!   (`forward_batch` over an explicit pool + identity), with three
//!   implementations: the linear [`DeterministicServer`], [`MlpTower`]
//!   and the off-tape [`TransformerTower`].
//! * [`replica`] — [`DeterministicServer`] (weights pre-packed once
//!   into microkernel panels, scratch-staged pooled batch GEMM) and
//!   [`ServeReplica`], a tower bound to a shareable
//!   [`crate::tensor::PoolHandle`].
//! * [`scheduler`] — [`ServeScheduler`], the deterministic
//!   dynamic-batching front end: concurrent clients submit requests,
//!   each is stamped with a monotone **ticket**, batch composition and
//!   shard choice (`ticket % shards`) are pure functions of ticket
//!   numbers — never of thread timing — and responses come back in
//!   ticket order. [`ServeConfig`] adds the deterministic queue-depth
//!   cap (reject by ticket arithmetic, typed `Error::Rejected`).
//! * [`registry`] — [`ModelRegistry`], multi-model routing: model id →
//!   scheduler under one router gate, so per-model ticket sequences are
//!   a pure function of the global submit order.
//! * [`cache`] — [`MemoCache`], the content-addressed response memo
//!   keyed by `weights_hash:request_hash` (hits can never cross
//!   models), with logical-clock (insertion-ticket) eviction; consulted
//!   at dispatch time so cache-on and cache-off runs share tickets,
//!   batches and bits.
//! * [`log`] — [`ResponseLog`], the ticket-addressed audit log of
//!   request/response content hashes (model-stamped via
//!   `weights_hash`), re-checkable bit-exactly via
//!   [`ServeScheduler::replay`] and rotatable via
//!   [`ResponseLog::truncate_below`] (replays below the watermark are
//!   the typed `Error::Truncated`).
//! * [`journal`] + [`faults`] — the durable, crash-consistent event
//!   journal (byte-deterministic, SHA-256-framed; DESIGN.md §11) with
//!   [`ServeScheduler::recover`] / [`ModelRegistry::recover_all`]
//!   rebuilding a bit-identical process from it, and the deterministic
//!   fault-injection harness ([`FaultPlan`], [`PanicAtTicket`]) that
//!   proves it under injected crashes.
//! * [`wire`] + [`net`] — the std-only TCP front end (DESIGN.md §14):
//!   the journal's `len ‖ payload ‖ SHA-256` framing reused as the
//!   socket protocol, [`NetServer`] putting a [`ModelRegistry`] behind
//!   a listener (per-connection FIFO reader/writer threads, typed
//!   error frames for untrusted bytes, logical-clock flush only) and
//!   [`NetClient`] speaking it.

pub mod cache;
pub mod faults;
pub mod journal;
pub mod log;
pub mod net;
pub mod registry;
pub mod replica;
pub mod scheduler;
pub mod session;
pub mod tower;
pub mod wire;

pub use cache::{CacheStats, MemoCache};
pub use faults::{FaultPlan, FaultyWriter, PanicAtTicket};
pub use journal::{
    read_journal, FileJournalWriter, Journal, JournalEvent, JournalPolicy, JournalReadout,
    JournalStats, JournalWriter, VecWriter,
};
pub use log::{LogEntry, ResponseLog};
pub use net::{NetClient, NetServer};
pub use registry::{ModelInfo, ModelRegistry, Promotion};
pub use replica::{DeterministicServer, ServeReplica, ServeReport, ServeThroughput};
pub use scheduler::{
    BatchTrace, Pending, RecoveryReport, ReplayReport, ServeConfig, ServeScheduler,
};
pub use session::{token_key, Session, SessionStats, SessionStore};
pub use tower::{MlpTower, ModelTower, NamedTower, ShardedTower, TransformerTower};
pub use wire::{WireFrame, MAX_WIRE_PAYLOAD, WIRE_MAGIC, WIRE_VERSION};

use std::sync::{Mutex, MutexGuard};

/// Acquire a serve-subsystem mutex, recovering from poisoning.
///
/// §7 error-not-panic policy: a panic in one dispatcher or client
/// thread must leave every *other* client with a typed error or a
/// correct response — never a propagated poison panic on the next
/// `submit`. Recovery is sound here because every guarded structure in
/// this subsystem is **update-atomic**: each critical section either
/// completes a whole logical update or performs none (BTreeMap
/// insert/remove pairs ordered so the panic window leaves a consistent
/// prefix, counter bumps, queue push + notify). A poisoned lock
/// therefore guards a consistent value, and `into_inner` is safe to
/// serve. Anything that can't meet that bar must not use this helper.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::lock_recover;
    use std::sync::Mutex;

    #[test]
    fn lock_recover_survives_a_poisoning_panic() {
        let m = Mutex::new(7u64);
        // poison the mutex from another thread
        let res = std::thread::scope(|s| {
            s.spawn(|| {
                let _g = m.lock().unwrap();
                panic!("poison it");
            })
            .join()
        });
        assert!(res.is_err(), "the poisoning thread must have panicked");
        assert!(m.lock().is_err(), "the mutex must actually be poisoned");
        // a plain .lock().unwrap() here would panic; lock_recover serves
        // the (update-atomic) guarded value
        let mut g = lock_recover(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*lock_recover(&m), 8);
    }
}
