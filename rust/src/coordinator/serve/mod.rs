//! Deterministic inference serving subsystem — the §2.2.2 "dynamic
//! batching" hazard and RepDL's answer (experiment E7), grown to a
//! concurrent, sharded serving stack.
//!
//! A serving system batches whatever requests are in the queue. The same
//! request can therefore run in a batch of 1 today and 64 tomorrow.
//! RepDL inference is **batch-size invariant**: every output row is an
//! independent fixed-order reduction, so a request's bits don't depend on
//! its batch-mates. The conventional baseline dispatches kernels by
//! problem size (like cuDNN), so its per-request bits change with batch
//! size — [`ServeReport`] quantifies that.
//!
//! The subsystem has four layers (DESIGN.md §7–§8):
//!
//! * [`replica`] — the model replica: [`DeterministicServer`] (weights
//!   pre-packed once into microkernel panels, scratch-staged pooled
//!   batch GEMM) and [`ServeReplica`], a replica bound to a shareable
//!   [`crate::tensor::PoolHandle`].
//! * [`scheduler`] — [`ServeScheduler`], the deterministic
//!   dynamic-batching front end: concurrent clients submit requests,
//!   each is stamped with a monotone **ticket**, batch composition and
//!   shard choice (`ticket % shards`) are pure functions of ticket
//!   numbers — never of thread timing — and responses come back in
//!   ticket order. [`ServeConfig`] adds the deterministic queue-depth
//!   cap (reject by ticket arithmetic, typed `Error::Rejected`).
//! * [`cache`] — [`MemoCache`], the content-addressed response memo
//!   keyed by request hash, with logical-clock (insertion-ticket)
//!   eviction; consulted at dispatch time so cache-on and cache-off
//!   runs share tickets, batches and bits.
//! * [`log`] — [`ResponseLog`], the ticket-addressed audit log of
//!   request/response content hashes, re-checkable bit-exactly via
//!   [`ServeScheduler::replay`].

pub mod cache;
pub mod log;
pub mod replica;
pub mod scheduler;

pub use cache::{CacheStats, MemoCache};
pub use log::{LogEntry, ResponseLog};
pub use replica::{DeterministicServer, ServeReplica, ServeReport, ServeThroughput};
pub use scheduler::{BatchTrace, Pending, ReplayReport, ServeConfig, ServeScheduler};
