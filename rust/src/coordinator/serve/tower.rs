//! `ModelTower` — the model-generic replica surface (DESIGN.md §9).
//!
//! PR 3–4 proved the shard/batch/cache/admission invariants for one
//! matmul; the paper's claim is bit-reproducible *deep learning*
//! inference, and non-associativity effects compound through deep
//! forward passes (Shanmugavelu et al., arXiv:2408.05148). This module
//! generalises the replica so the same scheduler machinery serves
//! genuinely deep towers:
//!
//! * the existing linear server ([`DeterministicServer`]) — unchanged
//!   bits, keeps its packed-weights fast path;
//! * [`MlpTower`] — the `nn::Mlp` forward off-tape;
//! * [`TransformerTower`] — an inference-only `CharTransformer` forward
//!   (no `Tape` allocation per request) through the pooled `*_in`
//!   kernels.
//!
//! **The off-tape inference rule.** A tower's `forward_batch` must be a
//! pure function of `(weights, batch)` built from the fixed-graph
//! kernels: no wall-clock reads, no tape construction, and a
//! per-request allocation count that does not vary with timing — so
//! serving cost and bits are both reproducible. Batch invariance is
//! mandatory: every response row must be an independent fixed-order
//! reduction over its own request, which is what lets the scheduler
//! batch freely, serve cache hits, and audit with singleton-batch
//! replays (`tests/serve_models.rs` pins all three per tower).
//!
//! **`weights_hash`.** Each tower fingerprints its parameters once at
//! construction (`hash_params` over the fixed parameter order). The
//! scheduler embeds this hash in every memo-cache key and response-log
//! entry, so a cached response can never cross models — even two towers
//! of the same architecture differing in one weight bit get disjoint
//! key spaces.

use super::replica::{check_request, DeterministicServer};
use super::session::{token_key, Session, SessionStats, SessionStore};
use crate::coordinator::hashing::hash_params;
use crate::nn::{
    CharTransformer, Mlp, Module, PackedMlp, PackedMlpShard, PackedTransformer,
    PackedTransformerShard, ShardPlan,
};
use crate::tensor::pool::global_pool;
use crate::tensor::{Tensor, WorkerPool};
use crate::{Error, Result};

/// Reject a token request whose count is outside `1..=context` —
/// variable-length sequences are the point of incremental decode (a
/// token tower's `d_in()` is the *maximum* request length).
fn check_token_len(context: usize, request: &Tensor) -> Result<()> {
    let n = request.numel();
    if n == 0 || n > context {
        return Err(Error::shape(format!(
            "transformer tower: request length {n} outside 1..={context}"
        )));
    }
    Ok(())
}

/// Decode a token request back to ids, rejecting anything that is not a
/// non-negative integer below `vocab`.
fn decode_token_ids(vocab: usize, request: &Tensor) -> Result<Vec<usize>> {
    request
        .data()
        .iter()
        .map(|&v| {
            let ok = v.is_finite() && v >= 0.0 && v.fract() == 0.0;
            if ok && (v as usize) < vocab {
                Ok(v as usize)
            } else {
                Err(Error::shape(format!(
                    "transformer tower: token {v} is not an id in 0..{vocab}"
                )))
            }
        })
        .collect()
}

/// A model replica's numerics surface: everything the serve scheduler
/// needs to batch, route, cache and audit requests for one model.
///
/// Contract (DESIGN.md §9): `forward_batch` must be **batch invariant**
/// (each output row depends only on its own request row) and
/// **pool-size invariant** (any `pool` produces identical bits), must
/// never panic on adversarial input (error instead), and must follow
/// the off-tape inference rule above. `validate_request` is called at
/// submit time, *before* a ticket is consumed — anything it accepts
/// must execute without error, so a malformed request can never poison
/// a batch.
pub trait ModelTower: Send + Sync {
    /// Stable model identifier — the routing key in a
    /// [`super::ModelRegistry`].
    fn model_id(&self) -> &str;
    /// Request length in f32 elements.
    fn d_in(&self) -> usize;
    /// Response length in f32 elements.
    fn d_out(&self) -> usize;
    /// Parameter fingerprint (`hash_params` over the model's fixed
    /// parameter order), computed once at construction.
    fn weights_hash(&self) -> &str;
    /// Execute one batch on `pool`: one response row per request, in
    /// request order.
    fn forward_batch(&self, pool: &WorkerPool, batch: &[Tensor]) -> Result<Vec<Tensor>>;
    /// Submit-time validation (default: element count). Towers with
    /// stricter domains (e.g. token ids) override so invalid requests
    /// are rejected before consuming a ticket.
    fn validate_request(&self, request: &Tensor) -> Result<()> {
        check_request(request, self.d_in())
    }
    /// [`Self::forward_batch`] with each request's admission ticket.
    /// Towers holding session state (KV caches) override this to key
    /// their stores by the scheduler's logical clock; the override must
    /// stay **bit-identical** to `forward_batch` on every request —
    /// sessions may only change cost, never bits. The default ignores
    /// the tickets.
    fn forward_batch_ticketed(
        &self,
        pool: &WorkerPool,
        batch: &[Tensor],
        tickets: &[u64],
    ) -> Result<Vec<Tensor>> {
        let _ = tickets;
        self.forward_batch(pool, batch)
    }
    /// Session-store counters, if this tower holds one (default: none).
    fn session_stats(&self) -> Option<SessionStats> {
        None
    }
}

/// The original linear server is the reference tower: `logits = x·W`
/// through the packed-panel fast path (weights packed once at
/// construction).
impl ModelTower for DeterministicServer {
    fn model_id(&self) -> &str {
        "linear"
    }
    fn d_in(&self) -> usize {
        DeterministicServer::d_in(self)
    }
    fn d_out(&self) -> usize {
        DeterministicServer::d_out(self)
    }
    fn weights_hash(&self) -> &str {
        DeterministicServer::weights_hash(self)
    }
    fn forward_batch(&self, pool: &WorkerPool, batch: &[Tensor]) -> Result<Vec<Tensor>> {
        self.process_repro_in(pool, batch)
    }
}

/// An [`crate::nn::Mlp`] behind the tower surface: requests are feature
/// rows of the first layer's width, responses the last layer's output
/// row. The whole batch is staged into one (B, d_in) matrix and runs
/// the off-tape pooled forward — batch invariant because every GEMM row
/// and every activation element is an independent fixed-order
/// computation.
pub struct MlpTower {
    mlp: Mlp,
    /// Layer weights frozen into microkernel B panels **once at
    /// construction** (layout-only, bit-neutral) — the serve hot path
    /// must never re-transpose or re-pack the immutable weights per
    /// call (same rule as [`DeterministicServer`]).
    packed: PackedMlp,
    model_id: String,
    weights_hash: String,
    d_in: usize,
    d_out: usize,
}

impl MlpTower {
    /// Wrap an MLP (id `"mlp"`). Errors on a layer-less model.
    pub fn new(mlp: Mlp) -> Result<MlpTower> {
        MlpTower::with_model_id(mlp, "mlp")
    }

    /// Wrap an MLP under an explicit model id (for registries holding
    /// several MLPs). Packs every layer's weights once, up front.
    pub fn with_model_id(mlp: Mlp, model_id: impl Into<String>) -> Result<MlpTower> {
        let d_in = mlp.d_in()?;
        let d_out = mlp.d_out()?;
        let weights_hash = hash_params(&mlp.params());
        let packed = mlp.pack_in(global_pool())?;
        Ok(MlpTower { mlp, packed, model_id: model_id.into(), weights_hash, d_in, d_out })
    }

    /// The wrapped model.
    pub fn mlp(&self) -> &Mlp {
        &self.mlp
    }
}

impl ModelTower for MlpTower {
    fn model_id(&self) -> &str {
        &self.model_id
    }
    fn d_in(&self) -> usize {
        self.d_in
    }
    fn d_out(&self) -> usize {
        self.d_out
    }
    fn weights_hash(&self) -> &str {
        &self.weights_hash
    }
    fn forward_batch(&self, pool: &WorkerPool, batch: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut x = Tensor::zeros(&[batch.len(), self.d_in]);
        for (i, r) in batch.iter().enumerate() {
            check_request(r, self.d_in)?;
            x.data_mut()[i * self.d_in..(i + 1) * self.d_in].copy_from_slice(r.data());
        }
        // construction-time panels: zero transpose/pack allocations here
        let y = self.mlp.forward_infer_packed_in(pool, &x, Some(&self.packed))?;
        (0..batch.len())
            .map(|i| {
                Tensor::from_vec(
                    &[self.d_out],
                    y.data()[i * self.d_out..(i + 1) * self.d_out].to_vec(),
                )
            })
            .collect()
    }
}

/// A [`crate::nn::CharTransformer`] behind the tower surface,
/// inference-only: a request is `1..=context` token ids encoded as f32
/// values, the response is the **last position's** (vocab,) logits row
/// — next-token inference. Each sequence runs the off-tape packed
/// forward independently (no `Tape` allocation per request), so batch
/// invariance holds trivially: a request's logits are a function of its
/// own ids and the weights, never of its batch-mates.
///
/// With [`Self::with_sessions`], the ticketed path keeps per-prefix KV
/// caches in a [`SessionStore`]: a request extending a live prefix by
/// one token runs a single decode step (O(T)) instead of a full
/// recompute (O(T²)). Any miss — unknown prefix, evicted session,
/// length mismatch — falls back to the full recompute, which also
/// *rebuilds* the session via prefill capture. Sessions change cost
/// only, never bits (DESIGN.md §10; pinned in `tests/serve_sessions`).
pub struct TransformerTower {
    model: CharTransformer,
    /// Every weight matrix frozen into microkernel B panels **once at
    /// construction** (layout-only, bit-neutral) — the serve hot path
    /// must never re-transpose the immutable weights per call.
    packed: PackedTransformer,
    /// KV-cache store for incremental decode, if enabled.
    sessions: Option<SessionStore>,
    model_id: String,
    weights_hash: String,
}

impl TransformerTower {
    /// Wrap a transformer (id `"transformer"`).
    pub fn new(model: CharTransformer) -> Result<TransformerTower> {
        TransformerTower::with_model_id(model, "transformer")
    }

    /// Wrap a transformer under an explicit model id. Packs every
    /// weight matrix once, up front; sessions start disabled.
    pub fn with_model_id(
        model: CharTransformer,
        model_id: impl Into<String>,
    ) -> Result<TransformerTower> {
        if model.cfg.context == 0 || model.cfg.vocab == 0 || model.cfg.dim == 0 {
            // a degenerate model must be a construction error, never a
            // per-request panic inside a dispatcher (trait contract)
            return Err(Error::config("transformer tower: zero context, vocab or dim"));
        }
        let weights_hash = hash_params(&model.params());
        let packed = model.pack_in(global_pool())?;
        Ok(TransformerTower {
            model,
            packed,
            sessions: None,
            model_id: model_id.into(),
            weights_hash,
        })
    }

    /// Enable KV-cached incremental decode with a session store holding
    /// at most `capacity` prefixes (`0` leaves sessions disabled). The
    /// store only ever changes serving *cost*: every response is
    /// bit-identical with sessions on, off, or thrashing.
    pub fn with_sessions(mut self, capacity: usize) -> TransformerTower {
        self.sessions = if capacity == 0 { None } else { Some(SessionStore::new(capacity)) };
        self
    }

    /// The wrapped model.
    pub fn model(&self) -> &CharTransformer {
        &self.model
    }

    /// Test hook: direct access to the session store (when enabled), so
    /// the poison-recovery suite can poison its internal lock for real
    /// (`SessionStore::poison_for_test`) and assert serving continues.
    #[doc(hidden)]
    pub fn sessions_for_test(&self) -> Option<&SessionStore> {
        self.sessions.as_ref()
    }

    /// Encode a token sequence as a request tensor (ids as f32 — exact
    /// for any realistic vocab: f32 holds integers ≤ 2²⁴).
    pub fn encode_request(&self, ids: &[usize]) -> Result<Tensor> {
        let t = Tensor::from_vec(&[ids.len()], ids.iter().map(|&i| i as f32).collect())?;
        self.validate_request(&t)?;
        Ok(t)
    }

    /// Reject a request whose token count is outside `1..=context` —
    /// variable-length sequences are the point of incremental decode
    /// (`d_in()` stays `context`: the *maximum* request length).
    fn check_len(&self, request: &Tensor) -> Result<()> {
        check_token_len(self.model.cfg.context, request)
    }

    /// Full recompute of one request's last-position logits through the
    /// construction-time panels — the reference path every session hit
    /// must bit-match, and the fallback when no session applies.
    fn full_logits(&self, pool: &WorkerPool, ids: &[usize]) -> Result<Tensor> {
        let vocab = self.model.cfg.vocab;
        let logits = self.model.forward_logits_packed_in(pool, ids, Some(&self.packed), None)?;
        let last = ids.len() - 1;
        Tensor::from_vec(&[vocab], logits.data()[last * vocab..(last + 1) * vocab].to_vec())
    }

    /// Serve one ticketed request through the session store: one decode
    /// step on a prefix hit, full recompute + prefill capture (session
    /// rebuild) on any miss. Bit-identical to [`Self::full_logits`]
    /// either way.
    fn session_logits(
        &self,
        store: &SessionStore,
        pool: &WorkerPool,
        ids: &[usize],
        ticket: u64,
    ) -> Result<Tensor> {
        let tt = ids.len();
        if tt >= 2 {
            if let Some(sess) = store.lookup(&token_key(&ids[..tt - 1])) {
                if sess.kv.steps() == tt - 1 {
                    // hit: score ONE new query row against the cached
                    // (K,V) rows — the identical per-row reduction
                    // graph as the full forward's last position
                    let mut kv = sess.kv; // lookup returned a clone
                    let row = self.model.forward_logits_step_packed_in(
                        pool,
                        ids[tt - 1],
                        &mut kv,
                        Some(&self.packed),
                    )?;
                    let key = token_key(ids);
                    store.insert(&key, ticket, &Session { kv, prefix_hash: key.clone() });
                    return Tensor::from_vec(&[self.model.cfg.vocab], row.data().to_vec());
                }
            }
        }
        // miss (unknown/evicted prefix, or a fresh one-token stream):
        // full recompute, capturing the KV state as it goes so the
        // stream's next request can hit (O(T) rebuild, not T steps)
        let mut kv = self.model.begin_kv();
        let vocab = self.model.cfg.vocab;
        let logits =
            self.model.forward_logits_packed_in(pool, ids, Some(&self.packed), Some(&mut kv))?;
        let key = token_key(ids);
        store.insert(&key, ticket, &Session { kv, prefix_hash: key.clone() });
        let last = tt - 1;
        Tensor::from_vec(&[vocab], logits.data()[last * vocab..(last + 1) * vocab].to_vec())
    }

    /// Decode a validated request back to token ids.
    fn ids_of(&self, request: &Tensor) -> Result<Vec<usize>> {
        decode_token_ids(self.model.cfg.vocab, request)
    }
}

/// Any tower under a different model id — e.g. two linear models (whose
/// reference implementation hardcodes id `"linear"`) registered side by
/// side in one [`super::ModelRegistry`]. Purely an identity rename:
/// numerics, shapes, validation and `weights_hash` all pass through
/// untouched — the memo-cache key's `weights_hash` prefix already keeps
/// same-architecture models disjoint, so a rename cannot change bits or
/// leak cached responses.
pub struct NamedTower<T> {
    inner: T,
    model_id: String,
}

impl<T: ModelTower> NamedTower<T> {
    /// Serve `inner` under `model_id`.
    pub fn new(inner: T, model_id: impl Into<String>) -> NamedTower<T> {
        NamedTower { inner, model_id: model_id.into() }
    }

    /// The wrapped tower.
    pub fn inner(&self) -> &T {
        &self.inner
    }
}

impl<T: ModelTower> ModelTower for NamedTower<T> {
    fn model_id(&self) -> &str {
        &self.model_id
    }
    fn d_in(&self) -> usize {
        self.inner.d_in()
    }
    fn d_out(&self) -> usize {
        self.inner.d_out()
    }
    fn weights_hash(&self) -> &str {
        self.inner.weights_hash()
    }
    fn forward_batch(&self, pool: &WorkerPool, batch: &[Tensor]) -> Result<Vec<Tensor>> {
        self.inner.forward_batch(pool, batch)
    }
    fn validate_request(&self, request: &Tensor) -> Result<()> {
        self.inner.validate_request(request)
    }
    fn forward_batch_ticketed(
        &self,
        pool: &WorkerPool,
        batch: &[Tensor],
        tickets: &[u64],
    ) -> Result<Vec<Tensor>> {
        self.inner.forward_batch_ticketed(pool, batch, tickets)
    }
    fn session_stats(&self) -> Option<SessionStats> {
        self.inner.session_stats()
    }
}

impl ModelTower for TransformerTower {
    fn model_id(&self) -> &str {
        &self.model_id
    }
    fn d_in(&self) -> usize {
        self.model.cfg.context
    }
    fn d_out(&self) -> usize {
        self.model.cfg.vocab
    }
    fn weights_hash(&self) -> &str {
        &self.weights_hash
    }
    fn forward_batch(&self, pool: &WorkerPool, batch: &[Tensor]) -> Result<Vec<Tensor>> {
        batch
            .iter()
            .map(|r| {
                // one decode pass covers the full validate_request
                // domain (length + token ids) — don't pay it twice per
                // request on the dispatch hot path
                self.check_len(r)?;
                let ids = self.ids_of(r)?;
                self.full_logits(pool, &ids)
            })
            .collect()
    }
    /// The session-aware path: bit-identical to [`Self::forward_batch`]
    /// (pinned in `tests/serve_sessions`), cheaper on prefix hits. With
    /// sessions disabled this *is* `forward_batch`.
    fn forward_batch_ticketed(
        &self,
        pool: &WorkerPool,
        batch: &[Tensor],
        tickets: &[u64],
    ) -> Result<Vec<Tensor>> {
        let Some(store) = &self.sessions else {
            return self.forward_batch(pool, batch);
        };
        if tickets.len() != batch.len() {
            return Err(Error::shape(format!(
                "transformer tower: {} tickets for {} requests",
                tickets.len(),
                batch.len()
            )));
        }
        batch
            .iter()
            .zip(tickets.iter())
            .map(|(r, &ticket)| {
                self.check_len(r)?;
                let ids = self.ids_of(r)?;
                self.session_logits(store, pool, &ids, ticket)
            })
            .collect()
    }
    fn session_stats(&self) -> Option<SessionStats> {
        self.sessions.as_ref().map(|s| s.stats())
    }
    /// Submit-time validation covers the full domain — length AND token
    /// ids — so a garbage token is rejected before it consumes a ticket
    /// and can never fail (and thereby poison) a composed batch.
    fn validate_request(&self, request: &Tensor) -> Result<()> {
        self.check_len(request)?;
        self.ids_of(request).map(|_| ())
    }
}

/// Model-specific state of a [`ShardedTower`].
enum ShardedInner {
    Mlp { mlp: Mlp, shards: Vec<PackedMlpShard>, d_in: usize, d_out: usize },
    Transformer {
        model: CharTransformer,
        shards: Vec<PackedTransformerShard>,
        sessions: Option<SessionStore>,
    },
}

/// A tensor-parallel tower: one model served through `tp` packed shard
/// sets (`nn`'s `ShardPlan` layout), every request's partial outputs
/// combined through the fixed logical-segment reduction tree
/// (`rnum::reduce`). Because the sharded forward's bits are invariant
/// across `tp ∈ {1, 2, 4}` at the `nn` layer, so is every serving
/// artifact built on them.
///
/// **Identity is TP-invariant by construction.** `model_id` stays
/// `"mlp"` / `"transformer"` and `weights_hash` fingerprints the
/// *unsharded* parameter order — shard packing is downstream layout, so
/// memo-cache keys, response-log entries and journal `Ident` records
/// are identical at every width: a journal recorded at `--tp 1` recovers
/// and replays bit-exactly on a `--tp 4` deployment, and KV sessions
/// captured at one width continue at another (the cache keeps the full
/// unsharded head layout).
///
/// Note the sharded reduction graph is a *different* (equally
/// deterministic) spec from the unsharded packed towers — like choosing
/// a microbatch size in training. `--tp N` deployments interoperate
/// with each other, not with journals recorded by the unsharded towers
/// (replay verification catches any such mix-up).
pub struct ShardedTower {
    inner: ShardedInner,
    model_id: String,
    weights_hash: String,
    tp: usize,
}

/// One shard plan per rank; rejects `tp == 0` before the empty range
/// could silently produce a shard-less tower.
fn shard_plans(tp: usize) -> Result<Vec<ShardPlan>> {
    if tp == 0 {
        return Err(Error::config("sharded tower: tp must be >= 1"));
    }
    (0..tp).map(|s| ShardPlan::new(tp, s)).collect()
}

impl ShardedTower {
    /// Serve an MLP at tensor-parallel width `tp` (id `"mlp"`). Errors
    /// — never panics — on `tp ∉ {1, 2, 4}` or layer widths the shard
    /// plan cannot divide.
    pub fn mlp(mlp: Mlp, tp: usize) -> Result<ShardedTower> {
        let d_in = mlp.d_in()?;
        let d_out = mlp.d_out()?;
        let weights_hash = hash_params(&mlp.params());
        let pool = global_pool();
        let shards = shard_plans(tp)?
            .into_iter()
            .map(|plan| mlp.pack_shard_in(pool, plan))
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardedTower {
            inner: ShardedInner::Mlp { mlp, shards, d_in, d_out },
            model_id: "mlp".into(),
            weights_hash,
            tp,
        })
    }

    /// Serve a transformer at tensor-parallel width `tp` (id
    /// `"transformer"`). Sessions start disabled.
    pub fn transformer(model: CharTransformer, tp: usize) -> Result<ShardedTower> {
        if model.cfg.context == 0 || model.cfg.vocab == 0 || model.cfg.dim == 0 {
            return Err(Error::config("sharded tower: zero context, vocab or dim"));
        }
        let weights_hash = hash_params(&model.params());
        let pool = global_pool();
        let shards = shard_plans(tp)?
            .into_iter()
            .map(|plan| model.pack_shard_in(pool, plan))
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardedTower {
            inner: ShardedInner::Transformer { model, shards, sessions: None },
            model_id: "transformer".into(),
            weights_hash,
            tp,
        })
    }

    /// Enable KV-cached incremental decode (transformer towers; a no-op
    /// for MLP towers, which hold no inter-request state). Capacity 0
    /// disables. The cache keeps the full unsharded head layout, so its
    /// contents — like every other bit — are TP-invariant.
    pub fn with_sessions(mut self, capacity: usize) -> ShardedTower {
        if let ShardedInner::Transformer { sessions, .. } = &mut self.inner {
            *sessions = if capacity == 0 { None } else { Some(SessionStore::new(capacity)) };
        }
        self
    }

    /// Tensor-parallel width — a pure layout/throughput knob, never part
    /// of the model identity.
    pub fn tp(&self) -> usize {
        self.tp
    }

    /// Encode a token sequence as a request tensor (transformer towers).
    pub fn encode_request(&self, ids: &[usize]) -> Result<Tensor> {
        let t = Tensor::from_vec(&[ids.len()], ids.iter().map(|&i| i as f32).collect())?;
        self.validate_request(&t)?;
        Ok(t)
    }

    /// Full sharded recompute of one request's last-position logits —
    /// the reference every session hit must bit-match.
    fn transformer_last_row(
        model: &CharTransformer,
        shards: &[PackedTransformerShard],
        pool: &WorkerPool,
        ids: &[usize],
    ) -> Result<Tensor> {
        let vocab = model.cfg.vocab;
        let logits = model.forward_logits_sharded_in(pool, ids, shards, None)?;
        let last = ids.len() - 1;
        Tensor::from_vec(&[vocab], logits.data()[last * vocab..(last + 1) * vocab].to_vec())
    }

    /// Sharded mirror of [`TransformerTower::session_logits`]: one
    /// sharded decode step on a prefix hit, full sharded recompute with
    /// prefill capture on any miss — bit-identical either way.
    fn transformer_session_logits(
        model: &CharTransformer,
        shards: &[PackedTransformerShard],
        store: &SessionStore,
        pool: &WorkerPool,
        ids: &[usize],
        ticket: u64,
    ) -> Result<Tensor> {
        let tt = ids.len();
        if tt >= 2 {
            if let Some(sess) = store.lookup(&token_key(&ids[..tt - 1])) {
                if sess.kv.steps() == tt - 1 {
                    let mut kv = sess.kv; // lookup returned a clone
                    let row =
                        model.forward_logits_step_sharded_in(pool, ids[tt - 1], shards, &mut kv)?;
                    let key = token_key(ids);
                    store.insert(&key, ticket, &Session { kv, prefix_hash: key.clone() });
                    return Tensor::from_vec(&[model.cfg.vocab], row.data().to_vec());
                }
            }
        }
        let mut kv = model.begin_kv();
        let vocab = model.cfg.vocab;
        let logits = model.forward_logits_sharded_in(pool, ids, shards, Some(&mut kv))?;
        let key = token_key(ids);
        store.insert(&key, ticket, &Session { kv, prefix_hash: key.clone() });
        let last = tt - 1;
        Tensor::from_vec(&[vocab], logits.data()[last * vocab..(last + 1) * vocab].to_vec())
    }
}

impl ModelTower for ShardedTower {
    fn model_id(&self) -> &str {
        &self.model_id
    }
    fn d_in(&self) -> usize {
        match &self.inner {
            ShardedInner::Mlp { d_in, .. } => *d_in,
            ShardedInner::Transformer { model, .. } => model.cfg.context,
        }
    }
    fn d_out(&self) -> usize {
        match &self.inner {
            ShardedInner::Mlp { d_out, .. } => *d_out,
            ShardedInner::Transformer { model, .. } => model.cfg.vocab,
        }
    }
    fn weights_hash(&self) -> &str {
        &self.weights_hash
    }
    fn forward_batch(&self, pool: &WorkerPool, batch: &[Tensor]) -> Result<Vec<Tensor>> {
        match &self.inner {
            ShardedInner::Mlp { mlp, shards, d_in, d_out } => {
                let mut x = Tensor::zeros(&[batch.len(), *d_in]);
                for (i, r) in batch.iter().enumerate() {
                    check_request(r, *d_in)?;
                    x.data_mut()[i * d_in..(i + 1) * d_in].copy_from_slice(r.data());
                }
                let y = mlp.forward_infer_sharded_in(pool, &x, shards)?;
                (0..batch.len())
                    .map(|i| {
                        Tensor::from_vec(
                            &[*d_out],
                            y.data()[i * d_out..(i + 1) * d_out].to_vec(),
                        )
                    })
                    .collect()
            }
            ShardedInner::Transformer { model, shards, .. } => batch
                .iter()
                .map(|r| {
                    check_token_len(model.cfg.context, r)?;
                    let ids = decode_token_ids(model.cfg.vocab, r)?;
                    ShardedTower::transformer_last_row(model, shards, pool, &ids)
                })
                .collect(),
        }
    }
    /// The session-aware path — bit-identical to [`Self::forward_batch`]
    /// at every TP width, cheaper on prefix hits.
    fn forward_batch_ticketed(
        &self,
        pool: &WorkerPool,
        batch: &[Tensor],
        tickets: &[u64],
    ) -> Result<Vec<Tensor>> {
        let ShardedInner::Transformer { model, shards, sessions: Some(store) } = &self.inner
        else {
            return self.forward_batch(pool, batch);
        };
        if tickets.len() != batch.len() {
            return Err(Error::shape(format!(
                "sharded tower: {} tickets for {} requests",
                tickets.len(),
                batch.len()
            )));
        }
        batch
            .iter()
            .zip(tickets.iter())
            .map(|(r, &ticket)| {
                check_token_len(model.cfg.context, r)?;
                let ids = decode_token_ids(model.cfg.vocab, r)?;
                ShardedTower::transformer_session_logits(model, shards, store, pool, &ids, ticket)
            })
            .collect()
    }
    fn session_stats(&self) -> Option<SessionStats> {
        match &self.inner {
            ShardedInner::Transformer { sessions, .. } => sessions.as_ref().map(|s| s.stats()),
            ShardedInner::Mlp { .. } => None,
        }
    }
    fn validate_request(&self, request: &Tensor) -> Result<()> {
        match &self.inner {
            ShardedInner::Mlp { d_in, .. } => check_request(request, *d_in),
            ShardedInner::Transformer { model, .. } => {
                check_token_len(model.cfg.context, request)?;
                decode_token_ids(model.cfg.vocab, request).map(|_| ())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Act, TransformerConfig};
    use std::sync::Arc;

    fn mlp_tower() -> MlpTower {
        MlpTower::new(Mlp::new(&[12, 16, 5], Act::Gelu, 3)).unwrap()
    }

    fn transformer_tower() -> TransformerTower {
        let cfg = TransformerConfig {
            vocab: 10,
            dim: 8,
            heads: 2,
            layers: 1,
            context: 4,
            mlp_ratio: 2,
        };
        TransformerTower::new(CharTransformer::new(cfg, 5).unwrap()).unwrap()
    }

    #[test]
    fn mlp_tower_matches_off_tape_forward_and_is_batch_invariant() {
        let tower = mlp_tower();
        assert_eq!((tower.d_in(), tower.d_out()), (12, 5));
        let pool = WorkerPool::new(2);
        let batch: Vec<Tensor> = (0..5)
            .map(|i| crate::rng::uniform_tensor(&[12], -1.0, 1.0, 40 + i))
            .collect();
        let outs = tower.forward_batch(&pool, &batch).unwrap();
        // singleton runs must reproduce every batched row bit-for-bit
        for (r, o) in batch.iter().zip(outs.iter()) {
            let single = tower.forward_batch(&pool, std::slice::from_ref(r)).unwrap();
            assert!(single[0].bit_eq(o), "MLP tower is not batch invariant");
            assert_eq!(o.dims(), &[5]);
        }
        // and equal the plain off-tape forward on the stacked matrix
        let mut x = Tensor::zeros(&[5, 12]);
        for (i, r) in batch.iter().enumerate() {
            x.data_mut()[i * 12..(i + 1) * 12].copy_from_slice(r.data());
        }
        let y = tower.mlp().forward_infer_in(&pool, &x).unwrap();
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.data(), &y.data()[i * 5..(i + 1) * 5]);
        }
    }

    #[test]
    fn transformer_tower_serves_last_position_logits() {
        let tower = transformer_tower();
        assert_eq!((tower.d_in(), tower.d_out()), (4, 10));
        let pool = WorkerPool::new(1);
        let ids = [1usize, 7, 0, 9];
        let req = tower.encode_request(&ids).unwrap();
        let out = &tower.forward_batch(&pool, std::slice::from_ref(&req)).unwrap()[0];
        let logits = tower.model().forward_logits_infer_in(&pool, &ids).unwrap();
        assert_eq!(out.data(), &logits.data()[3 * 10..4 * 10]);
    }

    #[test]
    fn degenerate_transformer_configs_are_construction_errors() {
        // dim = 0 would otherwise panic (divide-by-zero) in layer_norm
        // inside a dispatcher thread on the first request; heads = 0
        // would panic (`dim % 0`) in MultiheadAttention::new;
        // mlp_ratio = 0 would build a width-0 hidden layer whose GEMM
        // output is shape-degenerate
        for (vocab, dim, heads, context, mlp_ratio) in [
            (10, 0, 1, 4, 2),
            (0, 8, 1, 4, 2),
            (10, 8, 1, 0, 2),
            (10, 8, 0, 4, 2),
            (10, 8, 1, 4, 0),
        ] {
            let cfg = TransformerConfig { vocab, dim, heads, layers: 1, context, mlp_ratio };
            let Ok(m) = CharTransformer::new(cfg, 1) else {
                continue; // the model constructor rejecting it is fine too
            };
            assert!(
                TransformerTower::new(m).is_err(),
                "vocab={vocab} dim={dim} heads={heads} context={context} ratio={mlp_ratio} \
                 must not construct a tower"
            );
        }
        // mlp_ratio = 0 specifically must already die in the model
        // constructor (TransformerBlock::new), not only at the tower
        let cfg =
            TransformerConfig { vocab: 10, dim: 8, heads: 1, layers: 1, context: 4, mlp_ratio: 0 };
        assert!(CharTransformer::new(cfg, 1).is_err());
    }

    #[test]
    fn transformer_tower_rejects_bad_tokens_at_validation() {
        let tower = transformer_tower();
        // wrong length: empty and over-context (context = 4)
        assert!(tower.validate_request(&Tensor::zeros(&[0])).is_err());
        assert!(tower.validate_request(&Tensor::zeros(&[5])).is_err());
        // shorter-than-context requests are valid now (incremental serving)
        assert!(tower.validate_request(&Tensor::zeros(&[3])).is_ok());
        // out-of-vocab, fractional, negative, non-finite
        for bad in [10.0f32, 1.5, -1.0, f32::NAN, f32::INFINITY] {
            let r = Tensor::from_vec(&[4], vec![1.0, bad, 2.0, 3.0]).unwrap();
            assert!(tower.validate_request(&r).is_err(), "token {bad} must be rejected");
        }
        // valid request passes and round-trips
        assert!(tower.encode_request(&[0, 9, 4, 4]).is_ok());
        // encode_request refuses out-of-domain ids too
        assert!(tower.encode_request(&[0, 10, 0, 0]).is_err());
    }

    #[test]
    fn transformer_tower_serves_every_prefix_length() {
        let tower = transformer_tower();
        let pool = WorkerPool::new(2);
        let ids = [1usize, 7, 0, 9];
        for tt in 1..=ids.len() {
            let req = tower.encode_request(&ids[..tt]).unwrap();
            let out = &tower.forward_batch(&pool, std::slice::from_ref(&req)).unwrap()[0];
            let logits = tower.model().forward_logits_infer_in(&pool, &ids[..tt]).unwrap();
            assert_eq!(
                out.data(),
                &logits.data()[(tt - 1) * 10..tt * 10],
                "prefix length {tt}: packed tower row drifted from reference forward"
            );
        }
    }

    #[test]
    fn ticketed_sessions_change_cost_never_bits() {
        let plain = transformer_tower();
        let tower = transformer_tower().with_sessions(8);
        assert!(plain.session_stats().is_none());
        let pool = WorkerPool::new(1);
        let ids = [3usize, 1, 7, 2];
        // feed the growing stream through the ticketed path twice over:
        // first pass populates (miss+rebuild each new prefix arrival is a
        // hit on the previous insert), second pass re-lookups
        let mut ticket = 0u64;
        for _ in 0..2 {
            for tt in 1..=ids.len() {
                let req = tower.encode_request(&ids[..tt]).unwrap();
                ticket += 1;
                let got = &tower
                    .forward_batch_ticketed(&pool, std::slice::from_ref(&req), &[ticket])
                    .unwrap()[0];
                let want =
                    &plain.forward_batch(&pool, std::slice::from_ref(&req)).unwrap()[0];
                assert!(
                    got.bit_eq(want),
                    "prefix length {tt}: session-served bits differ from full recompute"
                );
            }
        }
        let stats = tower.session_stats().unwrap();
        // pass 1: tt=1 no lookup, tt∈{2,3,4} hit the previous insert;
        // pass 2: every tt≥2 hits again (duplicate re-inserts are dropped)
        assert_eq!(stats.hits, 6, "{stats:?}");
        assert_eq!(stats.misses, 0, "{stats:?}");
        assert_eq!(stats.len, 4, "{stats:?}");
        // ticket mismatch is an error, not a panic
        assert!(tower.forward_batch_ticketed(&pool, &[], &[1]).is_err());
    }

    #[test]
    fn capacity_one_sessions_thrash_but_stay_bit_exact() {
        let plain = transformer_tower();
        let tower = transformer_tower().with_sessions(1);
        let pool = WorkerPool::new(1);
        // two interleaved streams fighting over one slot: every lookup
        // whose session was evicted falls back to full recompute
        let streams: [&[usize]; 2] = [&[1, 2, 3, 4], &[5, 6, 7, 8]];
        let mut ticket = 0u64;
        for tt in 1..=4 {
            for s in streams {
                let req = tower.encode_request(&s[..tt]).unwrap();
                ticket += 1;
                let got = &tower
                    .forward_batch_ticketed(&pool, std::slice::from_ref(&req), &[ticket])
                    .unwrap()[0];
                let want =
                    &plain.forward_batch(&pool, std::slice::from_ref(&req)).unwrap()[0];
                assert!(
                    got.bit_eq(want),
                    "stream {s:?} len {tt}: eviction fallback changed bits"
                );
            }
        }
        let stats = tower.session_stats().unwrap();
        assert_eq!(stats.capacity, 1);
        assert!(stats.evictions > 0, "two streams over one slot must evict: {stats:?}");
        assert!(stats.misses > 0, "evicted prefixes must fall back: {stats:?}");
    }

    #[test]
    fn weights_hashes_distinguish_models_and_are_stable() {
        let a = mlp_tower();
        let b = MlpTower::new(Mlp::new(&[12, 16, 5], Act::Gelu, 3)).unwrap();
        let c = MlpTower::new(Mlp::new(&[12, 16, 5], Act::Gelu, 4)).unwrap();
        assert_eq!(a.weights_hash(), b.weights_hash(), "same init → same hash");
        assert_ne!(a.weights_hash(), c.weights_hash(), "different weights → different hash");
        assert_ne!(a.weights_hash(), transformer_tower().weights_hash());
    }

    #[test]
    fn named_tower_renames_without_touching_numerics() {
        let w = crate::rng::uniform_tensor(&[8, 3], -0.3, 0.3, 1);
        let srv = DeterministicServer::new(w, 4).unwrap();
        let pool = WorkerPool::new(1);
        let q: Vec<Tensor> = (0..3)
            .map(|i| crate::rng::uniform_tensor(&[8], -1.0, 1.0, 60 + i))
            .collect();
        let want = srv.process_repro_in(&pool, &q).unwrap();
        let named = NamedTower::new(srv, "linear-b");
        assert_eq!(named.model_id(), "linear-b");
        assert_eq!((named.d_in(), named.d_out()), (8, 3));
        assert_eq!(named.weights_hash(), named.inner().weights_hash());
        let got = named.forward_batch(&pool, &q).unwrap();
        for (a, b) in want.iter().zip(got.iter()) {
            assert!(a.bit_eq(b), "renaming a tower must not change bits");
        }
        // validation passes through too
        assert!(named.validate_request(&Tensor::zeros(&[7])).is_err());
    }

    fn tp4_transformer_cfg() -> TransformerConfig {
        // heads = 4 so every width in {1, 2, 4} divides the head count
        TransformerConfig { vocab: 10, dim: 8, heads: 4, layers: 2, context: 4, mlp_ratio: 2 }
    }

    #[test]
    fn sharded_towers_preserve_identity_and_are_tp_invariant() {
        let pool = WorkerPool::new(2);
        // mlp: identity (id, hash, dims) matches the unsharded tower;
        // response bits are pinned equal across every width
        let unsharded = mlp_tower();
        let batch: Vec<Tensor> =
            (0..3).map(|i| crate::rng::uniform_tensor(&[12], -1.0, 1.0, 80 + i)).collect();
        let mut want: Option<Vec<Tensor>> = None;
        for tp in [1usize, 2, 4] {
            let t = ShardedTower::mlp(Mlp::new(&[12, 16, 5], Act::Gelu, 3), tp).unwrap();
            assert_eq!(t.model_id(), "mlp");
            assert_eq!(t.weights_hash(), unsharded.weights_hash(), "hash must be TP-invariant");
            assert_eq!((t.d_in(), t.d_out(), t.tp()), (12, 5, tp));
            let outs = t.forward_batch(&pool, &batch).unwrap();
            match &want {
                None => want = Some(outs),
                Some(w) => {
                    for (a, b) in w.iter().zip(outs.iter()) {
                        assert!(a.bit_eq(b), "mlp tp={tp}: sharded response bits changed");
                    }
                }
            }
        }
        // transformer: same pins over a mixed-length prefix batch
        let cfg = tp4_transformer_cfg();
        let reference =
            TransformerTower::new(CharTransformer::new(cfg, 5).unwrap()).unwrap();
        let ids = [1usize, 7, 0, 9];
        let reqs: Vec<Tensor> =
            (1..=ids.len()).map(|tt| reference.encode_request(&ids[..tt]).unwrap()).collect();
        let mut want: Option<Vec<Tensor>> = None;
        for tp in [1usize, 2, 4] {
            let t = ShardedTower::transformer(CharTransformer::new(cfg, 5).unwrap(), tp).unwrap();
            assert_eq!(t.model_id(), "transformer");
            assert_eq!(t.weights_hash(), reference.weights_hash(), "hash must be TP-invariant");
            assert_eq!((t.d_in(), t.d_out(), t.tp()), (4, 10, tp));
            let outs = t.forward_batch(&pool, &reqs).unwrap();
            match &want {
                None => want = Some(outs),
                Some(w) => {
                    for (a, b) in w.iter().zip(outs.iter()) {
                        assert!(a.bit_eq(b), "transformer tp={tp}: sharded bits changed");
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_sessions_change_cost_never_bits_even_across_widths() {
        // the plain reference runs at tp=4, the session tower at tp=2:
        // a hit's one-step decode at one width must bit-match a full
        // recompute at another
        let cfg = tp4_transformer_cfg();
        let plain = ShardedTower::transformer(CharTransformer::new(cfg, 5).unwrap(), 4).unwrap();
        let tower = ShardedTower::transformer(CharTransformer::new(cfg, 5).unwrap(), 2)
            .unwrap()
            .with_sessions(8);
        assert!(plain.session_stats().is_none());
        let pool = WorkerPool::new(1);
        let ids = [3usize, 1, 7, 2];
        let mut ticket = 0u64;
        for _ in 0..2 {
            for tt in 1..=ids.len() {
                let req = tower.encode_request(&ids[..tt]).unwrap();
                ticket += 1;
                let got = &tower
                    .forward_batch_ticketed(&pool, std::slice::from_ref(&req), &[ticket])
                    .unwrap()[0];
                let want = &plain.forward_batch(&pool, std::slice::from_ref(&req)).unwrap()[0];
                assert!(
                    got.bit_eq(want),
                    "prefix {tt}: tp=2 session bits differ from tp=4 recompute"
                );
            }
        }
        let stats = tower.session_stats().unwrap();
        assert_eq!(stats.hits, 6, "{stats:?}");
        assert_eq!(stats.misses, 0, "{stats:?}");
        // ticket mismatch is an error, not a panic
        assert!(tower.forward_batch_ticketed(&pool, &[], &[1]).is_err());
    }

    #[test]
    fn sharded_tower_construction_and_validation_errors() {
        let cfg2 = TransformerConfig {
            vocab: 10,
            dim: 8,
            heads: 2,
            layers: 1,
            context: 4,
            mlp_ratio: 2,
        };
        // heads = 2 cannot split four ways; tp must be >= 1 and divide
        // the logical segment count
        assert!(ShardedTower::transformer(CharTransformer::new(cfg2, 1).unwrap(), 4).is_err());
        assert!(ShardedTower::transformer(CharTransformer::new(cfg2, 1).unwrap(), 0).is_err());
        assert!(ShardedTower::transformer(CharTransformer::new(cfg2, 1).unwrap(), 3).is_err());
        // a row-split width the 4-segment plan cannot divide fails at
        // every tp (the reduction graph is width-independent)
        assert!(ShardedTower::mlp(Mlp::new(&[12, 10, 5], Act::Gelu, 3), 1).is_err());
        assert!(ShardedTower::mlp(Mlp::new(&[12, 16, 5], Act::Gelu, 3), 0).is_err());
        // sessions are a transformer concern: a silent no-op on MLPs
        let t = ShardedTower::mlp(Mlp::new(&[12, 16, 5], Act::Gelu, 3), 2)
            .unwrap()
            .with_sessions(8);
        assert!(t.session_stats().is_none());
        // request validation mirrors the unsharded towers
        let t = ShardedTower::transformer(CharTransformer::new(tp4_transformer_cfg(), 1).unwrap(), 2)
            .unwrap();
        assert!(t.validate_request(&Tensor::zeros(&[0])).is_err());
        assert!(t.validate_request(&Tensor::zeros(&[5])).is_err());
        assert!(t.validate_request(&Tensor::from_vec(&[2], vec![1.0, 10.0]).unwrap()).is_err());
        assert!(t.encode_request(&[0, 9, 4]).is_ok());
    }

    #[test]
    fn towers_coerce_to_trait_objects() {
        let towers: Vec<Arc<dyn ModelTower>> = vec![
            Arc::new(
                DeterministicServer::new(crate::rng::uniform_tensor(&[8, 3], -0.3, 0.3, 1), 4)
                    .unwrap(),
            ),
            Arc::new(mlp_tower()),
            Arc::new(transformer_tower()),
        ];
        let ids: Vec<&str> = towers.iter().map(|t| t.model_id()).collect();
        assert_eq!(ids, vec!["linear", "mlp", "transformer"]);
        for t in &towers {
            assert!(!t.weights_hash().is_empty());
            assert!(t.d_in() > 0 && t.d_out() > 0);
        }
    }
}
